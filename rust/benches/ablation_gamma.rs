//! Ablation on the Theorem-1 parameter rules (§III-B):
//!
//! 1. γ: the paper's experiments run γ = 0 while (17) prescribes a
//!    worst-case γ ~ S(1+ρ²)(τ−1)²/2. How much does the proximal term cost
//!    or buy on a benign instance, and does it rescue an adversarial one?
//! 2. ρ: sweep ρ around the (16)/(18) thresholds on the non-convex
//!    sparse-PCA problem — the paper's "ρ must be large enough" claim.
//!
//! Run: `cargo bench --bench ablation_gamma` (AD_ADMM_BENCH_QUICK=1
//! shrinks). Emits `BENCH_ablation_gamma.json` next to the text output.

use ad_admm::admm::params::{gamma_lower_bound, rho_lower_bound_nonconvex};
use ad_admm::bench::json::{BenchReport, JsonValue};
use ad_admm::metrics::accuracy_series;
use ad_admm::prelude::*;
use ad_admm::util::Stopwatch;
use ad_admm::testkit::drivers::{run_full_barrier, run_partial_barrier};

fn main() {
    let quick = ad_admm::bench::quick_mode();
    let sw = Stopwatch::start();
    let mut json = BenchReport::new("ablation_gamma");
    // ---------------------------------------------------------- γ ablation
    let n_workers = 8;
    let tau = 8usize;
    let gamma_iters = if quick { 150 } else { 1500 };
    let mut rng = Pcg64::seed_from_u64(77);
    let inst = LassoInstance::synthetic(&mut rng, n_workers, 60, 40, 0.1, 0.1);
    let problem = inst.problem();
    let (_, f_star) = fista_lasso(&inst, if quick { 5_000 } else { 40_000 });
    let rho = 100.0;

    // Theorem-1 worst case with S = N (no arrival bound exploited).
    let gamma_thm = gamma_lower_bound(n_workers as f64, rho, tau, n_workers).max(0.0);
    println!("=== gamma ablation (LASSO N={n_workers}, tau={tau}, rho={rho}) ===");
    println!("Theorem-1 worst-case gamma = {gamma_thm:.3e} (paper's experiments use 0)\n");
    println!("{:>14} {:>10} {:>12} {:>12}", "gamma", "iters", "acc@500", "acc@final");
    for gamma in [0.0, 0.1 * gamma_thm, gamma_thm] {
        let cfg = AdmmConfig { rho, gamma, tau, max_iters: gamma_iters, ..Default::default() };
        let arrivals = ArrivalModel::fig3_profile(n_workers, 5);
        let out = run_partial_barrier(&problem, &cfg, &arrivals);
        let acc = accuracy_series(&out.history, f_star);
        let at500 = acc.get(499.min(acc.len() - 1)).copied().unwrap_or(f64::INFINITY);
        println!(
            "{:>14.4e} {:>10} {:>12.3e} {:>12.3e}",
            gamma,
            out.history.len(),
            at500,
            acc.last().unwrap()
        );
        json.series(vec![
            ("sweep", JsonValue::from("gamma")),
            ("gamma", JsonValue::Num(gamma)),
            ("final_accuracy", JsonValue::Num(*acc.last().unwrap())),
        ]);
    }
    println!("(expected: gamma=0 fastest on benign instances — the Theorem-1 value is a\n worst-case guarantee, trading speed for safety, exactly as §III-B discusses)");

    // ---------------------------------------------------------- ρ ablation
    println!("\n=== rho ablation (non-convex sparse PCA, N=8, sync) ===");
    let (spca_m, spca_n, spca_nnz) = if quick { (40, 20, 80) } else { (120, 60, 600) };
    let (rho_ref_iters, rho_iters) = if quick { (600, 300) } else { (6000, 3000) };
    let mut rng = Pcg64::seed_from_u64(78);
    let sinst = SparsePcaInstance::synthetic(&mut rng, 8, spca_m, spca_n, spca_nnz, 0.1);
    let sproblem = sinst.problem();
    let lam_max = sinst.max_lambda_max();
    let l = 2.0 * lam_max; // Lipschitz constant of ∇f_j
    let mut init = vec![0.0; spca_n];
    rng.fill_normal(&mut init);
    let nrm = init.iter().map(|v| v * v).sum::<f64>().sqrt();
    for v in init.iter_mut() {
        *v /= nrm;
    }
    let rho_rule = rho_lower_bound_nonconvex(l);
    println!("L = {l:.2}, Theorem-1 rho threshold (16) = {rho_rule:.2}");

    // reference from a clearly-convergent run
    let ref_cfg = AdmmConfig {
        rho: 3.0 * l,
        tau: 1,
        max_iters: rho_ref_iters,
        init_x0: Some(init.clone()),
        ..Default::default()
    };
    let f_hat = run_full_barrier(&sproblem, &ref_cfg).history.last().unwrap().aug_lagrangian;

    println!("{:>12} {:>10} {:>12} {:>10}", "rho/L", "rho", "acc@final", "stop");
    for beta in [1.0, 1.5, 1.9, 2.05, 3.0, 4.0] {
        let rho = beta * l;
        let cfg = AdmmConfig {
            rho,
            tau: 1,
            max_iters: rho_iters,
            init_x0: Some(init.clone()),
            ..Default::default()
        };
        let out = run_full_barrier(&sproblem, &cfg);
        let acc = accuracy_series(&out.history, f_hat);
        println!(
            "{:>12.2} {:>10.1} {:>12.3e} {:>10}",
            beta,
            rho,
            acc.last().unwrap(),
            format!("{:?}", out.stop)
        );
        json.series(vec![
            ("sweep", JsonValue::from("rho")),
            ("beta", JsonValue::Num(beta)),
            ("final_accuracy", JsonValue::Num(*acc.last().unwrap())),
            ("stop", JsonValue::from(format!("{:?}", out.stop))),
        ]);
    }
    println!("(expected: divergence below rho = 2L, where the worker-dual recursion's");
    println!(" amplification factor |L/(rho-L)| crosses 1; matches Fig. 3's beta=1.5-");
    println!(" diverges vs beta=3-converges contrast under rho = beta*L)");

    json.metric("total_real_s", sw.elapsed_s());
    let json_path = json.write().expect("write BENCH json");
    println!("machine-readable report → {}", json_path.display());
}
