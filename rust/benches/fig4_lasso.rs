//! Figure 4 reproduction: Algorithm 2 vs Algorithm 4 on LASSO (52),
//! accuracy (53) vs master iteration.
//!
//! Paper setup: N = 16 workers, A_i ∈ R^{200×n} ~ N(0,1), b_i = A_i w⁰ + ν,
//! θ = 0.1, γ = 0, arrivals 8×p=0.1 / 4×p=0.5 / 4×p=0.8, A = 1; F* is the
//! optimum of (52) (here: high-accuracy centralized FISTA).
//!
//! Panels:
//!   (a) n=100,  Algorithm 2, ρ=500, τ ∈ {1,3,10}   — converges everywhere
//!   (b) n=100,  Algorithm 4: ρ=500 diverges at τ=3; ρ=10 ok at τ=3;
//!       ρ=1 needed at τ=10 (and is much slower)
//!   (c) n=1000, Algorithm 2, ρ=500, τ ∈ {1,3,10}   — still converges
//!   (d) n=1000, Algorithm 4 diverges for every ρ even at τ=2
//!
//! Run: `cargo bench --bench fig4_lasso` (AD_ADMM_BENCH_QUICK=1 for the
//! shared reduced-size quick mode). Emits `BENCH_fig4_lasso.json` next to
//! the text output.

use ad_admm::bench::json::{BenchReport, JsonValue};
use ad_admm::metrics::rate::fit_linear_rate;
use ad_admm::metrics::{accuracy_series, write_curves, RunLog};
use ad_admm::util::plot::{render_log_curves, Series};
use ad_admm::prelude::*;
use ad_admm::util::Stopwatch;
use ad_admm::testkit::drivers::{run_alt, run_partial_barrier};

struct Panel {
    name: &'static str,
    n: usize,
    alg2: bool,
    // (rho, tau) sweep
    settings: Vec<(f64, usize)>,
    expected: &'static str,
}

fn main() {
    let quick = ad_admm::bench::quick_mode();
    let (n_workers, m, iters) = if quick { (8, 60, 400) } else { (16, 200, 2000) };
    let (n_small, n_large) = if quick { (30, 120) } else { (100, 1000) };
    let theta = 0.1;
    let sw = Stopwatch::start();
    let mut json = BenchReport::new("fig4_lasso");
    json.config("n_workers", n_workers).config("block_rows", m).config("iters", iters);

    let panels = vec![
        Panel {
            name: "4a_alg2_small",
            n: n_small,
            alg2: true,
            settings: vec![(500.0, 1), (500.0, 3), (500.0, 10)],
            expected: "Algorithm 2 converges for every tau at rho=500",
        },
        Panel {
            name: "4b_alg4_small",
            n: n_small,
            alg2: false,
            settings: vec![
                (500.0, 1),
                (500.0, 3),
                (10.0, 3),
                (10.0, 10),
                (1.0, 10),
            ],
            expected: "Algorithm 4: rho=500 ok at tau=1 but diverges at tau=3; smaller rho converges slowly",
        },
        Panel {
            name: "4c_alg2_large",
            n: n_large,
            alg2: true,
            settings: vec![(500.0, 1), (500.0, 3), (500.0, 10)],
            expected: "Algorithm 2 still converges (f_i not strongly convex)",
        },
        Panel {
            name: "4d_alg4_large",
            n: n_large,
            alg2: false,
            settings: vec![(500.0, 2), (10.0, 2), (1.0, 2), (1.0, 3)],
            expected: "Algorithm 4 diverges for every rho once tau>=2",
        },
    ];

    for panel in panels {
        println!("\n=== Fig. {} (n={}): {} ===", panel.name, panel.n, panel.expected);
        let mut rng = Pcg64::seed_from_u64(44);
        let inst = LassoInstance::synthetic(&mut rng, n_workers, m, panel.n, 0.05, theta);
        let problem = inst.problem();
        let (_, f_star) = fista_lasso(&inst, if quick { 20_000 } else { 60_000 });
        println!("F* = {f_star:.8e}");
        println!("{:>8} {:>6} {:>12} {:>12} {:>12}", "rho", "tau", "acc@500", "acc@final", "stop");

        let mut curves = Vec::new();
        for &(rho, tau) in &panel.settings {
            let cfg = AdmmConfig { rho, tau, max_iters: iters, ..Default::default() };
            let arrivals = ArrivalModel::fig4_profile(n_workers, 7 * tau as u64 + rho as u64);
            let (history, stop) = if panel.alg2 {
                let out = run_partial_barrier(&problem, &cfg, &arrivals);
                (out.history, format!("{:?}", out.stop))
            } else {
                let out = run_alt(&problem, &cfg, &arrivals);
                (out.history, format!("{:?}", out.stop))
            };
            let acc = accuracy_series(&history, f_star);
            let at500 = acc.get(499.min(acc.len() - 1)).copied().unwrap_or(f64::INFINITY);
            println!(
                "{:>8} {:>6} {:>12.3e} {:>12.3e} {:>12}",
                rho,
                tau,
                at500,
                acc.last().unwrap(),
                stop
            );
            curves.push(RunLog::new(format!("{}_rho{}_tau{}", panel.name, rho, tau), history));
        }

        let acc_series: Vec<Vec<f64>> = curves
            .iter()
            .map(|c| accuracy_series(&c.history, f_star))
            .collect();
        let plot_series: Vec<Series> = curves
            .iter()
            .zip(&acc_series)
            .map(|(c, ys)| Series { label: &c.label, ys })
            .collect();
        println!(
            "\naccuracy (53) vs iteration (log scale):\n{}",
            render_log_curves(&plot_series, 72, 16)
        );
        for (c, ys) in curves.iter().zip(&acc_series) {
            if let Some(fit) = fit_linear_rate(ys, 0.8) {
                if fit.is_linear() {
                    println!("  {}: empirically linear, rate {:.4}", c.label, fit.rate);
                }
            }
        }

        let path = ad_admm::bench::results_dir().join(format!("fig{}.csv", panel.name));
        write_curves(&path, &curves, f_star).expect("write csv");
        println!("series → {}", path.display());

        for c in &curves {
            json.series(vec![
                ("label", JsonValue::from(c.label.as_str())),
                ("final_accuracy", JsonValue::Num(c.final_accuracy(f_star))),
                (
                    "iters_to_1e-2",
                    match c.iters_to_accuracy(f_star, 1e-2) {
                        Some(k) => JsonValue::Num(k as f64),
                        None => JsonValue::Null,
                    },
                ),
            ]);
        }
    }

    json.metric("total_real_s", sw.elapsed_s());
    let json_path = json.write().expect("write BENCH json");
    println!("machine-readable report → {}", json_path.display());
    println!("\ntotal {:.1}s", sw.elapsed_s());
}
