//! Figure 2 reproduction: sync vs async timelines on a heterogeneous
//! 4-worker star — update counts, idle fractions, master wait.
//!
//! The paper's illustration: under the synchronous protocol the master and
//! the fast workers idle while waiting for the slowest worker; under the
//! asynchronous protocol (A=2 in the figure) everyone updates far more
//! often in the same wall-clock window.
//!
//! Expected shape: async completes ~2-4x more master iterations in the same
//! time; fast workers' idle% drops sharply.
//!
//! Run: `cargo bench --bench fig2_timeline` (AD_ADMM_BENCH_QUICK=1
//! shrinks). Emits `BENCH_fig2_timeline.json` next to the text output.

use ad_admm::bench::json::{BenchReport, JsonValue};
use ad_admm::cluster::{ClusterConfig, Protocol};
use ad_admm::prelude::*;
use ad_admm::util::CsvWriter;

fn main() {
    let quick = ad_admm::bench::quick_mode();
    let n_workers = 4;
    let mut rng = Pcg64::seed_from_u64(2);
    let inst = LassoInstance::synthetic(&mut rng, n_workers, 40, 20, 0.1, 0.1);
    let problem = inst.problem();

    // Fig. 2's heterogeneity: workers 1/3 fast, 2/4 slow.
    let per_worker_ms = if quick {
        vec![0.1, 0.6, 0.15, 0.8]
    } else {
        vec![1.0, 6.0, 1.5, 8.0]
    };
    let iters = if quick { 20 } else { 120 };
    println!(
        "=== Fig. 2: sync vs async timeline (N=4, worker delays {per_worker_ms:?} ms) ==="
    );
    let delays = DelayModel::Fixed { per_worker_ms };
    let mut json = BenchReport::new("fig2_timeline");
    json.config("n_workers", n_workers).config("iters", iters);
    let mut rows = Vec::new();
    for (label, tau, min_arrivals) in [("sync", 1usize, n_workers), ("async", 8, 2)] {
        let cfg = ClusterConfig::builder()
            .admm(AdmmConfig {
                rho: 50.0,
                tau,
                min_arrivals,
                max_iters: iters,
                ..Default::default()
            })
            .protocol(Protocol::AdAdmm)
            .delays(delays.clone())
            .build()
            .expect("valid cluster config");
        let r = StarCluster::new(problem.clone()).run(&cfg);
        println!("\n--- {label} (tau={tau}, A={min_arrivals}) ---");
        println!(
            "master: {} iterations in {:.3}s ({:.1} iters/s), waited {:.3}s ({:.0}% of wall)",
            r.history.len(),
            r.wall_clock_s,
            r.iters_per_sec(),
            r.master_wait_s,
            100.0 * r.master_wait_s / r.wall_clock_s.max(1e-9),
        );
        println!("worker  updates  busy[s]  idle%");
        for w in &r.workers {
            println!(
                "{:>6}  {:>7}  {:>7.3}  {:>5.1}",
                w.id,
                w.updates,
                w.busy_s,
                100.0 * w.idle_fraction()
            );
            rows.push(vec![
                if label == "sync" { 0.0 } else { 1.0 },
                w.id as f64,
                w.updates as f64,
                w.busy_s,
                w.idle_fraction(),
            ]);
        }
        rows.push(vec![
            if label == "sync" { 0.0 } else { 1.0 },
            -1.0, // master row
            r.history.len() as f64,
            r.wall_clock_s - r.master_wait_s,
            r.master_wait_s / r.wall_clock_s.max(1e-9),
        ]);
        json.metric(&format!("{label}_iters_per_sec"), r.iters_per_sec());
        json.metric(&format!("{label}_master_wait_s"), r.master_wait_s);
        json.series(vec![
            ("label", JsonValue::from(label)),
            ("iters", JsonValue::Num(r.history.len() as f64)),
            ("wall_clock_s", JsonValue::Num(r.wall_clock_s)),
            ("iters_per_sec", JsonValue::Num(r.iters_per_sec())),
        ]);
    }

    let path = ad_admm::bench::results_dir().join("fig2_timeline.csv");
    let mut w = CsvWriter::create(&path, &["is_async", "worker", "updates", "busy_s", "idle_frac"])
        .expect("csv");
    for row in &rows {
        w.row(row).unwrap();
    }
    w.flush().unwrap();
    println!("\nseries → {}", path.display());
    let json_path = json.write().expect("write BENCH json");
    println!("machine-readable report → {}", json_path.display());
}
