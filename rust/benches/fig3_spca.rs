//! Figure 3 reproduction: AD-ADMM on the non-convex sparse-PCA problem
//! (50), accuracy (51) vs master iteration, for τ ∈ {1, 5, 10, 20} at
//! β = 3 and the divergent β = 1.5.
//!
//! Paper setup: N = 32 workers, B_j ∈ R^{1000×500} sparse with ≈5000
//! non-zeros, θ = 0.1, ρ = β·max_j λmax(B_jᵀB_j), γ = 0, arrivals half
//! p=0.1 / half p=0.8, A = 1; F̂ from 10 000 synchronous iterations (β=3).
//!
//! Expected shape (paper): convergent curves for every τ at β = 3 (larger τ
//! slightly slower in iterations), divergence at β = 1.5.
//!
//! Run: `cargo bench --bench fig3_spca` (AD_ADMM_BENCH_QUICK=1 for the
//! shared reduced-size quick mode). Emits `BENCH_fig3_spca.json` next to
//! the text output.

use ad_admm::bench::json::{BenchReport, JsonValue};
use ad_admm::metrics::rate::fit_linear_rate;
use ad_admm::metrics::{accuracy_series, write_curves, RunLog};
use ad_admm::util::plot::{render_log_curves, Series};
use ad_admm::prelude::*;
use ad_admm::util::Stopwatch;
use ad_admm::testkit::drivers::{run_full_barrier, run_partial_barrier};

fn main() {
    let quick = ad_admm::bench::quick_mode();
    // Paper scale by default; quick mode for smoke runs.
    let (n_workers, m, n, nnz, iters, ref_iters) = if quick {
        (8, 100, 50, 500, 300, 2000)
    } else {
        (32, 1000, 500, 5000, 1500, 10_000)
    };
    let theta = 0.1;

    println!("=== Fig. 3: sparse PCA, N={n_workers}, B_j {m}x{n} ({nnz} nnz), theta={theta} ===");
    let sw = Stopwatch::start();
    let mut rng = Pcg64::seed_from_u64(33);
    let inst = SparsePcaInstance::synthetic(&mut rng, n_workers, m, n, nnz, theta);
    let problem = inst.problem();
    let lam_max = inst.max_lambda_max();
    println!("max λmax(BᵀB) = {lam_max:.4}  (setup {:.1}s)", sw.elapsed_s());

    // Non-convex: start from a random unit vector (x = 0 is a fixed point).
    let mut init = vec![0.0; n];
    rng.fill_normal(&mut init);
    let nrm = init.iter().map(|v| v * v).sum::<f64>().sqrt();
    for v in init.iter_mut() {
        *v /= nrm;
    }

    // F̂: 10k synchronous iterations at β = 3 (paper protocol).
    let lip = 2.0 * lam_max; // Lipschitz constant of grad f_j
    let rho3 = 3.0 * lip;
    let ref_cfg = AdmmConfig {
        rho: rho3,
        tau: 1,
        max_iters: ref_iters,
        init_x0: Some(init.clone()),
        ..Default::default()
    };
    let f_hat = run_full_barrier(&problem, &ref_cfg).history.last().unwrap().aug_lagrangian;
    println!("F̂ = {f_hat:.8e}");

    let mut curves = Vec::new();
    println!("\nβ = 3 (Theorem-1 regime — paper: converges for all tau):");
    println!("{:>6} {:>12} {:>12} {:>10}", "tau", "acc@250", "acc@final", "iters");
    for tau in [1usize, 5, 10, 20] {
        let cfg = AdmmConfig {
            rho: rho3,
            tau,
            max_iters: iters,
            init_x0: Some(init.clone()),
            ..Default::default()
        };
        let arrivals = ArrivalModel::fig3_profile(n_workers, 100 + tau as u64);
        let out = run_partial_barrier(&problem, &cfg, &arrivals);
        let acc = accuracy_series(&out.history, f_hat);
        let at250 = acc.get(249.min(acc.len() - 1)).copied().unwrap_or(f64::INFINITY);
        println!(
            "{:>6} {:>12.3e} {:>12.3e} {:>10}",
            tau,
            at250,
            acc.last().unwrap(),
            out.history.len()
        );
        curves.push(RunLog::new(format!("beta3_tau{tau}"), out.history));
    }

    println!("\nβ = 1.5 (rho below the non-convex requirement — paper: diverges):");
    let rho15 = 1.5 * lip;
    for tau in [1usize, 10] {
        let cfg = AdmmConfig {
            rho: rho15,
            tau,
            max_iters: iters,
            init_x0: Some(init.clone()),
            ..Default::default()
        };
        let arrivals = ArrivalModel::fig3_profile(n_workers, 200 + tau as u64);
        let out = run_partial_barrier(&problem, &cfg, &arrivals);
        let acc = accuracy_series(&out.history, f_hat);
        println!(
            "  tau={tau}: stop={:?}, final accuracy {:.3e}",
            out.stop,
            acc.last().unwrap()
        );
        curves.push(RunLog::new(format!("beta1.5_tau{tau}"), out.history));
    }

    // terminal rendition of the figure + Part-II-style rate fits
    let acc_series: Vec<Vec<f64>> = curves
        .iter()
        .map(|c| accuracy_series(&c.history, f_hat))
        .collect();
    let plot_series: Vec<Series> = curves
        .iter()
        .zip(&acc_series)
        .map(|(c, ys)| Series { label: &c.label, ys })
        .collect();
    println!(
        "\naccuracy (51) vs iteration (log scale):\n{}",
        render_log_curves(&plot_series, 72, 18)
    );
    for (c, ys) in curves.iter().zip(&acc_series) {
        if let Some(fit) = fit_linear_rate(ys, 0.8) {
            if fit.is_linear() {
                println!(
                    "  {}: empirically linear, rate {:.4} ({:.1} iters/digit)",
                    c.label,
                    fit.rate,
                    fit.iters_per_digit()
                );
            }
        }
    }

    let path = ad_admm::bench::results_dir().join("fig3_spca.csv");
    write_curves(&path, &curves, f_hat).expect("write csv");

    let mut json = BenchReport::new("fig3_spca");
    json.config("n_workers", n_workers)
        .config("block_rows", m)
        .config("dim", n)
        .config("iters", iters)
        .metric("total_real_s", sw.elapsed_s());
    for c in &curves {
        json.series(vec![
            ("label", JsonValue::from(c.label.as_str())),
            ("final_accuracy", JsonValue::Num(c.final_accuracy(f_hat))),
            (
                "iters_to_1e-2",
                match c.iters_to_accuracy(f_hat, 1e-2) {
                    Some(k) => JsonValue::Num(k as f64),
                    None => JsonValue::Null,
                },
            ),
        ]);
    }
    let json_path = json.write().expect("write BENCH json");
    println!("machine-readable report → {}", json_path.display());
    println!("\nseries written to {} ({:.1}s total)", path.display(), sw.elapsed_s());
}
