//! Inexact warm-started worker solves: exact Newton subproblems vs k-step
//! gradient inner loops ([`InexactPolicy`]), on the logistic consensus
//! problem where the exact solve is genuinely expensive (damped Newton
//! with a fresh Hessian per inner iteration).
//!
//! Two sections:
//!
//! 1. **Speedup sweep** — the same virtual-time cluster run under
//!    `exact` and `grad:k` for k ∈ {1, 5, 20}. The simulated schedule is
//!    identical across policies (delays do not depend on iterate values),
//!    so the *real* seconds the simulation takes are a direct measure of
//!    worker-solve cost. Emits the headline `inexact_speedup` metric
//!    (exact real-time / `grad:5` real-time, asserted > 1 in-bench and
//!    grepped by the CI bench-smoke job) plus the accuracy each policy
//!    reached on the same iteration budget.
//!
//! 2. **Divergence row** — the pinned "k too small" failure: one
//!    gradient step per round on the nonconvex sparse-PCA subproblem with
//!    ρ far below the paper's `ρ ≥ 2λmax(AᵀA)` convexification bound
//!    (Section V-B). The exact solve of the same indefinite stationary
//!    system stays bounded over the budget while the warm-started
//!    single-step iterate grows along the negative-curvature direction
//!    until the divergence guard fires — asserted via [`StopReason`].
//!
//! Run: `cargo bench --bench inexact_sweep` (AD_ADMM_BENCH_QUICK=1
//! shrinks). Emits `BENCH_inexact_sweep.json` next to the text output.

use std::time::Instant;

use ad_admm::bench::json::{BenchReport, JsonValue};
use ad_admm::cluster::ExecutionMode;
use ad_admm::prelude::*;
use ad_admm::solvers::fista::fista;
use ad_admm::util::CsvWriter;

fn main() {
    let quick = ad_admm::bench::quick_mode();
    let mut json = BenchReport::new("inexact_sweep");

    // --- Section 1: wall-clock speedup on logistic regression ------------
    let n_workers = if quick { 4 } else { 8 };
    let m = if quick { 60 } else { 150 };
    let n = if quick { 32 } else { 64 };
    let iters = if quick { 25 } else { 100 };
    let fista_iters = if quick { 5_000 } else { 30_000 };
    json.config("n_workers", n_workers as f64);
    json.config("m_per_worker", m as f64);
    json.config("dim", n as f64);
    json.config("iters", iters as f64);

    let mut rng = Pcg64::seed_from_u64(4242);
    let inst = LogisticInstance::synthetic(&mut rng, n_workers, m, n, 0.02);
    let problem = inst.problem();
    let rho = problem.lipschitz().max(1.0);
    let f_star = fista(&problem, fista_iters, 1e-12).objective;
    let delays = DelayModel::linear_spread(n_workers, 0.5, 4.0, 0.3, 11);

    // One deterministic virtual-time run per policy; real (host) seconds
    // measure the solve cost, best-of-3 to damp scheduler noise. The runs
    // are bit-identical across repeats, so min() is sound.
    let run = |policy: InexactPolicy| -> (ClusterReport, f64) {
        let mut best = f64::INFINITY;
        let mut report = None;
        for _ in 0..3 {
            let cfg = ClusterConfig::builder()
                .admm(AdmmConfig {
                    rho,
                    tau: 8,
                    min_arrivals: 1,
                    max_iters: iters,
                    inexact: policy,
                    ..Default::default()
                })
                .delays(delays.clone())
                .mode(ExecutionMode::VirtualTime)
                .build()
                .expect("valid cluster config");
            let t = Instant::now();
            let r = StarCluster::new(problem.clone()).run(&cfg);
            best = best.min(t.elapsed().as_secs_f64());
            report = Some(r);
        }
        (report.expect("at least one run"), best)
    };

    println!("=== inexact worker solves: logistic, N={n_workers}, m={m}, n={n}, {iters} iters ===");
    println!(
        "{:>10} {:>12} {:>9} {:>14} {:>12}",
        "policy", "real time", "speedup", "objective", "gap to F*"
    );

    let csv_path = ad_admm::bench::results_dir().join("inexact_sweep.csv");
    let mut csv =
        CsvWriter::create(&csv_path, &["k", "real_s", "speedup", "objective", "gap"]).expect("csv");

    let policies = [
        InexactPolicy::Exact,
        InexactPolicy::GradSteps { k: 1 },
        InexactPolicy::GradSteps { k: 5 },
        InexactPolicy::GradSteps { k: 20 },
    ];
    // Exact runs first, so its time is available as every later row's
    // denominator.
    let mut exact_s = f64::NAN;
    let mut exact_gap = f64::NAN;
    let mut grad5_s = f64::NAN;
    for &policy in &policies {
        let (r, real_s) = run(policy);
        assert!(
            r.stop != StopReason::Diverged,
            "policy {policy} diverged on the convex logistic problem"
        );
        let obj = r.history.last().unwrap().objective;
        let gap = obj - f_star;
        if policy.is_exact() {
            exact_s = real_s;
            exact_gap = gap;
        }
        let speedup = exact_s / real_s.max(1e-12);
        if policy == (InexactPolicy::GradSteps { k: 5 }) {
            grad5_s = real_s;
        }
        // A local String: `{:>10}` needs Display-with-padding, and the
        // policy's Display impl writes through unpadded.
        let label = policy.to_string();
        println!(
            "{:>10} {:>12} {:>8.2}x {:>14.6} {:>12.3e}",
            label,
            ad_admm::bench::BenchStats::human(real_s),
            speedup,
            obj,
            gap,
        );
        let k = match policy {
            InexactPolicy::GradSteps { k } => k as f64,
            _ => 0.0,
        };
        csv.row(&[k, real_s, speedup, obj, gap]).unwrap();
        json.series(vec![
            ("section", JsonValue::Str("speedup".to_string())),
            ("policy", JsonValue::Str(policy.to_string())),
            ("real_s", JsonValue::Num(real_s)),
            ("speedup_vs_exact", JsonValue::Num(speedup)),
            ("objective", JsonValue::Num(obj)),
            ("gap", JsonValue::Num(gap)),
            ("iters", JsonValue::Num(r.history.len() as f64)),
        ]);
    }
    csv.flush().unwrap();

    // Headline metric: the CI bench-smoke job asserts this is > 1 from the
    // JSON. grad:5 (not the fastest grad:1) is the pinned numerator so the
    // claim is "a *useful* inexact setting beats exact", not a degenerate
    // one.
    let inexact_speedup = exact_s / grad5_s.max(1e-12);
    json.metric("inexact_speedup", inexact_speedup);
    json.metric("exact_run_s", exact_s);
    json.metric("grad5_run_s", grad5_s);
    println!("\ninexact_speedup (exact / grad:5 real time) = {inexact_speedup:.2}x");
    assert!(
        inexact_speedup > 1.0,
        "5-step gradient inner loop must beat exact Newton solves: {inexact_speedup}"
    );
    println!("exact gap after {iters} iters: {exact_gap:.3e} (inexact gaps above)");

    // --- Section 2: pinned divergence when k is too small -----------------
    // Sparse PCA with ρ = 0.1·max_i λmax(B_iᵀB_i): every worker subproblem
    // Hessian ρI − 2B_iᵀB_i is indefinite (ρ is far below the 2λmax
    // convexification bound), so a warm-started single gradient step
    // amplifies the top-eigenvector component by ≈ 1 + (2λmax−ρ)/(2λmax+ρ)
    // per absorption — geometric blow-up. The exact path solves the same
    // indefinite stationary system directly (bounded LU solve), and its
    // dual recursion grows only like 1 + ρ/(2λmax−ρ) ≈ 1.05 — far from the
    // 1e12 guard within this budget.
    let div_iters = if quick { 120 } else { 250 };
    let mut rng2 = Pcg64::seed_from_u64(77);
    let spca = SparsePcaInstance::synthetic(&mut rng2, 4, 30, 16, 8, 0.1);
    let spca_problem = spca.problem();
    let rho_low = 0.1 * spca.max_lambda_max();
    let div_delays = DelayModel::linear_spread(4, 0.5, 3.0, 0.3, 5);
    let run_spca = |policy: InexactPolicy| {
        let cfg = ClusterConfig::builder()
            .admm(AdmmConfig {
                rho: rho_low,
                tau: 4,
                min_arrivals: 1,
                max_iters: div_iters,
                // x = 0 is a stationary point of the PCA objective; the
                // paper's runs start from a nonzero x₀ for the same reason.
                init_x0: Some(vec![0.3; spca.dim()]),
                inexact: policy,
                ..Default::default()
            })
            .delays(div_delays.clone())
            .mode(ExecutionMode::VirtualTime)
            .build()
            .expect("valid cluster config");
        StarCluster::new(spca_problem.clone()).run(&cfg)
    };

    println!("\n=== divergence when k is too small: sparse PCA, rho = 0.1 lambda_max ===");
    let diverged = run_spca(InexactPolicy::GradSteps { k: 1 });
    let bounded = run_spca(InexactPolicy::Exact);
    println!(
        "grad:1  stop = {:?} after {} iters (guard at |L| > 1e12)",
        diverged.stop,
        diverged.history.len()
    );
    println!("exact   stop = {:?} after {} iters", bounded.stop, bounded.history.len());
    assert_eq!(
        diverged.stop,
        StopReason::Diverged,
        "one gradient step per round must diverge on the indefinite subproblem"
    );
    assert!(
        bounded.stop != StopReason::Diverged,
        "the exact solve must stay bounded over the same budget"
    );
    json.series(vec![
        ("section", JsonValue::Str("divergence".to_string())),
        ("policy", JsonValue::Str("grad:1".to_string())),
        ("stop", JsonValue::Str(format!("{:?}", diverged.stop))),
        ("diverged_at_iter", JsonValue::Num(diverged.history.len() as f64)),
    ]);
    json.series(vec![
        ("section", JsonValue::Str("divergence".to_string())),
        ("policy", JsonValue::Str("exact".to_string())),
        ("stop", JsonValue::Str(format!("{:?}", bounded.stop))),
        ("diverged_at_iter", JsonValue::Num(f64::NAN)),
    ]);

    let json_path = json.write().expect("write BENCH json");
    println!("\nmachine-readable report → {}", json_path.display());
    println!("series → {}", csv_path.display());
    println!("note: same master schedule per policy — the win is pure worker-solve cost;");
    println!("accuracy after the fixed budget is the price (gap column), per arXiv:1412.6058.");
}
