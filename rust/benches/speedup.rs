//! Part-II teaser: wall-clock speedup of the asynchronous protocol over the
//! synchronous baseline as the cluster grows, on the threaded star cluster
//! with heterogeneous (log-normal) worker delays.
//!
//! Expected shape (per the paper family's claims): the async/sync
//! iteration-rate ratio grows with N and with delay heterogeneity, because
//! the sync master is rate-limited by the slowest worker while the async
//! master proceeds at the A-th fastest.
//!
//! Run: `cargo bench --bench speedup` (AD_ADMM_BENCH_QUICK=1 shrinks).
//! Emits `BENCH_speedup.json` next to the text output.

use ad_admm::bench::json::{BenchReport, JsonValue};
use ad_admm::cluster::{ClusterConfig, Protocol};
use ad_admm::metrics::accuracy_series;
use ad_admm::prelude::*;
use ad_admm::util::CsvWriter;

fn main() {
    let quick = ad_admm::bench::quick_mode();
    let mut json = BenchReport::new("speedup");
    let iters = if quick { 25 } else { 150 };
    let fista_iters = if quick { 5_000 } else { 30_000 };
    let worker_counts: &[usize] = if quick { &[2, 4] } else { &[2, 4, 8, 16] };
    println!("=== wall-clock speedup: async (tau=8, A=1) vs sync, lognormal delays 0.5-6 ms ===");
    println!(
        "{:>4} {:>12} {:>12} {:>9} {:>12} {:>12}",
        "N", "sync it/s", "async it/s", "speedup", "sync acc", "async acc"
    );

    let path = ad_admm::bench::results_dir().join("speedup.csv");
    let mut csv = CsvWriter::create(
        &path,
        &["n_workers", "sync_iters_per_s", "async_iters_per_s", "speedup", "sync_acc", "async_acc"],
    )
    .expect("csv");

    for &n_workers in worker_counts {
        let mut rng = Pcg64::seed_from_u64(900 + n_workers as u64);
        let inst = LassoInstance::synthetic(&mut rng, n_workers, 60, 30, 0.1, 0.1);
        let problem = inst.problem();
        let (_, f_star) = fista_lasso(&inst, fista_iters);
        let delays = DelayModel::linear_spread(n_workers, 0.5, 6.0, 0.4, 17);

        let run = |tau: usize, min_arrivals: usize| {
            let cfg = ClusterConfig::builder()
                .admm(AdmmConfig {
                    rho: 100.0,
                    tau,
                    min_arrivals,
                    max_iters: iters,
                    ..Default::default()
                })
                .protocol(Protocol::AdAdmm)
                .delays(delays.clone())
                .build()
                .expect("valid cluster config");
            StarCluster::new(problem.clone()).run(&cfg)
        };

        let sync = run(1, n_workers);
        let asyn = run(8, 1);
        let speedup = asyn.iters_per_sec() / sync.iters_per_sec().max(1e-12);
        let sync_acc = *accuracy_series(&sync.history, f_star).last().unwrap();
        let async_acc = *accuracy_series(&asyn.history, f_star).last().unwrap();
        println!(
            "{:>4} {:>12.1} {:>12.1} {:>8.2}x {:>12.3e} {:>12.3e}",
            n_workers,
            sync.iters_per_sec(),
            asyn.iters_per_sec(),
            speedup,
            sync_acc,
            async_acc,
        );
        csv.row(&[
            n_workers as f64,
            sync.iters_per_sec(),
            asyn.iters_per_sec(),
            speedup,
            sync_acc,
            async_acc,
        ])
        .unwrap();
        json.series(vec![
            ("n_workers", JsonValue::Num(n_workers as f64)),
            ("sync_iters_per_sec", JsonValue::Num(sync.iters_per_sec())),
            ("async_iters_per_sec", JsonValue::Num(asyn.iters_per_sec())),
            ("async_over_sync", JsonValue::Num(speedup)),
        ]);
        json.metric(&format!("async_speedup_n{n_workers}"), speedup);
    }
    csv.flush().unwrap();
    let json_path = json.write().expect("write BENCH json");
    println!("\nmachine-readable report → {}", json_path.display());
    println!("series → {}", path.display());
    println!("note: same iteration budget — async trades per-iteration progress for rate;");
    println!("the paper's claim is wall-clock time-to-accuracy, dominated by the rate win.");
}
