//! Virtual-time scale sweeps: the Section-V protocol-parameter studies
//! (τ gate, `|A_k| ≥ A` batching gate) at worker counts the wall-clock
//! threaded cluster cannot reach — 1000+ workers, hundreds of master
//! iterations, all in deterministic simulated time — plus the pooled
//! multicore execution study (serial vs `pool_threads = 0` on a
//! CPU-heavy worker fleet, asserted bit-identical), a 10⁵-worker
//! (quick) / 10⁶-worker (full) fleet sweep over the O(active) sparse
//! master, an M ∈ {1, 2, 4, 8} multi-master sweep over the same fleet
//! (per-master busy/byte meters; `multimaster_speedup` ratios the single
//! coordinator against the bottleneck master at M = 4), and a
//! sparse-vs-eager master A/B asserted bit-identical.
//!
//! Reported per setting: simulated wall-clock, simulated master wait,
//! simulated iterations/second, realized max |A_k|, final objective, and
//! the real time the *simulation itself* took (the number that makes this
//! CI-viable).
//!
//! Run: `cargo bench --bench virtual_scale` (AD_ADMM_BENCH_QUICK=1
//! shrinks). Emits `BENCH_virtual_scale.json` next to the text output.

use std::sync::Arc;
use std::time::Instant;

use ad_admm::admm::session::Session;
use ad_admm::admm::StopReason;
use ad_admm::bench::json::{BenchReport, JsonValue};
use ad_admm::bench::quick_mode;
use ad_admm::cluster::{ClusterConfig, ClusterReport, ExecutionMode, MasterGroup};
use ad_admm::prelude::*;
use ad_admm::problems::{LocalCost, QuadraticLocal};
use ad_admm::prox::Regularizer;
use ad_admm::util::CsvWriter;

fn quadratic_consensus(n_workers: usize, dim: usize, seed: u64) -> ConsensusProblem {
    let mut rng = Pcg64::seed_from_u64(seed);
    let locals: Vec<Arc<dyn LocalCost>> = (0..n_workers)
        .map(|_| {
            let diag: Vec<f64> = (0..dim).map(|_| 0.5 + rng.uniform()).collect();
            let q: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
            Arc::new(QuadraticLocal::diagonal(&diag, q)) as Arc<dyn LocalCost>
        })
        .collect();
    ConsensusProblem::new(locals, Regularizer::L1 { theta: 0.05 })
}

/// A CPU-heavy fleet: every worker shares one dense SPD `Q` (spectral norm
/// computed once, reused via `with_lipschitz`) with its own linear term, so
/// per-round work is a dense backsolve + dense eval — enough arithmetic
/// per worker for the pool to show multicore speedup.
fn dense_consensus(n_workers: usize, dim: usize, seed: u64) -> ConsensusProblem {
    let mut rng = Pcg64::seed_from_u64(seed);
    let a = DenseMatrix::randn(&mut rng, dim, dim);
    let mut q_mat = a.gram();
    q_mat.add_diag(1.0);
    let lip = {
        let probe = QuadraticLocal::new(q_mat.clone(), vec![0.0; dim]);
        probe.lipschitz()
    };
    let locals: Vec<Arc<dyn LocalCost>> = (0..n_workers)
        .map(|_| {
            let q: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
            Arc::new(QuadraticLocal::with_lipschitz(q_mat.clone(), q, lip)) as Arc<dyn LocalCost>
        })
        .collect();
    ConsensusProblem::new(locals, Regularizer::L1 { theta: 0.05 })
}

/// A block-sharded fleet: one coordinate block per worker (length
/// `block_len`), each block owned by `copies` workers round-robin, every
/// worker holding a diagonal quadratic over just its owned slice. Local
/// state is `O(copies·block_len)` per worker, so this scales to thousands
/// of workers where a dense per-worker problem could not.
fn sharded_consensus(
    n_workers: usize,
    block_len: usize,
    copies: usize,
    seed: u64,
) -> (ConsensusProblem, BlockPattern) {
    let n = n_workers * block_len;
    let pattern = BlockPattern::round_robin(n, n_workers, n_workers, copies)
        .expect("round-robin pattern is valid");
    let mut rng = Pcg64::seed_from_u64(seed);
    let locals: Vec<Arc<dyn LocalCost>> = (0..n_workers)
        .map(|i| {
            let ni = pattern.owned_len(i);
            let diag: Vec<f64> = (0..ni).map(|_| 0.5 + rng.uniform()).collect();
            let q: Vec<f64> = (0..ni).map(|_| rng.normal()).collect();
            Arc::new(QuadraticLocal::diagonal(&diag, q)) as Arc<dyn LocalCost>
        })
        .collect();
    let problem =
        ConsensusProblem::sharded(locals, Regularizer::L1 { theta: 0.05 }, pattern.clone())
            .expect("local dims match the pattern");
    (problem, pattern)
}

fn main() {
    let quick = quick_mode();
    let mut json = BenchReport::new("virtual_scale");
    let (n_workers, iters) = if quick { (200, 100) } else { (1000, 500) };
    let dim = 8;
    let problem = quadratic_consensus(n_workers, dim, 42);
    let delays = DelayModel::linear_spread(n_workers, 0.5, 50.0, 0.5, 17);
    json.config("n_workers", n_workers).config("iters", iters).config("dim", dim);

    println!(
        "=== virtual-time scale sweep: N={n_workers} workers, {iters} master iterations, \
         lognormal delays 0.5-50 ms ==="
    );
    println!(
        "{:>6} {:>6} {:>10} {:>10} {:>12} {:>9} {:>12} {:>10}",
        "tau", "A", "sim[s]", "wait[s]", "sim it/s", "max|A_k|", "objective", "real[s]"
    );

    let path = ad_admm::bench::results_dir().join("virtual_scale.csv");
    let mut csv = CsvWriter::create(
        &path,
        &[
            "tau",
            "min_arrivals",
            "sim_s",
            "wait_s",
            "sim_iters_per_s",
            "max_set",
            "objective",
            "real_s",
        ],
    )
    .expect("csv");

    // The two Section-V axes: the τ delay bound and the A batching gate.
    let tau_sweep: &[usize] = if quick { &[50, 200] } else { &[50, 200, 1000] };
    let a_sweep: &[usize] = if quick { &[1, 16] } else { &[1, 8, 64, 256] };
    let mut settings: Vec<(usize, usize)> = Vec::new();
    for &tau in tau_sweep {
        settings.push((tau, 8));
    }
    for &a in a_sweep {
        settings.push((if quick { 200 } else { 500 }, a));
    }

    let mut total_real_s = 0.0;
    let mut total_net_bytes = 0u64;
    for (tau, min_arrivals) in settings {
        let cfg = ClusterConfig::builder()
            .admm(AdmmConfig {
                rho: 20.0,
                tau,
                min_arrivals,
                max_iters: iters,
                objective_every: 0,
                ..Default::default()
            })
            .delays(delays.clone())
            .mode(ExecutionMode::VirtualTime)
            .build()
            .expect("valid cluster config");
        let t = Instant::now();
        let r = StarCluster::new(problem.clone()).run(&cfg);
        let real_s = t.elapsed().as_secs_f64();
        total_real_s += real_s;
        assert!(
            r.trace.satisfies_bounded_delay(n_workers, tau),
            "Assumption 1 violated at tau={tau}"
        );
        let max_set = r.trace.sets.iter().map(Vec::len).max().unwrap_or(0);
        let objective = problem.objective(&r.state.x0);
        println!(
            "{:>6} {:>6} {:>10.3} {:>10.3} {:>12.0} {:>9} {:>12.5e} {:>10.3}",
            tau,
            min_arrivals,
            r.wall_clock_s,
            r.master_wait_s,
            r.iters_per_sec(),
            max_set,
            objective,
            real_s,
        );
        csv.row(&[
            tau as f64,
            min_arrivals as f64,
            r.wall_clock_s,
            r.master_wait_s,
            r.iters_per_sec(),
            max_set as f64,
            objective,
            real_s,
        ])
        .unwrap();
        json.series(vec![
            ("tau", JsonValue::Num(tau as f64)),
            ("min_arrivals", JsonValue::Num(min_arrivals as f64)),
            ("sim_s", JsonValue::Num(r.wall_clock_s)),
            ("sim_iters_per_sec", JsonValue::Num(r.iters_per_sec())),
            ("max_set", JsonValue::Num(max_set as f64)),
            ("objective", JsonValue::Num(objective)),
            ("real_s", JsonValue::Num(real_s)),
            // Simulated payload volume (8 bytes/f64, deterministic in
            // virtual time) — the comm-cost axis next to the time axes.
            ("net_bytes_down", JsonValue::Num(r.net_bytes_down as f64)),
            ("net_bytes_up", JsonValue::Num(r.net_bytes_up as f64)),
        ]);
        total_net_bytes += r.net_bytes_down + r.net_bytes_up;
    }
    csv.flush().unwrap();
    json.metric("sweep_total_real_s", total_real_s);
    json.metric("sweep_net_bytes_total", total_net_bytes as f64);
    println!("\nseries → {}", path.display());

    // ---- pooled execution: the multicore win on CPU-heavy worker solves ----
    // Dense per-worker blocks make each arrived worker's round real
    // arithmetic (O(dim²) backsolve + O(dim²) eval); fanning the rounds
    // across cores must not change a single bit of the history.
    let (pn, pdim, piters, pa) = if quick { (200, 48, 80, 48) } else { (1000, 128, 300, 256) };
    println!(
        "\n=== pooled virtual-time execution: N={pn} dense {pdim}x{pdim} workers, \
         {piters} iterations, A={pa} ==="
    );
    let dense = dense_consensus(pn, pdim, 43);
    let make_cfg = |pool_threads: usize| {
        ClusterConfig::builder()
            .admm(AdmmConfig {
                rho: 20.0,
                tau: pn,
                min_arrivals: pa,
                max_iters: piters,
                objective_every: 0,
                ..Default::default()
            })
            .delays(DelayModel::linear_spread(pn, 0.5, 5.0, 0.3, 23))
            .mode(ExecutionMode::VirtualTime)
            .pool_threads(pool_threads)
            .build()
            .expect("valid cluster config")
    };

    let t = Instant::now();
    let serial = StarCluster::new(dense.clone()).run(&make_cfg(1));
    let serial_s = t.elapsed().as_secs_f64();
    let t = Instant::now();
    let pooled = StarCluster::new(dense.clone()).run(&make_cfg(0));
    let pooled_s = t.elapsed().as_secs_f64();

    // bit-identity: the pool must be invisible in the results
    assert_eq!(serial.trace, pooled.trace, "pooled run realized a different trace");
    assert_eq!(serial.state.x0, pooled.state.x0, "pooled x0 differs");
    assert_eq!(
        serial.history.len(),
        pooled.history.len(),
        "pooled history length differs"
    );
    for (a, b) in serial.history.iter().zip(&pooled.history) {
        assert_eq!(
            a.aug_lagrangian.to_bits(),
            b.aug_lagrangian.to_bits(),
            "pooled aug_lagrangian differs at k={}",
            a.k
        );
    }

    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let speedup = serial_s / pooled_s.max(1e-12);
    println!(
        "serial (1 thread):   {serial_s:>8.3}s real\n\
         pooled ({cores} threads): {pooled_s:>8.3}s real\n\
         speedup: {speedup:.2}x — histories bit-identical"
    );
    json.config("pooled_n_workers", pn)
        .config("pooled_dim", pdim)
        .config("pooled_iters", piters)
        .config("pool_cores", cores)
        .metric("pooled_serial_real_s", serial_s)
        .metric("pooled_real_s", pooled_s)
        .metric("pooled_speedup", speedup);

    // ---- fault sweep: dropout/rejoin + delay spikes across the τ gate ----
    // The engine's FaultPlan seam in action at scale: a down worker simply
    // stops being absorbed (its result is held until rejoin, re-entering
    // with stale iterates), outages longer than τ deliberately break
    // Assumption 1 on the realized trace, and delay spikes starve the
    // affected worker's cadence. All deterministic: same plan, same trace.
    let ftau = if quick { 50 } else { 200 };
    println!(
        "\n=== fault sweep: dropout/rejoin + delay spikes \
         (N={n_workers}, {iters} iters, tau={ftau}) ==="
    );
    println!(
        "{:>26} {:>10} {:>10} {:>9} {:>12} {:>6} {:>10}",
        "scenario", "sim[s]", "wait[s]", "min|A_k|", "objective", "A1", "real[s]"
    );
    let scenarios: Vec<(&str, FaultPlan)> = vec![
        ("fault-free", FaultPlan::default()),
        (
            "dropout+rejoin (worker 0)",
            FaultPlan::single_outage(0, iters / 4, iters / 4 + ftau + 10),
        ),
        (
            "seeded outages (x8)",
            FaultPlan::seeded_outages(n_workers, iters, 8, ftau / 2, ftau, 0xFA11),
        ),
        (
            "delay spike (slowest 10x)",
            FaultPlan {
                outages: Vec::new(),
                spikes: vec![DelaySpike {
                    worker: n_workers - 1,
                    from_s: 0.0,
                    until_s: f64::INFINITY,
                    factor: 10.0,
                }],
            },
        ),
    ];
    let mut fault_total_real_s = 0.0;
    for (label, plan) in scenarios {
        let mut builder = ClusterConfig::builder()
            .admm(AdmmConfig {
                rho: 20.0,
                tau: ftau,
                min_arrivals: 8,
                max_iters: iters,
                objective_every: 0,
                ..Default::default()
            })
            .delays(delays.clone())
            .mode(ExecutionMode::VirtualTime);
        if !plan.is_empty() {
            builder = builder.fault_plan(plan);
        }
        let cfg = builder.build().expect("valid cluster config");
        let t = Instant::now();
        let r = StarCluster::new(problem.clone()).run(&cfg);
        let real_s = t.elapsed().as_secs_f64();
        fault_total_real_s += real_s;
        // A down worker is never absorbed while down — pin the contract
        // in the bench itself so a scale regression cannot hide one.
        if let Some(p) = &cfg.fault_plan {
            for (k, set) in r.trace.sets.iter().enumerate() {
                for &i in set {
                    assert!(!p.down_at(i, k), "worker {i} absorbed while down at k={k}");
                }
            }
        }
        let a1 = r.trace.satisfies_bounded_delay(n_workers, ftau);
        let min_set = r.trace.sets.iter().map(Vec::len).min().unwrap_or(0);
        let objective = problem.objective(&r.state.x0);
        println!(
            "{label:>26} {:>10.3} {:>10.3} {:>9} {:>12.5e} {:>6} {:>10.3}",
            r.wall_clock_s, r.master_wait_s, min_set, objective, a1, real_s,
        );
        json.series(vec![
            ("section", JsonValue::Str("fault_sweep".into())),
            ("scenario", JsonValue::Str(label.into())),
            ("sim_s", JsonValue::Num(r.wall_clock_s)),
            ("min_set", JsonValue::Num(min_set as f64)),
            ("objective", JsonValue::Num(objective)),
            ("assumption1", JsonValue::Bool(a1)),
            ("real_s", JsonValue::Num(real_s)),
        ]);
    }
    json.metric("fault_sweep_total_real_s", fault_total_real_s);

    // ---- sharded consensus: block-owned slices cut master bandwidth ----
    // Each worker owns `copies` blocks of the global variable and ships
    // only that slice, so its virtual-time transit legs shrink by
    // |S_i| / n. The counterfactual run uses the SAME sharded problem but
    // with per-worker comm means pre-stretched by n / |S_i| — i.e. the
    // identical compute with dense-size messages — which isolates exactly
    // the comm-volume effect.
    let (sn, sblock, scopies, siters) = if quick { (200, 4, 2, 100) } else { (1000, 4, 2, 300) };
    let (sharded_problem, pattern) = sharded_consensus(sn, sblock, scopies, 0x5AAD);
    let ratio = pattern.comm_volume_ratio();
    println!(
        "\n=== sharded consensus: N={sn} workers, n={} global dims, \
         {scopies} owners/block, comm volume ratio {ratio:.3} ===",
        pattern.dim()
    );
    let comm_ms = 2.0;
    let mk_sharded_cfg = |dense_sized_messages: bool| {
        let per_worker_ms: Vec<f64> = (0..sn)
            .map(|i| {
                if dense_sized_messages {
                    // Counterfactual: undo the engine's |S_i|/n scaling so
                    // the link carries a full-length message again.
                    comm_ms * pattern.dim() as f64 / pattern.owned_len(i) as f64
                } else {
                    comm_ms
                }
            })
            .collect();
        ClusterConfig::builder()
            .admm(AdmmConfig {
                rho: 20.0,
                tau: if quick { 50 } else { 200 },
                min_arrivals: 8,
                max_iters: siters,
                objective_every: 0,
                ..Default::default()
            })
            .delays(DelayModel::linear_spread(sn, 0.5, 10.0, 0.4, 19))
            .comm_delays(DelayModel::Fixed { per_worker_ms })
            .mode(ExecutionMode::VirtualTime)
            .build()
            .expect("valid cluster config")
    };
    let t = Instant::now();
    let sharded = StarCluster::new(sharded_problem.clone()).run(&mk_sharded_cfg(false));
    let sharded_real_s = t.elapsed().as_secs_f64();
    let t = Instant::now();
    let dense_msgs = StarCluster::new(sharded_problem.clone()).run(&mk_sharded_cfg(true));
    let dense_real_s = t.elapsed().as_secs_f64();
    assert!(ratio < 1.0, "a sharded pattern must reduce comm volume");
    assert!(
        sharded.wall_clock_s < dense_msgs.wall_clock_s,
        "owned-slice messages must shrink simulated time: {} vs {}",
        sharded.wall_clock_s,
        dense_msgs.wall_clock_s
    );
    let sim_speedup = dense_msgs.wall_clock_s / sharded.wall_clock_s.max(1e-12);
    println!(
        "{:>24} {:>10} {:>10} {:>12} {:>10}",
        "messages", "sim[s]", "wait[s]", "objective", "real[s]"
    );
    for (label, r, real_s) in [
        ("owned slices (sharded)", &sharded, sharded_real_s),
        ("full-length (dense)", &dense_msgs, dense_real_s),
    ] {
        println!(
            "{label:>24} {:>10.3} {:>10.3} {:>12.5e} {:>10.3}",
            r.wall_clock_s,
            r.master_wait_s,
            sharded_problem.objective(&r.state.x0),
            real_s,
        );
        json.series(vec![
            ("section", JsonValue::Str("sharded".into())),
            ("messages", JsonValue::Str(label.into())),
            ("sim_s", JsonValue::Num(r.wall_clock_s)),
            ("master_wait_s", JsonValue::Num(r.master_wait_s)),
            ("real_s", JsonValue::Num(real_s)),
        ]);
    }
    println!(
        "sharded messages: {ratio:.3}x the dense comm volume, {sim_speedup:.2}x faster \
         simulated wall-clock"
    );
    json.config("sharded_n_workers", sn)
        .config("sharded_global_dim", pattern.dim())
        .config("sharded_owners_per_block", scopies)
        .metric("sharded_comm_volume_ratio", ratio)
        .metric("sharded_sim_speedup", sim_speedup)
        .metric("sharded_total_real_s", sharded_real_s + dense_real_s);

    // ---- fleet sweep: 10⁵ (quick) / 10⁶ (full) virtual workers ----
    // One coordinate per worker, single-owner blocks: the master's
    // per-iteration cost is Σ_{i∈A_k} |S_i| = |A_k| under the lazy sparse
    // master, independent of fleet size, and the 16-byte packed event heap
    // plus SoA worker stats keep the scheduler cache-resident. τ is set
    // above max_iters so the delay gate never force-marches the whole
    // fleet through one iteration — exactly the regime where O(active)
    // beats the O(n) eager sweep by orders of magnitude.
    let (wn, wscale) = if quick { (100_000, "1e5") } else { (1_000_000, "1e6") };
    let (witers, wa) = if quick { (50, 64) } else { (100, 256) };
    println!(
        "\n=== fleet sweep: N={wn} ({wscale}) virtual workers, {witers} iterations, \
         A={wa}, O(active) sparse master ==="
    );
    let (wproblem, _) = sharded_consensus(wn, 1, 1, 0xBEE5);
    let wcfg = ClusterConfig::builder()
        .admm(AdmmConfig {
            rho: 20.0,
            tau: witers + 1,
            min_arrivals: wa,
            max_iters: witers,
            objective_every: 0,
            metrics_every: 0,
            ..Default::default()
        })
        .delays(DelayModel::linear_spread(wn, 0.5, 20.0, 0.4, 29))
        .mode(ExecutionMode::VirtualTime)
        .build()
        .expect("valid cluster config");
    let wcluster = StarCluster::new(wproblem.clone());
    let t = Instant::now();
    let mut sweep_session = wcluster.virtual_session(&wcfg).expect("valid virtual session");
    assert!(
        sweep_session.sparse_active(),
        "the fleet sweep must run the O(active) sparse master"
    );
    let sweep_stop = sweep_session.run_to_completion().expect("fleet sweep completes");
    let (sweep_outcome, sweep_source) = sweep_session.finish();
    let sweep_real_s = t.elapsed().as_secs_f64();
    assert_eq!(sweep_stop, StopReason::MaxIters);
    assert_eq!(sweep_outcome.trace.sets.len(), witers);
    assert!(
        sweep_outcome.trace.sets.iter().all(|s| s.len() >= wa),
        "the |A_k| >= A batching gate must hold on every iteration"
    );
    let wreport = ClusterReport::from_virtual_parts(sweep_outcome, Vec::new(), sweep_source);
    let arrivals: usize = wreport.trace.sets.iter().map(Vec::len).sum();
    let wobjective = wproblem.objective(&wreport.state.x0);
    println!(
        "{witers} iterations / {arrivals} arrivals: sim {:.3}s, objective {:.5e}, \
         real {sweep_real_s:.3}s",
        wreport.wall_clock_s, wobjective,
    );
    println!("sweep_{wscale}_total_real_s = {sweep_real_s:.3}");
    json.config("fleet_n_workers", wn)
        .config("fleet_iters", witers)
        .metric(&format!("sweep_{wscale}_total_real_s"), sweep_real_s);

    // ---- multi-master sweep: shard the coordinator itself, M ∈ {1,2,4,8} ----
    // The same fleet and config as the sweep above; only the number of
    // coordinators changes. Each master absorbs just the slice parts of
    // the blocks it owns, so its simulated busy seconds (MASTER_PER_F64_S
    // per folded f64) shrink by ~1/M while the byte meters split the same
    // payload volume across masters (rows sum to the globals — asserted).
    // The headline metric ratios the single coordinator's busy time
    // against the *bottleneck* (max) master at M = 4: the quantity that
    // bounds coordinator throughput once the fleet outgrows one machine.
    println!(
        "\n=== multi-master sweep: N={wn} ({wscale}) workers, {witers} iterations, \
         M coordinators own ~n/M blocks each ==="
    );
    println!(
        "{:>3} {:>6} {:>10} {:>12} {:>12} {:>14} {:>10}",
        "M", "iters", "sim[s]", "busy max[s]", "busy sum[s]", "up[B]/master", "real[s]"
    );
    let mut busy_single = 0.0_f64;
    let mut busy_max_m4 = 0.0_f64;
    let mut mm_total_real_s = 0.0;
    for m in [1usize, 2, 4, 8] {
        let t = Instant::now();
        let mut session = if m == 1 {
            wcluster.virtual_session(&wcfg)
        } else {
            let group = MasterGroup::contiguous(wn, m).expect("fleet has >= M blocks");
            wcluster.virtual_multimaster_session(&wcfg, group)
        }
        .expect("valid multi-master sweep session");
        session.run_to_completion().expect("multi-master sweep completes");
        let (outcome, source) = session.finish();
        let real_s = t.elapsed().as_secs_f64();
        mm_total_real_s += real_s;
        let busy = source.master_busy_s().to_vec();
        assert_eq!(busy.len(), m, "one busy meter per master");
        let split = source.master_split();
        let (down, up) = source.network_bytes();
        let split_down: u64 = split.iter().map(|&(d, _)| d).sum();
        let split_up: u64 = split.iter().map(|&(_, u)| u).sum();
        assert_eq!(
            (split_down, split_up),
            (down, up),
            "per-master byte split must sum to the global counters at M={m}"
        );
        let iterations = outcome.iterations;
        let report = ClusterReport::from_virtual_parts(outcome, Vec::new(), source);
        let busy_max = busy.iter().cloned().fold(0.0_f64, f64::max);
        let busy_sum: f64 = busy.iter().sum();
        if m == 1 {
            busy_single = busy_max;
        }
        if m == 4 {
            busy_max_m4 = busy_max;
        }
        println!(
            "{m:>3} {iterations:>6} {:>10.3} {:>12.6} {:>12.6} {:>14} {real_s:>10.3}",
            report.wall_clock_s,
            busy_max,
            busy_sum,
            split_up / m as u64,
        );
        json.series(vec![
            ("section", JsonValue::Str("multimaster".into())),
            ("masters", JsonValue::Num(m as f64)),
            ("iterations", JsonValue::Num(iterations as f64)),
            ("sim_s", JsonValue::Num(report.wall_clock_s)),
            ("master_busy_max_s", JsonValue::Num(busy_max)),
            ("master_busy_total_s", JsonValue::Num(busy_sum)),
            (
                "net_bytes_down_per_master",
                JsonValue::Arr(split.iter().map(|&(d, _)| JsonValue::Num(d as f64)).collect()),
            ),
            (
                "net_bytes_up_per_master",
                JsonValue::Arr(split.iter().map(|&(_, u)| JsonValue::Num(u as f64)).collect()),
            ),
            ("real_s", JsonValue::Num(real_s)),
        ]);
    }
    let multimaster_speedup = busy_single / busy_max_m4.max(1e-12);
    assert!(
        multimaster_speedup > 1.0,
        "splitting the coordinator four ways must shrink the bottleneck master's busy \
         time: M=1 busy {busy_single:.6}s vs max-per-master at M=4 {busy_max_m4:.6}s"
    );
    println!("multimaster_speedup = {multimaster_speedup:.3}");
    json.metric("multimaster_speedup", multimaster_speedup)
        .metric("multimaster_total_real_s", mm_total_real_s);

    // ---- sparse vs eager master A/B: the O(active) win, bit-for-bit ----
    // Same sharded problem, same prescribed sparse arrival trace (A of N
    // workers round-robin per iteration); the only difference is the
    // master-update path. The lazy sparse master must reproduce the eager
    // dense sweep bit-identically while doing |A_k|/N of its work.
    let (abn, abblock, abiters, aba) =
        if quick { (2048, 16, 300, 16) } else { (4096, 32, 600, 32) };
    let (ab_problem, ab_pattern) = sharded_consensus(abn, abblock, 1, 0xAB5E);
    let ab_trace = ArrivalTrace {
        sets: (0..abiters)
            .map(|k| {
                let mut set: Vec<usize> = (0..aba).map(|j| (k * aba + j) % abn).collect();
                set.sort_unstable();
                set
            })
            .collect(),
    };
    println!(
        "\n=== sparse vs eager master: N={abn} workers, n={} dims, A={aba}, \
         {abiters} prescribed iterations ===",
        ab_pattern.dim()
    );
    let ab_run = |sparse: bool| {
        let t = Instant::now();
        let mut session = Session::builder()
            .problem(&ab_problem)
            .config(AdmmConfig {
                rho: 20.0,
                tau: abiters + 1,
                min_arrivals: 1,
                max_iters: abiters,
                objective_every: 0,
                metrics_every: 0,
                ..Default::default()
            })
            .arrivals(&ArrivalModel::Trace(ab_trace.clone()))
            .sparse_master(sparse)
            .build()
            .expect("valid session");
        assert_eq!(session.sparse_active(), sparse, "sparse-master eligibility mismatch");
        session.run_to_completion().expect("A/B run completes");
        let (outcome, _) = session.finish();
        (outcome, t.elapsed().as_secs_f64())
    };
    let (eager_out, eager_s) = ab_run(false);
    let (sparse_out, sparse_s) = ab_run(true);
    assert_eq!(eager_out.trace, sparse_out.trace, "A/B runs realized different traces");
    assert_eq!(eager_out.state.x0.len(), sparse_out.state.x0.len());
    for (j, (a, b)) in eager_out.state.x0.iter().zip(&sparse_out.state.x0).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "sparse master diverged from the eager sweep at coordinate {j}"
        );
    }
    let sparse_master_speedup = eager_s / sparse_s.max(1e-12);
    println!(
        "eager {eager_s:.3}s, sparse {sparse_s:.3}s → {sparse_master_speedup:.2}x \
         — final x0 bit-identical"
    );
    println!("sparse_master_speedup = {sparse_master_speedup:.3}");
    json.config("ab_n_workers", abn)
        .config("ab_dims", ab_pattern.dim())
        .metric("sparse_master_eager_s", eager_s)
        .metric("sparse_master_sparse_s", sparse_s)
        .metric("sparse_master_speedup", sparse_master_speedup);

    let json_path = json.write().expect("write BENCH json");
    println!("machine-readable report → {}", json_path.display());
    println!(
        "note: sim[s] is *simulated* time (what a real cluster would have spent);\n\
         real[s] is what the discrete-event simulation itself cost — the gap is\n\
         why these sweeps can run in CI where the threaded cluster cannot."
    );
}
