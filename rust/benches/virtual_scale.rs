//! Virtual-time scale sweeps: the Section-V protocol-parameter studies
//! (τ gate, `|A_k| ≥ A` batching gate) at worker counts the wall-clock
//! threaded cluster cannot reach — 1000+ workers, hundreds of master
//! iterations, all in deterministic simulated time.
//!
//! Reported per setting: simulated wall-clock, simulated master wait,
//! simulated iterations/second, realized max |A_k|, final objective, and
//! the real time the *simulation itself* took (the number that makes this
//! CI-viable).
//!
//! Run: `cargo bench --bench virtual_scale` (AD_ADMM_BENCH_QUICK=1 shrinks).

use std::sync::Arc;
use std::time::Instant;

use ad_admm::bench::quick_mode;
use ad_admm::cluster::{ClusterConfig, ExecutionMode};
use ad_admm::prelude::*;
use ad_admm::problems::{LocalCost, QuadraticLocal};
use ad_admm::prox::Regularizer;
use ad_admm::util::CsvWriter;

fn quadratic_consensus(n_workers: usize, dim: usize, seed: u64) -> ConsensusProblem {
    let mut rng = Pcg64::seed_from_u64(seed);
    let locals: Vec<Arc<dyn LocalCost>> = (0..n_workers)
        .map(|_| {
            let diag: Vec<f64> = (0..dim).map(|_| 0.5 + rng.uniform()).collect();
            let q: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
            Arc::new(QuadraticLocal::diagonal(&diag, q)) as Arc<dyn LocalCost>
        })
        .collect();
    ConsensusProblem::new(locals, Regularizer::L1 { theta: 0.05 })
}

fn main() {
    let quick = quick_mode();
    let (n_workers, iters) = if quick { (200, 100) } else { (1000, 500) };
    let dim = 8;
    let problem = quadratic_consensus(n_workers, dim, 42);
    let delays = DelayModel::linear_spread(n_workers, 0.5, 50.0, 0.5, 17);

    println!(
        "=== virtual-time scale sweep: N={n_workers} workers, {iters} master iterations, \
         lognormal delays 0.5-50 ms ==="
    );
    println!(
        "{:>6} {:>6} {:>10} {:>10} {:>12} {:>9} {:>12} {:>10}",
        "tau", "A", "sim[s]", "wait[s]", "sim it/s", "max|A_k|", "objective", "real[s]"
    );

    let path = std::path::Path::new("bench_results/virtual_scale.csv");
    let mut csv = CsvWriter::create(
        path,
        &[
            "tau",
            "min_arrivals",
            "sim_s",
            "wait_s",
            "sim_iters_per_s",
            "max_set",
            "objective",
            "real_s",
        ],
    )
    .expect("csv");

    // The two Section-V axes: the τ delay bound and the A batching gate.
    let tau_sweep: &[usize] = if quick { &[50, 200] } else { &[50, 200, 1000] };
    let a_sweep: &[usize] = if quick { &[1, 16] } else { &[1, 8, 64, 256] };
    let mut settings: Vec<(usize, usize)> = Vec::new();
    for &tau in tau_sweep {
        settings.push((tau, 8));
    }
    for &a in a_sweep {
        settings.push((if quick { 200 } else { 500 }, a));
    }

    for (tau, min_arrivals) in settings {
        let cfg = ClusterConfig {
            admm: AdmmConfig {
                rho: 20.0,
                tau,
                min_arrivals,
                max_iters: iters,
                objective_every: 0,
                ..Default::default()
            },
            delays: delays.clone(),
            mode: ExecutionMode::VirtualTime,
            ..Default::default()
        };
        let t = Instant::now();
        let r = StarCluster::new(problem.clone()).run(&cfg);
        let real_s = t.elapsed().as_secs_f64();
        assert!(
            r.trace.satisfies_bounded_delay(n_workers, tau),
            "Assumption 1 violated at tau={tau}"
        );
        let max_set = r.trace.sets.iter().map(Vec::len).max().unwrap_or(0);
        let objective = problem.objective(&r.state.x0);
        println!(
            "{:>6} {:>6} {:>10.3} {:>10.3} {:>12.0} {:>9} {:>12.5e} {:>10.3}",
            tau,
            min_arrivals,
            r.wall_clock_s,
            r.master_wait_s,
            r.iters_per_sec(),
            max_set,
            objective,
            real_s,
        );
        csv.row(&[
            tau as f64,
            min_arrivals as f64,
            r.wall_clock_s,
            r.master_wait_s,
            r.iters_per_sec(),
            max_set as f64,
            objective,
            real_s,
        ])
        .unwrap();
    }
    csv.flush().unwrap();
    println!("\nseries → {}", path.display());
    println!(
        "note: sim[s] is *simulated* time (what a real cluster would have spent);\n\
         real[s] is what the discrete-event simulation itself cost — the gap is\n\
         why these sweeps can run in CI where the threaded cluster cannot."
    );
}
