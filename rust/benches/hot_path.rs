//! Hot-path microbenchmarks: the per-iteration costs of every layer.
//!
//! - native worker subproblem solve (cached-Cholesky backsolve)
//! - uncached factorization (what the cache saves per iteration)
//! - native Gram mat-vec (the L1 kernel's native mirror)
//! - scratch-based `f_i` evaluation (the zero-allocation cache refresh)
//! - master x₀ update (prox assembly, scratch-buffered)
//! - PJRT worker solve + PJRT gram/prox artifacts (when built)
//! - master-PoV end-to-end iteration
//!
//! Run: `cargo bench --bench hot_path` (`AD_ADMM_BENCH_QUICK=1` shrinks).
//! Emits `BENCH_hot_path.json` next to the text output.

use std::sync::Arc;

use ad_admm::admm::{master_x0_update, AdmmConfig, AdmmState, MasterScratch};
use ad_admm::bench::json::BenchReport;
use ad_admm::bench::{bench_fn, black_box, banner, report, BenchStats};
use ad_admm::prelude::*;
use ad_admm::problems::{LassoLocal, WorkerScratch};
use ad_admm::runtime::{artifacts_available, artifacts_dir, PjrtLassoSolver, PjrtMasterProx};
use ad_admm::testkit::drivers::run_partial_barrier;

fn record(json: &mut BenchReport, label: &str, stats: &BenchStats) {
    report(label, stats);
    json.stats(label, stats);
}

fn main() {
    let quick = ad_admm::bench::quick_mode();
    let mut json = BenchReport::new("hot_path");
    let shapes: &[(usize, usize)] = if quick { &[(60, 30)] } else { &[(200, 100), (200, 1000)] };
    let (warm, samples) = if quick { (1, 5) } else { (3, 50) };
    json.config("quick_shapes", shapes.len());
    for &(m, n) in shapes {
        banner(&format!("worker hot path, block {m}x{n}"));
        let mut rng = Pcg64::seed_from_u64(5);
        let a = DenseMatrix::randn(&mut rng, m, n);
        let b: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let local = LassoLocal::new(a.clone(), b.clone());
        let lam: Vec<f64> = (0..n).map(|i| (i as f64 * 0.1).sin()).collect();
        let x0: Vec<f64> = (0..n).map(|i| (i as f64 * 0.2).cos()).collect();
        let mut out = vec![0.0; n];
        let mut ws = WorkerScratch::new();

        // warm the rho cache, then measure the cached path
        local.solve_subproblem(&lam, &x0, 500.0, &mut out, &mut ws);
        let stats = bench_fn(warm, samples, || {
            local.solve_subproblem(black_box(&lam), black_box(&x0), 500.0, &mut out, &mut ws);
            black_box(&out);
        });
        record(&mut json, &format!("native worker solve (cached chol) {m}x{n}"), &stats);

        let stats = bench_fn(1, if quick { 2 } else { 5 }, || {
            // fresh local cost → full gram + factorization every time
            let fresh = LassoLocal::new(a.clone(), b.clone());
            fresh.solve_subproblem(black_box(&lam), black_box(&x0), 500.0, &mut out, &mut ws);
            black_box(&out);
        });
        record(&mut json, &format!("native worker solve (uncached)    {m}x{n}"), &stats);

        let x: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let mut scratch = vec![0.0; m];
        let mut y = vec![0.0; n];
        let stats = bench_fn(5, if quick { 20 } else { 200 }, || {
            a.gram_matvec_into(black_box(&x), &mut scratch, &mut y);
            black_box(&y);
        });
        record(&mut json, &format!("native gram matvec                {m}x{n}"), &stats);

        // the f_i cache refresh: scratch-based eval, zero allocation
        let stats = bench_fn(5, if quick { 20 } else { 200 }, || {
            black_box(local.eval_with(black_box(&x), &mut ws));
        });
        record(&mut json, &format!("native eval (scratch buffers)     {m}x{n}"), &stats);
    }

    let master_n = if quick { 100 } else { 1000 };
    banner(&format!("master hot path (N=16, n={master_n})"));
    {
        let mut rng = Pcg64::seed_from_u64(6);
        let inst = LassoInstance::synthetic(&mut rng, 4, 20, master_n, 0.05, 0.1);
        let problem = inst.problem();
        let mut state = AdmmState::zeros(4, master_n);
        for i in 0..4 {
            rng.fill_normal(&mut state.xs[i]);
            rng.fill_normal(&mut state.lams[i]);
        }
        let mut ms = MasterScratch::new();
        let stats = bench_fn(5, if quick { 20 } else { 200 }, || {
            master_x0_update(&problem, &mut state, 500.0, 0.0, &mut ms);
            black_box(&state.x0);
        });
        record(&mut json, "master x0 update (prox assembly)", &stats);
    }

    banner("end-to-end master iteration (serial Algorithm 3, N=16, n=100)");
    {
        let e2e_m = if quick { 40 } else { 200 };
        let mut rng = Pcg64::seed_from_u64(7);
        let inst = LassoInstance::synthetic(&mut rng, 16, e2e_m, 100, 0.05, 0.1);
        let problem = inst.problem();
        let arrivals = ArrivalModel::fig4_profile(16, 3);
        // measure per-iteration cost via a fixed-length run
        let stats = bench_fn(1, 5, || {
            let cfg = AdmmConfig { rho: 500.0, tau: 10, max_iters: 50, ..Default::default() };
            let out = run_partial_barrier(&problem, &cfg, &arrivals);
            black_box(out.history.len());
        });
        println!("  (each sample = 50 master iterations)");
        record(&mut json, "50 iterations, full diagnostics", &stats);
        // diagnostics off the hot loop: objective every 50th iteration
        // (accuracy curves only need the cached augmented Lagrangian)
        let stats = bench_fn(1, 5, || {
            let cfg = AdmmConfig {
                rho: 500.0,
                tau: 10,
                max_iters: 50,
                objective_every: 50,
                ..Default::default()
            };
            let out = run_partial_barrier(&problem, &cfg, &arrivals);
            black_box(out.history.len());
        });
        record(&mut json, "50 iterations, objective_every=50", &stats);
    }

    if ad_admm::runtime::pjrt_enabled() && artifacts_available() {
        banner("PJRT hot path (AOT JAX/Pallas artifacts)");
        let engine = Arc::new(PjrtEngine::load(&artifacts_dir()).expect("engine"));
        let mut rng = Pcg64::seed_from_u64(8);
        let inst = LassoInstance::synthetic(&mut rng, 1, 200, 100, 0.05, 0.1);
        if let Ok(solver) = PjrtLassoSolver::new(engine.clone(), &inst) {
            let cg = engine
                .registry()
                .get("lasso_worker_m200_n100")
                .and_then(|e| e.attr_usize("cg_iters"))
                .unwrap_or(0);
            let lam: Vec<f64> = (0..100).map(|i| (i as f64 * 0.1).sin()).collect();
            let x0: Vec<f64> = (0..100).map(|i| (i as f64 * 0.2).cos()).collect();
            let stats = bench_fn(3, 30, || {
                let x = solver.solve_for(0, black_box(&lam), black_box(&x0), 500.0).unwrap();
                black_box(x);
            });
            record(&mut json, &format!("PJRT worker solve (CG{cg} + pallas) 200x100"), &stats);
        }
        if let Ok(prox) = PjrtMasterProx::new(engine.clone(), 100) {
            let v: Vec<f64> = (0..100).map(|i| (i as f64 * 0.3).sin()).collect();
            let stats = bench_fn(3, 50, || {
                let x = prox.run(black_box(&v), &v, &v, 500.0, 0.0, 0.1, 16).unwrap();
                black_box(x);
            });
            record(&mut json, "PJRT master prox n=100", &stats);
        }
        // raw gram artifact
        if engine.has("gram_matvec_m200_n100") {
            let a = DenseMatrix::randn(&mut rng, 200, 100);
            let x: Vec<f64> = (0..100).map(|i| (i as f64).cos()).collect();
            let a_buf = engine.upload(a.data(), &[200, 100]).unwrap();
            let x_buf = engine.upload(&x, &[100]).unwrap();
            let stats = bench_fn(3, 50, || {
                let y = engine.execute_f64("gram_matvec_m200_n100", &[&a_buf, &x_buf]).unwrap();
                black_box(y);
            });
            record(&mut json, "PJRT gram matvec (pallas) 200x100", &stats);
        }
    } else {
        println!("\n(PJRT section skipped — needs the `pjrt` feature and `make artifacts`)");
    }

    let path = json.write().expect("write BENCH json");
    println!("\nmachine-readable report → {}", path.display());
}
