//! BLAS-1 style vector kernels.
//!
//! These are the innermost loops of the whole stack (every ADMM iteration is
//! a handful of axpys/dots per worker), so they are written with 4-way
//! manual unrolling which LLVM reliably turns into SIMD.

/// Dot product `xᵀy`.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += x[i] * y[i];
        s1 += x[i + 1] * y[i + 1];
        s2 += x[i + 2] * y[i + 2];
        s3 += x[i + 3] * y[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in chunks * 4..n {
        s += x[i] * y[i];
    }
    s
}

/// `y += a * x`.
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * xi;
    }
}

/// `y = a * x + b * y` (scaled accumulate).
#[inline]
pub fn axpby(a: f64, x: &[f64], b: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi = a * xi + b * *yi;
    }
}

/// `out += a * x + y` — the fused accumulate of the master prox assembly
/// (12)/(25), `v += ρ·x_i + λ_i`, one pass per worker with no temporary.
#[inline]
pub fn acc_axpy(a: f64, x: &[f64], y: &[f64], out: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    for i in 0..x.len() {
        out[i] += a * x[i] + y[i];
    }
}

/// `x *= a`.
#[inline]
pub fn scale(a: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

/// Euclidean norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Squared Euclidean norm.
#[inline]
pub fn nrm2_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// L1 norm.
#[inline]
pub fn nrm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// Infinity norm.
#[inline]
pub fn nrm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0, |m, v| m.max(v.abs()))
}

/// `||x - y||₂`.
#[inline]
pub fn dist2(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut s = 0.0;
    for i in 0..x.len() {
        let d = x[i] - y[i];
        s += d * d;
    }
    s.sqrt()
}

/// `||x - y||₂²`.
#[inline]
pub fn dist2_sq(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut s = 0.0;
    for i in 0..x.len() {
        let d = x[i] - y[i];
        s += d * d;
    }
    s
}

/// Elementwise copy (explicit name for hot-loop readability).
#[inline]
pub fn copy(src: &[f64], dst: &mut [f64]) {
    dst.copy_from_slice(src);
}

/// `out = x - y`.
#[inline]
pub fn sub(x: &[f64], y: &[f64], out: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    for i in 0..x.len() {
        out[i] = x[i] - y[i];
    }
}

/// `out = x + y`.
#[inline]
pub fn add(x: &[f64], y: &[f64], out: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    for i in 0..x.len() {
        out[i] = x[i] + y[i];
    }
}

/// True if all entries are finite (divergence guard in the coordinators).
#[inline]
pub fn all_finite(x: &[f64]) -> bool {
    x.iter().all(|v| v.is_finite())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f64> = (0..37).map(|i| i as f64 * 0.5).collect();
        let y: Vec<f64> = (0..37).map(|i| (i as f64 - 18.0) * 0.25).collect();
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-10);
    }

    #[test]
    fn axpy_basic() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
    }

    #[test]
    fn axpby_basic() {
        let x = vec![1.0, 2.0];
        let mut y = vec![3.0, 4.0];
        axpby(2.0, &x, 0.5, &mut y);
        assert_eq!(y, vec![3.5, 6.0]);
    }

    #[test]
    fn acc_axpy_basic() {
        let x = vec![1.0, 2.0];
        let y = vec![10.0, 20.0];
        let mut out = vec![0.5, 0.5];
        acc_axpy(3.0, &x, &y, &mut out);
        // out_i + 3*x_i + y_i
        assert_eq!(out, vec![13.5, 26.5]);
    }

    #[test]
    fn norms() {
        let x = vec![3.0, -4.0];
        assert!((nrm2(&x) - 5.0).abs() < 1e-12);
        assert!((nrm1(&x) - 7.0).abs() < 1e-12);
        assert!((nrm_inf(&x) - 4.0).abs() < 1e-12);
        assert!((nrm2_sq(&x) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn distances() {
        let x = vec![1.0, 1.0];
        let y = vec![4.0, 5.0];
        assert!((dist2(&x, &y) - 5.0).abs() < 1e-12);
        assert!((dist2_sq(&x, &y) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn add_sub() {
        let x = vec![1.0, 2.0];
        let y = vec![0.5, 1.0];
        let mut out = vec![0.0; 2];
        sub(&x, &y, &mut out);
        assert_eq!(out, vec![0.5, 1.0]);
        add(&x, &y, &mut out);
        assert_eq!(out, vec![1.5, 3.0]);
    }

    #[test]
    fn finite_guard() {
        assert!(all_finite(&[1.0, -2.0]));
        assert!(!all_finite(&[1.0, f64::NAN]));
        assert!(!all_finite(&[f64::INFINITY]));
    }
}
