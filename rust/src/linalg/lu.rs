//! LU factorization with partial pivoting.
//!
//! Needed for *indefinite* subproblem systems — the sparse-PCA worker solve
//! `(ρI − 2BᵀB) x = rhs` when `ρ < 2λmax(BᵀB)` (the `β = 1.5` divergence
//! regime of Fig. 3, which we must still be able to *run*).

use super::dense::DenseMatrix;

/// `P A = L U` with partial pivoting; stored packed in one square buffer.
#[derive(Clone, Debug)]
pub struct Lu {
    n: usize,
    lu: Vec<f64>,
    piv: Vec<usize>,
    /// Number of row swaps (determinant sign).
    swaps: usize,
}

/// The matrix is numerically singular.
#[derive(Debug, Clone, PartialEq)]
pub struct Singular {
    pub pivot: usize,
}

impl std::fmt::Display for Singular {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "matrix singular at pivot {}", self.pivot)
    }
}

impl std::error::Error for Singular {}

impl Lu {
    /// Factor a general square matrix.
    pub fn factor(a: &DenseMatrix) -> Result<Self, Singular> {
        assert_eq!(a.rows(), a.cols());
        let n = a.rows();
        let mut lu = a.data().to_vec();
        let mut piv: Vec<usize> = (0..n).collect();
        let mut swaps = 0;
        for k in 0..n {
            // pivot search
            let mut p = k;
            let mut best = lu[k * n + k].abs();
            for i in k + 1..n {
                let v = lu[i * n + k].abs();
                if v > best {
                    best = v;
                    p = i;
                }
            }
            if best == 0.0 || !best.is_finite() {
                return Err(Singular { pivot: k });
            }
            if p != k {
                for j in 0..n {
                    lu.swap(k * n + j, p * n + j);
                }
                piv.swap(k, p);
                swaps += 1;
            }
            let pivval = lu[k * n + k];
            for i in k + 1..n {
                let m = lu[i * n + k] / pivval;
                lu[i * n + k] = m;
                if m != 0.0 {
                    for j in k + 1..n {
                        lu[i * n + j] -= m * lu[k * n + j];
                    }
                }
            }
        }
        Ok(Lu { n, lu, piv, swaps })
    }

    pub fn dim(&self) -> usize {
        self.n
    }

    /// Solve `A x = b` (allocates).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        assert_eq!(b.len(), n);
        // apply permutation
        let mut x: Vec<f64> = (0..n).map(|i| b[self.piv[i]]).collect();
        // forward: L y = Pb (unit diagonal)
        for i in 1..n {
            let mut s = x[i];
            for k in 0..i {
                s -= self.lu[i * n + k] * x[k];
            }
            x[i] = s;
        }
        // backward: U x = y
        for i in (0..n).rev() {
            let mut s = x[i];
            for k in i + 1..n {
                s -= self.lu[i * n + k] * x[k];
            }
            x[i] = s / self.lu[i * n + i];
        }
        x
    }

    /// Determinant (product of U diagonal, sign from swap parity).
    pub fn det(&self) -> f64 {
        let mut d: f64 = if self.swaps % 2 == 0 { 1.0 } else { -1.0 };
        for i in 0..self.n {
            d *= self.lu[i * self.n + i];
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops;
    use crate::rng::Pcg64;

    #[test]
    fn solve_small_known() {
        let a = DenseMatrix::from_rows(&[&[2.0, 1.0], &[1.0, 3.0]]);
        let lu = Lu::factor(&a).unwrap();
        let x = lu.solve(&[3.0, 5.0]);
        // x = [4/5, 7/5]
        assert!((x[0] - 0.8).abs() < 1e-12);
        assert!((x[1] - 1.4).abs() < 1e-12);
    }

    #[test]
    fn requires_pivoting() {
        // zero leading pivot: unpivoted Gaussian elimination would fail.
        let a = DenseMatrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let lu = Lu::factor(&a).unwrap();
        let x = lu.solve(&[2.0, 3.0]);
        assert!((x[0] - 3.0).abs() < 1e-12);
        assert!((x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn indefinite_system_solves() {
        // Cholesky would reject this; LU must handle it (sparse-PCA regime).
        let a = DenseMatrix::from_rows(&[&[1.0, 0.0], &[0.0, -2.0]]);
        let lu = Lu::factor(&a).unwrap();
        let x = lu.solve(&[1.0, 4.0]);
        assert_eq!(x, vec![1.0, -2.0]);
    }

    #[test]
    fn random_residuals() {
        let mut rng = Pcg64::seed_from_u64(6);
        for n in [1usize, 3, 10, 50] {
            let a = DenseMatrix::randn(&mut rng, n, n);
            let b: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).cos()).collect();
            let lu = Lu::factor(&a).unwrap();
            let x = lu.solve(&b);
            let r = a.matvec(&x);
            let rel = vecops::dist2(&r, &b) / vecops::nrm2(&b).max(1.0);
            assert!(rel < 1e-8, "n={n} rel={rel}");
        }
    }

    #[test]
    fn singular_rejected() {
        let a = DenseMatrix::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(Lu::factor(&a).is_err());
    }

    #[test]
    fn det_matches_2x2_formula() {
        let a = DenseMatrix::from_rows(&[&[3.0, 1.0], &[2.0, 5.0]]);
        let lu = Lu::factor(&a).unwrap();
        assert!((lu.det() - 13.0).abs() < 1e-10);
    }
}
