//! CSR sparse matrices.
//!
//! The Fig. 3 workload is sparse: each worker holds a `1000 × 500` block
//! `B_j` with ≈ 5000 non-zeros (1% density). Forming `B_jᵀB_j` densely is
//! still cheap at 500², but the mat-vecs used by power iteration and CG stay
//! sparse here.

use crate::rng::Pcg64;

use super::dense::DenseMatrix;
use super::vecops;

/// Compressed-sparse-row matrix.
#[derive(Clone, Debug)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    /// Row start offsets, length `rows + 1`.
    indptr: Vec<usize>,
    /// Column indices per non-zero.
    indices: Vec<usize>,
    /// Non-zero values.
    values: Vec<f64>,
}

impl CsrMatrix {
    /// Build from COO triplets (duplicates summed).
    pub fn from_triplets(rows: usize, cols: usize, triplets: &[(usize, usize, f64)]) -> Self {
        let mut sorted: Vec<(usize, usize, f64)> = triplets.to_vec();
        sorted.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices: Vec<usize> = Vec::with_capacity(sorted.len());
        let mut values: Vec<f64> = Vec::with_capacity(sorted.len());
        let mut cur_row = 0usize;
        for &(r, c, v) in &sorted {
            assert!(r < rows && c < cols, "triplet out of bounds");
            // close out rows up to r
            while cur_row < r {
                indptr[cur_row + 1] = indices.len();
                cur_row += 1;
            }
            // duplicate within this row?
            if indices.len() > indptr[r] && *indices.last().unwrap() == c { // ad-lint: allow(panic-free-lib): guarded by the indices.len() check on this line
                *values.last_mut().unwrap() += v;
            } else {
                indices.push(c);
                values.push(v);
            }
        }
        while cur_row < rows {
            indptr[cur_row + 1] = indices.len();
            cur_row += 1;
        }
        CsrMatrix { rows, cols, indptr, indices, values }
    }

    /// Random sparse matrix with exactly `nnz` entries at distinct positions,
    /// values ~ N(0,1) — the paper's `B_j` generator.
    pub fn random(rng: &mut Pcg64, rows: usize, cols: usize, nnz: usize) -> Self {
        assert!(nnz <= rows * cols);
        // sample distinct flat indices
        let mut seen = std::collections::HashSet::with_capacity(nnz * 2);
        let mut triplets = Vec::with_capacity(nnz);
        while triplets.len() < nnz {
            let flat = rng.below((rows * cols) as u64) as usize;
            if seen.insert(flat) {
                triplets.push((flat / cols, flat % cols, rng.normal()));
            }
        }
        CsrMatrix::from_triplets(rows, cols, &triplets)
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored non-zeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// `y = B x`.
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for r in 0..self.rows {
            let mut s = 0.0;
            for k in self.indptr[r]..self.indptr[r + 1] {
                s += self.values[k] * x[self.indices[k]];
            }
            y[r] = s;
        }
    }

    /// `y = Bᵀ x`.
    pub fn matvec_t_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        for r in 0..self.rows {
            let xr = x[r];
            if xr == 0.0 {
                continue;
            }
            for k in self.indptr[r]..self.indptr[r + 1] {
                y[self.indices[k]] += self.values[k] * xr;
            }
        }
    }

    /// Fused `y = Bᵀ(B x)` with caller scratch of length `rows`.
    pub fn gram_matvec_into(&self, x: &[f64], scratch: &mut [f64], y: &mut [f64]) {
        self.matvec_into(x, scratch);
        self.matvec_t_into(scratch, y);
    }

    /// Dense `BᵀB` (cols × cols) — formed once per worker for the direct
    /// subproblem factorization.
    pub fn gram_dense(&self) -> DenseMatrix {
        let n = self.cols;
        let mut g = DenseMatrix::zeros(n, n);
        for r in 0..self.rows {
            let lo = self.indptr[r];
            let hi = self.indptr[r + 1];
            for a in lo..hi {
                let (ia, va) = (self.indices[a], self.values[a]);
                for b in lo..hi {
                    let (ib, vb) = (self.indices[b], self.values[b]);
                    let cur = g.get(ia, ib);
                    g.set(ia, ib, cur + va * vb);
                }
            }
        }
        g
    }

    /// Densify (tests + PJRT marshalling, where artifacts take dense blocks).
    pub fn to_dense(&self) -> DenseMatrix {
        let mut d = DenseMatrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for k in self.indptr[r]..self.indptr[r + 1] {
                d.set(r, self.indices[k], self.values[k]);
            }
        }
        d
    }

    /// Quadratic form `xᵀ BᵀB x = ||Bx||²` (sparse-PCA objective term).
    pub fn quad_form(&self, x: &[f64], scratch: &mut [f64]) -> f64 {
        self.matvec_into(x, scratch);
        vecops::nrm2_sq(scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example() -> CsrMatrix {
        // [[1, 0, 2],
        //  [0, 0, 0],
        //  [3, 4, 0]]
        CsrMatrix::from_triplets(3, 3, &[(0, 0, 1.0), (0, 2, 2.0), (2, 0, 3.0), (2, 1, 4.0)])
    }

    #[test]
    fn matvec_matches_dense() {
        let m = example();
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![0.0; 3];
        m.matvec_into(&x, &mut y);
        assert_eq!(y, vec![7.0, 0.0, 11.0]);
    }

    #[test]
    fn matvec_t_matches_dense() {
        let m = example();
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![0.0; 3];
        m.matvec_t_into(&x, &mut y);
        assert_eq!(y, vec![10.0, 12.0, 2.0]);
    }

    #[test]
    fn duplicates_are_summed() {
        let m = CsrMatrix::from_triplets(1, 1, &[(0, 0, 1.0), (0, 0, 2.5)]);
        assert_eq!(m.nnz(), 1);
        let mut y = vec![0.0; 1];
        m.matvec_into(&[2.0], &mut y);
        assert_eq!(y, vec![7.0]);
    }

    #[test]
    fn random_has_requested_nnz_and_matches_dense_ops() {
        let mut rng = Pcg64::seed_from_u64(12);
        let m = CsrMatrix::random(&mut rng, 40, 25, 100);
        assert_eq!(m.nnz(), 100);
        let d = m.to_dense();
        let x: Vec<f64> = (0..25).map(|i| (i as f64).cos()).collect();
        let mut ys = vec![0.0; 40];
        m.matvec_into(&x, &mut ys);
        let yd = d.matvec(&x);
        for i in 0..40 {
            assert!((ys[i] - yd[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn gram_dense_matches_dense_gram() {
        let mut rng = Pcg64::seed_from_u64(13);
        let m = CsrMatrix::random(&mut rng, 30, 12, 60);
        let g1 = m.gram_dense();
        let g2 = m.to_dense().gram();
        assert!(g1.max_abs_diff(&g2) < 1e-10);
    }

    #[test]
    fn gram_matvec_consistency() {
        let mut rng = Pcg64::seed_from_u64(14);
        let m = CsrMatrix::random(&mut rng, 20, 10, 50);
        let x: Vec<f64> = (0..10).map(|i| i as f64 * 0.3 - 1.0).collect();
        let mut scratch = vec![0.0; 20];
        let mut y = vec![0.0; 10];
        m.gram_matvec_into(&x, &mut scratch, &mut y);
        let g = m.gram_dense();
        let yd = g.matvec(&x);
        for i in 0..10 {
            assert!((y[i] - yd[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn quad_form_is_norm_of_bx() {
        let m = example();
        let x = vec![1.0, 1.0, 1.0];
        let mut scratch = vec![0.0; 3];
        let q = m.quad_form(&x, &mut scratch);
        // Bx = [3, 0, 7] → 9 + 49 = 58
        assert!((q - 58.0).abs() < 1e-12);
    }

    #[test]
    fn empty_rows_are_fine() {
        let m = CsrMatrix::from_triplets(4, 2, &[(3, 1, 5.0)]);
        let mut y = vec![0.0; 4];
        m.matvec_into(&[1.0, 2.0], &mut y);
        assert_eq!(y, vec![0.0, 0.0, 0.0, 10.0]);
    }
}
