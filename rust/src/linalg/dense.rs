//! Row-major dense matrices with the BLAS-2/3 kernels the solvers need.

use crate::rng::Pcg64;

use super::vecops;

/// Row-major dense `rows x cols` matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl DenseMatrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        DenseMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity (square).
    pub fn eye(n: usize) -> Self {
        let mut m = DenseMatrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        DenseMatrix { rows, cols, data }
    }

    /// Build from nested rows (tests / small examples).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        DenseMatrix { rows: r, cols: c, data }
    }

    /// Standard-normal random matrix (the paper's LASSO `A_i ~ N(0,1)`).
    pub fn randn(rng: &mut Pcg64, rows: usize, cols: usize) -> Self {
        let mut m = DenseMatrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data);
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// `y = A x` (allocates).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// `y = A x` into a caller buffer (hot path, no allocation).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            y[i] = vecops::dot(self.row(i), x);
        }
    }

    /// `y = Aᵀ x` (allocates).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.matvec_t_into(x, &mut y);
        y
    }

    /// `y = Aᵀ x` into a caller buffer. Row-major transpose product is an
    /// axpy sweep over rows, which keeps the access pattern contiguous.
    pub fn matvec_t_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        for i in 0..self.rows {
            vecops::axpy(x[i], self.row(i), y);
        }
    }

    /// Fused Gram mat-vec `y = Aᵀ(A x)` with a caller-supplied scratch of
    /// length `rows`. This mirrors the L1 Pallas kernel and is the native
    /// backend's CG hot loop.
    pub fn gram_matvec_into(&self, x: &[f64], scratch: &mut [f64], y: &mut [f64]) {
        assert_eq!(scratch.len(), self.rows);
        self.matvec_into(x, scratch);
        self.matvec_t_into(scratch, y);
    }

    /// `C = A B` (allocates).
    pub fn matmul(&self, b: &DenseMatrix) -> DenseMatrix {
        assert_eq!(self.cols, b.rows, "inner dims");
        let mut c = DenseMatrix::zeros(self.rows, b.cols);
        // ikj loop order: streams B rows, C rows stay hot.
        for i in 0..self.rows {
            let arow = self.row(i);
            let crow = &mut c.data[i * b.cols..(i + 1) * b.cols];
            for (k, &aik) in arow.iter().enumerate() {
                if aik != 0.0 {
                    vecops::axpy(aik, b.row(k), crow);
                }
            }
        }
        c
    }

    /// Symmetric Gram product `G = AᵀA` exploiting symmetry (half the FLOPs
    /// of a general GEMM). Used once per worker to set up the subproblem
    /// normal equations.
    pub fn gram(&self) -> DenseMatrix {
        let n = self.cols;
        let mut g = DenseMatrix::zeros(n, n);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..n {
                let ai = row[i];
                if ai == 0.0 {
                    continue;
                }
                let grow = &mut g.data[i * n..i * n + n];
                // only j >= i (upper triangle)
                for j in i..n {
                    grow[j] += ai * row[j];
                }
            }
        }
        // mirror
        for i in 0..n {
            for j in i + 1..n {
                let v = g.data[i * n + j];
                g.data[j * n + i] = v;
            }
        }
        g
    }

    /// Transposed copy.
    pub fn transpose(&self) -> DenseMatrix {
        let mut t = DenseMatrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// `A += a * I` (square only); the `+ρI` shift of the normal equations.
    pub fn add_diag(&mut self, a: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self.data[i * self.cols + i] += a;
        }
    }

    /// `A *= a`.
    pub fn scale(&mut self, a: f64) {
        vecops::scale(a, &mut self.data);
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        vecops::nrm2(&self.data)
    }

    /// Max |a_ij| difference against another matrix (test helper).
    pub fn max_abs_diff(&self, other: &DenseMatrix) -> f64 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0, |m, (a, b)| m.max((a - b).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> DenseMatrix {
        DenseMatrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]])
    }

    #[test]
    fn matvec_and_transpose_matvec() {
        let a = small();
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0, 11.0]);
        assert_eq!(a.matvec_t(&[1.0, 1.0, 1.0]), vec![9.0, 12.0]);
    }

    #[test]
    fn matmul_matches_hand_computed() {
        let a = small(); // 3x2
        let b = DenseMatrix::from_rows(&[&[1.0, 0.0, 2.0], &[0.0, 1.0, 2.0]]); // 2x3
        let c = a.matmul(&b);
        let expect = DenseMatrix::from_rows(&[
            &[1.0, 2.0, 6.0],
            &[3.0, 4.0, 14.0],
            &[5.0, 6.0, 22.0],
        ]);
        assert!(c.max_abs_diff(&expect) < 1e-12);
    }

    #[test]
    fn gram_matches_explicit_product() {
        let mut rng = Pcg64::seed_from_u64(1);
        let a = DenseMatrix::randn(&mut rng, 17, 9);
        let g1 = a.gram();
        let g2 = a.transpose().matmul(&a);
        assert!(g1.max_abs_diff(&g2) < 1e-9);
    }

    #[test]
    fn gram_matvec_fused_matches_two_step() {
        let mut rng = Pcg64::seed_from_u64(2);
        let a = DenseMatrix::randn(&mut rng, 13, 7);
        let x: Vec<f64> = (0..7).map(|i| i as f64 * 0.1 - 0.3).collect();
        let mut scratch = vec![0.0; 13];
        let mut y = vec![0.0; 7];
        a.gram_matvec_into(&x, &mut scratch, &mut y);
        let expect = a.matvec_t(&a.matvec(&x));
        for i in 0..7 {
            assert!((y[i] - expect[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn transpose_round_trip() {
        let a = small();
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn eye_and_add_diag() {
        let mut m = DenseMatrix::eye(3);
        m.add_diag(2.0);
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j { 3.0 } else { 0.0 };
                assert_eq!(m.get(i, j), want);
            }
        }
    }

    #[test]
    fn randn_has_sane_scale() {
        let mut rng = Pcg64::seed_from_u64(9);
        let a = DenseMatrix::randn(&mut rng, 100, 100);
        let fro = a.fro_norm();
        // E[fro²] = 10_000 → fro ≈ 100
        assert!((fro - 100.0).abs() < 5.0, "fro={fro}");
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_mismatch_panics() {
        DenseMatrix::from_vec(2, 2, vec![1.0, 2.0, 3.0]);
    }
}
