//! Power iteration for the largest eigenvalue of a symmetric PSD operator.
//!
//! The paper sets the sparse-PCA penalty as `ρ = β · max_j λmax(B_jᵀB_j)`
//! (Fig. 3 caption) and the Lipschitz constants of the quadratic losses are
//! `2 λmax` as well, so this is the parameter-rule substrate.

use super::vecops;
use crate::rng::Pcg64;

/// Estimate `λmax` of the symmetric operator `apply` on `R^n`.
///
/// Returns `(lambda_max, iterations_used)`. Deterministic given `seed`.
pub fn power_iteration<F>(
    mut apply: F,
    n: usize,
    max_iters: usize,
    tol: f64,
    seed: u64,
) -> (f64, usize)
where
    F: FnMut(&[f64], &mut [f64]),
{
    assert!(n > 0);
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut v = vec![0.0; n];
    rng.fill_normal(&mut v);
    let nrm = vecops::nrm2(&v).max(f64::MIN_POSITIVE);
    vecops::scale(1.0 / nrm, &mut v);

    let mut av = vec![0.0; n];
    let mut lambda = 0.0;
    for it in 1..=max_iters {
        apply(&v, &mut av);
        let new_lambda = vecops::dot(&v, &av); // Rayleigh quotient
        let nrm = vecops::nrm2(&av);
        if nrm <= f64::MIN_POSITIVE {
            return (0.0, it); // operator annihilated v: λmax ≈ 0
        }
        for i in 0..n {
            v[i] = av[i] / nrm;
        }
        if (new_lambda - lambda).abs() <= tol * new_lambda.abs().max(1.0) && it > 3 {
            return (new_lambda, it);
        }
        lambda = new_lambda;
    }
    (lambda, max_iters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::DenseMatrix;

    #[test]
    fn diagonal_matrix_lambda_max() {
        let d = DenseMatrix::from_rows(&[&[3.0, 0.0, 0.0], &[0.0, 7.0, 0.0], &[0.0, 0.0, 1.0]]);
        let (lam, _) = power_iteration(|v, out| d.matvec_into(v, out), 3, 500, 1e-12, 1);
        assert!((lam - 7.0).abs() < 1e-6, "lam={lam}");
    }

    #[test]
    fn gram_lambda_max_matches_square_of_norm_for_rank_one() {
        // A = u vᵀ → AᵀA has λmax = ||u||² ||v||².
        let u = [1.0, 2.0];
        let v = [3.0, 0.0, 4.0];
        let mut a = DenseMatrix::zeros(2, 3);
        for i in 0..2 {
            for j in 0..3 {
                a.set(i, j, u[i] * v[j]);
            }
        }
        let mut scratch = vec![0.0; 2];
        let (lam, _) = power_iteration(
            |x, out| a.gram_matvec_into(x, &mut scratch, out),
            3,
            1000,
            1e-12,
            2,
        );
        let expect = (1.0 + 4.0) * (9.0 + 16.0); // 125
        assert!((lam - expect).abs() / expect < 1e-6, "lam={lam}");
    }

    #[test]
    fn deterministic_given_seed() {
        let d = DenseMatrix::eye(4);
        let (a, _) = power_iteration(|v, out| d.matvec_into(v, out), 4, 50, 1e-10, 3);
        let (b, _) = power_iteration(|v, out| d.matvec_into(v, out), 4, 50, 1e-10, 3);
        assert_eq!(a, b);
        assert!((a - 1.0).abs() < 1e-9);
    }
}
