//! Dense + sparse linear algebra substrate.
//!
//! The paper's subproblems are quadratic solves and mat-vecs over blocks of
//! at most a few thousand columns; this module supplies exactly what the
//! coordinator, the native solver backend and the baselines need, written on
//! `std` only (no BLAS/LAPACK on the image):
//!
//! - [`vecops`]   — BLAS-1: dot, axpy, norms, scaling (unrolled).
//! - [`dense`]    — row-major [`dense::DenseMatrix`], GEMV/GEMM, Gram (`AᵀA`).
//! - [`cholesky`] — SPD factorization + solves (worker subproblem hot path).
//! - [`lu`]       — partial-pivoted LU for indefinite systems (sparse-PCA
//!                  with `ρ < 2λmax`, i.e. the paper's divergence regime).
//! - [`cg`]       — conjugate gradient (mirrors the L2 JAX solver).
//! - [`power`]    — power iteration for `λmax` (the paper's `ρ = β·λmax` rule).
//! - [`sparse`]   — CSR matrices for the sparse-PCA data blocks.

pub mod cg;
pub mod cholesky;
pub mod dense;
pub mod lu;
pub mod power;
pub mod sparse;
pub mod vecops;

pub use dense::DenseMatrix;
pub use sparse::CsrMatrix;
