//! Cholesky factorization for SPD systems.
//!
//! Every worker's subproblem is `(2AᵀA + ρI) x = rhs` (LASSO / logistic
//! Newton) or `(ρI − 2BᵀB) x = rhs` (sparse PCA, SPD iff `ρ > 2λmax`), with
//! a matrix that is **fixed across iterations**. The coordinator therefore
//! factors once and backsolves per iteration — the single most important
//! native-backend optimization (O(n³) once, O(n²) per master iteration).

use super::dense::DenseMatrix;

/// Lower-triangular Cholesky factor `L` with `L Lᵀ = A`.
#[derive(Clone, Debug)]
pub struct Cholesky {
    n: usize,
    /// Row-major lower triangle (full square storage for simple indexing).
    l: Vec<f64>,
}

/// Factorization failure: the matrix is not (numerically) positive definite.
#[derive(Debug, Clone, PartialEq)]
pub struct NotPositiveDefinite {
    /// Pivot index at which the factorization broke down.
    pub pivot: usize,
    /// The offending pivot value.
    pub value: f64,
}

impl std::fmt::Display for NotPositiveDefinite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "matrix not positive definite at pivot {} (value {:.3e})", self.pivot, self.value)
    }
}

impl std::error::Error for NotPositiveDefinite {}

impl Cholesky {
    /// Factor an SPD matrix. Returns `Err` if a pivot is ≤ 0 (matrix
    /// indefinite — e.g. sparse-PCA subproblems with `ρ < 2λmax`).
    pub fn factor(a: &DenseMatrix) -> Result<Self, NotPositiveDefinite> {
        assert_eq!(a.rows(), a.cols(), "Cholesky needs a square matrix");
        let n = a.rows();
        let mut l = a.data().to_vec();
        for j in 0..n {
            // diagonal pivot
            let mut d = l[j * n + j];
            for k in 0..j {
                let v = l[j * n + k];
                d -= v * v;
            }
            if d <= 0.0 || !d.is_finite() {
                return Err(NotPositiveDefinite { pivot: j, value: d });
            }
            let dj = d.sqrt();
            l[j * n + j] = dj;
            let inv = 1.0 / dj;
            for i in j + 1..n {
                let mut s = l[i * n + j];
                // s -= L[i,0..j] · L[j,0..j]
                let (ri, rj) = (i * n, j * n);
                for k in 0..j {
                    s -= l[ri + k] * l[rj + k];
                }
                l[ri + j] = s * inv;
            }
        }
        // zero the strict upper triangle for cleanliness
        for i in 0..n {
            for j in i + 1..n {
                l[i * n + j] = 0.0;
            }
        }
        Ok(Cholesky { n, l })
    }

    pub fn dim(&self) -> usize {
        self.n
    }

    /// Solve `A x = b` (allocates).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_in_place(&mut x);
        x
    }

    /// Solve `A x = b` in place: forward then backward substitution.
    pub fn solve_in_place(&self, x: &mut [f64]) {
        let n = self.n;
        assert_eq!(x.len(), n);
        // L y = b
        for i in 0..n {
            let row = &self.l[i * n..i * n + i];
            let mut s = x[i];
            for (k, &lik) in row.iter().enumerate() {
                s -= lik * x[k];
            }
            x[i] = s / self.l[i * n + i];
        }
        // Lᵀ x = y
        for i in (0..n).rev() {
            let mut s = x[i];
            for k in i + 1..n {
                s -= self.l[k * n + i] * x[k];
            }
            x[i] = s / self.l[i * n + i];
        }
    }

    /// log-determinant of `A` (`2 Σ log L_ii`); used by tests.
    pub fn log_det(&self) -> f64 {
        (0..self.n).map(|i| self.l[i * self.n + i].ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops;
    use crate::rng::Pcg64;

    fn spd(rng: &mut Pcg64, n: usize) -> DenseMatrix {
        // AᵀA + I is SPD.
        let a = DenseMatrix::randn(rng, n + 3, n);
        let mut g = a.gram();
        g.add_diag(1.0);
        g
    }

    #[test]
    fn factor_and_solve_small() {
        let a = DenseMatrix::from_rows(&[&[4.0, 2.0], &[2.0, 3.0]]);
        let ch = Cholesky::factor(&a).unwrap();
        let x = ch.solve(&[2.0, 3.0]);
        // residual check
        let r = a.matvec(&x);
        assert!((r[0] - 2.0).abs() < 1e-12 && (r[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn random_spd_residuals_small() {
        let mut rng = Pcg64::seed_from_u64(4);
        for n in [1usize, 2, 5, 20, 64] {
            let a = spd(&mut rng, n);
            let b: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
            let ch = Cholesky::factor(&a).unwrap();
            let x = ch.solve(&b);
            let r = a.matvec(&x);
            let rel = vecops::dist2(&r, &b) / vecops::nrm2(&b).max(1.0);
            assert!(rel < 1e-9, "n={n} rel={rel}");
        }
    }

    #[test]
    fn indefinite_is_rejected() {
        let a = DenseMatrix::from_rows(&[&[1.0, 0.0], &[0.0, -1.0]]);
        let err = Cholesky::factor(&a).unwrap_err();
        assert_eq!(err.pivot, 1);
    }

    #[test]
    fn log_det_identity_is_zero() {
        let ch = Cholesky::factor(&DenseMatrix::eye(5)).unwrap();
        assert!(ch.log_det().abs() < 1e-12);
    }

    #[test]
    fn solve_in_place_matches_solve() {
        let mut rng = Pcg64::seed_from_u64(5);
        let a = spd(&mut rng, 12);
        let b: Vec<f64> = (0..12).map(|i| i as f64 - 6.0).collect();
        let ch = Cholesky::factor(&a).unwrap();
        let x1 = ch.solve(&b);
        let mut x2 = b.clone();
        ch.solve_in_place(&mut x2);
        assert_eq!(x1, x2);
    }
}
