//! Conjugate gradient for SPD systems, matrix-free.
//!
//! The native mirror of the L2 JAX solver (`python/compile/model.py` runs a
//! fixed-iteration CG inside a `lax.scan`, calling the Pallas Gram kernel for
//! every `Aᵀ(A p)` product). Keeping the two implementations structurally
//! identical makes the PJRT-vs-native parity tests meaningful.

use super::vecops;

/// Outcome of a CG solve.
#[derive(Clone, Debug)]
pub struct CgResult {
    /// Iterations actually performed.
    pub iters: usize,
    /// Final residual norm `||b - A x||`.
    pub residual: f64,
    /// Whether the tolerance was met (vs. iteration cap reached).
    pub converged: bool,
}

/// Solve `A x = b` for SPD `A` given as a mat-vec closure.
///
/// `x` holds the initial guess on entry and the solution on exit.
/// Terminates at `max_iters` or when `||r|| <= tol * ||b||`.
pub fn cg_solve<F>(mut apply_a: F, b: &[f64], x: &mut [f64], max_iters: usize, tol: f64) -> CgResult
where
    F: FnMut(&[f64], &mut [f64]),
{
    let n = b.len();
    assert_eq!(x.len(), n);
    let bnorm = vecops::nrm2(b).max(f64::MIN_POSITIVE);

    let mut r = vec![0.0; n];
    let mut ap = vec![0.0; n];
    // r = b - A x
    apply_a(x, &mut ap);
    for i in 0..n {
        r[i] = b[i] - ap[i];
    }
    let mut p = r.clone();
    let mut rs_old = vecops::nrm2_sq(&r);

    if rs_old.sqrt() <= tol * bnorm {
        return CgResult { iters: 0, residual: rs_old.sqrt(), converged: true };
    }

    let mut iters = 0;
    for _ in 0..max_iters {
        apply_a(&p, &mut ap);
        let pap = vecops::dot(&p, &ap);
        if pap <= 0.0 || !pap.is_finite() {
            // Not SPD along p (e.g. sparse-PCA with too-small ρ): bail with
            // whatever iterate we have; caller decides (this mirrors the
            // fixed-iteration JAX kernel which just keeps stepping).
            break;
        }
        let alpha = rs_old / pap;
        vecops::axpy(alpha, &p, x);
        vecops::axpy(-alpha, &ap, &mut r);
        let rs_new = vecops::nrm2_sq(&r);
        iters += 1;
        if rs_new.sqrt() <= tol * bnorm {
            return CgResult { iters, residual: rs_new.sqrt(), converged: true };
        }
        let beta = rs_new / rs_old;
        vecops::axpby(1.0, &r, beta, &mut p);
        rs_old = rs_new;
    }
    CgResult { iters, residual: rs_old.sqrt(), converged: false }
}

/// Fixed-iteration CG with **no tolerance test** — exactly the schedule the
/// AOT-compiled JAX artifact runs (a `lax.scan` cannot early-exit). Used by
/// parity tests to compare iterate-for-iterate.
pub fn cg_fixed<F>(mut apply_a: F, b: &[f64], x: &mut [f64], iters: usize)
where
    F: FnMut(&[f64], &mut [f64]),
{
    let n = b.len();
    let mut r = vec![0.0; n];
    let mut ap = vec![0.0; n];
    apply_a(x, &mut ap);
    for i in 0..n {
        r[i] = b[i] - ap[i];
    }
    let mut p = r.clone();
    let mut rs_old = vecops::nrm2_sq(&r);
    for _ in 0..iters {
        apply_a(&p, &mut ap);
        let pap = vecops::dot(&p, &ap);
        // Mirror the JAX kernel: guard the division but keep iterating.
        let alpha = if pap.abs() > 1e-300 { rs_old / pap } else { 0.0 };
        vecops::axpy(alpha, &p, x);
        vecops::axpy(-alpha, &ap, &mut r);
        let rs_new = vecops::nrm2_sq(&r);
        let beta = if rs_old.abs() > 1e-300 { rs_new / rs_old } else { 0.0 };
        vecops::axpby(1.0, &r, beta, &mut p);
        rs_old = rs_new;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::DenseMatrix;
    use crate::rng::Pcg64;

    fn spd_system(n: usize, seed: u64) -> (DenseMatrix, Vec<f64>) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let a = DenseMatrix::randn(&mut rng, n + 5, n);
        let mut g = a.gram();
        g.add_diag(1.0);
        let b: Vec<f64> = (0..n).map(|i| ((i * 3) as f64).sin()).collect();
        (g, b)
    }

    #[test]
    fn converges_on_spd() {
        let (g, b) = spd_system(30, 8);
        let mut x = vec![0.0; 30];
        let res = cg_solve(|v, out| g.matvec_into(v, out), &b, &mut x, 200, 1e-10);
        assert!(res.converged, "residual={}", res.residual);
        let r = g.matvec(&x);
        let rel = vecops::dist2(&r, &b) / vecops::nrm2(&b);
        assert!(rel < 1e-8, "rel={rel}");
    }

    #[test]
    fn exact_in_n_iterations() {
        // CG is exact after n steps in exact arithmetic; allow slack.
        let (g, b) = spd_system(10, 9);
        let mut x = vec![0.0; 10];
        let res = cg_solve(|v, out| g.matvec_into(v, out), &b, &mut x, 15, 1e-12);
        assert!(res.converged);
        assert!(res.iters <= 12);
    }

    #[test]
    fn identity_solves_in_one() {
        let b = vec![1.0, 2.0, 3.0];
        let mut x = vec![0.0; 3];
        let res = cg_solve(|v, out| out.copy_from_slice(v), &b, &mut x, 10, 1e-12);
        assert!(res.converged);
        assert!(res.iters <= 1);
        assert!(vecops::dist2(&x, &b) < 1e-12);
    }

    #[test]
    fn warm_start_zero_iterations() {
        let (g, b) = spd_system(8, 10);
        let mut x = vec![0.0; 8];
        cg_solve(|v, out| g.matvec_into(v, out), &b, &mut x, 100, 1e-12);
        // resolve starting from the solution
        let res = cg_solve(|v, out| g.matvec_into(v, out), &b, &mut x, 100, 1e-8);
        assert_eq!(res.iters, 0);
    }

    #[test]
    fn fixed_matches_tolerance_version_when_run_long() {
        let (g, b) = spd_system(20, 11);
        let mut x1 = vec![0.0; 20];
        let mut x2 = vec![0.0; 20];
        cg_solve(|v, out| g.matvec_into(v, out), &b, &mut x1, 60, 0.0);
        cg_fixed(|v, out| g.matvec_into(v, out), &b, &mut x2, 60);
        assert!(vecops::dist2(&x1, &x2) < 1e-8);
    }
}
