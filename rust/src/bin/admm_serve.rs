//! `admm_serve` — the long-lived AD-ADMM solver service.
//!
//! Serve mode (default): accept solve jobs over the framed control plane,
//! run each as the master side of a socket cluster on its own rendezvous
//! port (concurrent jobs multiplex by job id), print and send back a
//! per-job report.
//!
//!   admm_serve --listen 127.0.0.1:7401 [--oneshot]
//!
//! Submit mode: send one job to a running service, print the rendezvous
//! port for workers, block for the report (and the `final x0 digest`
//! line the CI loopback e2e greps):
//!
//!   admm_serve submit --connect 127.0.0.1:7401 --job ci-e2e \
//!       --workers 4 --m 60 --n 40 --tau 3 --iters 60 [--alt] \
//!       [--shard-blocks B --shard-owners C] [--free-running] [--masters M]
//!
//! Workers are separate `admm_worker` processes pointed at the printed
//! port (`--masters M` jobs print M comma-joined rendezvous ports; give
//! workers the whole list). Job flags are shared with `ad-admm
//! transport-digest`, which replays the identical spec through the
//! in-process trace source — under the default lockstep schedule both
//! print the same digest, bit-exact, for any M.

use ad_admm::cluster::transport::{serve, submit, JobSpec};
use ad_admm::util::cli::ArgParser;

fn main() {
    let args = ArgParser::from_env(&["help", "oneshot", "alt", "free-running"]);
    if args.has_flag("help") {
        print_help();
        return;
    }
    let cmd = args.positional().first().map(String::as_str).unwrap_or("serve");
    let result = match cmd {
        "serve" => serve(&args.get_or("listen", "127.0.0.1:7401"), args.has_flag("oneshot")),
        "submit" => JobSpec::from_args(&args)
            .and_then(|spec| submit(&args.get_or("connect", "127.0.0.1:7401"), &spec).map(|_| ())),
        _ => {
            print_help();
            return;
        }
    };
    if let Err(e) = result {
        eprintln!("admm_serve: {e}");
        std::process::exit(2);
    }
}

fn print_help() {
    println!(
        "admm_serve — long-lived AD-ADMM solver service over TCP\n\n\
         USAGE:\n\
         \x20 admm_serve [serve] --listen HOST:PORT [--oneshot]\n\
         \x20 admm_serve submit --connect HOST:PORT --job ID --workers N --m M --n N\n\
         \x20            --rho R --gamma G --tau T --min-arrivals A --iters K --tol E\n\
         \x20            [--alt] [--shard-blocks B --shard-owners C] [--free-running]\n\
         \x20            [--fast-ms F --slow-ms S] [--checkpoint-every N] [--seed S]\n\
         \x20            [--inexact exact|grad:K|proxgrad:K|newton:K|adaptive:TOL0:MAX]\n\
         \x20            [--inexact-workers P0,P1,...] [--masters M]\n\n\
         serve accepts jobs until killed (--oneshot: exit after the first job);\n\
         submit prints the per-job worker rendezvous port(s), then blocks for\n\
         the report. --masters M shards the coordinator itself over M sparse\n\
         masters (requires --shard-blocks, lockstep, non-alt); workers connect\n\
         to all M printed ports. --inexact-workers gives worker i policy Pi."
    );
}
