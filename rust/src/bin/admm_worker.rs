//! `admm_worker` — one AD-ADMM worker process.
//!
//! Connects to a master's rendezvous port (a `SocketSource` bound by
//! `admm_serve` or an embedding test), handshakes, rebuilds its local
//! problem from the assigned job spec, and answers `go` frames until
//! `shutdown`. The round arithmetic is the same code the in-process
//! threaded workers run, so a process fleet computes bit-identical
//! messages.
//!
//!   admm_worker --connect 127.0.0.1:PORT[,HOST:PORT...] --job ID [--worker I]
//!               [--retries N --retry-ms MS] [--max-rounds R]
//!
//! `--worker` pins a slot — a restarted worker names its old slot so the
//! master re-delivers the in-flight broadcast (with its dual reseed) and
//! the run continues bit-identically. `--max-rounds` makes the process
//! exit by dropping its connection cold after R rounds: the emulated
//! crash the disconnect/reconnect e2e uses. A comma-joined `--connect`
//! list (the ports a multi-master `admm_serve` job prints, in master
//! order) runs the multi-master loop: one socket per master, the owned
//! slice multiplexed across the masters owning this worker's blocks.

use std::time::Duration;

use ad_admm::cluster::transport::{run_worker, WorkerClientConfig};
use ad_admm::util::cli::ArgParser;

fn main() {
    let args = ArgParser::from_env(&["help"]);
    if args.has_flag("help") {
        println!(
            "admm_worker — one AD-ADMM worker process\n\n\
             USAGE: admm_worker --connect HOST:PORT[,HOST:PORT...] --job ID\n\
             \x20      [--worker I] [--retries N --retry-ms MS] [--max-rounds R]\n\n\
             a comma-joined --connect list (one address per master, in master\n\
             order) joins a multi-master job on every listed coordinator."
        );
        return;
    }
    let defaults = WorkerClientConfig::default();
    let worker: i64 = args.get_parse_or("worker", -1);
    let max_rounds: usize = args.get_parse_or("max-rounds", 0);
    let cfg = WorkerClientConfig {
        addr: args.get_or("connect", &defaults.addr),
        job_id: args.get_or("job", &defaults.job_id),
        worker: (worker >= 0).then_some(worker as usize),
        retries: args.get_parse_or("retries", defaults.retries),
        retry_delay: Duration::from_millis(args.get_parse_or("retry-ms", 100)),
        max_rounds: (max_rounds > 0).then_some(max_rounds),
    };
    match run_worker(&cfg) {
        Ok(stats) => {
            println!(
                "worker {} done: {} updates, busy {:.3}s, lifetime {:.3}s",
                stats.id, stats.updates, stats.busy_s, stats.lifetime_s
            );
        }
        Err(e) => {
            eprintln!("admm_worker: {e}");
            std::process::exit(2);
        }
    }
}
