//! `ad_admm_lint` — run the repo's static-analysis pass (`ad-lint`).
//!
//! Usage: `ad_admm_lint [--root <dir>] [--json <path>] [--quiet]`
//!
//! Scans `rust/src/**`, `rust/tests/*.rs`, `rust/benches/*.rs`,
//! `examples/*.rs`, and `README.md` under the repo root (auto-detected by
//! walking up from the current directory until `rust/src` exists, or given
//! explicitly with `--root`), runs every rule in
//! [`ad_admm::analysis::rules::registry`], and prints one
//! `file:line:col: error [rule-id] message` line per unsuppressed finding
//! plus a `bench_diff`-style summary
//! (`ad-lint: N files scanned, M rules, K errors (S suppressed)`).
//!
//! `--json <path>` additionally writes the full machine-readable report
//! (schema 1, suppressed findings included with their reasons) for the CI
//! artifact; `-` writes it to stdout. `--quiet` drops the per-finding lines
//! (the summary always prints).
//!
//! Exit status: 0 = clean (no unsuppressed errors), 1 = findings,
//! 2 = usage or I/O failure. The CI `analysis` job gates on this.

use std::path::PathBuf;
use std::process::ExitCode;

use ad_admm::analysis::{analyze, load_tree};

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut json_out: Option<String> = None;
    let mut quiet = false;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(v) => root = Some(PathBuf::from(v)),
                None => return usage("--root needs a directory"),
            },
            "--json" => match args.next() {
                Some(v) => json_out = Some(v),
                None => return usage("--json needs a path (or `-` for stdout)"),
            },
            "--quiet" => quiet = true,
            other => return usage(&format!("unknown argument {other:?}")),
        }
    }

    let root = match root.map(Ok).unwrap_or_else(detect_root) {
        Ok(r) => r,
        Err(msg) => {
            eprintln!("ad-lint: {msg}");
            return ExitCode::from(2);
        }
    };
    let files = match load_tree(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("ad-lint: failed to read tree under {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    if files.is_empty() {
        eprintln!("ad-lint: nothing to scan under {}", root.display());
        return ExitCode::from(2);
    }

    let report = analyze(&files);
    if !quiet {
        for d in &report.diagnostics {
            if !d.suppressed {
                println!("{d}");
            }
        }
    }
    println!("{}", report.summary_line());

    if let Some(path) = json_out {
        let doc = format!("{}\n", report.to_json());
        if path == "-" {
            print!("{doc}");
        } else if let Err(e) = std::fs::write(&path, doc) {
            eprintln!("ad-lint: failed to write {path}: {e}");
            return ExitCode::from(2);
        }
    }

    if report.errors() == 0 {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

/// Walk up from the current directory to the first ancestor containing
/// `rust/src` (so the bin works from the repo root and from `rust/`).
fn detect_root() -> Result<PathBuf, String> {
    let start = std::env::current_dir().map_err(|e| format!("cannot read cwd: {e}"))?;
    let mut dir = start.as_path();
    loop {
        if dir.join("rust/src").is_dir() {
            return Ok(dir.to_path_buf());
        }
        match dir.parent() {
            Some(p) => dir = p,
            None => {
                return Err(format!(
                    "no `rust/src` found walking up from {} (pass --root)",
                    start.display()
                ))
            }
        }
    }
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("ad-lint: {msg}");
    eprintln!("usage: ad_admm_lint [--root <dir>] [--json <path|->] [--quiet]");
    ExitCode::from(2)
}
