//! Diff machine-readable bench reports against committed baselines.
//!
//! Usage: `bench_diff <baseline_dir> <candidate_dir> [--threshold 0.20] [--strict]`
//!
//! Walks every `BENCH_*.json` in the baseline directory, loads the matching
//! candidate report, and compares the comparable numeric leaves:
//!
//! - `metrics/<key>` where the key ends in `_s` (durations, lower is
//!   better) or contains `per_sec`/`speedup` (rates, higher is better);
//! - `stats/<label>/median_s` for every timed section (lower is better).
//!
//! Every compared report additionally gets a one-line
//! `report <file>: N metric(s) compared, worst ±X% (<key>)` verdict even
//! when everything is within threshold, so CI logs always show each
//! baseline was actually exercised.
//!
//! Changes worse than the threshold (default 20%) print a GitHub
//! `::warning::` annotation; with `--strict` (the CI bench-smoke gate)
//! they also fail the run — EXCEPT when the baseline file carries
//! `"provisional": true`, which marks authored upper bounds that have not
//! yet been replaced by measured numbers: those always warn without
//! failing, so the gate can be blocking before every baseline is real.
//!
//! Error semantics: a missing baseline *directory*, or a baseline file
//! that is unreadable, malformed JSON, or an unknown schema version, is a
//! clear exit-2 error (baselines are committed files — corruption must
//! never make the gate vacuously green). A missing or unreadable
//! *candidate* report and quick-vs-full mismatches are reported and
//! skipped (the bench may simply not have run), never failed.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use ad_admm::bench::json::{self, parse, JsonValue};

struct Comparison {
    key: String,
    base: f64,
    cand: f64,
    /// Signed "worseness": positive = regression, negative = improvement,
    /// as a fraction of the baseline.
    regression: f64,
}

enum Direction {
    LowerIsBetter,
    HigherIsBetter,
}

fn direction(key: &str) -> Option<Direction> {
    if key.contains("per_sec") || key.contains("speedup") {
        Some(Direction::HigherIsBetter)
    } else if key.ends_with("_s") {
        Some(Direction::LowerIsBetter)
    } else {
        None
    }
}

fn load(path: &Path) -> Result<JsonValue, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    parse(&text).map_err(|e| format!("{}: {e}", path.display()))
}

/// Collect the comparable `(key, value)` leaves of one report.
fn comparable_leaves(doc: &JsonValue) -> Vec<(String, f64)> {
    let mut leaves = Vec::new();
    for (key, value) in doc.get("metrics").map(JsonValue::entries).unwrap_or(&[]) {
        if let (Some(v), Some(_)) = (value.as_f64(), direction(key)) {
            leaves.push((format!("metrics/{key}"), v));
        }
    }
    for (label, stats) in doc.get("stats").map(JsonValue::entries).unwrap_or(&[]) {
        if let Some(v) = stats.get("median_s").and_then(JsonValue::as_f64) {
            leaves.push((format!("stats/{label}/median_s"), v));
        }
    }
    leaves
}

fn compare(base: &JsonValue, cand: &JsonValue) -> Vec<Comparison> {
    let cand_leaves = comparable_leaves(cand);
    let mut out = Vec::new();
    for (key, base_v) in comparable_leaves(base) {
        let Some((_, cand_v)) = cand_leaves.iter().find(|(k, _)| *k == key) else {
            continue;
        };
        if base_v <= 0.0 {
            continue; // degenerate baseline; nothing meaningful to report
        }
        let leaf = key.rsplit('/').next().unwrap_or(&key);
        let regression = match direction(leaf).expect("leaves are pre-filtered") {
            Direction::LowerIsBetter => (cand_v - base_v) / base_v,
            Direction::HigherIsBetter => (base_v - cand_v) / base_v,
        };
        out.push(Comparison { key, base: base_v, cand: *cand_v, regression });
    }
    out
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut threshold = 0.20;
    let mut strict = false;
    let mut dirs: Vec<PathBuf> = Vec::new();
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--threshold" => match it.next().and_then(|v| v.parse::<f64>().ok()) {
                Some(v) if v > 0.0 => threshold = v,
                _ => {
                    eprintln!("--threshold needs a positive number");
                    return ExitCode::from(2);
                }
            },
            "--strict" => strict = true,
            other => dirs.push(PathBuf::from(other)),
        }
    }
    if dirs.len() != 2 {
        eprintln!("usage: bench_diff <baseline_dir> <candidate_dir> [--threshold 0.20] [--strict]");
        return ExitCode::from(2);
    }
    let (baseline_dir, candidate_dir) = (&dirs[0], &dirs[1]);

    let mut baselines: Vec<PathBuf> = match std::fs::read_dir(baseline_dir) {
        Ok(entries) => entries
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
            })
            .collect(),
        Err(e) => {
            eprintln!("cannot read baseline dir {}: {e}", baseline_dir.display());
            return ExitCode::from(2);
        }
    };
    baselines.sort();
    if baselines.is_empty() {
        println!("no BENCH_*.json baselines in {}", baseline_dir.display());
        return ExitCode::SUCCESS;
    }

    let mut regressions = 0usize;
    let mut provisional_regressions = 0usize;
    for base_path in &baselines {
        let file = base_path.file_name().unwrap().to_string_lossy().into_owned();
        let cand_path = candidate_dir.join(&file);
        // A baseline is a committed file: unreadable/malformed/unknown-schema
        // is repo corruption and must be a clear, blocking error — not a
        // silent skip that would make the gate vacuously green.
        let base = match load(base_path) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("error: malformed baseline {file}: {e}");
                return ExitCode::from(2);
            }
        };
        if let Err(e) = json::report_schema(&base) {
            eprintln!("error: baseline {file}: {e}");
            return ExitCode::from(2);
        }
        if !cand_path.exists() {
            println!("~ {file}: no candidate report (bench not run?), skipping");
            continue;
        }
        let cand = match load(&cand_path) {
            Ok(v) => v,
            Err(e) => {
                println!("~ {file}: unreadable candidate ({e}), skipping");
                continue;
            }
        };
        let quick = |d: &JsonValue| d.get("quick").and_then(JsonValue::as_bool);
        if quick(&base) != quick(&cand) {
            println!("~ {file}: quick/full mode mismatch, not comparable, skipping");
            continue;
        }
        let provisional = base
            .get("provisional")
            .and_then(JsonValue::as_bool)
            .unwrap_or(false);
        let comparisons = compare(&base, &cand);
        for c in &comparisons {
            let pct = c.regression * 100.0;
            if c.regression > threshold {
                if provisional {
                    provisional_regressions += 1;
                } else {
                    regressions += 1;
                }
                let note = if provisional { " [provisional baseline]" } else { "" };
                println!(
                    "::warning::bench regression{note}: {file} {} {:+.1}% (baseline {:.4e}, now {:.4e})",
                    c.key, pct, c.base, c.cand
                );
            } else if c.regression < -threshold {
                println!(
                    "+ {file} {} improved {:.1}% ({:.4e} -> {:.4e})",
                    c.key, -pct, c.base, c.cand
                );
            } else {
                println!("= {file} {} within ±{:.0}% ({:+.1}%)", c.key, threshold * 100.0, pct);
            }
        }
        // One-line per-report verdict, printed unconditionally — a report
        // whose every leaf is within threshold still leaves a greppable
        // trace that it WAS compared (an empty diff is indistinguishable
        // from a skipped one otherwise).
        match comparisons
            .iter()
            .max_by(|a, b| a.regression.total_cmp(&b.regression))
        {
            Some(worst) => println!(
                "report {file}: {} metric(s) compared, worst {:+.1}% ({})",
                comparisons.len(),
                worst.regression * 100.0,
                worst.key
            ),
            None => println!("report {file}: 0 metrics compared"),
        }
    }

    println!(
        "\nbench_diff: {} baseline file(s), {} blocking regression(s) beyond {:.0}% \
         (+{} against provisional baselines, warn-only)",
        baselines.len(),
        regressions,
        threshold * 100.0,
        provisional_regressions
    );
    if strict && regressions > 0 {
        return ExitCode::FAILURE;
    }
    ExitCode::SUCCESS
}
