//! `ad-admm` — CLI launcher for the AD-ADMM system.
//!
//! Subcommands:
//!   solve    run a solver on a synthetic workload (problem/algorithm/params via flags)
//!   cluster  run the threaded star cluster (async vs sync wall-clock comparison)
//!   params   print the Theorem-1 parameter rules for given L, τ, N, S
//!   artifacts  list the AOT artifacts visible to the runtime
//!
//! Examples:
//!   ad-admm solve --problem lasso --workers 16 --m 200 --n 100 --rho 500 --tau 10 --iters 500
//!   ad-admm cluster --workers 8 --tau 8 --slow-ms 4 --iters 200
//!   ad-admm params --lipschitz 10 --tau 5 --workers 16

use ad_admm::admm::arrivals::ArrivalModel;
use ad_admm::admm::kkt::kkt_residual;
use ad_admm::admm::master_pov::run_master_pov;
use ad_admm::admm::params::{gamma_lower_bound, rho_lower_bound_convex, rho_lower_bound_nonconvex};
use ad_admm::admm::sync::run_sync_admm;
use ad_admm::admm::AdmmConfig;
use ad_admm::cluster::{ClusterConfig, DelayModel, ExecutionMode, FaultPlan, Protocol, StarCluster};
use ad_admm::data::{LassoInstance, LogisticInstance, SparsePcaInstance};
use ad_admm::rng::Pcg64;
use ad_admm::util::cli::ArgParser;

fn main() {
    let args = ArgParser::from_env(&["help", "sync", "alt", "virtual"]);
    let cmd = args.positional().first().map(String::as_str).unwrap_or("help");
    match cmd {
        "solve" => cmd_solve(&args),
        "cluster" => cmd_cluster(&args),
        "params" => cmd_params(&args),
        "artifacts" => cmd_artifacts(),
        _ => print_help(),
    }
}

fn print_help() {
    println!(
        "ad-admm — Asynchronous Distributed ADMM (Chang et al., Part I)\n\n\
         USAGE: ad-admm <solve|cluster|params|artifacts> [--flags]\n\n\
         solve   --problem lasso|spca|logistic --workers N --m M --n N --rho R --tau T\n\
                 --gamma G --min-arrivals A --iters K --theta TH --seed S [--sync] [--alt]\n\
         cluster --workers N --m M --n N --rho R --tau T --iters K --fast-ms F --slow-ms S\n\
                 [--virtual]  (deterministic virtual-time simulation, scales to 1000s of workers)\n\
                 [--fault-worker W --fault-from K --fault-until K]  (one dropout/rejoin outage)\n\
                 [--fault-outages C --fault-seed S]  (seeded deterministic outage schedule)\n\
         params  --lipschitz L --tau T --workers N --s S --rho R\n\
         artifacts"
    );
}

fn admm_config(args: &ArgParser) -> AdmmConfig {
    AdmmConfig {
        rho: args.get_parse_or("rho", 500.0),
        gamma: args.get_parse_or("gamma", 0.0),
        tau: args.get_parse_or("tau", 10),
        min_arrivals: args.get_parse_or("min-arrivals", 1),
        max_iters: args.get_parse_or("iters", 500),
        x0_tol: args.get_parse_or("tol", 0.0),
        ..Default::default()
    }
}

fn cmd_solve(args: &ArgParser) {
    let problem_kind = args.get_or("problem", "lasso");
    let n_workers: usize = args.get_parse_or("workers", 16);
    let m: usize = args.get_parse_or("m", 200);
    let n: usize = args.get_parse_or("n", 100);
    let theta: f64 = args.get_parse_or("theta", 0.1);
    let seed: u64 = args.get_parse_or("seed", 1);
    let cfg = admm_config(args);
    let mut rng = Pcg64::seed_from_u64(seed);

    let problem = match problem_kind.as_str() {
        "lasso" => LassoInstance::synthetic(&mut rng, n_workers, m, n, 0.05, theta).problem(),
        "spca" => {
            let nnz = (m * n / 100).max(1);
            let inst = SparsePcaInstance::synthetic(&mut rng, n_workers, m, n, nnz, theta);
            inst.problem()
        }
        "logistic" => LogisticInstance::synthetic(&mut rng, n_workers, m, n, theta).problem(),
        other => {
            eprintln!("unknown problem {other:?}");
            std::process::exit(2);
        }
    };

    println!(
        "problem={problem_kind} N={n_workers} m={m} n={n} rho={} gamma={} tau={} A={} iters={}",
        cfg.rho, cfg.gamma, cfg.tau, cfg.min_arrivals, cfg.max_iters
    );

    if args.has_flag("sync") {
        let out = run_sync_admm(&problem, &cfg);
        report("sync (Algorithm 1)", &problem, &out.state, &out.history);
    } else if args.has_flag("alt") {
        let arr = ArrivalModel::fig4_profile(n_workers, seed);
        let out = ad_admm::admm::alt_scheme::run_alt_scheme(&problem, &cfg, &arr);
        report("alt scheme (Algorithm 4)", &problem, &out.state, &out.history);
        if out.diverged() {
            println!("NOTE: diverged — exactly the Section IV caution for large rho + delay");
        }
    } else {
        let arr = ArrivalModel::fig4_profile(n_workers, seed);
        let out = run_master_pov(&problem, &cfg, &arr);
        report("AD-ADMM (Algorithm 2)", &problem, &out.state, &out.history);
    }
}

fn report(
    label: &str,
    problem: &ad_admm::problems::ConsensusProblem,
    state: &ad_admm::admm::AdmmState,
    history: &[ad_admm::admm::IterRecord],
) {
    let last = history.last().expect("no iterations");
    let kkt = kkt_residual(problem, state);
    println!("--- {label} ---");
    println!("iterations         {}", history.len());
    println!("objective          {:.8e}", last.objective);
    println!("aug. Lagrangian    {:.8e}", last.aug_lagrangian);
    println!("consensus residual {:.3e}", last.consensus);
    println!(
        "KKT residual       dual={:.3e} stat={:.3e} cons={:.3e}",
        kkt.dual, kkt.stationarity, kkt.consensus
    );
}

fn cmd_cluster(args: &ArgParser) {
    let n_workers: usize = args.get_parse_or("workers", 8);
    let m: usize = args.get_parse_or("m", 100);
    let n: usize = args.get_parse_or("n", 50);
    let seed: u64 = args.get_parse_or("seed", 1);
    let fast_ms: f64 = args.get_parse_or("fast-ms", 0.5);
    let slow_ms: f64 = args.get_parse_or("slow-ms", 4.0);
    let cfg = admm_config(args);
    let mut rng = Pcg64::seed_from_u64(seed);
    let inst = LassoInstance::synthetic(&mut rng, n_workers, m, n, 0.05, 0.1);
    let problem = inst.problem();
    let delays = DelayModel::linear_spread(n_workers, fast_ms, slow_ms, 0.3, seed);

    let mode = if args.has_flag("virtual") {
        ExecutionMode::VirtualTime
    } else {
        ExecutionMode::RealThreads
    };

    // Deterministic fault scenario (dropout/rejoin), if requested: one
    // explicit outage and/or a seeded schedule over the whole run.
    let mut fault_plan = FaultPlan::default();
    let fault_worker: i64 = args.get_parse_or("fault-worker", -1);
    if fault_worker >= 0 {
        let from: usize = args.get_parse_or("fault-from", cfg.max_iters / 4);
        let until: usize = args.get_parse_or("fault-until", cfg.max_iters / 2);
        fault_plan.outages.push(ad_admm::cluster::Outage {
            worker: fault_worker as usize,
            from_iter: from,
            until_iter: until,
        });
    }
    let fault_outages: usize = args.get_parse_or("fault-outages", 0);
    if fault_outages > 0 {
        let fseed: u64 = args.get_parse_or("fault-seed", seed);
        let max_len = (cfg.max_iters / 5).max(2);
        let seeded = FaultPlan::seeded_outages(
            n_workers,
            cfg.max_iters,
            fault_outages,
            2,
            max_len,
            fseed,
        );
        fault_plan.outages.extend(seeded.outages);
    }
    let fault_plan = (!fault_plan.is_empty()).then_some(fault_plan);

    // Sync baseline: τ=1, A=N (fault-free — the comparison anchor).
    let sync_cfg = ClusterConfig {
        admm: AdmmConfig { tau: 1, min_arrivals: n_workers, ..cfg.clone() },
        protocol: Protocol::AdAdmm,
        delays: delays.clone(),
        mode,
        ..Default::default()
    };
    let sync = StarCluster::new(problem.clone()).run(&sync_cfg);
    // Async per the flags, with any fault plan applied.
    let tau = cfg.tau;
    let async_cfg = ClusterConfig {
        admm: cfg,
        delays,
        mode,
        fault_plan: fault_plan.clone(),
        ..Default::default()
    };
    let asyn = StarCluster::new(problem.clone()).run(&async_cfg);

    let mode_label = match mode {
        ExecutionMode::RealThreads => "threaded",
        ExecutionMode::VirtualTime => "virtual-time",
    };
    println!("--- {mode_label} star cluster (N={n_workers}, delays {fast_ms}–{slow_ms} ms) ---");
    for (label, r) in [("sync  (tau=1, A=N)", &sync), ("async (per flags) ", &asyn)] {
        println!(
            "{label}: {:4} iters in {:.3}s  ({:.1} iters/s)  obj={:.6e}  master-wait={:.3}s",
            r.history.len(),
            r.wall_clock_s,
            r.iters_per_sec(),
            r.history.last().unwrap().objective,
            r.master_wait_s,
        );
    }
    println!(
        "async speedup (iters/s): {:.2}x",
        asyn.iters_per_sec() / sync.iters_per_sec().max(1e-12)
    );
    if let Some(plan) = &async_cfg.fault_plan {
        println!("fault plan: {} outage(s)", plan.outages.len());
        for o in &plan.outages {
            println!(
                "  worker {:>4} down for iters [{}, {})",
                o.worker, o.from_iter, o.until_iter
            );
        }
        println!(
            "bounded-delay (Assumption 1, tau={tau}) on the faulted trace: {}",
            asyn.trace.satisfies_bounded_delay(n_workers, tau)
        );
    }
}

fn cmd_params(args: &ArgParser) {
    let l: f64 = args.get_parse_or("lipschitz", 1.0);
    let tau: usize = args.get_parse_or("tau", 10);
    let n_workers: usize = args.get_parse_or("workers", 16);
    let s: f64 = args.get_parse_or("s", n_workers as f64);
    let rho_nc = rho_lower_bound_nonconvex(l);
    let rho_c = rho_lower_bound_convex(l);
    let rho: f64 = args.get_parse_or("rho", rho_nc);
    println!("Theorem-1 parameter rules (L={l}, tau={tau}, N={n_workers}, S={s})");
    println!("  rho  > {rho_nc:.6} (non-convex, eq. 16)");
    println!("  rho >= {rho_c:.6} (convex, eq. 18)");
    println!("  gamma > {:.6} (eq. 17 at rho={rho})", gamma_lower_bound(s, rho, tau, n_workers));
}

fn cmd_artifacts() {
    let dir = ad_admm::runtime::artifacts_dir();
    match ad_admm::runtime::ArtifactRegistry::load(&dir) {
        Ok(reg) => {
            println!("artifacts dir: {}", dir.display());
            for name in reg.names() {
                let e = reg.get(name).unwrap();
                println!("  {name}  kind={} file={}", e.kind, e.file);
            }
        }
        Err(e) => println!("no artifacts: {e}"),
    }
}
