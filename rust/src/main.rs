//! `ad-admm` — CLI launcher for the AD-ADMM system.
//!
//! Subcommands:
//!   solve    run a solver on a synthetic workload (problem/algorithm/params via flags)
//!   cluster  run the threaded star cluster (async vs sync wall-clock comparison)
//!   resume   continue a checkpointed virtual-time cluster run bit-identically
//!   transport-digest  replay a transport job spec in-process and print its x₀ digest
//!   params   print the Theorem-1 parameter rules for given L, τ, N, S
//!   artifacts  list the AOT artifacts visible to the runtime
//!
//! Examples:
//!   ad-admm solve --problem lasso --workers 16 --m 200 --n 100 --rho 500 --tau 10 --iters 500
//!   ad-admm cluster --workers 8 --tau 8 --slow-ms 4 --iters 200
//!   ad-admm cluster --virtual --checkpoint-every 50 --checkpoint-path run.ckpt --iters 200
//!   ad-admm resume run.ckpt
//!   ad-admm params --lipschitz 10 --tau 5 --workers 16
//!
//! All solver subcommands drive the `Session` API: configs are validated
//! up front (a bad flag combination prints the typed `EngineError` and
//! exits 2 instead of panicking mid-run).

use ad_admm::admm::arrivals::ArrivalModel;
use ad_admm::admm::kkt::kkt_residual;
use ad_admm::admm::params::{gamma_lower_bound, rho_lower_bound_convex, rho_lower_bound_nonconvex};
use ad_admm::admm::session::{
    BufferingObserver, Checkpoint, EngineError, Session, StepStatus,
};
use ad_admm::admm::{AdmmConfig, IterRecord, StopReason};
use ad_admm::bench::json::JsonValue;
use ad_admm::cluster::{
    ClusterConfig, ClusterReport, DelayModel, ExecutionMode, FaultPlan, Protocol, StarCluster,
};
use ad_admm::data::{LassoInstance, LogisticInstance, SparsePcaInstance};
use ad_admm::cluster::transport::{run_reference, JobSpec};
use ad_admm::prelude::{AltScheme, FullBarrier, PartialBarrier};
use ad_admm::problems::BlockPattern;
use ad_admm::rng::Pcg64;
use ad_admm::util::cli::ArgParser;
use ad_admm::util::digest::x0_digest;

fn main() {
    let args = ArgParser::from_env(&["help", "sync", "alt", "virtual", "free-running"]);
    let cmd = args.positional().first().map(String::as_str).unwrap_or("help");
    match cmd {
        "solve" => cmd_solve(&args),
        "cluster" => cmd_cluster(&args),
        "resume" => cmd_resume(&args),
        "transport-digest" => cmd_transport_digest(&args),
        "params" => cmd_params(&args),
        "artifacts" => cmd_artifacts(),
        _ => print_help(),
    }
}

fn print_help() {
    println!(
        "ad-admm — Asynchronous Distributed ADMM (Chang et al., Part I)\n\n\
         USAGE: ad-admm <solve|cluster|resume|transport-digest|params|artifacts> [--flags]\n\n\
         solve   --problem lasso|spca|logistic --workers N --m M --n N --rho R --tau T\n\
                 --gamma G --min-arrivals A --iters K --theta TH --seed S [--sync] [--alt]\n\
                 [--shard-blocks B --shard-owners C]  (lasso only: block-sharded general-form\n\
                 consensus — split the N features into B blocks, each owned by C workers\n\
                 round-robin; workers solve and ship only their owned slices)\n\
         cluster --workers N --m M --n N --rho R --tau T --iters K --fast-ms F --slow-ms S\n\
                 [--virtual]  (deterministic virtual-time simulation, scales to 1000s of workers)\n\
                 [--fault-worker W --fault-from K --fault-until K]  (one dropout/rejoin outage)\n\
                 [--fault-outages C --fault-seed S]  (seeded deterministic outage schedule)\n\
                 [--checkpoint-every N --checkpoint-path P]  (virtual mode only: periodic\n\
                 session checkpoints; continue bit-identically with `ad-admm resume P`)\n\
         resume  <checkpoint-path>  (continue a checkpointed virtual cluster run)\n\
         transport-digest  --workers N --m M --n N --tau T --iters K [--alt]\n\
                 [--inexact exact|grad:K|proxgrad:K|newton:K|adaptive:TOL0:MAX]\n\
                 [--shard-blocks B --shard-owners C]  (in-process replay of an\n\
                 `admm_serve submit` job spec; prints the reference `final x0 digest`\n\
                 the socket loopback run must match bit-exactly)\n\
         params  --lipschitz L --tau T --workers N --s S --rho R\n\
         artifacts"
    );
}

fn exit_config_error(err: &EngineError) -> ! {
    eprintln!("configuration error: {err}");
    std::process::exit(2);
}

fn admm_config(args: &ArgParser) -> AdmmConfig {
    AdmmConfig {
        rho: args.get_parse_or("rho", 500.0),
        gamma: args.get_parse_or("gamma", 0.0),
        tau: args.get_parse_or("tau", 10),
        min_arrivals: args.get_parse_or("min-arrivals", 1),
        max_iters: args.get_parse_or("iters", 500),
        x0_tol: args.get_parse_or("tol", 0.0),
        ..Default::default()
    }
}

fn cmd_solve(args: &ArgParser) {
    let problem_kind = args.get_or("problem", "lasso");
    let n_workers: usize = args.get_parse_or("workers", 16);
    let m: usize = args.get_parse_or("m", 200);
    let n: usize = args.get_parse_or("n", 100);
    let theta: f64 = args.get_parse_or("theta", 0.1);
    let seed: u64 = args.get_parse_or("seed", 1);
    let cfg = admm_config(args);
    let mut rng = Pcg64::seed_from_u64(seed);

    let shard_blocks: usize = args.get_parse_or("shard-blocks", 0);
    let shard_owners: usize = args.get_parse_or("shard-owners", 2);
    if shard_blocks > 0 && problem_kind != "lasso" {
        eprintln!("--shard-blocks is only supported for --problem lasso");
        std::process::exit(2);
    }

    let problem = match problem_kind.as_str() {
        "lasso" => {
            let inst = LassoInstance::synthetic(&mut rng, n_workers, m, n, 0.05, theta);
            if shard_blocks > 0 {
                // No clamping: a misconfigured block count or owner count
                // surfaces as the typed BlockError, like every other
                // sharding misconfiguration.
                let pattern =
                    match BlockPattern::round_robin(n, shard_blocks, n_workers, shard_owners) {
                        Ok(p) => p,
                        Err(e) => exit_config_error(&EngineError::Block(e)),
                    };
                println!(
                    "sharded consensus: {shard_blocks} blocks, {shard_owners} owner(s)/block, \
                     comm volume ratio {:.3}",
                    pattern.comm_volume_ratio()
                );
                match inst.sharded_problem(&pattern) {
                    Ok(p) => p,
                    Err(e) => exit_config_error(&EngineError::Block(e)),
                }
            } else {
                inst.problem()
            }
        }
        "spca" => {
            let nnz = (m * n / 100).max(1);
            let inst = SparsePcaInstance::synthetic(&mut rng, n_workers, m, n, nnz, theta);
            inst.problem()
        }
        "logistic" => LogisticInstance::synthetic(&mut rng, n_workers, m, n, theta).problem(),
        other => {
            eprintln!("unknown problem {other:?}");
            std::process::exit(2);
        }
    };

    println!(
        "problem={problem_kind} N={n_workers} m={m} n={n} rho={} gamma={} tau={} A={} iters={}",
        cfg.rho, cfg.gamma, cfg.tau, cfg.min_arrivals, cfg.max_iters
    );

    // One Session per algorithm choice — the policy is the only moving
    // part, exactly the engine × policy design.
    let mut history = BufferingObserver::new();
    let builder = Session::builder().problem(&problem).observer(&mut history);
    let (label, builder) = if args.has_flag("sync") {
        let sync_cfg = AdmmConfig { tau: 1, min_arrivals: n_workers, ..cfg };
        (
            "sync (Algorithm 1)",
            builder.config(sync_cfg).policy(FullBarrier).arrivals(&ArrivalModel::Full),
        )
    } else if args.has_flag("alt") {
        (
            "alt scheme (Algorithm 4)",
            builder
                .config(cfg.clone())
                .policy(AltScheme { tau: cfg.tau })
                .arrivals(&ArrivalModel::fig4_profile(n_workers, seed))
                .residual_stopping(false),
        )
    } else {
        (
            "AD-ADMM (Algorithm 2)",
            builder
                .config(cfg.clone())
                .policy(PartialBarrier { tau: cfg.tau })
                .arrivals(&ArrivalModel::fig4_profile(n_workers, seed)),
        )
    };
    let mut session = builder.build().unwrap_or_else(|e| exit_config_error(&e));
    let stop = session.run_to_completion().unwrap_or_else(|e| exit_config_error(&e));
    // Bind the source to `_` so the boxed source (whose type carries the
    // builder lifetime) drops here and releases the `&mut history` borrow.
    let (outcome, _) = session.finish();
    report(label, &problem, &outcome.state, history.records());
    if stop == StopReason::Diverged && args.has_flag("alt") {
        println!("NOTE: diverged — exactly the Section IV caution for large rho + delay");
    }
}

fn report(
    label: &str,
    problem: &ad_admm::problems::ConsensusProblem,
    state: &ad_admm::admm::AdmmState,
    history: &[IterRecord],
) {
    let last = history.last().expect("no iterations");
    let kkt = kkt_residual(problem, state);
    println!("--- {label} ---");
    println!("iterations         {}", history.len());
    println!("objective          {:.8e}", last.objective);
    println!("aug. Lagrangian    {:.8e}", last.aug_lagrangian);
    println!("consensus residual {:.3e}", last.consensus);
    println!(
        "KKT residual       dual={:.3e} stat={:.3e} cons={:.3e}",
        kkt.dual, kkt.stationarity, kkt.consensus
    );
}

/// Everything needed to rebuild a `cluster` run from scratch — written
/// into checkpoints as `meta.cli` so `ad-admm resume` can reconstruct the
/// identical problem and config.
struct ClusterParams {
    workers: usize,
    m: usize,
    n: usize,
    seed: u64,
    fast_ms: f64,
    slow_ms: f64,
    rho: f64,
    gamma: f64,
    tau: usize,
    min_arrivals: usize,
    iters: usize,
    tol: f64,
    fault_worker: i64,
    fault_from: usize,
    fault_until: usize,
    fault_outages: usize,
    fault_seed: u64,
}

impl ClusterParams {
    fn from_args(args: &ArgParser) -> Self {
        let iters: usize = args.get_parse_or("iters", 500);
        let seed: u64 = args.get_parse_or("seed", 1);
        ClusterParams {
            workers: args.get_parse_or("workers", 8),
            m: args.get_parse_or("m", 100),
            n: args.get_parse_or("n", 50),
            seed,
            fast_ms: args.get_parse_or("fast-ms", 0.5),
            slow_ms: args.get_parse_or("slow-ms", 4.0),
            rho: args.get_parse_or("rho", 500.0),
            gamma: args.get_parse_or("gamma", 0.0),
            tau: args.get_parse_or("tau", 10),
            min_arrivals: args.get_parse_or("min-arrivals", 1),
            iters,
            tol: args.get_parse_or("tol", 0.0),
            fault_worker: args.get_parse_or("fault-worker", -1),
            fault_from: args.get_parse_or("fault-from", iters / 4),
            fault_until: args.get_parse_or("fault-until", iters / 2),
            fault_outages: args.get_parse_or("fault-outages", 0),
            fault_seed: args.get_parse_or("fault-seed", seed),
        }
    }

    fn to_meta(&self) -> JsonValue {
        JsonValue::Obj(vec![
            ("workers".to_string(), self.workers.into()),
            ("m".to_string(), self.m.into()),
            ("n".to_string(), self.n.into()),
            // Seeds are full-range u64s: store as strings so values >= 2^53
            // survive the JSON round trip exactly (an f64 would round them,
            // rebuilding a different problem and breaking bit-identity).
            ("seed".to_string(), JsonValue::Str(self.seed.to_string())),
            ("fast_ms".to_string(), self.fast_ms.into()),
            ("slow_ms".to_string(), self.slow_ms.into()),
            ("rho".to_string(), self.rho.into()),
            ("gamma".to_string(), self.gamma.into()),
            ("tau".to_string(), self.tau.into()),
            ("min_arrivals".to_string(), self.min_arrivals.into()),
            ("iters".to_string(), self.iters.into()),
            ("tol".to_string(), self.tol.into()),
            ("fault_worker".to_string(), JsonValue::Num(self.fault_worker as f64)),
            ("fault_from".to_string(), self.fault_from.into()),
            ("fault_until".to_string(), self.fault_until.into()),
            ("fault_outages".to_string(), self.fault_outages.into()),
            ("fault_seed".to_string(), JsonValue::Str(self.fault_seed.to_string())),
        ])
    }

    fn from_meta(meta: &JsonValue) -> Result<Self, String> {
        let num = |key: &str| -> Result<f64, String> {
            meta.get(key)
                .and_then(JsonValue::as_f64)
                .ok_or_else(|| format!("checkpoint meta is missing numeric field {key:?}"))
        };
        let seed = |key: &str| -> Result<u64, String> {
            meta.get(key)
                .and_then(JsonValue::as_str)
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| format!("checkpoint meta is missing u64 seed field {key:?}"))
        };
        Ok(ClusterParams {
            workers: num("workers")? as usize,
            m: num("m")? as usize,
            n: num("n")? as usize,
            seed: seed("seed")?,
            fast_ms: num("fast_ms")?,
            slow_ms: num("slow_ms")?,
            rho: num("rho")?,
            gamma: num("gamma")?,
            tau: num("tau")? as usize,
            min_arrivals: num("min_arrivals")? as usize,
            iters: num("iters")? as usize,
            tol: num("tol")?,
            fault_worker: num("fault_worker")? as i64,
            fault_from: num("fault_from")? as usize,
            fault_until: num("fault_until")? as usize,
            fault_outages: num("fault_outages")? as usize,
            fault_seed: seed("fault_seed")?,
        })
    }

    fn problem(&self) -> ad_admm::problems::ConsensusProblem {
        let mut rng = Pcg64::seed_from_u64(self.seed);
        LassoInstance::synthetic(&mut rng, self.workers, self.m, self.n, 0.05, 0.1).problem()
    }

    fn fault_plan(&self) -> Option<FaultPlan> {
        let mut plan = FaultPlan::default();
        if self.fault_worker >= 0 {
            plan.outages.push(ad_admm::cluster::Outage {
                worker: self.fault_worker as usize,
                from_iter: self.fault_from,
                until_iter: self.fault_until,
            });
        }
        if self.fault_outages > 0 {
            let max_len = (self.iters / 5).max(2);
            let seeded = FaultPlan::seeded_outages(
                self.workers,
                self.iters,
                self.fault_outages,
                2,
                max_len,
                self.fault_seed,
            );
            plan.outages.extend(seeded.outages);
        }
        (!plan.is_empty()).then_some(plan)
    }

    /// The asynchronous virtual-time config (the one checkpointed runs use).
    fn virtual_config(&self) -> ClusterConfig {
        let mut builder = ClusterConfig::builder()
            .admm(AdmmConfig {
                rho: self.rho,
                gamma: self.gamma,
                tau: self.tau,
                min_arrivals: self.min_arrivals,
                max_iters: self.iters,
                x0_tol: self.tol,
                ..Default::default()
            })
            .protocol(Protocol::AdAdmm)
            .delays(DelayModel::linear_spread(
                self.workers,
                self.fast_ms,
                self.slow_ms,
                0.3,
                self.seed,
            ))
            .mode(ExecutionMode::VirtualTime);
        if let Some(plan) = self.fault_plan() {
            builder = builder.fault_plan(plan);
        }
        builder.build().expect("valid cluster config")
    }
}

/// Replay a transport job spec through the in-process trace source and
/// print the digest line the socket run must reproduce bit-exactly — the
/// reference side of the CI loopback e2e (flags shared with
/// `admm_serve submit`).
fn cmd_transport_digest(args: &ArgParser) {
    let spec = match JobSpec::from_args(args) {
        Ok(spec) => spec,
        Err(e) => exit_config_error(&e),
    };
    match run_reference(&spec) {
        Ok((outcome, digest)) => {
            println!(
                "reference replay: {} iterations  stop={:?}",
                outcome.iterations, outcome.stop
            );
            println!("final x0 digest {digest:016x}");
        }
        Err(e) => exit_config_error(&e),
    }
}

fn print_virtual_summary(report: &ClusterReport, last: Option<&IterRecord>) {
    println!(
        "completed {} iterations  stop={:?}",
        report.trace.sets.len(),
        report.stop
    );
    println!(
        "virtual time {:.6}s  master-wait {:.6}s",
        report.wall_clock_s, report.master_wait_s
    );
    if let Some(rec) = last {
        println!("final objective {:.10e}", rec.objective);
    }
    println!("final x0 digest {:016x}", x0_digest(&report.state.x0));
}

/// Drive a virtual-time session to completion, writing a checkpoint every
/// `every` iterations (0 = never). Returns the report and the last record.
fn drive_virtual_session(
    session: &mut Session<'_, ad_admm::cluster::VirtualSource>,
    every: usize,
    path: Option<&str>,
    meta: &JsonValue,
    max_iters: usize,
) -> Option<IterRecord> {
    let mut last = None;
    loop {
        match session.step().unwrap_or_else(|e| exit_config_error(&e)) {
            StepStatus::Iterated(rec) => {
                last = Some(rec);
                let k = session.iteration();
                if let (Some(path), true) = (path, every > 0 && k % every == 0 && k < max_iters) {
                    let mut cp =
                        session.checkpoint().unwrap_or_else(|e| exit_config_error(&e));
                    cp.set_meta("cli", meta.clone());
                    if let Err(e) = cp.write_to_file(path) {
                        eprintln!("cannot write checkpoint {path}: {e}");
                        std::process::exit(2);
                    }
                    println!("checkpoint written at k={k} -> {path}");
                }
            }
            StepStatus::Done(_) => return last,
        }
    }
}

fn cmd_cluster(args: &ArgParser) {
    let ckpt_every: usize = args.get_parse_or("checkpoint-every", 0);
    let ckpt_path = args.get("checkpoint-path").map(str::to_string);
    if ckpt_every > 0 || ckpt_path.is_some() {
        if !args.has_flag("virtual") {
            eprintln!(
                "--checkpoint-every/--checkpoint-path require --virtual (the real-thread \
                 mode holds live OS state and cannot be checkpointed)"
            );
            std::process::exit(2);
        }
        let Some(path) = ckpt_path else {
            eprintln!("--checkpoint-every requires --checkpoint-path");
            std::process::exit(2);
        };
        let params = ClusterParams::from_args(args);
        let every = if ckpt_every > 0 { ckpt_every } else { (params.iters / 2).max(1) };
        let cfg = params.virtual_config();
        let problem = params.problem();
        let meta = params.to_meta();
        println!(
            "--- checkpointed virtual-time cluster (N={}, every {} iters -> {path}) ---",
            params.workers, every
        );
        let cluster = StarCluster::new(problem);
        let mut session =
            cluster.virtual_session(&cfg).unwrap_or_else(|e| exit_config_error(&e));
        let last = drive_virtual_session(
            &mut session,
            every,
            Some(path.as_str()),
            &meta,
            cfg.admm.max_iters,
        );
        let (outcome, source) = session.finish();
        let report = ClusterReport::from_virtual_parts(outcome, Vec::new(), source);
        print_virtual_summary(&report, last.as_ref());
        return;
    }

    // The historical sync-vs-async comparison path.
    let params = ClusterParams::from_args(args);
    let n_workers = params.workers;
    let cfg = AdmmConfig {
        rho: params.rho,
        gamma: params.gamma,
        tau: params.tau,
        min_arrivals: params.min_arrivals,
        max_iters: params.iters,
        x0_tol: params.tol,
        ..Default::default()
    };
    let problem = params.problem();
    let delays = DelayModel::linear_spread(
        n_workers,
        params.fast_ms,
        params.slow_ms,
        0.3,
        params.seed,
    );

    let mode = if args.has_flag("virtual") {
        ExecutionMode::VirtualTime
    } else {
        ExecutionMode::RealThreads
    };
    let fault_plan = params.fault_plan();

    // Sync baseline: τ=1, A=N (fault-free — the comparison anchor).
    let sync_cfg = ClusterConfig::builder()
        .admm(AdmmConfig { tau: 1, min_arrivals: n_workers, ..cfg.clone() })
        .protocol(Protocol::AdAdmm)
        .delays(delays.clone())
        .mode(mode)
        .build()
        .expect("valid cluster config");
    let sync = StarCluster::new(problem.clone()).run(&sync_cfg);
    // Async per the flags, with any fault plan applied.
    let tau = cfg.tau;
    let mut async_builder =
        ClusterConfig::builder().admm(cfg).delays(delays).mode(mode);
    if let Some(plan) = fault_plan.clone() {
        async_builder = async_builder.fault_plan(plan);
    }
    let async_cfg = async_builder.build().expect("valid cluster config");
    let asyn = StarCluster::new(problem.clone()).run(&async_cfg);

    let mode_label = match mode {
        ExecutionMode::RealThreads => "threaded",
        ExecutionMode::VirtualTime => "virtual-time",
    };
    println!(
        "--- {mode_label} star cluster (N={n_workers}, delays {}–{} ms) ---",
        params.fast_ms, params.slow_ms
    );
    for (label, r) in [("sync  (tau=1, A=N)", &sync), ("async (per flags) ", &asyn)] {
        println!(
            "{label}: {:4} iters in {:.3}s  ({:.1} iters/s)  obj={:.6e}  master-wait={:.3}s",
            r.history.len(),
            r.wall_clock_s,
            r.iters_per_sec(),
            r.history.last().unwrap().objective,
            r.master_wait_s,
        );
    }
    println!(
        "async speedup (iters/s): {:.2}x",
        asyn.iters_per_sec() / sync.iters_per_sec().max(1e-12)
    );
    if let Some(plan) = &async_cfg.fault_plan {
        println!("fault plan: {} outage(s)", plan.outages.len());
        for o in &plan.outages {
            println!(
                "  worker {:>4} down for iters [{}, {})",
                o.worker, o.from_iter, o.until_iter
            );
        }
        println!(
            "bounded-delay (Assumption 1, tau={tau}) on the faulted trace: {}",
            asyn.trace.satisfies_bounded_delay(n_workers, tau)
        );
    }
}

fn cmd_resume(args: &ArgParser) {
    let Some(path) = args.positional().get(1) else {
        eprintln!("usage: ad-admm resume <checkpoint-path>");
        std::process::exit(2);
    };
    let cp = match Checkpoint::read_from_file(path) {
        Ok(cp) => cp,
        Err(e) => {
            eprintln!("cannot load checkpoint {path}: {e}");
            std::process::exit(2);
        }
    };
    let Some(meta) = cp.meta("cli") else {
        eprintln!(
            "checkpoint {path} carries no CLI metadata (written by a library caller?) — \
             resume it through StarCluster::resume_virtual_session"
        );
        std::process::exit(2);
    };
    let params = match ClusterParams::from_meta(meta) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("cannot rebuild run from checkpoint meta: {e}");
            std::process::exit(2);
        }
    };
    let cfg = params.virtual_config();
    let problem = params.problem();
    let meta = params.to_meta();
    let cluster = StarCluster::new(problem);
    let mut session = cluster
        .resume_virtual_session(&cfg, &cp)
        .unwrap_or_else(|e| exit_config_error(&e));
    println!(
        "--- resumed virtual-time cluster from {path} at k={} ---",
        session.iteration()
    );
    let last = drive_virtual_session(&mut session, 0, None, &meta, cfg.admm.max_iters);
    let (outcome, source) = session.finish();
    let report = ClusterReport::from_virtual_parts(outcome, Vec::new(), source);
    print_virtual_summary(&report, last.as_ref());
}

fn cmd_params(args: &ArgParser) {
    let l: f64 = args.get_parse_or("lipschitz", 1.0);
    let tau: usize = args.get_parse_or("tau", 10);
    let n_workers: usize = args.get_parse_or("workers", 16);
    let s: f64 = args.get_parse_or("s", n_workers as f64);
    let rho_nc = rho_lower_bound_nonconvex(l);
    let rho_c = rho_lower_bound_convex(l);
    let rho: f64 = args.get_parse_or("rho", rho_nc);
    println!("Theorem-1 parameter rules (L={l}, tau={tau}, N={n_workers}, S={s})");
    println!("  rho  > {rho_nc:.6} (non-convex, eq. 16)");
    println!("  rho >= {rho_c:.6} (convex, eq. 18)");
    println!("  gamma > {:.6} (eq. 17 at rho={rho})", gamma_lower_bound(s, rho, tau, n_workers));
}

fn cmd_artifacts() {
    let dir = ad_admm::runtime::artifacts_dir();
    match ad_admm::runtime::ArtifactRegistry::load(&dir) {
        Ok(reg) => {
            println!("artifacts dir: {}", dir.display());
            for name in reg.names() {
                let e = reg.get(name).unwrap();
                println!("  {name}  kind={} file={}", e.kind, e.file);
            }
        }
        Err(e) => println!("no artifacts: {e}"),
    }
}
