//! The real-thread [`WorkerSource`]: one OS thread per worker, unbounded
//! mpsc channels for the star links, the master (the engine loop) on the
//! calling thread.
//!
//! Since the engine refactor the per-iteration ADMM state machine lives in
//! [`crate::admm::engine::run_engine`]; this module only spawns/joins the
//! worker threads, pumps the channels at the gather gate, and moves arrived
//! `(x̂_i, λ̂_i)` messages into the master state. Injected delays are real
//! sleeps, so arrival order is genuinely nondeterministic — that is the
//! point of the mode — *unless* a lockstep trace
//! ([`super::ClusterConfig::lockstep_trace`]) prescribes each iteration's
//! arrival set, in which case the master waits for exactly the prescribed
//! workers and the run becomes deterministic and bit-comparable with the
//! other two sources (the fault-scenario equivalence tests rely on this).
//!
//! Fault injection: [`FaultPlan`](crate::admm::engine::FaultPlan) outages
//! are enforced at the master's gate — a down worker's message still lands
//! in `pending` but is held, uncounted and unabsorbed, until rejoin, so the
//! worker re-enters with the stale iterate it computed against its
//! pre-outage broadcast. Delay spikes stretch the worker threads' compute
//! sleeps and their whole communication leg — model draw plus
//! retransmissions, matching the virtual-time transit rule (see
//! `worker_loop` and `comm_leg_ms` in [`super::worker`]).

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::admm::engine::{ActiveSet, Gate, MasterView, UpdatePolicy, WorkerSource};
use crate::admm::AdmmState;
use crate::problems::{BlockPattern, ConsensusProblem};
use crate::util::timer::{Clock, Stopwatch};

use super::messages::{MasterMsg, WorkerMsg};
use super::timeline::WorkerStats;
use super::worker::{self, WorkerSolveFn};
use super::ClusterConfig;

pub(crate) struct ThreadedSource {
    n_workers: usize,
    to_workers: Vec<Sender<MasterMsg>>,
    from_workers: Receiver<WorkerMsg>,
    handles: Vec<JoinHandle<WorkerStats>>,
    /// One held message per worker (arrived but not yet absorbed).
    pending: Vec<Option<WorkerMsg>>,
    /// Prescribed arrival sets (lockstep replay) and the replay cursor.
    lockstep: Option<(Vec<Vec<usize>>, usize)>,
    /// Block-sharding pattern (None = dense): broadcasts carry each
    /// worker's owned slice of x₀ and workers reply with owned-slice
    /// messages — the real-message counterpart of the virtual-time
    /// source's comm-volume scaling.
    shard: Option<Arc<BlockPattern>>,
    wall: Stopwatch,
    master_wait_s: f64,
}

impl ThreadedSource {
    /// Spawn one thread per worker over the star links. Workers start
    /// computing only after the engine's initial broadcast (`start`).
    pub(crate) fn spawn(
        problem: &ConsensusProblem,
        cfg: &ClusterConfig,
        solvers: Option<Vec<WorkerSolveFn>>,
    ) -> Self {
        let n_workers = problem.num_workers();
        let rho = cfg.admm.rho;
        let protocol = cfg.protocol;

        // Star links: one channel to each worker, one shared channel back.
        let (to_master, from_workers) = std::sync::mpsc::channel::<WorkerMsg>();
        let mut to_workers = Vec::with_capacity(n_workers);
        let mut handles = Vec::with_capacity(n_workers);
        let mut solver_list: Vec<Option<WorkerSolveFn>> = match solvers {
            Some(v) => {
                assert_eq!(v.len(), n_workers, "one solver per worker");
                v.into_iter().map(Some).collect()
            }
            None => (0..n_workers).map(|_| None).collect(),
        };

        for i in 0..n_workers {
            let (tx, rx) = std::sync::mpsc::channel::<MasterMsg>();
            to_workers.push(tx);
            let local = Arc::clone(problem.local(i));
            let back = to_master.clone();
            let delay = cfg.delays.sampler(i);
            let comm = cfg.comm_delays.as_ref().map(|d| d.sampler(i));
            let solve = solver_list[i].take();
            let faults = cfg.faults.clone();
            let spikes = cfg.fault_plan.clone();
            // Each spawned worker solves under its own policy (uniform
            // unless the config carries per-worker overrides).
            let policy = cfg.inexact_policy_for(i);
            let handle = std::thread::Builder::new()
                .name(format!("worker-{i}"))
                .spawn(move || {
                    worker::worker_loop(
                        i, local, rho, protocol, rx, back, delay, comm, solve, faults, spikes,
                        policy,
                    )
                })
                // ad-lint: allow(panic-free-lib): thread-spawn failure is unrecoverable for the real-thread cluster
                .expect("spawn worker");
            handles.push(handle);
        }
        drop(to_master);

        ThreadedSource {
            n_workers,
            to_workers,
            from_workers,
            handles,
            pending: (0..n_workers).map(|_| None).collect(),
            lockstep: cfg.lockstep_trace.as_ref().map(|t| (t.sets.clone(), 0)),
            shard: problem.pattern().cloned(),
            wall: Stopwatch::start(),
            master_wait_s: 0.0,
        }
    }

    fn drain_inbox(&mut self) {
        while let Ok(msg) = self.from_workers.try_recv() {
            let id = msg.id;
            self.pending[id] = Some(msg);
        }
    }

    /// Shutdown: tell everyone, drain stragglers, join. Returns per-worker
    /// stats, total wall-clock seconds and the master's blocked-wait time.
    pub(crate) fn finish(mut self) -> (Vec<WorkerStats>, f64, f64) {
        for tx in &self.to_workers {
            let _ = tx.send(MasterMsg::Shutdown);
        }
        self.to_workers.clear();
        while self.from_workers.try_recv().is_ok() {}
        let mut workers = Vec::with_capacity(self.handles.len());
        for h in self.handles.drain(..) {
            // ad-lint: allow(panic-free-lib): join propagates a worker-thread panic to the driving test or bench
            workers.push(h.join().expect("worker panicked"));
        }
        // Any message sent between drain and join is dropped with the channel.
        (workers, self.wall.now_s(), self.master_wait_s)
    }
}

impl WorkerSource for ThreadedSource {
    fn n_workers(&self) -> usize {
        self.n_workers
    }

    // No checkpoint support: worker threads hold live wall-clock state
    // (mid-sleep rounds, in-flight channel messages, thread-local duals)
    // that cannot be serialized. The default `save_checkpoint` returns
    // `CheckpointUnsupported { source: "threaded" }`; replay the realized
    // trace through a trace-driven session to checkpoint such a run.
    fn kind(&self) -> &'static str {
        "threaded"
    }

    fn supports_sharding(&self) -> bool {
        self.shard.is_some()
    }

    fn start(&mut self, state: &AdmmState, policy: &dyn UpdatePolicy) {
        // Initial broadcast: everyone starts computing against x⁰ (and λ⁰
        // for Algorithm 4). Sharded workers receive only their owned
        // slice of x⁰.
        let with_dual = policy.broadcasts_dual();
        for (i, tx) in self.to_workers.iter().enumerate() {
            let lam = with_dual.then(|| state.lams[i].clone());
            let x0 = match &self.shard {
                None => state.x0.clone(),
                Some(p) => p.gather_vec(i, &state.x0),
            };
            // ad-lint: allow(panic-free-lib): workers outlive the master loop by construction; a closed channel means a worker panicked
            tx.send(MasterMsg::Go { x0, lam }).expect("worker alive");
        }
    }

    fn gather(&mut self, _k: usize, d: &[usize], gate: &Gate<'_>) -> ActiveSet {
        let n = self.n_workers;
        let wait_started = self.wall.now_s();
        let set = if self.lockstep.is_some() {
            // Lockstep replay: wait until every live worker of the
            // prescribed set has a message in, absorb exactly that set and
            // leave everything else pending. Deterministic by design.
            let prescribed = {
                // ad-lint: allow(panic-free-lib): guarded by the lockstep.is_some() branch above
                let (sets, pos) = self.lockstep.as_mut().expect("checked above");
                let s = sets
                    .get(*pos)
                    .unwrap_or_else(|| {
                        // ad-lint: allow(panic-free-lib): documented contract: lockstep callers supply one set per iteration
                        panic!("lockstep trace exhausted at iteration {pos}", pos = *pos)
                    })
                    .clone();
                *pos += 1;
                s
            };
            loop {
                self.drain_inbox();
                if prescribed.iter().all(|&i| gate.down[i] || self.pending[i].is_some()) {
                    break;
                }
                match self.from_workers.recv() {
                    Ok(msg) => {
                        let id = msg.id;
                        self.pending[id] = Some(msg);
                    }
                    Err(_) => break, // all workers gone (shutdown path)
                }
            }
            // Lockstep traces are caller-supplied: validate (sort, dedup,
            // bounds-check) rather than trust ascending order.
            let live: Vec<usize> = prescribed.into_iter().filter(|&i| !gate.down[i]).collect();
            // ad-lint: allow(panic-free-lib): documented panic contract on malformed caller-supplied lockstep traces
            ActiveSet::new(live, n).expect("lockstep trace worker index out of range")
        } else {
            // Gather until the gate is met: |A_k| ≥ min(A, #live) and every
            // live worker with d_i ≥ τ−1 has arrived. Down workers neither
            // count nor block — their messages are held in `pending`.
            let n_live = (0..n).filter(|&i| !gate.down[i]).count();
            let target = gate.min_arrivals.min(n_live);
            loop {
                self.drain_inbox();
                let arrived = (0..n)
                    .filter(|&i| self.pending[i].is_some() && !gate.down[i])
                    .count();
                let forced_ok = (0..n).all(|i| {
                    gate.down[i] || d[i] + 1 < gate.tau || self.pending[i].is_some()
                });
                if arrived >= target && forced_ok {
                    break;
                }
                // Block for the next message.
                match self.from_workers.recv() {
                    Ok(msg) => {
                        let id = msg.id;
                        self.pending[id] = Some(msg);
                    }
                    Err(_) => break, // all workers gone (shutdown path)
                }
            }
            ActiveSet::from_sorted(
                (0..n).filter(|&i| self.pending[i].is_some() && !gate.down[i]).collect(),
            )
        };
        self.master_wait_s += self.wall.now_s() - wait_started;
        set
    }

    fn absorb(&mut self, set: &ActiveSet, m: &mut MasterView<'_>, _policy: &dyn UpdatePolicy) {
        // (9)/(10)/(44): absorb arrived variables. Algorithm 2 messages
        // carry the worker-computed dual; Algorithm 4 messages carry none
        // (the master owns the duals).
        for &i in set {
            // ad-lint: allow(panic-free-lib): gather() only returns workers whose message is pending
            let msg = self.pending[i].take().expect("arrived worker has a pending message");
            m.state.xs[i] = msg.x;
            if let Some(lam) = msg.lam {
                m.state.lams[i] = lam;
            }
            m.f_cache[i] = m.problem.local(i).eval_with(&m.state.xs[i], &mut m.scratch.ws);
        }
    }

    fn broadcast(&mut self, set: &ActiveSet, state: &AdmmState, policy: &dyn UpdatePolicy) {
        // Step 6: broadcast to arrived workers only (owned slices when
        // sharded).
        let with_dual = policy.broadcasts_dual();
        for &i in set {
            let lam = with_dual.then(|| state.lams[i].clone());
            let x0 = match &self.shard {
                None => state.x0.clone(),
                Some(p) => p.gather_vec(i, &state.x0),
            };
            // A worker may have exited only after shutdown; sends cannot
            // fail before that.
            // ad-lint: allow(panic-free-lib): sends cannot fail before shutdown; a closed channel means a worker panicked
            self.to_workers[i].send(MasterMsg::Go { x0, lam }).expect("worker alive");
        }
    }
}
