//! Multi-master partitioned coordination: the coordinator itself shards.
//!
//! The AD-ADMM of the paper is star-topology — one master absorbs every
//! worker's update — which leaves a single-coordinator bandwidth/compute
//! ceiling that neither the O(active) sparse master (PR 6) nor the real
//! transport (PR 7) removes. Following the block-wise general-form
//! consensus architecture of Zhu, Niu & Li (arXiv:1802.08882), the global
//! variable partitions cleanly along [`BlockPattern`] block ownership:
//! a [`MasterGroup`] assigns every block id to one of `M` masters, each
//! master runs its own [`crate::admm::SparseMaster`] over only its owned
//! blocks, and workers ship each owned slice only to the master owning
//! that block.
//!
//! **M = 1 equivalence.** Per-coordinate master updates never read across
//! blocks, owners fold in ascending worker order per block, and in the
//! lockstep composition every master performs its (possibly empty) update
//! on every global round — so each per-master update counter marches in
//! step with the single-master counter and the lazy-prox catch-up replay
//! counts align exactly. An M-master run over disjoint block groups is
//! therefore **bit-identical** to the single-master sparse engine
//! consuming the same realized arrival trace (pinned by the
//! `multimaster` integration suite for M ∈ {1, 2, 4} across random
//! patterns, fault plans and inexact policies).
//!
//! The subsystem threads through every layer:
//!
//! - engine composition: [`crate::admm::session::SessionBuilder::masters`]
//!   drives M per-master sparse states inside one session;
//! - virtual time: [`MultiMasterSource`] wraps the discrete-event
//!   [`VirtualSource`] with per-master gate counters (per-master
//!   Assumption-1 τ-forcing and `|A_k ∩ W_m| ≥ min(A, live_m)` batching),
//!   per-master byte meters and simulated busy time;
//! - transport: per-master rendezvous listeners and slice-multiplexed
//!   workers ([`crate::cluster::transport::MultiSocketSource`]);
//! - checkpoints: format v4 records the group map + per-master counters
//!   and still loads v1–v3 documents as M = 1.

use std::sync::Arc;

use crate::admm::session::EngineError;
use crate::bench::json::{json_usize, JsonValue};
use crate::problems::BlockPattern;

use super::sim::VirtualSource;
use super::ClusterConfig;

/// A validated assignment of [`BlockPattern`] block ids to master ids:
/// `assignment[b] = m` means coordinate block `b` is coordinated by
/// master `m`. Every master must own at least one block and master ids
/// must be dense in `[0, num_masters)` — rejected as typed
/// [`EngineError::Masters`] otherwise.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MasterGroup {
    /// Block id → master id.
    assignment: Vec<usize>,
    num_masters: usize,
    /// Per-master owned block ids, ascending (derived).
    owned_blocks: Vec<Vec<usize>>,
}

impl MasterGroup {
    /// Validate an explicit block → master assignment.
    pub fn new(assignment: Vec<usize>, num_masters: usize) -> Result<Self, EngineError> {
        if num_masters == 0 {
            return Err(EngineError::Masters("num_masters must be >= 1".to_string()));
        }
        if assignment.is_empty() {
            return Err(EngineError::Masters(
                "master assignment must cover at least one block".to_string(),
            ));
        }
        let mut owned_blocks = vec![Vec::new(); num_masters];
        for (b, &m) in assignment.iter().enumerate() {
            if m >= num_masters {
                return Err(EngineError::Masters(format!(
                    "block {b} assigned to master {m}, but there are only {num_masters} masters"
                )));
            }
            owned_blocks[m].push(b);
        }
        if let Some(empty) = owned_blocks.iter().position(Vec::is_empty) {
            return Err(EngineError::Masters(format!("master {empty} owns no blocks")));
        }
        Ok(MasterGroup { assignment, num_masters, owned_blocks })
    }

    /// The trivial single-master group over `num_blocks` blocks — the
    /// star topology of the paper, and the M = 1 baseline every
    /// equivalence claim is pinned against.
    pub fn single(num_blocks: usize) -> Self {
        // ad-lint: allow(panic-free-lib): vec![0; n] with one master always passes validation
        Self::new(vec![0; num_blocks.max(1)], 1).expect("single-master group is always valid")
    }

    /// Contiguous even split: the first `num_blocks % num_masters` masters
    /// own one extra block. Errors when `num_masters` is 0 or exceeds
    /// `num_blocks` (a master would own nothing).
    pub fn contiguous(num_blocks: usize, num_masters: usize) -> Result<Self, EngineError> {
        if num_masters == 0 || num_masters > num_blocks {
            return Err(EngineError::Masters(format!(
                "num_masters must be in [1, {num_blocks}], got {num_masters}"
            )));
        }
        let base = num_blocks / num_masters;
        let extra = num_blocks % num_masters;
        let mut assignment = Vec::with_capacity(num_blocks);
        for m in 0..num_masters {
            let len = base + usize::from(m < extra);
            assignment.extend(std::iter::repeat(m).take(len));
        }
        Self::new(assignment, num_masters)
    }

    /// Number of coordinators.
    pub fn num_masters(&self) -> usize {
        self.num_masters
    }

    /// Number of blocks this group assigns.
    pub fn num_blocks(&self) -> usize {
        self.assignment.len()
    }

    /// The master owning block `b`.
    pub fn master_of(&self, b: usize) -> usize {
        self.assignment[b]
    }

    /// The full block → master map.
    pub fn assignment(&self) -> &[usize] {
        &self.assignment
    }

    /// Master `m`'s owned block ids, ascending.
    pub fn owned_blocks(&self, m: usize) -> &[usize] {
        &self.owned_blocks[m]
    }

    /// Per-block ownership mask for master `m` (the filter a per-master
    /// [`crate::admm::SparseMaster`] runs under).
    pub fn block_mask(&self, m: usize) -> Vec<bool> {
        self.assignment.iter().map(|&owner| owner == m).collect()
    }

    /// The masters worker `i` talks to under `pattern`: owners of at
    /// least one of its blocks, ascending and unique.
    pub fn masters_of_worker(&self, pattern: &BlockPattern, worker: usize) -> Vec<usize> {
        let mut seen = vec![false; self.num_masters];
        let mut out = Vec::new();
        for &b in pattern.owned(worker) {
            let m = self.assignment[b];
            if !seen[m] {
                seen[m] = true;
                out.push(m);
            }
        }
        out.sort_unstable();
        out
    }

    /// Per-master sorted worker lists under `pattern`: worker `i` belongs
    /// to master `m`'s fleet iff it owns at least one of `m`'s blocks.
    pub fn workers_of(&self, pattern: &BlockPattern) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); self.num_masters];
        for i in 0..pattern.num_workers() {
            for m in self.masters_of_worker(pattern, i) {
                out[m].push(i);
            }
        }
        out
    }

    /// The `(local_offset, len)` runs of worker `i`'s owned slice that
    /// belong to master `m`, in ascending local order — the slice-split
    /// primitive both transport endpoints derive identically from
    /// `(pattern, group)`, so no layout metadata rides the wire.
    pub fn worker_ranges(
        &self,
        pattern: &BlockPattern,
        worker: usize,
        master: usize,
    ) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        let mut local = 0usize;
        for &b in pattern.owned(worker) {
            let (_, len) = pattern.block_range(b);
            if self.assignment[b] == master {
                out.push((local, len));
            }
            local += len;
        }
        out
    }

    /// Total length of worker `i`'s slice destined for master `m` (the
    /// per-link payload size in f64s).
    pub fn worker_part_len(&self, pattern: &BlockPattern, worker: usize, master: usize) -> usize {
        self.worker_ranges(pattern, worker, master).iter().map(|&(_, len)| len).sum()
    }

    /// Cross-check against a pattern: the group must assign exactly the
    /// pattern's blocks.
    pub fn validate_against(&self, pattern: &BlockPattern) -> Result<(), EngineError> {
        if self.num_blocks() != pattern.num_blocks() {
            return Err(EngineError::Masters(format!(
                "group assigns {} blocks, the pattern has {}",
                self.num_blocks(),
                pattern.num_blocks()
            )));
        }
        Ok(())
    }

    /// Checkpoint-v4 / wire form.
    pub fn to_json(&self) -> JsonValue {
        JsonValue::Obj(vec![
            ("num_masters".to_string(), JsonValue::Num(self.num_masters as f64)),
            (
                "assignment".to_string(),
                JsonValue::Arr(
                    self.assignment.iter().map(|&m| JsonValue::Num(m as f64)).collect(),
                ),
            ),
        ])
    }

    /// Inverse of [`MasterGroup::to_json`] (re-validated on load).
    pub fn from_json(doc: &JsonValue) -> Result<Self, String> {
        let num_masters =
            json_usize(doc.get("num_masters").ok_or("group missing field \"num_masters\"")?)?;
        let mut assignment = Vec::new();
        for v in doc.get("assignment").ok_or("group missing field \"assignment\"")?.items() {
            assignment.push(json_usize(v)?);
        }
        Self::new(assignment, num_masters).map_err(|e| format!("invalid master group: {e}"))
    }
}

/// The virtual-time multi-master [`WorkerSource`]: a
/// [`VirtualSource`] with a [`MasterGroup`] installed, so one
/// discrete-event queue drives M coordinators — each with its own gate
/// counters (per-master Assumption-1 τ-forcing over its own fleet,
/// per-master `min(A, live_m)` batching), byte meters and simulated busy
/// time. A round completes only when *every* master's gate is satisfied
/// (the lockstep-global-rounds composition the bit-identity pin rests
/// on); with M = 1 the gate, the meters and every event timing collapse
/// to the plain [`VirtualSource`].
///
/// [`WorkerSource`]: crate::admm::engine::WorkerSource
pub struct MultiMasterSource;

impl MultiMasterSource {
    /// Build a [`VirtualSource`] with `group` installed. Returned as the
    /// underlying source type so [`crate::admm::session::Session`]s stay
    /// `Session<'_, VirtualSource>` and the cluster's report plumbing
    /// ([`super::ClusterReport::from_virtual_parts`]) applies unchanged.
    pub fn build(
        n_workers: usize,
        cfg: &ClusterConfig,
        pattern: Arc<BlockPattern>,
        group: &MasterGroup,
    ) -> Result<VirtualSource, EngineError> {
        group.validate_against(&pattern)?;
        let mut source = VirtualSource::new(n_workers, cfg, None, Some(Arc::clone(&pattern)));
        source.set_master_group(Arc::new(group.clone()));
        Ok(source)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_split_covers_all_blocks() {
        let g = MasterGroup::contiguous(10, 4).unwrap();
        assert_eq!(g.num_masters(), 4);
        assert_eq!(g.num_blocks(), 10);
        // 10 = 3 + 3 + 2 + 2
        assert_eq!(g.owned_blocks(0), &[0, 1, 2]);
        assert_eq!(g.owned_blocks(1), &[3, 4, 5]);
        assert_eq!(g.owned_blocks(2), &[6, 7]);
        assert_eq!(g.owned_blocks(3), &[8, 9]);
        let mask = g.block_mask(2);
        assert_eq!(mask.iter().filter(|&&b| b).count(), 2);
        assert!(mask[6] && mask[7]);
    }

    #[test]
    fn single_group_is_the_star_topology() {
        let g = MasterGroup::single(5);
        assert_eq!(g.num_masters(), 1);
        assert!(g.assignment().iter().all(|&m| m == 0));
    }

    #[test]
    fn invalid_groups_are_typed_errors() {
        assert!(MasterGroup::new(vec![0, 2], 2).is_err(), "master id out of range");
        assert!(MasterGroup::new(vec![0, 0], 2).is_err(), "master 1 owns nothing");
        assert!(MasterGroup::new(Vec::new(), 1).is_err(), "no blocks");
        assert!(MasterGroup::contiguous(2, 3).is_err(), "more masters than blocks");
        assert!(MasterGroup::contiguous(2, 0).is_err(), "zero masters");
    }

    #[test]
    fn worker_ranges_split_the_local_layout() {
        // 8 coords, 4 blocks of 2, 4 workers, 2 copies: worker i owns
        // blocks {i, (i+3) % 4} sorted ascending.
        let p = BlockPattern::round_robin(8, 4, 4, 2).unwrap();
        let g = MasterGroup::contiguous(4, 2).unwrap(); // blocks {0,1} | {2,3}
        // Worker 0 owns blocks [0, 3]: local layout = block0 (len 2) then
        // block3 (len 2). Master 0 gets (0, 2), master 1 gets (2, 2).
        assert_eq!(g.worker_ranges(&p, 0, 0), vec![(0, 2)]);
        assert_eq!(g.worker_ranges(&p, 0, 1), vec![(2, 2)]);
        assert_eq!(g.worker_part_len(&p, 0, 0) + g.worker_part_len(&p, 0, 1), p.owned_len(0));
        assert_eq!(g.masters_of_worker(&p, 0), vec![0, 1]);
        // Worker 2 owns blocks [1, 2]: one block per master group.
        assert_eq!(g.worker_ranges(&p, 2, 0), vec![(0, 2)]);
        assert_eq!(g.worker_ranges(&p, 2, 1), vec![(2, 2)]);
        let fleets = g.workers_of(&p);
        assert_eq!(fleets.len(), 2);
        assert_eq!(fleets[0], vec![0, 1, 2, 3]);
        assert_eq!(fleets[1], vec![0, 1, 2, 3]);
    }

    #[test]
    fn group_json_roundtrips_and_revalidates() {
        let g = MasterGroup::contiguous(6, 3).unwrap();
        let back = MasterGroup::from_json(&g.to_json()).unwrap();
        assert_eq!(back, g);
        assert!(MasterGroup::from_json(&JsonValue::Obj(vec![
            ("num_masters".to_string(), JsonValue::Num(2.0)),
            ("assignment".to_string(), JsonValue::Arr(vec![JsonValue::Num(0.0)])),
        ]))
        .is_err());
    }
}
