//! The worker loop: Algorithm 2 / Algorithm 4, "Algorithm of the i-th
//! Worker" boxes.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::problems::{LocalCost, WorkerScratch};

use super::messages::{MasterMsg, WorkerMsg};
use super::timeline::WorkerStats;
use super::{DelaySampler, FaultModel, Protocol};
use crate::rng::Pcg64;

/// Optional solve override: `(lam, x0, rho, out)` — lets the PJRT runtime
/// replace the native closed-form subproblem solve per worker.
pub type WorkerSolveFn = Box<dyn FnMut(&[f64], &[f64], f64, &mut [f64]) + Send>;

/// One worker thread. Returns its accumulated stats at shutdown.
///
/// `delay` models the per-round compute time, `comm` (optional) the
/// outbound link latency; both are realized as real sleeps in this mode
/// (the virtual-time mode turns the same samplers into scheduler events).
/// `spikes` stretches both sleeps by the active
/// [`FaultPlan`](crate::admm::engine::FaultPlan) delay-spike factor, keyed
/// on wall seconds since this worker started (outages are enforced at the
/// master's gate, not here — a down worker's message is simply held).
#[allow(clippy::too_many_arguments)]
pub(crate) fn worker_loop(
    id: usize,
    local: Arc<dyn LocalCost>,
    rho: f64,
    protocol: Protocol,
    inbox: Receiver<MasterMsg>,
    outbox: Sender<WorkerMsg>,
    mut delay: DelaySampler,
    mut comm: Option<DelaySampler>,
    mut solve_override: Option<WorkerSolveFn>,
    faults: Option<FaultModel>,
    spikes: Option<crate::admm::engine::FaultPlan>,
) -> WorkerStats {
    let n = local.dim();
    let mut lam = vec![0.0; n]; // λ⁰ = 0 (Algorithm 2 keeps it worker-side)
    let mut x = vec![0.0; n];
    let mut scratch = WorkerScratch::new(); // reused across rounds
    let mut stats = WorkerStats::new(id);
    let mut fault_rng = faults
        .as_ref()
        .map(|f| Pcg64::seed_from_u64(f.seed.wrapping_add(id as u64 * 0x5bd1)));
    let loop_started = Instant::now();

    // Communication-failure emulation: each drop costs one retransmission
    // delay before the message reaches the master (the channel itself is
    // reliable; losses manifest purely as extra latency, which is exactly
    // the partially-asynchronous model's view of them).
    let mut comm_faults = |stats: &mut WorkerStats| {
        if let (Some(f), Some(rng)) = (faults.as_ref(), fault_rng.as_mut()) {
            while rng.bernoulli(f.drop_prob) {
                std::thread::sleep(Duration::from_secs_f64(f.retrans_ms * 1e-3));
                stats.retransmissions += 1;
            }
        }
    };

    while let Ok(msg) = inbox.recv() {
        let (x0, master_lam) = match msg {
            MasterMsg::Shutdown => break,
            MasterMsg::Go { x0, lam } => (x0, lam),
        };
        let t0 = Instant::now();

        // Injected heterogeneous compute delay (plus communication, when no
        // separate comm model is configured), stretched by any active
        // delay spike.
        let spike = |t: &Instant| match &spikes {
            Some(plan) => plan.delay_factor(id, t.elapsed().as_secs_f64()),
            None => 1.0,
        };
        let ms = delay.sample_ms() * spike(&loop_started);
        if ms > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(ms * 1e-3));
        }
        // Separate outbound-link latency, slept just like the compute part.
        if let Some(c) = comm.as_mut() {
            let cms = c.sample_ms() * spike(&loop_started);
            if cms > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(cms * 1e-3));
            }
        }

        match protocol {
            Protocol::AdAdmm => {
                // (13): x_i ← argmin f_i + xᵀλ_i + ρ/2‖x − x̂₀‖²
                match solve_override.as_mut() {
                    Some(f) => f(&lam, &x0, rho, &mut x),
                    None => local.solve_subproblem(&lam, &x0, rho, &mut x, &mut scratch),
                }
                // (14): λ_i ← λ_i + ρ(x_i − x̂₀)
                for j in 0..n {
                    lam[j] += rho * (x[j] - x0[j]);
                }
                comm_faults(&mut stats);
                let _ = outbox.send(WorkerMsg { id, x: x.clone(), lam: Some(lam.clone()) });
            }
            Protocol::AltScheme => {
                // (47): x_i ← argmin f_i + xᵀλ̂_i + ρ/2‖x − x̂₀‖²
                let master_lam = master_lam.expect("Algorithm 4 must send λ̂_i");
                match solve_override.as_mut() {
                    Some(f) => f(&master_lam, &x0, rho, &mut x),
                    None => local.solve_subproblem(&master_lam, &x0, rho, &mut x, &mut scratch),
                }
                comm_faults(&mut stats);
                let _ = outbox.send(WorkerMsg { id, x: x.clone(), lam: None });
            }
        }

        stats.updates += 1;
        stats.busy_s += t0.elapsed().as_secs_f64();
    }

    stats.lifetime_s = loop_started.elapsed().as_secs_f64();
    stats
}
