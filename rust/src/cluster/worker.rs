//! The worker loop: Algorithm 2 / Algorithm 4, "Algorithm of the i-th
//! Worker" boxes.
//!
//! The per-round protocol is split into two shared pieces so every real
//! transport (in-process channels here, TCP frames in
//! [`super::transport::client`]) degrades identically under injected
//! faults:
//!
//! - [`worker_round`] — the arithmetic of one round: subproblem solve and
//!   (Algorithm 2) the worker-side dual update;
//! - [`comm_leg_ms`] — the communication-leg latency: one comm-model draw
//!   plus any fault retransmissions, with an active
//!   [`FaultPlan`](crate::admm::engine::FaultPlan) delay spike stretching
//!   the **whole** leg. This mirrors the virtual-time source, which
//!   applies the spike factor to the full transit (sample + accumulated
//!   retransmissions); historically the threaded loop stretched only the
//!   comm-model draw and slept retransmissions unstretched, so a comm-leg
//!   spike was invisible whenever latency came from retransmissions alone.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::problems::{LocalCost, WorkerScratch};
use crate::solvers::inexact::{solve_inexact, InexactPolicy, WarmState};

use super::messages::{MasterMsg, WorkerMsg};
use super::timeline::WorkerStats;
use super::{DelaySampler, FaultModel, Protocol};
use crate::rng::Pcg64;

/// Optional solve override: `(lam, x0, rho, out)` — lets the PJRT runtime
/// replace the native closed-form subproblem solve per worker.
pub type WorkerSolveFn = Box<dyn FnMut(&[f64], &[f64], f64, &mut [f64]) + Send>;

/// One protocol round of worker `i` (the arithmetic only — no sleeps, no
/// I/O): Algorithm 2 solves (13) against the worker-held dual and applies
/// the dual ascent (14); Algorithm 4 solves (47) against the
/// master-supplied dual and leaves `lam` untouched. Returns the dual to
/// ship with the result (`Some` for Algorithm 2, `None` for Algorithm 4).
///
/// Shared verbatim by the threaded worker loop and the socket worker
/// client so that both transports compute bit-identical messages from the
/// same `(λ_i, x̂₀)` inputs. The solve honours the session's
/// [`InexactPolicy`] through this worker's persistent [`WarmState`]
/// (untouched under `Exact`; a solve override is always exact — the PJRT
/// artifacts bake in the full solve).
#[allow(clippy::too_many_arguments)]
pub(crate) fn worker_round(
    protocol: Protocol,
    local: &dyn LocalCost,
    rho: f64,
    lam: &mut [f64],
    x: &mut [f64],
    x0: &[f64],
    master_lam: Option<&[f64]>,
    solve_override: Option<&mut WorkerSolveFn>,
    scratch: &mut WorkerScratch,
    policy: &InexactPolicy,
    warm: &mut WarmState,
) -> Option<Vec<f64>> {
    match protocol {
        Protocol::AdAdmm => {
            // (13): x_i ← argmin f_i + xᵀλ_i + ρ/2‖x − x̂₀‖²
            match solve_override {
                Some(f) => f(lam, x0, rho, x),
                None => solve_inexact(local, policy, lam, x0, rho, x, scratch, warm),
            }
            // (14): λ_i ← λ_i + ρ(x_i − x̂₀)
            for j in 0..x.len() {
                lam[j] += rho * (x[j] - x0[j]);
            }
            Some(lam.to_vec())
        }
        Protocol::AltScheme => {
            // (47): x_i ← argmin f_i + xᵀλ̂_i + ρ/2‖x − x̂₀‖²
            // ad-lint: allow(panic-free-lib): protocol invariant: the master always attaches λ̂ under Algorithm 4
            let master_lam = master_lam.expect("Algorithm 4 must send λ̂_i");
            match solve_override {
                Some(f) => f(master_lam, x0, rho, x),
                None => solve_inexact(local, policy, master_lam, x0, rho, x, scratch, warm),
            }
            None
        }
    }
}

/// The communication-leg latency of one round, in milliseconds: one draw
/// from the comm delay model (if any) plus one retransmission delay per
/// emulated message drop, the **whole sum** stretched by `spike_factor`
/// (the active delay-spike factor; `1.0` when none). This is exactly the
/// virtual-time source's transit formula, so a comm-leg spike slows a
/// retransmitting worker identically in threaded, socket and virtual
/// modes.
pub(crate) fn comm_leg_ms(
    comm: Option<&mut DelaySampler>,
    faults: Option<&FaultModel>,
    fault_rng: Option<&mut Pcg64>,
    stats: &mut WorkerStats,
    spike_factor: f64,
) -> f64 {
    let mut ms = comm.map_or(0.0, DelaySampler::sample_ms);
    // Communication-failure emulation: each drop costs one retransmission
    // delay before the message reaches the master (the link itself is
    // reliable; losses manifest purely as extra latency, which is exactly
    // the partially-asynchronous model's view of them).
    if let (Some(f), Some(rng)) = (faults, fault_rng) {
        while rng.bernoulli(f.drop_prob) {
            ms += f.retrans_ms;
            stats.retransmissions += 1;
        }
    }
    ms * spike_factor
}

/// One worker thread. Returns its accumulated stats at shutdown.
///
/// `delay` models the per-round compute time, `comm` (optional) the
/// outbound link latency; both are realized as real sleeps in this mode
/// (the virtual-time mode turns the same samplers into scheduler events).
/// `spikes` stretches both legs by the active
/// [`FaultPlan`](crate::admm::engine::FaultPlan) delay-spike factor, keyed
/// on wall seconds since this worker started (outages are enforced at the
/// master's gate, not here — a down worker's message is simply held). The
/// comm leg — model draw *plus* retransmissions — is stretched as one unit
/// via [`comm_leg_ms`], matching the virtual-time transit formula.
#[allow(clippy::too_many_arguments)]
pub(crate) fn worker_loop(
    id: usize,
    local: Arc<dyn LocalCost>,
    rho: f64,
    protocol: Protocol,
    inbox: Receiver<MasterMsg>,
    outbox: Sender<WorkerMsg>,
    mut delay: DelaySampler,
    mut comm: Option<DelaySampler>,
    mut solve_override: Option<WorkerSolveFn>,
    faults: Option<FaultModel>,
    spikes: Option<crate::admm::engine::FaultPlan>,
    policy: InexactPolicy,
) -> WorkerStats {
    let n = local.dim();
    let mut lam = vec![0.0; n]; // λ⁰ = 0 (Algorithm 2 keeps it worker-side)
    let mut x = vec![0.0; n];
    let mut scratch = WorkerScratch::new(); // reused across rounds
    let mut warm = WarmState::default(); // inexact-policy warm start
    let mut stats = WorkerStats::new(id);
    let mut fault_rng = faults
        .as_ref()
        .map(|f| Pcg64::seed_from_u64(f.seed.wrapping_add(id as u64 * 0x5bd1)));
    let loop_started = Instant::now(); // ad-lint: allow(wallclock): OS-thread worker: delay spikes are keyed to real elapsed time

    while let Ok(msg) = inbox.recv() {
        let (x0, master_lam) = match msg {
            MasterMsg::Shutdown => break,
            MasterMsg::Go { x0, lam } => (x0, lam),
        };
        let t0 = Instant::now(); // ad-lint: allow(wallclock): OS-thread worker meters real busy time

        let spike = |t: &Instant| match &spikes { // ad-lint: allow(wallclock): real-time spike window lookup in the OS-thread worker
            Some(plan) => plan.delay_factor(id, t.elapsed().as_secs_f64()),
            None => 1.0,
        };
        // Injected heterogeneous compute delay (plus communication, when no
        // separate comm model is configured), stretched by any active
        // delay spike.
        let ms = delay.sample_ms() * spike(&loop_started);
        if ms > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(ms * 1e-3)); // ad-lint: allow(wallclock): injected compute delay in the real-thread cluster is a real sleep
        }

        let lam_out = worker_round(
            protocol,
            &*local,
            rho,
            &mut lam,
            &mut x,
            &x0,
            master_lam.as_deref(),
            solve_override.as_mut(),
            &mut scratch,
            &policy,
            &mut warm,
        );

        // Outbound leg: comm draw + retransmissions, slept as one stretched
        // unit (the spike factor is sampled at leg start, like the
        // virtual-time scheduler stamps transit at compute-done time).
        let cms = comm_leg_ms(
            comm.as_mut(),
            faults.as_ref(),
            fault_rng.as_mut(),
            &mut stats,
            spike(&loop_started),
        );
        if cms > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(cms * 1e-3)); // ad-lint: allow(wallclock): injected comm delay in the real-thread cluster is a real sleep
        }
        let _ = outbox.send(WorkerMsg { id, x: x.clone(), lam: lam_out });

        stats.updates += 1;
        stats.busy_s += t0.elapsed().as_secs_f64();
    }

    stats.lifetime_s = loop_started.elapsed().as_secs_f64();
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The comm-leg formula matches the virtual-time transit rule: the
    /// spike factor multiplies the model draw AND every retransmission.
    /// (The historical bug stretched only the model draw, so a spiked
    /// worker whose latency came from retransmissions was not slowed at
    /// all — threaded and virtual runs degraded differently.)
    #[test]
    fn comm_leg_spike_stretches_retransmissions() {
        let faults = FaultModel { drop_prob: 0.9, retrans_ms: 2.0, seed: 42 };
        let mut stats = WorkerStats::new(0);
        // Count the retransmissions this seed realizes, unspiked...
        let mut rng = Pcg64::seed_from_u64(faults.seed.wrapping_add(0));
        let base = comm_leg_ms(
            Some(&mut DelaySampler::Fixed(3.0)),
            Some(&faults),
            Some(&mut rng),
            &mut stats,
            1.0,
        );
        let k = stats.retransmissions;
        assert!(k > 0, "drop_prob=0.9 must realize at least one retransmission");
        assert_eq!(base, 3.0 + 2.0 * k as f64);
        // ...then the identical stream under a 10x spike: the whole leg
        // scales, bit-exactly (same draws — the rng restarts at the seed).
        let mut stats2 = WorkerStats::new(0);
        let mut rng2 = Pcg64::seed_from_u64(faults.seed.wrapping_add(0));
        let spiked = comm_leg_ms(
            Some(&mut DelaySampler::Fixed(3.0)),
            Some(&faults),
            Some(&mut rng2),
            &mut stats2,
            10.0,
        );
        assert_eq!(stats2.retransmissions, k);
        assert_eq!(spiked, 10.0 * base);
    }

    /// Without a comm model, latency comes from retransmissions alone —
    /// the case the historical code left entirely unstretched.
    #[test]
    fn comm_leg_spike_applies_with_no_comm_model() {
        let faults = FaultModel { drop_prob: 0.9, retrans_ms: 1.0, seed: 7 };
        let mut stats = WorkerStats::new(3);
        let mut rng = Pcg64::seed_from_u64(faults.seed.wrapping_add(3 * 0x5bd1));
        let leg = comm_leg_ms(None, Some(&faults), Some(&mut rng), &mut stats, 50.0);
        assert_eq!(leg, 50.0 * stats.retransmissions as f64);
        assert!(stats.retransmissions > 0);
    }
}
