//! A std-only scoped thread pool for the virtual-time scheduler's worker
//! rounds.
//!
//! The discrete-event simulator ([`super::sim`]) defers all worker
//! arithmetic of a master iteration — subproblem solves, dual updates,
//! `f_i(x_i)` cache refreshes — into a task list, one task per arrived
//! worker. This pool fans that list across OS threads while keeping the
//! run **bit-identical** to serial execution:
//!
//! - every task writes only its own per-worker slots (`x_i`, `λ_i`,
//!   `f_cache[i]`, the worker's scratch) and reads only shared immutable
//!   state (the `x₀`/`λ̂` snapshots, the problem data), so the results do
//!   not depend on scheduling;
//! - tasks are partitioned into **contiguous chunks in worker-index
//!   order** (chunk `c` always gets the same tasks for a given task count
//!   and thread count), so even the work assignment is deterministic, not
//!   just the result;
//! - all *reductions* over worker results (the master prox assembly, the
//!   cached augmented Lagrangian) stay on the calling thread in ascending
//!   worker-index order.
//!
//! `std::thread::scope` lets the tasks borrow the coordinator's state
//! directly — no channels, no `'static` bounds, no allocation besides the
//! per-round spawn of at most `threads` OS threads. The `virtual_time`
//! property tests pin pooled == serial bit-equality across worker counts,
//! seeds and pool sizes.

use std::num::NonZeroUsize;

/// Scoped fan-out pool. Cheap to construct; holds no threads between runs.
#[derive(Clone, Copy, Debug)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// `threads = 0` auto-sizes to the machine's available parallelism;
    /// `threads = 1` executes serially on the calling thread (no spawns);
    /// `threads = k` uses at most `k` OS threads per run.
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(NonZeroUsize::get).unwrap_or(1)
        } else {
            threads
        };
        WorkerPool { threads }
    }

    /// The resolved thread count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Apply `f` to every task. Serial in-order execution when the pool has
    /// one thread (or one task); otherwise tasks are split into contiguous
    /// chunks and each chunk runs on its own scoped thread, preserving
    /// in-chunk order. `f` must make the outcome independent of scheduling
    /// by writing only through the task it was handed.
    pub fn run<T, F>(&self, tasks: &mut [T], f: F)
    where
        T: Send,
        F: Fn(&mut T) + Sync,
    {
        let threads = self.threads.min(tasks.len());
        if threads <= 1 {
            for task in tasks.iter_mut() {
                f(task);
            }
            return;
        }
        // ceil(len / threads): every chunk but possibly the last is full,
        // and the chunk boundaries depend only on (len, threads).
        let chunk = tasks.len().div_ceil(threads);
        std::thread::scope(|scope| {
            for chunk_tasks in tasks.chunks_mut(chunk) {
                let f = &f;
                scope.spawn(move || {
                    for task in chunk_tasks.iter_mut() {
                        f(task);
                    }
                });
            }
        });
    }
}

impl Default for WorkerPool {
    /// Auto-sized pool (`threads = 0`).
    fn default() -> Self {
        WorkerPool::new(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn squares(pool: &WorkerPool, n: usize) -> Vec<f64> {
        let mut tasks: Vec<(usize, f64)> = (0..n).map(|i| (i, 0.0)).collect();
        pool.run(&mut tasks, |t| {
            t.1 = (t.0 as f64 + 1.0).sqrt();
        });
        tasks.into_iter().map(|t| t.1).collect()
    }

    #[test]
    fn zero_auto_sizes_to_at_least_one() {
        assert!(WorkerPool::new(0).threads() >= 1);
        assert_eq!(WorkerPool::new(3).threads(), 3);
        assert!(WorkerPool::default().threads() >= 1);
    }

    #[test]
    fn pooled_results_bit_equal_to_serial() {
        let serial = squares(&WorkerPool::new(1), 101);
        for threads in [2, 3, 4, 7, 200] {
            let pooled = squares(&WorkerPool::new(threads), 101);
            assert_eq!(serial, pooled, "threads={threads}");
        }
    }

    #[test]
    fn every_task_runs_exactly_once() {
        let mut tasks: Vec<u32> = vec![0; 57];
        WorkerPool::new(4).run(&mut tasks, |t| *t += 1);
        assert!(tasks.iter().all(|&t| t == 1));
    }

    #[test]
    fn empty_and_single_task_lists() {
        let pool = WorkerPool::new(8);
        let mut none: Vec<u32> = Vec::new();
        pool.run(&mut none, |_| unreachable!("no tasks to run"));
        let mut one = vec![41u32];
        pool.run(&mut one, |t| *t += 1);
        assert_eq!(one, vec![42]);
    }

    #[test]
    fn pool_larger_than_task_count() {
        // more threads than tasks: each task still runs once, in a chunk
        // of its own
        let mut tasks: Vec<usize> = (0..3).collect();
        WorkerPool::new(64).run(&mut tasks, |t| *t *= 10);
        assert_eq!(tasks, vec![0, 10, 20]);
    }
}
