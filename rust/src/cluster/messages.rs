//! Wire messages of the star links — shared verbatim by the real-thread
//! mode (sent over mpsc channels) and the virtual-time mode (whose events
//! stand in for their transit).

/// Master → worker.
#[derive(Clone, Debug)]
pub enum MasterMsg {
    /// Compute one subproblem round against this x₀ (and, for Algorithm 4,
    /// this master-updated dual).
    Go { x0: Vec<f64>, lam: Option<Vec<f64>> },
    /// Stop the worker loop.
    Shutdown,
}

/// Worker → master: the arrived variables `(x̂_i, λ̂_i)` of Step 4.
#[derive(Clone, Debug)]
pub struct WorkerMsg {
    pub id: usize,
    pub x: Vec<f64>,
    /// Algorithm 2 carries the worker-updated dual; Algorithm 4 sends none
    /// (the master owns the duals).
    pub lam: Option<Vec<f64>>,
}
