//! Virtual time for the star cluster: a simulated [`Clock`] plus the
//! deterministic discrete-event queue that drives it.
//!
//! The real-thread mode injects delays by sleeping on the OS clock; the
//! virtual-time mode replaces every sleep with an *event* — "worker `i`
//! finishes computing at `t`", "worker `i`'s result reaches the master at
//! `t`" — ordered by `(time, sequence)` so ties resolve by enqueue order
//! and the whole simulation is bit-reproducible. This is what lets the
//! Section-V τ / `|A_k| ≥ A` sweeps run with thousands of workers in
//! milliseconds instead of wall-clock hours.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::util::timer::Clock;

/// A simulated clock: reads in seconds, advanced only by the event loop.
#[derive(Clone, Debug, Default)]
pub struct VirtualClock {
    now_s: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { now_s: 0.0 }
    }

    /// Advance to an event timestamp. Time never runs backwards; an event
    /// stamped in the past (numerically possible with f64 ties) leaves the
    /// clock unchanged.
    pub fn advance_to(&mut self, t_s: f64) {
        if t_s > self.now_s {
            self.now_s = t_s;
        }
    }
}

impl Clock for VirtualClock {
    fn now_s(&self) -> f64 {
        self.now_s
    }
}

/// What happens at an event timestamp.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Worker finished its subproblem compute; the result now enters the
    /// communication link.
    ComputeDone,
    /// The worker's message reached the master (arrival of Step 4).
    Arrive,
}

/// One scheduled event. Ordered by `(time, seq)`: earlier time first, FIFO
/// among equal timestamps — the determinism contract of the simulator.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub time_s: f64,
    pub seq: u64,
    pub worker: usize,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time_s
            .total_cmp(&other.time_s)
            .then(self.seq.cmp(&other.seq))
    }
}

/// Deterministic min-heap of events.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<std::cmp::Reverse<Event>>,
    next_seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0 }
    }

    /// Schedule `kind` for `worker` at absolute time `time_s`.
    pub fn push(&mut self, time_s: f64, worker: usize, kind: EventKind) {
        debug_assert!(time_s.is_finite(), "event time must be finite");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(std::cmp::Reverse(Event { time_s, seq, worker, kind }));
    }

    /// Pop the earliest event (ties: FIFO).
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|r| r.0)
    }

    /// Timestamp of the earliest pending event.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|r| r.0.time_s)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Deterministic snapshot for checkpointing: every pending event in
    /// ascending `(time, seq)` order plus the sequence counter.
    pub fn snapshot(&self) -> (Vec<Event>, u64) {
        let mut events: Vec<Event> = self.heap.iter().map(|r| r.0).collect();
        events.sort();
        (events, self.next_seq)
    }

    /// Rebuild a queue from a [`EventQueue::snapshot`]: the events keep
    /// their original sequence numbers, so FIFO tie-breaking — and with it
    /// the whole simulation — continues bit-identically.
    pub fn restore(events: Vec<Event>, next_seq: u64) -> Self {
        EventQueue {
            heap: events.into_iter().map(std::cmp::Reverse).collect(),
            next_seq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_monotone() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now_s(), 0.0);
        c.advance_to(2.5);
        assert_eq!(c.now_s(), 2.5);
        c.advance_to(1.0); // never backwards
        assert_eq!(c.now_s(), 2.5);
    }

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, 0, EventKind::Arrive);
        q.push(1.0, 1, EventKind::Arrive);
        q.push(2.0, 2, EventKind::ComputeDone);
        let order: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|e| e.worker).collect();
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn equal_times_resolve_fifo() {
        let mut q = EventQueue::new();
        for w in [5usize, 3, 9, 1] {
            q.push(1.0, w, EventKind::Arrive);
        }
        let order: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|e| e.worker).collect();
        assert_eq!(order, vec![5, 3, 9, 1], "FIFO among ties");
    }

    #[test]
    fn snapshot_restore_preserves_order_and_ties() {
        let mut q = EventQueue::new();
        q.push(2.0, 0, EventKind::Arrive);
        q.push(1.0, 1, EventKind::ComputeDone);
        q.push(1.0, 2, EventKind::Arrive); // FIFO tie with worker 1's event
        let (events, next_seq) = q.snapshot();
        assert_eq!(next_seq, 3);
        assert_eq!(events.iter().map(|e| e.worker).collect::<Vec<_>>(), vec![1, 2, 0]);
        let mut restored = EventQueue::restore(events, next_seq);
        // a new push must sort after the restored tie at t = 1.0
        restored.push(1.0, 9, EventKind::Arrive);
        let order: Vec<usize> =
            std::iter::from_fn(|| restored.pop()).map(|e| e.worker).collect();
        assert_eq!(order, vec![1, 2, 9, 0]);
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
        q.push(0.5, 0, EventKind::Arrive);
        q.push(0.25, 1, EventKind::Arrive);
        assert_eq!(q.len(), 2);
        assert_eq!(q.peek_time(), Some(0.25));
        assert_eq!(q.pop().unwrap().worker, 1);
        assert_eq!(q.peek_time(), Some(0.5));
    }
}
