//! Virtual time for the star cluster: a simulated [`Clock`] plus the
//! deterministic discrete-event queue that drives it.
//!
//! The real-thread mode injects delays by sleeping on the OS clock; the
//! virtual-time mode replaces every sleep with an *event* — "worker `i`
//! finishes computing at `t`", "worker `i`'s result reaches the master at
//! `t`" — ordered by `(time, sequence)` so ties resolve by enqueue order
//! and the whole simulation is bit-reproducible. This is what lets the
//! Section-V τ / `|A_k| ≥ A` sweeps run with thousands of workers in
//! milliseconds instead of wall-clock hours.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::util::timer::Clock;

/// A simulated clock: reads in seconds, advanced only by the event loop.
#[derive(Clone, Debug, Default)]
pub struct VirtualClock {
    now_s: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { now_s: 0.0 }
    }

    /// Advance to an event timestamp. Time never runs backwards; an event
    /// stamped in the past (numerically possible with f64 ties) leaves the
    /// clock unchanged.
    pub fn advance_to(&mut self, t_s: f64) {
        if t_s > self.now_s {
            self.now_s = t_s;
        }
    }
}

impl Clock for VirtualClock {
    fn now_s(&self) -> f64 {
        self.now_s
    }
}

/// What happens at an event timestamp.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Worker finished its subproblem compute; the result now enters the
    /// communication link.
    ComputeDone,
    /// The worker's message reached the master (arrival of Step 4).
    Arrive,
}

/// One scheduled event. Ordered by `(time, seq)`: earlier time first, FIFO
/// among equal timestamps — the determinism contract of the simulator.
///
/// This is the *interchange* form (checkpoints, [`EventQueue::snapshot`],
/// the pop result); inside the queue events live as 16-byte
/// [`PackedEvent`]s so a 10⁶-worker sweep keeps its two-million-entry heap
/// in a compact, cache-dense array.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub time_s: f64,
    pub seq: u64,
    pub worker: usize,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time_s
            .total_cmp(&other.time_s)
            .then(self.seq.cmp(&other.seq))
    }
}

/// Bits of `PackedEvent::key` holding the worker id (above the kind bit).
const WORKER_BITS: u32 = 20;
/// Largest representable worker id (2²⁰ − 1 ≈ 10⁶ — the sweep ceiling).
const MAX_WORKER: usize = (1 << WORKER_BITS) - 1;
/// Largest representable sequence number (the remaining 43 key bits).
const MAX_SEQ: u64 = (1 << (63 - WORKER_BITS)) - 1;

/// Heap entry: `(seq, worker, kind)` packed into one `u64` next to the
/// timestamp — 16 bytes per event instead of the 32 of the naive struct,
/// and one branch-free `u64` compare for the tie-break. `seq` occupies the
/// high bits, so comparing keys compares sequence numbers first; the
/// worker/kind payload below can only break ties between *equal* seqs,
/// which never occur (each push gets a fresh seq).
#[derive(Clone, Copy, Debug)]
struct PackedEvent {
    time_s: f64,
    /// `seq << 21 | worker << 1 | kind` (kind: 0 = ComputeDone, 1 = Arrive).
    key: u64,
}

impl PackedEvent {
    fn pack(time_s: f64, seq: u64, worker: usize, kind: EventKind) -> Self {
        assert!(worker <= MAX_WORKER, "worker id {worker} exceeds the 2^20 event-queue limit");
        assert!(seq <= MAX_SEQ, "event sequence number overflow");
        let kind_bit = match kind {
            EventKind::ComputeDone => 0u64,
            EventKind::Arrive => 1u64,
        };
        let key = (seq << (WORKER_BITS + 1)) | ((worker as u64) << 1) | kind_bit;
        PackedEvent { time_s, key }
    }

    fn unpack(self) -> Event {
        Event {
            time_s: self.time_s,
            seq: self.key >> (WORKER_BITS + 1),
            worker: ((self.key >> 1) & MAX_WORKER as u64) as usize,
            kind: if self.key & 1 == 0 { EventKind::ComputeDone } else { EventKind::Arrive },
        }
    }
}

impl PartialEq for PackedEvent {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for PackedEvent {}

impl PartialOrd for PackedEvent {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for PackedEvent {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time_s.total_cmp(&other.time_s).then(self.key.cmp(&other.key))
    }
}

/// Deterministic min-heap of events over the packed representation.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<std::cmp::Reverse<PackedEvent>>,
    next_seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0 }
    }

    /// Schedule `kind` for `worker` at absolute time `time_s`.
    pub fn push(&mut self, time_s: f64, worker: usize, kind: EventKind) {
        debug_assert!(time_s.is_finite(), "event time must be finite");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(std::cmp::Reverse(PackedEvent::pack(time_s, seq, worker, kind)));
    }

    /// Pop the earliest event (ties: FIFO).
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|r| r.0.unpack())
    }

    /// Timestamp of the earliest pending event.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|r| r.0.time_s)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Deterministic snapshot for checkpointing: every pending event in
    /// ascending `(time, seq)` order plus the sequence counter. Events are
    /// unpacked into the interchange form, so the checkpoint format is
    /// independent of the internal packing.
    pub fn snapshot(&self) -> (Vec<Event>, u64) {
        let mut events: Vec<Event> = self.heap.iter().map(|r| r.0.unpack()).collect();
        events.sort();
        (events, self.next_seq)
    }

    /// Rebuild a queue from a [`EventQueue::snapshot`]: the events keep
    /// their original sequence numbers, so FIFO tie-breaking — and with it
    /// the whole simulation — continues bit-identically.
    pub fn restore(events: Vec<Event>, next_seq: u64) -> Self {
        EventQueue {
            heap: events
                .into_iter()
                .map(|e| std::cmp::Reverse(PackedEvent::pack(e.time_s, e.seq, e.worker, e.kind)))
                .collect(),
            next_seq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_monotone() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now_s(), 0.0);
        c.advance_to(2.5);
        assert_eq!(c.now_s(), 2.5);
        c.advance_to(1.0); // never backwards
        assert_eq!(c.now_s(), 2.5);
    }

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, 0, EventKind::Arrive);
        q.push(1.0, 1, EventKind::Arrive);
        q.push(2.0, 2, EventKind::ComputeDone);
        let order: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|e| e.worker).collect();
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn equal_times_resolve_fifo() {
        let mut q = EventQueue::new();
        for w in [5usize, 3, 9, 1] {
            q.push(1.0, w, EventKind::Arrive);
        }
        let order: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|e| e.worker).collect();
        assert_eq!(order, vec![5, 3, 9, 1], "FIFO among ties");
    }

    #[test]
    fn snapshot_restore_preserves_order_and_ties() {
        let mut q = EventQueue::new();
        q.push(2.0, 0, EventKind::Arrive);
        q.push(1.0, 1, EventKind::ComputeDone);
        q.push(1.0, 2, EventKind::Arrive); // FIFO tie with worker 1's event
        let (events, next_seq) = q.snapshot();
        assert_eq!(next_seq, 3);
        assert_eq!(events.iter().map(|e| e.worker).collect::<Vec<_>>(), vec![1, 2, 0]);
        let mut restored = EventQueue::restore(events, next_seq);
        // a new push must sort after the restored tie at t = 1.0
        restored.push(1.0, 9, EventKind::Arrive);
        let order: Vec<usize> =
            std::iter::from_fn(|| restored.pop()).map(|e| e.worker).collect();
        assert_eq!(order, vec![1, 2, 9, 0]);
    }

    #[test]
    fn packed_event_is_16_bytes_and_round_trips() {
        assert_eq!(std::mem::size_of::<PackedEvent>(), 16);
        for &(seq, worker, kind) in &[
            (0u64, 0usize, EventKind::ComputeDone),
            (7, 1, EventKind::Arrive),
            (MAX_SEQ, MAX_WORKER, EventKind::Arrive),
            (12345, 999_999, EventKind::ComputeDone),
        ] {
            let e = PackedEvent::pack(1.25, seq, worker, kind).unpack();
            assert_eq!(e.time_s, 1.25);
            assert_eq!(e.seq, seq);
            assert_eq!(e.worker, worker);
            assert_eq!(e.kind, kind);
        }
    }

    #[test]
    #[should_panic(expected = "exceeds the 2^20 event-queue limit")]
    fn worker_id_beyond_packing_limit_panics() {
        let mut q = EventQueue::new();
        q.push(0.0, MAX_WORKER + 1, EventKind::Arrive);
    }

    #[test]
    fn snapshot_restore_round_trips_packed_events_exactly() {
        // Snapshot → restore → snapshot must reproduce the identical event
        // list (times bit-for-bit, seq/worker/kind exact) — the checkpoint
        // contract the virtual source's save/load relies on, independent of
        // the internal packed representation.
        let mut q = EventQueue::new();
        q.push(0.125, 999_999, EventKind::Arrive);
        q.push(0.125, 0, EventKind::ComputeDone);
        q.push(3.5e-9, 42, EventKind::Arrive);
        let (events, next_seq) = q.snapshot();
        let restored = EventQueue::restore(events.clone(), next_seq);
        let (events2, next_seq2) = restored.snapshot();
        assert_eq!(next_seq2, next_seq);
        assert_eq!(events2.len(), events.len());
        for (a, b) in events.iter().zip(&events2) {
            assert_eq!(a.time_s.to_bits(), b.time_s.to_bits());
            assert_eq!(a.seq, b.seq);
            assert_eq!(a.worker, b.worker);
            assert_eq!(a.kind, b.kind);
        }
        // and the restored queue keeps draining in (time, seq) order
        let mut restored = EventQueue::restore(events2, next_seq2);
        let order: Vec<usize> =
            std::iter::from_fn(|| restored.pop()).map(|e| e.worker).collect();
        assert_eq!(order, vec![42, 999_999, 0]);
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
        q.push(0.5, 0, EventKind::Arrive);
        q.push(0.25, 1, EventKind::Arrive);
        assert_eq!(q.len(), 2);
        assert_eq!(q.peek_time(), Some(0.25));
        assert_eq!(q.pop().unwrap().worker, 1);
        assert_eq!(q.peek_time(), Some(0.5));
    }
}
