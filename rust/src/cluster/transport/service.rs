//! The long-lived solver service: job specs, the per-job runner, and the
//! `admm-serve` control plane.
//!
//! A *job* is a fully deterministic description of a solve — synthetic
//! LASSO instance (seeded), algorithm, gate parameters, optional block
//! sharding, optional lockstep trace. Master and every worker process
//! rebuild the identical problem from the shared [`JobSpec`], so the only
//! bytes on the wire are protocol state, never data matrices.
//!
//! Control plane (`admm-serve`): a client connects, sends `submit` with a
//! spec; the service binds a fresh per-job rendezvous port, replies
//! `accepted {job, port}`, runs the job as a [`SocketSource`] session
//! (concurrent jobs each get their own port and thread, keyed by job id),
//! and finally sends `report` with iterations, stop reason, wall time,
//! wire-byte counters, realized outages and the FNV x₀ digest.
//!
//! [`run_reference`] replays the *same* spec through the in-process
//! [`TraceSource`](crate::admm::engine::TraceSource) — the loopback e2e CI
//! job asserts its digest is bit-identical to the socket run's.

use std::net::{TcpListener, TcpStream};

use crate::admm::arrivals::{ArrivalModel, ArrivalTrace};
use crate::admm::engine::{AltScheme, PartialBarrier};
use crate::admm::session::{EngineError, Session, SessionOutcome, StepStatus};
use crate::admm::AdmmConfig;
use crate::bench::json::{json_usize, JsonValue};
use crate::data::LassoInstance;
use crate::problems::{BlockPattern, ConsensusProblem};
use crate::rng::Pcg64;
use crate::solvers::inexact::InexactPolicy;
use crate::util::cli::ArgParser;
use crate::util::digest::x0_digest;

use super::super::multimaster::MasterGroup;
use super::frame::{write_frame, FrameReader};
use super::multisocket::MultiSocketSource;
use super::socket::{SocketSource, TransportConfig, TransportStats};
use super::wire::WireMsg;

/// Everything needed to rebuild one solve job deterministically in any
/// process — the `assign.spec`/`submit.spec` payload.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    pub job_id: String,
    pub workers: usize,
    pub m: usize,
    pub n: usize,
    pub seed: u64,
    pub rho: f64,
    pub gamma: f64,
    pub tau: usize,
    pub min_arrivals: usize,
    pub iters: usize,
    pub tol: f64,
    /// Block-sharded general-form consensus when > 0 (LASSO only).
    pub shard_blocks: usize,
    pub shard_owners: usize,
    /// Algorithm 4 (master-owned duals) instead of Algorithm 2.
    pub alt: bool,
    /// Prescribe the round-robin lockstep trace — deterministic runs,
    /// bit-comparable to trace replay.
    pub lockstep: bool,
    /// Injected per-worker compute delay spread (milliseconds).
    pub fast_ms: f64,
    pub slow_ms: f64,
    /// Master-side checkpoint cadence in iterations (0 = never).
    pub ckpt_every: usize,
    /// Worker subproblem inexactness (`exact`, `grad:K`, `proxgrad:K`,
    /// `newton:K`, `adaptive:TOL0:MAX`). Shipped in the assign frame, so
    /// every worker process honours the same policy as the master's
    /// reference replay — the loopback digest comparison stays exact.
    pub inexact: InexactPolicy,
    /// Heterogeneous per-worker policies overriding `inexact` (one entry
    /// per worker). Shipped in the assign frame like the uniform policy;
    /// worker `i` solves under entry `i` everywhere — trace replay,
    /// threads, virtual time, sockets — so mixed fleets stay
    /// bit-comparable across sources.
    pub inexact_workers: Option<Vec<InexactPolicy>>,
    /// Partition the coordinator across this many masters
    /// ([`MasterGroup::contiguous`] over `shard_blocks`; both sides
    /// derive the same group, so only the count rides the wire).
    /// Requires `shard_blocks > 0`, `lockstep` and the default
    /// (non-`alt`) algorithm; 1 = the classic star topology.
    pub masters: usize,
}

impl Default for JobSpec {
    fn default() -> Self {
        JobSpec {
            job_id: "job-0".to_string(),
            workers: 4,
            m: 60,
            n: 40,
            seed: 1,
            rho: 500.0,
            gamma: 0.0,
            tau: 3,
            min_arrivals: 1,
            iters: 60,
            tol: 0.0,
            shard_blocks: 0,
            shard_owners: 2,
            alt: false,
            lockstep: true,
            fast_ms: 0.0,
            slow_ms: 0.0,
            ckpt_every: 0,
            inexact: InexactPolicy::Exact,
            inexact_workers: None,
            masters: 1,
        }
    }
}

impl JobSpec {
    /// Build a spec from CLI flags (shared by `admm-serve submit` and the
    /// `ad-admm transport-digest` reference subcommand, so both sides of
    /// the CI digest comparison parse identically). A malformed policy
    /// spelling is a typed [`EngineError::Transport`] — specs also arrive
    /// over the wire from `submit` clients, and a bad one must fail that
    /// job, not abort the serve loop.
    pub fn from_args(args: &ArgParser) -> Result<Self, EngineError> {
        let d = JobSpec::default();
        Ok(JobSpec {
            job_id: args.get_or("job", &d.job_id),
            workers: args.get_parse_or("workers", d.workers),
            m: args.get_parse_or("m", d.m),
            n: args.get_parse_or("n", d.n),
            seed: args.get_parse_or("seed", d.seed),
            rho: args.get_parse_or("rho", d.rho),
            gamma: args.get_parse_or("gamma", d.gamma),
            tau: args.get_parse_or("tau", d.tau),
            min_arrivals: args.get_parse_or("min-arrivals", d.min_arrivals),
            iters: args.get_parse_or("iters", d.iters),
            tol: args.get_parse_or("tol", d.tol),
            shard_blocks: args.get_parse_or("shard-blocks", d.shard_blocks),
            shard_owners: args.get_parse_or("shard-owners", d.shard_owners),
            alt: args.has_flag("alt"),
            lockstep: !args.has_flag("free-running"),
            fast_ms: args.get_parse_or("fast-ms", d.fast_ms),
            slow_ms: args.get_parse_or("slow-ms", d.slow_ms),
            ckpt_every: args.get_parse_or("checkpoint-every", d.ckpt_every),
            inexact: match args.get("inexact") {
                None => d.inexact,
                Some(s) => InexactPolicy::parse(s)
                    .map_err(|e| EngineError::Transport(format!("--inexact: {e}")))?,
            },
            // Comma-joined per-worker spellings, e.g.
            // `--inexact-workers exact,grad:3,newton:2,exact`.
            inexact_workers: match args.get("inexact-workers") {
                None => None,
                Some(list) => Some(
                    list.split(',')
                        .map(|s| {
                            InexactPolicy::parse(s.trim()).map_err(|e| {
                                EngineError::Transport(format!("--inexact-workers: {e}"))
                            })
                        })
                        .collect::<Result<Vec<_>, EngineError>>()?,
                ),
            },
            masters: args.get_parse_or("masters", d.masters),
        })
    }

    pub fn to_json(&self) -> JsonValue {
        JsonValue::Obj(vec![
            ("job_id".to_string(), JsonValue::Str(self.job_id.clone())),
            ("workers".to_string(), self.workers.into()),
            ("m".to_string(), self.m.into()),
            ("n".to_string(), self.n.into()),
            // Full-range u64: as a string, like checkpoint meta seeds.
            ("seed".to_string(), JsonValue::Str(self.seed.to_string())),
            ("rho".to_string(), self.rho.into()),
            ("gamma".to_string(), self.gamma.into()),
            ("tau".to_string(), self.tau.into()),
            ("min_arrivals".to_string(), self.min_arrivals.into()),
            ("iters".to_string(), self.iters.into()),
            ("tol".to_string(), self.tol.into()),
            ("shard_blocks".to_string(), self.shard_blocks.into()),
            ("shard_owners".to_string(), self.shard_owners.into()),
            ("alt".to_string(), self.alt.into()),
            ("lockstep".to_string(), self.lockstep.into()),
            ("fast_ms".to_string(), self.fast_ms.into()),
            ("slow_ms".to_string(), self.slow_ms.into()),
            ("ckpt_every".to_string(), self.ckpt_every.into()),
            ("inexact".to_string(), self.inexact.to_json()),
            (
                "inexact_workers".to_string(),
                match &self.inexact_workers {
                    None => JsonValue::Null,
                    Some(v) => {
                        JsonValue::Arr(v.iter().map(InexactPolicy::to_json).collect())
                    }
                },
            ),
            ("masters".to_string(), self.masters.into()),
        ])
    }

    pub fn from_json(doc: &JsonValue) -> Result<Self, String> {
        let get = |key: &str| doc.get(key).ok_or_else(|| format!("job spec missing {key:?}"));
        let usize_of = |key: &str| get(key).and_then(json_usize);
        let f64_of = |key: &str| {
            get(key)?.as_f64().ok_or_else(|| format!("job spec field {key:?} is not a number"))
        };
        let bool_of = |key: &str| {
            get(key)?.as_bool().ok_or_else(|| format!("job spec field {key:?} is not a bool"))
        };
        let str_of = |key: &str| {
            get(key)?
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| format!("job spec field {key:?} is not a string"))
        };
        let spec = JobSpec {
            job_id: str_of("job_id")?,
            workers: usize_of("workers")?,
            m: usize_of("m")?,
            n: usize_of("n")?,
            seed: str_of("seed")?
                .parse()
                .map_err(|e| format!("job spec seed is not a u64: {e}"))?,
            rho: f64_of("rho")?,
            gamma: f64_of("gamma")?,
            tau: usize_of("tau")?,
            min_arrivals: usize_of("min_arrivals")?,
            iters: usize_of("iters")?,
            tol: f64_of("tol")?,
            shard_blocks: usize_of("shard_blocks")?,
            shard_owners: usize_of("shard_owners")?,
            alt: bool_of("alt")?,
            lockstep: bool_of("lockstep")?,
            fast_ms: f64_of("fast_ms")?,
            slow_ms: f64_of("slow_ms")?,
            ckpt_every: usize_of("ckpt_every")?,
            // Absent in specs from pre-inexact peers: default to the exact
            // (historical) solve so mixed-version fleets stay coherent.
            inexact: match doc.get("inexact") {
                None => InexactPolicy::Exact,
                Some(v) => InexactPolicy::from_json(v)?,
            },
            inexact_workers: match doc.get("inexact_workers") {
                None | Some(JsonValue::Null) => None,
                Some(arr) => Some(
                    arr.items()
                        .iter()
                        .map(InexactPolicy::from_json)
                        .collect::<Result<Vec<_>, String>>()?,
                ),
            },
            // Absent in specs from pre-multimaster peers: the classic
            // single-coordinator star.
            masters: match doc.get("masters") {
                None => 1,
                Some(v) => json_usize(v)?,
            },
        };
        if let Some(v) = &spec.inexact_workers {
            if v.len() != spec.workers {
                return Err(format!(
                    "inexact_workers has {} entries for {} workers",
                    v.len(),
                    spec.workers
                ));
            }
        }
        Ok(spec)
    }

    /// Rebuild the job's consensus problem — identical in every process
    /// that holds the same spec (seeded synthetic LASSO, optional
    /// round-robin block sharding).
    pub fn build_problem(&self) -> Result<ConsensusProblem, EngineError> {
        let mut rng = Pcg64::seed_from_u64(self.seed);
        let inst = LassoInstance::synthetic(&mut rng, self.workers, self.m, self.n, 0.05, 0.1);
        if self.shard_blocks > 0 {
            let pattern =
                BlockPattern::round_robin(self.n, self.shard_blocks, self.workers, self.shard_owners)
                    .map_err(EngineError::Block)?;
            inst.sharded_problem(&pattern).map_err(EngineError::Block)
        } else {
            Ok(inst.problem())
        }
    }

    fn admm_config(&self) -> AdmmConfig {
        AdmmConfig {
            rho: self.rho,
            gamma: self.gamma,
            tau: self.tau,
            min_arrivals: self.min_arrivals,
            max_iters: self.iters,
            x0_tol: self.tol,
            inexact: self.inexact,
            ..Default::default()
        }
    }

    /// The job's lockstep trace (when enabled): the round-robin
    /// alternation below, long enough for `iters` iterations.
    pub fn trace(&self) -> Option<ArrivalTrace> {
        self.lockstep.then(|| roundrobin_trace(self.workers, self.iters))
    }

    /// The derived block→master split for multi-master jobs (`None` when
    /// `masters <= 1`). Only the master *count* rides the wire — every
    /// process derives the same contiguous group from `(shard_blocks,
    /// masters)`, like the problem itself is derived from the seed.
    pub fn master_group(&self) -> Result<Option<MasterGroup>, EngineError> {
        if self.masters <= 1 {
            return Ok(None);
        }
        if self.shard_blocks == 0 || !self.lockstep || self.alt {
            return Err(EngineError::Masters(
                "multi-master jobs require shard-blocks > 0, lockstep and the default \
                 (non-alt) algorithm"
                    .to_string(),
            ));
        }
        MasterGroup::contiguous(self.shard_blocks, self.masters).map(Some)
    }
}

/// A deterministic partially-asynchronous arrival schedule: at iteration
/// `k`, workers with `(i + k) % 2 == 0` arrive (every worker arrives every
/// other iteration, so staleness stays ≤ 2 and any τ ≥ 3 gate is
/// satisfied). Empty sets — possible only for N = 1 — fall back to
/// `{k % N}`.
pub fn roundrobin_trace(n_workers: usize, iters: usize) -> ArrivalTrace {
    let sets = (0..iters)
        .map(|k| {
            let set: Vec<usize> = (0..n_workers).filter(|i| (i + k) % 2 == 0).collect();
            if set.is_empty() {
                vec![k % n_workers]
            } else {
                set
            }
        })
        .collect();
    ArrivalTrace { sets }
}

fn run_session_to_done<S: crate::admm::engine::WorkerSource>(
    session: &mut Session<'_, S>,
    ckpt_every: usize,
) -> Result<(), EngineError> {
    loop {
        match session.step()? {
            StepStatus::Iterated(_) => {
                let k = session.iteration();
                if ckpt_every > 0 && k % ckpt_every == 0 {
                    // Periodic master-side checkpoint: held messages and
                    // per-worker broadcast snapshots serialize; the
                    // document is kept by the caller of the service binary
                    // via --checkpoint-path (here we only exercise and
                    // validate the path).
                    session.checkpoint()?;
                }
            }
            StepStatus::Done(_) => return Ok(()),
        }
    }
}

/// Replay `spec` through the in-process trace-driven source. This is the
/// digest oracle for the loopback e2e: a socket run of the same lockstep
/// spec must produce a bit-identical x₀. Deliberately single-master
/// whatever `spec.masters` says — the M = 1 equivalence claim is that a
/// multi-master run matches exactly this replay.
pub fn run_reference(spec: &JobSpec) -> Result<(SessionOutcome, u64), EngineError> {
    let problem = spec.build_problem()?;
    let arrivals = match spec.trace() {
        Some(t) => ArrivalModel::Trace(t),
        None => ArrivalModel::Full,
    };
    let mut builder = Session::builder()
        .problem(&problem)
        .config(spec.admm_config())
        .arrivals(&arrivals)
        .residual_stopping(true);
    if let Some(policies) = &spec.inexact_workers {
        builder = builder.inexact_per_worker(policies.clone());
    }
    let mut session = if spec.alt {
        builder.policy(AltScheme { tau: spec.tau }).build()?
    } else {
        builder.policy(PartialBarrier { tau: spec.tau }).build()?
    };
    session.run_to_completion()?;
    let (outcome, _) = session.finish();
    let digest = x0_digest(&outcome.state.x0);
    Ok((outcome, digest))
}

/// One finished job's result — the `report.report` payload.
#[derive(Clone, Debug)]
pub struct JobReport {
    pub job_id: String,
    pub iterations: usize,
    pub stop: String,
    /// FNV-1a digest of the final x₀ bit patterns, 16 hex digits.
    pub digest: String,
    pub wall_clock_s: f64,
    pub master_wait_s: f64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// Per-master `(bytes_in, bytes_out)` split — one entry per
    /// coordinator, summing to the global counters (payloads partition
    /// exactly across masters; single-master runs report one entry).
    pub bytes_per_master: Vec<(u64, u64)>,
    /// Realized worker-disconnect windows `(worker, from, until)`.
    pub outages: Vec<(usize, usize, usize)>,
}

impl JobReport {
    pub fn to_json(&self) -> JsonValue {
        JsonValue::Obj(vec![
            ("job_id".to_string(), JsonValue::Str(self.job_id.clone())),
            ("iterations".to_string(), self.iterations.into()),
            ("stop".to_string(), JsonValue::Str(self.stop.clone())),
            ("digest".to_string(), JsonValue::Str(self.digest.clone())),
            ("wall_clock_s".to_string(), self.wall_clock_s.into()),
            ("master_wait_s".to_string(), self.master_wait_s.into()),
            ("bytes_in".to_string(), (self.bytes_in as usize).into()),
            ("bytes_out".to_string(), (self.bytes_out as usize).into()),
            (
                "bytes_per_master".to_string(),
                JsonValue::Arr(
                    self.bytes_per_master
                        .iter()
                        .map(|&(i, o)| {
                            JsonValue::Obj(vec![
                                ("in".to_string(), (i as usize).into()),
                                ("out".to_string(), (o as usize).into()),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "outages".to_string(),
                JsonValue::Arr(
                    self.outages
                        .iter()
                        .map(|&(w, f, u)| {
                            JsonValue::Obj(vec![
                                ("worker".to_string(), w.into()),
                                ("from".to_string(), f.into()),
                                ("until".to_string(), u.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Run one job as the master side of a [`SocketSource`] session on an
/// already-bound rendezvous listener. Blocks until the run stops.
pub fn run_job(listener: TcpListener, spec: &JobSpec) -> Result<JobReport, EngineError> {
    run_job_multi(vec![listener], spec)
}

/// Run one job across per-master rendezvous listeners — one per master of
/// the spec's derived [`MasterGroup`] (a single listener is the classic
/// single-coordinator path, byte-for-byte the old `run_job`). A
/// multi-master job runs a [`MultiSocketSource`] under one session with M
/// masked sparse masters; the digest is bit-identical to the
/// [`run_reference`] replay of the same spec. Blocks until the run stops.
pub fn run_job_multi(
    listeners: Vec<TcpListener>,
    spec: &JobSpec,
) -> Result<JobReport, EngineError> {
    let problem = spec.build_problem()?;
    let group = spec.master_group()?;
    let transport = TransportConfig {
        job_id: spec.job_id.clone(),
        assign_spec: spec.to_json(),
        lockstep: spec.trace(),
        // Multi-master endpoints ship pre-sliced parts; only the
        // single-master source derives owned slices from the pattern.
        shard: if group.is_some() { None } else { problem.pattern().cloned() },
        ..TransportConfig::default()
    };
    let mut builder = Session::builder()
        .problem(&problem)
        .config(spec.admm_config())
        .residual_stopping(true);
    if let Some(policies) = &spec.inexact_workers {
        builder = builder.inexact_per_worker(policies.clone());
    }
    let report = |outcome: &SessionOutcome,
                  stats: &TransportStats,
                  bytes_per_master: Vec<(u64, u64)>| JobReport {
        job_id: spec.job_id.clone(),
        iterations: outcome.iterations,
        stop: format!("{:?}", outcome.stop),
        digest: format!("{:016x}", x0_digest(&outcome.state.x0)),
        wall_clock_s: stats.wall_clock_s,
        master_wait_s: stats.master_wait_s,
        bytes_in: stats.bytes_in,
        bytes_out: stats.bytes_out,
        bytes_per_master,
        outages: stats.outages.iter().map(|o| (o.worker, o.from_iter, o.until_iter)).collect(),
    };
    match group {
        Some(group) => {
            let pattern = problem.pattern().cloned().ok_or_else(|| {
                EngineError::Masters("master_group requires shard_blocks > 0".to_string())
            })?;
            let source = MultiSocketSource::from_listeners(
                listeners,
                spec.workers,
                transport,
                pattern,
                &group,
            )?;
            let mut session = builder
                .policy(PartialBarrier { tau: spec.tau })
                .masters(group)
                .build_typed(source)?;
            // Per-master endpoint state does not checkpoint; multi-master
            // jobs run straight through (ckpt_every is ignored).
            run_session_to_done(&mut session, 0)?;
            let (outcome, source) = session.finish();
            let (stats, per) = source.finish();
            Ok(report(
                &outcome,
                &stats,
                per.iter().map(|s| (s.bytes_in, s.bytes_out)).collect(),
            ))
        }
        None => {
            if listeners.len() != 1 {
                return Err(EngineError::Masters(format!(
                    "{} listeners for a single-master job",
                    listeners.len()
                )));
            }
            let listener = listeners.into_iter().next().ok_or_else(|| {
                EngineError::Transport("no rendezvous listener for single-master job".to_string())
            })?;
            let source = SocketSource::from_listener(listener, spec.workers, transport)?;
            let mut session = if spec.alt {
                builder.policy(AltScheme { tau: spec.tau }).build_typed(source)?
            } else {
                builder.policy(PartialBarrier { tau: spec.tau }).build_typed(source)?
            };
            run_session_to_done(&mut session, spec.ckpt_every)?;
            let (outcome, source) = session.finish();
            let stats: TransportStats = source.finish();
            let split = vec![(stats.bytes_in, stats.bytes_out)];
            Ok(report(&outcome, &stats, split))
        }
    }
}

fn control_err(stream: &TcpStream, message: String) {
    let mut sink = stream;
    let _ = write_frame(&mut sink, &WireMsg::Error { message }.encode());
}

/// Comma-joined port list for the `accepted` log lines (a single port
/// prints exactly as before, so existing scripts keep parsing).
fn join_ports(ports: &[u16]) -> String {
    ports.iter().map(u16::to_string).collect::<Vec<_>>().join(",")
}

/// The `admm-serve` accept loop: each control connection submits one job;
/// jobs run concurrently (thread per job, rendezvous port per job) and the
/// report is sent back on the submitting connection. With `oneshot`, the
/// service exits after the first job completes — the CI e2e mode.
pub fn serve(listen: &str, oneshot: bool) -> Result<(), EngineError> {
    let control = TcpListener::bind(listen)
        .map_err(|e| EngineError::Transport(format!("cannot bind control {listen}: {e}")))?;
    let addr = control
        .local_addr()
        .map_err(|e| EngineError::Transport(format!("control addr: {e}")))?;
    println!("admm-serve listening on {addr}");
    let mut jobs: Vec<std::thread::JoinHandle<()>> = Vec::new();
    for conn in control.incoming() {
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        let mut reader = FrameReader::new();
        let payload = {
            let mut src = &stream;
            match reader.next_frame(&mut src) {
                Ok(Some(p)) => p,
                _ => continue,
            }
        };
        let spec = match WireMsg::decode(&payload) {
            Ok(WireMsg::Submit { spec }) => match JobSpec::from_json(&spec) {
                Ok(s) => s,
                Err(e) => {
                    control_err(&stream, format!("bad job spec: {e}"));
                    continue;
                }
            },
            Ok(other) => {
                control_err(&stream, format!("expected submit, got {other:?}"));
                continue;
            }
            Err(e) => {
                control_err(&stream, format!("bad frame: {e}"));
                continue;
            }
        };
        // One rendezvous listener per master (1 for the classic star).
        let rendezvous = {
            let mut listeners = Vec::with_capacity(spec.masters.max(1));
            let mut failed = None;
            for _ in 0..spec.masters.max(1) {
                match TcpListener::bind("127.0.0.1:0") {
                    Ok(l) => listeners.push(l),
                    Err(e) => {
                        failed = Some(e);
                        break;
                    }
                }
            }
            match failed {
                None => listeners,
                Some(e) => {
                    control_err(&stream, format!("cannot bind job port: {e}"));
                    continue;
                }
            }
        };
        let ports: Vec<u16> = rendezvous
            .iter()
            .map(|l| l.local_addr().map(|a| a.port()).unwrap_or(0))
            .collect();
        {
            let accepted =
                WireMsg::Accepted { job: spec.job_id.clone(), ports: ports.clone() };
            let mut sink = &stream;
            if write_frame(&mut sink, &accepted.encode()).is_err() {
                continue;
            }
        }
        println!(
            "job {} accepted: workers connect on 127.0.0.1:{}",
            spec.job_id,
            join_ports(&ports)
        );
        let job = move || match run_job_multi(rendezvous, &spec) {
            Ok(report) => {
                println!(
                    "job {} done: {} iterations, stop={}, {} outage(s), \
                     {} bytes in / {} bytes out",
                    report.job_id,
                    report.iterations,
                    report.stop,
                    report.outages.len(),
                    report.bytes_in,
                    report.bytes_out
                );
                println!("final x0 digest {}", report.digest);
                let msg =
                    WireMsg::Report { job: report.job_id.clone(), report: report.to_json() };
                let mut sink = &stream;
                let _ = write_frame(&mut sink, &msg.encode());
            }
            Err(e) => {
                eprintln!("job failed: {e}");
                control_err(&stream, format!("job failed: {e}"));
            }
        };
        if oneshot {
            job();
            return Ok(());
        }
        jobs.push(
            std::thread::Builder::new()
                .name("admm-serve-job".to_string())
                .spawn(job)
                .map_err(|e| EngineError::Transport(format!("cannot spawn job thread: {e}")))?,
        );
        jobs.retain(|h| !h.is_finished());
    }
    Ok(())
}

/// Submit `spec` to a running `admm-serve` and block for the report.
/// Prints the rendezvous port as soon as the job is accepted (scripts
/// parse it to launch workers) and the digest line when the job finishes.
pub fn submit(addr: &str, spec: &JobSpec) -> Result<JobReport, EngineError> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| EngineError::Transport(format!("cannot connect to {addr}: {e}")))?;
    {
        let mut sink = &stream;
        write_frame(&mut sink, &WireMsg::Submit { spec: spec.to_json() }.encode())
            .map_err(|e| EngineError::Transport(format!("submit write failed: {e}")))?;
    }
    let mut reader = FrameReader::new();
    let mut src = &stream;
    let next = |reader: &mut FrameReader, src: &mut &TcpStream| -> Result<WireMsg, EngineError> {
        let payload = reader
            .next_frame(src)
            .map_err(|e| EngineError::Transport(format!("control read failed: {e}")))?
            .ok_or_else(|| EngineError::Transport("serve closed the control link".to_string()))?;
        WireMsg::decode(&payload).map_err(EngineError::Transport)
    };
    match next(&mut reader, &mut src)? {
        WireMsg::Accepted { job, ports } => {
            println!("job {job} accepted: workers connect on 127.0.0.1:{}", join_ports(&ports));
        }
        WireMsg::Error { message } => {
            return Err(EngineError::Transport(format!("submit rejected: {message}")))
        }
        other => {
            return Err(EngineError::Transport(format!("expected accepted, got {other:?}")))
        }
    }
    match next(&mut reader, &mut src)? {
        WireMsg::Report { job, report } => {
            let field = |key: &str| report.get(key).cloned().unwrap_or(JsonValue::Null);
            let digest = field("digest").as_str().unwrap_or("").to_string();
            let out = JobReport {
                job_id: job,
                iterations: field("iterations").as_f64().unwrap_or(0.0) as usize,
                stop: field("stop").as_str().unwrap_or("").to_string(),
                digest: digest.clone(),
                wall_clock_s: field("wall_clock_s").as_f64().unwrap_or(0.0),
                master_wait_s: field("master_wait_s").as_f64().unwrap_or(0.0),
                bytes_in: field("bytes_in").as_f64().unwrap_or(0.0) as u64,
                bytes_out: field("bytes_out").as_f64().unwrap_or(0.0) as u64,
                bytes_per_master: field("bytes_per_master")
                    .items()
                    .iter()
                    .filter_map(|e| {
                        Some((e.get("in")?.as_f64()? as u64, e.get("out")?.as_f64()? as u64))
                    })
                    .collect(),
                outages: field("outages")
                    .items()
                    .iter()
                    .filter_map(|o| {
                        Some((
                            json_usize(o.get("worker")?).ok()?,
                            json_usize(o.get("from")?).ok()?,
                            json_usize(o.get("until")?).ok()?,
                        ))
                    })
                    .collect(),
            };
            println!(
                "job {} done: {} iterations, stop={}, {} outage(s)",
                out.job_id,
                out.iterations,
                out.stop,
                out.outages.len()
            );
            println!("final x0 digest {digest}");
            Ok(out)
        }
        WireMsg::Error { message } => {
            Err(EngineError::Transport(format!("job failed: {message}")))
        }
        other => Err(EngineError::Transport(format!("expected report, got {other:?}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_spec_round_trips_through_json() {
        let spec = JobSpec {
            job_id: "j-42".to_string(),
            seed: u64::MAX - 3, // > 2^53: must survive via the string path
            shard_blocks: 5,
            alt: true,
            inexact: InexactPolicy::GradSteps { k: 5 },
            ..JobSpec::default()
        };
        let back = JobSpec::from_json(&spec.to_json()).expect("round trip");
        assert_eq!(back, spec);
        // Multi-master + heterogeneous per-worker policies survive too.
        let multi = JobSpec {
            shard_blocks: 8,
            masters: 2,
            inexact_workers: Some(vec![
                InexactPolicy::Exact,
                InexactPolicy::GradSteps { k: 3 },
                InexactPolicy::NewtonSteps { k: 2 },
                InexactPolicy::Exact,
            ]),
            ..JobSpec::default()
        };
        let back = JobSpec::from_json(&multi.to_json()).expect("round trip");
        assert_eq!(back, multi);
        assert_eq!(back.master_group().unwrap().unwrap().num_masters(), 2);
    }

    /// Specs serialized before multi-master existed (no "masters" key)
    /// deserialize as the single-coordinator star, and a mis-sized
    /// per-worker policy list is rejected at parse time.
    #[test]
    fn job_spec_without_masters_field_defaults_to_single() {
        let spec = JobSpec::default();
        let JsonValue::Obj(fields) = spec.to_json() else { panic!("spec json is an object") };
        let stripped = JsonValue::Obj(
            fields
                .into_iter()
                .filter(|(k, _)| k != "masters" && k != "inexact_workers")
                .collect(),
        );
        let back = JobSpec::from_json(&stripped).expect("legacy spec parses");
        assert_eq!(back.masters, 1);
        assert_eq!(back.inexact_workers, None);
        assert_eq!(back, spec);
        let short = JobSpec {
            inexact_workers: Some(vec![InexactPolicy::Exact]), // 1 entry, 4 workers
            ..JobSpec::default()
        };
        assert!(JobSpec::from_json(&short.to_json()).is_err());
    }

    /// Multi-master jobs refuse the shapes the partitioned coordinator
    /// cannot drive (dense, free-running, Algorithm 4).
    #[test]
    fn multimaster_job_spec_validation() {
        let ok = JobSpec { shard_blocks: 6, masters: 3, ..JobSpec::default() };
        assert_eq!(ok.master_group().unwrap().unwrap().num_masters(), 3);
        let single = JobSpec::default();
        assert!(single.master_group().unwrap().is_none());
        let dense = JobSpec { masters: 2, ..JobSpec::default() };
        assert!(dense.master_group().is_err());
        let free = JobSpec { shard_blocks: 6, masters: 2, lockstep: false, ..JobSpec::default() };
        assert!(free.master_group().is_err());
        let alt = JobSpec { shard_blocks: 6, masters: 2, alt: true, ..JobSpec::default() };
        assert!(alt.master_group().is_err());
    }

    /// Specs serialized before the inexact field existed (no "inexact"
    /// key) deserialize to the exact policy — mixed-version fleets keep
    /// solving the historical subproblem.
    #[test]
    fn job_spec_without_inexact_field_defaults_to_exact() {
        let spec = JobSpec::default();
        let json = spec.to_json();
        let JsonValue::Obj(fields) = json else { panic!("spec json is an object") };
        let stripped =
            JsonValue::Obj(fields.into_iter().filter(|(k, _)| k != "inexact").collect());
        let back = JobSpec::from_json(&stripped).expect("legacy spec parses");
        assert_eq!(back.inexact, InexactPolicy::Exact);
        assert_eq!(back, spec);
    }

    #[test]
    fn roundrobin_trace_alternates_and_bounds_staleness() {
        let t = roundrobin_trace(4, 10);
        assert_eq!(t.sets.len(), 10);
        assert_eq!(t.sets[0], vec![0, 2]);
        assert_eq!(t.sets[1], vec![1, 3]);
        // Every worker arrives every other iteration: delay ≤ 2 ⇒ the
        // trace satisfies Assumption 1 for any τ ≥ 3.
        assert!(t.satisfies_bounded_delay(4, 3));
        // Degenerate single-worker case never produces an empty set.
        let one = roundrobin_trace(1, 5);
        assert!(one.sets.iter().all(|s| s == &vec![0]));
    }

    #[test]
    fn reference_run_is_reproducible() {
        let spec = JobSpec { iters: 12, ..JobSpec::default() };
        let (a, da) = run_reference(&spec).expect("run");
        let (b, db) = run_reference(&spec).expect("run");
        assert_eq!(da, db);
        let bits = |v: &[f64]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.state.x0), bits(&b.state.x0));
    }
}
