//! [`MultiSocketSource`] — per-master rendezvous listeners and
//! slice-multiplexed workers: the TCP realization of multi-master
//! partitioned coordination ([`crate::cluster::multimaster`]).
//!
//! Each of the `M` coordinators binds its own listener and runs a full
//! [`SocketSource`] endpoint — acceptor, claim table, per-connection
//! reader threads, reconnect re-delivery — restricted to its *fleet*:
//! the workers owning at least one of its blocks. A worker process opens
//! one socket per owning master (claiming the same global slot id on
//! every endpoint) and, per round, receives that master's part of its
//! owned slice and ships back exactly the part the master coordinates.
//!
//! **No layout metadata rides the wire.** Both endpoints derive the
//! slice split identically from `(pattern, group)` via
//! [`MasterGroup::worker_ranges`]; a `go`/`up` part payload is just the
//! concatenation of those runs, stitched back into the full owned slice
//! on arrival. Payload bytes therefore partition exactly across masters:
//! the per-master byte meters sum to the single-master totals.
//!
//! Multi-master transport runs are lockstep-only: the prescribed global
//! arrival sets project onto each endpoint (`S_k ∩ fleet_m`), every
//! endpoint waits for its projection each round, and the session above
//! runs one masked sparse master per coordinator
//! ([`crate::admm::session::SessionBuilder::masters`]) — which is
//! bit-identical to the single-master sparse engine on the same trace,
//! so an M = 2 loopback digest must equal the M = 1 in-process
//! reference. Disconnects remain per-endpoint Assumption-1 outages with
//! `go.reseed` re-delivery of the in-flight part.

use std::net::{SocketAddr, TcpListener};
use std::sync::Arc;

use crate::admm::arrivals::ArrivalTrace;
use crate::admm::engine::{ActiveSet, Gate, MasterView, UpdatePolicy, WorkerSource};
use crate::admm::session::EngineError;
use crate::admm::AdmmState;
use crate::problems::BlockPattern;
use crate::util::timer::{Clock, Stopwatch};

use super::super::multimaster::MasterGroup;
use super::socket::{SocketSource, TransportConfig, TransportStats};

/// Concatenate the `(offset, len)` runs of `src` (a worker's part payload
/// for one master). Shared with the worker-side client: both ends split
/// and stitch by the same derived ranges.
pub(crate) fn extract(src: &[f64], ranges: &[(usize, usize)]) -> Vec<f64> {
    let total = ranges.iter().map(|&(_, len)| len).sum();
    let mut out = Vec::with_capacity(total);
    for &(off, len) in ranges {
        out.extend_from_slice(&src[off..off + len]);
    }
    out
}

/// Stitch a part payload back into the full owned slice at its runs.
pub(crate) fn scatter(dst: &mut [f64], ranges: &[(usize, usize)], part: &[f64]) {
    let total: usize = ranges.iter().map(|&(_, len)| len).sum();
    assert_eq!(part.len(), total, "part payload does not match the derived slice split");
    let mut cur = 0;
    for &(off, len) in ranges {
        dst[off..off + len].copy_from_slice(&part[cur..cur + len]);
        cur += len;
    }
}

/// The multi-master TCP [`WorkerSource`]: one [`SocketSource`] endpoint
/// per coordinator, slice parts multiplexed across them. See the module
/// docs for the protocol.
pub struct MultiSocketSource {
    n_workers: usize,
    pattern: Arc<BlockPattern>,
    endpoints: Vec<SocketSource>,
    /// Per worker: `(master, slice runs)` for every owning master,
    /// ascending in master id — the wire layout both sides derive.
    parts: Vec<Vec<(usize, Vec<(usize, usize)>)>>,
    /// The *global* prescribed arrival sets and the replay cursor (each
    /// endpoint replays its own projection in step).
    lockstep: (Vec<Vec<usize>>, usize),
    wall: Stopwatch,
}

impl MultiSocketSource {
    /// Start accepting on `listeners` — one already-bound listener per
    /// master of `group`. Requires a lockstep trace in `cfg` (free-running
    /// multi-master gathers are a virtual-time-only feature) and a
    /// block-sharded `pattern` the group validates against.
    pub fn from_listeners(
        listeners: Vec<TcpListener>,
        n_workers: usize,
        cfg: TransportConfig,
        pattern: Arc<BlockPattern>,
        group: &MasterGroup,
    ) -> Result<Self, EngineError> {
        if listeners.len() != group.num_masters() {
            return Err(EngineError::Masters(format!(
                "{} listeners for {} masters",
                listeners.len(),
                group.num_masters()
            )));
        }
        if pattern.num_workers() != n_workers {
            return Err(EngineError::Masters(format!(
                "pattern has {} workers, transport expects {n_workers}",
                pattern.num_workers()
            )));
        }
        group.validate_against(&pattern)?;
        let trace = cfg.lockstep.clone().ok_or_else(|| {
            EngineError::Masters(
                "multi-master transport requires a lockstep trace".to_string(),
            )
        })?;
        let fleets = group.workers_of(&pattern);
        let parts: Vec<Vec<(usize, Vec<(usize, usize)>)>> = (0..n_workers)
            .map(|i| {
                group
                    .masters_of_worker(&pattern, i)
                    .into_iter()
                    .map(|m| (m, group.worker_ranges(&pattern, i, m)))
                    .collect()
            })
            .collect();
        let mut endpoints = Vec::with_capacity(listeners.len());
        for (m, listener) in listeners.into_iter().enumerate() {
            let mut mask = vec![false; n_workers];
            for &i in &fleets[m] {
                mask[i] = true;
            }
            let projected = ArrivalTrace {
                sets: trace
                    .sets
                    .iter()
                    .map(|s| s.iter().copied().filter(|&i| mask[i]).collect())
                    .collect(),
            };
            let ep_cfg = TransportConfig {
                lockstep: Some(projected),
                // Parts are pre-sliced here; the endpoint must not re-derive
                // owned slices from a pattern it does not have.
                shard: None,
                expected: Some(mask),
                ..cfg.clone()
            };
            endpoints.push(SocketSource::from_listener(listener, n_workers, ep_cfg)?);
        }
        Ok(MultiSocketSource {
            n_workers,
            pattern,
            endpoints,
            parts,
            lockstep: (trace.sets, 0),
            wall: Stopwatch::start(),
        })
    }

    /// The bound per-master rendezvous addresses, in master order (query
    /// after binding port 0).
    pub fn local_addrs(&self) -> Vec<SocketAddr> {
        self.endpoints.iter().map(SocketSource::local_addr).collect()
    }

    /// Ship worker `i` its per-master `go` parts: each owning endpoint
    /// gets the runs of `x₀` it coordinates plus the matching dual runs
    /// (snapshotted endpoint-side for reconnect re-delivery).
    fn send_parts(&mut self, i: usize, state: &AdmmState, with_dual: bool) {
        let x0_owned = self.pattern.gather_vec(i, &state.x0);
        for (m, ranges) in &self.parts[i] {
            let px0 = extract(&x0_owned, ranges);
            let plam = with_dual.then(|| extract(&state.lams[i], ranges));
            let pstate = extract(&state.lams[i], ranges);
            self.endpoints[*m].send_part(i, px0, plam, pstate);
        }
    }

    /// Shutdown every endpoint; returns the aggregate stats plus the
    /// per-master split (payloads partition across masters, so the
    /// per-master byte meters sum to the aggregate).
    pub fn finish(self) -> (TransportStats, Vec<TransportStats>) {
        let wall_clock_s = self.wall.now_s();
        let per: Vec<TransportStats> =
            self.endpoints.into_iter().map(SocketSource::finish).collect();
        let agg = TransportStats {
            outages: per.iter().flat_map(|s| s.outages.iter().cloned()).collect(),
            bytes_in: per.iter().map(|s| s.bytes_in).sum(),
            bytes_out: per.iter().map(|s| s.bytes_out).sum(),
            wall_clock_s,
            master_wait_s: per.iter().map(|s| s.master_wait_s).sum(),
        };
        (agg, per)
    }
}

impl WorkerSource for MultiSocketSource {
    fn n_workers(&self) -> usize {
        self.n_workers
    }

    fn kind(&self) -> &'static str {
        "multisocket"
    }

    fn supports_sharding(&self) -> bool {
        true
    }

    fn start(&mut self, state: &AdmmState, policy: &dyn UpdatePolicy) {
        // Every endpoint assembles its fleet before the initial parts go
        // out (workers dial every owning master, so no roster can starve
        // another's).
        for ep in &mut self.endpoints {
            ep.wait_for_workers();
            ep.mark_started();
        }
        let with_dual = policy.broadcasts_dual();
        for i in 0..self.n_workers {
            self.send_parts(i, state, with_dual);
        }
    }

    fn gather(&mut self, k: usize, d: &[usize], gate: &Gate<'_>) -> ActiveSet {
        // One global round = every master's projected gather: endpoint m
        // blocks until S_k ∩ fleet_m is fully pending (through
        // disconnects, as in the single-master lockstep path). The
        // per-endpoint cursors advance in step with the global one.
        let prescribed = {
            let (sets, pos) = &mut self.lockstep;
            let s = sets
                .get(*pos)
                .unwrap_or_else(|| {
                    // ad-lint: allow(panic-free-lib): documented contract: lockstep callers supply one set per iteration
                    panic!("lockstep trace exhausted at iteration {pos}", pos = *pos)
                })
                .clone();
            *pos += 1;
            s
        };
        for ep in &mut self.endpoints {
            let _ = ep.gather(k, d, gate);
        }
        let live: Vec<usize> = prescribed.into_iter().filter(|&i| !gate.down[i]).collect();
        // ad-lint: allow(panic-free-lib): documented panic contract on malformed caller-supplied lockstep traces
        ActiveSet::new(live, self.n_workers).expect("lockstep trace worker index out of range")
    }

    fn absorb(&mut self, set: &ActiveSet, view: &mut MasterView<'_>, _policy: &dyn UpdatePolicy) {
        // Stitch each arrived worker's part payloads — ascending master
        // order, the same derived layout the worker split by — back into
        // the full owned slice, then refresh f_i once per worker.
        let parts = &self.parts;
        let endpoints = &mut self.endpoints;
        for &i in set {
            for (m, ranges) in &parts[i] {
                let msg = endpoints[*m]
                    .take_pending(i)
                    // ad-lint: allow(panic-free-lib): gather() only returns workers fully arrived at every owning master
                    .expect("every owning master holds the arrived worker's part");
                scatter(&mut view.state.xs[i], ranges, &msg.x);
                if let Some(lam) = msg.lam {
                    scatter(&mut view.state.lams[i], ranges, &lam);
                }
            }
            view.f_cache[i] =
                view.problem.local(i).eval_with(&view.state.xs[i], &mut view.scratch.ws);
        }
    }

    fn broadcast(&mut self, set: &ActiveSet, state: &AdmmState, policy: &dyn UpdatePolicy) {
        let with_dual = policy.broadcasts_dual();
        for &i in set {
            self.send_parts(i, state, with_dual);
        }
    }
}
