//! [`SocketSource`] — the real-network [`WorkerSource`]: the
//! `ThreadedSource` star protocol spoken over TCP.
//!
//! One acceptor thread owns the listener; each accepted connection
//! handshakes (`hello` → `assign`), then gets a dedicated reader thread
//! that decodes [`WireMsg::Up`] frames into the same shared event channel
//! the in-process source uses, so the master's gather/pending logic is
//! identical across transports. The master writes `go`/`shutdown` frames
//! directly on its per-worker stream handles.
//!
//! ## Disconnects are Assumption-1 outages
//!
//! The paper's bounded-delay Assumption 1 says every worker's update is at
//! most τ master iterations stale. A worker process that drops its TCP
//! connection is exactly the `FaultPlan` outage model realized by a real
//! network: its slot is treated as down at the gate — it neither counts
//! toward `|A_k| ≥ A` nor blocks the forced-τ wait — and the iteration
//! window of the disconnect is recorded as a realized
//! [`Outage`](crate::admm::engine::Outage) (see
//! [`SocketSource::realized_outages`]). On reconnect the master re-delivers
//! the worker's last broadcast together with its worker-held dual λ_i
//! (`go.reseed`), so the restarted process recomputes the in-flight round
//! from exactly the state the dead one held — the re-entry-with-stale-
//! iterate semantics of the threaded mode's held-`pending` outages, and
//! the reason lockstep runs stay bit-identical across a kill + restart.
//! An outage outlasting τ iterations violates Assumption 1, as it would
//! under any source; the τ gate simply stops forcing waits on a worker
//! that cannot answer.
//!
//! Under a `lockstep_trace` the master instead *waits* for every
//! prescribed worker — through disconnects, until a replacement process
//! rejoins — which keeps loopback runs deterministic and bit-comparable
//! to [`TraceSource`](crate::admm::engine::TraceSource) replay.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::admm::arrivals::ArrivalTrace;
use crate::admm::engine::{ActiveSet, Gate, MasterView, Outage, UpdatePolicy, WorkerSource};
use crate::admm::session::EngineError;
use crate::admm::AdmmState;
use crate::bench::json::{hex_vec, json_usize, vec_from_hex, JsonValue};
use crate::problems::BlockPattern;
use crate::util::timer::{Clock, Stopwatch};

use super::super::messages::WorkerMsg;
use super::frame::{write_frame, FrameEvent, FrameReader, MAX_FRAME_LEN};
use super::wire::WireMsg;

/// Transport knobs for a [`SocketSource`] (and the per-connection
/// timeouts it applies to every accepted stream).
#[derive(Clone, Debug)]
pub struct TransportConfig {
    /// Job identifier workers must present in their `hello`.
    pub job_id: String,
    /// Opaque job object sent to each worker in `assign` — everything a
    /// worker needs to rebuild its local problem deterministically.
    pub assign_spec: JsonValue,
    /// Replay exactly these arrival sets (deterministic loopback runs,
    /// bit-comparable to trace replay). `None` gathers at the live gate.
    pub lockstep: Option<ArrivalTrace>,
    /// Block-sharding pattern (from the problem; `None` = dense):
    /// broadcasts carry owned slices, like the other sources.
    pub shard: Option<Arc<BlockPattern>>,
    /// Reader-thread poll interval: how long a blocking read waits before
    /// re-checking the shutdown flag. Not a liveness bound on workers.
    pub read_timeout: Duration,
    /// Per-connection write timeout for master → worker frames; an
    /// expired write marks the worker disconnected (outage) rather than
    /// wedging the master.
    pub write_timeout: Duration,
    /// Handshake deadline: a connection that sends no valid `hello`
    /// within this window is dropped.
    pub hello_timeout: Duration,
    /// Frame-payload bound for every connection (see
    /// [`MAX_FRAME_LEN`]).
    pub max_frame: usize,
    /// Which worker slots this endpoint actually expects to connect
    /// (`None` = all of them). A multi-master endpoint serves only its
    /// own fleet — the workers owning at least one of its blocks — so
    /// its roster wait must not block on slots that will never dial in.
    pub expected: Option<Vec<bool>>,
}

impl Default for TransportConfig {
    fn default() -> Self {
        TransportConfig {
            job_id: "default".to_string(),
            assign_spec: JsonValue::Null,
            lockstep: None,
            shard: None,
            read_timeout: Duration::from_millis(200),
            write_timeout: Duration::from_secs(30),
            hello_timeout: Duration::from_secs(10),
            max_frame: MAX_FRAME_LEN,
            expected: None,
        }
    }
}

/// What [`SocketSource::finish`] returns: realized disconnect windows and
/// wire accounting for the per-job report.
#[derive(Clone, Debug, Default)]
pub struct TransportStats {
    /// Disconnect windows in master iterations, [`Outage`]-shaped (a
    /// window still open at shutdown is closed at the final iteration).
    pub outages: Vec<Outage>,
    /// Worker→master bytes received (frames incl. headers).
    pub bytes_in: u64,
    /// Master→worker bytes sent (frames incl. headers).
    pub bytes_out: u64,
    /// Wall-clock seconds from bind to finish.
    pub wall_clock_s: f64,
    /// Seconds the master spent blocked in gather.
    pub master_wait_s: f64,
}

/// The last broadcast a worker received — re-delivered (with the
/// worker-held dual) when that worker reconnects.
#[derive(Clone, Debug)]
pub(crate) struct LastGo {
    pub(crate) x0: Vec<f64>,
    /// Master-supplied dual (Algorithm 4 broadcasts).
    pub(crate) lam: Option<Vec<f64>>,
    /// The worker-held dual λ_i at broadcast time (= the value the worker
    /// computes this round against) — the `go.reseed` payload.
    pub(crate) lam_state: Vec<f64>,
}

enum Event {
    Up(WorkerMsg),
    Joined { worker: usize, gen: u64, stream: TcpStream },
    Left { worker: usize, gen: u64 },
}

/// Worker-slot claims shared with the acceptor thread.
struct ClaimTable {
    claimed: Vec<bool>,
    gens: Vec<u64>,
}

/// The socket-backed [`WorkerSource`]. See the module docs for the
/// protocol and the disconnect/Assumption-1 semantics.
pub struct SocketSource {
    n_workers: usize,
    cfg: TransportConfig,
    listen_addr: SocketAddr,
    events: Receiver<Event>,
    writers: Vec<Option<TcpStream>>,
    gen: Vec<u64>,
    connected: Vec<bool>,
    /// One held message per worker (arrived but not yet absorbed).
    pending: Vec<Option<WorkerMsg>>,
    /// Prescribed arrival sets (lockstep replay) and the replay cursor.
    lockstep: Option<(Vec<Vec<usize>>, usize)>,
    shard: Option<Arc<BlockPattern>>,
    last_go: Vec<Option<LastGo>>,
    realized: Vec<Outage>,
    open_outage: Vec<Option<usize>>,
    iter: usize,
    started: bool,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    bytes_in: Arc<AtomicU64>,
    bytes_out: u64,
    wall: Stopwatch,
    master_wait_s: f64,
}

impl SocketSource {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral loopback port)
    /// and start accepting worker connections for `n_workers` slots.
    pub fn bind(addr: &str, n_workers: usize, cfg: TransportConfig) -> Result<Self, EngineError> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| EngineError::Transport(format!("cannot bind {addr}: {e}")))?;
        Self::from_listener(listener, n_workers, cfg)
    }

    /// Start accepting on an already-bound listener (the solver service
    /// binds per-job rendezvous ports itself).
    pub fn from_listener(
        listener: TcpListener,
        n_workers: usize,
        cfg: TransportConfig,
    ) -> Result<Self, EngineError> {
        if n_workers == 0 {
            return Err(EngineError::Transport("n_workers must be >= 1".to_string()));
        }
        let listen_addr = listener
            .local_addr()
            .map_err(|e| EngineError::Transport(format!("listener has no local addr: {e}")))?;
        let (tx, events) = std::sync::mpsc::channel::<Event>();
        let stop = Arc::new(AtomicBool::new(false));
        let bytes_in = Arc::new(AtomicU64::new(0));
        let claims = Arc::new(Mutex::new(ClaimTable {
            claimed: vec![false; n_workers],
            gens: vec![0; n_workers],
        }));
        let acceptor = {
            let cfg = cfg.clone();
            let stop = Arc::clone(&stop);
            let bytes_in = Arc::clone(&bytes_in);
            std::thread::Builder::new()
                .name("socket-acceptor".to_string())
                .spawn(move || accept_loop(listener, n_workers, cfg, claims, tx, stop, bytes_in))
                .map_err(|e| EngineError::Transport(format!("cannot spawn acceptor: {e}")))?
        };
        Ok(SocketSource {
            n_workers,
            listen_addr,
            events,
            writers: (0..n_workers).map(|_| None).collect(),
            gen: vec![0; n_workers],
            connected: vec![false; n_workers],
            pending: (0..n_workers).map(|_| None).collect(),
            lockstep: cfg.lockstep.as_ref().map(|t| (t.sets.clone(), 0)),
            shard: cfg.shard.clone(),
            last_go: (0..n_workers).map(|_| None).collect(),
            realized: Vec::new(),
            open_outage: vec![None; n_workers],
            iter: 0,
            started: false,
            stop,
            acceptor: Some(acceptor),
            bytes_in,
            bytes_out: 0,
            wall: Stopwatch::start(),
            master_wait_s: 0.0,
            cfg,
        })
    }

    /// The bound address workers connect to (query this after binding
    /// port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.listen_addr
    }

    /// Disconnect windows realized so far (closed windows only; an
    /// in-progress outage is closed by [`SocketSource::finish`]).
    pub fn realized_outages(&self) -> &[Outage] {
        &self.realized
    }

    /// Block until every expected worker slot has connected and handshaked
    /// (used by callers that want a full roster before building the
    /// session; [`WorkerSource::start`] also waits on its own). With a
    /// [`TransportConfig::expected`] mask, only the masked slots — this
    /// endpoint's fleet — are waited for.
    pub fn wait_for_workers(&mut self) {
        let missing = |src: &Self| {
            src.connected.iter().enumerate().any(|(i, &c)| {
                !c && src.cfg.expected.as_ref().map_or(true, |e| e[i])
            })
        };
        while missing(self) {
            // ad-lint: allow(panic-free-lib): the acceptor thread lives for the source's lifetime; a closed channel means it panicked
            let ev = self.events.recv().expect("acceptor alive while waiting for workers");
            self.handle_event(ev);
        }
    }

    /// Take worker `i`'s held (arrived, unabsorbed) message, if any. The
    /// multi-master wrapper stitches per-endpoint part payloads itself
    /// instead of going through [`WorkerSource::absorb`].
    pub(crate) fn take_pending(&mut self, worker: usize) -> Option<WorkerMsg> {
        self.pending[worker].take()
    }

    /// Send worker `i` an explicit part payload (a multi-master endpoint
    /// ships only the slice runs of the blocks it owns, so the broadcast
    /// cannot be derived from the full state here). The payload is
    /// snapshotted for reconnect re-delivery exactly like a full `go`.
    pub(crate) fn send_part(
        &mut self,
        worker: usize,
        x0: Vec<f64>,
        lam: Option<Vec<f64>>,
        lam_state: Vec<f64>,
    ) {
        let lg = LastGo { x0, lam, lam_state };
        self.last_go[worker] = Some(lg.clone());
        self.send_go(worker, &lg, false);
    }

    /// Arm reconnect re-delivery without going through
    /// [`WorkerSource::start`] (whose broadcast layout a multi-master
    /// wrapper replaces with per-endpoint parts).
    pub(crate) fn mark_started(&mut self) {
        self.started = true;
    }

    /// Shutdown: `shutdown` frames to every live worker, stop the
    /// acceptor, return the realized-outage and wire accounting.
    pub fn finish(mut self) -> TransportStats {
        self.shutdown_internal();
        let mut outages = std::mem::take(&mut self.realized);
        for (worker, open) in self.open_outage.iter_mut().enumerate() {
            if let Some(from) = open.take() {
                outages.push(Outage { worker, from_iter: from, until_iter: self.iter + 1 });
            }
        }
        TransportStats {
            outages,
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out,
            wall_clock_s: self.wall.now_s(),
            master_wait_s: self.master_wait_s,
        }
    }

    fn shutdown_internal(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return; // already shut down
        }
        let payload = WireMsg::Shutdown.encode();
        for w in self.writers.iter_mut() {
            if let Some(stream) = w.take() {
                let mut sink = &stream;
                let _ = write_frame(&mut sink, &payload);
                self.bytes_out += payload.len() as u64 + 4;
            }
        }
        // Wake the acceptor out of accept() so it can observe the flag.
        let _ = TcpStream::connect(self.listen_addr);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        // Reader threads exit on peer close / poll timeout + stop flag.
    }

    fn handle_event(&mut self, ev: Event) {
        match ev {
            Event::Up(msg) => {
                if msg.id < self.n_workers {
                    self.pending[msg.id] = Some(msg);
                }
            }
            Event::Joined { worker, gen, stream } => {
                self.gen[worker] = gen;
                let was_connected = self.connected[worker];
                self.writers[worker] = Some(stream);
                self.connected[worker] = true;
                if !was_connected {
                    if let Some(from) = self.open_outage[worker].take() {
                        let until = self.iter.max(from + 1);
                        self.realized.push(Outage { worker, from_iter: from, until_iter: until });
                    }
                }
                // Mid-run (re)join: re-deliver the last broadcast with the
                // worker-held dual so the process recomputes the in-flight
                // round bit-identically. Safe even when a replacement races
                // a not-yet-detected dead connection: the recomputation is
                // deterministic, so a duplicate `up` carries identical bits.
                if self.started {
                    if let Some(lg) = self.last_go[worker].clone() {
                        self.send_go(worker, &lg, true);
                    }
                }
            }
            Event::Left { worker, gen } => {
                // Stale Left events from a replaced connection are ignored.
                if gen == self.gen[worker] && self.connected[worker] {
                    self.mark_disconnected(worker);
                }
            }
        }
    }

    fn mark_disconnected(&mut self, worker: usize) {
        self.connected[worker] = false;
        self.writers[worker] = None;
        if self.open_outage[worker].is_none() {
            self.open_outage[worker] = Some(self.iter);
        }
    }

    fn send_go(&mut self, worker: usize, lg: &LastGo, reseed: bool) {
        let msg = WireMsg::Go {
            x0: lg.x0.clone(),
            lam: lg.lam.clone(),
            reseed: reseed.then(|| lg.lam_state.clone()),
        };
        let payload = msg.encode();
        let ok = match &self.writers[worker] {
            Some(stream) => {
                let mut sink = stream;
                write_frame(&mut sink, &payload).is_ok()
            }
            None => false,
        };
        if ok {
            self.bytes_out += payload.len() as u64 + 4;
        } else if self.connected[worker] {
            // A failed/timed-out write is a disconnect: the worker gets
            // this broadcast re-delivered (with reseed) when it rejoins.
            self.mark_disconnected(worker);
        }
    }

    fn drain_events(&mut self) {
        while let Ok(ev) = self.events.try_recv() {
            self.handle_event(ev);
        }
    }

    fn recv_blocking(&mut self) {
        // ad-lint: allow(panic-free-lib): the acceptor thread lives for the source's lifetime; a closed channel means it panicked
        let ev = self.events.recv().expect("acceptor alive");
        self.handle_event(ev);
    }
}

impl Drop for SocketSource {
    fn drop(&mut self) {
        self.shutdown_internal();
    }
}

impl WorkerSource for SocketSource {
    fn n_workers(&self) -> usize {
        self.n_workers
    }

    fn kind(&self) -> &'static str {
        "socket"
    }

    fn supports_sharding(&self) -> bool {
        self.shard.is_some()
    }

    fn start(&mut self, state: &AdmmState, policy: &dyn UpdatePolicy) {
        // Full roster before the initial broadcast: everyone starts
        // computing against x⁰ (owned slices when sharded).
        self.wait_for_workers();
        let with_dual = policy.broadcasts_dual();
        for i in 0..self.n_workers {
            let x0 = match &self.shard {
                None => state.x0.clone(),
                Some(p) => p.gather_vec(i, &state.x0),
            };
            let lg = LastGo {
                x0,
                lam: with_dual.then(|| state.lams[i].clone()),
                lam_state: state.lams[i].clone(),
            };
            self.last_go[i] = Some(lg.clone());
            self.send_go(i, &lg, false);
        }
        self.started = true;
    }

    fn gather(&mut self, k: usize, d: &[usize], gate: &Gate<'_>) -> ActiveSet {
        self.iter = k;
        let n = self.n_workers;
        let wait_started = self.wall.now_s();
        let set = if self.lockstep.is_some() {
            // Lockstep replay: wait until every live prescribed worker has
            // a message in — through disconnects, until a replacement
            // rejoins and recomputes. Deterministic by design.
            let prescribed = {
                // ad-lint: allow(panic-free-lib): guarded by the lockstep.is_some() branch above
                let (sets, pos) = self.lockstep.as_mut().expect("checked above");
                let s = sets
                    .get(*pos)
                    .unwrap_or_else(|| {
                        // ad-lint: allow(panic-free-lib): documented contract: lockstep callers supply one set per iteration
                        panic!("lockstep trace exhausted at iteration {pos}", pos = *pos)
                    })
                    .clone();
                *pos += 1;
                s
            };
            loop {
                self.drain_events();
                if prescribed.iter().all(|&i| gate.down[i] || self.pending[i].is_some()) {
                    break;
                }
                self.recv_blocking();
            }
            let live: Vec<usize> = prescribed.into_iter().filter(|&i| !gate.down[i]).collect();
            // ad-lint: allow(panic-free-lib): documented panic contract on malformed caller-supplied lockstep traces
            ActiveSet::new(live, n).expect("lockstep trace worker index out of range")
        } else {
            // Live gate: |A_k| ≥ min(A, #live) and every live connected
            // worker with d_i ≥ τ−1 has arrived. Down workers (fault plan)
            // and disconnected workers (realized outages) neither count
            // nor block — the τ gate cannot force a wait on a worker that
            // cannot answer.
            loop {
                self.drain_events();
                let arrived = (0..n)
                    .filter(|&i| self.pending[i].is_some() && !gate.down[i])
                    .count();
                let live = (0..n)
                    .filter(|&i| !gate.down[i] && (self.connected[i] || self.pending[i].is_some()))
                    .count();
                let target = gate.min_arrivals.min(live.max(1));
                let forced_ok = (0..n).all(|i| {
                    gate.down[i]
                        || !self.connected[i]
                        || d[i] + 1 < gate.tau
                        || self.pending[i].is_some()
                });
                if arrived >= target && forced_ok {
                    break;
                }
                self.recv_blocking();
            }
            ActiveSet::from_sorted(
                (0..n).filter(|&i| self.pending[i].is_some() && !gate.down[i]).collect(),
            )
        };
        self.master_wait_s += self.wall.now_s() - wait_started;
        set
    }

    fn absorb(&mut self, set: &ActiveSet, m: &mut MasterView<'_>, _policy: &dyn UpdatePolicy) {
        // (9)/(10)/(44): identical to the threaded source — the transport
        // changes, the protocol does not.
        for &i in set {
            // ad-lint: allow(panic-free-lib): gather() only returns workers whose message is pending
            let msg = self.pending[i].take().expect("arrived worker has a pending message");
            m.state.xs[i] = msg.x;
            if let Some(lam) = msg.lam {
                m.state.lams[i] = lam;
            }
            m.f_cache[i] = m.problem.local(i).eval_with(&m.state.xs[i], &mut m.scratch.ws);
        }
    }

    fn broadcast(&mut self, set: &ActiveSet, state: &AdmmState, policy: &dyn UpdatePolicy) {
        // Step 6: broadcast to arrived workers only (owned slices when
        // sharded). The broadcast is also snapshotted per worker for
        // reconnect re-delivery.
        let with_dual = policy.broadcasts_dual();
        for &i in set {
            let x0 = match &self.shard {
                None => state.x0.clone(),
                Some(p) => p.gather_vec(i, &state.x0),
            };
            let lg = LastGo {
                x0,
                lam: with_dual.then(|| state.lams[i].clone()),
                lam_state: state.lams[i].clone(),
            };
            self.last_go[i] = Some(lg.clone());
            self.send_go(i, &lg, false);
        }
    }

    fn save_checkpoint(&self) -> Result<JsonValue, EngineError> {
        // Master-side protocol state only: held messages, the lockstep
        // cursor, per-worker broadcast snapshots and realized outages.
        // Worker processes are external — on resume they reconnect and are
        // re-sent their snapshot (`go.reseed`), recomputing any in-flight
        // round. Messages still in flight at save time are therefore
        // recovered, not lost.
        let opt_vec = |v: &Option<Vec<f64>>| match v {
            Some(v) => hex_vec(v),
            None => JsonValue::Null,
        };
        let pending = JsonValue::Arr(
            self.pending
                .iter()
                .map(|p| match p {
                    None => JsonValue::Null,
                    Some(msg) => JsonValue::Obj(vec![
                        ("x".to_string(), hex_vec(&msg.x)),
                        ("lam".to_string(), opt_vec(&msg.lam)),
                    ]),
                })
                .collect(),
        );
        let last_go = JsonValue::Arr(
            self.last_go
                .iter()
                .map(|lg| match lg {
                    None => JsonValue::Null,
                    Some(lg) => JsonValue::Obj(vec![
                        ("x0".to_string(), hex_vec(&lg.x0)),
                        ("lam".to_string(), opt_vec(&lg.lam)),
                        ("lam_state".to_string(), hex_vec(&lg.lam_state)),
                    ]),
                })
                .collect(),
        );
        let outages = JsonValue::Arr(
            self.realized
                .iter()
                .map(|o| {
                    JsonValue::Obj(vec![
                        ("worker".to_string(), o.worker.into()),
                        ("from".to_string(), o.from_iter.into()),
                        ("until".to_string(), o.until_iter.into()),
                    ])
                })
                .collect(),
        );
        Ok(JsonValue::Obj(vec![
            ("iter".to_string(), self.iter.into()),
            (
                "cursor".to_string(),
                self.lockstep.as_ref().map_or(JsonValue::Null, |(_, pos)| (*pos).into()),
            ),
            ("pending".to_string(), pending),
            ("last_go".to_string(), last_go),
            ("outages".to_string(), outages),
        ]))
    }

    fn load_checkpoint(&mut self, doc: &JsonValue) -> Result<(), EngineError> {
        let bad = |msg: String| EngineError::Checkpoint(format!("socket source: {msg}"));
        let field = |key: &str| doc.get(key).ok_or_else(|| bad(format!("missing {key:?}")));
        self.iter = json_usize(field("iter")?).map_err(bad)?;
        match (field("cursor")?, &mut self.lockstep) {
            (JsonValue::Null, None) => {}
            (v, Some((_, pos))) => *pos = json_usize(v).map_err(bad)?,
            _ => return Err(bad("lockstep cursor does not match the configured trace".into())),
        }
        let opt_vec = |v: Option<&JsonValue>| -> Result<Option<Vec<f64>>, String> {
            match v {
                None | Some(JsonValue::Null) => Ok(None),
                Some(v) => Ok(Some(vec_from_hex(v)?)),
            }
        };
        let pending = field("pending")?.items();
        if pending.len() != self.n_workers {
            return Err(bad(format!("pending has {} slots", pending.len())));
        }
        for (i, p) in pending.iter().enumerate() {
            self.pending[i] = match p {
                JsonValue::Null => None,
                obj => Some(WorkerMsg {
                    id: i,
                    x: vec_from_hex(
                        obj.get("x").ok_or_else(|| bad("pending entry missing x".into()))?,
                    )
                    .map_err(bad)?,
                    lam: opt_vec(obj.get("lam")).map_err(bad)?,
                }),
            };
        }
        let last_go = field("last_go")?.items();
        if last_go.len() != self.n_workers {
            return Err(bad(format!("last_go has {} slots", last_go.len())));
        }
        for (i, lg) in last_go.iter().enumerate() {
            self.last_go[i] = match lg {
                JsonValue::Null => None,
                obj => Some(LastGo {
                    x0: vec_from_hex(
                        obj.get("x0").ok_or_else(|| bad("last_go entry missing x0".into()))?,
                    )
                    .map_err(bad)?,
                    lam: opt_vec(obj.get("lam")).map_err(bad)?,
                    lam_state: vec_from_hex(
                        obj.get("lam_state")
                            .ok_or_else(|| bad("last_go entry missing lam_state".into()))?,
                    )
                    .map_err(bad)?,
                }),
            };
        }
        for o in field("outages")?.items() {
            let get = |key: &str| {
                o.get(key)
                    .ok_or_else(|| bad(format!("outage missing {key:?}")))
                    .and_then(|v| json_usize(v).map_err(bad))
            };
            self.realized.push(Outage {
                worker: get("worker")?,
                from_iter: get("from")?,
                until_iter: get("until")?,
            });
        }
        // Resumed runs skip `start`: mark started so the workers that
        // reconnect are re-sent their snapshot and recompute in-flight
        // rounds.
        self.started = true;
        Ok(())
    }
}

/// The acceptor thread: handshake every incoming connection, claim a
/// worker slot, spawn its reader.
fn accept_loop(
    listener: TcpListener,
    n_workers: usize,
    cfg: TransportConfig,
    claims: Arc<Mutex<ClaimTable>>,
    events: Sender<Event>,
    stop: Arc<AtomicBool>,
    bytes_in: Arc<AtomicU64>,
) {
    loop {
        let (stream, _) = match listener.accept() {
            Ok(s) => s,
            Err(_) => return,
        };
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let _ = stream.set_nodelay(true);
        match handshake(&stream, n_workers, &cfg, &claims, &bytes_in) {
            Ok((worker, gen)) => {
                let _ = stream.set_read_timeout(Some(cfg.read_timeout));
                let _ = stream.set_write_timeout(Some(cfg.write_timeout));
                let writer = match stream.try_clone() {
                    Ok(w) => w,
                    Err(_) => continue,
                };
                if events.send(Event::Joined { worker, gen, stream: writer }).is_err() {
                    return; // master gone
                }
                let events = events.clone();
                let stop = Arc::clone(&stop);
                let bytes_in = Arc::clone(&bytes_in);
                let max_frame = cfg.max_frame;
                let _ = std::thread::Builder::new()
                    .name(format!("socket-reader-{worker}"))
                    .spawn(move || {
                        reader_loop(stream, worker, gen, max_frame, events, stop, bytes_in)
                    });
            }
            Err(reply) => {
                // Bad handshake: best-effort error frame, then drop.
                if let Some(message) = reply {
                    let mut sink = &stream;
                    let _ = write_frame(&mut sink, &WireMsg::Error { message }.encode());
                }
            }
        }
    }
}

/// `hello` → slot claim → `assign`. Returns the claimed (worker, gen), or
/// an optional error message for the peer.
fn handshake(
    stream: &TcpStream,
    n_workers: usize,
    cfg: &TransportConfig,
    claims: &Mutex<ClaimTable>,
    bytes_in: &AtomicU64,
) -> Result<(usize, u64), Option<String>> {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let deadline = Instant::now() + cfg.hello_timeout;
    let mut reader = FrameReader::with_max_len(cfg.max_frame);
    let mut src = stream;
    let payload = loop {
        match reader.poll(&mut src) {
            Ok(FrameEvent::Frame(p)) => break p,
            Ok(FrameEvent::WouldBlock) => {
                if Instant::now() >= deadline {
                    return Err(Some("hello timeout".to_string()));
                }
            }
            Ok(FrameEvent::Closed) | Err(_) => return Err(None),
        }
    };
    bytes_in.fetch_add(payload.len() as u64 + 4, Ordering::Relaxed);
    let (job, hint) = match WireMsg::decode(&payload) {
        Ok(WireMsg::Hello { job, worker }) => (job, worker),
        Ok(_) => return Err(Some("expected hello".to_string())),
        Err(e) => return Err(Some(format!("bad hello: {e}"))),
    };
    if job != cfg.job_id {
        return Err(Some(format!("unknown job {job:?} (serving {:?})", cfg.job_id)));
    }
    let (worker, gen) = {
        // ad-lint: allow(panic-free-lib): mutex poisoning only follows a panic in another connection thread; propagating it is the lock idiom
        let mut t = claims.lock().expect("claim table");
        let worker = match hint {
            Some(i) if i < n_workers => i,
            Some(i) => return Err(Some(format!("worker slot {i} out of range 0..{n_workers}"))),
            None => match t.claimed.iter().position(|&c| !c) {
                Some(i) => i,
                None => return Err(Some("no free worker slots".to_string())),
            },
        };
        t.claimed[worker] = true;
        t.gens[worker] += 1;
        (worker, t.gens[worker])
    };
    let assign = WireMsg::Assign { worker, spec: cfg.assign_spec.clone() };
    let mut sink = stream;
    write_frame(&mut sink, &assign.encode()).map_err(|_| None)?;
    Ok((worker, gen))
}

/// Per-connection reader: frames → decoded `up` messages → the shared
/// event channel. Exit (with a `Left` event) on close, protocol error, or
/// the stop flag.
fn reader_loop(
    stream: TcpStream,
    worker: usize,
    gen: u64,
    max_frame: usize,
    events: Sender<Event>,
    stop: Arc<AtomicBool>,
    bytes_in: Arc<AtomicU64>,
) {
    let mut reader = FrameReader::with_max_len(max_frame);
    let mut src = &stream;
    loop {
        match reader.poll(&mut src) {
            Ok(FrameEvent::Frame(payload)) => {
                bytes_in.fetch_add(payload.len() as u64 + 4, Ordering::Relaxed);
                match WireMsg::decode(&payload) {
                    Ok(WireMsg::Up { worker: id, x, lam }) if id == worker => {
                        if events.send(Event::Up(WorkerMsg { id, x, lam })).is_err() {
                            return;
                        }
                    }
                    // Anything else on an assigned connection is a
                    // protocol violation: drop the peer.
                    _ => break,
                }
            }
            Ok(FrameEvent::WouldBlock) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
            }
            Ok(FrameEvent::Closed) | Err(_) => break,
        }
    }
    let _ = events.send(Event::Left { worker, gen });
}
