//! Length-prefixed frame codec — the lowest layer of the wire protocol.
//!
//! A frame is a little-endian `u32` payload length followed by exactly
//! that many payload bytes. No magic, no checksum (TCP provides
//! integrity), no escaping: the payload is opaque to this layer. The
//! length is bounded by a caller-supplied maximum so a corrupt or hostile
//! header cannot make the receiver allocate gigabytes.
//!
//! Reading is driven by [`FrameReader`], an incremental state machine that
//! tolerates arbitrarily fragmented `read` returns (TCP segmentation,
//! read timeouts used as keep-alive polls): partial headers and partial
//! payloads are buffered across calls, and a timeout surfacing as
//! [`std::io::ErrorKind::WouldBlock`]/`TimedOut` yields
//! [`FrameEvent::WouldBlock`] without losing the bytes already consumed.

use std::io::{ErrorKind, Read, Write};

/// Default bound on a single frame's payload (64 MiB) — far above any
/// owned-slice broadcast this crate produces, far below an allocation DoS.
pub const MAX_FRAME_LEN: usize = 64 << 20;

/// Why a frame could not be read.
#[derive(Debug)]
pub enum FrameError {
    /// The underlying stream failed (not a timeout — timeouts surface as
    /// [`FrameEvent::WouldBlock`]).
    Io(std::io::Error),
    /// The header announced a payload larger than the configured bound.
    TooLarge { len: usize, max: usize },
    /// The stream ended mid-frame: `got` of `want` bytes had arrived
    /// (counting the 4 header bytes). A clean close *between* frames is
    /// [`FrameEvent::Closed`], not an error.
    Truncated { got: usize, want: usize },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "frame i/o error: {e}"),
            FrameError::TooLarge { len, max } => {
                write!(f, "frame length {len} exceeds the {max}-byte bound")
            }
            FrameError::Truncated { got, want } => {
                write!(f, "stream closed mid-frame ({got} of {want} bytes)")
            }
        }
    }
}

impl std::error::Error for FrameError {}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> Self {
        FrameError::Io(e)
    }
}

/// One step of [`FrameReader::poll`].
#[derive(Debug)]
pub enum FrameEvent {
    /// A complete payload (possibly empty — zero-length frames are legal).
    Frame(Vec<u8>),
    /// The read timed out ([`ErrorKind::WouldBlock`]/`TimedOut`); call
    /// again — any partial frame stays buffered.
    WouldBlock,
    /// The peer closed the stream cleanly on a frame boundary.
    Closed,
}

/// Incremental frame reassembly over any [`Read`].
///
/// One `FrameReader` per connection: it owns the partial-frame buffer, so
/// interleaving streams through one reader would corrupt both.
pub struct FrameReader {
    max_len: usize,
    /// Bytes of the 4-byte header received so far.
    header: [u8; 4],
    header_filled: usize,
    /// Payload buffer, allocated once the header is complete.
    payload: Vec<u8>,
    payload_filled: usize,
    /// Whether the header is complete and `payload` is live.
    in_payload: bool,
}

impl FrameReader {
    /// A reader enforcing the default [`MAX_FRAME_LEN`] bound.
    pub fn new() -> Self {
        Self::with_max_len(MAX_FRAME_LEN)
    }

    /// A reader enforcing a custom payload bound (tests use tiny ones).
    pub fn with_max_len(max_len: usize) -> Self {
        FrameReader {
            max_len,
            header: [0; 4],
            header_filled: 0,
            payload: Vec::new(),
            payload_filled: 0,
            in_payload: false,
        }
    }

    /// Pull from `r` until one frame completes, the stream closes, or a
    /// timeout fires. Short reads are fine: state persists across calls.
    pub fn poll(&mut self, r: &mut impl Read) -> Result<FrameEvent, FrameError> {
        loop {
            if !self.in_payload {
                // Header phase.
                match r.read(&mut self.header[self.header_filled..]) {
                    Ok(0) => {
                        if self.header_filled == 0 {
                            return Ok(FrameEvent::Closed);
                        }
                        return Err(FrameError::Truncated {
                            got: self.header_filled,
                            want: 4 + u32::from_le_bytes(self.header) as usize,
                        });
                    }
                    Ok(n) => self.header_filled += n,
                    Err(e) => return Self::map_err(e),
                }
                if self.header_filled < 4 {
                    continue;
                }
                let len = u32::from_le_bytes(self.header) as usize;
                if len > self.max_len {
                    return Err(FrameError::TooLarge { len, max: self.max_len });
                }
                self.in_payload = true;
                self.payload = vec![0; len];
                self.payload_filled = 0;
            }
            // Payload phase (zero-length frames complete immediately).
            if self.payload_filled == self.payload.len() {
                let frame = std::mem::take(&mut self.payload);
                self.in_payload = false;
                self.header_filled = 0;
                self.payload_filled = 0;
                return Ok(FrameEvent::Frame(frame));
            }
            match r.read(&mut self.payload[self.payload_filled..]) {
                Ok(0) => {
                    return Err(FrameError::Truncated {
                        got: 4 + self.payload_filled,
                        want: 4 + self.payload.len(),
                    })
                }
                Ok(n) => self.payload_filled += n,
                Err(e) => return Self::map_err(e),
            }
        }
    }

    /// Block until a full frame arrives (treats timeouts as fatal — for
    /// callers that did not set a read timeout).
    pub fn next_frame(&mut self, r: &mut impl Read) -> Result<Option<Vec<u8>>, FrameError> {
        loop {
            match self.poll(r)? {
                FrameEvent::Frame(p) => return Ok(Some(p)),
                FrameEvent::Closed => return Ok(None),
                FrameEvent::WouldBlock => continue,
            }
        }
    }

    fn map_err(e: std::io::Error) -> Result<FrameEvent, FrameError> {
        match e.kind() {
            ErrorKind::WouldBlock | ErrorKind::TimedOut => Ok(FrameEvent::WouldBlock),
            ErrorKind::Interrupted => Ok(FrameEvent::WouldBlock),
            _ => Err(FrameError::Io(e)),
        }
    }
}

impl Default for FrameReader {
    fn default() -> Self {
        Self::new()
    }
}

/// Write one frame: 4-byte little-endian length, then the payload.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> std::io::Result<()> {
    let len = u32::try_from(payload.len()).map_err(|_| {
        std::io::Error::new(ErrorKind::InvalidInput, "frame payload exceeds u32::MAX")
    })?;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn framed(payload: &[u8]) -> Vec<u8> {
        let mut buf = Vec::new();
        write_frame(&mut buf, payload).unwrap();
        buf
    }

    fn read_all(bytes: &[u8], max: usize) -> Result<Vec<Vec<u8>>, FrameError> {
        let mut cursor = std::io::Cursor::new(bytes);
        let mut reader = FrameReader::with_max_len(max);
        let mut out = Vec::new();
        while let Some(p) = reader.next_frame(&mut cursor)? {
            out.push(p);
        }
        Ok(out)
    }

    #[test]
    fn round_trips_frames_back_to_back() {
        let mut bytes = framed(b"hello");
        bytes.extend(framed(b""));
        bytes.extend(framed(&[0xff; 300]));
        let frames = read_all(&bytes, MAX_FRAME_LEN).unwrap();
        assert_eq!(frames.len(), 3);
        assert_eq!(frames[0], b"hello");
        assert_eq!(frames[1], b"");
        assert_eq!(frames[2], vec![0xff; 300]);
    }

    #[test]
    fn zero_length_frame_is_legal() {
        let frames = read_all(&framed(b""), 16).unwrap();
        assert_eq!(frames, vec![Vec::<u8>::new()]);
    }

    #[test]
    fn truncated_header_is_an_error() {
        // 2 of the 4 header bytes, then EOF.
        let err = read_all(&framed(b"abcd")[..2], 16).unwrap_err();
        match err {
            FrameError::Truncated { got, .. } => assert_eq!(got, 2),
            other => panic!("expected Truncated, got {other:?}"),
        }
    }

    #[test]
    fn truncated_payload_is_an_error() {
        // Header promises 4 payload bytes; only 1 arrives.
        let err = read_all(&framed(b"abcd")[..5], 16).unwrap_err();
        match err {
            FrameError::Truncated { got, want } => {
                assert_eq!(got, 5);
                assert_eq!(want, 8);
            }
            other => panic!("expected Truncated, got {other:?}"),
        }
    }

    #[test]
    fn oversize_frame_is_rejected_before_allocation() {
        let bytes = framed(&[7u8; 100]);
        let err = read_all(&bytes, 99).unwrap_err();
        match err {
            FrameError::TooLarge { len, max } => {
                assert_eq!(len, 100);
                assert_eq!(max, 99);
            }
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    /// A `Read` that returns the stream in adversarially small pieces and
    /// interleaves spurious timeouts — the shapes a real socket produces.
    struct ChunkedReader {
        bytes: Vec<u8>,
        pos: usize,
        rng: Pcg64,
    }

    impl Read for ChunkedReader {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if self.pos == self.bytes.len() {
                return Ok(0);
            }
            // One in four reads "times out" instead of delivering bytes.
            if self.rng.bernoulli(0.25) {
                return Err(std::io::Error::new(ErrorKind::WouldBlock, "poll"));
            }
            let n = 1 + (self.rng.next_u64() as usize) % 3.min(buf.len()).max(1);
            let n = n.min(self.bytes.len() - self.pos).min(buf.len());
            buf[..n].copy_from_slice(&self.bytes[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    /// Property test (seed-swept): any interleaving of partial reads and
    /// timeouts reassembles the exact frame sequence.
    #[test]
    fn partial_reads_and_timeouts_reassemble_exactly() {
        for seed in 0..25u64 {
            let mut rng = Pcg64::seed_from_u64(seed);
            let n_frames = 1 + (rng.next_u64() % 5) as usize;
            let payloads: Vec<Vec<u8>> = (0..n_frames)
                .map(|_| {
                    let len = (rng.next_u64() % 64) as usize;
                    (0..len).map(|_| rng.next_u64() as u8).collect()
                })
                .collect();
            let mut bytes = Vec::new();
            for p in &payloads {
                bytes.extend(framed(p));
            }
            let mut reader = FrameReader::new();
            let mut src = ChunkedReader { bytes, pos: 0, rng };
            let mut got = Vec::new();
            loop {
                match reader.poll(&mut src) {
                    Ok(FrameEvent::Frame(p)) => got.push(p),
                    Ok(FrameEvent::WouldBlock) => continue,
                    Ok(FrameEvent::Closed) => break,
                    Err(e) => panic!("seed {seed}: {e}"),
                }
            }
            assert_eq!(got, payloads, "seed {seed}");
        }
    }
}
