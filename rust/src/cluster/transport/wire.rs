//! Wire messages: the [`super::frame`] payloads, serialized with the
//! in-repo JSON codec using bit-exact hex f64 encodings (`hex_vec`), so
//! NaN/Inf state and every rounding-sensitive coordinate survive the wire
//! unchanged — the transport's bit-identity guarantees reduce to the
//! checkpoint codec's.
//!
//! | type       | direction        | fields                                        |
//! |------------|------------------|-----------------------------------------------|
//! | `hello`    | worker → master  | `job`, `worker` (slot hint or null)           |
//! | `assign`   | master → worker  | `worker` (assigned slot), `spec` (job object) |
//! | `go`       | master → worker  | `x0`, `lam?` (Alg 4), `reseed?` (λ_i rewind)  |
//! | `up`       | worker → master  | `worker`, `x`, `lam?` (Alg 2)                 |
//! | `shutdown` | master → worker  | —                                             |
//! | `submit`   | client → serve   | `spec` (job object incl. `job_id`)            |
//! | `accepted` | serve → client   | `job`, `port`, `ports?` (rendezvous ports)    |
//! | `report`   | serve → client   | `job`, `report` (per-job result object)       |
//! | `error`    | serve → client   | `message`                                     |
//!
//! `go.reseed` carries the worker-held dual λ_i to restore after a
//! reconnect (Algorithm 2 keeps λ_i worker-side; a restarted worker
//! process would otherwise restart it at zero and silently break protocol
//! equivalence — see [`super::socket::SocketSource`]).

use crate::bench::json::{self, hex_vec, vec_from_hex, JsonValue};

use super::super::messages::{MasterMsg, WorkerMsg};

/// Every message the transport exchanges, across both planes (the solve
/// protocol master↔worker and the service control plane client↔serve).
#[derive(Clone, Debug, PartialEq)]
pub enum WireMsg {
    /// Worker connects: which job it serves and (optionally) which worker
    /// slot it wants — a reconnecting worker names its old slot.
    Hello { job: String, worker: Option<usize> },
    /// Master's handshake reply: the assigned slot and the job spec the
    /// worker needs to rebuild its local problem deterministically.
    Assign { worker: usize, spec: JsonValue },
    /// One round's broadcast (Step 6): the (owned slice of) x₀, the
    /// master-updated dual for Algorithm 4, and — after a reconnect — the
    /// worker-held dual to restore before computing.
    Go { x0: Vec<f64>, lam: Option<Vec<f64>>, reseed: Option<Vec<f64>> },
    /// One round's upload (Step 4): the arrived variables `(x̂_i, λ̂_i)`.
    Up { worker: usize, x: Vec<f64>, lam: Option<Vec<f64>> },
    /// Stop the worker loop.
    Shutdown,
    /// Control plane: submit a solve job to `admm-serve`.
    Submit { spec: JsonValue },
    /// Control plane: job accepted; workers rendezvous on these ports —
    /// one per master (multi-master jobs bind one listener per
    /// coordinator). The wire form keeps the legacy scalar `port` field
    /// (= `ports[0]`) so pre-multimaster peers still parse single-master
    /// accepts, and decoding a legacy frame without `ports` yields
    /// `vec![port]`.
    Accepted { job: String, ports: Vec<u16> },
    /// Control plane: the finished job's report.
    Report { job: String, report: JsonValue },
    /// Control plane: the request failed.
    Error { message: String },
}

impl WireMsg {
    /// The engine-side view of a `go` frame (reseed handled by the client
    /// before the round starts, so it is not part of [`MasterMsg`]).
    pub fn from_master(msg: &MasterMsg, reseed: Option<Vec<f64>>) -> WireMsg {
        match msg {
            MasterMsg::Shutdown => WireMsg::Shutdown,
            MasterMsg::Go { x0, lam } => {
                WireMsg::Go { x0: x0.clone(), lam: lam.clone(), reseed }
            }
        }
    }

    /// The engine-side view of an `up` frame.
    pub fn from_worker(msg: &WorkerMsg) -> WireMsg {
        WireMsg::Up { worker: msg.id, x: msg.x.clone(), lam: msg.lam.clone() }
    }

    /// Serialize to a frame payload (UTF-8 JSON bytes).
    pub fn encode(&self) -> Vec<u8> {
        let obj = |t: &str, mut fields: Vec<(String, JsonValue)>| {
            let mut all = vec![("type".to_string(), JsonValue::Str(t.to_string()))];
            all.append(&mut fields);
            JsonValue::Obj(all)
        };
        let opt_vec = |v: &Option<Vec<f64>>| match v {
            Some(v) => hex_vec(v),
            None => JsonValue::Null,
        };
        let doc = match self {
            WireMsg::Hello { job, worker } => obj(
                "hello",
                vec![
                    ("job".to_string(), JsonValue::Str(job.clone())),
                    (
                        "worker".to_string(),
                        worker.map_or(JsonValue::Null, JsonValue::from),
                    ),
                ],
            ),
            WireMsg::Assign { worker, spec } => obj(
                "assign",
                vec![
                    ("worker".to_string(), (*worker).into()),
                    ("spec".to_string(), spec.clone()),
                ],
            ),
            WireMsg::Go { x0, lam, reseed } => obj(
                "go",
                vec![
                    ("x0".to_string(), hex_vec(x0)),
                    ("lam".to_string(), opt_vec(lam)),
                    ("reseed".to_string(), opt_vec(reseed)),
                ],
            ),
            WireMsg::Up { worker, x, lam } => obj(
                "up",
                vec![
                    ("worker".to_string(), (*worker).into()),
                    ("x".to_string(), hex_vec(x)),
                    ("lam".to_string(), opt_vec(lam)),
                ],
            ),
            WireMsg::Shutdown => obj("shutdown", Vec::new()),
            WireMsg::Submit { spec } => obj("submit", vec![("spec".to_string(), spec.clone())]),
            WireMsg::Accepted { job, ports } => obj(
                "accepted",
                vec![
                    ("job".to_string(), JsonValue::Str(job.clone())),
                    (
                        "port".to_string(),
                        (ports.first().copied().unwrap_or(0) as usize).into(),
                    ),
                    (
                        "ports".to_string(),
                        JsonValue::Arr(
                            ports.iter().map(|&p| JsonValue::from(p as usize)).collect(),
                        ),
                    ),
                ],
            ),
            WireMsg::Report { job, report } => obj(
                "report",
                vec![
                    ("job".to_string(), JsonValue::Str(job.clone())),
                    ("report".to_string(), report.clone()),
                ],
            ),
            WireMsg::Error { message } => obj(
                "error",
                vec![("message".to_string(), JsonValue::Str(message.clone()))],
            ),
        };
        doc.to_string().into_bytes()
    }

    /// Parse a frame payload. Unknown `type` tags and malformed fields are
    /// errors — the protocol is versionless-strict, like the checkpoint
    /// schema.
    pub fn decode(payload: &[u8]) -> Result<WireMsg, String> {
        let text = std::str::from_utf8(payload).map_err(|e| format!("non-UTF-8 payload: {e}"))?;
        let doc = json::parse(text)?;
        let tag = doc
            .get("type")
            .and_then(JsonValue::as_str)
            .ok_or_else(|| "missing \"type\" tag".to_string())?
            .to_string();
        let get = |key: &str| doc.get(key).ok_or_else(|| format!("{tag}: missing {key:?}"));
        let opt_vec = |key: &str| -> Result<Option<Vec<f64>>, String> {
            match doc.get(key) {
                None | Some(JsonValue::Null) => Ok(None),
                Some(v) => Ok(Some(vec_from_hex(v)?)),
            }
        };
        let get_str = |key: &str| -> Result<String, String> {
            get(key)?
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| format!("{tag}: {key:?} is not a string"))
        };
        let get_usize =
            |key: &str| -> Result<usize, String> { json::json_usize(get(key)?) };
        Ok(match tag.as_str() {
            "hello" => WireMsg::Hello {
                job: get_str("job")?,
                worker: match doc.get("worker") {
                    None | Some(JsonValue::Null) => None,
                    Some(v) => Some(json::json_usize(v)?),
                },
            },
            "assign" => WireMsg::Assign { worker: get_usize("worker")?, spec: get("spec")?.clone() },
            "go" => WireMsg::Go {
                x0: vec_from_hex(get("x0")?)?,
                lam: opt_vec("lam")?,
                reseed: opt_vec("reseed")?,
            },
            "up" => WireMsg::Up {
                worker: get_usize("worker")?,
                x: vec_from_hex(get("x")?)?,
                lam: opt_vec("lam")?,
            },
            "shutdown" => WireMsg::Shutdown,
            "submit" => WireMsg::Submit { spec: get("spec")?.clone() },
            "accepted" => {
                let port_of = |v: &JsonValue| -> Result<u16, String> {
                    u16::try_from(json::json_usize(v)?)
                        .map_err(|_| "accepted: port out of range".to_string())
                };
                let ports = match doc.get("ports") {
                    // Legacy single-master frame: the scalar field is the
                    // whole rendezvous story.
                    None | Some(JsonValue::Null) => vec![port_of(get("port")?)?],
                    Some(arr) => {
                        let ports = arr
                            .items()
                            .iter()
                            .map(port_of)
                            .collect::<Result<Vec<u16>, String>>()?;
                        if ports.is_empty() {
                            return Err("accepted: empty ports list".to_string());
                        }
                        ports
                    }
                };
                WireMsg::Accepted { job: get_str("job")?, ports }
            }
            "report" => WireMsg::Report { job: get_str("job")?, report: get("report")?.clone() },
            "error" => WireMsg::Error { message: get_str("message")? },
            other => return Err(format!("unknown message type {other:?}")),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(msg: WireMsg) {
        let decoded = WireMsg::decode(&msg.encode()).expect("decodes");
        assert_eq!(decoded, msg);
    }

    #[test]
    fn every_variant_round_trips() {
        round_trip(WireMsg::Hello { job: "j1".to_string(), worker: Some(3) });
        round_trip(WireMsg::Hello { job: "j1".to_string(), worker: None });
        round_trip(WireMsg::Assign {
            worker: 2,
            spec: JsonValue::Obj(vec![("m".to_string(), 40usize.into())]),
        });
        round_trip(WireMsg::Go { x0: vec![1.0, -2.5], lam: None, reseed: Some(vec![0.125]) });
        round_trip(WireMsg::Up { worker: 1, x: vec![3.5], lam: Some(vec![-0.0]) });
        round_trip(WireMsg::Shutdown);
        round_trip(WireMsg::Submit { spec: JsonValue::Null });
        round_trip(WireMsg::Accepted { job: "j".to_string(), ports: vec![65535] });
        round_trip(WireMsg::Accepted { job: "j".to_string(), ports: vec![7401, 7402, 7403] });
        round_trip(WireMsg::Report { job: "j".to_string(), report: JsonValue::Obj(Vec::new()) });
        round_trip(WireMsg::Error { message: "boom \"quoted\"\n".to_string() });
    }

    /// Non-finite and signed-zero f64 bit patterns survive the wire
    /// exactly (the plain-number JSON path would collapse them to null).
    #[test]
    fn nan_inf_bit_patterns_round_trip_exactly() {
        let weird = vec![
            f64::NAN,
            f64::from_bits(0x7ff8_0000_0000_0001), // NaN with a payload
            f64::from_bits(0xfff0_0000_0000_0000), // -inf
            f64::INFINITY,
            -0.0,
            f64::MIN_POSITIVE / 2.0, // subnormal
        ];
        let msg = WireMsg::Go { x0: weird.clone(), lam: Some(weird.clone()), reseed: None };
        match WireMsg::decode(&msg.encode()).unwrap() {
            WireMsg::Go { x0, lam, reseed } => {
                let bits = |v: &[f64]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&x0), bits(&weird));
                assert_eq!(bits(&lam.unwrap()), bits(&weird));
                assert!(reseed.is_none());
            }
            other => panic!("expected Go, got {other:?}"),
        }
    }

    /// `accepted` frames from pre-multimaster serves carry only the scalar
    /// `port`; they decode as a single-entry ports list.
    #[test]
    fn legacy_accepted_frame_decodes_as_single_port() {
        let legacy = b"{\"type\":\"accepted\",\"job\":\"j9\",\"port\":7401}";
        assert_eq!(
            WireMsg::decode(legacy).unwrap(),
            WireMsg::Accepted { job: "j9".to_string(), ports: vec![7401] }
        );
        let empty = b"{\"type\":\"accepted\",\"job\":\"j9\",\"port\":1,\"ports\":[]}";
        assert!(WireMsg::decode(empty).is_err());
    }

    #[test]
    fn malformed_payloads_are_rejected() {
        assert!(WireMsg::decode(b"not json").is_err());
        assert!(WireMsg::decode(b"{\"no\": \"type\"}").is_err());
        assert!(WireMsg::decode(b"{\"type\": \"warp\"}").is_err());
        assert!(WireMsg::decode(&[0xff, 0xfe]).is_err()); // invalid UTF-8
        // `up` with a non-hex coordinate
        assert!(WireMsg::decode(b"{\"type\":\"up\",\"worker\":0,\"x\":[1.5],\"lam\":null}")
            .is_err());
    }
}
