//! The worker-side TCP client: connect, handshake, then run the
//! per-round protocol of [`super::super::worker`] over frames instead of
//! channels.
//!
//! The round arithmetic is [`worker_round`] — the *same function* the
//! threaded worker loop calls — and the injected communication latency is
//! [`comm_leg_ms`], so a socket worker computes bit-identical messages to
//! an in-process worker fed the same `(λ_i, x̂₀)` sequence. A `go` frame
//! carrying `reseed` restores the worker-held dual first (reconnect
//! recovery; see [`super::socket`]).

use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::admm::session::EngineError;
use crate::problems::WorkerScratch;
use crate::rng::Pcg64;
use crate::solvers::inexact::WarmState;
use crate::util::timer::{Clock, Stopwatch};

use super::super::timeline::WorkerStats;
use super::super::worker::{comm_leg_ms, worker_round};
use super::super::{DelayModel, FaultModel, Protocol};
use super::frame::{write_frame, FrameReader};
use super::service::JobSpec;
use super::wire::WireMsg;

/// How a worker process finds and identifies itself to a master.
#[derive(Clone, Debug)]
pub struct WorkerClientConfig {
    /// Master address, e.g. `"127.0.0.1:7401"`.
    pub addr: String,
    /// Job id to present in `hello` (must match the master's).
    pub job_id: String,
    /// Worker-slot hint: a reconnecting worker names its old slot so the
    /// master re-delivers the in-flight broadcast; `None` takes any free
    /// slot.
    pub worker: Option<usize>,
    /// Connect retries before giving up (the master may not be listening
    /// yet when a fleet launches).
    pub retries: u32,
    /// Delay between connect attempts.
    pub retry_delay: Duration,
    /// Exit after this many completed rounds by dropping the connection
    /// without a goodbye — the fault-injection hook the disconnect tests
    /// use to emulate a crashing worker process.
    pub max_rounds: Option<usize>,
}

impl Default for WorkerClientConfig {
    fn default() -> Self {
        WorkerClientConfig {
            addr: "127.0.0.1:7401".to_string(),
            job_id: "default".to_string(),
            worker: None,
            retries: 50,
            retry_delay: Duration::from_millis(100),
            max_rounds: None,
        }
    }
}

fn transport_err(msg: String) -> EngineError {
    EngineError::Transport(msg)
}

fn connect(cfg: &WorkerClientConfig) -> Result<TcpStream, EngineError> {
    let mut attempt = 0;
    loop {
        match TcpStream::connect(&cfg.addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                attempt += 1;
                if attempt > cfg.retries {
                    return Err(transport_err(format!(
                        "cannot connect to {} after {} attempts: {e}",
                        cfg.addr, attempt
                    )));
                }
                std::thread::sleep(cfg.retry_delay);
            }
        }
    }
}

/// Run one worker process to completion: connect (with retries),
/// handshake, rebuild the local problem from the assigned [`JobSpec`],
/// then answer `go` frames until `shutdown` (or `max_rounds`). Returns the
/// worker's accumulated stats, exactly like the threaded loop does.
pub fn run_worker(cfg: &WorkerClientConfig) -> Result<WorkerStats, EngineError> {
    let stream = connect(cfg)?;
    let _ = stream.set_nodelay(true);
    let mut sink = &stream;
    let mut src = &stream;
    let mut reader = FrameReader::new();

    let hello = WireMsg::Hello { job: cfg.job_id.clone(), worker: cfg.worker };
    write_frame(&mut sink, &hello.encode())
        .map_err(|e| transport_err(format!("hello write failed: {e}")))?;

    let payload = reader
        .next_frame(&mut src)
        .map_err(|e| transport_err(format!("handshake read failed: {e}")))?
        .ok_or_else(|| transport_err("master closed during handshake".to_string()))?;
    let (worker, spec) = match WireMsg::decode(&payload).map_err(transport_err)? {
        WireMsg::Assign { worker, spec } => {
            (worker, JobSpec::from_json(&spec).map_err(transport_err)?)
        }
        WireMsg::Error { message } => {
            return Err(transport_err(format!("master rejected hello: {message}")))
        }
        other => return Err(transport_err(format!("expected assign, got {other:?}"))),
    };

    // Rebuild the local problem deterministically from the spec — every
    // process derives the identical instance from the shared seed.
    let problem = spec.build_problem()?;
    if worker >= problem.num_workers() {
        return Err(transport_err(format!("assigned slot {worker} out of range")));
    }
    let local = std::sync::Arc::clone(problem.local(worker));
    let protocol = if spec.alt { Protocol::AltScheme } else { Protocol::AdAdmm };
    let rho = spec.rho;

    // Same injected-latency models as the threaded mode, same seeds.
    let mut delay = DelayModel::linear_spread(
        spec.workers,
        spec.fast_ms,
        spec.slow_ms,
        0.3,
        spec.seed,
    )
    .sampler(worker);
    let faults: Option<FaultModel> = None;
    let mut fault_rng: Option<Pcg64> = None;

    let n = local.dim();
    let mut lam = vec![0.0; n]; // λ⁰ = 0 (reseed frames overwrite on reconnect)
    let mut x = vec![0.0; n];
    let mut scratch = WorkerScratch::new();
    // The spec's inexactness policy, honoured through this process-local
    // warm state — same per-arrival solve cadence as the in-process
    // sources, so lockstep digests still match under inexact policies.
    // (A reconnecting worker restarts cold; under `lockstep` the e2e
    // digest jobs run fault-free, so the schedule stays aligned.)
    let policy = spec.inexact;
    let mut warm = WarmState::default();
    let mut stats = WorkerStats::new(worker);
    let mut rounds = 0usize;
    let wall = Stopwatch::start();

    loop {
        let payload = match reader
            .next_frame(&mut src)
            .map_err(|e| transport_err(format!("read failed: {e}")))?
        {
            Some(p) => p,
            None => break, // master closed: treat as shutdown
        };
        let (x0, master_lam, reseed) = match WireMsg::decode(&payload).map_err(transport_err)? {
            WireMsg::Go { x0, lam, reseed } => (x0, lam, reseed),
            WireMsg::Shutdown => break,
            other => return Err(transport_err(format!("expected go/shutdown, got {other:?}"))),
        };
        if let Some(r) = reseed {
            if r.len() != lam.len() {
                return Err(transport_err(format!(
                    "reseed dual has {} coordinates, expected {}",
                    r.len(),
                    lam.len()
                )));
            }
            lam.copy_from_slice(&r);
        }
        let t0 = Instant::now();

        let ms = delay.sample_ms();
        if ms > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(ms * 1e-3));
        }

        let lam_out = worker_round(
            protocol,
            &*local,
            rho,
            &mut lam,
            &mut x,
            &x0,
            master_lam.as_deref(),
            None,
            &mut scratch,
            &policy,
            &mut warm,
        );

        let cms = comm_leg_ms(None, faults.as_ref(), fault_rng.as_mut(), &mut stats, 1.0);
        if cms > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(cms * 1e-3));
        }

        let up = WireMsg::Up { worker, x: x.clone(), lam: lam_out };
        write_frame(&mut sink, &up.encode())
            .map_err(|e| transport_err(format!("up write failed: {e}")))?;

        stats.updates += 1;
        stats.busy_s += t0.elapsed().as_secs_f64();
        rounds += 1;
        if cfg.max_rounds == Some(rounds) {
            break; // drop the connection cold — emulated process crash
        }
    }

    stats.lifetime_s = wall.now_s();
    Ok(stats)
}
