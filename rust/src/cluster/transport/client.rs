//! The worker-side TCP client: connect, handshake, then run the
//! per-round protocol of [`super::super::worker`] over frames instead of
//! channels.
//!
//! The round arithmetic is [`worker_round`] — the *same function* the
//! threaded worker loop calls — and the injected communication latency is
//! [`comm_leg_ms`], so a socket worker computes bit-identical messages to
//! an in-process worker fed the same `(λ_i, x̂₀)` sequence. A `go` frame
//! carrying `reseed` restores the worker-held dual first (reconnect
//! recovery; see [`super::socket`]).

use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::admm::session::EngineError;
use crate::problems::WorkerScratch;
use crate::rng::Pcg64;
use crate::solvers::inexact::WarmState;
use crate::util::timer::{Clock, Stopwatch};

use super::super::timeline::WorkerStats;
use super::super::worker::{comm_leg_ms, worker_round};
use super::super::{DelayModel, FaultModel, Protocol};
use super::frame::{write_frame, FrameReader};
use super::multisocket::{extract, scatter};
use super::service::JobSpec;
use super::wire::WireMsg;

/// How a worker process finds and identifies itself to a master.
#[derive(Clone, Debug)]
pub struct WorkerClientConfig {
    /// Master address, e.g. `"127.0.0.1:7401"` — or a comma-joined list
    /// (`"127.0.0.1:7401,127.0.0.1:7402"`), one address per master of a
    /// multi-master job, in master order (the `accepted` log line prints
    /// exactly this list).
    pub addr: String,
    /// Job id to present in `hello` (must match the master's).
    pub job_id: String,
    /// Worker-slot hint: a reconnecting worker names its old slot so the
    /// master re-delivers the in-flight broadcast; `None` takes any free
    /// slot.
    pub worker: Option<usize>,
    /// Connect retries before giving up (the master may not be listening
    /// yet when a fleet launches).
    pub retries: u32,
    /// Delay between connect attempts.
    pub retry_delay: Duration,
    /// Exit after this many completed rounds by dropping the connection
    /// without a goodbye — the fault-injection hook the disconnect tests
    /// use to emulate a crashing worker process.
    pub max_rounds: Option<usize>,
}

impl Default for WorkerClientConfig {
    fn default() -> Self {
        WorkerClientConfig {
            addr: "127.0.0.1:7401".to_string(),
            job_id: "default".to_string(),
            worker: None,
            retries: 50,
            retry_delay: Duration::from_millis(100),
            max_rounds: None,
        }
    }
}

fn transport_err(msg: String) -> EngineError {
    EngineError::Transport(msg)
}

fn connect_addr(
    addr: &str,
    retries: u32,
    retry_delay: Duration,
) -> Result<TcpStream, EngineError> {
    let mut attempt = 0;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                attempt += 1;
                if attempt > retries {
                    return Err(transport_err(format!(
                        "cannot connect to {addr} after {attempt} attempts: {e}"
                    )));
                }
                std::thread::sleep(retry_delay);
            }
        }
    }
}

fn connect(cfg: &WorkerClientConfig) -> Result<TcpStream, EngineError> {
    connect_addr(&cfg.addr, cfg.retries, cfg.retry_delay)
}

/// `hello`/`assign` exchange on one connection: present the job id (and a
/// slot hint, if any), return the assigned slot and the job spec.
fn handshake(
    mut sink: &TcpStream,
    reader: &mut FrameReader,
    job_id: &str,
    slot: Option<usize>,
) -> Result<(usize, JobSpec), EngineError> {
    let hello = WireMsg::Hello { job: job_id.to_string(), worker: slot };
    write_frame(&mut sink, &hello.encode())
        .map_err(|e| transport_err(format!("hello write failed: {e}")))?;
    let payload = reader
        .next_frame(&mut sink)
        .map_err(|e| transport_err(format!("handshake read failed: {e}")))?
        .ok_or_else(|| transport_err("master closed during handshake".to_string()))?;
    match WireMsg::decode(&payload).map_err(transport_err)? {
        WireMsg::Assign { worker, spec } => {
            Ok((worker, JobSpec::from_json(&spec).map_err(transport_err)?))
        }
        WireMsg::Error { message } => {
            Err(transport_err(format!("master rejected hello: {message}")))
        }
        other => Err(transport_err(format!("expected assign, got {other:?}"))),
    }
}

/// Run one worker process to completion: connect (with retries),
/// handshake, rebuild the local problem from the assigned [`JobSpec`],
/// then answer `go` frames until `shutdown` (or `max_rounds`). Returns the
/// worker's accumulated stats, exactly like the threaded loop does.
pub fn run_worker(cfg: &WorkerClientConfig) -> Result<WorkerStats, EngineError> {
    if cfg.addr.contains(',') {
        return run_worker_multi(cfg);
    }
    let stream = connect(cfg)?;
    let _ = stream.set_nodelay(true);
    let mut sink = &stream;
    let mut src = &stream;
    let mut reader = FrameReader::new();

    let (worker, spec) = handshake(&stream, &mut reader, &cfg.job_id, cfg.worker)?;

    // Rebuild the local problem deterministically from the spec — every
    // process derives the identical instance from the shared seed.
    let problem = spec.build_problem()?;
    if worker >= problem.num_workers() {
        return Err(transport_err(format!("assigned slot {worker} out of range")));
    }
    let local = std::sync::Arc::clone(problem.local(worker));
    let protocol = if spec.alt { Protocol::AltScheme } else { Protocol::AdAdmm };
    let rho = spec.rho;

    // Same injected-latency models as the threaded mode, same seeds.
    let mut delay = DelayModel::linear_spread(
        spec.workers,
        spec.fast_ms,
        spec.slow_ms,
        0.3,
        spec.seed,
    )
    .sampler(worker);
    let faults: Option<FaultModel> = None;
    let mut fault_rng: Option<Pcg64> = None;

    let n = local.dim();
    let mut lam = vec![0.0; n]; // λ⁰ = 0 (reseed frames overwrite on reconnect)
    let mut x = vec![0.0; n];
    let mut scratch = WorkerScratch::new();
    // The spec's inexactness policy, honoured through this process-local
    // warm state — same per-arrival solve cadence as the in-process
    // sources, so lockstep digests still match under inexact policies.
    // (A reconnecting worker restarts cold; under `lockstep` the e2e
    // digest jobs run fault-free, so the schedule stays aligned.)
    // A short per-worker policy list from a malformed spec must fail this
    // worker's job, not panic the connection thread.
    let policy = match spec.inexact_workers.as_ref() {
        None => spec.inexact,
        Some(v) => *v.get(worker).ok_or_else(|| {
            transport_err(format!(
                "inexact_workers has {} entries but this worker was assigned slot {worker}",
                v.len()
            ))
        })?,
    };
    let mut warm = WarmState::default();
    let mut stats = WorkerStats::new(worker);
    let mut rounds = 0usize;
    let wall = Stopwatch::start();

    loop {
        let payload = match reader
            .next_frame(&mut src)
            .map_err(|e| transport_err(format!("read failed: {e}")))?
        {
            Some(p) => p,
            None => break, // master closed: treat as shutdown
        };
        let (x0, master_lam, reseed) = match WireMsg::decode(&payload).map_err(transport_err)? {
            WireMsg::Go { x0, lam, reseed } => (x0, lam, reseed),
            WireMsg::Shutdown => break,
            other => return Err(transport_err(format!("expected go/shutdown, got {other:?}"))),
        };
        if let Some(r) = reseed {
            if r.len() != lam.len() {
                return Err(transport_err(format!(
                    "reseed dual has {} coordinates, expected {}",
                    r.len(),
                    lam.len()
                )));
            }
            lam.copy_from_slice(&r);
        }
        let t0 = Instant::now();

        let ms = delay.sample_ms();
        if ms > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(ms * 1e-3));
        }

        let lam_out = worker_round(
            protocol,
            &*local,
            rho,
            &mut lam,
            &mut x,
            &x0,
            master_lam.as_deref(),
            None,
            &mut scratch,
            &policy,
            &mut warm,
        );

        let cms = comm_leg_ms(None, faults.as_ref(), fault_rng.as_mut(), &mut stats, 1.0);
        if cms > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(cms * 1e-3));
        }

        let up = WireMsg::Up { worker, x: x.clone(), lam: lam_out };
        write_frame(&mut sink, &up.encode())
            .map_err(|e| transport_err(format!("up write failed: {e}")))?;

        stats.updates += 1;
        stats.busy_s += t0.elapsed().as_secs_f64();
        rounds += 1;
        if cfg.max_rounds == Some(rounds) {
            break; // drop the connection cold — emulated process crash
        }
    }

    stats.lifetime_s = wall.now_s();
    Ok(stats)
}

/// The multi-master worker loop behind a comma-joined `addr` list: one
/// socket per master, the owned slice multiplexed across the masters
/// owning this worker's blocks.
///
/// Master 0's claim table is the global slot allocator — the worker
/// handshakes `addrs[0]` first (with its hint, if any), then claims the
/// assigned slot explicitly on every other master so all endpoints agree
/// on the worker id. Ownership is derivable only once the spec arrives,
/// so the worker dials *every* master; connections to non-owning masters
/// stay idle after the handshake. Per round it reads one `go` part from
/// each owning master (ascending master order), stitches them into the
/// full owned `x̂₀` by the same derived ranges the master split by
/// ([`crate::cluster::multimaster::MasterGroup::worker_ranges`] via
/// [`super::multisocket`]), runs the
/// one shared [`worker_round`], and ships each owning master exactly its
/// part of `(x_i, λ_i)` back.
fn run_worker_multi(cfg: &WorkerClientConfig) -> Result<WorkerStats, EngineError> {
    let addrs: Vec<&str> = cfg.addr.split(',').map(str::trim).collect();
    let mut streams = Vec::with_capacity(addrs.len());
    for addr in &addrs {
        let s = connect_addr(addr, cfg.retries, cfg.retry_delay)?;
        let _ = s.set_nodelay(true);
        streams.push(s);
    }
    let mut readers: Vec<FrameReader> =
        (0..streams.len()).map(|_| FrameReader::new()).collect();

    let (worker, spec) = handshake(&streams[0], &mut readers[0], &cfg.job_id, cfg.worker)?;
    for m in 1..streams.len() {
        let (w, _) = handshake(&streams[m], &mut readers[m], &cfg.job_id, Some(worker))?;
        if w != worker {
            return Err(transport_err(format!(
                "master {m} assigned slot {w}, master 0 assigned {worker}"
            )));
        }
    }
    if addrs.len() != spec.masters {
        return Err(transport_err(format!(
            "{} addresses for a {}-master job",
            addrs.len(),
            spec.masters
        )));
    }
    let group = spec.master_group()?.ok_or_else(|| {
        transport_err("multi-address connect to a single-master job".to_string())
    })?;

    let problem = spec.build_problem()?;
    if worker >= problem.num_workers() {
        return Err(transport_err(format!("assigned slot {worker} out of range")));
    }
    let pattern = std::sync::Arc::clone(problem.pattern().ok_or_else(|| {
        transport_err("master_group requires a block-sharded spec".to_string())
    })?);
    let local = std::sync::Arc::clone(problem.local(worker));
    // `(master, slice runs)` per owning master, ascending — the wire
    // layout both sides derive; no layout metadata rides the frames.
    let parts: Vec<(usize, Vec<(usize, usize)>)> = group
        .masters_of_worker(&pattern, worker)
        .into_iter()
        .map(|m| (m, group.worker_ranges(&pattern, worker, m)))
        .collect();
    // `master_group` rejects the alternative (dual-broadcasting) scheme.
    let protocol = Protocol::AdAdmm;
    let rho = spec.rho;

    let mut delay = DelayModel::linear_spread(
        spec.workers,
        spec.fast_ms,
        spec.slow_ms,
        0.3,
        spec.seed,
    )
    .sampler(worker);
    let faults: Option<FaultModel> = None;
    let mut fault_rng: Option<Pcg64> = None;

    let n = local.dim();
    let mut lam = vec![0.0; n]; // λ⁰ = 0 (reseed parts overwrite on reconnect)
    let mut x = vec![0.0; n];
    let mut x0 = vec![0.0; n];
    let mut scratch = WorkerScratch::new();
    // A short per-worker policy list from a malformed spec must fail this
    // worker's job, not panic the connection thread.
    let policy = match spec.inexact_workers.as_ref() {
        None => spec.inexact,
        Some(v) => *v.get(worker).ok_or_else(|| {
            transport_err(format!(
                "inexact_workers has {} entries but this worker was assigned slot {worker}",
                v.len()
            ))
        })?,
    };
    let mut warm = WarmState::default();
    let mut stats = WorkerStats::new(worker);
    let mut rounds = 0usize;
    let wall = Stopwatch::start();

    'rounds: loop {
        // Collect this round's `go` parts from every owning master,
        // stitching each into the full owned slice. A shutdown or closed
        // connection from any owning master ends the job.
        for (m, ranges) in &parts {
            let mut src = &streams[*m];
            let payload = match readers[*m]
                .next_frame(&mut src)
                .map_err(|e| transport_err(format!("read from master {m} failed: {e}")))?
            {
                Some(p) => p,
                None => break 'rounds,
            };
            let (px0, plam, reseed) = match WireMsg::decode(&payload).map_err(transport_err)? {
                WireMsg::Go { x0, lam, reseed } => (x0, lam, reseed),
                WireMsg::Shutdown => break 'rounds,
                other => {
                    return Err(transport_err(format!("expected go/shutdown, got {other:?}")))
                }
            };
            if plam.is_some() {
                // Only the rejected dual-broadcasting scheme ships duals
                // down; a dual part here means the ends disagree.
                return Err(transport_err(
                    "unexpected dual broadcast on a multi-master job".to_string(),
                ));
            }
            if let Some(r) = reseed {
                scatter(&mut lam, ranges, &r);
            }
            scatter(&mut x0, ranges, &px0);
        }
        let t0 = Instant::now();

        let ms = delay.sample_ms();
        if ms > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(ms * 1e-3));
        }

        let lam_out = worker_round(
            protocol,
            &*local,
            rho,
            &mut lam,
            &mut x,
            &x0,
            None,
            None,
            &mut scratch,
            &policy,
            &mut warm,
        );

        let cms = comm_leg_ms(None, faults.as_ref(), fault_rng.as_mut(), &mut stats, 1.0);
        if cms > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(cms * 1e-3));
        }

        for (m, ranges) in &parts {
            let up = WireMsg::Up {
                worker,
                x: extract(&x, ranges),
                lam: lam_out.as_ref().map(|l| extract(l, ranges)),
            };
            let mut sink = &streams[*m];
            write_frame(&mut sink, &up.encode())
                .map_err(|e| transport_err(format!("up write to master {m} failed: {e}")))?;
        }

        stats.updates += 1;
        stats.busy_s += t0.elapsed().as_secs_f64();
        rounds += 1;
        if cfg.max_rounds == Some(rounds) {
            break; // drop every connection cold — emulated process crash
        }
    }

    stats.lifetime_s = wall.now_s();
    Ok(stats)
}
