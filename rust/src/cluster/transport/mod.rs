//! Real-network transport: the star protocol of [`super`] spoken over
//! TCP, plus the long-lived solver service built on it.
//!
//! Layering, bottom up:
//!
//! - [`frame`] — length-prefixed frame codec (u32 LE length + payload,
//!   bounded, incremental reassembly over fragmented reads);
//! - [`wire`] — the typed messages inside frames, serialized with the
//!   in-repo JSON codec's bit-exact hex-f64 encoding;
//! - [`socket`] — [`SocketSource`], the TCP-backed
//!   [`WorkerSource`](crate::admm::engine::WorkerSource): disconnects are
//!   Assumption-1 outages, reconnects re-deliver the in-flight broadcast
//!   with the worker-held dual, lockstep runs are bit-comparable to trace
//!   replay;
//! - [`multisocket`] — [`MultiSocketSource`], M per-master rendezvous
//!   endpoints multiplexing each worker's owned slice across the masters
//!   owning its blocks (multi-master partitioned coordination,
//!   [`crate::cluster::multimaster`]);
//! - [`client`] — the worker-side process loop, sharing the round
//!   arithmetic with the threaded worker so both transports compute
//!   bit-identical messages;
//! - [`service`] — job specs, the per-job master runner, and the
//!   `admm-serve`/`submit` control plane.
//!
//! Everything here is dependency-free `std::net`; the engine above sees
//! only the [`WorkerSource`](crate::admm::engine::WorkerSource) trait.

pub mod frame;
pub mod wire;
pub mod socket;
pub mod multisocket;
pub mod client;
pub mod service;

pub use frame::{write_frame, FrameError, FrameEvent, FrameReader, MAX_FRAME_LEN};
pub use wire::WireMsg;
pub use socket::{SocketSource, TransportConfig, TransportStats};
pub use multisocket::MultiSocketSource;
pub use client::{run_worker, WorkerClientConfig};
pub use service::{
    roundrobin_trace, run_job, run_job_multi, run_reference, serve, submit, JobReport, JobSpec,
};
