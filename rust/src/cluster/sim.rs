//! Virtual-time star cluster: the Algorithm 2/4 protocol driven by a
//! deterministic discrete-event scheduler instead of OS threads.
//!
//! Every worker cycles through `Go → compute (ComputeDone event) →
//! transit (Arrive event) → absorbed by the master → Go`, with the
//! compute/comm durations drawn from the *same* [`super::DelaySampler`]s
//! the real-thread mode sleeps on. The master gathers arrivals until the
//! `|A_k| ≥ A` + τ-forcing gate is met, then performs the iteration.
//!
//! Two properties make this the CI workhorse:
//!
//! 1. **Bit-equivalence.** The per-iteration arithmetic (worker solves in
//!    ascending id order against their `x₀` snapshots, the shared
//!    [`iter_record`] bookkeeping) is the exact sequence of
//!    [`crate::admm::master_pov`]; replaying the realized
//!    [`ArrivalTrace`] through `run_master_pov` reproduces the history
//!    bit-for-bit (pinned by the `virtual_time` integration tests).
//! 2. **Scale.** No sleeps and no threads: a 1000-worker × 500-iteration
//!    sweep runs in fractions of a second, so the Section-V τ / `A`
//!    parameter sweeps run on every CI push.

use crate::admm::arrivals::ArrivalTrace;
use crate::admm::{
    divergence_or_tol_stop, iter_record, master_x0_update, MasterScratch, StopReason,
};
use crate::problems::{ConsensusProblem, WorkerScratch};
use crate::rng::Pcg64;
use crate::util::timer::Clock;

use super::clock::{Event, EventKind, EventQueue, VirtualClock};
use super::pool::WorkerPool;
use super::timeline::WorkerStats;
use super::worker::WorkerSolveFn;
use super::{ClusterConfig, ClusterReport, DelaySampler, FaultModel, Protocol};

/// Per-worker simulation state (delay streams + optional solve override).
struct VirtualWorker {
    compute: DelaySampler,
    comm: Option<DelaySampler>,
    fault_rng: Option<Pcg64>,
    solve: Option<WorkerSolveFn>,
    /// Reusable subproblem/eval buffers, reused across this worker's rounds
    /// (zero allocation in the compute hot path).
    scratch: WorkerScratch,
    /// Duration of the in-flight compute phase, charged to `busy_s` when
    /// the ComputeDone event fires (a round cut off by the end of the run
    /// is never charged — matching the threaded mode, which accounts busy
    /// time per *completed* round).
    inflight_compute_s: f64,
    /// Duration of the in-flight transit phase (comm + retransmissions),
    /// charged when the Arrive event fires.
    inflight_transit_s: f64,
}

/// One arrived worker's deferred round of arithmetic, fanned across the
/// [`WorkerPool`]. Each task owns mutable access to exactly the slots it
/// writes (`x_i`, `λ_i`, `f_cache[i]`, its worker's scratch) and reads only
/// shared immutable snapshots, so pooled execution is bit-identical to
/// serial regardless of scheduling.
struct SolveTask<'a> {
    worker: usize,
    solve: Option<&'a mut WorkerSolveFn>,
    scratch: &'a mut WorkerScratch,
    x: &'a mut Vec<f64>,
    lam: &'a mut Vec<f64>,
    f: &'a mut f64,
}

/// Start worker `i`'s next round at virtual instant `now_s`: sample its
/// compute delay and schedule the ComputeDone.
fn dispatch(w: &mut VirtualWorker, queue: &mut EventQueue, now_s: f64, worker: usize) {
    let compute_s = w.compute.sample_ms() * 1e-3;
    w.inflight_compute_s = compute_s;
    queue.push(now_s + compute_s, worker, EventKind::ComputeDone);
}

/// Process one event. ComputeDone enters the link (comm latency plus any
/// fault retransmissions, mirroring the threaded worker's `comm_faults`);
/// Arrive lands the message at the master and updates the gate counters.
fn absorb(
    ev: Event,
    workers: &mut [VirtualWorker],
    stats: &mut [WorkerStats],
    pending: &mut [bool],
    queue: &mut EventQueue,
    faults: Option<&FaultModel>,
    d: &[usize],
    tau: usize,
    arrived_count: &mut usize,
    forced_missing: &mut usize,
) {
    match ev.kind {
        EventKind::ComputeDone => {
            let w = &mut workers[ev.worker];
            stats[ev.worker].busy_s += w.inflight_compute_s;
            let mut transit_ms = match w.comm.as_mut() {
                Some(c) => c.sample_ms(),
                None => 0.0,
            };
            if let (Some(f), Some(rng)) = (faults, w.fault_rng.as_mut()) {
                while rng.bernoulli(f.drop_prob) {
                    transit_ms += f.retrans_ms;
                    stats[ev.worker].retransmissions += 1;
                }
            }
            w.inflight_transit_s = transit_ms * 1e-3;
            queue.push(ev.time_s + transit_ms * 1e-3, ev.worker, EventKind::Arrive);
        }
        EventKind::Arrive => {
            debug_assert!(!pending[ev.worker], "one outstanding message per worker");
            // The threaded worker's busy time covers the whole round
            // (compute sleep + comm sleep + retransmissions); charge the
            // transit leg now that it completed.
            stats[ev.worker].busy_s += workers[ev.worker].inflight_transit_s;
            pending[ev.worker] = true;
            stats[ev.worker].updates += 1;
            *arrived_count += 1;
            if d[ev.worker] + 1 >= tau {
                *forced_missing -= 1;
            }
        }
    }
}

/// Run the configured protocol in simulated time. Semantics of the
/// returned [`ClusterReport`] match the threaded mode, with all seconds
/// measured on the virtual clock.
pub(crate) fn run_virtual(
    problem: &ConsensusProblem,
    cfg: &ClusterConfig,
    solvers: Option<Vec<WorkerSolveFn>>,
) -> ClusterReport {
    let n_workers = problem.num_workers();
    let n = problem.dim();
    let rho = cfg.admm.rho;
    let tau = cfg.admm.tau;
    let protocol = cfg.protocol;

    let mut solver_list: Vec<Option<WorkerSolveFn>> = match solvers {
        Some(v) => {
            assert_eq!(v.len(), n_workers, "one solver per worker");
            v.into_iter().map(Some).collect()
        }
        None => (0..n_workers).map(|_| None).collect(),
    };
    let mut workers: Vec<VirtualWorker> = (0..n_workers)
        .map(|i| VirtualWorker {
            compute: cfg.delays.sampler(i),
            comm: cfg.comm_delays.as_ref().map(|d| d.sampler(i)),
            fault_rng: cfg
                .faults
                .as_ref()
                .map(|f| Pcg64::seed_from_u64(f.seed.wrapping_add(i as u64 * 0x5bd1))),
            solve: solver_list[i].take(),
            scratch: WorkerScratch::new(),
            inflight_compute_s: 0.0,
            inflight_transit_s: 0.0,
        })
        .collect();
    let mut stats: Vec<WorkerStats> = (0..n_workers).map(WorkerStats::new).collect();
    let pool = WorkerPool::new(cfg.pool_threads);

    let mut vclock = VirtualClock::new();
    let mut queue = EventQueue::new();

    let mut state = cfg.admm.initial_state(n_workers, n);
    // x₀^{k̄_i+1} as each worker last received it — same bookkeeping as the
    // serial simulator.
    let mut x0_snap: Vec<Vec<f64>> = vec![state.x0.clone(); n_workers];
    // Algorithm 4 additionally broadcasts the master-updated duals.
    let mut lam_snap: Vec<Vec<f64>> = state.lams.clone();
    let mut d = vec![0usize; n_workers];
    let mut history = Vec::with_capacity(cfg.admm.max_iters);
    let mut trace = ArrivalTrace::default();
    let mut prev_x0 = state.x0.clone();
    let mut stop = StopReason::MaxIters;
    let mut master_scratch = MasterScratch::new();
    let mut f_cache: Vec<f64> = Vec::with_capacity(n_workers);
    for i in 0..n_workers {
        f_cache.push(problem.local(i).eval_with(&state.xs[i], &mut master_scratch.ws));
    }
    let mut pending = vec![false; n_workers];
    let mut master_wait_s = 0.0;

    // Initial broadcast at t = 0: every worker starts computing against x⁰.
    for i in 0..n_workers {
        dispatch(&mut workers[i], &mut queue, vclock.now_s(), i);
    }

    for k in 0..cfg.admm.max_iters {
        let wait_from = vclock.now_s();
        // Gate counters, maintained incrementally so the gather loop is
        // O(1) per event (N can be in the thousands here).
        let mut arrived_count = pending.iter().filter(|&&p| p).count();
        let mut forced_missing = (0..n_workers)
            .filter(|&i| d[i] + 1 >= tau && !pending[i])
            .count();
        let target = cfg.admm.min_arrivals.min(n_workers);
        loop {
            if arrived_count >= target && forced_missing == 0 {
                // Absorb everything that has arrived by this instant — the
                // threaded master's try_recv drain.
                while queue.peek_time().is_some_and(|t| t <= vclock.now_s()) {
                    let ev = queue.pop().expect("peeked event");
                    absorb(
                        ev,
                        &mut workers,
                        &mut stats,
                        &mut pending,
                        &mut queue,
                        cfg.faults.as_ref(),
                        &d,
                        tau,
                        &mut arrived_count,
                        &mut forced_missing,
                    );
                }
                break;
            }
            match queue.pop() {
                Some(ev) => {
                    vclock.advance_to(ev.time_s);
                    absorb(
                        ev,
                        &mut workers,
                        &mut stats,
                        &mut pending,
                        &mut queue,
                        cfg.faults.as_ref(),
                        &d,
                        tau,
                        &mut arrived_count,
                        &mut forced_missing,
                    );
                }
                // Unreachable with ≥1 worker (every worker always has an
                // in-flight event), but mirror the threaded recv-Err path.
                None => break,
            }
        }
        master_wait_s += vclock.now_s() - wait_from;

        let set: Vec<usize> = (0..n_workers).filter(|&i| pending[i]).collect();
        // Deferred worker arithmetic: one task per arrived worker, built in
        // ascending id order and fanned across the pool. Every task writes
        // only its own slots against the shared immutable snapshots, so the
        // result is the exact bit sequence of the serial Algorithm-3
        // simulator for any pool size (pinned by the property tests).
        let mut tasks: Vec<SolveTask> = Vec::with_capacity(set.len());
        for (i, ((w, x), (lam, f))) in workers
            .iter_mut()
            .zip(state.xs.iter_mut())
            .zip(state.lams.iter_mut().zip(f_cache.iter_mut()))
            .enumerate()
        {
            if pending[i] {
                tasks.push(SolveTask {
                    worker: i,
                    solve: w.solve.as_mut(),
                    scratch: &mut w.scratch,
                    x,
                    lam,
                    f,
                });
            }
        }
        let x0_snaps = &x0_snap;
        let lam_snaps = &lam_snap;
        pool.run(&mut tasks, |t| {
            let i = t.worker;
            match protocol {
                Protocol::AdAdmm => {
                    // (19)/(23): solve against the worker's own dual and its
                    // x₀ snapshot, then (20)/(24): the dual update.
                    let snap = &x0_snaps[i];
                    match &mut t.solve {
                        Some(f) => (**f)(t.lam, snap, rho, t.x),
                        None => {
                            problem.local(i).solve_subproblem(t.lam, snap, rho, t.x, t.scratch)
                        }
                    }
                    for j in 0..n {
                        t.lam[j] += rho * (t.x[j] - snap[j]);
                    }
                }
                Protocol::AltScheme => {
                    // (47): solve against the master-broadcast (x̂₀, λ̂_i).
                    let (snap, lsnap) = (&x0_snaps[i], &lam_snaps[i]);
                    match &mut t.solve {
                        Some(f) => (**f)(lsnap, snap, rho, t.x),
                        None => {
                            problem.local(i).solve_subproblem(lsnap, snap, rho, t.x, t.scratch)
                        }
                    }
                }
            }
            *t.f = problem.local(i).eval_with(t.x, t.scratch);
        });
        drop(tasks);
        for i in 0..n_workers {
            if pending[i] {
                d[i] = 0;
            } else {
                d[i] += 1;
            }
        }

        // (12)/(25)/(45): master x₀ update.
        prev_x0.copy_from_slice(&state.x0);
        master_x0_update(problem, &mut state, rho, cfg.admm.gamma, &mut master_scratch);

        // Algorithm 4 (46): master updates ALL duals against fresh x₀.
        if protocol == Protocol::AltScheme {
            for i in 0..n_workers {
                for j in 0..n {
                    state.lams[i][j] += rho * (state.xs[i][j] - state.x0[j]);
                }
            }
        }

        // Step 6: broadcast to the arrived workers only and start their
        // next round at the current virtual instant.
        for &i in &set {
            pending[i] = false;
            x0_snap[i].copy_from_slice(&state.x0);
            if protocol == Protocol::AltScheme {
                lam_snap[i].copy_from_slice(&state.lams[i]);
            }
            dispatch(&mut workers[i], &mut queue, vclock.now_s(), i);
        }

        let rec = iter_record(
            problem,
            &state,
            &cfg.admm,
            k,
            set.len(),
            &f_cache,
            &mut master_scratch,
            &prev_x0,
        );
        let early = divergence_or_tol_stop(&cfg.admm, &state, &rec, k);
        history.push(rec);
        trace.sets.push(set);

        if let Some(reason) = early {
            stop = reason;
            break;
        }
        if let Some(rule) = &cfg.admm.stopping {
            let r = crate::admm::stopping::residuals(&state, &prev_x0, rho);
            if k > 0 && rule.satisfied(&r, n, n_workers) {
                stop = StopReason::Residuals;
                break;
            }
        }
    }

    let total_s = vclock.now_s();
    for w in stats.iter_mut() {
        w.lifetime_s = total_s;
    }

    ClusterReport {
        state,
        history,
        trace,
        stop,
        wall_clock_s: total_s,
        master_wait_s,
        workers: stats,
    }
}

#[cfg(test)]
mod tests {
    use crate::admm::AdmmConfig;
    use crate::cluster::{ClusterConfig, DelayModel, ExecutionMode, StarCluster};
    use crate::data::LassoInstance;
    use crate::rng::Pcg64;

    fn problem(seed: u64, n_workers: usize) -> crate::problems::ConsensusProblem {
        let mut rng = Pcg64::seed_from_u64(seed);
        LassoInstance::synthetic(&mut rng, n_workers, 20, 10, 0.2, 0.1).problem()
    }

    fn virt_cfg(tau: usize, min_arrivals: usize, max_iters: usize) -> ClusterConfig {
        ClusterConfig {
            admm: AdmmConfig { rho: 50.0, tau, min_arrivals, max_iters, ..Default::default() },
            delays: DelayModel::LogNormal {
                mean_ms: vec![1.0, 2.0, 4.0, 8.0],
                sigma: 0.3,
                seed: 7,
            },
            mode: ExecutionMode::VirtualTime,
            ..Default::default()
        }
    }

    #[test]
    fn virtual_run_is_deterministic() {
        let p = problem(801, 4);
        let cfg = virt_cfg(4, 1, 80);
        let a = StarCluster::new(p.clone()).run(&cfg);
        let b = StarCluster::new(p).run(&cfg);
        assert_eq!(a.trace, b.trace, "same seed must realize the same arrival sets");
        assert_eq!(a.state.x0, b.state.x0);
        assert_eq!(a.wall_clock_s, b.wall_clock_s, "virtual time is exact");
    }

    #[test]
    fn pooled_virtual_run_matches_serial() {
        let p = problem(805, 4);
        let serial = StarCluster::new(p.clone()).run(&virt_cfg(3, 1, 70));
        let mut cfg = virt_cfg(3, 1, 70);
        cfg.pool_threads = 3;
        let pooled = StarCluster::new(p).run(&cfg);
        assert_eq!(serial.trace, pooled.trace);
        assert_eq!(serial.state.x0, pooled.state.x0);
        assert_eq!(serial.state.xs, pooled.state.xs);
        assert_eq!(serial.state.lams, pooled.state.lams);
        assert_eq!(serial.wall_clock_s, pooled.wall_clock_s);
    }

    #[test]
    fn virtual_trace_respects_gate_and_tau() {
        let p = problem(802, 4);
        let tau = 3;
        let cfg = virt_cfg(tau, 2, 150);
        let report = StarCluster::new(p).run(&cfg);
        assert!(report.trace.satisfies_bounded_delay(4, tau));
        assert!(report.trace.sets.iter().all(|s| s.len() >= 2));
    }

    #[test]
    fn virtual_time_accounts_busy_and_wait() {
        let p = problem(803, 3);
        let mut cfg = virt_cfg(5, 1, 60);
        cfg.delays = DelayModel::Fixed { per_worker_ms: vec![1.0, 2.0, 3.0] };
        let report = StarCluster::new(p).run(&cfg);
        assert!(report.wall_clock_s > 0.0);
        assert!(report.master_wait_s <= report.wall_clock_s + 1e-12);
        for w in &report.workers {
            assert!(w.updates > 0);
            // busy time covers the compute phase of every *absorbed* round
            let expected = w.updates as f64;
            assert!(
                w.busy_s * 1e3 >= expected * (w.id + 1) as f64 - 1e-6,
                "worker {} busy {:.6}s over {} absorbed updates",
                w.id,
                w.busy_s,
                w.updates
            );
            // ...and never counts rounds cut off by the end of the run
            assert!(w.busy_s <= w.lifetime_s + 1e-12);
            // lifetime is the full simulated run for every worker
            assert_eq!(w.lifetime_s, report.wall_clock_s);
        }
        // the run summarizes into a Timeline like any threaded run
        let tl = crate::cluster::Timeline::from_report(&report);
        assert_eq!(tl.master_iters, report.history.len());
        assert_eq!(
            tl.total_updates(),
            report.workers.iter().map(|w| w.updates).sum::<usize>()
        );
        assert!(tl.render().contains("master iterations: 60"));
    }

    #[test]
    fn fixed_equal_delays_run_synchronously() {
        let p = problem(804, 4);
        let mut cfg = virt_cfg(1, 4, 50);
        cfg.delays = DelayModel::Fixed { per_worker_ms: vec![2.0; 4] };
        let report = StarCluster::new(p).run(&cfg);
        // equal delays + τ=1 gate: every iteration sees all 4 workers
        assert!(report.trace.sets.iter().all(|s| s.len() == 4));
        // 50 synchronous rounds at 2 ms each ≈ 100 ms of simulated time
        assert!((report.wall_clock_s - 0.1).abs() < 1e-9, "t={}", report.wall_clock_s);
    }
}
