//! Virtual-time star cluster: the Algorithm 2/4 protocol driven by a
//! deterministic discrete-event scheduler instead of OS threads.
//!
//! Since the engine refactor this module is a [`WorkerSource`]
//! implementation plus a thin wrapper: the per-iteration ADMM state
//! machine lives in [`crate::admm::engine::run_engine`]; what remains here
//! is purely the *event mechanics* — every worker cycles through `Go →
//! compute (ComputeDone event) → transit (Arrive event) → absorbed by the
//! master → Go`, with compute/comm durations drawn from the *same*
//! `DelaySampler`s the real-thread mode sleeps on. The master
//! gathers arrivals until the `|A_k| ≥ A` + τ-forcing gate is met, then
//! the engine performs the iteration.
//!
//! Two properties make this the CI workhorse:
//!
//! 1. **Bit-equivalence.** The per-iteration arithmetic (worker solves in
//!    ascending id order against their `x₀` snapshots, the shared
//!    `iter_record` bookkeeping) is the exact sequence of
//!    [`crate::admm::master_pov`]; replaying the realized
//!    [`ArrivalTrace`](crate::admm::arrivals::ArrivalTrace) through
//!    `run_master_pov` reproduces the history bit-for-bit (pinned by the
//!    `virtual_time` and `engine_equivalence` integration tests).
//! 2. **Scale.** No sleeps and no threads: a 1000-worker × 500-iteration
//!    sweep runs in fractions of a second, so the Section-V τ / `A`
//!    parameter sweeps — and now the fault/straggler sweeps — run on every
//!    CI push.
//!
//! Fault injection: [`FaultPlan`](crate::admm::engine::FaultPlan) outages
//! gate the master's bookkeeping inside the engine (a down worker's Arrive
//! event still fires, but the message is *held* — `pending` — until
//! rejoin, so the worker re-enters with the stale iterate it computed
//! against its pre-outage snapshot); delay spikes stretch this source's
//! compute/transit legs on the virtual clock.

use std::sync::Arc;

use crate::admm::engine::{ActiveSet, Gate, MasterView, UpdatePolicy, WorkerSource};
use crate::admm::session::{jget, EngineError};
use crate::admm::AdmmState;
use crate::bench::json::{
    f64_from_hex, hex_f64, hex_mat, hex_u128, json_usize, mat_from_hex, u128_from_hex, JsonValue,
};
use crate::problems::{BlockPattern, ConsensusProblem, WorkerScratch};
use crate::rng::Pcg64;
use crate::solvers::inexact::{solve_inexact, InexactPolicy, WarmState};
use crate::util::timer::Clock;

use super::clock::{Event, EventKind, EventQueue, VirtualClock};
use super::multimaster::MasterGroup;
use super::pool::WorkerPool;
use super::timeline::WorkerStats;
use super::worker::WorkerSolveFn;
use super::{ClusterConfig, ClusterReport, DelaySampler, FaultModel};

/// Simulated master-side processing cost per absorbed f64 coordinate
/// (folding one accumulator entry ≈ 10 ns). Pure *metering* — it never
/// enters the event timings, so enabling the meter leaves every run
/// bit-identical; the `virtual_scale` bench uses the resulting per-master
/// busy split to report `multimaster_speedup`.
const MASTER_PER_F64_S: f64 = 1e-8;

/// Per-worker simulation state (delay streams + optional solve override).
struct VirtualWorker {
    compute: DelaySampler,
    comm: Option<DelaySampler>,
    fault_rng: Option<Pcg64>,
    solve: Option<WorkerSolveFn>,
    /// Reusable subproblem/eval buffers, reused across this worker's rounds
    /// (zero allocation in the compute hot path).
    scratch: WorkerScratch,
    /// Inexact-policy warm start: previous iterate + cached step size,
    /// persisting across this worker's rounds (and into checkpoints).
    warm: WarmState,
}

/// One arrived worker's deferred round of arithmetic, fanned across the
/// [`WorkerPool`]. Each task owns mutable access to exactly the slots it
/// writes (`x_i`, `λ_i`, `f_cache[i]`, its worker's scratch) and reads only
/// shared immutable snapshots, so pooled execution is bit-identical to
/// serial regardless of scheduling.
struct SolveTask<'a> {
    worker: usize,
    solve: Option<&'a mut WorkerSolveFn>,
    scratch: &'a mut WorkerScratch,
    x: &'a mut Vec<f64>,
    lam: &'a mut Vec<f64>,
    f: &'a mut f64,
    warm: &'a mut WarmState,
}

/// The discrete-event [`WorkerSource`]: mirrors the threaded star cluster
/// event-for-event on a [`VirtualClock`], deterministically.
///
/// Public (with crate-internal construction through
/// [`super::StarCluster::virtual_session`]) so incremental sessions can be
/// typed as `Session<'_, VirtualSource>` and hand the source back by value
/// at [`crate::admm::session::Session::finish`] — that is how the
/// utilization stats survive into a [`super::ClusterReport`]. Unlike the
/// real-thread source this one is fully checkpointable: the event queue,
/// virtual clock, per-worker delay/fault RNG streams, held messages and
/// execution stats all serialize, so a resumed simulation continues
/// bit-identically.
pub struct VirtualSource {
    workers: Vec<VirtualWorker>,
    /// Duration of each worker's in-flight compute phase, charged to
    /// `busy_s` when the ComputeDone event fires (a round cut off by the
    /// end of the run is never charged — matching the threaded mode, which
    /// accounts busy time per *completed* round). Structure-of-arrays: the
    /// event loop touches only these scalars per event, so a 10⁶-worker
    /// sweep stays cache-friendly instead of striding over the fat
    /// `VirtualWorker` records (sampler state, scratch buffers).
    inflight_compute_s: Vec<f64>,
    /// Duration of each worker's in-flight transit phase (comm +
    /// retransmissions), charged when the Arrive event fires.
    inflight_transit_s: Vec<f64>,
    /// Per-worker execution stats, kept as parallel arrays for the same
    /// cache-locality reason; materialized into [`WorkerStats`] rows at
    /// [`VirtualSource::finish`].
    stat_updates: Vec<usize>,
    stat_busy_s: Vec<f64>,
    stat_retransmissions: Vec<usize>,
    pool: WorkerPool,
    vclock: VirtualClock,
    queue: EventQueue,
    /// One outstanding message per worker, *held* here until the master
    /// absorbs it (possibly several iterations later, under outages).
    pending: Vec<bool>,
    /// Block-sharding pattern (None = dense). Snapshots are owned slices
    /// under a pattern, and message transit times scale with the
    /// owned-slice length (`comm_scale`).
    shard: Option<Arc<BlockPattern>>,
    /// Per-worker transit-time factor `|S_i| / n` — messages carry only
    /// the owned slice, so link time shrinks proportionally. Empty (no
    /// scaling) for dense runs, leaving their event timings untouched.
    comm_scale: Vec<f64>,
    /// `x₀^{k̄_i+1}` as worker i last received it.
    x0_snap: Vec<Vec<f64>>,
    /// `λ̂_i` as worker i last received it (Algorithm 4 only).
    lam_snap: Vec<Vec<f64>>,
    faults: Option<FaultModel>,
    fault_plan: Option<crate::admm::engine::FaultPlan>,
    master_wait_s: f64,
    /// Per-worker inexactness policies, applied to the native worker
    /// solves (`Exact` = the historical closed-form path, bit-identical).
    /// Uniform — one copy of `cfg.admm.inexact` per worker — unless the
    /// config carries per-worker overrides.
    policies: Vec<InexactPolicy>,
    /// Coordinator partition (None = the classic single master). With a
    /// group installed every master runs its own `|A_k| ≥ A` + τ-forcing
    /// gate over *its own fleet* (the workers owning at least one of its
    /// blocks), and the byte/busy meters split per master. Installed via
    /// [`VirtualSource::set_master_group`] before the run starts.
    group: Option<Arc<MasterGroup>>,
    /// Per worker: `(master, part f64 length)` rows of its owned slice,
    /// ascending in master id. Empty vecs when single-master.
    worker_parts: Vec<Vec<(usize, usize)>>,
    /// Per-master downlink byte meters (len = M; a single unused slot when
    /// no group is installed — [`VirtualSource::master_split`] then
    /// mirrors the globals). Invariant: rows sum to the global counters.
    m_bytes_down: Vec<u64>,
    /// Per-master uplink byte meters (see `m_bytes_down`).
    m_bytes_up: Vec<u64>,
    /// Per-master simulated busy seconds — [`MASTER_PER_F64_S`] per
    /// absorbed f64 coordinate. Metered in *both* modes so the
    /// `virtual_scale` bench can ratio an M-way split against the
    /// single-master baseline (`multimaster_speedup`).
    m_busy_s: Vec<f64>,
    /// Simulated payload bytes shipped master → workers (x₀ slices, plus
    /// λ̂ under Algorithm 4), at 8 bytes per f64. Deterministic, so it
    /// doubles as a cheap cross-run network-volume metric.
    bytes_down: u64,
    /// Simulated payload bytes shipped workers → master (x̂ slices, plus
    /// the worker-updated dual under Algorithm 2), counted at absorption.
    bytes_up: u64,
}

impl VirtualSource {
    pub(crate) fn new(
        n_workers: usize,
        cfg: &ClusterConfig,
        solvers: Option<Vec<WorkerSolveFn>>,
        shard: Option<Arc<BlockPattern>>,
    ) -> Self {
        let mut solver_list: Vec<Option<WorkerSolveFn>> = match solvers {
            Some(v) => {
                assert_eq!(v.len(), n_workers, "one solver per worker");
                v.into_iter().map(Some).collect()
            }
            None => (0..n_workers).map(|_| None).collect(),
        };
        let workers: Vec<VirtualWorker> = (0..n_workers)
            .map(|i| VirtualWorker {
                compute: cfg.delays.sampler(i),
                comm: cfg.comm_delays.as_ref().map(|d| d.sampler(i)),
                fault_rng: cfg
                    .faults
                    .as_ref()
                    .map(|f| Pcg64::seed_from_u64(f.seed.wrapping_add(i as u64 * 0x5bd1))),
                solve: solver_list[i].take(),
                scratch: WorkerScratch::new(),
                warm: WarmState::default(),
            })
            .collect();
        let comm_scale = match &shard {
            None => Vec::new(),
            Some(p) => {
                let n = p.dim() as f64;
                (0..n_workers).map(|i| p.owned_len(i) as f64 / n).collect()
            }
        };
        VirtualSource {
            workers,
            inflight_compute_s: vec![0.0; n_workers],
            inflight_transit_s: vec![0.0; n_workers],
            stat_updates: vec![0; n_workers],
            stat_busy_s: vec![0.0; n_workers],
            stat_retransmissions: vec![0; n_workers],
            pool: WorkerPool::new(cfg.pool_threads),
            vclock: VirtualClock::new(),
            queue: EventQueue::new(),
            pending: vec![false; n_workers],
            shard,
            comm_scale,
            x0_snap: Vec::new(),
            lam_snap: Vec::new(),
            faults: cfg.faults.clone(),
            fault_plan: cfg.fault_plan.clone(),
            master_wait_s: 0.0,
            policies: match &cfg.inexact_per_worker {
                Some(v) => {
                    assert_eq!(v.len(), n_workers, "one inexact policy per worker");
                    v.clone()
                }
                None => vec![cfg.admm.inexact; n_workers],
            },
            group: None,
            worker_parts: Vec::new(),
            m_bytes_down: vec![0],
            m_bytes_up: vec![0],
            m_busy_s: vec![0.0],
            bytes_down: 0,
            bytes_up: 0,
        }
    }

    /// Install the coordinator partition: precompute each worker's
    /// per-master slice parts and size the per-master meters. Must be
    /// called on a block-sharded source before the run starts (the
    /// session/cluster layers do this during construction).
    pub(crate) fn set_master_group(&mut self, group: Arc<MasterGroup>) {
        // ad-lint: allow(panic-free-lib): construction-order invariant: the session/cluster layers shard the source before installing the group
        let p = self.shard.as_ref().expect("multi-master requires a block-sharded source");
        let n = self.pending.len();
        self.worker_parts = (0..n)
            .map(|i| {
                group
                    .masters_of_worker(p, i)
                    .into_iter()
                    .map(|m| (m, group.worker_part_len(p, i, m)))
                    .collect()
            })
            .collect();
        let mm = group.num_masters();
        self.m_bytes_down = vec![0; mm];
        self.m_bytes_up = vec![0; mm];
        self.m_busy_s = vec![0.0; mm];
        self.group = Some(group);
    }

    /// Simulated network volume so far as `(bytes_down, bytes_up)`:
    /// master→worker payloads (x₀ slices + λ̂ under Algorithm 4) and
    /// worker→master payloads (x̂ slices + λ under Algorithm 2), at 8
    /// bytes per f64. Deterministic for a given config, so sweeps can use
    /// it as a comm-volume metric without a real transport.
    pub fn network_bytes(&self) -> (u64, u64) {
        (self.bytes_down, self.bytes_up)
    }

    /// Per-master network split, one `(bytes_down, bytes_up)` row per
    /// coordinator — a single row mirroring [`VirtualSource::network_bytes`]
    /// when no master group is installed. Invariant (unit-tested): the rows
    /// sum to the global counters, because every worker's owned slice is
    /// partitioned exactly once across its owning masters.
    pub fn master_split(&self) -> Vec<(u64, u64)> {
        match &self.group {
            None => vec![(self.bytes_down, self.bytes_up)],
            Some(_) => {
                self.m_bytes_down.iter().zip(&self.m_bytes_up).map(|(&d, &u)| (d, u)).collect()
            }
        }
    }

    /// Per-master simulated busy seconds ([`MASTER_PER_F64_S`] per folded
    /// f64 at absorption); a single entry when single-master. Pure meter —
    /// never feeds back into event timings.
    pub fn master_busy_s(&self) -> &[f64] {
        &self.m_busy_s
    }

    /// The installed coordinator partition, if any.
    pub fn master_group(&self) -> Option<&MasterGroup> {
        self.group.as_deref()
    }

    /// Start worker `i`'s next round at the current virtual instant:
    /// sample its compute delay (stretched by any active delay spike) and
    /// schedule the ComputeDone.
    fn dispatch(&mut self, i: usize) {
        let now_s = self.vclock.now_s();
        let mut compute_s = self.workers[i].compute.sample_ms() * 1e-3;
        if let Some(plan) = &self.fault_plan {
            compute_s *= plan.delay_factor(i, now_s);
        }
        self.inflight_compute_s[i] = compute_s;
        self.queue.push(now_s + compute_s, i, EventKind::ComputeDone);
    }

    /// Process one event. ComputeDone enters the link (comm latency plus
    /// any fault retransmissions, mirroring the threaded worker's
    /// `comm_faults`); Arrive lands the message at the master and updates
    /// the gate counters — unless the worker is down, in which case the
    /// message is held (`pending`) without counting. Under a master group
    /// the same arrival also counts once at every owning master
    /// (`m_arrived` / `m_forced`, empty slices when single-master).
    #[allow(clippy::too_many_arguments)]
    fn absorb_event(
        &mut self,
        ev: Event,
        d: &[usize],
        gate: &Gate<'_>,
        arrived_count: &mut usize,
        forced_missing: &mut usize,
        m_arrived: &mut [usize],
        m_forced: &mut [usize],
    ) {
        match ev.kind {
            EventKind::ComputeDone => {
                let w = &mut self.workers[ev.worker];
                self.stat_busy_s[ev.worker] += self.inflight_compute_s[ev.worker];
                let mut transit_ms = match w.comm.as_mut() {
                    Some(c) => c.sample_ms(),
                    None => 0.0,
                };
                if let (Some(f), Some(rng)) = (self.faults.as_ref(), w.fault_rng.as_mut()) {
                    while rng.bernoulli(f.drop_prob) {
                        transit_ms += f.retrans_ms;
                        self.stat_retransmissions[ev.worker] += 1;
                    }
                }
                let mut transit_s = transit_ms * 1e-3;
                if let Some(plan) = &self.fault_plan {
                    transit_s *= plan.delay_factor(ev.worker, ev.time_s);
                }
                // Sharded messages carry only the owned slice: link time
                // scales with |S_i| / n (empty = dense, no scaling).
                if let Some(&scale) = self.comm_scale.get(ev.worker) {
                    transit_s *= scale;
                }
                self.inflight_transit_s[ev.worker] = transit_s;
                self.queue.push(ev.time_s + transit_s, ev.worker, EventKind::Arrive);
            }
            EventKind::Arrive => {
                debug_assert!(!self.pending[ev.worker], "one outstanding message per worker");
                // The threaded worker's busy time covers the whole round
                // (compute sleep + comm sleep + retransmissions); charge the
                // transit leg now that it completed.
                self.stat_busy_s[ev.worker] += self.inflight_transit_s[ev.worker];
                self.pending[ev.worker] = true;
                self.stat_updates[ev.worker] += 1;
                if !gate.down[ev.worker] {
                    *arrived_count += 1;
                    let forced = d[ev.worker] + 1 >= gate.tau;
                    if forced {
                        *forced_missing -= 1;
                    }
                    if self.group.is_some() {
                        for &(m, _) in &self.worker_parts[ev.worker] {
                            m_arrived[m] += 1;
                            if forced {
                                m_forced[m] -= 1;
                            }
                        }
                    }
                }
            }
        }
    }

    /// Consume the source at end of run: per-worker stats (lifetimes
    /// stamped with the final virtual instant), total simulated seconds,
    /// and the master's simulated wait.
    pub fn finish(self) -> (Vec<WorkerStats>, f64, f64) {
        let total_s = self.vclock.now_s();
        let stats = (0..self.pending.len())
            .map(|i| WorkerStats {
                id: i,
                updates: self.stat_updates[i],
                busy_s: self.stat_busy_s[i],
                lifetime_s: total_s,
                retransmissions: self.stat_retransmissions[i],
            })
            .collect();
        (stats, total_s, self.master_wait_s)
    }
}

impl WorkerSource for VirtualSource {
    fn n_workers(&self) -> usize {
        self.pending.len()
    }

    fn kind(&self) -> &'static str {
        "virtual"
    }

    fn supports_sharding(&self) -> bool {
        self.shard.is_some()
    }

    fn save_checkpoint(&self) -> Result<JsonValue, EngineError> {
        let (events, next_seq) = self.queue.snapshot();
        let events_json = JsonValue::Arr(
            events
                .iter()
                .map(|e| {
                    JsonValue::Obj(vec![
                        ("t".to_string(), hex_f64(e.time_s)),
                        ("seq".to_string(), JsonValue::Num(e.seq as f64)),
                        ("worker".to_string(), JsonValue::Num(e.worker as f64)),
                        (
                            "kind".to_string(),
                            match e.kind {
                                EventKind::ComputeDone => "compute_done",
                                EventKind::Arrive => "arrive",
                            }
                            .into(),
                        ),
                    ])
                })
                .collect(),
        );
        let workers_json = JsonValue::Arr(
            self.workers
                .iter()
                .enumerate()
                .map(|(i, w)| {
                    let fault_rng = match &w.fault_rng {
                        None => JsonValue::Null,
                        Some(rng) => {
                            let (state, inc) = rng.to_raw();
                            JsonValue::Obj(vec![
                                ("rng_state".to_string(), hex_u128(state)),
                                ("rng_inc".to_string(), hex_u128(inc)),
                            ])
                        }
                    };
                    JsonValue::Obj(vec![
                        ("compute".to_string(), w.compute.save()),
                        (
                            "comm".to_string(),
                            match &w.comm {
                                Some(c) => c.save(),
                                None => JsonValue::Null,
                            },
                        ),
                        ("fault_rng".to_string(), fault_rng),
                        (
                            "inflight_compute_s".to_string(),
                            hex_f64(self.inflight_compute_s[i]),
                        ),
                        (
                            "inflight_transit_s".to_string(),
                            hex_f64(self.inflight_transit_s[i]),
                        ),
                        ("updates".to_string(), JsonValue::Num(self.stat_updates[i] as f64)),
                        ("busy_s".to_string(), hex_f64(self.stat_busy_s[i])),
                        (
                            "retransmissions".to_string(),
                            JsonValue::Num(self.stat_retransmissions[i] as f64),
                        ),
                        ("warm".to_string(), w.warm.to_json()),
                    ])
                })
                .collect(),
        );
        Ok(JsonValue::Obj(vec![
            ("now_s".to_string(), hex_f64(self.vclock.now_s())),
            ("master_wait_s".to_string(), hex_f64(self.master_wait_s)),
            ("next_seq".to_string(), JsonValue::Num(next_seq as f64)),
            ("events".to_string(), events_json),
            (
                "pending".to_string(),
                JsonValue::Arr(self.pending.iter().map(|&p| JsonValue::Bool(p)).collect()),
            ),
            ("x0_snap".to_string(), hex_mat(&self.x0_snap)),
            ("lam_snap".to_string(), hex_mat(&self.lam_snap)),
            ("bytes_down".to_string(), hex_u128(self.bytes_down as u128)),
            ("bytes_up".to_string(), hex_u128(self.bytes_up as u128)),
            (
                "m_bytes_down".to_string(),
                JsonValue::Arr(
                    self.m_bytes_down.iter().map(|&b| hex_u128(b as u128)).collect(),
                ),
            ),
            (
                "m_bytes_up".to_string(),
                JsonValue::Arr(self.m_bytes_up.iter().map(|&b| hex_u128(b as u128)).collect()),
            ),
            (
                "m_busy_s".to_string(),
                JsonValue::Arr(self.m_busy_s.iter().map(|&s| hex_f64(s)).collect()),
            ),
            ("workers".to_string(), workers_json),
        ]))
    }

    fn load_checkpoint(&mut self, doc: &JsonValue) -> Result<(), EngineError> {
        let n = self.pending.len();
        let bad = |msg: String| EngineError::Checkpoint(msg);

        let now_s = f64_from_hex(jget(doc, "now_s")?).map_err(bad)?;
        let master_wait_s = f64_from_hex(jget(doc, "master_wait_s")?).map_err(bad)?;
        let next_seq = json_usize(jget(doc, "next_seq")?).map_err(bad)? as u64;

        let mut events = Vec::new();
        for ev in jget(doc, "events")?.items() {
            let time_s = f64_from_hex(jget(ev, "t")?).map_err(bad)?;
            let seq = json_usize(jget(ev, "seq")?).map_err(bad)? as u64;
            let worker = json_usize(jget(ev, "worker")?).map_err(bad)?;
            if worker >= n {
                return Err(bad(format!("event worker index {worker} out of range")));
            }
            let kind = match jget(ev, "kind")?.as_str() {
                Some("compute_done") => EventKind::ComputeDone,
                Some("arrive") => EventKind::Arrive,
                other => return Err(bad(format!("bad event kind {other:?}"))),
            };
            events.push(Event { time_s, seq, worker, kind });
        }

        let pending_json = jget(doc, "pending")?;
        if pending_json.items().len() != n {
            return Err(bad("pending mask length mismatch".to_string()));
        }
        let mut pending = Vec::with_capacity(n);
        for v in pending_json.items() {
            pending.push(
                v.as_bool().ok_or_else(|| bad("pending mask entry is not a bool".to_string()))?,
            );
        }

        let x0_snap = mat_from_hex(jget(doc, "x0_snap")?).map_err(bad)?;
        let lam_snap = mat_from_hex(jget(doc, "lam_snap")?).map_err(bad)?;
        if x0_snap.len() != n || lam_snap.len() != n {
            return Err(bad("snapshot worker count mismatch".to_string()));
        }

        let workers_json = jget(doc, "workers")?;
        if workers_json.items().len() != n {
            return Err(bad("per-worker state count mismatch".to_string()));
        }
        for (i, wdoc) in workers_json.items().iter().enumerate() {
            let w = &mut self.workers[i];
            w.compute.load(jget(wdoc, "compute")?).map_err(bad)?;
            match (&mut w.comm, jget(wdoc, "comm")?) {
                (None, JsonValue::Null) => {}
                (Some(c), comm_doc) => c.load(comm_doc).map_err(bad)?,
                (None, _) => {
                    return Err(bad(format!(
                        "worker {i} checkpoint has comm state but the config has no comm model"
                    )))
                }
            }
            match (&mut w.fault_rng, jget(wdoc, "fault_rng")?) {
                (None, JsonValue::Null) => {}
                (Some(rng), frng @ JsonValue::Obj(_)) => {
                    let state = u128_from_hex(jget(frng, "rng_state")?).map_err(bad)?;
                    let inc = u128_from_hex(jget(frng, "rng_inc")?).map_err(bad)?;
                    *rng = Pcg64::from_raw(state, inc);
                }
                _ => {
                    return Err(bad(format!(
                        "worker {i} fault-rng checkpoint does not match the configured faults"
                    )))
                }
            }
            self.inflight_compute_s[i] =
                f64_from_hex(jget(wdoc, "inflight_compute_s")?).map_err(bad)?;
            self.inflight_transit_s[i] =
                f64_from_hex(jget(wdoc, "inflight_transit_s")?).map_err(bad)?;
            self.stat_updates[i] = json_usize(jget(wdoc, "updates")?).map_err(bad)?;
            self.stat_busy_s[i] = f64_from_hex(jget(wdoc, "busy_s")?).map_err(bad)?;
            self.stat_retransmissions[i] =
                json_usize(jget(wdoc, "retransmissions")?).map_err(bad)?;
            // Warm-start state is absent in pre-v3 checkpoints (which the
            // session layer only accepts under the Exact policy, where a
            // cold warm state is semantically identical).
            w.warm = match wdoc.get("warm") {
                Some(wj) => WarmState::from_json(wj).map_err(bad)?,
                None => WarmState::default(),
            };
        }
        self.bytes_down = match doc.get("bytes_down") {
            Some(v) => u128_from_hex(v).map_err(bad)? as u64,
            None => 0,
        };
        self.bytes_up = match doc.get("bytes_up") {
            Some(v) => u128_from_hex(v).map_err(bad)? as u64,
            None => 0,
        };
        // Per-master meters: absent in pre-v4 documents, which the session
        // layer only accepts into single-master sessions — there the
        // single row mirrors the globals, so zeros are never observed.
        if let Some(v) = doc.get("m_bytes_down") {
            let items = v.items();
            if items.len() != self.m_bytes_down.len() {
                return Err(bad("per-master downlink meter count mismatch".to_string()));
            }
            for (slot, item) in self.m_bytes_down.iter_mut().zip(items) {
                *slot = u128_from_hex(item).map_err(bad)? as u64;
            }
        }
        if let Some(v) = doc.get("m_bytes_up") {
            let items = v.items();
            if items.len() != self.m_bytes_up.len() {
                return Err(bad("per-master uplink meter count mismatch".to_string()));
            }
            for (slot, item) in self.m_bytes_up.iter_mut().zip(items) {
                *slot = u128_from_hex(item).map_err(bad)? as u64;
            }
        }
        if let Some(v) = doc.get("m_busy_s") {
            let items = v.items();
            if items.len() != self.m_busy_s.len() {
                return Err(bad("per-master busy meter count mismatch".to_string()));
            }
            for (slot, item) in self.m_busy_s.iter_mut().zip(items) {
                *slot = f64_from_hex(item).map_err(bad)?;
            }
        }

        self.vclock = VirtualClock::new();
        self.vclock.advance_to(now_s);
        self.master_wait_s = master_wait_s;
        self.queue = EventQueue::restore(events, next_seq);
        self.pending = pending;
        self.x0_snap = x0_snap;
        self.lam_snap = lam_snap;
        Ok(())
    }

    fn start(&mut self, state: &AdmmState, policy: &dyn UpdatePolicy) {
        let n_workers = self.pending.len();
        // x₀^{k̄_i+1} as each worker last received it — same bookkeeping
        // as the serial simulator; Algorithm 4 additionally broadcasts the
        // master-updated duals. Sharded workers receive owned slices.
        self.x0_snap = match &self.shard {
            None => vec![state.x0.clone(); n_workers],
            Some(p) => (0..n_workers).map(|i| p.gather_vec(i, &state.x0)).collect(),
        };
        self.lam_snap = state.lams.clone();
        // Initial broadcast at t = 0: every worker starts computing
        // against x⁰.
        let with_dual = policy.broadcasts_dual();
        let down_mult = if with_dual { 2 } else { 1 };
        for i in 0..n_workers {
            self.bytes_down += 8 * (self.x0_snap[i].len()
                + if with_dual { self.lam_snap[i].len() } else { 0 })
                as u64;
            if self.group.is_some() {
                // λ̂ slices share the owned-slice layout, so the dual
                // payload splits by the same per-master part lengths.
                for &(m, len) in &self.worker_parts[i] {
                    self.m_bytes_down[m] += 8 * (len * down_mult) as u64;
                }
            }
            self.dispatch(i);
        }
    }

    fn gather(&mut self, _k: usize, d: &[usize], gate: &Gate<'_>) -> ActiveSet {
        let n = self.pending.len();
        let wait_from = self.vclock.now_s();
        // Gate counters, maintained incrementally so the gather loop is
        // O(1) per event (N can be in the thousands here). Down workers
        // never count: the master can neither absorb nor wait for them.
        let n_live = (0..n).filter(|&i| !gate.down[i]).count();
        let target = gate.min_arrivals.min(n_live);
        let mut arrived_count = (0..n).filter(|&i| self.pending[i] && !gate.down[i]).count();
        let mut forced_missing = (0..n)
            .filter(|&i| !gate.down[i] && d[i] + 1 >= gate.tau && !self.pending[i])
            .count();
        // Per-master gate counters (empty when single-master): each
        // coordinator enforces `|A_k ∩ fleet_m| ≥ min(A, live_m)` plus
        // τ-forcing over its own fleet. The round fires when *every*
        // master's gate is satisfied — with M = 1 the conjunction is
        // exactly the global gate, so the classic event sequence is
        // untouched.
        let (mut m_arrived, mut m_forced, m_target) = match &self.group {
            None => (Vec::new(), Vec::new(), Vec::new()),
            Some(g) => {
                let mm = g.num_masters();
                let (mut live, mut arr, mut forc) =
                    (vec![0usize; mm], vec![0usize; mm], vec![0usize; mm]);
                for i in 0..n {
                    if gate.down[i] {
                        continue;
                    }
                    for &(m, _) in &self.worker_parts[i] {
                        live[m] += 1;
                        if self.pending[i] {
                            arr[m] += 1;
                        } else if d[i] + 1 >= gate.tau {
                            forc[m] += 1;
                        }
                    }
                }
                let tgt: Vec<usize> =
                    live.iter().map(|&l| gate.min_arrivals.min(l)).collect();
                (arr, forc, tgt)
            }
        };
        loop {
            let masters_ok = m_target
                .iter()
                .enumerate()
                .all(|(m, &t)| m_arrived[m] >= t && m_forced[m] == 0);
            if arrived_count >= target && forced_missing == 0 && masters_ok {
                // Absorb everything that has arrived by this instant — the
                // threaded master's try_recv drain.
                while self.queue.peek_time().is_some_and(|t| t <= self.vclock.now_s()) {
                    // ad-lint: allow(panic-free-lib): guarded by peek_time() in the loop condition
                    let ev = self.queue.pop().expect("peeked event");
                    self.absorb_event(
                        ev,
                        d,
                        gate,
                        &mut arrived_count,
                        &mut forced_missing,
                        &mut m_arrived,
                        &mut m_forced,
                    );
                }
                break;
            }
            match self.queue.pop() {
                Some(ev) => {
                    self.vclock.advance_to(ev.time_s);
                    self.absorb_event(
                        ev,
                        d,
                        gate,
                        &mut arrived_count,
                        &mut forced_missing,
                        &mut m_arrived,
                        &mut m_forced,
                    );
                }
                // Unreachable with ≥1 live worker (every worker always has
                // an in-flight event), but mirror the threaded recv-Err
                // path.
                None => break,
            }
        }
        self.master_wait_s += self.vclock.now_s() - wait_from;
        // Built by an ascending scan over worker ids: sorted and unique by
        // construction.
        ActiveSet::from_sorted((0..n).filter(|&i| self.pending[i] && !gate.down[i]).collect())
    }

    fn absorb(&mut self, set: &ActiveSet, m: &mut MasterView<'_>, policy: &dyn UpdatePolicy) {
        let rho = m.rho;
        let problem = m.problem;
        let worker_dual = policy.worker_updates_dual();
        // Deferred worker arithmetic: one task per arrived worker, built in
        // ascending id order and fanned across the pool. Every task writes
        // only its own slots against the shared immutable snapshots, so the
        // result is the exact bit sequence of the serial Algorithm-3
        // simulator for any pool size (pinned by the property tests).
        let mut tasks: Vec<SolveTask> = Vec::with_capacity(set.len());
        let mut set_iter = set.iter().peekable();
        for (i, ((w, x), (lam, f))) in self
            .workers
            .iter_mut()
            .zip(m.state.xs.iter_mut())
            .zip(m.state.lams.iter_mut().zip(m.f_cache.iter_mut()))
            .enumerate()
        {
            if set_iter.peek() == Some(&&i) {
                set_iter.next();
                tasks.push(SolveTask {
                    worker: i,
                    solve: w.solve.as_mut(),
                    scratch: &mut w.scratch,
                    x,
                    lam,
                    f,
                    warm: &mut w.warm,
                });
            }
        }
        // Uplink accounting: each absorbed message carried the worker's x̂
        // slice, plus its updated dual under Algorithm 2 (8 bytes/f64).
        self.bytes_up += tasks
            .iter()
            .map(|t| 8 * (t.x.len() + if worker_dual { t.x.len() } else { 0 }) as u64)
            .sum::<u64>();
        // Per-master meters: the same uplink bytes split by owning master,
        // plus the simulated folding cost each coordinator pays for the
        // coordinates it absorbed. Metering only — event timings are
        // untouched, so runs stay bit-identical with the meters on.
        let up_mult = if worker_dual { 2 } else { 1 };
        for t in &tasks {
            match &self.group {
                None => self.m_busy_s[0] += MASTER_PER_F64_S * t.x.len() as f64,
                Some(_) => {
                    for &(m, len) in &self.worker_parts[t.worker] {
                        self.m_bytes_up[m] += 8 * (len * up_mult) as u64;
                        self.m_busy_s[m] += MASTER_PER_F64_S * len as f64;
                    }
                }
            }
        }
        let x0_snaps = &self.x0_snap;
        let lam_snaps = &self.lam_snap;
        let policies = &self.policies;
        self.pool.run(&mut tasks, |t| {
            let i = t.worker;
            // Worker i's slice length (owned-slice length when sharded).
            let ni = t.x.len();
            if worker_dual {
                // (19)/(23): solve against the worker's own dual and its
                // x₀ snapshot, then (20)/(24): the dual update.
                let snap = &x0_snaps[i];
                match &mut t.solve {
                    Some(f) => (**f)(t.lam, snap, rho, t.x),
                    None => solve_inexact(
                        &**problem.local(i),
                        &policies[i],
                        t.lam,
                        snap,
                        rho,
                        t.x,
                        t.scratch,
                        t.warm,
                    ),
                }
                for j in 0..ni {
                    t.lam[j] += rho * (t.x[j] - snap[j]);
                }
            } else {
                // (47): solve against the master-broadcast (x̂₀, λ̂_i).
                let (snap, lsnap) = (&x0_snaps[i], &lam_snaps[i]);
                match &mut t.solve {
                    Some(f) => (**f)(lsnap, snap, rho, t.x),
                    None => solve_inexact(
                        &**problem.local(i),
                        &policies[i],
                        lsnap,
                        snap,
                        rho,
                        t.x,
                        t.scratch,
                        t.warm,
                    ),
                }
            }
            *t.f = problem.local(i).eval_with(t.x, t.scratch);
        });
    }

    fn broadcast(&mut self, set: &ActiveSet, state: &AdmmState, policy: &dyn UpdatePolicy) {
        // Step 6: broadcast to the arrived workers only and start their
        // next round at the current virtual instant (owned slices when
        // sharded).
        let with_dual = policy.broadcasts_dual();
        for &i in set {
            self.pending[i] = false;
            match self.shard.clone() {
                None => self.x0_snap[i].copy_from_slice(&state.x0),
                Some(p) => p.gather_into(i, &state.x0, &mut self.x0_snap[i]),
            }
            if with_dual {
                self.lam_snap[i].copy_from_slice(&state.lams[i]);
            }
            self.bytes_down += 8 * (self.x0_snap[i].len()
                + if with_dual { self.lam_snap[i].len() } else { 0 })
                as u64;
            if self.group.is_some() {
                let down_mult = if with_dual { 2 } else { 1 };
                for &(m, len) in &self.worker_parts[i] {
                    self.m_bytes_down[m] += 8 * (len * down_mult) as u64;
                }
            }
            self.dispatch(i);
        }
    }
}

/// Run the configured protocol in simulated time: build the
/// [`VirtualSource`], hand it to the unified engine, repackage. Semantics
/// of the returned [`ClusterReport`] match the threaded mode, with all
/// seconds measured on the virtual clock.
pub(crate) fn run_virtual(
    problem: &ConsensusProblem,
    cfg: &ClusterConfig,
    solvers: Option<Vec<WorkerSolveFn>>,
) -> ClusterReport {
    let mut source =
        VirtualSource::new(problem.num_workers(), cfg, solvers, problem.pattern().cloned());
    let run = super::run_cluster_engine(problem, cfg, &mut source);
    let (net_bytes_down, net_bytes_up) = source.network_bytes();
    let net_bytes_per_master = source.master_split();
    let (workers, wall_clock_s, master_wait_s) = source.finish();
    ClusterReport {
        state: run.state,
        history: run.history,
        trace: run.trace,
        stop: run.stop,
        wall_clock_s,
        master_wait_s,
        workers,
        net_bytes_down,
        net_bytes_up,
        net_bytes_per_master,
    }
}

#[cfg(test)]
mod tests {
    use crate::admm::AdmmConfig;
    use crate::cluster::{ClusterConfig, DelayModel, ExecutionMode, StarCluster};
    use crate::data::LassoInstance;
    use crate::rng::Pcg64;

    fn problem(seed: u64, n_workers: usize) -> crate::problems::ConsensusProblem {
        let mut rng = Pcg64::seed_from_u64(seed);
        LassoInstance::synthetic(&mut rng, n_workers, 20, 10, 0.2, 0.1).problem()
    }

    fn virt_cfg(tau: usize, min_arrivals: usize, max_iters: usize) -> ClusterConfig {
        ClusterConfig::builder()
            .admm(AdmmConfig { rho: 50.0, tau, min_arrivals, max_iters, ..Default::default() })
            .delays(DelayModel::LogNormal {
                mean_ms: vec![1.0, 2.0, 4.0, 8.0],
                sigma: 0.3,
                seed: 7,
            })
            .mode(ExecutionMode::VirtualTime)
            .build()
            .expect("valid config")
    }

    #[test]
    fn virtual_run_is_deterministic() {
        let p = problem(801, 4);
        let cfg = virt_cfg(4, 1, 80);
        let a = StarCluster::new(p.clone()).run(&cfg);
        let b = StarCluster::new(p).run(&cfg);
        assert_eq!(a.trace, b.trace, "same seed must realize the same arrival sets");
        assert_eq!(a.state.x0, b.state.x0);
        assert_eq!(a.wall_clock_s, b.wall_clock_s, "virtual time is exact");
    }

    #[test]
    fn pooled_virtual_run_matches_serial() {
        let p = problem(805, 4);
        let serial = StarCluster::new(p.clone()).run(&virt_cfg(3, 1, 70));
        let mut cfg = virt_cfg(3, 1, 70);
        cfg.pool_threads = 3;
        let pooled = StarCluster::new(p).run(&cfg);
        assert_eq!(serial.trace, pooled.trace);
        assert_eq!(serial.state.x0, pooled.state.x0);
        assert_eq!(serial.state.xs, pooled.state.xs);
        assert_eq!(serial.state.lams, pooled.state.lams);
        assert_eq!(serial.wall_clock_s, pooled.wall_clock_s);
    }

    #[test]
    fn virtual_trace_respects_gate_and_tau() {
        let p = problem(802, 4);
        let tau = 3;
        let cfg = virt_cfg(tau, 2, 150);
        let report = StarCluster::new(p).run(&cfg);
        assert!(report.trace.satisfies_bounded_delay(4, tau));
        assert!(report.trace.sets.iter().all(|s| s.len() >= 2));
    }

    #[test]
    fn virtual_time_accounts_busy_and_wait() {
        let p = problem(803, 3);
        let mut cfg = virt_cfg(5, 1, 60);
        cfg.delays = DelayModel::Fixed { per_worker_ms: vec![1.0, 2.0, 3.0] };
        let report = StarCluster::new(p).run(&cfg);
        assert!(report.wall_clock_s > 0.0);
        assert!(report.master_wait_s <= report.wall_clock_s + 1e-12);
        for w in &report.workers {
            assert!(w.updates > 0);
            // busy time covers the compute phase of every *absorbed* round
            let expected = w.updates as f64;
            assert!(
                w.busy_s * 1e3 >= expected * (w.id + 1) as f64 - 1e-6,
                "worker {} busy {:.6}s over {} absorbed updates",
                w.id,
                w.busy_s,
                w.updates
            );
            // ...and never counts rounds cut off by the end of the run
            assert!(w.busy_s <= w.lifetime_s + 1e-12);
            // lifetime is the full simulated run for every worker
            assert_eq!(w.lifetime_s, report.wall_clock_s);
        }
        // the run summarizes into a Timeline like any threaded run
        let tl = crate::cluster::Timeline::from_report(&report);
        assert_eq!(tl.master_iters, report.history.len());
        assert_eq!(
            tl.total_updates(),
            report.workers.iter().map(|w| w.updates).sum::<usize>()
        );
        assert!(tl.render().contains("master iterations: 60"));
    }

    #[test]
    fn fixed_equal_delays_run_synchronously() {
        let p = problem(804, 4);
        let mut cfg = virt_cfg(1, 4, 50);
        cfg.delays = DelayModel::Fixed { per_worker_ms: vec![2.0; 4] };
        let report = StarCluster::new(p).run(&cfg);
        // equal delays + τ=1 gate: every iteration sees all 4 workers
        assert!(report.trace.sets.iter().all(|s| s.len() == 4));
        // 50 synchronous rounds at 2 ms each ≈ 100 ms of simulated time
        assert!((report.wall_clock_s - 0.1).abs() < 1e-9, "t={}", report.wall_clock_s);
    }

    #[test]
    fn dropout_holds_messages_and_rejoins_with_stale_iterates() {
        use crate::admm::engine::FaultPlan;
        let p = problem(806, 4);
        let mut cfg = virt_cfg(3, 1, 60);
        cfg.delays = DelayModel::Fixed { per_worker_ms: vec![1.0, 1.5, 2.0, 2.5] };
        cfg.fault_plan = Some(FaultPlan::single_outage(1, 15, 30));
        let report = StarCluster::new(p).run(&cfg);
        assert_eq!(report.history.len(), 60);
        for (k, set) in report.trace.sets.iter().enumerate() {
            if (15..30).contains(&k) {
                assert!(!set.contains(&1), "down worker absorbed at k={k}");
            }
        }
        // rejoin: worker 1 arrives again after the outage ends
        assert!(report.trace.sets[30..].iter().any(|s| s.contains(&1)));
        // the outage (15 iters) exceeds τ = 3 ⇒ Assumption 1 violated
        assert!(!report.trace.satisfies_bounded_delay(4, 3));
        // determinism: the same config realizes the same faulted trace
        let p2 = problem(806, 4);
        let again = StarCluster::new(p2).run(&cfg);
        assert_eq!(report.trace, again.trace);
        assert_eq!(report.state.x0, again.state.x0);
    }

    #[test]
    fn delay_spike_slows_the_affected_worker() {
        use crate::admm::engine::{DelaySpike, FaultPlan};
        let p = problem(807, 2);
        let mk = |spike| {
            let mut cfg = virt_cfg(100, 1, 80);
            cfg.delays = DelayModel::Fixed { per_worker_ms: vec![1.0, 1.0] };
            if spike {
                cfg.fault_plan = Some(FaultPlan {
                    outages: Vec::new(),
                    spikes: vec![DelaySpike {
                        worker: 1,
                        from_s: 0.0,
                        until_s: f64::INFINITY,
                        factor: 8.0,
                    }],
                });
            }
            cfg
        };
        let base = StarCluster::new(p.clone()).run(&mk(false));
        let spiked = StarCluster::new(p).run(&mk(true));
        let updates = |r: &crate::cluster::ClusterReport, i: usize| r.workers[i].updates;
        // the spiked worker completes materially fewer rounds than it does
        // in the fault-free run, while worker 0 keeps its cadence
        assert!(
            updates(&spiked, 1) * 4 <= updates(&base, 1),
            "spike did not slow worker 1: {} vs {}",
            updates(&spiked, 1),
            updates(&base, 1)
        );
        assert!(updates(&spiked, 0) * 2 >= updates(&base, 0));
    }
}
