//! The star cluster: master/worker implementations of Algorithm 2 and
//! Algorithm 4 in two execution modes behind one [`ClusterConfig`]:
//!
//! - **[`ExecutionMode::RealThreads`]** — one OS thread per worker,
//!   unbounded mpsc channels for the star links, the master on the calling
//!   thread. Heterogeneous compute/communication delays are injected as
//!   real sleeps through [`DelayModel`], reproducing the paper's motivating
//!   Fig. 2 wall-clock scenario (fast workers idle under the synchronous
//!   protocol; the asynchronous master updates as soon as `A` workers
//!   arrived while honouring the τ gate).
//! - **[`ExecutionMode::VirtualTime`]** — the same protocol driven by a
//!   deterministic discrete-event scheduler ([`sim`]) on a simulated
//!   [`clock::VirtualClock`]: delays become *events*, not sleeps, so a
//!   1000-worker × 500-iteration run finishes in well under a second and
//!   is bit-reproducible across machines. This is the mode the Section-V
//!   τ / `|A_k| ≥ A` sweeps use in CI.
//!
//! Both modes are [`crate::admm::engine::WorkerSource`] implementations
//! driven by the **same** unified iteration engine
//! ([`crate::admm::engine::run_engine`]) as the serial drivers, so they
//! realize semantics *identical* to the serial
//! [`crate::admm::master_pov`] simulator — given the same realized arrival
//! trace all three produce bit-equal iterates (enforced by the
//! `cluster_e2e`, `virtual_time` and `engine_equivalence` integration
//! tests). Deterministic fault scenarios ([`FaultPlan`]: worker
//! dropout/rejoin, delay spikes) plug into every mode through the same
//! seam via [`ClusterConfig::fault_plan`].

pub mod clock;
pub mod messages;
pub mod multimaster;
pub mod pool;
pub mod sim;
pub mod threaded;
pub mod timeline;
pub mod transport;
pub mod worker;

use crate::admm::arrivals::ArrivalTrace;
use crate::admm::engine::{self, run_engine, EngineRun, PartialBarrier, WorkerSource};
use crate::admm::session::{Checkpoint, EngineError, Session, SessionOutcome};
use crate::admm::{AdmmConfig, AdmmState, IterRecord, StopReason};
use crate::bench::json::{hex_u128, u128_from_hex, JsonValue};
use crate::problems::ConsensusProblem;
use crate::rng::Pcg64;
use crate::solvers::inexact::InexactPolicy;

pub use crate::admm::engine::{DelaySpike, FaultPlan, Outage};
pub use multimaster::{MasterGroup, MultiMasterSource};
pub use sim::VirtualSource;
pub use clock::VirtualClock;
pub use messages::{MasterMsg, WorkerMsg};
pub use pool::WorkerPool;
pub use timeline::{Timeline, WorkerStats};
pub use transport::{JobReport, JobSpec, SocketSource, TransportConfig, TransportStats};
use worker::WorkerSolveFn;

/// Which coordinator protocol the cluster runs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Protocol {
    /// Algorithm 2: workers own their dual updates.
    AdAdmm,
    /// Algorithm 4: the master owns all dual updates.
    AltScheme,
}

/// How the cluster executes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecutionMode {
    /// One OS thread per worker; injected delays are real sleeps and the
    /// report's timings are wall-clock. Nondeterministic arrival order
    /// (that is the point), bounded to a handful of workers in practice.
    #[default]
    RealThreads,
    /// Deterministic discrete-event simulation on a virtual clock: no
    /// threads, no sleeps. Timings in the report are *simulated* seconds.
    /// Scales to thousands of workers and reproduces bit-equal iterates
    /// with [`crate::admm::master_pov::run_master_pov`] on the same trace.
    VirtualTime,
}

/// Per-worker delay injection (simulated heterogeneous network/compute).
#[derive(Clone, Debug)]
pub enum DelayModel {
    /// No injected delay (protocol still fully asynchronous — OS scheduling
    /// provides the nondeterminism).
    None,
    /// Deterministic per-worker delay in milliseconds per round
    /// (compute + communicate combined).
    Fixed { per_worker_ms: Vec<f64> },
    /// Log-normal around a per-worker mean: `exp(N(ln(mean_i), sigma))` ms.
    LogNormal { mean_ms: Vec<f64>, sigma: f64, seed: u64 },
}

impl DelayModel {
    /// A heterogeneous profile: worker i's mean delay grows linearly from
    /// `fast_ms` to `slow_ms` — the paper's "slowest worker" scenario.
    pub fn linear_spread(
        n_workers: usize,
        fast_ms: f64,
        slow_ms: f64,
        sigma: f64,
        seed: u64,
    ) -> Self {
        let mean_ms = (0..n_workers)
            .map(|i| {
                if n_workers == 1 {
                    fast_ms
                } else {
                    fast_ms + (slow_ms - fast_ms) * i as f64 / (n_workers - 1) as f64
                }
            })
            .collect();
        DelayModel::LogNormal { mean_ms, sigma, seed }
    }

    /// Build the per-worker sampler.
    fn sampler(&self, worker: usize) -> DelaySampler {
        match self {
            DelayModel::None => DelaySampler::None,
            DelayModel::Fixed { per_worker_ms } => DelaySampler::Fixed(per_worker_ms[worker]),
            DelayModel::LogNormal { mean_ms, sigma, seed } => DelaySampler::LogNormal {
                mu: mean_ms[worker].max(1e-6).ln(),
                sigma: *sigma,
                rng: Pcg64::seed_from_u64(seed.wrapping_add(worker as u64 * 0x9e37)),
            },
        }
    }
}

pub(crate) enum DelaySampler {
    None,
    Fixed(f64),
    LogNormal { mu: f64, sigma: f64, rng: Pcg64 },
}

impl DelaySampler {
    pub(crate) fn sample_ms(&mut self) -> f64 {
        match self {
            DelaySampler::None => 0.0,
            DelaySampler::Fixed(ms) => *ms,
            DelaySampler::LogNormal { mu, sigma, rng } => rng.lognormal(*mu, *sigma),
        }
    }

    /// Serialize this sampler's mid-run state for a session checkpoint.
    /// `None`/`Fixed` draws are stateless (the values are rebuilt from the
    /// config); only the log-normal stream carries RNG state.
    pub(crate) fn save(&self) -> JsonValue {
        match self {
            DelaySampler::None | DelaySampler::Fixed(_) => JsonValue::Null,
            DelaySampler::LogNormal { rng, .. } => {
                let (state, inc) = rng.to_raw();
                JsonValue::Obj(vec![
                    ("rng_state".to_string(), hex_u128(state)),
                    ("rng_inc".to_string(), hex_u128(inc)),
                ])
            }
        }
    }

    /// Restore state produced by [`DelaySampler::save`] into a sampler
    /// freshly rebuilt from the same [`DelayModel`].
    pub(crate) fn load(&mut self, doc: &JsonValue) -> Result<(), String> {
        match (&mut *self, doc) {
            (DelaySampler::None | DelaySampler::Fixed(_), JsonValue::Null) => Ok(()),
            (DelaySampler::LogNormal { rng, .. }, JsonValue::Obj(_)) => {
                let state = u128_from_hex(
                    doc.get("rng_state").ok_or_else(|| "missing rng_state".to_string())?,
                )?;
                let inc = u128_from_hex(
                    doc.get("rng_inc").ok_or_else(|| "missing rng_inc".to_string())?,
                )?;
                *rng = Pcg64::from_raw(state, inc);
                Ok(())
            }
            _ => Err("delay-sampler checkpoint does not match the configured model".to_string()),
        }
    }
}

/// Probabilistic communication failures with retransmission (paper,
/// footnote 2: "the communication delays can also be different, e.g., due
/// to probabilistic communication failures and message retransmission").
/// A worker's result is "lost" with `drop_prob`; each retransmission costs
/// `retrans_ms` before the master sees it.
#[derive(Clone, Debug)]
pub struct FaultModel {
    pub drop_prob: f64,
    pub retrans_ms: f64,
    pub seed: u64,
}

/// Cluster configuration = algorithm parameters + protocol + delay model
/// + execution mode.
///
/// Prefer [`ClusterConfig::builder`] over filling the fields by hand: the
/// builder validates the cross-field invariants (delay-model shapes, fault
/// probabilities, spike factors, outage windows) at build time and returns
/// a typed [`EngineError`] instead of letting a malformed config panic —
/// or silently misbehave — deep inside a run. Direct struct literals keep
/// working (the fields stay public so functional updates like
/// `ClusterConfig { pool_threads: 4, ..base }` compose), but new code and
/// examples should go through the builder.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub admm: AdmmConfig,
    pub protocol: Protocol,
    /// Per-round *compute* delay (in real-thread mode: the injected sleep).
    pub delays: DelayModel,
    /// Optional separate *communication* delay model. `None` folds
    /// communication into [`ClusterConfig::delays`] (the historical
    /// behaviour); `Some` gives the virtual-time scheduler distinct
    /// compute-done / arrive events per round.
    pub comm_delays: Option<DelayModel>,
    /// Optional communication-failure injection.
    pub faults: Option<FaultModel>,
    /// Real threads (wall clock) or discrete-event virtual time.
    pub mode: ExecutionMode,
    /// Worker-solve thread-pool size for [`ExecutionMode::VirtualTime`]:
    /// `1` (default) solves each round serially on the calling thread,
    /// `0` auto-sizes to the machine's available parallelism, `k > 1` uses
    /// at most `k` threads. Results are **bit-identical** across every
    /// setting (pinned by the `virtual_time` property tests); the
    /// real-thread mode ignores it — it already runs one thread per worker.
    pub pool_threads: usize,
    /// Deterministic, seeded worker dropout/rejoin + delay-spike schedule
    /// ([`FaultPlan`]), enforced identically at the master's gate in every
    /// execution mode: a down worker's result is held until rejoin, so it
    /// re-enters with stale iterates (the paper's delayed-information
    /// model). `None` = fault-free (the historical behaviour).
    pub fault_plan: Option<FaultPlan>,
    /// Real-thread mode only: replay this prescribed sequence of arrival
    /// sets in lockstep — each iteration the master waits for *exactly*
    /// the prescribed workers — which makes the otherwise nondeterministic
    /// threaded mode bit-comparable with the trace-driven and virtual-time
    /// sources on the same trace. Ignored by the other modes (they are
    /// already deterministic; replay traces there via
    /// [`crate::admm::arrivals::ArrivalModel::Trace`]).
    pub lockstep_trace: Option<ArrivalTrace>,
    /// Per-worker heterogeneous inexact subproblem policies. `None`
    /// (the default spelling) applies [`AdmmConfig::inexact`] uniformly;
    /// `Some(v)` must have one entry per worker and overrides the uniform
    /// policy worker-by-worker — a fast machine can run `newton:2` while a
    /// stragglers runs `grad:3`. Honoured identically by every execution
    /// mode (pinned by the three-source heterogeneous bit-identity test).
    pub inexact_per_worker: Option<Vec<InexactPolicy>>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            admm: AdmmConfig::default(),
            protocol: Protocol::AdAdmm,
            delays: DelayModel::None,
            comm_delays: None,
            faults: None,
            mode: ExecutionMode::RealThreads,
            pool_threads: 1,
            fault_plan: None,
            lockstep_trace: None,
            inexact_per_worker: None,
        }
    }
}

impl ClusterConfig {
    /// Start a validated [`ClusterConfigBuilder`] from the defaults.
    pub fn builder() -> ClusterConfigBuilder {
        ClusterConfigBuilder { cfg: ClusterConfig::default() }
    }

    /// The inexact policy worker `i` solves under: its
    /// [`ClusterConfig::inexact_per_worker`] entry when set, the uniform
    /// [`AdmmConfig::inexact`] otherwise.
    pub fn inexact_policy_for(&self, worker: usize) -> InexactPolicy {
        match &self.inexact_per_worker {
            Some(v) => v[worker],
            None => self.admm.inexact,
        }
    }
}

/// Typed builder for [`ClusterConfig`]. Every setter mirrors the field of
/// the same name; [`ClusterConfigBuilder::build`] validates the whole
/// configuration and returns [`EngineError::Cluster`] describing the first
/// problem it finds — the same fail-at-the-seam philosophy as the
/// [`Session`] builder.
#[derive(Clone, Debug)]
pub struct ClusterConfigBuilder {
    cfg: ClusterConfig,
}

impl ClusterConfigBuilder {
    /// Algorithm parameters (ρ, τ, `min_arrivals`, iteration budget…).
    pub fn admm(mut self, admm: AdmmConfig) -> Self {
        self.cfg.admm = admm;
        self
    }

    /// Coordinator protocol (Algorithm 2 vs Algorithm 4).
    pub fn protocol(mut self, protocol: Protocol) -> Self {
        self.cfg.protocol = protocol;
        self
    }

    /// Per-round compute delay model.
    pub fn delays(mut self, delays: DelayModel) -> Self {
        self.cfg.delays = delays;
        self
    }

    /// Separate communication delay model (`None` folds comm into
    /// [`ClusterConfigBuilder::delays`]).
    pub fn comm_delays(mut self, comm: DelayModel) -> Self {
        self.cfg.comm_delays = Some(comm);
        self
    }

    /// Probabilistic message-drop/retransmission injection.
    pub fn faults(mut self, faults: FaultModel) -> Self {
        self.cfg.faults = Some(faults);
        self
    }

    /// Real threads or discrete-event virtual time.
    pub fn mode(mut self, mode: ExecutionMode) -> Self {
        self.cfg.mode = mode;
        self
    }

    /// Worker-solve pool size for virtual-time runs (see
    /// [`ClusterConfig::pool_threads`]).
    pub fn pool_threads(mut self, threads: usize) -> Self {
        self.cfg.pool_threads = threads;
        self
    }

    /// Deterministic dropout/rejoin + delay-spike schedule.
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.cfg.fault_plan = Some(plan);
        self
    }

    /// Real-thread lockstep replay of a prescribed arrival trace.
    pub fn lockstep_trace(mut self, trace: ArrivalTrace) -> Self {
        self.cfg.lockstep_trace = Some(trace);
        self
    }

    /// Per-worker heterogeneous inexact policies (one entry per worker;
    /// overrides the uniform [`AdmmConfig::inexact`]).
    pub fn inexact_per_worker(mut self, policies: Vec<InexactPolicy>) -> Self {
        self.cfg.inexact_per_worker = Some(policies);
        self
    }

    /// Validate and produce the [`ClusterConfig`].
    pub fn build(self) -> Result<ClusterConfig, EngineError> {
        let bad = |msg: String| Err(EngineError::Cluster(msg));
        let cfg = self.cfg;
        if !(cfg.admm.rho.is_finite() && cfg.admm.rho > 0.0) {
            return bad(format!("rho must be positive and finite, got {}", cfg.admm.rho));
        }
        if cfg.admm.tau == 0 {
            return bad("tau must be at least 1".to_string());
        }
        if cfg.admm.min_arrivals == 0 {
            return bad("min_arrivals must be at least 1".to_string());
        }
        for (name, model) in [("delays", Some(&cfg.delays)), ("comm_delays", cfg.comm_delays.as_ref())]
        {
            let Some(model) = model else { continue };
            match model {
                DelayModel::None => {}
                DelayModel::Fixed { per_worker_ms } => {
                    if per_worker_ms.is_empty() {
                        return bad(format!("{name}: Fixed delay model has no workers"));
                    }
                    if let Some(ms) = per_worker_ms.iter().find(|ms| !(ms.is_finite() && **ms >= 0.0))
                    {
                        return bad(format!("{name}: fixed delay {ms} ms is not finite and >= 0"));
                    }
                }
                DelayModel::LogNormal { mean_ms, sigma, .. } => {
                    if mean_ms.is_empty() {
                        return bad(format!("{name}: LogNormal delay model has no workers"));
                    }
                    if let Some(ms) = mean_ms.iter().find(|ms| !(ms.is_finite() && **ms >= 0.0)) {
                        return bad(format!("{name}: mean delay {ms} ms is not finite and >= 0"));
                    }
                    if !(sigma.is_finite() && *sigma >= 0.0) {
                        return bad(format!("{name}: log-normal sigma {sigma} is not finite and >= 0"));
                    }
                }
            }
        }
        if let Some(f) = &cfg.faults {
            if !(f.drop_prob >= 0.0 && f.drop_prob < 1.0) {
                return bad(format!("fault drop_prob {} is outside [0, 1)", f.drop_prob));
            }
            if !(f.retrans_ms.is_finite() && f.retrans_ms >= 0.0) {
                return bad(format!("fault retrans_ms {} is not finite and >= 0", f.retrans_ms));
            }
        }
        if let Some(policies) = &cfg.inexact_per_worker {
            if policies.is_empty() {
                return bad("inexact_per_worker has no workers".to_string());
            }
            for (i, p) in policies.iter().enumerate() {
                if let Err(e) = p.validate() {
                    return bad(format!("inexact_per_worker[{i}]: {e}"));
                }
            }
        }
        if let Some(plan) = &cfg.fault_plan {
            for o in &plan.outages {
                if o.from_iter >= o.until_iter {
                    return bad(format!(
                        "outage for worker {} has empty window [{}, {})",
                        o.worker, o.from_iter, o.until_iter
                    ));
                }
            }
            for s in &plan.spikes {
                if !(s.factor.is_finite() && s.factor > 0.0) {
                    return bad(format!(
                        "delay spike for worker {} has non-positive factor {}",
                        s.worker, s.factor
                    ));
                }
                if !(s.from_s < s.until_s) {
                    return bad(format!(
                        "delay spike for worker {} has empty window [{}, {})",
                        s.worker, s.from_s, s.until_s
                    ));
                }
            }
        }
        Ok(cfg)
    }
}

/// What a cluster run returns.
pub struct ClusterReport {
    pub state: AdmmState,
    pub history: Vec<IterRecord>,
    /// Realized arrival sets — replayable through the serial simulator.
    pub trace: ArrivalTrace,
    pub stop: StopReason,
    /// Total run time in seconds — wall clock in
    /// [`ExecutionMode::RealThreads`], simulated time in
    /// [`ExecutionMode::VirtualTime`].
    pub wall_clock_s: f64,
    /// Seconds the master spent blocked waiting for arrivals (same clock
    /// as `wall_clock_s`).
    pub master_wait_s: f64,
    pub workers: Vec<WorkerStats>,
    /// Simulated master→worker payload bytes (8 bytes per f64 shipped).
    /// Deterministic in [`ExecutionMode::VirtualTime`]; `0` in
    /// [`ExecutionMode::RealThreads`], which does not meter its channels
    /// (real-socket runs report measured bytes via
    /// [`TransportStats`] instead).
    pub net_bytes_down: u64,
    /// Simulated worker→master payload bytes (see `net_bytes_down`).
    pub net_bytes_up: u64,
    /// Per-master `(down, up)` split of the simulated payload bytes. One
    /// entry per coordinator — single-master runs report one pair equal to
    /// the global counters; multi-master virtual-time runs split by slice
    /// ownership. Invariant (unit-tested): the element-wise sum over
    /// masters equals `(net_bytes_down, net_bytes_up)` exactly.
    pub net_bytes_per_master: Vec<(u64, u64)>,
}

impl ClusterReport {
    /// Master iterations per wall-clock second.
    pub fn iters_per_sec(&self) -> f64 {
        self.history.len() as f64 / self.wall_clock_s.max(1e-12)
    }

    /// Assemble a report from a finished incremental virtual-time session
    /// (see [`StarCluster::virtual_session`]). `history` is whatever the
    /// caller's observer collected — pass an empty `Vec` for a
    /// memory-bounded run that never buffered (then `iters_per_sec` is
    /// meaningless and `outcome.iterations` is the count to use).
    pub fn from_virtual_parts(
        outcome: SessionOutcome,
        history: Vec<IterRecord>,
        source: VirtualSource,
    ) -> ClusterReport {
        let (net_bytes_down, net_bytes_up) = source.network_bytes();
        let net_bytes_per_master = source.master_split();
        let (workers, wall_clock_s, master_wait_s) = source.finish();
        ClusterReport {
            state: outcome.state,
            history,
            trace: outcome.trace,
            stop: outcome.stop,
            wall_clock_s,
            master_wait_s,
            workers,
            net_bytes_down,
            net_bytes_up,
            net_bytes_per_master,
        }
    }
}

/// The threaded star cluster.
pub struct StarCluster {
    problem: ConsensusProblem,
}

impl StarCluster {
    pub fn new(problem: ConsensusProblem) -> Self {
        StarCluster { problem }
    }

    /// Run the configured protocol to `max_iters` master iterations.
    ///
    /// `solvers`: optional per-worker solve overrides (PJRT-backed workers);
    /// `None` uses the problem's native closed-form solves.
    pub fn run(&self, cfg: &ClusterConfig) -> ClusterReport {
        self.run_with_solvers(cfg, None)
    }

    pub fn run_with_solvers(
        &self,
        cfg: &ClusterConfig,
        solvers: Option<Vec<WorkerSolveFn>>,
    ) -> ClusterReport {
        // ad-lint: allow(panic-free-lib): legacy cluster entry keeps its panic-on-invalid contract; Session::builder is the typed path
        cfg.admm.validate(self.problem.num_workers()).expect("invalid AdmmConfig");
        match cfg.mode {
            ExecutionMode::RealThreads => self.run_threaded(cfg, solvers),
            ExecutionMode::VirtualTime => sim::run_virtual(&self.problem, cfg, solvers),
        }
    }

    /// The real-thread implementation (historical default): spawn the
    /// [`threaded::ThreadedSource`], hand it to the unified engine, join.
    fn run_threaded(
        &self,
        cfg: &ClusterConfig,
        solvers: Option<Vec<WorkerSolveFn>>,
    ) -> ClusterReport {
        let mut source = threaded::ThreadedSource::spawn(&self.problem, cfg, solvers);
        let run = run_cluster_engine(&self.problem, cfg, &mut source);
        let (workers, wall_clock_s, master_wait_s) = source.finish();
        ClusterReport {
            state: run.state,
            history: run.history,
            trace: run.trace,
            stop: run.stop,
            wall_clock_s,
            master_wait_s,
            workers,
            net_bytes_down: 0,
            net_bytes_up: 0,
            net_bytes_per_master: vec![(0, 0)],
        }
    }

    /// The protocol/fault translation for the incremental sessions —
    /// mirror of [`run_cluster_engine`]'s, so a session realizes the same
    /// semantics as [`StarCluster::run`]: `AdAdmm` → [`PartialBarrier`],
    /// `AltScheme` → [`engine::AltScheme`], fault plan → builder faults.
    fn session_builder(&self, cfg: &ClusterConfig) -> crate::admm::session::SessionBuilder<'_> {
        let mut builder = Session::builder()
            .problem(&self.problem)
            .config(cfg.admm.clone())
            .residual_stopping(true);
        builder = match cfg.protocol {
            Protocol::AdAdmm => builder.policy(PartialBarrier { tau: cfg.admm.tau }),
            Protocol::AltScheme => builder.policy(engine::AltScheme { tau: cfg.admm.tau }),
        };
        if let Some(plan) = &cfg.fault_plan {
            builder = builder.faults(plan.clone());
        }
        if let Some(policies) = &cfg.inexact_per_worker {
            builder = builder.inexact_per_worker(policies.clone());
        }
        builder
    }

    /// An **incremental** virtual-time cluster run: a typed
    /// [`Session`] over the deterministic discrete-event
    /// [`VirtualSource`], supporting `step()`, observers and — unlike the
    /// real-thread mode — bit-identical [`Checkpoint`]/resume (the full
    /// event queue, virtual clock and every RNG stream serialize). Returns
    /// [`EngineError::Checkpoint`]-style typed errors instead of
    /// panicking on bad configs.
    ///
    /// Finish with [`Session::finish`] and
    /// [`ClusterReport::from_virtual_parts`] to recover the utilization
    /// report.
    pub fn virtual_session(
        &self,
        cfg: &ClusterConfig,
    ) -> Result<Session<'_, VirtualSource>, EngineError> {
        let source = VirtualSource::new(
            self.problem.num_workers(),
            cfg,
            None,
            self.problem.pattern().cloned(),
        );
        self.session_builder(cfg).build_typed(source)
    }

    /// Resume a virtual-time cluster session from a [`Checkpoint`] taken
    /// by [`StarCluster::virtual_session`]. `cfg` must be the
    /// configuration the checkpointed run was built with; the resumed run
    /// continues **bit-identically** to the uninterrupted one (pinned by
    /// the `session_api` suite and the CLI round-trip test).
    pub fn resume_virtual_session(
        &self,
        cfg: &ClusterConfig,
        checkpoint: &Checkpoint,
    ) -> Result<Session<'_, VirtualSource>, EngineError> {
        let source = VirtualSource::new(
            self.problem.num_workers(),
            cfg,
            None,
            self.problem.pattern().cloned(),
        );
        self.session_builder(cfg).resume_typed(source, checkpoint)
    }

    /// A virtual-time session whose coordinator is partitioned across
    /// `group.num_masters()` masters (see [`MasterGroup`]): each master
    /// runs its own masked sparse update over the blocks it owns and its
    /// own arrival gate over its own fleet, under one shared virtual-time
    /// event queue. Requires a block-sharded problem whose pattern has
    /// exactly `group.num_blocks()` blocks. With `MasterGroup::single` the
    /// session is bit-identical to [`StarCluster::virtual_session`].
    pub fn virtual_multimaster_session(
        &self,
        cfg: &ClusterConfig,
        group: MasterGroup,
    ) -> Result<Session<'_, VirtualSource>, EngineError> {
        let pattern = self.problem.pattern().cloned().ok_or_else(|| {
            EngineError::Masters(
                "multi-master coordination requires a block-sharded problem".to_string(),
            )
        })?;
        let source =
            MultiMasterSource::build(self.problem.num_workers(), cfg, pattern, &group)?;
        self.session_builder(cfg).masters(group).build_typed(source)
    }

    /// Resume a multi-master virtual-time session from a v4 [`Checkpoint`]
    /// taken by [`StarCluster::virtual_multimaster_session`]. `cfg` and
    /// `group` must match the checkpointed run; the resumed run continues
    /// bit-identically (pinned by the `multimaster` suite).
    pub fn resume_virtual_multimaster_session(
        &self,
        cfg: &ClusterConfig,
        group: MasterGroup,
        checkpoint: &Checkpoint,
    ) -> Result<Session<'_, VirtualSource>, EngineError> {
        let pattern = self.problem.pattern().cloned().ok_or_else(|| {
            EngineError::Masters(
                "multi-master coordination requires a block-sharded problem".to_string(),
            )
        })?;
        let source =
            MultiMasterSource::build(self.problem.num_workers(), cfg, pattern, &group)?;
        self.session_builder(cfg).masters(group).resume_typed(source, checkpoint)
    }
}

/// The one place a [`ClusterConfig`] is translated into an engine run:
/// protocol → [`UpdatePolicy`](crate::admm::engine::UpdatePolicy)
/// (`AdAdmm` → [`PartialBarrier`], `AltScheme` →
/// [`engine::AltScheme`]), fault plan → engine options. Both execution
/// modes (threaded and virtual-time) funnel through here, which is what
/// guarantees they realize identical protocol semantics.
pub(crate) fn run_cluster_engine(
    problem: &ConsensusProblem,
    cfg: &ClusterConfig,
    source: &mut dyn WorkerSource,
) -> EngineRun {
    let opts = engine::EngineOptions {
        residual_stopping: true,
        fault_plan: cfg.fault_plan.clone(),
    };
    match cfg.protocol {
        Protocol::AdAdmm => {
            let policy = PartialBarrier { tau: cfg.admm.tau };
            run_engine(problem, &cfg.admm, &policy, source, &opts)
        }
        Protocol::AltScheme => {
            let policy = engine::AltScheme { tau: cfg.admm.tau };
            run_engine(problem, &cfg.admm, &policy, source, &opts)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::admm::kkt::kkt_residual;
    use crate::data::LassoInstance;
    use crate::rng::Pcg64;

    fn problem(seed: u64, n_workers: usize) -> ConsensusProblem {
        let mut rng = Pcg64::seed_from_u64(seed);
        LassoInstance::synthetic(&mut rng, n_workers, 20, 10, 0.2, 0.1).problem()
    }

    #[test]
    fn sync_cluster_converges() {
        let p = problem(111, 4);
        let cfg = ClusterConfig::builder()
            .admm(AdmmConfig {
                rho: 50.0,
                tau: 1,
                min_arrivals: 4,
                max_iters: 400,
                ..Default::default()
            })
            .build()
            .expect("valid config");
        let report = StarCluster::new(p.clone()).run(&cfg);
        assert_eq!(report.stop, StopReason::MaxIters);
        let r = kkt_residual(&p, &report.state);
        assert!(r.max() < 1e-6, "{r:?}");
        // every iteration synchronous: all 4 workers in every set
        assert!(report.trace.sets.iter().all(|s| s.len() == 4));
    }

    #[test]
    fn async_cluster_converges_and_respects_tau() {
        let p = problem(112, 4);
        let tau = 4;
        let cfg = ClusterConfig::builder()
            .admm(AdmmConfig {
                rho: 50.0,
                tau,
                min_arrivals: 1,
                max_iters: 800,
                ..Default::default()
            })
            .delays(DelayModel::Fixed { per_worker_ms: vec![0.0, 0.0, 1.0, 2.0] })
            .build()
            .expect("valid config");
        let report = StarCluster::new(p.clone()).run(&cfg);
        let r = kkt_residual(&p, &report.state);
        assert!(r.max() < 1e-5, "{r:?}");
        assert!(report.trace.satisfies_bounded_delay(4, tau));
    }

    #[test]
    fn alt_scheme_cluster_runs_synchronously() {
        let p = problem(113, 3);
        let cfg = ClusterConfig::builder()
            .admm(AdmmConfig {
                rho: 30.0,
                tau: 1,
                min_arrivals: 3,
                max_iters: 400,
                ..Default::default()
            })
            .protocol(Protocol::AltScheme)
            .build()
            .expect("valid config");
        let report = StarCluster::new(p.clone()).run(&cfg);
        assert_eq!(report.stop, StopReason::MaxIters);
        let r = kkt_residual(&p, &report.state);
        assert!(r.max() < 1e-5, "{r:?}");
    }

    #[test]
    fn builder_rejects_malformed_configs() {
        use crate::admm::engine::{DelaySpike, FaultPlan, Outage};
        let msg = |b: ClusterConfigBuilder| match b.build() {
            Err(EngineError::Cluster(m)) => m,
            other => panic!("expected EngineError::Cluster, got {other:?}"),
        };
        assert!(msg(ClusterConfig::builder()
            .admm(AdmmConfig { rho: -1.0, ..Default::default() }))
        .contains("rho"));
        assert!(msg(ClusterConfig::builder()
            .admm(AdmmConfig { tau: 0, ..Default::default() }))
        .contains("tau"));
        assert!(msg(ClusterConfig::builder()
            .admm(AdmmConfig { min_arrivals: 0, ..Default::default() }))
        .contains("min_arrivals"));
        assert!(msg(ClusterConfig::builder()
            .delays(DelayModel::Fixed { per_worker_ms: vec![1.0, f64::NAN] }))
        .contains("delays"));
        assert!(msg(ClusterConfig::builder().comm_delays(DelayModel::LogNormal {
            mean_ms: Vec::new(),
            sigma: 0.3,
            seed: 1,
        }))
        .contains("comm_delays"));
        assert!(msg(ClusterConfig::builder().faults(FaultModel {
            drop_prob: 1.0,
            retrans_ms: 1.0,
            seed: 0,
        }))
        .contains("drop_prob"));
        assert!(msg(ClusterConfig::builder().fault_plan(FaultPlan {
            outages: vec![Outage { worker: 2, from_iter: 9, until_iter: 9 }],
            spikes: Vec::new(),
        }))
        .contains("outage"));
        assert!(msg(ClusterConfig::builder().fault_plan(FaultPlan {
            outages: Vec::new(),
            spikes: vec![DelaySpike { worker: 0, from_s: 0.0, until_s: 1.0, factor: 0.0 }],
        }))
        .contains("spike"));
        // a well-formed config with every knob set builds
        let cfg = ClusterConfig::builder()
            .admm(AdmmConfig { rho: 10.0, tau: 3, min_arrivals: 2, ..Default::default() })
            .protocol(Protocol::AltScheme)
            .delays(DelayModel::linear_spread(4, 1.0, 8.0, 0.2, 7))
            .comm_delays(DelayModel::Fixed { per_worker_ms: vec![0.5; 4] })
            .faults(FaultModel { drop_prob: 0.1, retrans_ms: 2.0, seed: 3 })
            .mode(ExecutionMode::VirtualTime)
            .pool_threads(2)
            .fault_plan(FaultPlan::single_outage(1, 5, 10))
            .build()
            .expect("valid config");
        assert_eq!(cfg.pool_threads, 2);
        assert!(matches!(cfg.mode, ExecutionMode::VirtualTime));
    }

    #[test]
    fn worker_stats_accumulate() {
        let p = problem(114, 2);
        let cfg = ClusterConfig::builder()
            .admm(AdmmConfig {
                rho: 20.0,
                tau: 1,
                min_arrivals: 2,
                max_iters: 50,
                ..Default::default()
            })
            .build()
            .expect("valid config");
        let report = StarCluster::new(p).run(&cfg);
        for w in &report.workers {
            assert!(w.updates >= 50, "updates={}", w.updates);
            assert!(w.busy_s >= 0.0);
        }
        assert!(report.wall_clock_s > 0.0);
        assert!(report.iters_per_sec() > 0.0);
    }
}
