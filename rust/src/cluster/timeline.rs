//! Per-worker utilization accounting — the data behind the Fig. 2
//! sync-vs-async timeline comparison.

/// Accumulated per-worker statistics over one cluster run.
#[derive(Clone, Debug)]
pub struct WorkerStats {
    pub id: usize,
    /// Completed subproblem rounds (messages sent to the master).
    pub updates: usize,
    /// Seconds spent computing (incl. injected delay).
    pub busy_s: f64,
    /// Seconds between thread start and shutdown.
    pub lifetime_s: f64,
    /// Emulated message retransmissions (fault injection).
    pub retransmissions: usize,
}

impl WorkerStats {
    pub fn new(id: usize) -> Self {
        WorkerStats { id, updates: 0, busy_s: 0.0, lifetime_s: 0.0, retransmissions: 0 }
    }

    /// Fraction of the run spent idle (waiting for the master).
    pub fn idle_fraction(&self) -> f64 {
        if self.lifetime_s <= 0.0 {
            return 0.0;
        }
        (1.0 - self.busy_s / self.lifetime_s).clamp(0.0, 1.0)
    }
}

/// A summary of a whole run's utilization, printable as the Fig. 2 table.
#[derive(Clone, Debug)]
pub struct Timeline {
    pub workers: Vec<WorkerStats>,
    pub master_iters: usize,
    pub wall_clock_s: f64,
}

impl Timeline {
    /// Build the timeline of a finished cluster run. Works for both
    /// execution modes: in virtual time the seconds are simulated seconds.
    pub fn from_report(report: &crate::cluster::ClusterReport) -> Self {
        Timeline {
            workers: report.workers.clone(),
            master_iters: report.history.len(),
            wall_clock_s: report.wall_clock_s,
        }
    }

    pub fn total_updates(&self) -> usize {
        self.workers.iter().map(|w| w.updates).sum()
    }

    pub fn mean_idle_fraction(&self) -> f64 {
        if self.workers.is_empty() {
            return 0.0;
        }
        self.workers.iter().map(|w| w.idle_fraction()).sum::<f64>() / self.workers.len() as f64
    }

    /// Render an ASCII utilization table.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "master iterations: {}  wall-clock: {:.3}s\n",
            self.master_iters, self.wall_clock_s
        ));
        s.push_str("worker  updates  busy_s   idle%\n");
        for w in &self.workers {
            s.push_str(&format!(
                "{:>6}  {:>7}  {:>6.3}  {:>5.1}\n",
                w.id,
                w.updates,
                w.busy_s,
                100.0 * w.idle_fraction()
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_fraction_bounds() {
        let mut w = WorkerStats::new(0);
        w.busy_s = 1.0;
        w.lifetime_s = 4.0;
        assert!((w.idle_fraction() - 0.75).abs() < 1e-12);
        w.busy_s = 10.0; // busy > lifetime (clock skew) clamps to 0
        assert_eq!(w.idle_fraction(), 0.0);
        let fresh = WorkerStats::new(1);
        assert_eq!(fresh.idle_fraction(), 0.0);
    }

    #[test]
    fn timeline_aggregates() {
        let mut a = WorkerStats::new(0);
        a.updates = 5;
        a.busy_s = 1.0;
        a.lifetime_s = 2.0;
        let mut b = WorkerStats::new(1);
        b.updates = 7;
        b.busy_s = 2.0;
        b.lifetime_s = 2.0;
        let t = Timeline { workers: vec![a, b], master_iters: 10, wall_clock_s: 2.0 };
        assert_eq!(t.total_updates(), 12);
        assert!((t.mean_idle_fraction() - 0.25).abs() < 1e-12);
        let text = t.render();
        assert!(text.contains("master iterations: 10"));
        assert!(text.lines().count() >= 4);
    }
}
