//! Algorithm 4: the alternative asynchronous implementation in which the
//! **master** owns the dual updates (46) and the workers only compute
//! `x_i` (47).
//!
//! Section IV's cautionary tale: synchronously this is just Algorithm 1
//! with the update order interchanged, but asynchronously it needs strong
//! convexity and a *small* ρ (Theorem 2, eq. (48)) — and Fig. 4(b)/(d) show
//! it diverging where Algorithm 2 sails through. This module exists to
//! reproduce exactly that behaviour.

use crate::problems::ConsensusProblem;

use super::arrivals::{ArrivalModel, ArrivalTrace};
use super::engine::{run_engine, AltScheme, EngineOptions, TraceSource};
use super::master_pov::{NativeSolver, SubproblemSolver};
use super::{AdmmConfig, AdmmState, IterRecord, StopReason};

/// Result of an Algorithm-4 run.
pub struct AltSchemeOutput {
    pub state: AdmmState,
    pub history: Vec<IterRecord>,
    pub trace: ArrivalTrace,
    pub stop: StopReason,
}

impl AltSchemeOutput {
    pub fn diverged(&self) -> bool {
        self.stop == StopReason::Diverged
    }
}

/// Run Algorithm 4 (master's point of view) under the same partially
/// asynchronous protocol as Algorithm 2.
///
/// Deprecated: build a [`crate::admm::session::Session`] with the
/// [`AltScheme`] policy (and `residual_stopping(false)` for the historical
/// behaviour) instead.
#[deprecated(note = "use Session::builder()")]
pub fn run_alt_scheme(
    problem: &ConsensusProblem,
    cfg: &AdmmConfig,
    arrivals: &ArrivalModel,
) -> AltSchemeOutput {
    let mut solver = NativeSolver::new(problem);
    run_alt_scheme_with_solver(problem, cfg, arrivals, &mut solver)
}

/// Thin wrapper over the unified engine: the [`AltScheme`] policy
/// (master-owned duals, eq. (45)–(47)) driven by the in-process
/// [`TraceSource`] consuming `arrivals`. The historical Algorithm-4 driver
/// never evaluated the residual-based stopping rule, so
/// `residual_stopping` stays off here.
#[deprecated(note = "use Session::builder()")]
pub fn run_alt_scheme_with_solver(
    problem: &ConsensusProblem,
    cfg: &AdmmConfig,
    arrivals: &ArrivalModel,
    solver: &mut dyn SubproblemSolver,
) -> AltSchemeOutput {
    // ad-lint: allow(panic-free-lib): deprecated wrapper keeps its documented panic-on-invalid contract; Session::builder is the typed path
    cfg.validate(problem.num_workers()).expect("invalid AdmmConfig");
    let mut source = TraceSource::with_solver(problem.num_workers(), arrivals, solver);
    let policy = AltScheme { tau: cfg.tau };
    let opts = EngineOptions { residual_stopping: false, fault_plan: None };
    let run = run_engine(problem, cfg, &policy, &mut source, &opts);
    AltSchemeOutput { state: run.state, history: run.history, trace: run.trace, stop: run.stop }
}

#[cfg(test)]
#[allow(deprecated)] // the deprecated wrappers stay pinned by these tests
mod tests {
    use super::*;
    use crate::admm::kkt::kkt_residual;
    use crate::data::LassoInstance;
    use crate::rng::Pcg64;

    fn lasso(seed: u64, n_workers: usize, m: usize, n: usize) -> ConsensusProblem {
        let mut rng = Pcg64::seed_from_u64(seed);
        LassoInstance::synthetic(&mut rng, n_workers, m, n, 0.1, 0.1).problem()
    }

    #[test]
    fn synchronous_alt_scheme_converges() {
        // τ = 1: Algorithm 4 ≡ Algorithm 1 with interchanged order.
        let p = lasso(91, 4, 30, 10);
        let cfg = AdmmConfig { rho: 50.0, tau: 1, max_iters: 800, ..Default::default() };
        let out = run_alt_scheme(&p, &cfg, &ArrivalModel::Full);
        assert!(!out.diverged());
        let r = kkt_residual(&p, &out.state);
        assert!(r.max() < 1e-6, "{r:?}");
    }

    #[test]
    fn async_large_rho_diverges() {
        // The Fig. 4(b) phenomenon: strongly-convex-ish blocks (m > n) but
        // ρ far above the Theorem-2 bound + delays ⇒ divergence.
        let p = lasso(92, 8, 30, 10);
        let cfg = AdmmConfig { rho: 500.0, tau: 5, max_iters: 3000, ..Default::default() };
        let arr = ArrivalModel::probabilistic(vec![0.1, 0.1, 0.1, 0.1, 0.8, 0.8, 0.8, 0.8], 17);
        let out = run_alt_scheme(&p, &cfg, &arr);
        assert!(
            out.diverged() || out.history.last().unwrap().consensus > 1.0,
            "expected divergence; consensus={}",
            out.history.last().unwrap().consensus
        );
    }

    #[test]
    fn async_small_rho_converges_strongly_convex() {
        // Theorem 2 regime: strongly convex blocks (m >> n), tiny ρ.
        let p = lasso(93, 4, 60, 8);
        let cfg = AdmmConfig { rho: 1.0, tau: 3, max_iters: 6000, ..Default::default() };
        let arr = ArrivalModel::probabilistic(vec![0.3, 0.9, 0.3, 0.9], 19);
        let out = run_alt_scheme(&p, &cfg, &arr);
        assert!(!out.diverged());
        let r = kkt_residual(&p, &out.state);
        assert!(r.max() < 1e-2, "{r:?}");
    }

    #[test]
    fn trace_replay_is_deterministic() {
        let p = lasso(94, 4, 20, 8);
        let cfg = AdmmConfig { rho: 10.0, tau: 3, max_iters: 60, ..Default::default() };
        let arr = ArrivalModel::probabilistic(vec![0.4; 4], 23);
        let a = run_alt_scheme(&p, &cfg, &arr);
        let b = run_alt_scheme(&p, &cfg, &ArrivalModel::Trace(a.trace.clone()));
        assert_eq!(a.state.x0, b.state.x0);
    }
}
