//! Algorithm 4: the alternative asynchronous implementation in which the
//! **master** owns the dual updates (46) and the workers only compute
//! `x_i` (47).
//!
//! Section IV's cautionary tale: synchronously this is just Algorithm 1
//! with the update order interchanged, but asynchronously it needs strong
//! convexity and a *small* ρ (Theorem 2, eq. (48)) — and Fig. 4(b)/(d) show
//! it diverging where Algorithm 2 sails through. This module exists to
//! reproduce exactly that behaviour.

use crate::problems::ConsensusProblem;

use super::arrivals::{ArrivalModel, ArrivalTrace};
use super::master_pov::{NativeSolver, SubproblemSolver};
use super::{
    divergence_or_tol_stop, iter_record, master_x0_update, AdmmConfig, AdmmState, IterRecord,
    MasterScratch, StopReason,
};

/// Result of an Algorithm-4 run.
pub struct AltSchemeOutput {
    pub state: AdmmState,
    pub history: Vec<IterRecord>,
    pub trace: ArrivalTrace,
    pub stop: StopReason,
}

impl AltSchemeOutput {
    pub fn diverged(&self) -> bool {
        self.stop == StopReason::Diverged
    }
}

/// Run Algorithm 4 (master's point of view) under the same partially
/// asynchronous protocol as Algorithm 2.
pub fn run_alt_scheme(
    problem: &ConsensusProblem,
    cfg: &AdmmConfig,
    arrivals: &ArrivalModel,
) -> AltSchemeOutput {
    let mut solver = NativeSolver::new(problem);
    run_alt_scheme_with_solver(problem, cfg, arrivals, &mut solver)
}

pub fn run_alt_scheme_with_solver(
    problem: &ConsensusProblem,
    cfg: &AdmmConfig,
    arrivals: &ArrivalModel,
    solver: &mut dyn SubproblemSolver,
) -> AltSchemeOutput {
    cfg.validate(problem.num_workers()).expect("invalid AdmmConfig");
    let n_workers = problem.num_workers();
    let n = problem.dim();

    let mut state = cfg.initial_state(n_workers, n);
    // What each worker last *received*: (x̂₀, λ̂_i) — Algorithm 4 broadcasts
    // both (Step 6), unlike Algorithm 2 where workers own their duals.
    let mut x0_snap: Vec<Vec<f64>> = vec![state.x0.clone(); n_workers];
    let mut lam_snap: Vec<Vec<f64>> = state.lams.clone();
    let mut d = vec![0usize; n_workers];
    let mut sampler = arrivals.sampler(n_workers);

    let mut history = Vec::with_capacity(cfg.max_iters);
    let mut trace = ArrivalTrace::default();
    let mut prev_x0 = state.x0.clone();
    let mut stop = StopReason::MaxIters;
    let mut scratch = MasterScratch::new();
    let mut f_cache: Vec<f64> = Vec::with_capacity(n_workers);
    for i in 0..n_workers {
        f_cache.push(problem.local(i).eval_with(&state.xs[i], &mut scratch.ws));
    }

    for k in 0..cfg.max_iters {
        let set = sampler.next_set(&d, cfg.tau, cfg.min_arrivals);

        // (44)+(47): arrived workers report x_i computed against their
        // *stale* (x̂₀, λ̂_i) snapshots.
        let mut arrived = vec![false; n_workers];
        for &i in &set {
            arrived[i] = true;
            solver.solve(i, &lam_snap[i], &x0_snap[i], cfg.rho, &mut state.xs[i]);
            f_cache[i] = problem.local(i).eval_with(&state.xs[i], &mut scratch.ws);
            d[i] = 0;
        }
        for i in 0..n_workers {
            if !arrived[i] {
                d[i] += 1;
            }
        }

        // (45): x₀ update uses λᵏ (pre-update duals).
        prev_x0.copy_from_slice(&state.x0);
        master_x0_update(problem, &mut state, cfg.rho, cfg.gamma, &mut scratch);

        // (46): master updates the duals of **all** workers against the
        // fresh x₀ — the step that injects stale-x into every λ_i and
        // breaks the eq.-(29) identity Algorithm 2 enjoys.
        for i in 0..n_workers {
            for j in 0..n {
                state.lams[i][j] += cfg.rho * (state.xs[i][j] - state.x0[j]);
            }
        }

        // Step 6: broadcast (x₀, λ_i) to the arrived workers only.
        for &i in &set {
            x0_snap[i].copy_from_slice(&state.x0);
            lam_snap[i].copy_from_slice(&state.lams[i]);
        }

        let rec =
            iter_record(problem, &state, cfg, k, set.len(), &f_cache, &mut scratch, &prev_x0);
        let early = divergence_or_tol_stop(cfg, &state, &rec, k);
        history.push(rec);
        trace.sets.push(set);

        if let Some(reason) = early {
            stop = reason;
            break;
        }
    }

    AltSchemeOutput { state, history, trace, stop }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::admm::kkt::kkt_residual;
    use crate::data::LassoInstance;
    use crate::rng::Pcg64;

    fn lasso(seed: u64, n_workers: usize, m: usize, n: usize) -> ConsensusProblem {
        let mut rng = Pcg64::seed_from_u64(seed);
        LassoInstance::synthetic(&mut rng, n_workers, m, n, 0.1, 0.1).problem()
    }

    #[test]
    fn synchronous_alt_scheme_converges() {
        // τ = 1: Algorithm 4 ≡ Algorithm 1 with interchanged order.
        let p = lasso(91, 4, 30, 10);
        let cfg = AdmmConfig { rho: 50.0, tau: 1, max_iters: 800, ..Default::default() };
        let out = run_alt_scheme(&p, &cfg, &ArrivalModel::Full);
        assert!(!out.diverged());
        let r = kkt_residual(&p, &out.state);
        assert!(r.max() < 1e-6, "{r:?}");
    }

    #[test]
    fn async_large_rho_diverges() {
        // The Fig. 4(b) phenomenon: strongly-convex-ish blocks (m > n) but
        // ρ far above the Theorem-2 bound + delays ⇒ divergence.
        let p = lasso(92, 8, 30, 10);
        let cfg = AdmmConfig { rho: 500.0, tau: 5, max_iters: 3000, ..Default::default() };
        let arr = ArrivalModel::probabilistic(vec![0.1, 0.1, 0.1, 0.1, 0.8, 0.8, 0.8, 0.8], 17);
        let out = run_alt_scheme(&p, &cfg, &arr);
        assert!(
            out.diverged() || out.history.last().unwrap().consensus > 1.0,
            "expected divergence; consensus={}",
            out.history.last().unwrap().consensus
        );
    }

    #[test]
    fn async_small_rho_converges_strongly_convex() {
        // Theorem 2 regime: strongly convex blocks (m >> n), tiny ρ.
        let p = lasso(93, 4, 60, 8);
        let cfg = AdmmConfig { rho: 1.0, tau: 3, max_iters: 6000, ..Default::default() };
        let arr = ArrivalModel::probabilistic(vec![0.3, 0.9, 0.3, 0.9], 19);
        let out = run_alt_scheme(&p, &cfg, &arr);
        assert!(!out.diverged());
        let r = kkt_residual(&p, &out.state);
        assert!(r.max() < 1e-2, "{r:?}");
    }

    #[test]
    fn trace_replay_is_deterministic() {
        let p = lasso(94, 4, 20, 8);
        let cfg = AdmmConfig { rho: 10.0, tau: 3, max_iters: 60, ..Default::default() };
        let arr = ArrivalModel::probabilistic(vec![0.4; 4], 23);
        let a = run_alt_scheme(&p, &cfg, &arr);
        let b = run_alt_scheme(&p, &cfg, &ArrivalModel::Trace(a.trace.clone()));
        assert_eq!(a.state.x0, b.state.x0);
    }
}
