//! Algorithm 3: the AD-ADMM (Algorithm 2) from the master's point of view.
//!
//! This serial simulator is what the paper's own Section V figures were
//! produced with ("implemented on a desktop computer"): it replays the exact
//! update sequence the distributed protocol induces — per-worker `x₀`
//! snapshots (`x₀^{k̄_i+1}`), delayed dual updates, delay counters, the
//! `|A_k| ≥ A` gate — without threads, so figure runs are deterministic and
//! fast. The threaded implementation lives in [`crate::cluster`] and is
//! trace-equivalent (tested).

use crate::bench::json::JsonValue;
use crate::problems::{ConsensusProblem, WorkerScratch};
use crate::solvers::inexact::{solve_inexact, InexactPolicy, WarmState};

use super::arrivals::{ArrivalModel, ArrivalTrace};
use super::engine::{run_engine, EngineOptions, PartialBarrier, TraceSource};
use super::{AdmmConfig, AdmmState, IterRecord, StopReason};

/// Pluggable worker-subproblem solver: the native path delegates to
/// [`crate::problems::LocalCost::solve_subproblem`]; the PJRT path
/// ([`crate::runtime`]) executes the AOT-compiled JAX/Pallas artifact.
pub trait SubproblemSolver {
    fn solve(&mut self, worker: usize, lam: &[f64], x0: &[f64], rho: f64, out: &mut [f64]);
}

/// Closed-form/native solver backed by the problem's own local costs. Owns
/// the [`WorkerScratch`] its solves reuse across iterations, one
/// [`InexactPolicy`] per worker (uniform under the default spelling;
/// heterogeneous via [`NativeSolver::with_policies`]), and one
/// [`WarmState`] per worker (the inner-loop warm starts the inexact
/// policies persist across rounds; untouched — and empty — under
/// [`InexactPolicy::Exact`]).
pub struct NativeSolver<'a> {
    problem: &'a ConsensusProblem,
    scratch: WorkerScratch,
    policies: Vec<InexactPolicy>,
    warm: Vec<WarmState>,
}

impl<'a> NativeSolver<'a> {
    pub fn new(problem: &'a ConsensusProblem) -> Self {
        Self::with_policy(problem, InexactPolicy::Exact)
    }

    /// A solver whose per-worker solves all run under `policy`.
    pub fn with_policy(problem: &'a ConsensusProblem, policy: InexactPolicy) -> Self {
        Self::with_policies(problem, vec![policy; problem.num_workers()])
    }

    /// A solver with heterogeneous per-worker policies: worker `i` solves
    /// under `policies[i]` — a fast machine can run `newton:2` while a
    /// straggler runs `grad:3`.
    pub fn with_policies(problem: &'a ConsensusProblem, policies: Vec<InexactPolicy>) -> Self {
        let warm = vec![WarmState::default(); problem.num_workers()];
        NativeSolver { problem, scratch: WorkerScratch::new(), policies, warm }
    }

    /// The per-worker policies this solver runs under.
    pub fn policies(&self) -> &[InexactPolicy] {
        &self.policies
    }

    /// Serialize the per-worker warm-start states (checkpoint v3).
    pub fn warm_to_json(&self) -> JsonValue {
        JsonValue::Arr(self.warm.iter().map(WarmState::to_json).collect())
    }

    /// Restore the per-worker warm-start states from
    /// [`NativeSolver::warm_to_json`] output.
    pub fn load_warm(&mut self, doc: &JsonValue) -> Result<(), String> {
        let items = doc.items();
        if items.len() != self.warm.len() {
            return Err(format!(
                "warm-state count mismatch: checkpoint has {}, problem has {} workers",
                items.len(),
                self.warm.len()
            ));
        }
        for (slot, item) in self.warm.iter_mut().zip(items) {
            *slot = WarmState::from_json(item)?;
        }
        Ok(())
    }
}

impl<'a> SubproblemSolver for NativeSolver<'a> {
    fn solve(&mut self, worker: usize, lam: &[f64], x0: &[f64], rho: f64, out: &mut [f64]) {
        solve_inexact(
            &**self.problem.local(worker),
            &self.policies[worker],
            lam,
            x0,
            rho,
            out,
            &mut self.scratch,
            &mut self.warm[worker],
        );
    }
}

/// Result of a master-PoV run.
pub struct MasterPovOutput {
    pub state: AdmmState,
    pub history: Vec<IterRecord>,
    /// The realized arrival sets (replayable via `ArrivalModel::Trace`).
    pub trace: ArrivalTrace,
    pub stop: StopReason,
    /// Final delay counters (invariant: all ≤ τ − 1).
    pub final_delays: Vec<usize>,
}

impl MasterPovOutput {
    pub fn diverged(&self) -> bool {
        self.stop == StopReason::Diverged
    }
}

/// Run Algorithm 3 with the native subproblem solver.
///
/// Deprecated: build a [`crate::admm::session::Session`] with the
/// [`PartialBarrier`] policy instead (typed errors, streaming observers,
/// step/checkpoint/resume).
#[deprecated(note = "use Session::builder()")]
pub fn run_master_pov(
    problem: &ConsensusProblem,
    cfg: &AdmmConfig,
    arrivals: &ArrivalModel,
) -> MasterPovOutput {
    let mut solver = NativeSolver::new(problem);
    run_master_pov_with_solver(problem, cfg, arrivals, &mut solver)
}

/// Run Algorithm 3 with a caller-supplied subproblem solver (e.g. the PJRT
/// engine executing the AOT JAX/Pallas artifacts).
///
/// Thin wrapper over the unified engine: the [`PartialBarrier`] policy
/// (τ-forced partially asynchronous gate, workers own their duals) driven
/// by the in-process [`TraceSource`] consuming `arrivals`.
#[deprecated(note = "use Session::builder()")]
pub fn run_master_pov_with_solver(
    problem: &ConsensusProblem,
    cfg: &AdmmConfig,
    arrivals: &ArrivalModel,
    solver: &mut dyn SubproblemSolver,
) -> MasterPovOutput {
    // ad-lint: allow(panic-free-lib): deprecated wrapper keeps its documented panic-on-invalid contract; Session::builder is the typed path
    cfg.validate(problem.num_workers()).expect("invalid AdmmConfig");
    let mut source = TraceSource::with_solver(problem.num_workers(), arrivals, solver);
    let policy = PartialBarrier { tau: cfg.tau };
    let run = run_engine(problem, cfg, &policy, &mut source, &EngineOptions::default());
    MasterPovOutput {
        state: run.state,
        history: run.history,
        trace: run.trace,
        stop: run.stop,
        final_delays: run.final_delays,
    }
}

#[cfg(test)]
#[allow(deprecated)] // the deprecated wrappers stay pinned by these tests
mod tests {
    use super::*;
    use crate::admm::kkt::{dual_identity_residual, kkt_residual};
    use crate::data::LassoInstance;
    use crate::rng::Pcg64;

    fn small_lasso(seed: u64) -> ConsensusProblem {
        let mut rng = Pcg64::seed_from_u64(seed);
        LassoInstance::synthetic(&mut rng, 4, 20, 10, 0.2, 0.1).problem()
    }

    #[test]
    fn synchronous_run_converges_to_kkt() {
        let p = small_lasso(71);
        let cfg = AdmmConfig { rho: 50.0, tau: 1, max_iters: 600, ..Default::default() };
        let out = run_master_pov(&p, &cfg, &ArrivalModel::Full);
        assert_eq!(out.stop, StopReason::MaxIters);
        let r = kkt_residual(&p, &out.state);
        assert!(r.max() < 1e-6, "KKT residual {:?}", r);
    }

    #[test]
    fn async_run_converges_to_kkt() {
        let p = small_lasso(72);
        let cfg = AdmmConfig { rho: 50.0, tau: 5, max_iters: 2000, ..Default::default() };
        let arr = ArrivalModel::probabilistic(vec![0.3, 0.9, 0.3, 0.9], 7);
        let out = run_master_pov(&p, &cfg, &arr);
        let r = kkt_residual(&p, &out.state);
        assert!(r.max() < 1e-5, "KKT residual {:?}", r);
        // the realized trace must satisfy Assumption 1
        assert!(out.trace.satisfies_bounded_delay(4, cfg.tau));
    }

    #[test]
    fn dual_identity_holds_every_iteration() {
        // eq. (29): ∇f_i(x_i^{k+1}) + λ_i^{k+1} = 0 for all i and k.
        // Check at the end (it holds inductively if it holds once).
        let p = small_lasso(73);
        let cfg = AdmmConfig { rho: 30.0, tau: 4, max_iters: 50, ..Default::default() };
        let arr = ArrivalModel::probabilistic(vec![0.4; 4], 3);
        let out = run_master_pov(&p, &cfg, &arr);
        assert!(dual_identity_residual(&p, &out.state) < 1e-8);
    }

    #[test]
    fn delays_never_exceed_tau_minus_one() {
        let p = small_lasso(74);
        let tau = 3;
        let cfg = AdmmConfig { rho: 30.0, tau, max_iters: 200, ..Default::default() };
        let arr = ArrivalModel::probabilistic(vec![0.15; 4], 11);
        let out = run_master_pov(&p, &cfg, &arr);
        assert!(out.final_delays.iter().all(|&di| di <= tau - 1));
        assert!(out.trace.satisfies_bounded_delay(4, tau));
    }

    #[test]
    fn trace_replay_reproduces_run_exactly() {
        let p = small_lasso(75);
        let cfg = AdmmConfig { rho: 40.0, tau: 4, max_iters: 120, ..Default::default() };
        let arr = ArrivalModel::probabilistic(vec![0.3, 0.8, 0.5, 0.2], 5);
        let out1 = run_master_pov(&p, &cfg, &arr);
        let out2 = run_master_pov(&p, &cfg, &ArrivalModel::Trace(out1.trace.clone()));
        assert_eq!(out1.state.x0, out2.state.x0);
        assert_eq!(out1.history.len(), out2.history.len());
        for (a, b) in out1.history.iter().zip(&out2.history) {
            assert_eq!(a.aug_lagrangian, b.aug_lagrangian);
        }
    }

    #[test]
    fn gamma_proximal_slows_x0() {
        let p = small_lasso(76);
        let arr = ArrivalModel::Full;
        let run = |gamma| {
            let cfg = AdmmConfig { rho: 20.0, gamma, tau: 1, max_iters: 1, ..Default::default() };
            run_master_pov(&p, &cfg, &arr).history[0].x0_change
        };
        assert!(run(1e6) < run(0.0));
    }

    #[test]
    fn nonconvex_spca_converges_with_large_rho() {
        use crate::data::SparsePcaInstance;
        let mut rng = Pcg64::seed_from_u64(77);
        let inst = SparsePcaInstance::synthetic(&mut rng, 4, 40, 16, 80, 0.1);
        let p = inst.problem();
        // ρ = 3L (β = 3 under the paper's ρ = β·L rule); random nonzero
        // start — x = 0 is an exact fixed point of the iteration.
        let rho = 3.0 * p.lipschitz();
        let mut init = vec![0.0; 16];
        rng.fill_normal(&mut init);
        let cfg = AdmmConfig {
            rho,
            tau: 4,
            max_iters: 2000,
            init_x0: Some(init),
            ..Default::default()
        };
        let arr = ArrivalModel::fig3_profile(4, 9);
        let out = run_master_pov(&p, &cfg, &arr);
        assert_eq!(out.stop, StopReason::MaxIters);
        let r = kkt_residual(&p, &out.state);
        assert!(r.max() < 1e-4, "KKT residual {:?}", r);
        // the solution is non-trivial (escaped the x = 0 fixed point)
        assert!(out.state.x0.iter().any(|v| v.abs() > 1e-3));
    }

    #[test]
    fn l1_regularizer_induces_sparsity() {
        let mut rng = Pcg64::seed_from_u64(78);
        let inst = LassoInstance::synthetic(&mut rng, 4, 30, 20, 0.1, 5.0);
        let p = inst.problem();
        let cfg = AdmmConfig { rho: 50.0, tau: 1, max_iters: 500, ..Default::default() };
        let out = run_master_pov(&p, &cfg, &ArrivalModel::Full);
        let zeros = out.state.x0.iter().filter(|v| v.abs() < 1e-9).count();
        assert!(zeros > 0, "strong θ should zero some coordinates");
    }
}
