//! Arrival-set models: who reports to the master at iteration k.
//!
//! The partially asynchronous protocol (Assumption 1 + the `|A_k| ≥ A`
//! gate) is enforced *on top of* a stochastic arrival process, exactly as in
//! the paper's Section V simulations: each worker independently "arrives"
//! with its own probability, the master keeps waiting (re-drawing) until at
//! least `A` workers arrived, and any worker whose delay counter has hit
//! `τ − 1` is waited for unconditionally (it joins the arrival set).

use crate::bench::json::{hex_u128, json_usize, u128_from_hex, JsonValue};
use crate::rng::Pcg64;

/// A recorded sequence of arrival sets (sorted worker indices per
/// iteration). Produced by every run for replay + invariant checking.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ArrivalTrace {
    pub sets: Vec<Vec<usize>>,
}

impl ArrivalTrace {
    /// Number of recorded master iterations.
    pub fn len(&self) -> usize {
        self.sets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sets.is_empty()
    }

    /// Check Assumption 1 against a delay bound τ: every worker appears at
    /// least once in every window of τ consecutive iterations (after its
    /// first possible window).
    pub fn satisfies_bounded_delay(&self, n_workers: usize, tau: usize) -> bool {
        let mut last_seen = vec![-1isize; n_workers]; // A_{-1} = V (paper's convention)
        for (k, set) in self.sets.iter().enumerate() {
            for &i in set {
                last_seen[i] = k as isize;
            }
            for i in 0..n_workers {
                if (k as isize) - last_seen[i] >= tau as isize {
                    return false;
                }
            }
        }
        true
    }

    /// The per-block Assumption 1 of the block-wise analysis
    /// (arXiv:1802.08882): every coordinate block receives an update from
    /// at least one of its owners in every window of τ consecutive
    /// iterations. Implied by [`ArrivalTrace::satisfies_bounded_delay`]
    /// (per worker) whenever every block has an owner, but strictly
    /// weaker: a block with several owners stays fresh as long as *any*
    /// of them keeps arriving.
    pub fn satisfies_bounded_delay_blocks(
        &self,
        pattern: &crate::problems::BlockPattern,
        tau: usize,
    ) -> bool {
        let nb = pattern.num_blocks();
        let mut last_seen = vec![-1isize; nb]; // A_{-1} = V convention
        for (k, set) in self.sets.iter().enumerate() {
            for &i in set {
                for &b in pattern.owned(i) {
                    last_seen[b] = k as isize;
                }
            }
            for b in 0..nb {
                if (k as isize) - last_seen[b] >= tau as isize {
                    return false;
                }
            }
        }
        true
    }

    /// Max observed arrival-set size (the `S` of Theorem 1, as `|A_k| < S`
    /// wants a strict bound: returns `max|A_k| + 1` capped at `N`).
    pub fn observed_s(&self, n_workers: usize) -> f64 {
        let m = self.sets.iter().map(Vec::len).max().unwrap_or(0);
        ((m + 1) as f64).min(n_workers as f64).max(1.0)
    }
}

/// How arrival sets are produced.
#[derive(Clone, Debug)]
pub enum ArrivalModel {
    /// Every worker arrives every iteration (synchronous; τ must be 1-compatible).
    Full,
    /// Independent per-worker Bernoulli arrivals, re-drawn while `|A_k| < A`
    /// (the paper's Section V process).
    Probabilistic { probs: Vec<f64>, seed: u64 },
    /// Replay an explicit trace (deterministic tests, cluster equivalence).
    Trace(ArrivalTrace),
}

impl ArrivalModel {
    pub fn probabilistic(probs: Vec<f64>, seed: u64) -> Self {
        ArrivalModel::Probabilistic { probs, seed }
    }

    /// The Fig. 3 worker profile: half the workers arrive w.p. 0.1, half
    /// w.p. 0.8.
    pub fn fig3_profile(n_workers: usize, seed: u64) -> Self {
        let mut probs = vec![0.1; n_workers];
        for p in probs.iter_mut().skip(n_workers / 2) {
            *p = 0.8;
        }
        ArrivalModel::Probabilistic { probs, seed }
    }

    /// The Fig. 4 worker profile for N = 16: 8 workers w.p. 0.1, 4 w.p.
    /// 0.5, 4 w.p. 0.8 (generalized proportionally for other N).
    pub fn fig4_profile(n_workers: usize, seed: u64) -> Self {
        let mut probs = Vec::with_capacity(n_workers);
        for i in 0..n_workers {
            let frac = i as f64 / n_workers as f64;
            probs.push(if frac < 0.5 {
                0.1
            } else if frac < 0.75 {
                0.5
            } else {
                0.8
            });
        }
        ArrivalModel::Probabilistic { probs, seed }
    }

    /// Create the per-run sampler.
    pub fn sampler(&self, n_workers: usize) -> ArrivalSampler {
        match self {
            ArrivalModel::Full => ArrivalSampler {
                n_workers,
                kind: SamplerKind::Full,
            },
            ArrivalModel::Probabilistic { probs, seed } => {
                assert_eq!(probs.len(), n_workers, "one probability per worker");
                ArrivalSampler {
                    n_workers,
                    kind: SamplerKind::Probabilistic {
                        probs: probs.clone(),
                        rng: Pcg64::seed_from_u64(*seed),
                    },
                }
            }
            ArrivalModel::Trace(trace) => ArrivalSampler {
                n_workers,
                kind: SamplerKind::Trace { sets: trace.sets.clone(), pos: 0 },
            },
        }
    }
}

enum SamplerKind {
    Full,
    Probabilistic { probs: Vec<f64>, rng: Pcg64 },
    Trace { sets: Vec<Vec<usize>>, pos: usize },
}

/// Stateful arrival-set source for one run.
pub struct ArrivalSampler {
    n_workers: usize,
    kind: SamplerKind,
}

impl ArrivalSampler {
    /// Draw the next arrival set given current pre-update delay counters
    /// `d`, the delay bound τ and the batching gate `A = min_arrivals`.
    ///
    /// Guarantees on return (stochastic kinds): every `i` with
    /// `d[i] ≥ τ − 1` is included (the master waited for it) and
    /// `|set| ≥ min(A, N)`. Trace replays are authoritative instead — see
    /// [`ArrivalSampler::next_set_gated`].
    pub fn next_set(&mut self, d: &[usize], tau: usize, min_arrivals: usize) -> Vec<usize> {
        let no_down = vec![false; self.n_workers];
        self.next_set_gated(d, tau, min_arrivals, &no_down)
    }

    /// [`ArrivalSampler::next_set`] under a fault mask: workers with
    /// `down[i]` set never arrive this iteration — they are excluded from
    /// the τ-forcing (the master cannot wait for a dropped worker), from
    /// the Bernoulli draws, and from the returned set — and the `|A_k| ≥ A`
    /// target shrinks to the live-worker count. With an all-false mask the
    /// draw sequence and the returned set are identical to `next_set`.
    ///
    /// A replayed [`ArrivalTrace`] is **authoritative**: its prescribed
    /// sets are honoured literally (minus down workers), with no τ-forcing
    /// on top. Traces realized under Assumption 1 already contain every
    /// forced worker, so this changes nothing for them — but it lets
    /// traces that *violate* the bound (fault scenarios: a dropped worker
    /// overstays τ) replay bit-exactly instead of having absent workers
    /// silently forced back in.
    pub fn next_set_gated(
        &mut self,
        d: &[usize],
        tau: usize,
        min_arrivals: usize,
        down: &[bool],
    ) -> Vec<usize> {
        let n = self.n_workers;
        debug_assert_eq!(d.len(), n);
        debug_assert_eq!(down.len(), n);
        let mut arrived = vec![false; n];
        match &mut self.kind {
            SamplerKind::Full => {
                for (i, a) in arrived.iter_mut().enumerate() {
                    if !down[i] {
                        *a = true;
                    }
                }
            }
            SamplerKind::Trace { sets, pos } => {
                let set = sets
                    .get(*pos)
                    .unwrap_or_else(|| {
                        // ad-lint: allow(panic-free-lib): documented ArrivalModel::Trace contract: callers supply enough sets; Session validates length at build
                        panic!("arrival trace exhausted at iteration {pos}", pos = *pos)
                    })
                    .clone();
                *pos += 1;
                for &i in &set {
                    assert!(i < n, "trace worker index out of range");
                    arrived[i] = true;
                }
            }
            SamplerKind::Probabilistic { probs, rng } => {
                for i in 0..n {
                    if !down[i] && d[i] + 1 >= tau {
                        arrived[i] = true; // forced by the Assumption-1 gate
                    }
                }
                // The master keeps waiting (we keep drawing rounds) until the
                // gate is met; arrivals accumulate across rounds, modelling
                // messages that keep coming in while it waits.
                let n_live = down.iter().filter(|&&dn| !dn).count();
                let target = if n_live == 0 { 0 } else { min_arrivals.min(n_live).max(1) };
                let mut rounds = 0usize;
                loop {
                    for i in 0..n {
                        if !arrived[i] && !down[i] && rng.bernoulli(probs[i]) {
                            arrived[i] = true;
                        }
                    }
                    if arrived.iter().filter(|&&a| a).count() >= target {
                        break;
                    }
                    rounds += 1;
                    if rounds > 100_000 {
                        // all-zero probabilities: degenerate configuration;
                        // wait for every live worker rather than spin forever.
                        for (i, a) in arrived.iter_mut().enumerate() {
                            if !down[i] {
                                *a = true;
                            }
                        }
                        break;
                    }
                }
            }
        }
        (0..n).filter(|&i| arrived[i] && !down[i]).collect()
    }

    /// Serialize the sampler's mid-run cursor for a session checkpoint:
    /// the full model is stateless, a trace replay carries its position,
    /// and the probabilistic model carries its exact PCG-64 stream state
    /// (so resumed draws continue bit-identically).
    pub fn save(&self) -> JsonValue {
        match &self.kind {
            SamplerKind::Full => JsonValue::Obj(vec![("kind".to_string(), "full".into())]),
            SamplerKind::Probabilistic { rng, .. } => {
                let (state, inc) = rng.to_raw();
                JsonValue::Obj(vec![
                    ("kind".to_string(), "probabilistic".into()),
                    ("rng_state".to_string(), hex_u128(state)),
                    ("rng_inc".to_string(), hex_u128(inc)),
                ])
            }
            SamplerKind::Trace { pos, .. } => JsonValue::Obj(vec![
                ("kind".to_string(), "trace".into()),
                ("pos".to_string(), JsonValue::Num(*pos as f64)),
            ]),
        }
    }

    /// Restore a cursor produced by [`ArrivalSampler::save`] into a
    /// freshly built sampler of the *same* model (probabilities and
    /// replayed sets are rebuilt by the caller; only the cursor/stream
    /// state is restored). Errors on a model-kind mismatch.
    pub fn load(&mut self, doc: &JsonValue) -> Result<(), String> {
        let kind = doc
            .get("kind")
            .and_then(JsonValue::as_str)
            .ok_or_else(|| "sampler checkpoint missing kind".to_string())?;
        match (&mut self.kind, kind) {
            (SamplerKind::Full, "full") => Ok(()),
            (SamplerKind::Probabilistic { rng, .. }, "probabilistic") => {
                let state = u128_from_hex(
                    doc.get("rng_state").ok_or_else(|| "missing rng_state".to_string())?,
                )?;
                let inc = u128_from_hex(
                    doc.get("rng_inc").ok_or_else(|| "missing rng_inc".to_string())?,
                )?;
                *rng = Pcg64::from_raw(state, inc);
                Ok(())
            }
            (SamplerKind::Trace { sets, pos }, "trace") => {
                let p = json_usize(doc.get("pos").ok_or_else(|| "missing pos".to_string())?)?;
                if p > sets.len() {
                    return Err(format!(
                        "trace cursor {p} beyond the replayed trace ({} sets)",
                        sets.len()
                    ));
                }
                *pos = p;
                Ok(())
            }
            (_, other) => Err(format!(
                "sampler checkpoint kind {other:?} does not match the configured arrival model"
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_model_returns_everyone() {
        let m = ArrivalModel::Full;
        let mut s = m.sampler(4);
        assert_eq!(s.next_set(&[0; 4], 1, 4), vec![0, 1, 2, 3]);
    }

    #[test]
    fn forced_workers_always_included() {
        let m = ArrivalModel::probabilistic(vec![0.0, 1.0, 0.0], 1);
        let mut s = m.sampler(3);
        // worker 0 has d = 2 with τ = 3 → d+1 >= τ → forced
        let set = s.next_set(&[2, 0, 0], 3, 1);
        assert!(set.contains(&0));
    }

    #[test]
    fn gate_met_even_with_low_probs() {
        let m = ArrivalModel::probabilistic(vec![0.05; 8], 2);
        let mut s = m.sampler(8);
        for _ in 0..50 {
            let set = s.next_set(&[0; 8], 100, 3);
            assert!(set.len() >= 3);
        }
    }

    #[test]
    fn tau_one_forces_everyone() {
        let m = ArrivalModel::probabilistic(vec![0.01; 5], 3);
        let mut s = m.sampler(5);
        // τ = 1 → every d[i] + 1 >= 1 → all forced → synchronous
        assert_eq!(s.next_set(&[0; 5], 1, 1), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn trace_replays_exactly() {
        let trace = ArrivalTrace { sets: vec![vec![0, 2], vec![1]] };
        let m = ArrivalModel::Trace(trace.clone());
        let mut s = m.sampler(3);
        assert_eq!(s.next_set(&[0; 3], 10, 1), vec![0, 2]);
        assert_eq!(s.next_set(&[0; 3], 10, 1), vec![1]);
    }

    #[test]
    fn bounded_delay_checker() {
        let good = ArrivalTrace { sets: vec![vec![0], vec![1], vec![0], vec![1]] };
        assert!(good.satisfies_bounded_delay(2, 2));
        let bad = ArrivalTrace { sets: vec![vec![0], vec![0], vec![0]] };
        assert!(!bad.satisfies_bounded_delay(2, 2));
        // worker 1 is absent for the whole 3-iteration trace: still a
        // violation at τ = 3 (window [0,2] excludes A_{-1} = V)...
        assert!(!bad.satisfies_bounded_delay(2, 3));
        // ...but fine at τ = 4 where every window still reaches A_{-1}.
        assert!(bad.satisfies_bounded_delay(2, 4));
        let recovers = ArrivalTrace { sets: vec![vec![0], vec![0], vec![0, 1]] };
        assert!(recovers.satisfies_bounded_delay(2, 3));
    }

    #[test]
    fn per_block_bounded_delay_is_weaker_than_per_worker() {
        use crate::problems::BlockPattern;
        // 2 workers, both owning the single block: the block stays fresh
        // as long as ANY worker arrives, even when worker 1 overstays τ.
        let p = BlockPattern::dense(4, 2);
        let t = ArrivalTrace { sets: vec![vec![0], vec![0], vec![0]] };
        assert!(!t.satisfies_bounded_delay(2, 2));
        assert!(t.satisfies_bounded_delay_blocks(&p, 2));

        // Disjoint ownership: worker 1's silence starves its block.
        let q = BlockPattern::new(4, &[(0, 2), (2, 2)], vec![vec![0], vec![1]]).unwrap();
        assert!(!t.satisfies_bounded_delay_blocks(&q, 2));
        let alternating = ArrivalTrace { sets: vec![vec![0], vec![1], vec![0], vec![1]] };
        assert!(alternating.satisfies_bounded_delay_blocks(&q, 2));
    }

    #[test]
    fn fig_profiles_have_expected_shape() {
        if let ArrivalModel::Probabilistic { probs, .. } = ArrivalModel::fig3_profile(32, 0) {
            assert_eq!(probs.iter().filter(|&&p| p == 0.1).count(), 16); // ad-lint: allow(float-eq): profile probabilities are assigned literals; counting them is exact
            assert_eq!(probs.iter().filter(|&&p| p == 0.8).count(), 16);
        } else {
            panic!("wrong variant");
        }
        if let ArrivalModel::Probabilistic { probs, .. } = ArrivalModel::fig4_profile(16, 0) {
            assert_eq!(probs.iter().filter(|&&p| p == 0.1).count(), 8); // ad-lint: allow(float-eq): assigned literal, exact
            assert_eq!(probs.iter().filter(|&&p| p == 0.5).count(), 4); // ad-lint: allow(float-eq): assigned literal, exact
            assert_eq!(probs.iter().filter(|&&p| p == 0.8).count(), 4);
        } else {
            panic!("wrong variant");
        }
    }

    #[test]
    fn observed_s_bounds() {
        let t = ArrivalTrace { sets: vec![vec![0, 1], vec![2]] };
        assert_eq!(t.observed_s(4), 3.0);
        assert_eq!(t.observed_s(2), 2.0); // capped at N
    }

    #[test]
    fn empty_trace_edge_cases() {
        let t = ArrivalTrace::default();
        assert!(t.is_empty());
        assert_eq!(t.len(), 0);
        // vacuously satisfies Assumption 1 for every τ (no iterations)
        for tau in 1..5 {
            assert!(t.satisfies_bounded_delay(3, tau));
        }
        // S is clamped to ≥ 1 even with no arrivals observed
        assert_eq!(t.observed_s(4), 1.0);
        assert_eq!(t.observed_s(0), 1.0);
    }

    #[test]
    fn tau_one_requires_everyone_every_iteration() {
        // τ = 1 means synchronous: any missing worker is an immediate
        // violation at that iteration.
        let full = ArrivalTrace { sets: vec![vec![0, 1, 2]; 4] };
        assert!(full.satisfies_bounded_delay(3, 1));
        let miss = ArrivalTrace { sets: vec![vec![0, 1, 2], vec![0, 2], vec![0, 1, 2]] };
        assert!(!miss.satisfies_bounded_delay(3, 1));
    }

    #[test]
    fn worker_never_arriving() {
        // Worker 1 is absent for the whole L-iteration trace. Counting from
        // the A_{-1} = V convention, the violation appears exactly when the
        // trace is at least τ iterations long.
        for len in 1..6 {
            let t = ArrivalTrace { sets: vec![vec![0]; len] };
            for tau in 1..8 {
                let ok = t.satisfies_bounded_delay(2, tau);
                assert_eq!(ok, len < tau, "len={len} tau={tau} → {ok}");
            }
        }
        // observed_s only counts arrivals; the absentee does not inflate S
        let t = ArrivalTrace { sets: vec![vec![0]; 3] };
        assert_eq!(t.observed_s(2), 2.0);
    }

    #[test]
    fn trace_replay_is_authoritative_even_when_violating_assumption1() {
        // The prescribed sets exclude worker 1 for longer than τ (a fault
        // scenario's realized trace); replay must honour them literally
        // instead of forcing the overdue worker back in.
        let trace = ArrivalTrace { sets: vec![vec![0], vec![0], vec![0], vec![0, 1]] };
        assert!(!trace.satisfies_bounded_delay(2, 2));
        let mut s = ArrivalModel::Trace(trace.clone()).sampler(2);
        let mut d = vec![0usize; 2];
        for k in 0..4 {
            let set = s.next_set(&d, 2, 1);
            assert_eq!(set, trace.sets[k], "replay diverged at k={k}");
            for i in 0..2 {
                if set.contains(&i) {
                    d[i] = 0;
                } else {
                    d[i] += 1;
                }
            }
        }
    }

    #[test]
    fn gated_sampler_excludes_down_workers() {
        // down workers leave the set, and the |A_k| ≥ A target shrinks to
        // the live count so the gate stays satisfiable
        let m = ArrivalModel::probabilistic(vec![1.0; 4], 5);
        let mut s = m.sampler(4);
        let down = [false, true, false, false];
        let set = s.next_set_gated(&[0; 4], 5, 4, &down);
        assert_eq!(set, vec![0, 2, 3]);
        // an overdue worker is NOT forced in while down — the master
        // cannot wait for a dropped worker
        let m2 = ArrivalModel::probabilistic(vec![0.0, 1.0, 1.0], 6);
        let mut s2 = m2.sampler(3);
        let set2 = s2.next_set_gated(&[9, 0, 0], 3, 1, &[true, false, false]);
        assert!(!set2.contains(&0));
    }

    #[test]
    fn gated_sampler_all_false_matches_ungated() {
        let mk = || ArrivalModel::probabilistic(vec![0.3, 0.7, 0.5], 11).sampler(3);
        let (mut a, mut b) = (mk(), mk());
        let down = [false; 3];
        for _ in 0..50 {
            assert_eq!(a.next_set(&[0; 3], 4, 2), b.next_set_gated(&[0; 3], 4, 2, &down));
        }
    }

    #[test]
    fn gated_sampler_all_down_returns_empty() {
        let mut s = ArrivalModel::Full.sampler(3);
        assert!(s.next_set_gated(&[0; 3], 1, 3, &[true; 3]).is_empty());
        let mut p = ArrivalModel::probabilistic(vec![0.9; 3], 8).sampler(3);
        assert!(p.next_set_gated(&[0; 3], 2, 2, &[true; 3]).is_empty());
    }

    #[test]
    fn observed_s_strictness() {
        // |A_k| = 1 everywhere: the strict bound S must exceed it.
        let t = ArrivalTrace { sets: vec![vec![0], vec![1], vec![0]] };
        assert_eq!(t.observed_s(8), 2.0);
        // all-N sets: the cap keeps S ≤ N
        let full = ArrivalTrace { sets: vec![vec![0, 1, 2]] };
        assert_eq!(full.observed_s(3), 3.0);
    }
}
