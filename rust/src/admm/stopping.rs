//! Residual-based stopping criteria (Boyd et al. §3.3, adapted to the
//! consensus form) — the "predefined stopping criterion" the paper's
//! algorithm boxes leave open.
//!
//! Primal residual: `rᵏ = (x₁−x₀, …, x_N−x₀)`; dual residual for the
//! consensus problem: `sᵏ = ρ·N·(x₀ᵏ − x₀ᵏ⁻¹)` (the change of the shared
//! variable scaled by the coupling). Termination when both fall below
//! absolute + relative tolerances.

use crate::linalg::vecops;
use crate::problems::BlockPattern;

use super::AdmmState;

/// Combined absolute/relative tolerance rule.
#[derive(Clone, Debug)]
pub struct StoppingRule {
    pub abs_tol: f64,
    pub rel_tol: f64,
}

impl Default for StoppingRule {
    fn default() -> Self {
        StoppingRule { abs_tol: 1e-6, rel_tol: 1e-4 }
    }
}

/// The two residual norms at one iterate.
#[derive(Clone, Debug)]
pub struct Residuals {
    /// `‖rᵏ‖ = √(Σ‖x_i − x₀‖²)`.
    pub primal: f64,
    /// `‖sᵏ‖ = ρ·√N·‖x₀ᵏ − x₀ᵏ⁻¹‖`.
    pub dual: f64,
    /// Scale for the relative primal test: `max(√Σ‖x_i‖², √N‖x₀‖)`.
    pub primal_scale: f64,
    /// Scale for the relative dual test: `√(Σ‖λ_i‖²)`.
    pub dual_scale: f64,
}

/// Evaluate the residuals given the current state and previous `x₀`.
///
/// Reads every coordinate of `state.x0`, so the caller must hand it a
/// **materialized** iterate: under the lazy sparse master
/// ([`super::SparseMaster`]) blocks whose owners have not arrived
/// recently lag behind until caught up. [`super::session::Session`] does
/// this automatically — it only evaluates stopping on metric iterations,
/// after folding all deferred per-block prox work into `x₀`.
pub fn residuals(state: &AdmmState, prev_x0: &[f64], rho: f64) -> Residuals {
    let n_workers = state.xs.len() as f64;
    let mut primal_sq = 0.0;
    let mut xs_sq = 0.0;
    let mut lam_sq = 0.0;
    for i in 0..state.xs.len() {
        primal_sq += vecops::dist2_sq(&state.xs[i], &state.x0);
        xs_sq += vecops::nrm2_sq(&state.xs[i]);
        lam_sq += vecops::nrm2_sq(&state.lams[i]);
    }
    let x0_norm = vecops::nrm2(&state.x0);
    Residuals {
        primal: primal_sq.sqrt(),
        dual: rho * n_workers.sqrt() * vecops::dist2(&state.x0, prev_x0),
        primal_scale: xs_sq.sqrt().max(n_workers.sqrt() * x0_norm),
        dual_scale: lam_sq.sqrt(),
    }
}

/// [`residuals`] under a block pattern — the general-form consensus
/// residuals. The primal residual stacks each worker's `x_i − (x₀)_{S_i}`
/// over its owned slice; the dual residual and the `x₀` scale weight each
/// coordinate by its owner count `N_j` (the stacked constraint carries
/// `N_j` copies of coordinate `j`):
/// `‖sᵏ‖ = ρ·√(Σ_j N_j Δ_j²)` and `√(Σ_j N_j x₀ⱼ²)` — which reduce to the
/// dense `ρ·√N·‖Δ‖` / `√N·‖x₀‖` when every `N_j = N`. Effectively-dense
/// patterns delegate to [`residuals`] outright, so the dense arithmetic
/// (and its bit pattern) is preserved exactly.
pub fn residuals_blocks(
    state: &AdmmState,
    prev_x0: &[f64],
    rho: f64,
    pattern: &BlockPattern,
) -> Residuals {
    if pattern.is_effectively_dense() {
        return residuals(state, prev_x0, rho);
    }
    let mut primal_sq = 0.0;
    let mut xs_sq = 0.0;
    let mut lam_sq = 0.0;
    for i in 0..state.xs.len() {
        let xi = &state.xs[i];
        let mut s = 0.0;
        pattern.for_each_range(i, |lo, g, len| {
            for k in 0..len {
                let d = xi[lo + k] - state.x0[g + k];
                s += d * d;
            }
        });
        primal_sq += s;
        xs_sq += vecops::nrm2_sq(xi);
        lam_sq += vecops::nrm2_sq(&state.lams[i]);
    }
    let mut dual_sq = 0.0;
    let mut x0_w_sq = 0.0;
    for j in 0..state.x0.len() {
        let w = pattern.count(j) as f64;
        let d = state.x0[j] - prev_x0[j];
        dual_sq += w * d * d;
        x0_w_sq += w * state.x0[j] * state.x0[j];
    }
    Residuals {
        primal: primal_sq.sqrt(),
        dual: rho * dual_sq.sqrt(),
        primal_scale: xs_sq.sqrt().max(x0_w_sq.sqrt()),
        dual_scale: lam_sq.sqrt(),
    }
}

impl StoppingRule {
    /// True when both residuals satisfy `‖·‖ ≤ abs·√dim + rel·scale`.
    pub fn satisfied(&self, r: &Residuals, dim: usize, n_workers: usize) -> bool {
        let sqrt_p = ((dim * n_workers) as f64).sqrt();
        let eps_pri = self.abs_tol * sqrt_p + self.rel_tol * r.primal_scale;
        let eps_dual = self.abs_tol * sqrt_p + self.rel_tol * r.dual_scale;
        r.primal <= eps_pri && r.dual <= eps_dual
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residuals_zero_at_consensus_fixed_point() {
        let state = AdmmState::init(3, vec![1.0, -2.0]);
        let r = residuals(&state, &[1.0, -2.0], 10.0);
        assert_eq!(r.primal, 0.0);
        assert_eq!(r.dual, 0.0);
        assert!(StoppingRule::default().satisfied(&r, 2, 3));
    }

    #[test]
    fn violated_consensus_reports_primal() {
        let mut state = AdmmState::zeros(2, 2);
        state.xs[0] = vec![3.0, 4.0];
        let r = residuals(&state, &[0.0, 0.0], 1.0);
        assert!((r.primal - 5.0).abs() < 1e-12);
        assert!(!StoppingRule::default().satisfied(&r, 2, 2));
    }

    #[test]
    fn sharded_residuals_weight_by_owner_count() {
        use crate::problems::BlockPattern;
        // n = 2 as two singleton blocks; worker 0 owns both, worker 1 owns
        // block 0 → owner counts N_0 = 2, N_1 = 1.
        let pattern =
            BlockPattern::new(2, &[(0, 1), (1, 1)], vec![vec![0, 1], vec![0]]).unwrap();
        let mut state = AdmmState::init_blocks(&pattern, vec![1.0, 2.0]);
        state.xs[1] = vec![4.0]; // primal violation of 3 on coordinate 0
        let prev = vec![0.0, 0.0]; // Δ = (1, 2)
        let r = residuals_blocks(&state, &prev, 2.0, &pattern);
        assert!((r.primal - 3.0).abs() < 1e-12);
        // ‖s‖ = ρ·√(N_0·1² + N_1·2²) = 2·√6
        assert!((r.dual - 2.0 * 6.0f64.sqrt()).abs() < 1e-12);
        // x₀ scale: √(N_0·1² + N_1·2²) = √6; stacked xs: (1,2) and (4)
        let xs_norm = (1.0f64 + 4.0 + 16.0).sqrt();
        assert!((r.primal_scale - xs_norm.max(6.0f64.sqrt())).abs() < 1e-12);

        // Effectively-dense patterns delegate to the dense formulas
        // verbatim (bit-identical).
        let dense_pattern = BlockPattern::dense(2, 2);
        let mut s2 = AdmmState::init(2, vec![0.5, -1.0]);
        s2.xs[0] = vec![0.7, -0.2];
        let a = residuals(&s2, &[0.0, 0.1], 3.0);
        let b = residuals_blocks(&s2, &[0.0, 0.1], 3.0, &dense_pattern);
        assert_eq!(a.primal.to_bits(), b.primal.to_bits());
        assert_eq!(a.dual.to_bits(), b.dual.to_bits());
        assert_eq!(a.primal_scale.to_bits(), b.primal_scale.to_bits());
        assert_eq!(a.dual_scale.to_bits(), b.dual_scale.to_bits());
    }

    #[test]
    fn x0_movement_reports_dual() {
        let state = AdmmState::zeros(4, 1);
        let r = residuals(&state, &[1.0], 2.0);
        // ρ·√N·|0 − 1| = 2·2·1 = 4
        assert!((r.dual - 4.0).abs() < 1e-12);
    }

    #[test]
    fn nan_iterate_never_satisfies_the_rule() {
        // NaN anywhere in the iterate poisons the residual norms; every
        // comparison with NaN is false, so the rule must NOT report
        // convergence (the divergence guard upstream is what catches it).
        let mut state = AdmmState::zeros(2, 2);
        state.xs[0][0] = f64::NAN;
        let r = residuals(&state, &[0.0, 0.0], 1.0);
        assert!(r.primal.is_nan());
        assert!(!StoppingRule::default().satisfied(&r, 2, 2));
        let mut s2 = AdmmState::zeros(2, 2);
        s2.x0[0] = f64::NAN;
        let r2 = residuals(&s2, &[0.0, 0.0], 1.0);
        assert!(!StoppingRule { abs_tol: f64::INFINITY, rel_tol: 0.0 }.satisfied(&r2, 2, 2));
        assert!(!s2.is_finite());
    }

    /// A one-worker quadratic with `q = 0` and zero start: `x = 0` is an
    /// exact fixed point, so `x₀` never moves — the sharpest probe for the
    /// iteration-0 and max-iter tie edge cases.
    fn fixed_point_problem() -> crate::problems::ConsensusProblem {
        use crate::problems::QuadraticLocal;
        use std::sync::Arc;
        let l = Arc::new(QuadraticLocal::diagonal(&[1.0], vec![0.0]));
        crate::problems::ConsensusProblem::new(vec![l], crate::prox::Regularizer::Zero)
    }

    #[test]
    fn x0_tol_exactly_met_on_iter_zero_does_not_stop() {
        use crate::testkit::drivers::run_full_barrier;
        use crate::admm::AdmmConfig;
        use crate::data::LassoInstance;
        use crate::rng::Pcg64;

        let mut rng = Pcg64::seed_from_u64(610);
        let p = LassoInstance::synthetic(&mut rng, 3, 20, 8, 0.2, 0.1).problem();
        // Probe the exact k=0 movement, then use it as the tolerance: the
        // condition `x0_change <= x0_tol` holds with equality on iteration
        // 0, but the rule only arms from k ≥ 1.
        let probe_cfg = AdmmConfig { rho: 40.0, max_iters: 1, ..Default::default() };
        let probe = run_full_barrier(&p, &probe_cfg);
        let c0 = probe.history[0].x0_change;
        assert!(c0 > 0.0);
        let cfg = AdmmConfig { rho: 40.0, max_iters: 50, x0_tol: c0, ..Default::default() };
        let out = run_full_barrier(&p, &cfg);
        assert!(out.history.len() > 1, "stopped on iteration 0");
        assert_eq!(out.history[0].x0_change.to_bits(), c0.to_bits());
    }

    #[test]
    fn tolerance_on_final_iteration_wins_over_max_iters() {
        use crate::testkit::drivers::run_full_barrier;
        use crate::admm::{AdmmConfig, StopReason};

        // x₀ never moves; with max_iters = 2 the tolerance fires exactly
        // at k = 1 = max_iters − 1. The tie goes to X0Tolerance (the early
        // check precedes the loop bound) with a full-length history.
        let p = fixed_point_problem();
        let cfg = AdmmConfig { rho: 1.0, max_iters: 2, x0_tol: 1e-12, ..Default::default() };
        let out = run_full_barrier(&p, &cfg);
        assert_eq!(out.stop, StopReason::X0Tolerance);
        assert_eq!(out.history.len(), 2);
    }

    #[test]
    fn residual_rule_never_fires_on_iteration_zero() {
        use crate::testkit::drivers::run_full_barrier;
        use crate::admm::{AdmmConfig, StopReason};

        // At the fixed point both residuals are exactly zero from k = 0 —
        // satisfied — yet the k > 0 guard defers the rule...
        let p = fixed_point_problem();
        let cfg = AdmmConfig {
            rho: 1.0,
            max_iters: 1,
            stopping: Some(StoppingRule::default()),
            ..Default::default()
        };
        let out = run_full_barrier(&p, &cfg);
        assert_eq!(out.stop, StopReason::MaxIters);
        assert_eq!(out.history.len(), 1);
        // ...so the earliest it can fire is k = 1.
        let cfg2 = AdmmConfig { max_iters: 10, ..cfg };
        let out2 = run_full_barrier(&p, &cfg2);
        assert_eq!(out2.stop, StopReason::Residuals);
        assert_eq!(out2.history.len(), 2);
    }

    #[test]
    fn stopping_rule_triggers_on_converged_run() {
        use crate::testkit::drivers::run_full_barrier;
        use crate::admm::AdmmConfig;
        use crate::data::LassoInstance;
        use crate::rng::Pcg64;

        let mut rng = Pcg64::seed_from_u64(600);
        let inst = LassoInstance::synthetic(&mut rng, 3, 20, 8, 0.2, 0.1);
        let p = inst.problem();
        let cfg = AdmmConfig { rho: 40.0, max_iters: 2000, ..Default::default() };
        let out = run_full_barrier(&p, &cfg);
        // Reconstruct residuals at the limit: x0 changed ~0 on the last step.
        let last = out.history.last().unwrap();
        let mut prev = out.state.x0.clone();
        // emulate the previous x0 from the recorded change (direction unknown
        // — use the recorded magnitude conservatively)
        prev[0] += last.x0_change;
        let r = residuals(&out.state, &prev, cfg.rho);
        assert!(
            StoppingRule { abs_tol: 1e-5, rel_tol: 1e-3 }.satisfied(&r, 8, 3),
            "{r:?}"
        );
    }
}
