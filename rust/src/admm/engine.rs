//! The unified iteration engine behind every ADMM driver in this crate.
//!
//! The paper's central observation is that Algorithms 1–4 are **one**
//! consensus-ADMM iteration whose behaviour is decided entirely by *when*
//! the master updates and *who owns the duals*. Historically this repo
//! encoded each answer as its own hand-rolled loop (`admm/sync.rs`,
//! `admm/master_pov.rs`, `admm/alt_scheme.rs`, plus two more copies inside
//! the threaded and virtual-time clusters). This module collapses all five
//! into a single state machine:
//!
//! ```text
//! gather arrivals ─→ absorb worker results ─→ master x₀ update (12)/(25)
//!        ─→ policy post-step (Alg. 4 dual sweep) ─→ broadcast ─→ record/stop
//! ```
//!
//! parameterized along two orthogonal axes:
//!
//! - an [`UpdatePolicy`] — *which algorithm of the paper runs*
//!   ([`FullBarrier`] = Algorithm 1, [`PartialBarrier`] = Algorithms 2/3,
//!   [`AltScheme`] = Algorithm 4);
//! - a [`WorkerSource`] — *how worker results are produced*
//!   ([`TraceSource`] replays/draws arrival sets in-process, exactly like
//!   the paper's own serial simulator; the threaded source in
//!   [`crate::cluster::threaded`] uses one OS thread per worker; the
//!   virtual-time source in [`crate::cluster::sim`] drives the same
//!   protocol from a deterministic discrete-event queue).
//!
//! Every public driver (`run_sync_admm`, `run_master_pov`,
//! `run_alt_scheme`, `StarCluster::run`) is now a thin wrapper that picks a
//! (policy, source) pair and calls [`run_engine`]. Two runs that realize
//! the same [`ArrivalTrace`] produce **bit-identical** [`IterRecord`]
//! histories regardless of the source — the equivalence the
//! `engine_equivalence`, `cluster_e2e` and `virtual_time` test suites pin.
//!
//! Since the Session redesign the loop itself lives in
//! [`super::session::Session::step`]; [`run_engine`] constructs a session
//! with a [`super::session::BufferingObserver`] and runs it to completion,
//! so the one-shot and incremental paths cannot drift apart. New code
//! should prefer [`super::session::Session::builder`], which validates its
//! configuration into a typed [`super::session::EngineError`] instead of
//! panicking, streams records through observers, and supports
//! step/checkpoint/resume.
//!
//! The single seam also makes fault injection uniform: a [`FaultPlan`]
//! (deterministic, seeded worker outages + delay spikes) gates the master's
//! arrival bookkeeping identically in all three sources, realizing the
//! delayed-information regime of the incremental/blockwise ADMM line
//! (Hong, arXiv:1412.6058; Zhu et al., arXiv:1802.08882).

use std::sync::Arc;

use crate::bench::json::{hex_mat, mat_from_hex, JsonValue};
use crate::problems::{BlockPattern, ConsensusProblem};
use crate::rng::Pcg64;

use crate::solvers::inexact::InexactPolicy;

use super::arrivals::{ArrivalModel, ArrivalSampler, ArrivalTrace};
use super::master_pov::{NativeSolver, SubproblemSolver};
use super::session::{BufferingObserver, EngineError, Session};
use super::{AdmmConfig, AdmmState, IterRecord, MasterScratch, StopReason};

/// Where the master's `x₀` update sits relative to the worker updates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepOrder {
    /// Algorithm 1: the master updates `x₀` first (eq. (6)), then every
    /// worker solves against the *fresh* `x₀^{k+1}`.
    MasterFirst,
    /// Algorithms 2/3/4: arrived workers report solves against their last
    /// *broadcast* snapshots, then the master updates `x₀`.
    WorkersFirst,
}

/// One of the paper's master-update disciplines. A policy decides the
/// update order, the delay bound τ of Assumption 1 (every worker must
/// appear in any window of τ consecutive master iterations — exactly what
/// [`ArrivalTrace::satisfies_bounded_delay`] checks on the realized trace),
/// and who owns the dual variables.
///
/// The three implementations map onto the paper:
///
/// | policy            | paper            | order        | duals            |
/// |-------------------|------------------|--------------|------------------|
/// | [`FullBarrier`]   | Algorithm 1      | master-first | workers (8)      |
/// | [`PartialBarrier`]| Algorithms 2/3   | workers-first| workers (14)/(20)|
/// | [`AltScheme`]     | Algorithm 4      | workers-first| master (46)      |
pub trait UpdatePolicy {
    /// Human-readable name (used by the CLI/examples to self-describe).
    fn name(&self) -> &'static str;

    /// Master-first (Algorithm 1) or workers-first (Algorithms 2–4).
    fn order(&self) -> StepOrder {
        StepOrder::WorkersFirst
    }

    /// The Assumption-1 delay bound τ ≥ 1 this policy enforces at the
    /// gate: any worker with delay counter `d_i + 1 ≥ τ` is waited for
    /// unconditionally. τ = 1 forces every (live) worker every iteration —
    /// the synchronous barrier.
    fn tau(&self) -> usize;

    /// Do arrived workers perform their own dual update
    /// `λ_i ← λ_i + ρ(x_i − x̂₀)` (eq. (14)/(20))? True for Algorithms
    /// 1–3; false for Algorithm 4, where workers only compute `x_i` (47).
    fn worker_updates_dual(&self) -> bool;

    /// Does the master, after its `x₀` update, refresh the duals of
    /// **all** workers against the fresh `x₀` (Algorithm 4, eq. (46))?
    /// This is the step that injects stale `x_i` into every `λ_i` and
    /// breaks the eq.-(29) identity — the Section-IV cautionary tale.
    fn master_updates_all_duals(&self) -> bool;

    /// Does the broadcast to arrived workers carry the master-updated dual
    /// `λ̂_i` alongside `x̂₀` (Algorithm 4, Step 6)?
    fn broadcasts_dual(&self) -> bool;
}

impl<P: UpdatePolicy + ?Sized> UpdatePolicy for &P {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn order(&self) -> StepOrder {
        (**self).order()
    }

    fn tau(&self) -> usize {
        (**self).tau()
    }

    fn worker_updates_dual(&self) -> bool {
        (**self).worker_updates_dual()
    }

    fn master_updates_all_duals(&self) -> bool {
        (**self).master_updates_all_duals()
    }

    fn broadcasts_dual(&self) -> bool {
        (**self).broadcasts_dual()
    }
}

/// Algorithm 1: the synchronous baseline. The master updates `x₀` from
/// `(xᵏ, λᵏ)` first, then all `N` workers solve against the fresh
/// `x₀^{k+1}` and update their own duals. τ = 1 by construction.
#[derive(Clone, Copy, Debug, Default)]
pub struct FullBarrier;

impl UpdatePolicy for FullBarrier {
    fn name(&self) -> &'static str {
        "full-barrier (Algorithm 1, synchronous)"
    }

    fn order(&self) -> StepOrder {
        StepOrder::MasterFirst
    }

    fn tau(&self) -> usize {
        1
    }

    fn worker_updates_dual(&self) -> bool {
        true
    }

    fn master_updates_all_duals(&self) -> bool {
        false
    }

    fn broadcasts_dual(&self) -> bool {
        false
    }
}

/// Algorithms 2/3: the AD-ADMM's partially asynchronous barrier. The
/// master proceeds as soon as `|A_k| ≥ A` workers arrived, *except* that
/// any worker about to violate the Assumption-1 bound (`d_i + 1 ≥ τ`) is
/// waited for — the τ gate that Theorem 1's convergence rests on. Workers
/// own their duals (eq. (20)), so the eq.-(29) identity
/// `∇f_i(x_i) + λ_i = 0` holds after every arrival.
#[derive(Clone, Copy, Debug)]
pub struct PartialBarrier {
    /// Maximum tolerable delay τ ≥ 1 of Assumption 1.
    pub tau: usize,
}

impl UpdatePolicy for PartialBarrier {
    fn name(&self) -> &'static str {
        "partial-barrier (Algorithms 2/3, AD-ADMM)"
    }

    fn tau(&self) -> usize {
        self.tau
    }

    fn worker_updates_dual(&self) -> bool {
        true
    }

    fn master_updates_all_duals(&self) -> bool {
        false
    }

    fn broadcasts_dual(&self) -> bool {
        false
    }
}

/// Algorithm 4: the "slightly modified" alternative in which the master
/// owns **all** dual updates (46) and broadcasts `(x̂₀, λ̂_i)` back.
/// Synchronously this is just Algorithm 1 with the update order
/// interchanged; under asynchrony it needs strong convexity and a *small*
/// ρ (Theorem 2, eq. (48)) and otherwise diverges — Fig. 4(b)/(d).
#[derive(Clone, Copy, Debug)]
pub struct AltScheme {
    /// Maximum tolerable delay τ ≥ 1 of Assumption 1.
    pub tau: usize,
}

impl UpdatePolicy for AltScheme {
    fn name(&self) -> &'static str {
        "alt-scheme (Algorithm 4, master-owned duals)"
    }

    fn tau(&self) -> usize {
        self.tau
    }

    fn worker_updates_dual(&self) -> bool {
        false
    }

    fn master_updates_all_duals(&self) -> bool {
        true
    }

    fn broadcasts_dual(&self) -> bool {
        true
    }
}

/// One deterministic worker outage: worker `worker` is *down* for master
/// iterations `from_iter ≤ k < until_iter`. A down worker simply stops
/// arriving — its in-flight result is held at the link and its delay
/// counter keeps growing (an outage of τ or more iterations therefore
/// makes the realized trace violate Assumption 1, which is the point of
/// the scenario). On rejoin the held result is absorbed as-is: the worker
/// re-enters with the *stale* iterate it computed against its pre-outage
/// `x₀` snapshot, exactly the paper's delayed-information model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Outage {
    pub worker: usize,
    pub from_iter: usize,
    pub until_iter: usize,
}

/// One deterministic delay spike: worker `worker`'s compute/communication
/// delays are multiplied by `factor` while the run's clock (virtual
/// seconds in the discrete-event source, wall seconds since worker start
/// in the threaded source) is in `[from_s, until_s)`. The trace-driven
/// source has no clock and ignores spikes — model stragglers there through
/// [`ArrivalModel::Probabilistic`] instead.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DelaySpike {
    pub worker: usize,
    pub from_s: f64,
    pub until_s: f64,
    pub factor: f64,
}

/// A deterministic fault schedule applied identically by every
/// [`WorkerSource`]: iteration-indexed dropout/rejoin [`Outage`]s gate the
/// master's arrival bookkeeping, time-indexed [`DelaySpike`]s stretch the
/// timing-driven sources' delays. Build one explicitly or with
/// [`FaultPlan::seeded_outages`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub outages: Vec<Outage>,
    pub spikes: Vec<DelaySpike>,
}

impl FaultPlan {
    /// A single dropout-and-rejoin event.
    pub fn single_outage(worker: usize, from_iter: usize, until_iter: usize) -> Self {
        FaultPlan {
            outages: vec![Outage { worker, from_iter, until_iter }],
            spikes: Vec::new(),
        }
    }

    /// A deterministic, seeded schedule of `count` outages over the
    /// iteration horizon `[0, horizon)`, each hitting a pseudo-random
    /// worker for a pseudo-random span in `[min_len, max_len]` iterations.
    /// The same `(n_workers, horizon, count, min_len, max_len, seed)`
    /// always yields the same plan on every machine.
    pub fn seeded_outages(
        n_workers: usize,
        horizon: usize,
        count: usize,
        min_len: usize,
        max_len: usize,
        seed: u64,
    ) -> Self {
        assert!(n_workers > 0 && min_len >= 1 && max_len >= min_len);
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut outages = Vec::with_capacity(count);
        for _ in 0..count {
            let worker = (rng.next_u64() % n_workers as u64) as usize;
            let len = min_len + (rng.next_u64() % (max_len - min_len + 1) as u64) as usize;
            let latest_start = horizon.saturating_sub(len).max(1);
            let from_iter = (rng.next_u64() % latest_start as u64) as usize;
            outages.push(Outage { worker, from_iter, until_iter: from_iter + len });
        }
        FaultPlan { outages, spikes: Vec::new() }
    }

    /// Is `worker` down at master iteration `k`?
    pub fn down_at(&self, worker: usize, k: usize) -> bool {
        self.outages
            .iter()
            .any(|o| o.worker == worker && k >= o.from_iter && k < o.until_iter)
    }

    /// Fill the per-worker down mask for iteration `k`.
    pub fn fill_down(&self, k: usize, down: &mut [bool]) {
        for (i, flag) in down.iter_mut().enumerate() {
            *flag = self.down_at(i, k);
        }
    }

    /// Combined delay multiplier for `worker` at clock instant `t_s`.
    pub fn delay_factor(&self, worker: usize, t_s: f64) -> f64 {
        self.spikes
            .iter()
            .filter(|s| s.worker == worker && t_s >= s.from_s && t_s < s.until_s)
            .fold(1.0, |acc, s| acc * s.factor)
    }

    /// True when the plan injects nothing (gating can be skipped).
    pub fn is_empty(&self) -> bool {
        self.outages.is_empty() && self.spikes.is_empty()
    }
}

/// The arrival gate of one master iteration, assembled by the engine from
/// the policy (τ), the config (`A = min_arrivals`) and the fault plan
/// (`down`). Sources realize the wait however they like (drawing Bernoulli
/// rounds, pumping the event queue, blocking on a channel) but must honour
/// the same contract: the returned set contains every live worker with
/// `d_i + 1 ≥ τ`, at least `min(A, #live)` workers, and no down worker.
#[derive(Debug)]
pub struct Gate<'a> {
    /// Assumption-1 delay bound from the policy.
    pub tau: usize,
    /// The `|A_k| ≥ A` batching gate.
    pub min_arrivals: usize,
    /// Per-worker outage mask for this iteration (all-false without
    /// faults). Down workers are excluded from the set, from the forced-τ
    /// wait, and from the arrival count.
    pub down: &'a [bool],
}

/// A validated arrival set: the workers the master absorbs this iteration.
///
/// Construction sorts, dedupes and bounds-checks the indices, so every
/// consumer downstream — the sparse master update, per-block bookkeeping,
/// the broadcast fan-out — can rely on *ascending unique in-range worker
/// ids* without re-validating. The ascending order is load-bearing for
/// bit-identity: the master accumulates owned-slice contributions in
/// worker order, and reordering would change floating-point summation.
///
/// Derefs to `[usize]`, so all slice reads (`len`, `iter`, `contains`,
/// indexing) work unchanged; use [`ActiveSet::into_vec`] to move the
/// indices out.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ActiveSet {
    idx: Vec<usize>,
}

impl ActiveSet {
    /// Validate an arbitrary index list into an arrival set: sorts,
    /// removes duplicates, and rejects any index `>= n_workers` with a
    /// typed [`EngineError::ActiveSetOutOfRange`].
    pub fn new(mut idx: Vec<usize>, n_workers: usize) -> Result<Self, EngineError> {
        idx.sort_unstable();
        idx.dedup();
        if let Some(&bad) = idx.iter().find(|&&i| i >= n_workers) {
            return Err(EngineError::ActiveSetOutOfRange { index: bad, n_workers });
        }
        Ok(ActiveSet { idx })
    }

    /// The full set `{0, …, n_workers−1}` (the synchronous barrier).
    pub fn full(n_workers: usize) -> Self {
        ActiveSet { idx: (0..n_workers).collect() }
    }

    /// Hot-path constructor for sets already produced in ascending unique
    /// order (samplers and event pumps emit them that way by
    /// construction). Checked in debug builds only.
    pub(crate) fn from_sorted(idx: Vec<usize>) -> Self {
        debug_assert!(idx.windows(2).all(|w| w[0] < w[1]), "set must be ascending unique");
        ActiveSet { idx }
    }

    /// The arrived workers, ascending.
    pub fn as_slice(&self) -> &[usize] {
        &self.idx
    }

    /// Move the indices out (e.g. into an [`ArrivalTrace`]).
    pub fn into_vec(self) -> Vec<usize> {
        self.idx
    }
}

impl std::ops::Deref for ActiveSet {
    type Target = [usize];

    fn deref(&self) -> &[usize] {
        &self.idx
    }
}

impl<'s> IntoIterator for &'s ActiveSet {
    type Item = &'s usize;
    type IntoIter = std::slice::Iter<'s, usize>;

    fn into_iter(self) -> Self::IntoIter {
        self.idx.iter()
    }
}

impl From<ActiveSet> for Vec<usize> {
    fn from(set: ActiveSet) -> Vec<usize> {
        set.idx
    }
}

/// The master-side state a source may touch while materializing one
/// iteration's arrived results: the primal/dual state, the `f_i(x_i)`
/// cache (refreshed only for arrived workers), and the master scratch
/// whose `ws` buffers the `eval_with` calls reuse.
pub struct MasterView<'a> {
    pub problem: &'a ConsensusProblem,
    pub state: &'a mut AdmmState,
    pub f_cache: &'a mut [f64],
    pub scratch: &'a mut MasterScratch,
    pub rho: f64,
    /// Block-sharding pattern of the session (None = dense). Under a
    /// pattern, `state.xs[i]`/`state.lams[i]` are worker i's owned slices
    /// (length `shard.owned_len(i)`), stored per worker-block in owned
    /// order; custom sources use this to map local coordinates back to
    /// the global `x₀`.
    pub shard: Option<&'a BlockPattern>,
    /// The session's sparse master state when the O(active) path is live
    /// (see [`MasterView::sparse`]).
    pub(crate) sparse: Option<&'a super::SparseMaster>,
}

impl<'a> MasterView<'a> {
    /// Read-only view of the O(active) sparse master state: the
    /// per-coordinate accumulators `Σ_{i∋j}(ρ x_{i,j} + λ_{i,j})` and the
    /// per-block staleness stamps the lazy prox catch-up runs on.
    ///
    /// `None` on the eager dense path (unsharded sessions, master-first
    /// policies, Algorithm-4 master-owned duals, or an explicit
    /// `sparse_master(false)` on the builder). Beware that during
    /// `absorb` the stamps reflect the *previous* master update — the
    /// catch-up for this iteration's arrivals runs after absorption.
    pub fn sparse(&self) -> Option<super::SparseView<'_>> {
        self.sparse.map(|s| s.view())
    }
}

/// Where worker results come from. Implementations:
///
/// - [`TraceSource`] — in-process: arrival sets come from an
///   [`ArrivalModel`] (stochastic, full, or an explicit replayed
///   [`ArrivalTrace`]) and the subproblem solves run serially at
///   absorption time against the stored snapshots. This is the paper's
///   own serial simulator (Algorithm 3's "master point of view").
/// - `ThreadedSource` ([`crate::cluster::threaded`]) — one OS thread per
///   worker and mpsc star links; arrivals are real messages, delays are
///   real sleeps. Nondeterministic by nature unless driven in lockstep.
/// - `VirtualSource` ([`crate::cluster::sim`]) — the same protocol on a
///   deterministic discrete-event queue; delays are events on a virtual
///   clock, bit-reproducible at thousands of workers.
///
/// All three realize identical protocol semantics: replaying one source's
/// realized trace through another produces bit-identical iterates.
pub trait WorkerSource {
    /// Number of workers this source drives (must equal the problem's).
    fn n_workers(&self) -> usize;

    /// Short stable name used in error messages and checkpoint envelopes
    /// (`"trace"`, `"threaded"`, `"virtual"`).
    fn kind(&self) -> &'static str {
        "custom"
    }

    /// Can this source run a [`StepOrder::MasterFirst`] policy? Only the
    /// in-process [`TraceSource`] can: the timing-driven sources pipeline
    /// worker rounds against broadcast snapshots, which is exactly what a
    /// master-first barrier forbids.
    fn supports_master_first(&self) -> bool {
        false
    }

    /// Can this source drive a *genuinely* block-sharded session (workers
    /// exchanging owned slices of differing lengths)? The in-tree sources
    /// return true when constructed from a sharded problem; the default
    /// is false so shard-unaware sources (external-solver
    /// [`TraceSource::with_solver`], custom impls) are rejected at
    /// `build()` with a typed error instead of panicking on dimension
    /// mismatches mid-run. Effectively-dense patterns (every worker owns
    /// the full dimension) need no support — all messages are full-length.
    fn supports_sharding(&self) -> bool {
        false
    }

    /// One-time setup from the initial state (snapshot init, thread
    /// spawn + initial broadcast, event-queue priming).
    fn start(&mut self, state: &AdmmState, policy: &dyn UpdatePolicy);

    /// Block/draw until the iteration-`k` gate is met and return the
    /// realized arrival set as a validated [`ActiveSet`] (ascending,
    /// unique, in range). Sources that produce ascending sets by
    /// construction can build it with zero cost; anything else should go
    /// through [`ActiveSet::new`].
    fn gather(&mut self, k: usize, d: &[usize], gate: &Gate<'_>) -> ActiveSet;

    /// Materialize the arrived workers' `(x_i, λ_i, f_i)` into the master
    /// state, in ascending worker order.
    fn absorb(&mut self, set: &ActiveSet, m: &mut MasterView<'_>, policy: &dyn UpdatePolicy);

    /// Deliver the post-update broadcast (`x̂₀`, plus `λ̂_i` when the
    /// policy broadcasts duals) to exactly the arrived workers.
    fn broadcast(&mut self, set: &ActiveSet, state: &AdmmState, policy: &dyn UpdatePolicy);

    /// Serialize this source's mid-run state (sampler cursors, RNG
    /// streams, per-worker snapshots, event queues) for a
    /// [`super::session::Checkpoint`]. Sources with live OS-thread state
    /// cannot support this and keep the default.
    fn save_checkpoint(&self) -> Result<JsonValue, EngineError> {
        Err(EngineError::CheckpointUnsupported { source: self.kind() })
    }

    /// Restore state produced by [`WorkerSource::save_checkpoint`] into a
    /// freshly constructed source (called *instead of*
    /// [`WorkerSource::start`] on resume).
    fn load_checkpoint(&mut self, _doc: &JsonValue) -> Result<(), EngineError> {
        Err(EngineError::CheckpointUnsupported { source: self.kind() })
    }
}

impl<S: WorkerSource + ?Sized> WorkerSource for &mut S {
    fn n_workers(&self) -> usize {
        (**self).n_workers()
    }

    fn kind(&self) -> &'static str {
        (**self).kind()
    }

    fn supports_master_first(&self) -> bool {
        (**self).supports_master_first()
    }

    fn supports_sharding(&self) -> bool {
        (**self).supports_sharding()
    }

    fn start(&mut self, state: &AdmmState, policy: &dyn UpdatePolicy) {
        (**self).start(state, policy)
    }

    fn gather(&mut self, k: usize, d: &[usize], gate: &Gate<'_>) -> ActiveSet {
        (**self).gather(k, d, gate)
    }

    fn absorb(&mut self, set: &ActiveSet, m: &mut MasterView<'_>, policy: &dyn UpdatePolicy) {
        (**self).absorb(set, m, policy)
    }

    fn broadcast(&mut self, set: &ActiveSet, state: &AdmmState, policy: &dyn UpdatePolicy) {
        (**self).broadcast(set, state, policy)
    }

    fn save_checkpoint(&self) -> Result<JsonValue, EngineError> {
        (**self).save_checkpoint()
    }

    fn load_checkpoint(&mut self, doc: &JsonValue) -> Result<(), EngineError> {
        (**self).load_checkpoint(doc)
    }
}

impl<S: WorkerSource + ?Sized> WorkerSource for Box<S> {
    fn n_workers(&self) -> usize {
        (**self).n_workers()
    }

    fn kind(&self) -> &'static str {
        (**self).kind()
    }

    fn supports_master_first(&self) -> bool {
        (**self).supports_master_first()
    }

    fn supports_sharding(&self) -> bool {
        (**self).supports_sharding()
    }

    fn start(&mut self, state: &AdmmState, policy: &dyn UpdatePolicy) {
        (**self).start(state, policy)
    }

    fn gather(&mut self, k: usize, d: &[usize], gate: &Gate<'_>) -> ActiveSet {
        (**self).gather(k, d, gate)
    }

    fn absorb(&mut self, set: &ActiveSet, m: &mut MasterView<'_>, policy: &dyn UpdatePolicy) {
        (**self).absorb(set, m, policy)
    }

    fn broadcast(&mut self, set: &ActiveSet, state: &AdmmState, policy: &dyn UpdatePolicy) {
        (**self).broadcast(set, state, policy)
    }

    fn save_checkpoint(&self) -> Result<JsonValue, EngineError> {
        (**self).save_checkpoint()
    }

    fn load_checkpoint(&mut self, doc: &JsonValue) -> Result<(), EngineError> {
        (**self).load_checkpoint(doc)
    }
}

/// The pre-[`ActiveSet`] source contract: `gather` returned a raw
/// `Vec<usize>` and `absorb`/`broadcast` took `&[usize]`, pushing the
/// sorted/unique/in-range invariants onto every consumer. Implement
/// [`WorkerSource`] directly instead; an existing implementation keeps
/// working unchanged when wrapped in [`LegacySourceAdapter`].
#[deprecated(note = "implement WorkerSource (ActiveSet signatures); wrap old impls in \
                     LegacySourceAdapter")]
pub trait LegacyWorkerSource {
    fn n_workers(&self) -> usize;

    fn kind(&self) -> &'static str {
        "custom"
    }

    fn supports_master_first(&self) -> bool {
        false
    }

    fn supports_sharding(&self) -> bool {
        false
    }

    fn start(&mut self, state: &AdmmState, policy: &dyn UpdatePolicy);

    fn gather(&mut self, k: usize, d: &[usize], gate: &Gate<'_>) -> Vec<usize>;

    fn absorb(&mut self, set: &[usize], m: &mut MasterView<'_>, policy: &dyn UpdatePolicy);

    fn broadcast(&mut self, set: &[usize], state: &AdmmState, policy: &dyn UpdatePolicy);

    fn save_checkpoint(&self) -> Result<JsonValue, EngineError> {
        Err(EngineError::CheckpointUnsupported { source: self.kind() })
    }

    fn load_checkpoint(&mut self, _doc: &JsonValue) -> Result<(), EngineError> {
        Err(EngineError::CheckpointUnsupported { source: self.kind() })
    }
}

/// Adapter running a [`LegacyWorkerSource`] under the [`ActiveSet`]
/// contract: the wrapped source's raw `gather` output is validated (and
/// sorted/deduped) on every iteration, so a sloppy legacy set surfaces as
/// a panic at the seam instead of silent misaccumulation downstream.
#[allow(deprecated)]
pub struct LegacySourceAdapter<S: LegacyWorkerSource>(pub S);

#[allow(deprecated)]
impl<S: LegacyWorkerSource> WorkerSource for LegacySourceAdapter<S> {
    fn n_workers(&self) -> usize {
        self.0.n_workers()
    }

    fn kind(&self) -> &'static str {
        self.0.kind()
    }

    fn supports_master_first(&self) -> bool {
        self.0.supports_master_first()
    }

    fn supports_sharding(&self) -> bool {
        self.0.supports_sharding()
    }

    fn start(&mut self, state: &AdmmState, policy: &dyn UpdatePolicy) {
        self.0.start(state, policy)
    }

    fn gather(&mut self, k: usize, d: &[usize], gate: &Gate<'_>) -> ActiveSet {
        let raw = self.0.gather(k, d, gate);
        let n = self.0.n_workers();
        ActiveSet::new(raw, n)
            // ad-lint: allow(panic-free-lib): LegacySourceAdapter's documented contract: an invalid legacy arrival set is a caller bug
            .unwrap_or_else(|e| panic!("legacy source produced an invalid arrival set: {e}"))
    }

    fn absorb(&mut self, set: &ActiveSet, m: &mut MasterView<'_>, policy: &dyn UpdatePolicy) {
        self.0.absorb(set, m, policy)
    }

    fn broadcast(&mut self, set: &ActiveSet, state: &AdmmState, policy: &dyn UpdatePolicy) {
        self.0.broadcast(set, state, policy)
    }

    fn save_checkpoint(&self) -> Result<JsonValue, EngineError> {
        self.0.save_checkpoint()
    }

    fn load_checkpoint(&mut self, doc: &JsonValue) -> Result<(), EngineError> {
        self.0.load_checkpoint(doc)
    }
}

/// Engine knobs that are caller choices rather than policy properties.
///
/// Owns its [`FaultPlan`] since the Session redesign (the historical
/// borrowed variant forced the awkward `EngineOptions<'static>` `Default`
/// impl); the same knobs live on [`super::session::SessionBuilder`] as
/// `residual_stopping` / `faults`, which is the preferred spelling.
#[derive(Clone, Debug)]
pub struct EngineOptions {
    /// Evaluate the residual-based [`super::stopping::StoppingRule`] (when
    /// the config carries one). The serial Algorithm-4 driver historically
    /// never did; every other driver does.
    pub residual_stopping: bool,
    /// Deterministic outage/delay-spike schedule (None = fault-free).
    pub fault_plan: Option<FaultPlan>,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions { residual_stopping: true, fault_plan: None }
    }
}

/// What one engine run returns; the public driver wrappers repackage this
/// into their historical output types.
pub struct EngineRun {
    pub state: AdmmState,
    pub history: Vec<IterRecord>,
    /// Realized arrival sets — replayable through any source.
    pub trace: ArrivalTrace,
    pub stop: StopReason,
    /// Final per-worker delay counters (≤ τ − 1 whenever the realized
    /// trace satisfies Assumption 1; may exceed it under outages).
    pub final_delays: Vec<usize>,
}

/// Run the unified iteration engine: one (policy, source) pair, one
/// config, one problem. Since the Session redesign this is a thin
/// run-to-completion shim: it builds a [`Session`] around the borrowed
/// source with a [`BufferingObserver`] and repackages the outcome into the
/// historical [`EngineRun`]. Panics on an invalid configuration (the
/// pre-session contract); use [`Session::builder`] for typed errors.
pub fn run_engine(
    problem: &ConsensusProblem,
    cfg: &AdmmConfig,
    policy: &dyn UpdatePolicy,
    source: &mut dyn WorkerSource,
    opts: &EngineOptions,
) -> EngineRun {
    let mut history = BufferingObserver::new();
    let mut builder = Session::builder()
        .problem(problem)
        .config(cfg.clone())
        .policy(policy)
        .residual_stopping(opts.residual_stopping)
        .observer(&mut history);
    if let Some(plan) = &opts.fault_plan {
        builder = builder.faults(plan.clone());
    }
    let mut session = builder
        .build_typed(source)
        // ad-lint: allow(panic-free-lib): deprecated run_trace_driven keeps its panic-on-invalid contract; Session::builder is the typed path
        .unwrap_or_else(|e| panic!("invalid engine configuration: {e}"));
    let stop = session
        .run_to_completion()
        // ad-lint: allow(panic-free-lib): deprecated run_trace_driven has no error channel; Session::run_to_completion is the typed path
        .unwrap_or_else(|e| panic!("engine run failed: {e}"));
    let (outcome, _) = session.finish();
    EngineRun {
        state: outcome.state,
        history: history.into_records(),
        trace: outcome.trace,
        stop,
        final_delays: outcome.final_delays,
    }
}

/// Reset arrived workers' delay counters, bump everyone else's. `arrived`
/// is a reusable scratch mask (left all-false on return).
pub(crate) fn advance_delays(set: &[usize], arrived: &mut [bool], d: &mut [usize]) {
    for &i in set {
        arrived[i] = true;
    }
    for i in 0..d.len() {
        if arrived[i] {
            d[i] = 0;
            arrived[i] = false;
        } else {
            d[i] += 1;
        }
    }
}

/// Convenience wrapper: run the in-process [`TraceSource`] under an
/// arbitrary policy + options. Panics on an invalid [`AdmmConfig`], like
/// the legacy serial entry points it generalizes.
#[deprecated(note = "use Session::builder()")]
pub fn run_trace_driven(
    problem: &ConsensusProblem,
    cfg: &AdmmConfig,
    arrivals: &ArrivalModel,
    policy: &dyn UpdatePolicy,
    opts: &EngineOptions,
) -> EngineRun {
    // ad-lint: allow(panic-free-lib): deprecated wrapper keeps its documented panic-on-invalid contract; Session::builder is the typed path
    cfg.validate(problem.num_workers()).expect("invalid AdmmConfig");
    let mut source = TraceSource::new(problem, arrivals);
    run_engine(problem, cfg, policy, &mut source, opts)
}

enum SolverSlot<'a> {
    Native(NativeSolver<'a>),
    Borrowed(&'a mut dyn SubproblemSolver),
}

impl<'a> SolverSlot<'a> {
    fn solve(&mut self, worker: usize, lam: &[f64], x0: &[f64], rho: f64, out: &mut [f64]) {
        match self {
            SolverSlot::Native(s) => s.solve(worker, lam, x0, rho, out),
            SolverSlot::Borrowed(s) => s.solve(worker, lam, x0, rho, out),
        }
    }
}

/// The in-process [`WorkerSource`]: arrival sets come from an
/// [`ArrivalModel`] sampler (Bernoulli draws, the full set, or an explicit
/// replayed trace) and the arrived workers' subproblems are solved
/// serially *at absorption time* against the snapshots the master last
/// broadcast to them — the exact bookkeeping of the paper's serial
/// simulator (Algorithm 3), which is why a trace realized by any other
/// source replays bit-identically through this one.
pub struct TraceSource<'a> {
    n_workers: usize,
    sampler: ArrivalSampler,
    solver: SolverSlot<'a>,
    /// Block-sharding pattern (from the problem; None = dense). Snapshots
    /// below are owned slices under a pattern.
    shard: Option<Arc<BlockPattern>>,
    /// `x₀^{k̄_i+1}` as worker i last received it.
    x0_snap: Vec<Vec<f64>>,
    /// `λ̂_i` as worker i last received it (Algorithm 4 only).
    lam_snap: Vec<Vec<f64>>,
}

impl<'a> TraceSource<'a> {
    /// Native closed-form subproblem solves backed by the problem itself
    /// (block-sharded when the problem is).
    pub fn new(problem: &'a ConsensusProblem, arrivals: &ArrivalModel) -> Self {
        Self::with_policy(problem, arrivals, InexactPolicy::Exact)
    }

    /// Native solves under an [`InexactPolicy`]: every arrived worker's
    /// subproblem runs the policy's k-step inner loop with that worker's
    /// warm-start state persisting across rounds (and into checkpoints).
    pub fn with_policy(
        problem: &'a ConsensusProblem,
        arrivals: &ArrivalModel,
        policy: InexactPolicy,
    ) -> Self {
        let n_workers = problem.num_workers();
        TraceSource {
            n_workers,
            sampler: arrivals.sampler(n_workers),
            solver: SolverSlot::Native(NativeSolver::with_policy(problem, policy)),
            shard: problem.pattern().cloned(),
            x0_snap: Vec::new(),
            lam_snap: Vec::new(),
        }
    }

    /// Native solves under heterogeneous per-worker policies: worker `i`
    /// runs `policies[i]`'s inner loop. The session builder validates the
    /// vector length before the source ever solves.
    pub fn with_policies(
        problem: &'a ConsensusProblem,
        arrivals: &ArrivalModel,
        policies: Vec<InexactPolicy>,
    ) -> Self {
        let n_workers = problem.num_workers();
        TraceSource {
            n_workers,
            sampler: arrivals.sampler(n_workers),
            solver: SolverSlot::Native(NativeSolver::with_policies(problem, policies)),
            shard: problem.pattern().cloned(),
            x0_snap: Vec::new(),
            lam_snap: Vec::new(),
        }
    }

    /// Caller-supplied solver (e.g. the PJRT engine executing AOT
    /// JAX/Pallas artifacts). Dense-only: the external-solver protocol
    /// exchanges full-dimension vectors.
    pub fn with_solver(
        n_workers: usize,
        arrivals: &ArrivalModel,
        solver: &'a mut dyn SubproblemSolver,
    ) -> Self {
        TraceSource {
            n_workers,
            sampler: arrivals.sampler(n_workers),
            solver: SolverSlot::Borrowed(solver),
            shard: None,
            x0_snap: Vec::new(),
            lam_snap: Vec::new(),
        }
    }
}

impl<'a> WorkerSource for TraceSource<'a> {
    fn n_workers(&self) -> usize {
        self.n_workers
    }

    fn kind(&self) -> &'static str {
        "trace"
    }

    fn supports_master_first(&self) -> bool {
        true
    }

    fn supports_sharding(&self) -> bool {
        self.shard.is_some()
    }

    fn save_checkpoint(&self) -> Result<JsonValue, EngineError> {
        // "warm" (checkpoint v3+) carries the per-worker inexact-policy
        // warm-start states; Null for external solvers (always exact).
        let warm = match &self.solver {
            SolverSlot::Native(s) => s.warm_to_json(),
            SolverSlot::Borrowed(_) => JsonValue::Null,
        };
        Ok(JsonValue::Obj(vec![
            ("sampler".to_string(), self.sampler.save()),
            ("x0_snap".to_string(), hex_mat(&self.x0_snap)),
            ("lam_snap".to_string(), hex_mat(&self.lam_snap)),
            ("warm".to_string(), warm),
        ]))
    }

    fn load_checkpoint(&mut self, doc: &JsonValue) -> Result<(), EngineError> {
        self.sampler
            .load(super::session::jget(doc, "sampler")?)
            .map_err(EngineError::Checkpoint)?;
        self.x0_snap =
            mat_from_hex(super::session::jget(doc, "x0_snap")?).map_err(EngineError::Checkpoint)?;
        self.lam_snap =
            mat_from_hex(super::session::jget(doc, "lam_snap")?).map_err(EngineError::Checkpoint)?;
        if self.x0_snap.len() != self.n_workers || self.lam_snap.len() != self.n_workers {
            return Err(EngineError::Checkpoint(
                "snapshot worker count does not match the source".to_string(),
            ));
        }
        // Absent in v1/v2 checkpoints (exact-only by construction).
        if let Some(warm) = doc.get("warm") {
            if let (SolverSlot::Native(s), JsonValue::Arr(_)) = (&mut self.solver, warm) {
                s.load_warm(warm).map_err(EngineError::Checkpoint)?;
            }
        }
        Ok(())
    }

    fn start(&mut self, state: &AdmmState, _policy: &dyn UpdatePolicy) {
        self.x0_snap = match &self.shard {
            None => vec![state.x0.clone(); self.n_workers],
            // Sharded: each worker receives (and snapshots) only its
            // owned slice of x₀.
            Some(p) => (0..self.n_workers).map(|i| p.gather_vec(i, &state.x0)).collect(),
        };
        self.lam_snap = state.lams.clone();
    }

    fn gather(&mut self, _k: usize, d: &[usize], gate: &Gate<'_>) -> ActiveSet {
        // The sampler emits ascending unique in-range sets by construction.
        ActiveSet::from_sorted(self.sampler.next_set_gated(d, gate.tau, gate.min_arrivals, gate.down))
    }

    fn absorb(&mut self, set: &ActiveSet, m: &mut MasterView<'_>, policy: &dyn UpdatePolicy) {
        let worker_dual = policy.worker_updates_dual();
        for &i in set {
            // Worker i's slice length: the global dimension when dense,
            // its owned-slice length when sharded.
            let ni = m.state.xs[i].len();
            if worker_dual {
                // (19)/(23): solve against the worker's own dual and its
                // x₀ snapshot, then (20)/(24): the dual update.
                let snap = &self.x0_snap[i];
                self.solver.solve(i, &m.state.lams[i], snap, m.rho, &mut m.state.xs[i]);
                for j in 0..ni {
                    m.state.lams[i][j] += m.rho * (m.state.xs[i][j] - snap[j]);
                }
            } else {
                // (47): solve against the master-broadcast (x̂₀, λ̂_i).
                let snap = &self.x0_snap[i];
                self.solver.solve(i, &self.lam_snap[i], snap, m.rho, &mut m.state.xs[i]);
            }
            m.f_cache[i] = m.problem.local(i).eval_with(&m.state.xs[i], &mut m.scratch.ws);
        }
    }

    fn broadcast(&mut self, set: &ActiveSet, state: &AdmmState, policy: &dyn UpdatePolicy) {
        let with_dual = policy.broadcasts_dual();
        for &i in set {
            match &self.shard {
                None => self.x0_snap[i].copy_from_slice(&state.x0),
                Some(p) => p.gather_into(i, &state.x0, &mut self.x0_snap[i]),
            }
            if with_dual {
                self.lam_snap[i].copy_from_slice(&state.lams[i]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::LassoInstance;

    fn lasso(seed: u64, n_workers: usize) -> ConsensusProblem {
        let mut rng = Pcg64::seed_from_u64(seed);
        LassoInstance::synthetic(&mut rng, n_workers, 20, 8, 0.2, 0.1).problem()
    }

    // Shared Session-based runner (these tests predate the Session facade
    // and used the deprecated `run_trace_driven` wrapper, which stays
    // pinned by the `engine_equivalence` suite).
    use crate::testkit::drivers::run_policy_with_faults;

    #[test]
    fn policy_metadata_matches_the_paper() {
        let full = FullBarrier;
        assert_eq!(full.order(), StepOrder::MasterFirst);
        assert_eq!(full.tau(), 1);
        assert!(full.worker_updates_dual() && !full.master_updates_all_duals());

        let partial = PartialBarrier { tau: 7 };
        assert_eq!(partial.order(), StepOrder::WorkersFirst);
        assert_eq!(partial.tau(), 7);
        assert!(partial.worker_updates_dual());
        assert!(!partial.broadcasts_dual());

        let alt = AltScheme { tau: 3 };
        assert!(!alt.worker_updates_dual());
        assert!(alt.master_updates_all_duals() && alt.broadcasts_dual());
    }

    #[test]
    fn active_set_validates_sorts_and_dedups() {
        let set = ActiveSet::new(vec![3, 1, 3, 0], 4).unwrap();
        assert_eq!(set.as_slice(), &[0, 1, 3]);
        assert_eq!(set.len(), 3);
        assert!(set.contains(&1) && !set.contains(&2));
        let err = ActiveSet::new(vec![0, 4], 4).unwrap_err();
        assert!(matches!(err, EngineError::ActiveSetOutOfRange { index: 4, n_workers: 4 }));
        assert_eq!(ActiveSet::full(3).into_vec(), vec![0, 1, 2]);
        assert_eq!(Vec::from(ActiveSet::from_sorted(vec![0, 2])), vec![0, 2]);
    }

    #[test]
    #[allow(deprecated)]
    fn legacy_source_adapter_validates_raw_sets() {
        struct Raw;
        impl LegacyWorkerSource for Raw {
            fn n_workers(&self) -> usize {
                3
            }
            fn start(&mut self, _state: &AdmmState, _policy: &dyn UpdatePolicy) {}
            fn gather(&mut self, _k: usize, _d: &[usize], _gate: &Gate<'_>) -> Vec<usize> {
                vec![2, 0, 2] // unsorted with a duplicate: the adapter cleans it
            }
            fn absorb(
                &mut self,
                set: &[usize],
                _m: &mut MasterView<'_>,
                _policy: &dyn UpdatePolicy,
            ) {
                assert_eq!(set, &[0, 2]);
            }
            fn broadcast(&mut self, _set: &[usize], _state: &AdmmState, _policy: &dyn UpdatePolicy) {
            }
        }
        let mut adapted = LegacySourceAdapter(Raw);
        let down = vec![false; 3];
        let gate = Gate { tau: 1, min_arrivals: 1, down: &down };
        let set = WorkerSource::gather(&mut adapted, 0, &[0; 3], &gate);
        assert_eq!(set.as_slice(), &[0, 2]);
    }

    #[test]
    fn fault_plan_masks_and_factors() {
        let plan = FaultPlan {
            outages: vec![Outage { worker: 1, from_iter: 5, until_iter: 9 }],
            spikes: vec![DelaySpike { worker: 0, from_s: 1.0, until_s: 2.0, factor: 10.0 }],
        };
        assert!(!plan.down_at(1, 4) && plan.down_at(1, 5) && plan.down_at(1, 8));
        assert!(!plan.down_at(1, 9) && !plan.down_at(0, 6));
        let mut mask = vec![false; 3];
        plan.fill_down(6, &mut mask);
        assert_eq!(mask, vec![false, true, false]);
        assert_eq!(plan.delay_factor(0, 1.5), 10.0);
        assert_eq!(plan.delay_factor(0, 2.5), 1.0);
        assert_eq!(plan.delay_factor(1, 1.5), 1.0);
        assert!(!plan.is_empty());
        assert!(FaultPlan::default().is_empty());
    }

    #[test]
    fn seeded_outage_plans_are_reproducible() {
        let a = FaultPlan::seeded_outages(8, 100, 4, 3, 10, 42);
        let b = FaultPlan::seeded_outages(8, 100, 4, 3, 10, 42);
        assert_eq!(a, b);
        assert_eq!(a.outages.len(), 4);
        for o in &a.outages {
            assert!(o.worker < 8);
            let len = o.until_iter - o.from_iter;
            assert!((3..=10).contains(&len));
            assert!(o.from_iter < 100);
        }
        let c = FaultPlan::seeded_outages(8, 100, 4, 3, 10, 43);
        assert_ne!(a, c, "different seeds should differ");
    }

    #[test]
    fn dropout_suppresses_arrivals_and_rejoin_is_forced() {
        let p = lasso(901, 4);
        let cfg = AdmmConfig { rho: 40.0, tau: 3, max_iters: 40, ..Default::default() };
        let plan = FaultPlan::single_outage(2, 10, 20);
        let run = run_policy_with_faults(
            &p,
            &cfg,
            &ArrivalModel::Full,
            PartialBarrier { tau: cfg.tau },
            true,
            Some(plan),
        );
        let (history, trace) = (run.history, run.trace);
        assert_eq!(history.len(), 40);
        for (k, set) in trace.sets.iter().enumerate() {
            if (10..20).contains(&k) {
                assert!(!set.contains(&2), "down worker arrived at k={k}");
            } else {
                assert!(set.contains(&2), "live worker missing at k={k}");
            }
        }
        // The 10-iteration outage exceeds τ = 3: Assumption 1 is violated
        // on the realized trace — exactly the stress the scenario exists
        // to produce — while the pre-outage prefix still satisfies it.
        assert!(!trace.satisfies_bounded_delay(4, 3));
        let prefix = ArrivalTrace { sets: trace.sets[..10].to_vec() };
        assert!(prefix.satisfies_bounded_delay(4, 3));
    }

    #[test]
    fn all_workers_down_yields_empty_sets_and_still_terminates() {
        let p = lasso(902, 2);
        let cfg = AdmmConfig { rho: 20.0, tau: 2, max_iters: 5, ..Default::default() };
        let plan = FaultPlan {
            outages: vec![
                Outage { worker: 0, from_iter: 0, until_iter: 5 },
                Outage { worker: 1, from_iter: 0, until_iter: 5 },
            ],
            spikes: Vec::new(),
        };
        let run = run_policy_with_faults(
            &p,
            &cfg,
            &ArrivalModel::Full,
            PartialBarrier { tau: cfg.tau },
            true,
            Some(plan),
        );
        assert_eq!(run.history.len(), 5);
        assert!(run.trace.sets.iter().all(Vec::is_empty));
        assert_eq!(run.stop, StopReason::MaxIters);
    }

    #[test]
    fn full_barrier_policy_runs_via_trace_source() {
        // Smoke: the master-first order wired through the in-process
        // source terminates and records N arrivals every iteration. (The
        // bit-equality with the historical sync driver is pinned by the
        // engine_equivalence integration suite.)
        let p = lasso(903, 3);
        let cfg = AdmmConfig { rho: 40.0, max_iters: 30, ..Default::default() };
        let run = run_policy_with_faults(&p, &cfg, &ArrivalModel::Full, FullBarrier, true, None);
        assert_eq!(run.history.len(), 30);
        assert!(run.history.iter().all(|r| r.arrivals == 3));
    }
}
