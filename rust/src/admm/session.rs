//! The `Session` facade: the crate's public face over the unified
//! iteration engine.
//!
//! PR 3 collapsed every driver into one policy-driven state machine
//! ([`super::engine::run_engine`]); this module redesigns the *API* around
//! it for long-horizon production runs:
//!
//! - a **typed builder** ([`Session::builder`]) that moves every scattered
//!   `assert!`/config check into build-time validation returning a typed
//!   [`EngineError`] — no panics on user input;
//! - an **incremental execution model**: [`Session::step`] advances
//!   exactly one master iteration, [`Session::run_for`] /
//!   [`Session::run_to_completion`] loop over it, so callers own the loop
//!   (live metrics, custom stopping rules, progress UIs);
//! - **streaming observers** ([`Observer`]): per-iteration callbacks
//!   replace mandatory history buffering — [`BufferingObserver`]
//!   reproduces the historical `Vec<IterRecord>` outputs bit-for-bit for
//!   the legacy wrappers. A long-horizon run no longer retains
//!   `O(max_iters)` float-laden records; the one per-iteration artifact
//!   the session still accumulates is the realized [`ArrivalTrace`]
//!   (compact integer sets), which the replay and checkpoint contracts
//!   are built on;
//! - **checkpoint/resume** ([`Checkpoint`], [`SessionBuilder::resume`]):
//!   the full mid-run state — primal/dual iterates, delay counters, the
//!   realized trace, and the worker source's own cursors and RNG streams —
//!   serialized through the dependency-free [`crate::bench::json`] writer
//!   with `f64`s encoded as exact bit patterns, so a resumed run is
//!   **bit-identical** to an uninterrupted one (pinned by the
//!   `session_api` integration suite).
//!
//! The paper connection: Section V's experiments (and the related
//! incremental/asynchronous ADMM lines, arXiv:1412.6058 and
//! arXiv:1307.8254) are long-horizon runs where online monitoring, early
//! stopping and restart-from-state are the operations of interest — the
//! run-to-completion free functions could not express any of them without
//! re-running from iteration 0.
//!
//! ```
//! use ad_admm::prelude::*;
//!
//! let mut rng = Pcg64::seed_from_u64(7);
//! let problem = LassoInstance::synthetic(&mut rng, 4, 20, 8, 0.2, 0.1).problem();
//! let cfg = AdmmConfig { rho: 40.0, tau: 3, max_iters: 50, ..Default::default() };
//! let mut history = BufferingObserver::new();
//! let mut session = Session::builder()
//!     .problem(&problem)
//!     .config(cfg)
//!     .policy(PartialBarrier { tau: 3 })
//!     .arrivals(&ArrivalModel::probabilistic(vec![0.5; 4], 1))
//!     .observer(&mut history)
//!     .build()
//!     .unwrap();
//! let stop = session.run_to_completion().unwrap();
//! assert_eq!(stop, StopReason::MaxIters);
//! let (outcome, _) = session.finish(); // `_` drops the source, releasing `&mut history`
//! assert_eq!(history.records().len(), outcome.iterations);
//! ```

use std::fmt;
use std::path::Path;
use std::sync::Arc;

use crate::bench::json::{
    self, hex_mat, hex_vec, json_usize, mat_from_hex, vec_from_hex, JsonValue,
};
use crate::cluster::multimaster::MasterGroup;
use crate::problems::{BlockError, BlockPattern, ConsensusProblem};
use crate::solvers::inexact::InexactPolicy;

use super::arrivals::{ArrivalModel, ArrivalTrace};
use super::engine::{
    ActiveSet, FaultPlan, Gate, MasterView, PartialBarrier, StepOrder, TraceSource, UpdatePolicy,
    WorkerSource,
};
use super::{
    divergence_or_tol_stop, iter_record, master_x0_update, master_x0_update_blocks, AdmmConfig,
    AdmmState, IterRecord, MasterScratch, SparseMaster, SparseView, StopReason,
};

/// Everything the builder (or a checkpoint restore) can reject. Every
/// variant corresponds to a check that used to be a scattered `assert!`
/// inside the free-function drivers.
#[derive(Clone, Debug, PartialEq)]
pub enum EngineError {
    /// The builder was not given a problem ([`SessionBuilder::problem`]).
    MissingProblem,
    /// The penalty parameter ρ must be positive and finite.
    InvalidRho(f64),
    /// The Assumption-1 delay bound τ must be ≥ 1 (on the config and on
    /// the policy).
    InvalidTau(usize),
    /// The `|A_k| ≥ A` batching gate must satisfy `1 ≤ A ≤ N`.
    InvalidMinArrivals { min_arrivals: usize, n_workers: usize },
    /// `AdmmConfig::init_x0` does not match the problem dimension.
    InitDimMismatch { got: usize, dim: usize },
    /// The worker source drives a different worker count than the problem.
    WorkerCountMismatch { source: usize, problem: usize },
    /// A master-first (full-barrier) policy on a source that pipelines
    /// worker rounds and therefore cannot realize it.
    MasterFirstUnsupported { source: &'static str },
    /// The worker source holds live, non-serializable execution state
    /// (e.g. OS threads mid-sleep) and cannot be checkpointed.
    CheckpointUnsupported { source: &'static str },
    /// Malformed or incompatible checkpoint data.
    Checkpoint(String),
    /// An invalid block-sharding configuration ([`SessionBuilder::blocks`]
    /// or [`ConsensusProblem::sharded`]): coverage gaps, overlapping
    /// blocks, out-of-range ids, ownership/dimension mismatches — the
    /// carried [`BlockError`] says which.
    Block(BlockError),
    /// A genuinely sharded session on a worker source that cannot
    /// exchange owned slices (external-solver trace sources, custom
    /// sources that keep the shard-unaware default) — rejected at build
    /// time instead of panicking on dimension mismatches mid-run.
    ShardingUnsupported { source: &'static str },
    /// An [`ActiveSet`] was built with a worker index out of range
    /// ([`ActiveSet::new`]).
    ActiveSetOutOfRange { index: usize, n_workers: usize },
    /// An invalid cluster configuration
    /// ([`crate::cluster::ClusterConfig::builder`]): bad delay, fault or
    /// thread-pool parameters, rejected at build time instead of
    /// asserting mid-run. The message says which knob.
    Cluster(String),
    /// A network-transport failure in the socket source or solver service
    /// ([`crate::cluster::transport`]): bind/connect/handshake errors,
    /// protocol violations, malformed wire payloads. Mid-run worker
    /// disconnects are *not* errors — they surface as realized outages.
    Transport(String),
    /// An invalid [`crate::solvers::inexact::InexactPolicy`] (k = 0 inner
    /// steps, non-positive adaptive tolerance, …) on the config or the
    /// builder; the message says which knob.
    InvalidInexact(String),
    /// An invalid multi-master configuration
    /// ([`crate::cluster::MasterGroup`] /
    /// [`SessionBuilder::masters`]): malformed block→master assignment,
    /// group/pattern mismatch, or a session shape the partitioned
    /// coordinators cannot drive (dense, master-first, Algorithm-4
    /// master-owned duals). The message says which.
    Masters(String),
}

impl From<BlockError> for EngineError {
    fn from(e: BlockError) -> Self {
        EngineError::Block(e)
    }
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::MissingProblem => {
                write!(f, "no problem supplied: call SessionBuilder::problem(..)")
            }
            EngineError::InvalidRho(rho) => {
                write!(f, "rho must be positive and finite, got {rho}")
            }
            EngineError::InvalidTau(tau) => write!(f, "tau must be >= 1, got {tau}"),
            EngineError::InvalidMinArrivals { min_arrivals, n_workers } => {
                write!(f, "min_arrivals must be in [1, {n_workers}], got {min_arrivals}")
            }
            EngineError::InitDimMismatch { got, dim } => {
                write!(f, "init_x0 has dimension {got}, the problem has {dim}")
            }
            EngineError::WorkerCountMismatch { source, problem } => {
                write!(
                    f,
                    "source/problem worker-count mismatch: source drives {source} workers, \
                     problem has {problem}"
                )
            }
            EngineError::MasterFirstUnsupported { source } => {
                write!(
                    f,
                    "the {source:?} worker source cannot drive a master-first (full-barrier) \
                     policy"
                )
            }
            EngineError::CheckpointUnsupported { source } => {
                write!(f, "the {source:?} worker source does not support checkpointing")
            }
            EngineError::Checkpoint(msg) => write!(f, "checkpoint error: {msg}"),
            EngineError::Block(e) => write!(f, "block pattern error: {e}"),
            EngineError::ShardingUnsupported { source } => {
                write!(
                    f,
                    "the {source:?} worker source cannot drive a block-sharded session \
                     (owned-slice messages)"
                )
            }
            EngineError::ActiveSetOutOfRange { index, n_workers } => {
                write!(
                    f,
                    "arrival set contains worker index {index}, but there are only \
                     {n_workers} workers"
                )
            }
            EngineError::Cluster(msg) => write!(f, "cluster config error: {msg}"),
            EngineError::Transport(msg) => write!(f, "transport error: {msg}"),
            EngineError::InvalidInexact(msg) => write!(f, "inexact policy error: {msg}"),
            EngineError::Masters(msg) => write!(f, "multi-master error: {msg}"),
        }
    }
}

impl std::error::Error for EngineError {}

/// Streaming per-iteration callbacks: the memory-bounded replacement for
/// mandatory history buffering.
///
/// Observers are registered with [`SessionBuilder::observer`] and see every
/// iteration as it completes — live metrics, progress UIs and log sinks
/// without retaining `O(max_iters)` records. For custom *stopping* rules,
/// own the loop instead: call [`Session::step`] and break when your
/// criterion fires.
///
/// ```
/// use ad_admm::prelude::*;
///
/// /// Counts iterations and remembers the best objective seen.
/// #[derive(Default)]
/// struct Best {
///     iters: usize,
///     best: f64,
/// }
/// impl Observer for Best {
///     fn on_start(&mut self, _state: &AdmmState) {
///         self.best = f64::INFINITY;
///     }
///     fn on_iteration(&mut self, rec: &IterRecord, _state: &AdmmState) {
///         self.iters += 1;
///         if rec.objective < self.best {
///             self.best = rec.objective;
///         }
///     }
/// }
///
/// let mut rng = Pcg64::seed_from_u64(5);
/// let problem = LassoInstance::synthetic(&mut rng, 3, 15, 6, 0.2, 0.1).problem();
/// let mut best = Best::default();
/// let mut session = Session::builder()
///     .problem(&problem)
///     .config(AdmmConfig { rho: 30.0, max_iters: 25, ..Default::default() })
///     .observer(&mut best)
///     .build()
///     .unwrap();
/// session.run_to_completion().unwrap();
/// drop(session);
/// assert_eq!(best.iters, 25);
/// assert!(best.best.is_finite());
/// ```
pub trait Observer {
    /// Once, before the first iteration (or once on resume), with the
    /// initial (or restored) state.
    fn on_start(&mut self, _state: &AdmmState) {}

    /// After every completed master iteration, with that iteration's
    /// record and the post-update state.
    fn on_iteration(&mut self, _rec: &IterRecord, _state: &AdmmState) {}

    /// Exactly once, when the run stops (early stop or iteration budget).
    /// Not called if the session is dropped mid-run.
    fn on_stop(&mut self, _stop: &StopReason, _state: &AdmmState) {}
}

impl<O: Observer + ?Sized> Observer for &mut O {
    fn on_start(&mut self, state: &AdmmState) {
        (**self).on_start(state)
    }

    fn on_iteration(&mut self, rec: &IterRecord, state: &AdmmState) {
        (**self).on_iteration(rec, state)
    }

    fn on_stop(&mut self, stop: &StopReason, state: &AdmmState) {
        (**self).on_stop(stop, state)
    }
}

/// The [`Observer`] that reproduces the historical buffered-history
/// behaviour: clones every [`IterRecord`] into a `Vec`. The legacy
/// free-function wrappers run through one of these, which is how their
/// outputs stay bit-for-bit identical to the pre-session drivers (pinned
/// by the `engine_equivalence` golden suite).
#[derive(Debug, Default)]
pub struct BufferingObserver {
    records: Vec<IterRecord>,
}

impl BufferingObserver {
    pub fn new() -> Self {
        Self::default()
    }

    /// The records buffered so far.
    pub fn records(&self) -> &[IterRecord] {
        &self.records
    }

    /// Consume the observer, yielding the buffered history.
    pub fn into_records(self) -> Vec<IterRecord> {
        self.records
    }

    /// Drain the buffered history, leaving the observer empty.
    pub fn take(&mut self) -> Vec<IterRecord> {
        std::mem::take(&mut self.records)
    }
}

impl Observer for BufferingObserver {
    fn on_iteration(&mut self, rec: &IterRecord, _state: &AdmmState) {
        self.records.push(rec.clone());
    }
}

/// What one [`Session::step`] call did.
#[derive(Clone, Debug)]
pub enum StepStatus {
    /// One master iteration completed; its record. The session may have
    /// stopped *on* this iteration — check [`Session::stop_reason`].
    Iterated(IterRecord),
    /// The run had already stopped; no iteration was performed.
    Done(StopReason),
}

/// The final artifacts of a session, extracted by [`Session::finish`].
#[derive(Clone, Debug)]
pub struct SessionOutcome {
    /// Final primal/dual state `({x_i}, x₀, {λ_i})`.
    pub state: AdmmState,
    /// Realized arrival sets — replayable through any source.
    pub trace: ArrivalTrace,
    /// Why the run stopped ([`StopReason::MaxIters`] if finished early by
    /// the caller, mirroring the engine's historical initialization).
    pub stop: StopReason,
    /// Final per-worker delay counters.
    pub final_delays: Vec<usize>,
    /// Number of completed master iterations.
    pub iterations: usize,
}

/// `doc.get(key)` with a typed missing-field error (shared by every
/// checkpointable source's `load_checkpoint`).
pub(crate) fn jget<'j>(doc: &'j JsonValue, key: &str) -> Result<&'j JsonValue, EngineError> {
    doc.get(key)
        .ok_or_else(|| EngineError::Checkpoint(format!("missing field {key:?}")))
}

fn get_usize(doc: &JsonValue, key: &str) -> Result<usize, EngineError> {
    json_usize(jget(doc, key)?)
        .map_err(|e| EngineError::Checkpoint(format!("field {key:?}: {e}")))
}

fn stop_to_json(stop: &Option<StopReason>) -> JsonValue {
    match stop {
        None => JsonValue::Null,
        Some(StopReason::MaxIters) => "max_iters".into(),
        Some(StopReason::X0Tolerance) => "x0_tolerance".into(),
        Some(StopReason::Residuals) => "residuals".into(),
        Some(StopReason::Diverged) => "diverged".into(),
    }
}

fn stop_from_json(v: &JsonValue) -> Result<Option<StopReason>, EngineError> {
    match v {
        JsonValue::Null => Ok(None),
        JsonValue::Str(s) => match s.as_str() {
            "max_iters" => Ok(Some(StopReason::MaxIters)),
            "x0_tolerance" => Ok(Some(StopReason::X0Tolerance)),
            "residuals" => Ok(Some(StopReason::Residuals)),
            "diverged" => Ok(Some(StopReason::Diverged)),
            other => Err(EngineError::Checkpoint(format!("unknown stop reason {other:?}"))),
        },
        other => Err(EngineError::Checkpoint(format!("bad stop field: {other}"))),
    }
}

// ---------------------------------------------------------------------------
// Checkpoint
// ---------------------------------------------------------------------------

/// A serialized mid-run snapshot of a [`Session`]: the `AdmmState`, delay
/// counters, realized trace, iteration cursor and the worker source's own
/// state (arrival-sampler RNG streams, per-worker `x₀`/`λ̂` snapshots, and
/// — for the virtual-time source — the full event queue and clock).
///
/// Serialized through the dependency-free [`crate::bench::json`] writer
/// with every `f64` encoded as its exact bit pattern, so
/// [`SessionBuilder::resume`] continues **bit-identically** to an
/// uninterrupted run. Resume requires a builder configured identically to
/// the one that produced the checkpoint (same problem, config, policy and
/// source construction); the checkpoint validates worker count, dimension
/// and source kind, the rest is the caller's contract.
///
/// ```
/// use ad_admm::prelude::*;
///
/// let mut rng = Pcg64::seed_from_u64(3);
/// let problem = LassoInstance::synthetic(&mut rng, 3, 15, 6, 0.2, 0.1).problem();
/// let cfg = AdmmConfig { rho: 30.0, tau: 2, max_iters: 40, ..Default::default() };
/// let arrivals = ArrivalModel::probabilistic(vec![0.6; 3], 9);
/// let build = || {
///     Session::builder()
///         .problem(&problem)
///         .config(cfg.clone())
///         .policy(PartialBarrier { tau: 2 })
///         .arrivals(&arrivals)
/// };
///
/// // Uninterrupted reference run.
/// let mut full = build().build().unwrap();
/// full.run_to_completion().unwrap();
///
/// // Interrupted run: 10 iterations, checkpoint (JSON round-trip), resume.
/// let mut first = build().build().unwrap();
/// first.run_for(10).unwrap();
/// let cp = Checkpoint::from_json_str(&first.checkpoint().unwrap().to_json_string()).unwrap();
/// let mut second = build().resume(&cp).unwrap();
/// second.run_to_completion().unwrap();
/// assert_eq!(second.state().x0, full.state().x0); // bit-identical
/// ```
#[derive(Clone, Debug)]
pub struct Checkpoint {
    doc: JsonValue,
}

impl Checkpoint {
    /// The `schema` marker every checkpoint document carries.
    pub const SCHEMA: &'static str = "ad-admm-checkpoint";
    /// Current checkpoint format version: v4 adds the multi-master
    /// section (`masters`: the block→master group map plus per-master
    /// update counters; `null` for single-master runs) and the
    /// per-worker heterogeneous policy list (`inexact_workers`; `null`
    /// when the uniform policy applies).
    pub const VERSION: usize = 4;
    /// The pre-sharding format. Still readable: a v1 document is exactly
    /// a v2 document with no `blocks` section, so v1 checkpoints resume
    /// into dense sessions unchanged.
    pub const V1: usize = 1;
    /// The block-sharding format (adds the `blocks` section; `null` for
    /// dense runs). Still readable: v2 predates inexact policies, so v2
    /// checkpoints resume into exact-policy sessions unchanged.
    pub const V2: usize = 2;
    /// The inexact-solve format (adds `inexact_policy` plus per-worker
    /// warm-start states inside the source document). Still readable: v3
    /// predates multi-master coordination, so v3 checkpoints resume into
    /// single-master (M = 1), uniform-policy sessions unchanged.
    pub const V3: usize = 3;

    fn validate(doc: &JsonValue) -> Result<(), EngineError> {
        match doc.get("schema").and_then(JsonValue::as_str) {
            Some(s) if s == Self::SCHEMA => {}
            other => {
                return Err(EngineError::Checkpoint(format!(
                    "not an ad-admm checkpoint (schema field: {other:?})"
                )))
            }
        }
        let version = get_usize(doc, "version")?;
        if !(Self::V1..=Self::VERSION).contains(&version) {
            return Err(EngineError::Checkpoint(format!(
                "unsupported checkpoint version {version} (this build reads versions {} \
                 through {})",
                Self::V1,
                Self::VERSION
            )));
        }
        let required =
            ["k", "n_workers", "dim", "stop", "source_kind", "state", "delays", "trace", "source"];
        for key in required {
            jget(doc, key)?;
        }
        Ok(())
    }

    /// Wrap an already-parsed document (validates the envelope).
    pub fn from_json(doc: JsonValue) -> Result<Self, EngineError> {
        Self::validate(&doc)?;
        Ok(Checkpoint { doc })
    }

    /// Parse a checkpoint from its JSON text.
    pub fn from_json_str(text: &str) -> Result<Self, EngineError> {
        let doc = json::parse(text)
            .map_err(|e| EngineError::Checkpoint(format!("malformed checkpoint JSON: {e}")))?;
        Self::from_json(doc)
    }

    /// The underlying document.
    pub fn as_json(&self) -> &JsonValue {
        &self.doc
    }

    /// Serialize to JSON text.
    pub fn to_json_string(&self) -> String {
        self.doc.to_string()
    }

    /// The master iteration this checkpoint was taken at (= completed
    /// iterations; resume continues with this iteration).
    pub fn iteration(&self) -> usize {
        get_usize(&self.doc, "k").unwrap_or(0)
    }

    /// Worker count recorded in the checkpoint.
    pub fn n_workers(&self) -> usize {
        get_usize(&self.doc, "n_workers").unwrap_or(0)
    }

    /// Which [`WorkerSource::kind`] produced this checkpoint.
    pub fn source_kind(&self) -> &str {
        self.doc.get("source_kind").and_then(JsonValue::as_str).unwrap_or("")
    }

    /// Write the checkpoint to a file (atomic enough for a single writer:
    /// staged through a `<name>.tmp` sibling then renamed — the suffix is
    /// *appended* so checkpoints sharing a file stem never collide on the
    /// staging path; any existing destination is removed first, since
    /// rename-over-existing fails on Windows).
    pub fn write_to_file<P: AsRef<Path>>(&self, path: P) -> std::io::Result<()> {
        let path = path.as_ref();
        let mut tmp_name = path.as_os_str().to_owned();
        tmp_name.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp_name);
        std::fs::write(&tmp, self.to_json_string())?;
        if path.exists() {
            std::fs::remove_file(path)?;
        }
        std::fs::rename(&tmp, path)
    }

    /// Read a checkpoint back from a file.
    pub fn read_from_file<P: AsRef<Path>>(path: P) -> Result<Self, EngineError> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).map_err(|e| {
            EngineError::Checkpoint(format!("cannot read {}: {e}", path.display()))
        })?;
        Self::from_json_str(&text)
    }

    /// Attach (or replace) a caller-defined metadata entry under the
    /// checkpoint's `meta` object — e.g. the CLI records the flags needed
    /// to rebuild the problem for `ad_admm resume`.
    pub fn set_meta(&mut self, key: &str, value: JsonValue) {
        if let JsonValue::Obj(fields) = &mut self.doc {
            let idx = match fields.iter().position(|(k, _)| k == "meta") {
                Some(i) => i,
                None => {
                    fields.push(("meta".to_string(), JsonValue::Obj(Vec::new())));
                    fields.len() - 1
                }
            };
            if let JsonValue::Obj(entries) = &mut fields[idx].1 {
                match entries.iter().position(|(k, _)| k == key) {
                    Some(i) => entries[i].1 = value,
                    None => entries.push((key.to_string(), value)),
                }
            }
        }
    }

    /// Read a caller-defined metadata entry.
    pub fn meta(&self, key: &str) -> Option<&JsonValue> {
        self.doc.get("meta").and_then(|m| m.get(key))
    }
}

// ---------------------------------------------------------------------------
// Builder
// ---------------------------------------------------------------------------

enum SourceSpec<'a> {
    Boxed(Box<dyn WorkerSource + 'a>),
    Arrivals(ArrivalModel),
}

/// Typed, validating builder for [`Session`]. Every knob that used to be a
/// free-function parameter or an `EngineOptions` field lives here; *all*
/// config checks happen in [`SessionBuilder::build`] and return
/// [`EngineError`] instead of panicking.
///
/// Defaults: policy = [`PartialBarrier`] at the config's τ (Algorithms
/// 2/3, the paper's headline protocol); source = the in-process
/// trace-driven source over [`ArrivalModel::Full`]; residual stopping on;
/// no faults; no observers.
pub struct SessionBuilder<'a> {
    problem: Option<&'a ConsensusProblem>,
    cfg: AdmmConfig,
    policy: Option<Box<dyn UpdatePolicy + 'a>>,
    source: Option<SourceSpec<'a>>,
    observers: Vec<Box<dyn Observer + 'a>>,
    fault_plan: Option<FaultPlan>,
    residual_stopping: bool,
    blocks: Option<BlockPattern>,
    sparse_master: bool,
    inexact: Option<InexactPolicy>,
    inexact_workers: Option<Vec<InexactPolicy>>,
    masters: Option<MasterGroup>,
}

impl<'a> Default for SessionBuilder<'a> {
    fn default() -> Self {
        Self::new()
    }
}

impl<'a> SessionBuilder<'a> {
    pub fn new() -> Self {
        SessionBuilder {
            problem: None,
            cfg: AdmmConfig::default(),
            policy: None,
            source: None,
            observers: Vec::new(),
            fault_plan: None,
            residual_stopping: true,
            blocks: None,
            sparse_master: true,
            inexact: None,
            inexact_workers: None,
            masters: None,
        }
    }

    /// The consensus problem to solve (required).
    pub fn problem(mut self, problem: &'a ConsensusProblem) -> Self {
        self.problem = Some(problem);
        self
    }

    /// Algorithm parameters (ρ, γ, τ, `min_arrivals`, iteration budget,
    /// tolerances…). Defaults to [`AdmmConfig::default`].
    pub fn config(mut self, cfg: AdmmConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// The update policy — *which algorithm of the paper runs*. Defaults
    /// to [`PartialBarrier`] at the config's τ.
    pub fn policy<P: UpdatePolicy + 'a>(mut self, policy: P) -> Self {
        self.policy = Some(Box::new(policy));
        self
    }

    /// An explicit worker source. Overrides [`SessionBuilder::arrivals`].
    pub fn source<S: WorkerSource + 'a>(mut self, source: S) -> Self {
        self.source = Some(SourceSpec::Boxed(Box::new(source)));
        self
    }

    /// Convenience: drive the in-process trace-driven source
    /// ([`TraceSource`]) from this arrival model. Default:
    /// [`ArrivalModel::Full`].
    pub fn arrivals(mut self, arrivals: &ArrivalModel) -> Self {
        self.source = Some(SourceSpec::Arrivals(arrivals.clone()));
        self
    }

    /// Register a streaming [`Observer`] (repeatable; called in
    /// registration order).
    pub fn observer<O: Observer + 'a>(mut self, observer: O) -> Self {
        self.observers.push(Box::new(observer));
        self
    }

    /// Deterministic worker outage / delay-spike schedule, enforced at the
    /// master's gate identically in every source.
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    /// Evaluate the residual-based stopping rule when the config carries
    /// one (on by default; the historical Algorithm-4 driver ran with this
    /// off).
    pub fn residual_stopping(mut self, enabled: bool) -> Self {
        self.residual_stopping = enabled;
        self
    }

    /// Run block-sharded general-form consensus under this
    /// [`BlockPattern`]. Validated at `build()` (coverage, overlaps,
    /// out-of-range ids, per-worker dimensions) into
    /// [`EngineError::Block`].
    ///
    /// A problem built with [`ConsensusProblem::sharded`] carries its
    /// pattern already and picks it up automatically — calling this too is
    /// allowed but the patterns must agree. On a *dense* problem, only an
    /// effectively-dense pattern (every worker owns the full dimension,
    /// e.g. [`BlockPattern::dense`]) is accepted; the session then runs
    /// the sharded code path, which is bit-identical to the dense engine.
    pub fn blocks(mut self, pattern: BlockPattern) -> Self {
        self.blocks = Some(pattern);
        self
    }

    /// Run the worker subproblem solves under this
    /// [`InexactPolicy`] — the k-step inner loops of the inexact
    /// consensus-ADMM line (arXiv:1412.6058) with per-worker warm starts.
    /// Overrides the config's `inexact` field; validated at `build()` into
    /// [`EngineError::InvalidInexact`]. The default
    /// ([`InexactPolicy::Exact`]) is bit-identical to the historical exact
    /// solve path.
    pub fn inexact(mut self, policy: InexactPolicy) -> Self {
        self.inexact = Some(policy);
        self
    }

    /// Per-worker heterogeneous [`InexactPolicy`] vector (one entry per
    /// worker), overriding the uniform policy worker-by-worker — a fast
    /// machine can run `newton:2` while a straggler runs `grad:3`. The
    /// uniform [`SessionBuilder::inexact`] spelling remains the default.
    /// Validated at `build()` (length = worker count, each policy sane)
    /// into [`EngineError::InvalidInexact`]; serialized into v4
    /// checkpoints so a resume never continues under different per-worker
    /// inner-loop schedules.
    pub fn inexact_per_worker(mut self, policies: Vec<InexactPolicy>) -> Self {
        self.inexact_workers = Some(policies);
        self
    }

    /// Partition the coordinator itself across the masters of `group`
    /// ([`MasterGroup`]: a validated block→master map): each master runs
    /// its own masked [`SparseMaster`] over only its owned blocks, and a
    /// round completes when every master's gate is satisfied. Requires a
    /// block-sharded, workers-first session whose policy leaves duals
    /// with the workers (the sparse-eligible shape); anything else is
    /// rejected as [`EngineError::Masters`] at `build()`. An M-master run
    /// is bit-identical to the single-master sparse engine on the same
    /// realized arrival trace (pinned by the `multimaster` suite).
    pub fn masters(mut self, group: MasterGroup) -> Self {
        self.masters = Some(group);
        self
    }

    /// Run the master update through the O(active) lazy sparse path
    /// ([`SparseMaster`]) when the session is eligible: block-sharded,
    /// workers-first step order, and the policy does not rewrite all duals
    /// (Algorithm 4). On by default — the sparse path is bit-identical to
    /// the eager [`super::master_x0_update_blocks`], so this is purely a
    /// performance knob; pass `false` to force the eager dense sweep
    /// (e.g. for A/B benchmarking). Ineligible sessions always run eager,
    /// whatever this is set to.
    pub fn sparse_master(mut self, enabled: bool) -> Self {
        self.sparse_master = enabled;
        self
    }

    fn take_source(&mut self) -> Result<Box<dyn WorkerSource + 'a>, EngineError> {
        let problem = self.problem.ok_or(EngineError::MissingProblem)?;
        let policy = self.inexact.unwrap_or(self.cfg.inexact);
        // Heterogeneous per-worker policies, validated later in
        // `into_session` (which runs before the source ever solves).
        let per_worker = self.inexact_workers.clone();
        let trace_source = |model: &ArrivalModel| match per_worker {
            Some(policies) => TraceSource::with_policies(problem, model, policies),
            None => TraceSource::with_policy(problem, model, policy),
        };
        Ok(match self.source.take() {
            Some(SourceSpec::Boxed(b)) => b,
            Some(SourceSpec::Arrivals(model)) => Box::new(trace_source(&model)),
            None => Box::new(trace_source(&ArrivalModel::Full)),
        })
    }

    /// Validate everything and construct the session at iteration 0.
    pub fn build(mut self) -> Result<Session<'a>, EngineError> {
        let source = self.take_source()?;
        self.into_session(source, None)
    }

    /// Validate everything and restore the session from `checkpoint`
    /// instead of iteration 0. The builder must be configured identically
    /// to the one that produced the checkpoint.
    pub fn resume(mut self, checkpoint: &Checkpoint) -> Result<Session<'a>, EngineError> {
        let source = self.take_source()?;
        self.into_session(source, Some(checkpoint))
    }

    /// [`SessionBuilder::build`] with a concretely-typed source, so the
    /// caller keeps by-value access to it after [`Session::finish`] (the
    /// cluster uses this to read execution stats back out of the
    /// virtual-time source). Any source set on the builder is ignored.
    pub fn build_typed<S: WorkerSource + 'a>(
        self,
        source: S,
    ) -> Result<Session<'a, S>, EngineError> {
        self.into_session(source, None)
    }

    /// [`SessionBuilder::resume`] with a concretely-typed source.
    pub fn resume_typed<S: WorkerSource + 'a>(
        self,
        source: S,
        checkpoint: &Checkpoint,
    ) -> Result<Session<'a, S>, EngineError> {
        self.into_session(source, Some(checkpoint))
    }

    fn into_session<S: WorkerSource + 'a>(
        self,
        source: S,
        checkpoint: Option<&Checkpoint>,
    ) -> Result<Session<'a, S>, EngineError> {
        let problem = self.problem.ok_or(EngineError::MissingProblem)?;
        let mut cfg = self.cfg;
        if let Some(p) = self.inexact {
            cfg.inexact = p;
        }
        cfg.inexact.validate().map_err(EngineError::InvalidInexact)?;
        let n_workers = problem.num_workers();
        let dim = problem.dim();
        if let Some(policies) = &self.inexact_workers {
            if policies.len() != n_workers {
                return Err(EngineError::InvalidInexact(format!(
                    "inexact_per_worker has {} entries, the problem has {n_workers} workers",
                    policies.len()
                )));
            }
            for (i, p) in policies.iter().enumerate() {
                p.validate()
                    .map_err(|e| EngineError::InvalidInexact(format!("worker {i}: {e}")))?;
            }
        }

        // Resolve the block-sharding pattern: the builder's override or
        // the problem's own ([`ConsensusProblem::sharded`]). A
        // builder-supplied pattern is structurally valid by construction
        // ([`BlockPattern::new`] rejects gaps/overlaps/out-of-range); what
        // remains are the cross-checks against this problem.
        let shard: Option<Arc<BlockPattern>> = match (self.blocks, problem.pattern()) {
            (None, None) => None,
            (None, Some(p)) => Some(Arc::clone(p)),
            (Some(b), problem_pattern) => {
                if b.num_workers() != n_workers {
                    return Err(BlockError::WorkerCountMismatch {
                        pattern: b.num_workers(),
                        problem: n_workers,
                    }
                    .into());
                }
                if b.dim() != dim {
                    return Err(
                        BlockError::DimMismatch { pattern: b.dim(), problem: dim }.into()
                    );
                }
                for i in 0..n_workers {
                    let local_dim = problem.local(i).dim();
                    if local_dim != b.owned_len(i) {
                        return Err(BlockError::LocalDimMismatch {
                            worker: i,
                            local_dim,
                            owned_len: b.owned_len(i),
                        }
                        .into());
                    }
                }
                if let Some(p) = problem_pattern {
                    if **p != b {
                        return Err(BlockError::PatternMismatch.into());
                    }
                }
                Some(Arc::new(b))
            }
        };

        if !(cfg.rho > 0.0 && cfg.rho.is_finite()) {
            return Err(EngineError::InvalidRho(cfg.rho));
        }
        if cfg.tau < 1 {
            return Err(EngineError::InvalidTau(cfg.tau));
        }
        let policy = self
            .policy
            .unwrap_or_else(|| Box::new(PartialBarrier { tau: cfg.tau }));
        if policy.tau() < 1 {
            return Err(EngineError::InvalidTau(policy.tau()));
        }
        if cfg.min_arrivals < 1 || cfg.min_arrivals > n_workers {
            return Err(EngineError::InvalidMinArrivals {
                min_arrivals: cfg.min_arrivals,
                n_workers,
            });
        }
        if let Some(x0) = &cfg.init_x0 {
            if x0.len() != dim {
                return Err(EngineError::InitDimMismatch { got: x0.len(), dim });
            }
        }
        if source.n_workers() != n_workers {
            return Err(EngineError::WorkerCountMismatch {
                source: source.n_workers(),
                problem: n_workers,
            });
        }
        if policy.order() == StepOrder::MasterFirst && !source.supports_master_first() {
            return Err(EngineError::MasterFirstUnsupported { source: source.kind() });
        }
        // A genuinely sharded session needs a source that gathers owned
        // slices; effectively-dense patterns exchange full-length
        // messages, so any source can drive them (that is the
        // bit-identity acceptance case).
        if let Some(p) = &shard {
            if !p.is_effectively_dense() && !source.supports_sharding() {
                return Err(EngineError::ShardingUnsupported { source: source.kind() });
            }
        }

        let state = match &shard {
            // Sharded init: per-worker owned slices (ragged xs/lams). The
            // InitDimMismatch check above already validated init_x0
            // against the global dimension.
            Some(p) => {
                let x0 = match &cfg.init_x0 {
                    Some(x0) => x0.clone(),
                    None => vec![0.0; dim],
                };
                AdmmState::init_blocks(p, x0)
            }
            None => cfg.initial_state(n_workers, dim),
        };
        let num_blocks = shard.as_ref().map(|p| p.num_blocks()).unwrap_or(0);
        // The O(active) lazy sparse master: eligible whenever the arrived
        // set is what drives the update (workers-first) and the policy
        // does not rewrite every dual against the fresh x₀ (Algorithm 4
        // invalidates the cached accumulators wholesale). Bit-identical to
        // the eager sweep, so on by default.
        // Multi-master partitioned coordination: one masked sparse master
        // per coordinator. Requires the sparse-eligible session shape —
        // the per-master masters *are* masked [`SparseMaster`]s, and a
        // master-first or Algorithm-4 policy has no per-block arrival
        // structure to partition.
        let masters = match self.masters {
            None => None,
            Some(group) => {
                let p = shard.as_ref().ok_or_else(|| {
                    EngineError::Masters(
                        "multi-master coordination requires a block-sharded session \
                         (SessionBuilder::blocks or ConsensusProblem::sharded)"
                            .to_string(),
                    )
                })?;
                if policy.order() != StepOrder::WorkersFirst {
                    return Err(EngineError::Masters(
                        "multi-master coordination requires a workers-first policy".to_string(),
                    ));
                }
                if policy.master_updates_all_duals() {
                    return Err(EngineError::Masters(
                        "multi-master coordination cannot drive Algorithm 4 \
                         (master-owned duals rewrite every block each round)"
                            .to_string(),
                    ));
                }
                if !self.sparse_master {
                    return Err(EngineError::Masters(
                        "multi-master coordination requires the sparse master \
                         (sparse_master(false) conflicts with masters(..))"
                            .to_string(),
                    ));
                }
                group.validate_against(p)?;
                let per = (0..group.num_masters())
                    .map(|m| SparseMaster::new_masked(p, &state, cfg.rho, group.block_mask(m)))
                    .collect();
                Some(MultiMasterState { group: Arc::new(group), per })
            }
        };
        let sparse = if masters.is_none()
            && self.sparse_master
            && policy.order() == StepOrder::WorkersFirst
            && !policy.master_updates_all_duals()
        {
            shard.as_ref().map(|p| SparseMaster::new(p, &state, cfg.rho))
        } else {
            None
        };
        let mut scratch = MasterScratch::new();
        // f_i(x_i) cache: only arrived workers' x_i move, so only they are
        // re-evaluated (perf: N → |A_k| data passes per iteration). On
        // resume the restore pass recomputes every entry from the restored
        // iterates, so skip the N initial data passes entirely.
        let mut f_cache = vec![0.0; n_workers];
        if checkpoint.is_none() {
            for i in 0..n_workers {
                f_cache[i] = problem.local(i).eval_with(&state.xs[i], &mut scratch.ws);
            }
        }
        let prev_x0 = state.x0.clone();

        let mut session = Session {
            problem,
            cfg,
            policy,
            observers: self.observers,
            fault_plan: self.fault_plan,
            residual_stopping: self.residual_stopping,
            source,
            state,
            d: vec![0; n_workers],
            down: vec![false; n_workers],
            arrived: vec![false; n_workers],
            all: ActiveSet::full(n_workers),
            f_cache,
            scratch,
            prev_x0,
            trace: ArrivalTrace::default(),
            k: 0,
            stop: None,
            source_started: false,
            observers_started: false,
            shard,
            sparse,
            masters,
            inexact_workers: self.inexact_workers,
            block_updates: vec![0; num_blocks],
            block_last_arrival: vec![-1; num_blocks],
        };
        if let Some(cp) = checkpoint {
            session.restore_from(cp)?;
        }
        Ok(session)
    }
}

// ---------------------------------------------------------------------------
// Session
// ---------------------------------------------------------------------------

/// The partitioned-coordinator state of a multi-master session: the
/// block→master [`MasterGroup`] and one masked [`SparseMaster`] per
/// coordinator. Every master performs its (possibly empty) update on
/// every global round, so the per-master update counters march in step —
/// the invariant that makes the union of the M masked updates
/// bit-identical to the single global sparse update.
pub(crate) struct MultiMasterState {
    pub(crate) group: Arc<MasterGroup>,
    pub(crate) per: Vec<SparseMaster>,
}

/// An incremental run of the unified iteration engine: one (problem,
/// config, policy, source) tuple with its full mid-run state, advanced one
/// master iteration at a time.
///
/// Construct with [`Session::builder`]. The generic source parameter `S`
/// defaults to a boxed trait object (what [`SessionBuilder::build`]
/// returns); [`SessionBuilder::build_typed`] keeps a concrete source type
/// so it can be recovered by value from [`Session::finish`].
///
/// Two sessions realizing the same arrival trace produce bit-identical
/// iterates — the engine-refactor equivalence, which the session preserves
/// by construction: [`Session::step`] *is* the engine's loop body.
pub struct Session<'a, S: WorkerSource + 'a = Box<dyn WorkerSource + 'a>> {
    problem: &'a ConsensusProblem,
    cfg: AdmmConfig,
    policy: Box<dyn UpdatePolicy + 'a>,
    observers: Vec<Box<dyn Observer + 'a>>,
    fault_plan: Option<FaultPlan>,
    residual_stopping: bool,
    source: S,
    state: AdmmState,
    /// Per-worker delay counters `d_i`.
    d: Vec<usize>,
    /// Per-iteration outage mask (recomputed from the fault plan).
    down: Vec<bool>,
    /// Reusable scratch mask for the delay-counter update.
    arrived: Vec<bool>,
    /// `0..N`, the full-broadcast set.
    all: ActiveSet,
    f_cache: Vec<f64>,
    scratch: MasterScratch,
    prev_x0: Vec<f64>,
    trace: ArrivalTrace,
    /// Completed master iterations.
    k: usize,
    stop: Option<StopReason>,
    source_started: bool,
    observers_started: bool,
    /// Block-sharding pattern (None = the historical dense protocol).
    shard: Option<Arc<BlockPattern>>,
    /// The O(active) lazy sparse master (None = eager path: dense
    /// sessions, master-first or Algorithm-4 policies, an explicit
    /// [`SessionBuilder::sparse_master`]`(false)`, or a multi-master
    /// session — whose masked per-master states live in `masters`).
    sparse: Option<SparseMaster>,
    /// Multi-master partitioned coordination
    /// ([`SessionBuilder::masters`]): the group map plus one masked
    /// sparse master per coordinator. `None` = the single-master star.
    masters: Option<MultiMasterState>,
    /// Per-worker heterogeneous inexact policies declared on the builder
    /// (`None` = uniform `cfg.inexact`). Carried for checkpoint
    /// serialization/validation; the solving itself happens inside the
    /// worker source.
    inexact_workers: Option<Vec<InexactPolicy>>,
    /// Per-block arrival counters: total arrivals of owners of each block.
    block_updates: Vec<u64>,
    /// Per-block last-arrival stamps: the iteration at which any owner of
    /// the block last arrived (−1 = never). Kept as stamps rather than a
    /// per-iteration age sweep so the bookkeeping stays O(active);
    /// [`Session::block_ages`] derives the staleness — bounded by τ − 1
    /// whenever the realized trace satisfies Assumption 1, the per-block
    /// delay bound of the block-wise analysis (arXiv:1802.08882).
    block_last_arrival: Vec<i64>,
}

impl<'a> Session<'a> {
    /// Start configuring a session.
    pub fn builder() -> SessionBuilder<'a> {
        SessionBuilder::new()
    }
}

impl<'a, S: WorkerSource + 'a> Session<'a, S> {
    /// The problem this session solves.
    pub fn problem(&self) -> &'a ConsensusProblem {
        self.problem
    }

    /// The algorithm parameters.
    pub fn config(&self) -> &AdmmConfig {
        &self.cfg
    }

    /// Current primal/dual state.
    pub fn state(&self) -> &AdmmState {
        &self.state
    }

    /// Completed master iterations.
    pub fn iteration(&self) -> usize {
        self.k
    }

    /// Why the run stopped (None while still running).
    pub fn stop_reason(&self) -> Option<&StopReason> {
        self.stop.as_ref()
    }

    /// Realized arrival sets so far.
    pub fn trace(&self) -> &ArrivalTrace {
        &self.trace
    }

    /// Current per-worker delay counters.
    pub fn delays(&self) -> &[usize] {
        &self.d
    }

    /// The worker source (e.g. to inspect virtual-time execution stats).
    pub fn source(&self) -> &S {
        &self.source
    }

    /// The block-sharding pattern this session runs under (None = dense).
    pub fn shard(&self) -> Option<&BlockPattern> {
        self.shard.as_deref()
    }

    /// Per-block arrival counters (empty when not sharded): how many
    /// owner arrivals each coordinate block has absorbed so far.
    pub fn block_updates(&self) -> &[u64] {
        &self.block_updates
    }

    /// Per-block staleness (empty when not sharded): completed iterations
    /// since each block last received an owner arrival. Under Assumption 1
    /// every entry stays ≤ τ − 1 — the per-block delay bound. Derived on
    /// demand from last-arrival stamps (the hot loop keeps no per-block
    /// sweep), so this allocates; don't call it per iteration at scale.
    pub fn block_ages(&self) -> Vec<usize> {
        let done = self.k as i64;
        self.block_last_arrival.iter().map(|&last| (done - 1 - last).max(0) as usize).collect()
    }

    /// Read-only view of the lazy sparse-master state: per-block staleness
    /// stamps and the O(active) accumulators. `None` on the eager path —
    /// dense sessions, master-first or Algorithm-4 policies, or an
    /// explicit [`SessionBuilder::sparse_master`]`(false)`.
    pub fn sparse(&self) -> Option<SparseView<'_>> {
        self.sparse.as_ref().map(|s| s.view())
    }

    /// Whether this session's master update runs the O(active) sparse
    /// path (single-master; a multi-master session runs M masked sparse
    /// paths instead — see [`Session::master_group`]).
    pub fn sparse_active(&self) -> bool {
        self.sparse.is_some()
    }

    /// The multi-master partition this session coordinates under
    /// (`None` = the single-master star topology).
    pub fn master_group(&self) -> Option<&MasterGroup> {
        self.masters.as_ref().map(|mm| mm.group.as_ref())
    }

    /// Number of coordinators (1 for the single-master star).
    pub fn num_masters(&self) -> usize {
        self.masters.as_ref().map_or(1, |mm| mm.group.num_masters())
    }

    fn ensure_started(&mut self) {
        if !self.source_started {
            self.source.start(&self.state, self.policy.as_ref());
            self.source_started = true;
        }
        if !self.observers_started {
            for obs in self.observers.iter_mut() {
                obs.on_start(&self.state);
            }
            self.observers_started = true;
        }
    }

    /// The master `x₀` update (12)/(25)/(45): record the previous `x₀`,
    /// then dispatch to the dense or block-sharded (per-coordinate
    /// owner-count) assembly. Shared by both step orders.
    fn master_update(&mut self) {
        self.prev_x0.copy_from_slice(&self.state.x0);
        match self.shard.clone() {
            None => master_x0_update(
                self.problem,
                &mut self.state,
                self.cfg.rho,
                self.cfg.gamma,
                &mut self.scratch,
            ),
            Some(p) => master_x0_update_blocks(
                self.problem,
                &mut self.state,
                self.cfg.rho,
                self.cfg.gamma,
                &mut self.scratch,
                &p,
            ),
        }
    }

    fn set_stop(&mut self, reason: StopReason) {
        self.stop = Some(reason);
        if let Some(reason) = self.stop.as_ref() {
            for obs in self.observers.iter_mut() {
                obs.on_stop(reason, &self.state);
            }
        }
    }

    /// Advance exactly one master iteration. This is the engine's loop
    /// body — gather arrivals, absorb worker results, master `x₀` update,
    /// policy post-step, broadcast, record, stop checks — so stepping to
    /// completion is bit-identical to the one-shot drivers.
    pub fn step(&mut self) -> Result<StepStatus, EngineError> {
        if let Some(stop) = &self.stop {
            return Ok(StepStatus::Done(stop.clone()));
        }
        // Start (source + observers) before the budget check so a
        // max_iters = 0 session still honours the observer contract
        // (on_start strictly before on_stop) and the legacy engine's
        // source.start-before-the-loop behaviour.
        self.ensure_started();
        if self.k >= self.cfg.max_iters {
            self.set_stop(StopReason::MaxIters);
            return Ok(StepStatus::Done(StopReason::MaxIters));
        }

        let k = self.k;
        let n_workers = self.state.xs.len();
        let n = self.state.x0.len();
        // Whether this iteration evaluates the O(Σ|S_i|) diagnostics
        // (augmented Lagrangian, consensus, ‖Δx₀‖) and the stopping rules
        // that read them. Off-iterations keep the sparse master genuinely
        // O(active) and record NaN metrics.
        let metrics_on = self.cfg.metrics_every > 0 && k % self.cfg.metrics_every == 0;
        if let Some(plan) = &self.fault_plan {
            plan.fill_down(k, &mut self.down);
        }

        let set = match self.policy.order() {
            StepOrder::WorkersFirst => {
                // Steps 3–5: gather the arrival set, absorb the arrived
                // worker updates (19)/(23)/(47), advance delay counters.
                let gate = Gate {
                    tau: self.policy.tau(),
                    min_arrivals: self.cfg.min_arrivals,
                    down: &self.down,
                };
                let set = self.source.gather(k, &self.d, &gate);
                {
                    let mut view = MasterView {
                        problem: self.problem,
                        state: &mut self.state,
                        f_cache: &mut self.f_cache,
                        scratch: &mut self.scratch,
                        rho: self.cfg.rho,
                        shard: self.shard.as_deref(),
                        sparse: self.sparse.as_ref(),
                    };
                    self.source.absorb(&set, &mut view, self.policy.as_ref());
                }
                super::engine::advance_delays(&set, &mut self.arrived, &mut self.d);

                // (12)/(25)/(45): master x₀ update with the proximal γ.
                // Sparse path: touch only the arrived owners' blocks —
                // O(Σ_{i∈A_k} |S_i|) — deferring the rest; the
                // materialize/copy sandwich (only when this iteration's
                // diagnostics read x₀ densely) reproduces the eager
                // per-iteration x₀ bit-for-bit. Eager path: the historical
                // dense or per-coordinate owner-count sweep.
                if let Some(mm) = &mut self.masters {
                    // Multi-master: every coordinator performs its masked
                    // update on every global round (the block masks
                    // partition the touched set, the update counters march
                    // in step), so looping the masters in id order is
                    // bit-identical to the single global sparse update —
                    // each block sees exactly the same block-local
                    // operations in the same order. The stitched global
                    // view for diagnostics is the same materialize/copy
                    // sandwich, looped per master.
                    // ad-lint: allow(panic-free-lib): builder invariant: multi-master state is only constructed with a shard pattern
                    let p = self.shard.clone().expect("masters implies sharded");
                    if metrics_on {
                        for sp in &mut mm.per {
                            sp.materialize(
                                self.problem,
                                &mut self.state.x0,
                                self.cfg.rho,
                                self.cfg.gamma,
                                &p,
                            );
                        }
                        self.prev_x0.copy_from_slice(&self.state.x0);
                    }
                    for sp in &mut mm.per {
                        sp.update(
                            self.problem,
                            &mut self.state,
                            self.cfg.rho,
                            self.cfg.gamma,
                            &p,
                            &set,
                        );
                    }
                    if metrics_on {
                        for sp in &mut mm.per {
                            sp.materialize(
                                self.problem,
                                &mut self.state.x0,
                                self.cfg.rho,
                                self.cfg.gamma,
                                &p,
                            );
                        }
                    }
                } else {
                    match &mut self.sparse {
                        Some(sp) => {
                            // ad-lint: allow(panic-free-lib): builder invariant: the sparse master is only constructed with a shard pattern
                            let p = self.shard.clone().expect("sparse implies sharded");
                            if metrics_on {
                                sp.materialize(
                                    self.problem,
                                    &mut self.state.x0,
                                    self.cfg.rho,
                                    self.cfg.gamma,
                                    &p,
                                );
                                self.prev_x0.copy_from_slice(&self.state.x0);
                            }
                            sp.update(
                                self.problem,
                                &mut self.state,
                                self.cfg.rho,
                                self.cfg.gamma,
                                &p,
                                &set,
                            );
                            if metrics_on {
                                sp.materialize(
                                    self.problem,
                                    &mut self.state.x0,
                                    self.cfg.rho,
                                    self.cfg.gamma,
                                    &p,
                                );
                            }
                        }
                        None => self.master_update(),
                    }
                }

                // Algorithm 4 (46): master refreshes ALL duals against the
                // fresh x₀ (each worker-block dual against its owned slice
                // of x₀ when sharded).
                if self.policy.master_updates_all_duals() {
                    match self.shard.clone() {
                        None => {
                            for i in 0..n_workers {
                                for j in 0..n {
                                    self.state.lams[i][j] += self.cfg.rho
                                        * (self.state.xs[i][j] - self.state.x0[j]);
                                }
                            }
                        }
                        Some(p) => {
                            let rho = self.cfg.rho;
                            let AdmmState { xs, x0, lams } = &mut self.state;
                            for i in 0..n_workers {
                                let xi = &xs[i];
                                let li = &mut lams[i];
                                p.for_each_range(i, |lo, g, len| {
                                    for c in 0..len {
                                        li[lo + c] += rho * (xi[lo + c] - x0[g + c]);
                                    }
                                });
                            }
                        }
                    }
                }

                // Step 6: broadcast to the arrived workers only.
                self.source.broadcast(&set, &self.state, self.policy.as_ref());
                set
            }
            StepOrder::MasterFirst => {
                // Algorithm 1: master x₀ update (6) from (xᵏ, λᵏ) first...
                self.master_update();
                // ...broadcast to every LIVE worker. A down worker keeps
                // its last pre-outage snapshot (and its frozen x_i/λ_i):
                // under a full barrier "dropped" means its contribution to
                // the master update simply stops moving until rejoin.
                if self.fault_plan.is_some() {
                    let live = ActiveSet::from_sorted(
                        (0..n_workers).filter(|&i| !self.down[i]).collect(),
                    );
                    self.source.broadcast(&live, &self.state, self.policy.as_ref());
                } else {
                    self.source.broadcast(&self.all, &self.state, self.policy.as_ref());
                }
                // ...then every worker solves (7)+(8) against the fresh
                // x₀^{k+1} (τ = 1 forces the full barrier at the gate).
                let gate = Gate {
                    tau: self.policy.tau(),
                    min_arrivals: self.cfg.min_arrivals,
                    down: &self.down,
                };
                let set = self.source.gather(k, &self.d, &gate);
                {
                    let mut view = MasterView {
                        problem: self.problem,
                        state: &mut self.state,
                        f_cache: &mut self.f_cache,
                        scratch: &mut self.scratch,
                        rho: self.cfg.rho,
                        shard: self.shard.as_deref(),
                        sparse: self.sparse.as_ref(),
                    };
                    self.source.absorb(&set, &mut view, self.policy.as_ref());
                }
                super::engine::advance_delays(&set, &mut self.arrived, &mut self.d);
                set
            }
        };

        // Per-block arrival bookkeeping: a block "updates" whenever any of
        // its owners arrives, and its last-arrival stamp yields the
        // per-block staleness the block-wise Assumption 1 bounds by τ.
        // Stamps instead of a per-block age sweep keep this
        // O(Σ_{i∈A_k} |owned(i)|).
        if let Some(p) = self.shard.clone() {
            for &i in &set {
                for &b in p.owned(i) {
                    self.block_updates[b] += 1;
                    self.block_last_arrival[b] = k as i64;
                }
            }
        }

        let shard = self.shard.clone();
        let rec = if metrics_on {
            iter_record(
                self.problem,
                &self.state,
                &self.cfg,
                k,
                set.len(),
                &self.f_cache,
                &mut self.scratch,
                &self.prev_x0,
                shard.as_deref(),
            )
        } else {
            // Metrics skipped: NaN diagnostics, real arrival count —
            // mirrors the `objective_every` convention.
            IterRecord {
                k,
                objective: f64::NAN,
                aug_lagrangian: f64::NAN,
                consensus: f64::NAN,
                x0_change: f64::NAN,
                arrivals: set.len(),
            }
        };
        let early = if metrics_on {
            divergence_or_tol_stop(&self.cfg, &self.state, &rec, k)
        } else {
            // O(|A_k|) divergence guard: only the arrived workers' iterates
            // moved, and a non-finite x_i surfaces in its fresh f_i value.
            if set.iter().any(|&i| !self.f_cache[i].is_finite()) {
                Some(StopReason::Diverged)
            } else {
                None
            }
        };
        self.trace.sets.push(set.into_vec());
        self.k += 1;
        for obs in self.observers.iter_mut() {
            obs.on_iteration(&rec, &self.state);
        }

        if let Some(reason) = early {
            self.set_stop(reason);
            return Ok(StepStatus::Iterated(rec));
        }
        if metrics_on && self.residual_stopping {
            if let Some(rule) = &self.cfg.stopping {
                // The absolute-tolerance floor scales with the stacked
                // constraint dimension: N·n dense, Σ_i |S_i| sharded
                // (identical for effectively-dense patterns, and the
                // products below make the dense call bit-identical to the
                // historical `satisfied(&r, n, n_workers)`).
                let (r, stacked_rows) = match self.shard.as_deref() {
                    None => (
                        super::stopping::residuals(&self.state, &self.prev_x0, self.cfg.rho),
                        n * n_workers,
                    ),
                    Some(p) => (
                        super::stopping::residuals_blocks(
                            &self.state,
                            &self.prev_x0,
                            self.cfg.rho,
                            p,
                        ),
                        (0..n_workers).map(|i| p.owned_len(i)).sum(),
                    ),
                };
                if k > 0 && rule.satisfied(&r, stacked_rows, 1) {
                    self.set_stop(StopReason::Residuals);
                    return Ok(StepStatus::Iterated(rec));
                }
            }
        }
        Ok(StepStatus::Iterated(rec))
    }

    /// Run at most `n` further iterations. Returns the stop reason if the
    /// run ended within the budget, `None` otherwise.
    pub fn run_for(&mut self, n: usize) -> Result<Option<StopReason>, EngineError> {
        for _ in 0..n {
            if let StepStatus::Done(reason) = self.step()? {
                return Ok(Some(reason));
            }
        }
        Ok(self.stop.clone())
    }

    /// Run until the session stops (early stop or iteration budget).
    pub fn run_to_completion(&mut self) -> Result<StopReason, EngineError> {
        loop {
            if let StepStatus::Done(reason) = self.step()? {
                return Ok(reason);
            }
        }
    }

    /// Serialize the full mid-run state. Supported by the trace-driven and
    /// virtual-time sources; the real-thread source has live OS-thread
    /// state and returns [`EngineError::CheckpointUnsupported`] (replay
    /// its realized trace through a trace-driven session instead).
    pub fn checkpoint(&mut self) -> Result<Checkpoint, EngineError> {
        // The source's per-worker snapshots exist only after start; taking
        // a k = 0 checkpoint before the first step must still capture them.
        self.ensure_started();
        // Lazy sparse master: fold every deferred prox application into x₀
        // first, so the serialized state is exactly the eager path's and a
        // dense-path resume (or vice versa) is bit-identical. The sparse
        // accumulators/stamps are derived state and are not serialized —
        // resume rebuilds them from the restored iterates.
        self.materialize_x0();
        let source_doc = self.source.save_checkpoint()?;
        let n_workers = self.state.xs.len();
        // v2: the block-sharding section (null for dense sessions — such
        // documents differ from v1 only by the version number and the
        // explicit null).
        let blocks_doc = match &self.shard {
            None => JsonValue::Null,
            Some(p) => JsonValue::Obj(vec![
                ("pattern".to_string(), p.to_json()),
                (
                    "updates".to_string(),
                    JsonValue::Arr(
                        self.block_updates.iter().map(|&u| JsonValue::Num(u as f64)).collect(),
                    ),
                ),
                (
                    // Serialized as ages (not stamps) so the v2 document
                    // layout predating the stamp compaction is unchanged.
                    "age".to_string(),
                    JsonValue::Arr(
                        self.block_ages().iter().map(|&a| JsonValue::Num(a as f64)).collect(),
                    ),
                ),
            ]),
        };
        let doc = JsonValue::Obj(vec![
            ("schema".to_string(), Checkpoint::SCHEMA.into()),
            ("version".to_string(), JsonValue::Num(Checkpoint::VERSION as f64)),
            ("blocks".to_string(), blocks_doc),
            // v3: the session's inexact policy; resume validates it so a
            // mid-inner-schedule warm state never continues under a
            // different policy.
            ("inexact_policy".to_string(), self.cfg.inexact.to_json()),
            // v4: the per-worker heterogeneous policy list (null =
            // uniform) and the multi-master section (null = the
            // single-master star). The per-master sparse states are
            // derived (rebuilt on resume from the materialized iterates);
            // the group map is the contract a resume must match, and the
            // update counters make the document auditable.
            (
                "inexact_workers".to_string(),
                match &self.inexact_workers {
                    None => JsonValue::Null,
                    Some(ws) => JsonValue::Arr(ws.iter().map(|p| p.to_json()).collect()),
                },
            ),
            (
                "masters".to_string(),
                match &self.masters {
                    None => JsonValue::Null,
                    Some(mm) => JsonValue::Obj(vec![
                        ("group".to_string(), mm.group.to_json()),
                        (
                            "per".to_string(),
                            JsonValue::Arr(
                                mm.per
                                    .iter()
                                    .map(|sp| {
                                        JsonValue::Obj(vec![(
                                            "updates".to_string(),
                                            JsonValue::Num(sp.view().updates as f64),
                                        )])
                                    })
                                    .collect(),
                            ),
                        ),
                    ]),
                },
            ),
            ("k".to_string(), JsonValue::Num(self.k as f64)),
            ("n_workers".to_string(), JsonValue::Num(n_workers as f64)),
            ("dim".to_string(), JsonValue::Num(self.state.x0.len() as f64)),
            ("stop".to_string(), stop_to_json(&self.stop)),
            ("source_kind".to_string(), self.source.kind().into()),
            (
                "state".to_string(),
                JsonValue::Obj(vec![
                    ("x0".to_string(), hex_vec(&self.state.x0)),
                    ("xs".to_string(), hex_mat(&self.state.xs)),
                    ("lams".to_string(), hex_mat(&self.state.lams)),
                ]),
            ),
            (
                "delays".to_string(),
                JsonValue::Arr(self.d.iter().map(|&v| JsonValue::Num(v as f64)).collect()),
            ),
            (
                "trace".to_string(),
                JsonValue::Arr(
                    self.trace
                        .sets
                        .iter()
                        .map(|set| {
                            JsonValue::Arr(
                                set.iter().map(|&i| JsonValue::Num(i as f64)).collect(),
                            )
                        })
                        .collect(),
                ),
            ),
            ("source".to_string(), source_doc),
            ("meta".to_string(), JsonValue::Obj(Vec::new())),
        ]);
        Ok(Checkpoint { doc })
    }

    fn restore_from(&mut self, cp: &Checkpoint) -> Result<(), EngineError> {
        let doc = cp.as_json();
        let n_workers = self.problem.num_workers();
        let dim = self.problem.dim();

        let cp_workers = get_usize(doc, "n_workers")?;
        if cp_workers != n_workers {
            return Err(EngineError::Checkpoint(format!(
                "checkpoint has {cp_workers} workers, the problem has {n_workers}"
            )));
        }
        let cp_dim = get_usize(doc, "dim")?;
        if cp_dim != dim {
            return Err(EngineError::Checkpoint(format!(
                "checkpoint has dimension {cp_dim}, the problem has {dim}"
            )));
        }
        let kind = jget(doc, "source_kind")?
            .as_str()
            .ok_or_else(|| EngineError::Checkpoint("source_kind is not a string".to_string()))?;
        if kind != self.source.kind() {
            return Err(EngineError::Checkpoint(format!(
                "checkpoint was taken from a {kind:?} source, resuming into {:?}",
                self.source.kind()
            )));
        }

        // Block-sharding compatibility: a v2+ checkpoint records the
        // pattern it was taken under (null = dense); a v1 checkpoint
        // predates sharding and is dense by definition. Either way the
        // session being resumed into must match.
        let version = get_usize(doc, "version")?;
        let blocks_doc = if version >= Checkpoint::V2 {
            Some(jget(doc, "blocks")?)
        } else {
            None // v1: no section, dense
        };

        // Inexact-policy compatibility: a v3+ checkpoint records the
        // policy its warm-start states were produced under; resuming
        // under a different policy would silently desynchronize the
        // inner-loop schedule. v1/v2 documents predate inexact solves and
        // only resume into exact-policy sessions.
        if version >= Checkpoint::V3 {
            let stored = InexactPolicy::from_json(jget(doc, "inexact_policy")?)
                .map_err(EngineError::Checkpoint)?;
            if stored != self.cfg.inexact {
                return Err(EngineError::Checkpoint(format!(
                    "checkpoint was taken under inexact policy {stored}, the session is \
                     configured with {}",
                    self.cfg.inexact
                )));
            }
        } else if !self.cfg.inexact.is_exact() {
            return Err(EngineError::Checkpoint(format!(
                "checkpoint version {version} predates inexact policies (exact-only), the \
                 session is configured with {}",
                self.cfg.inexact
            )));
        }

        // Per-worker heterogeneous policy compatibility (v4): same rule
        // as the uniform policy, entry by entry. Pre-v4 documents are
        // uniform by definition and only resume into uniform sessions.
        if version >= Checkpoint::VERSION {
            let stored = match jget(doc, "inexact_workers")? {
                JsonValue::Null => None,
                list => {
                    let mut ws = Vec::new();
                    for v in list.items() {
                        ws.push(
                            InexactPolicy::from_json(v).map_err(EngineError::Checkpoint)?,
                        );
                    }
                    Some(ws)
                }
            };
            if stored != self.inexact_workers {
                return Err(EngineError::Checkpoint(
                    "checkpoint per-worker inexact policies do not match the session's"
                        .to_string(),
                ));
            }
        } else if self.inexact_workers.is_some() {
            return Err(EngineError::Checkpoint(format!(
                "checkpoint version {version} predates per-worker inexact policies, the \
                 session is configured with a heterogeneous policy vector"
            )));
        }

        // Multi-master compatibility (v4): the group map recorded in the
        // document must equal the session's. Pre-v4 documents are
        // single-master (M = 1) by definition and load into (and only
        // into) sessions without a master group — the per-master sparse
        // states are derived and rebuilt below either way.
        if version >= Checkpoint::VERSION {
            match (jget(doc, "masters")?, &self.masters) {
                (JsonValue::Null, None) => {}
                (JsonValue::Null, Some(_)) => {
                    return Err(EngineError::Checkpoint(
                        "checkpoint was taken from a single-master run, resuming into a \
                         multi-master session"
                            .to_string(),
                    ));
                }
                (_, None) => {
                    return Err(EngineError::Checkpoint(
                        "checkpoint was taken from a multi-master run, resuming into a \
                         single-master session"
                            .to_string(),
                    ));
                }
                (md, Some(mm)) => {
                    let stored = MasterGroup::from_json(jget(md, "group")?)
                        .map_err(EngineError::Checkpoint)?;
                    if stored != *mm.group {
                        return Err(EngineError::Checkpoint(
                            "checkpoint master group does not match the session's".to_string(),
                        ));
                    }
                }
            }
        } else if self.masters.is_some() {
            return Err(EngineError::Checkpoint(format!(
                "checkpoint version {version} predates multi-master coordination (M = 1 \
                 only), the session is configured with a master group"
            )));
        }
        match (blocks_doc, &self.shard) {
            (None | Some(JsonValue::Null), None) => {}
            (None | Some(JsonValue::Null), Some(_)) => {
                return Err(EngineError::Checkpoint(
                    "checkpoint was taken from a dense run, resuming into a block-sharded \
                     session"
                        .to_string(),
                ));
            }
            (Some(bd), shard) => {
                let pattern = BlockPattern::from_json(jget(bd, "pattern")?)
                    .map_err(EngineError::Checkpoint)?;
                match shard {
                    Some(p) if **p == pattern => {}
                    _ => {
                        return Err(EngineError::Checkpoint(
                            "checkpoint block pattern does not match the session's".to_string(),
                        ));
                    }
                }
                let mut updates = Vec::new();
                for v in jget(bd, "updates")?.items() {
                    updates.push(json_usize(v).map_err(EngineError::Checkpoint)? as u64);
                }
                let mut age = Vec::new();
                for v in jget(bd, "age")?.items() {
                    age.push(json_usize(v).map_err(EngineError::Checkpoint)?);
                }
                if updates.len() != pattern.num_blocks() || age.len() != pattern.num_blocks() {
                    return Err(EngineError::Checkpoint(
                        "per-block counter length does not match the pattern".to_string(),
                    ));
                }
                self.block_updates = updates;
                // The document carries ages (historical v2 layout); the
                // session keeps last-arrival stamps: age = k − 1 − last,
                // with "never arrived" (age = k) mapping to −1.
                let k = get_usize(doc, "k")? as i64;
                self.block_last_arrival = age.iter().map(|&a| k - 1 - a as i64).collect();
            }
        }

        self.k = get_usize(doc, "k")?;
        self.stop = stop_from_json(jget(doc, "stop")?)?;

        let st = jget(doc, "state")?;
        let x0 = vec_from_hex(jget(st, "x0")?).map_err(EngineError::Checkpoint)?;
        let xs = mat_from_hex(jget(st, "xs")?).map_err(EngineError::Checkpoint)?;
        let lams = mat_from_hex(jget(st, "lams")?).map_err(EngineError::Checkpoint)?;
        // Per-worker expected lengths: owned-slice lengths when sharded,
        // the global dimension otherwise.
        let expect = |i: usize| match &self.shard {
            Some(p) => p.owned_len(i),
            None => dim,
        };
        if x0.len() != dim
            || xs.len() != n_workers
            || lams.len() != n_workers
            || xs.iter().enumerate().any(|(i, x)| x.len() != expect(i))
            || lams.iter().enumerate().any(|(i, l)| l.len() != expect(i))
        {
            return Err(EngineError::Checkpoint(
                "state dimensions do not match the problem".to_string(),
            ));
        }
        self.state = AdmmState { xs, x0, lams };

        let mut d = Vec::with_capacity(n_workers);
        for item in jget(doc, "delays")?.items() {
            d.push(json_usize(item).map_err(EngineError::Checkpoint)?);
        }
        if d.len() != n_workers {
            return Err(EngineError::Checkpoint(format!(
                "delay counters have length {}, expected {n_workers}",
                d.len()
            )));
        }
        self.d = d;

        let mut sets = Vec::new();
        for row in jget(doc, "trace")?.items() {
            let mut set = Vec::with_capacity(row.items().len());
            for v in row.items() {
                let i = json_usize(v).map_err(EngineError::Checkpoint)?;
                if i >= n_workers {
                    return Err(EngineError::Checkpoint(format!(
                        "trace worker index {i} out of range"
                    )));
                }
                set.push(i);
            }
            sets.push(set);
        }
        if sets.len() != self.k {
            return Err(EngineError::Checkpoint(format!(
                "trace has {} sets but the checkpoint is at iteration {}",
                sets.len(),
                self.k
            )));
        }
        self.trace = ArrivalTrace { sets };

        // f_i(x_i) is a pure function of the restored iterates: recomputing
        // reproduces the uninterrupted run's cache bit-for-bit.
        for i in 0..n_workers {
            self.f_cache[i] = self
                .problem
                .local(i)
                .eval_with(&self.state.xs[i], &mut self.scratch.ws);
        }
        self.prev_x0.copy_from_slice(&self.state.x0);

        self.source.load_checkpoint(jget(doc, "source")?)?;
        // The source's snapshots were restored, not initialized: starting
        // it again would overwrite them with the resumed state.
        self.source_started = true;
        // Rebuild the sparse accumulators from the restored iterates — the
        // same ascending-worker reduction as the eager path, so resuming a
        // dense-path checkpoint onto the sparse path (and vice versa) is
        // bit-identical.
        if let Some(sp) = &mut self.sparse {
            // ad-lint: allow(panic-free-lib): builder invariant: the sparse master is only constructed with a shard pattern
            let p = self.shard.clone().expect("sparse implies sharded");
            sp.rebuild(&p, &self.state, self.cfg.rho);
        }
        // Multi-master: every masked master rebuilds from the same
        // restored iterates. All update counters reset to 0 *together*,
        // and catch-up work is a function of counter differences only, so
        // the common shift preserves bit-identity (same argument as the
        // single-master rebuild, per master).
        if let Some(mm) = &mut self.masters {
            // ad-lint: allow(panic-free-lib): builder invariant: multi-master state is only constructed with a shard pattern
            let p = self.shard.clone().expect("masters implies sharded");
            for sp in &mut mm.per {
                sp.rebuild(&p, &self.state, self.cfg.rho);
            }
        }
        Ok(())
    }

    /// Fold every deferred sparse-master prox application into `x₀`
    /// (no-op on the eager path). [`Session::checkpoint`] and
    /// [`Session::finish`] call this; mid-run, [`Session::state`] may lag
    /// on blocks whose owners have not arrived recently when running with
    /// `metrics_every: 0`.
    fn materialize_x0(&mut self) {
        if let Some(sp) = &mut self.sparse {
            // ad-lint: allow(panic-free-lib): builder invariant: the sparse master is only constructed with a shard pattern
            let p = self.shard.clone().expect("sparse implies sharded");
            sp.materialize(self.problem, &mut self.state.x0, self.cfg.rho, self.cfg.gamma, &p);
        }
        if let Some(mm) = &mut self.masters {
            // ad-lint: allow(panic-free-lib): builder invariant: multi-master state is only constructed with a shard pattern
            let p = self.shard.clone().expect("masters implies sharded");
            for sp in &mut mm.per {
                sp.materialize(
                    self.problem,
                    &mut self.state.x0,
                    self.cfg.rho,
                    self.cfg.gamma,
                    &p,
                );
            }
        }
    }

    /// Consume the session, yielding its final artifacts and the source
    /// (by value — typed sessions can read execution stats back out).
    /// Materializes any deferred lazy-prox work first, so the returned
    /// `x₀` is always the fully-caught-up iterate.
    pub fn finish(mut self) -> (SessionOutcome, S) {
        self.materialize_x0();
        let outcome = SessionOutcome {
            state: self.state,
            trace: self.trace,
            stop: self.stop.unwrap_or(StopReason::MaxIters),
            final_delays: self.d,
            iterations: self.k,
        };
        (outcome, self.source)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::LassoInstance;
    use crate::rng::Pcg64;

    fn lasso(seed: u64, n_workers: usize) -> ConsensusProblem {
        let mut rng = Pcg64::seed_from_u64(seed);
        LassoInstance::synthetic(&mut rng, n_workers, 15, 6, 0.2, 0.1).problem()
    }

    #[test]
    fn stop_reason_serialization_roundtrips() {
        for stop in [
            None,
            Some(StopReason::MaxIters),
            Some(StopReason::X0Tolerance),
            Some(StopReason::Residuals),
            Some(StopReason::Diverged),
        ] {
            assert_eq!(stop_from_json(&stop_to_json(&stop)).unwrap(), stop);
        }
        assert!(stop_from_json(&JsonValue::Str("bogus".into())).is_err());
    }

    #[test]
    fn builder_defaults_run_synchronously() {
        let p = lasso(11, 3);
        let mut session = Session::builder()
            .problem(&p)
            .config(AdmmConfig { rho: 30.0, max_iters: 10, ..Default::default() })
            .build()
            .unwrap();
        let stop = session.run_to_completion().unwrap();
        assert_eq!(stop, StopReason::MaxIters);
        assert_eq!(session.iteration(), 10);
        // default source = Full arrivals: everyone arrives every iteration
        assert!(session.trace().sets.iter().all(|s| s.len() == 3));
    }

    #[test]
    fn step_after_done_is_idempotent() {
        let p = lasso(12, 2);
        let mut session = Session::builder()
            .problem(&p)
            .config(AdmmConfig { rho: 20.0, max_iters: 3, ..Default::default() })
            .build()
            .unwrap();
        assert!(matches!(session.step().unwrap(), StepStatus::Iterated(_)));
        session.run_to_completion().unwrap();
        assert!(matches!(session.step().unwrap(), StepStatus::Done(StopReason::MaxIters)));
        assert!(matches!(session.step().unwrap(), StepStatus::Done(StopReason::MaxIters)));
        assert_eq!(session.iteration(), 3);
    }

    #[test]
    fn observers_fire_in_order_and_exactly_once() {
        use std::cell::RefCell;
        use std::rc::Rc;

        #[derive(Default)]
        struct Log {
            events: Rc<RefCell<Vec<&'static str>>>,
        }
        impl Observer for Log {
            fn on_start(&mut self, _s: &AdmmState) {
                self.events.borrow_mut().push("start");
            }
            fn on_iteration(&mut self, _r: &IterRecord, _s: &AdmmState) {
                self.events.borrow_mut().push("iter");
            }
            fn on_stop(&mut self, _stop: &StopReason, _s: &AdmmState) {
                self.events.borrow_mut().push("stop");
            }
        }

        let events = Rc::new(RefCell::new(Vec::new()));
        let p = lasso(13, 2);
        let mut session = Session::builder()
            .problem(&p)
            .config(AdmmConfig { rho: 20.0, max_iters: 2, ..Default::default() })
            .observer(Log { events: Rc::clone(&events) })
            .build()
            .unwrap();
        session.run_to_completion().unwrap();
        // stepping again must not re-fire on_stop
        session.step().unwrap();
        assert_eq!(*events.borrow(), vec!["start", "iter", "iter", "stop"]);
    }

    #[test]
    fn checkpoint_envelope_is_validated() {
        assert!(Checkpoint::from_json_str("").is_err());
        assert!(Checkpoint::from_json_str("{}").is_err());
        assert!(Checkpoint::from_json_str(r#"{"schema": "other"}"#).is_err());
        let wrong_version = format!(
            r#"{{"schema": "{}", "version": 99}}"#,
            Checkpoint::SCHEMA
        );
        assert!(Checkpoint::from_json_str(&wrong_version).is_err());
    }

    #[test]
    fn checkpoint_meta_set_and_read_back() {
        let p = lasso(14, 2);
        let mut session = Session::builder()
            .problem(&p)
            .config(AdmmConfig { rho: 20.0, max_iters: 4, ..Default::default() })
            .build()
            .unwrap();
        session.run_for(2).unwrap();
        let mut cp = session.checkpoint().unwrap();
        cp.set_meta("cli", JsonValue::Obj(vec![("workers".to_string(), JsonValue::Num(2.0))]));
        cp.set_meta("label", "first".into());
        cp.set_meta("label", "second".into());
        let round = Checkpoint::from_json_str(&cp.to_json_string()).unwrap();
        assert_eq!(round.iteration(), 2);
        assert_eq!(round.n_workers(), 2);
        assert_eq!(round.source_kind(), "trace");
        assert_eq!(round.meta("label").and_then(JsonValue::as_str), Some("second"));
        assert_eq!(
            round.meta("cli").and_then(|c| c.get("workers")).and_then(JsonValue::as_f64),
            Some(2.0)
        );
    }

    #[test]
    fn engine_error_display_is_informative() {
        let errs = [
            EngineError::MissingProblem,
            EngineError::InvalidRho(-1.0),
            EngineError::InvalidTau(0),
            EngineError::InvalidMinArrivals { min_arrivals: 9, n_workers: 4 },
            EngineError::InitDimMismatch { got: 3, dim: 5 },
            EngineError::WorkerCountMismatch { source: 2, problem: 4 },
            EngineError::MasterFirstUnsupported { source: "virtual" },
            EngineError::CheckpointUnsupported { source: "threaded" },
            EngineError::Checkpoint("bad".to_string()),
            EngineError::Block(BlockError::Gap { at: 3 }),
            EngineError::ShardingUnsupported { source: "custom" },
            EngineError::ActiveSetOutOfRange { index: 7, n_workers: 4 },
            EngineError::Cluster("drop_prob must be in [0, 1)".to_string()),
            EngineError::InvalidInexact("inner step count must be >= 1".to_string()),
            EngineError::Masters("master 1 owns no blocks".to_string()),
        ];
        for e in errs {
            let text = e.to_string();
            assert!(!text.is_empty());
            let _: &dyn std::error::Error = &e;
        }
    }
}
