//! Algorithm 1: the synchronous distributed ADMM baseline (Boyd et al.),
//! implemented exactly in the paper's order — master `x₀` update (6) first,
//! then all worker `x_i` (7) and dual (8) updates.
//!
//! Used as (i) the baseline every asynchronous run is compared against and
//! (ii) the generator of the reference value `F̂` for the Fig. 3 accuracy
//! definition (51) (10 000 synchronous iterations).

use crate::problems::ConsensusProblem;

use super::arrivals::ArrivalModel;
use super::engine::{run_engine, EngineOptions, FullBarrier, TraceSource};
use super::master_pov::{NativeSolver, SubproblemSolver};
use super::{AdmmConfig, AdmmState, IterRecord, StopReason};

/// Result of a synchronous run.
pub struct SyncOutput {
    pub state: AdmmState,
    pub history: Vec<IterRecord>,
    pub stop: StopReason,
}

/// Run Algorithm 1 for `cfg.max_iters` iterations (τ/min_arrivals ignored;
/// γ enters the x₀ step only if nonzero, matching (12) with τ = 1 where the
/// proximal term is unnecessary but harmless).
///
/// Like every other coordinator this honours `cfg.objective_every`
/// (records hold NaN on skipped iterations; historically the sync baseline
/// evaluated the objective unconditionally) — callers that read
/// `history.last().objective` must leave `objective_every` at its default
/// of 1.
///
/// Deprecated: build a [`crate::admm::session::Session`] with the
/// [`FullBarrier`] policy instead (typed errors, streaming observers,
/// step/checkpoint/resume).
#[deprecated(note = "use Session::builder()")]
pub fn run_sync_admm(problem: &ConsensusProblem, cfg: &AdmmConfig) -> SyncOutput {
    let mut solver = NativeSolver::new(problem);
    run_sync_admm_with_solver(problem, cfg, &mut solver)
}

/// Thin wrapper over the unified engine: the [`FullBarrier`] policy
/// (master-first order, everyone forced every iteration) driven by the
/// in-process [`TraceSource`] with the full arrival model.
#[deprecated(note = "use Session::builder()")]
pub fn run_sync_admm_with_solver(
    problem: &ConsensusProblem,
    cfg: &AdmmConfig,
    solver: &mut dyn SubproblemSolver,
) -> SyncOutput {
    let mut source = TraceSource::with_solver(problem.num_workers(), &ArrivalModel::Full, solver);
    let run = run_engine(problem, cfg, &FullBarrier, &mut source, &EngineOptions::default());
    SyncOutput { state: run.state, history: run.history, stop: run.stop }
}

#[cfg(test)]
#[allow(deprecated)] // the deprecated wrappers stay pinned by these tests
mod tests {
    use super::*;
    use crate::admm::arrivals::ArrivalModel;
    use crate::admm::kkt::kkt_residual;
    use crate::admm::master_pov::run_master_pov;
    use crate::data::LassoInstance;
    use crate::linalg::vecops;
    use crate::rng::Pcg64;

    fn small_lasso(seed: u64) -> ConsensusProblem {
        let mut rng = Pcg64::seed_from_u64(seed);
        LassoInstance::synthetic(&mut rng, 3, 25, 12, 0.2, 0.1).problem()
    }

    #[test]
    fn converges_to_kkt() {
        let p = small_lasso(81);
        let cfg = AdmmConfig { rho: 40.0, max_iters: 800, ..Default::default() };
        let out = run_sync_admm(&p, &cfg);
        let r = kkt_residual(&p, &out.state);
        assert!(r.max() < 1e-6, "{r:?}");
    }

    #[test]
    fn matches_async_with_tau_one_at_the_limit() {
        // Algorithm 1 and Algorithm 2 (τ=1) differ only in update order
        // (footnote 8), so their limits coincide.
        let p = small_lasso(82);
        let cfg = AdmmConfig { rho: 40.0, tau: 1, max_iters: 1500, ..Default::default() };
        let sync = run_sync_admm(&p, &cfg);
        let asyn = run_master_pov(&p, &cfg, &ArrivalModel::Full);
        assert!(
            vecops::dist2(&sync.state.x0, &asyn.state.x0) < 1e-6,
            "limits differ: {}",
            vecops::dist2(&sync.state.x0, &asyn.state.x0)
        );
    }

    #[test]
    fn objective_decreases_overall() {
        let p = small_lasso(83);
        let cfg = AdmmConfig { rho: 40.0, max_iters: 300, ..Default::default() };
        let out = run_sync_admm(&p, &cfg);
        let first = out.history.first().unwrap().objective;
        let last = out.history.last().unwrap().objective;
        assert!(last < first, "first={first} last={last}");
    }

    #[test]
    fn aug_lagrangian_monotone_after_warmup_for_large_rho() {
        // Lemma 1 with τ=1 (no async error terms) + ρ large ⇒ descent.
        let p = small_lasso(84);
        let cfg = AdmmConfig { rho: 200.0, max_iters: 100, ..Default::default() };
        let out = run_sync_admm(&p, &cfg);
        for w in out.history.windows(2).skip(2) {
            assert!(
                w[1].aug_lagrangian <= w[0].aug_lagrangian + 1e-7,
                "ascent at k={}",
                w[1].k
            );
        }
    }
}
