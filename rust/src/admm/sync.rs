//! Algorithm 1: the synchronous distributed ADMM baseline (Boyd et al.),
//! implemented exactly in the paper's order — master `x₀` update (6) first,
//! then all worker `x_i` (7) and dual (8) updates.
//!
//! Used as (i) the baseline every asynchronous run is compared against and
//! (ii) the generator of the reference value `F̂` for the Fig. 3 accuracy
//! definition (51) (10 000 synchronous iterations).

use crate::problems::ConsensusProblem;

use super::master_pov::{NativeSolver, SubproblemSolver};
use super::{
    divergence_or_tol_stop, iter_record, master_x0_update, AdmmConfig, AdmmState, IterRecord,
    MasterScratch, StopReason,
};

/// Result of a synchronous run.
pub struct SyncOutput {
    pub state: AdmmState,
    pub history: Vec<IterRecord>,
    pub stop: StopReason,
}

/// Run Algorithm 1 for `cfg.max_iters` iterations (τ/min_arrivals ignored;
/// γ enters the x₀ step only if nonzero, matching (12) with τ = 1 where the
/// proximal term is unnecessary but harmless).
///
/// Like every other coordinator this honours `cfg.objective_every`
/// (records hold NaN on skipped iterations; historically the sync baseline
/// evaluated the objective unconditionally) — callers that read
/// `history.last().objective` must leave `objective_every` at its default
/// of 1.
pub fn run_sync_admm(problem: &ConsensusProblem, cfg: &AdmmConfig) -> SyncOutput {
    let mut solver = NativeSolver::new(problem);
    run_sync_admm_with_solver(problem, cfg, &mut solver)
}

pub fn run_sync_admm_with_solver(
    problem: &ConsensusProblem,
    cfg: &AdmmConfig,
    solver: &mut dyn SubproblemSolver,
) -> SyncOutput {
    let n_workers = problem.num_workers();
    let n = problem.dim();
    let mut state = cfg.initial_state(n_workers, n);
    let mut history = Vec::with_capacity(cfg.max_iters);
    let mut prev_x0 = state.x0.clone();
    let mut x0 = state.x0.clone();
    let mut stop = StopReason::MaxIters;
    let mut scratch = MasterScratch::new();
    let mut f_cache = vec![0.0; n_workers];

    for k in 0..cfg.max_iters {
        // (6): master x₀ update from current (xᵏ, λᵏ).
        prev_x0.copy_from_slice(&state.x0);
        master_x0_update(problem, &mut state, cfg.rho, cfg.gamma, &mut scratch);

        // (7)+(8): every worker, against the fresh x₀^{k+1}.
        x0.copy_from_slice(&state.x0);
        for i in 0..n_workers {
            solver.solve(i, &state.lams[i], &x0, cfg.rho, &mut state.xs[i]);
            for j in 0..n {
                state.lams[i][j] += cfg.rho * (state.xs[i][j] - x0[j]);
            }
            f_cache[i] = problem.local(i).eval_with(&state.xs[i], &mut scratch.ws);
        }

        let rec =
            iter_record(problem, &state, cfg, k, n_workers, &f_cache, &mut scratch, &prev_x0);
        let early = divergence_or_tol_stop(cfg, &state, &rec, k);
        history.push(rec);
        if let Some(reason) = early {
            stop = reason;
            break;
        }
        if let Some(rule) = &cfg.stopping {
            let r = super::stopping::residuals(&state, &prev_x0, cfg.rho);
            if k > 0 && rule.satisfied(&r, n, n_workers) {
                stop = StopReason::Residuals;
                break;
            }
        }
    }
    SyncOutput { state, history, stop }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::admm::arrivals::ArrivalModel;
    use crate::admm::kkt::kkt_residual;
    use crate::admm::master_pov::run_master_pov;
    use crate::data::LassoInstance;
    use crate::linalg::vecops;
    use crate::rng::Pcg64;

    fn small_lasso(seed: u64) -> ConsensusProblem {
        let mut rng = Pcg64::seed_from_u64(seed);
        LassoInstance::synthetic(&mut rng, 3, 25, 12, 0.2, 0.1).problem()
    }

    #[test]
    fn converges_to_kkt() {
        let p = small_lasso(81);
        let cfg = AdmmConfig { rho: 40.0, max_iters: 800, ..Default::default() };
        let out = run_sync_admm(&p, &cfg);
        let r = kkt_residual(&p, &out.state);
        assert!(r.max() < 1e-6, "{r:?}");
    }

    #[test]
    fn matches_async_with_tau_one_at_the_limit() {
        // Algorithm 1 and Algorithm 2 (τ=1) differ only in update order
        // (footnote 8), so their limits coincide.
        let p = small_lasso(82);
        let cfg = AdmmConfig { rho: 40.0, tau: 1, max_iters: 1500, ..Default::default() };
        let sync = run_sync_admm(&p, &cfg);
        let asyn = run_master_pov(&p, &cfg, &ArrivalModel::Full);
        assert!(
            vecops::dist2(&sync.state.x0, &asyn.state.x0) < 1e-6,
            "limits differ: {}",
            vecops::dist2(&sync.state.x0, &asyn.state.x0)
        );
    }

    #[test]
    fn objective_decreases_overall() {
        let p = small_lasso(83);
        let cfg = AdmmConfig { rho: 40.0, max_iters: 300, ..Default::default() };
        let out = run_sync_admm(&p, &cfg);
        let first = out.history.first().unwrap().objective;
        let last = out.history.last().unwrap().objective;
        assert!(last < first, "first={first} last={last}");
    }

    #[test]
    fn aug_lagrangian_monotone_after_warmup_for_large_rho() {
        // Lemma 1 with τ=1 (no async error terms) + ρ large ⇒ descent.
        let p = small_lasso(84);
        let cfg = AdmmConfig { rho: 200.0, max_iters: 100, ..Default::default() };
        let out = run_sync_admm(&p, &cfg);
        for w in out.history.windows(2).skip(2) {
            assert!(
                w[1].aug_lagrangian <= w[0].aug_lagrangian + 1e-7,
                "ascent at k={}",
                w[1].k
            );
        }
    }
}
