//! KKT residuals for problem (4), conditions (34a)–(34c).
//!
//! Theorem 1 guarantees limit points satisfy
//! `∇f_i(x_i*) + λ_i* = 0`, `s₀* − Σλ_i* = 0 (s₀* ∈ ∂h(x₀*))` and
//! `x_i* = x₀*`; the integration tests drive these residuals to ~0.

use crate::linalg::vecops;
use crate::problems::ConsensusProblem;

use super::AdmmState;

/// The three KKT residual groups.
#[derive(Clone, Debug)]
pub struct KktResidual {
    /// `max_i ‖∇f_i(x_i) + λ_i‖∞` — dual feasibility per worker (34a).
    pub dual: f64,
    /// distance of `Σλ_i` to `∂h(x₀)` (∞-norm) — master stationarity (34b).
    pub stationarity: f64,
    /// `max_i ‖x_i − x₀‖∞` — primal consensus (34c).
    pub consensus: f64,
}

impl KktResidual {
    pub fn max(&self) -> f64 {
        self.dual.max(self.stationarity).max(self.consensus)
    }
}

/// Evaluate all KKT residuals at the given state.
///
/// Block-sharded problems ([`ConsensusProblem::pattern`]) use the
/// general-form conditions: per worker-block dual feasibility
/// `∇f_i(x_i) + λ_i = 0` over the owned slice, consensus
/// `x_i = (x₀)_{S_i}`, and master stationarity
/// `Σ_{i∋j} λ_{i,j} ∈ ∂h(x₀)_j` — coordinate `j` sums only its owners'
/// duals.
///
/// Reads every coordinate of `state.x0`, so the state must be
/// **materialized**: under the lazy sparse master
/// ([`super::SparseMaster`]) stale blocks lag until caught up.
/// States obtained from [`super::session::Session::finish`] or a
/// checkpoint are always materialized; [`super::session::Session::state`]
/// mid-run may not be when running with `metrics_every: 0`.
pub fn kkt_residual(problem: &ConsensusProblem, state: &AdmmState) -> KktResidual {
    let n = state.x0.len();
    let mut dual: f64 = 0.0;
    let mut consensus: f64 = 0.0;
    let mut lam_sum = vec![0.0; n];
    match problem.pattern() {
        None => {
            let mut grad = vec![0.0; n];
            for (i, local) in problem.locals().iter().enumerate() {
                local.grad_into(&state.xs[i], &mut grad);
                for j in 0..n {
                    dual = dual.max((grad[j] + state.lams[i][j]).abs());
                    consensus = consensus.max((state.xs[i][j] - state.x0[j]).abs());
                    lam_sum[j] += state.lams[i][j];
                }
            }
        }
        Some(p) => {
            let mut grad: Vec<f64> = Vec::new();
            for (i, local) in problem.locals().iter().enumerate() {
                grad.resize(local.dim(), 0.0);
                local.grad_into(&state.xs[i], &mut grad);
                let xi = &state.xs[i];
                let li = &state.lams[i];
                let gref = &grad;
                p.for_each_range(i, |lo, g, len| {
                    for k in 0..len {
                        dual = dual.max((gref[lo + k] + li[lo + k]).abs());
                        consensus = consensus.max((xi[lo + k] - state.x0[g + k]).abs());
                        lam_sum[g + k] += li[lo + k];
                    }
                });
            }
        }
    }
    let stationarity = problem.regularizer().subdiff_dist(&state.x0, &lam_sum);
    KktResidual { dual, stationarity, consensus }
}

/// Check the per-worker dual identity (29): after every master iteration of
/// Algorithm 2/3, `∇f_i(x_i^{k+1}) + λ_i^{k+1} = 0` for **all** workers
/// (arrived or not) — over each worker's owned slice when sharded.
/// Returns the worst violation; property tests assert ≈ 0.
pub fn dual_identity_residual(problem: &ConsensusProblem, state: &AdmmState) -> f64 {
    let mut grad: Vec<f64> = Vec::new();
    let mut worst: f64 = 0.0;
    for (i, local) in problem.locals().iter().enumerate() {
        grad.resize(local.dim(), 0.0);
        local.grad_into(&state.xs[i], &mut grad);
        vecops::axpy(1.0, &state.lams[i], &mut grad);
        worst = worst.max(vecops::nrm_inf(&grad));
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::QuadraticLocal;
    use crate::prox::Regularizer;
    use std::sync::Arc;

    #[test]
    fn exact_kkt_point_has_zero_residual() {
        // f1 = ½(x−1)², f2 = ½(x+1)², h = 0: minimizer x* = 0,
        // λ_i* = −∇f_i(0) → λ1 = 1·(0−1)·(−1) = 1? compute: ∇f1(0) = −1 →
        // λ1 = 1; ∇f2(0) = 1 → λ2 = −1; Σλ = 0. ✓
        let l1 = Arc::new(QuadraticLocal::diagonal(&[1.0], vec![-1.0]));
        let l2 = Arc::new(QuadraticLocal::diagonal(&[1.0], vec![1.0]));
        let p = ConsensusProblem::new(vec![l1, l2], Regularizer::Zero);
        let mut s = AdmmState::zeros(2, 1);
        s.lams[0] = vec![1.0];
        s.lams[1] = vec![-1.0];
        let r = kkt_residual(&p, &s);
        assert!(r.max() < 1e-12, "{r:?}");
        assert!(dual_identity_residual(&p, &s) < 1e-12);
    }

    #[test]
    fn analytic_quadratic_stationary_point_has_zero_residual() {
        // N diagonal quadratic workers f_i(x) = ½ xᵀD_i x + q_iᵀx with
        // h = 0: the stationary point solves (Σ D_i) x* = −Σ q_i
        // componentwise, and λ_i* = −∇f_i(x*) = −(D_i x* + q_i) sums to
        // zero by construction. Every KKT residual must vanish exactly
        // (up to f64 rounding) at that analytically derived point.
        let diags = [vec![1.0, 2.0, 0.5, 4.0], vec![3.0, 1.0, 2.0, 0.25], vec![0.5, 0.5, 1.5, 2.0]];
        let qs = [
            vec![1.0, -2.0, 0.5, 1.0],
            vec![-0.5, 1.0, -1.5, 2.0],
            vec![0.25, 0.5, 1.0, -3.0],
        ];
        let n = 4;
        let locals: Vec<Arc<dyn crate::problems::LocalCost>> = diags
            .iter()
            .zip(&qs)
            .map(|(d, q)| {
                Arc::new(QuadraticLocal::diagonal(d, q.clone()))
                    as Arc<dyn crate::problems::LocalCost>
            })
            .collect();
        let p = ConsensusProblem::new(locals, Regularizer::Zero);

        let mut x_star = vec![0.0; n];
        for j in 0..n {
            let d_sum: f64 = diags.iter().map(|d| d[j]).sum();
            let q_sum: f64 = qs.iter().map(|q| q[j]).sum();
            x_star[j] = -q_sum / d_sum;
        }
        let mut s = AdmmState::init(3, x_star.clone());
        for (i, (d, q)) in diags.iter().zip(&qs).enumerate() {
            for j in 0..n {
                s.lams[i][j] = -(d[j] * x_star[j] + q[j]);
            }
        }
        let r = kkt_residual(&p, &s);
        assert!(r.max() < 1e-12, "{r:?}");
        assert!(dual_identity_residual(&p, &s) < 1e-12);

        // Perturbing x₀ off the stationary point must surface in the
        // consensus residual and ONLY there (x_i and λ_i untouched).
        let mut off = s.clone();
        off.x0[0] += 1e-3;
        let r_off = kkt_residual(&p, &off);
        assert!(r_off.consensus >= 1e-3 - 1e-12);
        assert!(r_off.dual < 1e-12);
    }

    #[test]
    fn l1_stationary_point_uses_subdifferential() {
        // h(x) = θ‖x‖₁ with x* = 0: stationarity needs Σλ_i ∈ [−θ, θ]
        // componentwise. λ_i = −q_i keeps the dual identity exact; the
        // residual must be 0 inside the subdifferential and the exact
        // excess outside it.
        let mk = |q1: f64, q2: f64, theta: f64| {
            let l1 = Arc::new(QuadraticLocal::diagonal(&[1.0], vec![q1]));
            let l2 = Arc::new(QuadraticLocal::diagonal(&[1.0], vec![q2]));
            let p = ConsensusProblem::new(vec![l1, l2], Regularizer::L1 { theta });
            let mut s = AdmmState::zeros(2, 1);
            s.lams[0] = vec![-q1];
            s.lams[1] = vec![-q2];
            kkt_residual(&p, &s)
        };
        // Σλ = −0.7 with θ = 1: inside the subdifferential at 0 → exact KKT.
        let r = mk(0.3, 0.4, 1.0);
        assert!(r.max() < 1e-12, "{r:?}");
        // Σλ = −1.5 with θ = 1: 0.5 outside → stationarity reports exactly that.
        let r = mk(0.7, 0.8, 1.0);
        assert!((r.stationarity - 0.5).abs() < 1e-12, "{r:?}");
        assert!(r.dual < 1e-12 && r.consensus < 1e-12);
    }

    #[test]
    fn violations_are_reported() {
        let l1 = Arc::new(QuadraticLocal::diagonal(&[1.0], vec![0.0]));
        let p = ConsensusProblem::new(vec![l1], Regularizer::Zero);
        let mut s = AdmmState::zeros(1, 1);
        s.xs[0] = vec![2.0]; // ∇f(2) = 2, λ = 0 → dual 2; consensus 2
        let r = kkt_residual(&p, &s);
        assert!((r.dual - 2.0).abs() < 1e-12);
        assert!((r.consensus - 2.0).abs() < 1e-12);
    }
}
