//! The Theorem-1/Corollary-1 parameter rules.
//!
//! - (16): non-convex ρ rule:
//!   `ρ > [(1+L+L²) + √((1+L+L²)² + 8L²)] / 2`
//! - (18): convex ρ rule:
//!   `ρ ≥ [(1+L²) + √((1+L²)² + 8L²)] / 2`
//! - (17): γ rule: `γ > [S(1+ρ²)(τ−1)² − Nρ] / 2`
//!   where `S` bounds `|A_k|` and `τ` is the maximum delay.
//!
//! These are *worst-case* sufficient conditions; the paper's own experiments
//! run γ = 0 and problem-scaled ρ. The ablation bench contrasts both.

/// RHS of (16): minimal ρ for non-convex `f_i` with Lipschitz constant `L`.
pub fn rho_lower_bound_nonconvex(l: f64) -> f64 {
    assert!(l >= 0.0);
    let a = 1.0 + l + l * l;
    (a + (a * a + 8.0 * l * l).sqrt()) / 2.0
}

/// RHS of (18): minimal ρ when all `f_i` are convex.
pub fn rho_lower_bound_convex(l: f64) -> f64 {
    assert!(l >= 0.0);
    let a = 1.0 + l * l;
    (a + (a * a + 8.0 * l * l).sqrt()) / 2.0
}

/// RHS of (17): minimal γ given the arrival bound `S ∈ [1, N]`, penalty ρ,
/// max delay τ and worker count `N`. Negative values mean the proximal term
/// can be dropped (e.g. τ = 1 gives `−Nρ/2`).
pub fn gamma_lower_bound(s: f64, rho: f64, tau: usize, n_workers: usize) -> f64 {
    assert!(tau >= 1);
    assert!((1.0..=n_workers as f64).contains(&s), "S must be in [1, N]");
    let t = (tau - 1) as f64;
    (s * (1.0 + rho * rho) * t * t - n_workers as f64 * rho) / 2.0
}

/// Theorem-2 ρ *upper* bound for Algorithm 4 (eq. (48)):
/// `ρ ≤ σ² / [(5τ−3)·max(2τ, 3(τ−1))]` — note it shrinks with τ, the
/// opposite direction of Theorem 1. `sigma_sq` is the strong-convexity
/// modulus of the `f_i`.
pub fn alt_scheme_rho_upper_bound(sigma_sq: f64, tau: usize) -> f64 {
    assert!(tau >= 1);
    assert!(sigma_sq > 0.0);
    let t = tau as f64;
    sigma_sq / ((5.0 * t - 3.0) * (2.0 * t).max(3.0 * (t - 1.0)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nonconvex_rule_exceeds_convex_rule() {
        for l in [0.1, 1.0, 10.0, 100.0] {
            assert!(rho_lower_bound_nonconvex(l) > rho_lower_bound_convex(l));
        }
    }

    #[test]
    fn rho_rules_exceed_l() {
        // The analysis needs ρ ≥ L; the closed forms must imply it.
        for l in [0.0, 0.5, 2.0, 50.0] {
            assert!(rho_lower_bound_nonconvex(l) >= l);
            assert!(rho_lower_bound_convex(l) >= l);
        }
    }

    #[test]
    fn rho_rule_l_zero() {
        // L = 0: (16) gives (1 + 1)/2 = 1.
        assert!((rho_lower_bound_nonconvex(0.0) - 1.0).abs() < 1e-12);
        assert!((rho_lower_bound_convex(0.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rho_satisfies_its_own_quadratic() {
        // (16) is the positive root of ρ² − (1+L+L²)ρ − 2L² = 0.
        for l in [0.3, 1.0, 7.0] {
            let rho = rho_lower_bound_nonconvex(l);
            let q = rho * rho - (1.0 + l + l * l) * rho - 2.0 * l * l;
            assert!(q.abs() < 1e-8 * rho * rho, "q={q}");
        }
    }

    #[test]
    fn gamma_synchronous_is_negative() {
        // τ = 1 → γ_min = −Nρ/2 < 0: the proximal term can be removed.
        let g = gamma_lower_bound(4.0, 2.0, 1, 8);
        assert!((g - (-8.0)).abs() < 1e-12);
    }

    #[test]
    fn gamma_grows_quadratically_with_tau() {
        let n = 16;
        let g2 = gamma_lower_bound(8.0, 10.0, 2, n);
        let g3 = gamma_lower_bound(8.0, 10.0, 3, n);
        let g5 = gamma_lower_bound(8.0, 10.0, 5, n);
        assert!(g3 > g2);
        // leading term ∝ (τ−1)²: (g5+Nρ/2)/(g3+Nρ/2) = 16/4 = 4
        let shift = 16.0 * 10.0 / 2.0;
        let ratio = (g5 + shift) / (g3 + shift);
        assert!((ratio - 4.0).abs() < 1e-9, "ratio={ratio}");
    }

    #[test]
    fn gamma_increases_with_s() {
        let a = gamma_lower_bound(1.0, 5.0, 4, 8);
        let b = gamma_lower_bound(8.0, 5.0, 4, 8);
        assert!(b > a);
    }

    #[test]
    fn alt_scheme_bound_shrinks_with_tau() {
        let r1 = alt_scheme_rho_upper_bound(1.0, 1);
        let r3 = alt_scheme_rho_upper_bound(1.0, 3);
        let r10 = alt_scheme_rho_upper_bound(1.0, 10);
        assert!(r1 > r3 && r3 > r10);
        // τ=1: σ²/(2·2) = 0.25
        assert!((r1 - 0.25).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "S must be in")]
    fn gamma_rejects_bad_s() {
        gamma_lower_bound(0.5, 1.0, 2, 4);
    }
}
