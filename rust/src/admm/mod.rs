//! The ADMM core: shared state, the augmented Lagrangian (5)/(26), KKT
//! residuals (34), and the four algorithm variants of the paper:
//!
//! - [`sync`]        — Algorithm 1, the synchronous baseline.
//! - [`master_pov`]  — Algorithm 3 = Algorithm 2 from the master's point of
//!   view; the serial simulator the paper's own figures were produced with.
//! - [`alt_scheme`]  — Algorithm 4, the cautionary alternative (master owns
//!   the duals) that needs strong convexity + small ρ (Theorem 2).
//! - [`arrivals`]    — arrival-set models implementing the partially
//!   asynchronous protocol (Assumption 1 + the `|A_k| ≥ A` gate).
//! - [`params`]      — the Theorem-1 parameter rules (16)–(18).
//! - [`engine`]      — the unified iteration engine all of the above (and
//!   both cluster execution modes) are thin wrappers over: one
//!   collect/update/record loop parameterized by an
//!   [`engine::UpdatePolicy`] and a [`engine::WorkerSource`], plus the
//!   deterministic fault-injection seam ([`engine::FaultPlan`]).
//! - [`session`]     — the public face over the engine: the typed
//!   [`session::Session`] builder (build-time validation, no panics on
//!   user input), incremental `step()` execution, streaming
//!   [`session::Observer`]s, and bit-identical
//!   [`session::Checkpoint`]/resume. The free-function drivers above are
//!   deprecated thin wrappers kept for compatibility.

pub mod alt_scheme;
pub mod arrivals;
pub mod engine;
pub mod kkt;
pub mod master_pov;
pub mod params;
pub mod session;
pub mod stopping;
pub mod sync;

use crate::linalg::vecops;
use crate::problems::{BlockPattern, ConsensusProblem, WorkerScratch};
use crate::prox::Regularizer;
use crate::solvers::inexact::InexactPolicy;

/// Master-side reusable buffers for the per-iteration hot path — the
/// counterpart of [`WorkerScratch`]. One instance is owned by each
/// engine run (whatever the worker source) and threaded through
/// [`master_x0_update`] and the per-iteration record assembly, so the
/// steady-state master iteration performs no heap allocation.
#[derive(Debug, Default)]
pub struct MasterScratch {
    /// Prox-assembly buffer `v` of the master update (12)/(25).
    pub v: Vec<f64>,
    /// Difference buffer of the cached augmented Lagrangian (26).
    pub al: Vec<f64>,
    /// Per-coordinate prox weights `1/(N_j ρ + γ)` of the block-sharded
    /// master update (unused on the dense path).
    pub wd: Vec<f64>,
    /// Scratch for master-side `f_i` / objective evaluations.
    pub ws: WorkerScratch,
}

impl MasterScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Algorithm parameters shared by all variants.
#[derive(Clone, Debug)]
pub struct AdmmConfig {
    /// Penalty parameter ρ of the augmented Lagrangian (5).
    pub rho: f64,
    /// Proximal weight γ of the master update (12). The paper's experiments
    /// use γ = 0; Theorem 1 gives the worst-case safe value
    /// ([`params::gamma_lower_bound`]).
    pub gamma: f64,
    /// Maximum tolerable delay τ ≥ 1 (Assumption 1). τ = 1 ⇒ synchronous.
    pub tau: usize,
    /// Minimum number of arrived workers `A ≥ 1` per master iteration.
    pub min_arrivals: usize,
    /// Master iteration budget.
    pub max_iters: usize,
    /// Optional early stop on `‖x₀^{k+1} − x₀^k‖ ≤ tol` (0 disables).
    pub x0_tol: f64,
    /// Abort when the augmented Lagrangian magnitude exceeds this
    /// (divergence guard; Algorithm 4 needs it).
    pub divergence_threshold: f64,
    /// Initial `x⁰` broadcast to the workers (None ⇒ zeros). Non-convex
    /// problems (sparse PCA) need a nonzero start: `x = 0` is an exact
    /// fixed point of the iteration.
    pub init_x0: Option<Vec<f64>>,
    /// Optional residual-based stopping rule ([`stopping`]): terminate
    /// when primal and dual residuals meet the tolerances.
    pub stopping: Option<stopping::StoppingRule>,
    /// Evaluate the (purely diagnostic) objective `F(x₀)` every k-th
    /// iteration (1 = always, 0 = never; skipped records hold NaN).
    /// `F(x₀)` costs one full data pass per worker, which dominates the
    /// coordinator loop on small problems — see EXPERIMENTS.md §Perf.
    pub objective_every: usize,
    /// Evaluate the per-iteration diagnostics (augmented Lagrangian,
    /// consensus residual, `‖x₀^{k+1} − x₀^k‖`) every k-th iteration
    /// (1 = always, 0 = never; skipped records hold NaN, mirroring
    /// `objective_every`). These diagnostics walk every worker's owned
    /// slice — `O(Σ_i |S_i|)` per iteration — which defeats the
    /// O(active) sparse master path, so large-scale sweeps run with 0.
    /// On skipped iterations the divergence guard falls back to checking
    /// the arrived workers' cached `f_i` values, and the `x0_tol` /
    /// residual stopping rules are not evaluated (their inputs are NaN).
    pub metrics_every: usize,
    /// How workers solve the subproblem (13):
    /// [`InexactPolicy::Exact`] (the default, bit-identical to the
    /// historical exact-solve path) or one of the warm-started k-step
    /// inner-loop policies of [`crate::solvers::inexact`].
    pub inexact: InexactPolicy,
}

impl Default for AdmmConfig {
    fn default() -> Self {
        AdmmConfig {
            rho: 1.0,
            gamma: 0.0,
            tau: 1,
            min_arrivals: 1,
            max_iters: 500,
            x0_tol: 0.0,
            divergence_threshold: 1e12,
            init_x0: None,
            stopping: None,
            objective_every: 1,
            metrics_every: 1,
            inexact: InexactPolicy::Exact,
        }
    }
}

impl AdmmConfig {
    /// Validate against the problem size.
    pub fn validate(&self, n_workers: usize) -> Result<(), String> {
        if self.rho <= 0.0 {
            return Err("rho must be positive".into());
        }
        if self.tau < 1 {
            return Err("tau must be >= 1".into());
        }
        if self.min_arrivals < 1 || self.min_arrivals > n_workers {
            return Err(format!(
                "min_arrivals must be in [1, {n_workers}], got {}",
                self.min_arrivals
            ));
        }
        self.inexact.validate()?;
        Ok(())
    }

    /// The initial state per this config (paper init: `x_i⁰ = x₀⁰ = x⁰`,
    /// `λ⁰ = 0`).
    pub fn initial_state(&self, n_workers: usize, dim: usize) -> AdmmState {
        match &self.init_x0 {
            Some(x0) => {
                assert_eq!(x0.len(), dim, "init_x0 dimension mismatch");
                AdmmState::init(n_workers, x0.clone())
            }
            None => AdmmState::zeros(n_workers, dim),
        }
    }
}

/// Full primal/dual state `({x_i}, x₀, {λ_i})`.
#[derive(Clone, Debug)]
pub struct AdmmState {
    pub xs: Vec<Vec<f64>>,
    pub x0: Vec<f64>,
    pub lams: Vec<Vec<f64>>,
}

impl AdmmState {
    /// Paper init: `x_i⁰ = x₀⁰ = x⁰`, `λ⁰` given (zeros by default).
    pub fn init(n_workers: usize, x0: Vec<f64>) -> Self {
        let n = x0.len();
        AdmmState {
            xs: vec![x0.clone(); n_workers],
            x0,
            lams: vec![vec![0.0; n]; n_workers],
        }
    }

    /// Block-sharded init: worker i's primal starts at its owned slice of
    /// `x⁰` and its dual (stored per worker-block, concatenated in owned
    /// order) at zero. With an effectively-dense pattern this reproduces
    /// [`AdmmState::init`] exactly.
    pub fn init_blocks(pattern: &BlockPattern, x0: Vec<f64>) -> Self {
        assert_eq!(x0.len(), pattern.dim(), "init x0 dimension mismatch");
        let n_workers = pattern.num_workers();
        let xs: Vec<Vec<f64>> = (0..n_workers).map(|i| pattern.gather_vec(i, &x0)).collect();
        let lams: Vec<Vec<f64>> =
            (0..n_workers).map(|i| vec![0.0; pattern.owned_len(i)]).collect();
        AdmmState { xs, x0, lams }
    }

    pub fn zeros(n_workers: usize, dim: usize) -> Self {
        Self::init(n_workers, vec![0.0; dim])
    }

    /// Max consensus violation `max_i ‖x_i − x₀‖`.
    pub fn consensus_residual(&self) -> f64 {
        self.xs
            .iter()
            .map(|x| vecops::dist2(x, &self.x0))
            .fold(0.0, f64::max)
    }

    /// Max consensus violation under a block pattern:
    /// `max_i ‖x_i − (x₀)_{S_i}‖`. Same accumulation order as
    /// [`AdmmState::consensus_residual`], so an effectively-dense pattern
    /// reproduces it bit-for-bit.
    pub fn consensus_residual_blocks(&self, pattern: &BlockPattern) -> f64 {
        let mut worst = 0.0f64;
        for (i, x) in self.xs.iter().enumerate() {
            let mut s = 0.0;
            pattern.for_each_range(i, |lo, g, len| {
                for k in 0..len {
                    let d = x[lo + k] - self.x0[g + k];
                    s += d * d;
                }
            });
            worst = worst.max(s.sqrt());
        }
        worst
    }

    pub fn is_finite(&self) -> bool {
        vecops::all_finite(&self.x0)
            && self.xs.iter().all(|x| vecops::all_finite(x))
            && self.lams.iter().all(|l| vecops::all_finite(l))
    }
}

/// The augmented Lagrangian (26):
/// `L_ρ = Σ f_i(x_i) + h(x₀) + Σ λ_iᵀ(x_i − x₀) + ρ/2 Σ ‖x_i − x₀‖²`.
pub fn augmented_lagrangian(problem: &ConsensusProblem, state: &AdmmState, rho: f64) -> f64 {
    let mut total = problem.regularizer().eval(&state.x0);
    let n = state.x0.len();
    let mut diff = vec![0.0; n];
    for (i, local) in problem.locals().iter().enumerate() {
        total += local.eval(&state.xs[i]);
        vecops::sub(&state.xs[i], &state.x0, &mut diff);
        total += vecops::dot(&state.lams[i], &diff) + 0.5 * rho * vecops::nrm2_sq(&diff);
    }
    total
}

/// Incremental evaluation of (26): `f_cache[i]` holds `f_i(x_i)` which the
/// coordinators refresh only for *arrived* workers (the others' `x_i` did
/// not move). Cuts the per-iteration metric cost from `N` full data passes
/// to `|A_k|` — the main L3 hot-loop win (EXPERIMENTS.md §Perf).
pub fn augmented_lagrangian_cached(
    problem: &ConsensusProblem,
    state: &AdmmState,
    rho: f64,
    f_cache: &[f64],
    scratch: &mut Vec<f64>,
) -> f64 {
    debug_assert_eq!(f_cache.len(), state.xs.len());
    let n = state.x0.len();
    scratch.resize(n, 0.0);
    let mut total = problem.regularizer().eval(&state.x0);
    for i in 0..state.xs.len() {
        total += f_cache[i];
        vecops::sub(&state.xs[i], &state.x0, scratch);
        total += vecops::dot(&state.lams[i], scratch) + 0.5 * rho * vecops::nrm2_sq(scratch);
    }
    total
}

/// Block-sharded [`augmented_lagrangian_cached`]: the penalty/dual terms
/// run over each worker's owned slice, `x_i − (x₀)_{S_i}`. Same per-term
/// arithmetic and summation order as the dense version, so an
/// effectively-dense pattern reproduces it bit-for-bit.
pub fn augmented_lagrangian_cached_blocks(
    problem: &ConsensusProblem,
    state: &AdmmState,
    rho: f64,
    f_cache: &[f64],
    scratch: &mut Vec<f64>,
    pattern: &BlockPattern,
) -> f64 {
    debug_assert_eq!(f_cache.len(), state.xs.len());
    let mut total = problem.regularizer().eval(&state.x0);
    for i in 0..state.xs.len() {
        total += f_cache[i];
        let xi = &state.xs[i];
        let ni = xi.len();
        scratch.resize(ni, 0.0);
        let diff = &mut scratch[..ni];
        pattern.for_each_range(i, |lo, g, len| {
            for k in 0..len {
                diff[lo + k] = xi[lo + k] - state.x0[g + k];
            }
        });
        total += vecops::dot(&state.lams[i], diff) + 0.5 * rho * vecops::nrm2_sq(diff);
    }
    total
}

/// The master update (12)/(25): with every `x_i^{k+1}`, `λ_i^{k+1}` in hand,
/// `x₀⁺ = prox_{h/(Nρ+γ)}((ρ Σ x_i + Σ λ_i + γ x₀ᵏ) / (Nρ + γ))`.
///
/// Shared by all coordinator variants (and mirrored by the L2 `master_prox`
/// artifact). Assembles `v` in `scratch.v` (zero allocation in steady
/// state) and writes the result into `state.x0`.
pub fn master_x0_update(
    problem: &ConsensusProblem,
    state: &mut AdmmState,
    rho: f64,
    gamma: f64,
    scratch: &mut MasterScratch,
) {
    let n = state.x0.len();
    let n_workers = state.xs.len() as f64;
    let denom = n_workers * rho + gamma;
    debug_assert!(denom > 0.0, "Nρ + γ must be positive");
    let v = &mut scratch.v;
    v.resize(n, 0.0);
    v.fill(0.0);
    for i in 0..state.xs.len() {
        vecops::acc_axpy(rho, &state.xs[i], &state.lams[i], v);
    }
    for j in 0..n {
        v[j] = (v[j] + gamma * state.x0[j]) / denom;
    }
    problem.regularizer().prox_in_place(v, 1.0 / denom);
    state.x0.copy_from_slice(v);
}

/// Block-sharded master update: the general-form consensus version of
/// (12)/(25). Coordinate `j` receives contributions only from the `N_j`
/// workers owning it, so
/// `x₀⁺_j = prox_{h/(N_j ρ + γ)}((ρ Σ_{i∋j} x_{i,j} + Σ_{i∋j} λ_{i,j} + γ x₀ⱼ) / (N_j ρ + γ))`.
/// The accumulation walks workers in ascending order with the same fused
/// `v += ρ·x + λ` expression as [`vecops::acc_axpy`], the per-coordinate
/// prox weight is applied through [`crate::prox::Regularizer::prox_weighted_in_place`],
/// and with an effectively-dense pattern (`N_j = N` everywhere) every
/// operation matches [`master_x0_update`] bit-for-bit.
pub fn master_x0_update_blocks(
    problem: &ConsensusProblem,
    state: &mut AdmmState,
    rho: f64,
    gamma: f64,
    scratch: &mut MasterScratch,
    pattern: &BlockPattern,
) {
    let n = state.x0.len();
    debug_assert_eq!(n, pattern.dim());
    let v = &mut scratch.v;
    v.resize(n, 0.0);
    v.fill(0.0);
    for i in 0..state.xs.len() {
        let xi = &state.xs[i];
        let li = &state.lams[i];
        pattern.for_each_range(i, |lo, g, len| {
            for k in 0..len {
                v[g + k] += rho * xi[lo + k] + li[lo + k];
            }
        });
    }
    let wd = &mut scratch.wd;
    wd.resize(n, 0.0);
    for j in 0..n {
        let denom = pattern.count(j) as f64 * rho + gamma;
        debug_assert!(denom > 0.0, "N_j ρ + γ must be positive");
        v[j] = (v[j] + gamma * state.x0[j]) / denom;
        wd[j] = 1.0 / denom;
    }
    problem.regularizer().prox_weighted_in_place(v, wd);
    state.x0.copy_from_slice(v);
}

/// The O(active) sparse master state: per-coordinate running accumulators
/// plus per-block lazy prox stamps.
///
/// [`master_x0_update_blocks`] walks every worker's owned slice each
/// iteration — `O(Σ_i |S_i|)` — even though only the arrived set `A_k`
/// changed. This state makes the master update
/// `O(Σ_{i∈A_k} |S_i|)` instead:
///
/// - `acc_j = Σ_{i∋j} (ρ x_{i,j} + λ_{i,j})` is kept as a running
///   per-coordinate sum; an arrival only recomputes the coordinates of the
///   blocks its owners touch (over the owners in ascending worker order, so
///   the sum carries the exact bit pattern of the eager dense reduction).
/// - The per-coordinate prox map
///   `m(x_j) = prox_{h/(N_j ρ + γ)}((acc_j + γ x_j) / (N_j ρ + γ))`
///   is applied *lazily*: each block carries a stamp counting how many
///   applications have been folded into `x₀`, and a stale block is caught
///   up on read by replaying the missed applications with the cached
///   accumulators — which is exactly what the eager path would have
///   computed, because a block is stale only while none of its owners
///   arrived, i.e. while its accumulators were constant.
/// - With γ = 0 (the paper's experimental setting) the map does not read
///   `x₀` at all beyond the first application, so catch-up collapses to at
///   most one application per block and the whole path is genuinely
///   O(active) per iteration.
///
/// Every [`Regularizer`] is coordinate-separable and the map reads only
/// coordinate `j` before writing it, so applying blocks in any order is
/// bit-identical to the eager whole-vector sweep. The `sharded_consensus`
/// suite and the `lazy_sparse_master` property test pin `to_bits`
/// equality against [`master_x0_update_blocks`] on random patterns,
/// traces, τ values and fault plans.
#[derive(Clone, Debug)]
pub struct SparseMaster {
    /// Per-coordinate accumulator `acc_j = Σ_{i∋j} (ρ x_{i,j} + λ_{i,j})`,
    /// current w.r.t. the latest absorbed worker iterates.
    acc: Vec<f64>,
    /// Per-block count of prox applications already folded into `x₀`
    /// (`stamp[b] < updates` ⇒ block `b` owes `updates − stamp[b]`
    /// catch-up applications of the cached map).
    stamp: Vec<u64>,
    /// Master updates performed since the sparse state was (re)built.
    updates: u64,
    /// Scratch: unique block ids touched by the most recent update.
    touched: Vec<usize>,
    /// Scratch: per-block dedup mask for `touched` (cleared after use).
    touched_mask: Vec<bool>,
    /// Block-ownership filter for multi-master partitioned coordination
    /// ([`crate::cluster::MasterGroup`]): `Some(mask)` restricts every
    /// update/materialize to blocks with `mask[b]` — this master's shard
    /// of the global variable. `None` (single master) coordinates all
    /// blocks. Because per-coordinate updates never read across blocks
    /// and `updates` still counts every global round, a masked master is
    /// bit-identical to the same-mask restriction of an unmasked one.
    mask: Option<Vec<bool>>,
}

impl SparseMaster {
    /// Build the sparse state from a full primal/dual state (initial or
    /// checkpoint-restored). The accumulators are recomputed by the same
    /// ascending-worker reduction as the eager path, so a restore followed
    /// by sparse iterations is bit-identical to never having stopped.
    pub(crate) fn new(pattern: &BlockPattern, state: &AdmmState, rho: f64) -> Self {
        let mut s = SparseMaster {
            acc: Vec::new(),
            stamp: Vec::new(),
            updates: 0,
            touched: Vec::new(),
            touched_mask: vec![false; pattern.num_blocks()],
            mask: None,
        };
        s.rebuild(pattern, state, rho);
        s
    }

    /// A masked sparse master coordinating only the blocks with
    /// `mask[b]` — one shard of a multi-master group. The accumulators
    /// are rebuilt globally (same values as the unmasked state; the
    /// unowned entries are simply never read), while updates and
    /// materialization touch owned blocks only.
    pub(crate) fn new_masked(
        pattern: &BlockPattern,
        state: &AdmmState,
        rho: f64,
        mask: Vec<bool>,
    ) -> Self {
        debug_assert_eq!(mask.len(), pattern.num_blocks());
        let mut s = Self::new(pattern, state, rho);
        s.mask = Some(mask);
        s
    }

    /// `true` when this master coordinates block `b`.
    #[inline]
    fn owns(&self, b: usize) -> bool {
        self.mask.as_ref().map_or(true, |m| m[b])
    }

    /// Recompute the accumulators from `state` and reset all stamps
    /// (`x₀` is taken as fully materialized).
    pub(crate) fn rebuild(&mut self, pattern: &BlockPattern, state: &AdmmState, rho: f64) {
        self.acc.clear();
        self.acc.resize(pattern.dim(), 0.0);
        let acc = &mut self.acc;
        for i in 0..state.xs.len() {
            let xi = &state.xs[i];
            let li = &state.lams[i];
            pattern.for_each_range(i, |lo, g, len| {
                for k in 0..len {
                    acc[g + k] += rho * xi[lo + k] + li[lo + k];
                }
            });
        }
        self.stamp.clear();
        self.stamp.resize(pattern.num_blocks(), 0);
        self.updates = 0;
        self.touched.clear();
    }

    /// Read-only window for [`engine::MasterView::sparse`].
    pub(crate) fn view(&self) -> SparseView<'_> {
        SparseView { stamps: &self.stamp, acc: &self.acc, updates: self.updates }
    }

    /// Unique block ids touched by the most recent [`SparseMaster::update`]
    /// (the union of the arrived workers' owned blocks) — reused by the
    /// session's per-block bookkeeping so the touch scan runs once.
    pub(crate) fn touched(&self) -> &[usize] {
        &self.touched
    }

    /// Apply the cached-accumulator prox map to block `b` once, in place.
    fn apply_once(
        acc: &[f64],
        reg: &Regularizer,
        pattern: &BlockPattern,
        x0: &mut [f64],
        rho: f64,
        gamma: f64,
        b: usize,
    ) {
        let (start, len) = pattern.block_range(b);
        for j in start..start + len {
            let denom = pattern.count(j) as f64 * rho + gamma;
            debug_assert!(denom > 0.0, "N_j ρ + γ must be positive");
            let v = (acc[j] + gamma * x0[j]) / denom;
            x0[j] = reg.prox_scalar(v, 1.0 / denom);
        }
    }

    /// Replay the `target − stamp` missed applications of the *cached* map
    /// on block `b` and return the new stamp. With γ = 0 the map is
    /// constant in `x₀` and bit-stable after one application (`x₀` enters
    /// only through `γ·x₀_j`), so at most one application is performed.
    #[allow(clippy::too_many_arguments)]
    fn catch_up(
        acc: &[f64],
        reg: &Regularizer,
        pattern: &BlockPattern,
        x0: &mut [f64],
        rho: f64,
        gamma: f64,
        b: usize,
        stamp: u64,
        target: u64,
    ) -> u64 {
        if stamp >= target {
            return stamp;
        }
        if gamma == 0.0 {
            if stamp == 0 {
                Self::apply_once(acc, reg, pattern, x0, rho, gamma, b);
            }
        } else {
            for _ in stamp..target {
                Self::apply_once(acc, reg, pattern, x0, rho, gamma, b);
            }
        }
        target
    }

    /// One sparse master update for arrival set `set` (ascending worker
    /// ids): catch the touched blocks up with the pre-arrival
    /// accumulators, fold the arrived owners' fresh `(x_i, λ_i)` into the
    /// accumulators, and apply the refreshed map once per touched block.
    /// Untouched blocks only grow staler; their catch-up is deferred to
    /// [`SparseMaster::materialize`]. Cost `O(Σ_{i∈set} |S_i|)`.
    pub(crate) fn update(
        &mut self,
        problem: &ConsensusProblem,
        state: &mut AdmmState,
        rho: f64,
        gamma: f64,
        pattern: &BlockPattern,
        set: &[usize],
    ) {
        let reg = problem.regularizer();
        let AdmmState { xs, x0, lams } = state;
        self.touched.clear();
        for &i in set {
            for &b in pattern.owned(i) {
                if self.touched_mask[b] {
                    continue;
                }
                if let Some(m) = &self.mask {
                    if !m[b] {
                        continue;
                    }
                }
                self.touched_mask[b] = true;
                self.touched.push(b);
            }
        }
        let target = self.updates;
        for &b in &self.touched {
            self.stamp[b] =
                Self::catch_up(&self.acc, reg, pattern, x0, rho, gamma, b, self.stamp[b], target);
        }
        // Fold in the arrivals: recompute each touched block's coordinates
        // over its owners in ascending worker order — the same terms in
        // the same order as the eager reduction, so the sums carry
        // identical bits (the non-arrived owners' iterates are unchanged).
        let acc = &mut self.acc;
        for &b in &self.touched {
            let (start, len) = pattern.block_range(b);
            acc[start..start + len].fill(0.0);
            pattern.for_each_owner(b, |i, lo| {
                let xi = &xs[i];
                let li = &lams[i];
                for k in 0..len {
                    acc[start + k] += rho * xi[lo + k] + li[lo + k];
                }
            });
        }
        self.updates = target + 1;
        for &b in &self.touched {
            Self::apply_once(&self.acc, reg, pattern, x0, rho, gamma, b);
            self.stamp[b] = target + 1;
        }
        for &b in &self.touched {
            self.touched_mask[b] = false;
        }
    }

    /// Catch every stale block up to the current update count so `x₀` is
    /// exactly what the eager path would hold. Called before any dense
    /// read of `x₀` (per-iteration diagnostics, stopping rules,
    /// checkpointing, final state). Idempotent; `O(num_blocks)` plus the
    /// replay work actually owed.
    pub(crate) fn materialize(
        &mut self,
        problem: &ConsensusProblem,
        x0: &mut [f64],
        rho: f64,
        gamma: f64,
        pattern: &BlockPattern,
    ) {
        let reg = problem.regularizer();
        let target = self.updates;
        for b in 0..pattern.num_blocks() {
            if !self.owns(b) {
                continue;
            }
            self.stamp[b] =
                Self::catch_up(&self.acc, reg, pattern, x0, rho, gamma, b, self.stamp[b], target);
        }
    }
}

/// Read-only window over the [`SparseMaster`] state, exposed through
/// [`engine::MasterView::sparse`]. `stamps[b] < updates` means block `b`
/// is stale: its pending catch-up applications will be replayed on the
/// next materialization (diagnostics, checkpoint, or finish).
#[derive(Clone, Copy, Debug)]
pub struct SparseView<'a> {
    /// Per-block count of prox applications folded into `x₀` so far.
    pub stamps: &'a [u64],
    /// Per-coordinate accumulators `Σ_{i∋j} (ρ x_{i,j} + λ_{i,j})`.
    pub acc: &'a [f64],
    /// Master updates performed since the sparse state was (re)built.
    pub updates: u64,
}

/// Assemble the [`IterRecord`] for iteration `k` from the post-update
/// state. Shared by every coordinator (serial Algorithm 3, Algorithm 4,
/// the threaded star cluster and the virtual-time simulator) so that two
/// runs realizing the same arrival trace produce **bit-identical**
/// histories — the equivalence the `cluster_e2e`/`virtual_time` tests pin.
pub(crate) fn iter_record(
    problem: &ConsensusProblem,
    state: &AdmmState,
    cfg: &AdmmConfig,
    k: usize,
    arrivals: usize,
    f_cache: &[f64],
    scratch: &mut MasterScratch,
    prev_x0: &[f64],
    shard: Option<&BlockPattern>,
) -> IterRecord {
    let aug = match shard {
        None => augmented_lagrangian_cached(problem, state, cfg.rho, f_cache, &mut scratch.al),
        Some(p) => augmented_lagrangian_cached_blocks(
            problem,
            state,
            cfg.rho,
            f_cache,
            &mut scratch.al,
            p,
        ),
    };
    let x0_change = vecops::dist2(&state.x0, prev_x0);
    let objective = if cfg.objective_every > 0 && k % cfg.objective_every == 0 {
        problem.objective_with(&state.x0, &mut scratch.ws)
    } else {
        f64::NAN
    };
    let consensus = match shard {
        None => state.consensus_residual(),
        Some(p) => state.consensus_residual_blocks(p),
    };
    IterRecord {
        k,
        objective,
        aug_lagrangian: aug,
        consensus,
        x0_change,
        arrivals,
    }
}

/// The divergence / `x₀`-tolerance stop checks shared by all coordinators.
/// (The residual-based [`stopping::StoppingRule`] stays with the callers
/// that support it.)
pub(crate) fn divergence_or_tol_stop(
    cfg: &AdmmConfig,
    state: &AdmmState,
    rec: &IterRecord,
    k: usize,
) -> Option<StopReason> {
    if !state.is_finite() || rec.aug_lagrangian.abs() > cfg.divergence_threshold {
        return Some(StopReason::Diverged);
    }
    if cfg.x0_tol > 0.0 && rec.x0_change <= cfg.x0_tol && k > 0 {
        return Some(StopReason::X0Tolerance);
    }
    None
}

/// Per-iteration record used by figures, tests and logs.
#[derive(Clone, Debug)]
pub struct IterRecord {
    /// Master iteration number k.
    pub k: usize,
    /// Original objective (1) evaluated at the consensus point x₀.
    pub objective: f64,
    /// Augmented Lagrangian (26) — the quantity the paper's accuracy
    /// definitions (51)/(53) are based on.
    pub aug_lagrangian: f64,
    /// `max_i ‖x_i − x₀‖`.
    pub consensus: f64,
    /// `‖x₀^{k+1} − x₀^k‖`.
    pub x0_change: f64,
    /// Number of arrived workers this iteration.
    pub arrivals: usize,
}

/// Why a run stopped.
#[derive(Clone, Debug, PartialEq)]
pub enum StopReason {
    MaxIters,
    X0Tolerance,
    /// The residual-based rule ([`stopping::StoppingRule`]) fired.
    Residuals,
    Diverged,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::QuadraticLocal;
    use crate::prox::Regularizer;
    use std::sync::Arc;

    fn toy_problem() -> ConsensusProblem {
        // f1 = ½(x−1)² → Q=1, q=−1 ; f2 = ½(x+1)²
        let l1 = Arc::new(QuadraticLocal::diagonal(&[1.0], vec![-1.0]));
        let l2 = Arc::new(QuadraticLocal::diagonal(&[1.0], vec![1.0]));
        ConsensusProblem::new(vec![l1, l2], Regularizer::Zero)
    }

    #[test]
    fn aug_lagrangian_at_consensus_equals_objective_plus_const() {
        let p = toy_problem();
        let state = AdmmState::init(2, vec![0.5]);
        let al = augmented_lagrangian(&p, &state, 10.0);
        // at consensus the penalty and dual terms vanish
        let f = p.locals()[0].eval(&[0.5]) + p.locals()[1].eval(&[0.5]);
        assert!((al - f).abs() < 1e-12);
    }

    #[test]
    fn master_update_unregularized_is_weighted_average() {
        let p = toy_problem();
        let mut state = AdmmState::zeros(2, 1);
        state.xs[0] = vec![2.0];
        state.xs[1] = vec![4.0];
        state.lams[0] = vec![1.0];
        state.lams[1] = vec![-1.0];
        master_x0_update(&p, &mut state, 1.0, 0.0, &mut MasterScratch::new());
        // (ρ(2+4) + (1−1)) / (2ρ) = 3
        assert!((state.x0[0] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn master_update_gamma_pulls_towards_previous() {
        let p = toy_problem();
        let mut state = AdmmState::init(2, vec![10.0]);
        state.xs[0] = vec![0.0];
        state.xs[1] = vec![0.0];
        // γ → ∞ keeps x0 at 10; γ = 0 moves it to 0.
        master_x0_update(&p, &mut state, 1.0, 1e9, &mut MasterScratch::new());
        assert!((state.x0[0] - 10.0).abs() < 1e-6);
        let mut state2 = AdmmState::init(2, vec![10.0]);
        state2.xs[0] = vec![0.0];
        state2.xs[1] = vec![0.0];
        master_x0_update(&p, &mut state2, 1.0, 0.0, &mut MasterScratch::new());
        assert!(state2.x0[0].abs() < 1e-12);
    }

    #[test]
    fn master_update_l1_soft_thresholds() {
        let l1 = Arc::new(QuadraticLocal::diagonal(&[1.0], vec![0.0]));
        let p = ConsensusProblem::new(vec![l1], Regularizer::L1 { theta: 1.0 });
        let mut state = AdmmState::zeros(1, 1);
        state.xs[0] = vec![0.5]; // v = 0.5, threshold 1/ρ = 1 → 0
        master_x0_update(&p, &mut state, 1.0, 0.0, &mut MasterScratch::new());
        assert_eq!(state.x0[0], 0.0);
        state.xs[0] = vec![3.0]; // v = 3, threshold 1 → 2
        master_x0_update(&p, &mut state, 1.0, 0.0, &mut MasterScratch::new());
        assert!((state.x0[0] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn sharded_master_update_dense_pattern_is_bit_identical() {
        let p = toy_problem();
        let pattern = BlockPattern::dense(1, 2);
        let mk = || {
            let mut s = AdmmState::zeros(2, 1);
            s.xs[0] = vec![2.0];
            s.xs[1] = vec![4.0];
            s.lams[0] = vec![1.0];
            s.lams[1] = vec![-0.3];
            s
        };
        let mut dense = mk();
        master_x0_update(&p, &mut dense, 7.0, 0.5, &mut MasterScratch::new());
        let mut sharded = mk();
        master_x0_update_blocks(&p, &mut sharded, 7.0, 0.5, &mut MasterScratch::new(), &pattern);
        assert_eq!(dense.x0[0].to_bits(), sharded.x0[0].to_bits());

        // Same with an L1 prox in the loop (per-coordinate weights active).
        let mk_local = || -> Arc<dyn crate::problems::LocalCost> {
            Arc::new(QuadraticLocal::diagonal(&[1.0], vec![0.0]))
        };
        let pl1 =
            ConsensusProblem::new(vec![mk_local(), mk_local()], Regularizer::L1 { theta: 0.4 });
        let mut dense2 = mk();
        master_x0_update(&pl1, &mut dense2, 1.0, 0.0, &mut MasterScratch::new());
        let mut sharded2 = mk();
        master_x0_update_blocks(&pl1, &mut sharded2, 1.0, 0.0, &mut MasterScratch::new(), &pattern);
        assert_eq!(dense2.x0[0].to_bits(), sharded2.x0[0].to_bits());
    }

    #[test]
    fn sharded_master_update_uses_per_coordinate_owner_counts() {
        // n = 2 split into two singleton blocks; worker 0 owns both,
        // worker 1 owns only block 0. Coordinate 0 averages over 2 owners,
        // coordinate 1 over 1.
        let pattern =
            BlockPattern::new(2, &[(0, 1), (1, 1)], vec![vec![0, 1], vec![0]]).unwrap();
        let l0 = Arc::new(QuadraticLocal::diagonal(&[1.0, 1.0], vec![0.0, 0.0]))
            as Arc<dyn crate::problems::LocalCost>;
        let l1 = Arc::new(QuadraticLocal::diagonal(&[1.0], vec![0.0]));
        let p = ConsensusProblem::sharded(
            vec![l0, l1],
            Regularizer::Zero,
            pattern.clone(),
        )
        .unwrap();
        assert_eq!(p.dim(), 2);
        let mut state = AdmmState::init_blocks(&pattern, vec![0.0, 0.0]);
        state.xs[0] = vec![2.0, 6.0];
        state.xs[1] = vec![4.0];
        state.lams[0] = vec![1.0, 0.0];
        state.lams[1] = vec![-1.0];
        master_x0_update_blocks(&p, &mut state, 1.0, 0.0, &mut MasterScratch::new(), &pattern);
        // x0_0 = (1·(2+4) + (1−1)) / 2 = 3 ; x0_1 = (1·6 + 0) / 1 = 6
        assert!((state.x0[0] - 3.0).abs() < 1e-12);
        assert!((state.x0[1] - 6.0).abs() < 1e-12);
    }

    /// Drive eager [`master_x0_update_blocks`] and the lazy [`SparseMaster`]
    /// over the same perturbation/arrival schedule and pin `to_bits`
    /// equality of `x₀` — both with materialization only at the end
    /// (metrics-off mode) and with materialization every iteration.
    fn sparse_vs_eager_case(gamma: f64, reg: Regularizer, materialize_every_iter: bool) {
        let pattern =
            BlockPattern::new(3, &[(0, 1), (1, 1), (2, 1)], vec![vec![0, 1], vec![0, 2]])
                .unwrap();
        let l0 = Arc::new(QuadraticLocal::diagonal(&[1.0, 2.0], vec![0.3, -0.1]))
            as Arc<dyn crate::problems::LocalCost>;
        let l1 = Arc::new(QuadraticLocal::diagonal(&[1.5, 0.5], vec![0.0, 0.2]));
        let p = ConsensusProblem::sharded(vec![l0, l1], reg, pattern.clone()).unwrap();
        let rho = 1.3;
        let init = vec![0.4, -0.7, 1.1];
        let mut eager = AdmmState::init_blocks(&pattern, init.clone());
        let mut lazy = AdmmState::init_blocks(&pattern, init);
        let mut sparse = SparseMaster::new(&pattern, &lazy, rho);
        let mut scratch = MasterScratch::new();
        let sets: [&[usize]; 6] = [&[0], &[1], &[0, 1], &[1], &[0], &[0, 1]];
        for (k, set) in sets.iter().enumerate() {
            // Deterministic "worker step": only the arrived workers move.
            for state in [&mut eager, &mut lazy] {
                for &i in *set {
                    for (m, x) in state.xs[i].iter_mut().enumerate() {
                        *x += 0.1 * (k + 1) as f64 - 0.07 * (i + m) as f64;
                    }
                    for (m, l) in state.lams[i].iter_mut().enumerate() {
                        *l += 0.03 * (m + 1) as f64 - 0.05 * k as f64;
                    }
                }
            }
            master_x0_update_blocks(&p, &mut eager, rho, gamma, &mut scratch, &pattern);
            sparse.update(&p, &mut lazy, rho, gamma, &pattern, set);
            if materialize_every_iter {
                sparse.materialize(&p, &mut lazy.x0, rho, gamma, &pattern);
                for j in 0..3 {
                    assert_eq!(
                        eager.x0[j].to_bits(),
                        lazy.x0[j].to_bits(),
                        "k={k} j={j} γ={gamma}"
                    );
                }
            }
        }
        sparse.materialize(&p, &mut lazy.x0, rho, gamma, &pattern);
        for j in 0..3 {
            assert_eq!(eager.x0[j].to_bits(), lazy.x0[j].to_bits(), "final j={j} γ={gamma}");
        }
    }

    #[test]
    fn sparse_master_bit_identical_to_eager_blocks() {
        for materialize_every in [false, true] {
            sparse_vs_eager_case(0.0, Regularizer::Zero, materialize_every);
            sparse_vs_eager_case(0.0, Regularizer::L1 { theta: 0.3 }, materialize_every);
            sparse_vs_eager_case(0.7, Regularizer::Zero, materialize_every);
            sparse_vs_eager_case(0.7, Regularizer::L1 { theta: 0.3 }, materialize_every);
        }
    }

    #[test]
    fn sparse_master_stamps_track_touched_blocks() {
        let pattern =
            BlockPattern::new(2, &[(0, 1), (1, 1)], vec![vec![0, 1], vec![0]]).unwrap();
        let l0 = Arc::new(QuadraticLocal::diagonal(&[1.0, 1.0], vec![0.0, 0.0]))
            as Arc<dyn crate::problems::LocalCost>;
        let l1 = Arc::new(QuadraticLocal::diagonal(&[1.0], vec![0.0]));
        let p = ConsensusProblem::sharded(vec![l0, l1], Regularizer::Zero, pattern.clone())
            .unwrap();
        let mut state = AdmmState::init_blocks(&pattern, vec![0.0, 0.0]);
        let mut sparse = SparseMaster::new(&pattern, &state, 1.0);
        assert_eq!(sparse.view().updates, 0);
        // Worker 1 arrives: only block 0 is touched.
        sparse.update(&p, &mut state, 1.0, 0.0, &pattern, &[1]);
        assert_eq!(sparse.touched(), &[0]);
        assert_eq!(sparse.view().stamps, &[1, 0]);
        assert_eq!(sparse.view().updates, 1);
        // Worker 0 arrives: both its blocks are touched; block 1 catches up.
        sparse.update(&p, &mut state, 1.0, 0.0, &pattern, &[0]);
        assert_eq!(sparse.touched(), &[0, 1]);
        assert_eq!(sparse.view().stamps, &[2, 2]);
        sparse.materialize(&p, &mut state.x0, 1.0, 0.0, &pattern);
        assert_eq!(sparse.view().stamps, &[2, 2]);
    }

    #[test]
    fn sharded_aug_lagrangian_and_consensus_over_owned_slices() {
        let pattern =
            BlockPattern::new(2, &[(0, 1), (1, 1)], vec![vec![0, 1], vec![0]]).unwrap();
        let l0 = Arc::new(QuadraticLocal::diagonal(&[1.0, 1.0], vec![0.0, 0.0]))
            as Arc<dyn crate::problems::LocalCost>;
        let l1 = Arc::new(QuadraticLocal::diagonal(&[1.0], vec![0.0]));
        let p = ConsensusProblem::sharded(vec![l0, l1], Regularizer::Zero, pattern.clone())
            .unwrap();
        let mut state = AdmmState::init_blocks(&pattern, vec![1.0, 2.0]);
        assert_eq!(state.xs[1], vec![1.0]); // worker 1's owned slice of x0
        state.xs[1] = vec![4.0]; // violates consensus on coordinate 0 by 3
        assert!((state.consensus_residual_blocks(&pattern) - 3.0).abs() < 1e-12);
        let f_cache = vec![0.0, 0.0];
        let mut scratch = Vec::new();
        let al =
            augmented_lagrangian_cached_blocks(&p, &state, 2.0, &f_cache, &mut scratch, &pattern);
        // only the (x_1 − x0_0) penalty term is nonzero: ½·ρ·3² = 9
        assert!((al - 9.0).abs() < 1e-12, "al={al}");
    }

    #[test]
    fn consensus_residual_and_finiteness() {
        let mut s = AdmmState::zeros(2, 2);
        s.xs[1] = vec![3.0, 4.0];
        assert!((s.consensus_residual() - 5.0).abs() < 1e-12);
        assert!(s.is_finite());
        s.lams[0][0] = f64::NAN;
        assert!(!s.is_finite());
    }

    #[test]
    fn config_validation() {
        let cfg = AdmmConfig::default();
        assert!(cfg.validate(4).is_ok());
        let bad = AdmmConfig { rho: -1.0, ..Default::default() };
        assert!(bad.validate(4).is_err());
        let bad2 = AdmmConfig { min_arrivals: 5, ..Default::default() };
        assert!(bad2.validate(4).is_err());
        let bad3 = AdmmConfig { tau: 0, ..Default::default() };
        assert!(bad3.validate(4).is_err());
    }
}
