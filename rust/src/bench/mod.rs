//! Benchmark harness (criterion is unavailable offline).
//!
//! Provides warmed-up, repeated timing with robust statistics, a tiny text
//! reporter the `rust/benches/*.rs` binaries (all `harness = false`)
//! share, and the machine-readable [`json::BenchReport`] every bench
//! writes as `BENCH_<name>.json` (uploaded by CI, diffed against committed
//! baselines by the `bench_diff` binary). Times are wall-clock via
//! `Instant`; a `black_box` defeats dead-code elimination.

pub mod json;

use std::hint::black_box as std_black_box;
use std::path::PathBuf;
use std::time::Instant;

/// Re-export under the criterion-familiar name.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// True when the shared quick-mode switch `AD_ADMM_BENCH_QUICK` is set in
/// the environment (to *any* value — presence is what counts; unset it to
/// run full scale). This is the **single** quick-mode knob for every bench
/// in `rust/benches/` (the legacy per-bench `FIG3_QUICK`/`FIG4_QUICK`
/// variables are gone). The CI bench-smoke job sets it so every bench runs
/// one reduced-size pass and can never bit-rot silently; full paper-scale
/// runs remain the default.
pub fn quick_mode() -> bool {
    std::env::var_os("AD_ADMM_BENCH_QUICK").is_some()
}

/// Where bench outputs (CSV series and `BENCH_<name>.json` reports) go:
/// `$AD_ADMM_BENCH_DIR` when set (CI pins it so artifact-upload paths are
/// deterministic), `bench_results/` relative to the working directory
/// otherwise.
pub fn results_dir() -> PathBuf {
    match std::env::var_os("AD_ADMM_BENCH_DIR") {
        Some(dir) => PathBuf::from(dir),
        None => PathBuf::from("bench_results"),
    }
}

/// Summary statistics over a set of per-iteration timings (seconds).
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub samples: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub stddev_s: f64,
}

impl BenchStats {
    pub fn from_samples(mut xs: Vec<f64>) -> Self {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.total_cmp(b));
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        BenchStats {
            samples: n,
            mean_s: mean,
            median_s: xs[n / 2],
            p95_s: xs[((n as f64) * 0.95) as usize % n.max(1)],
            min_s: xs[0],
            max_s: xs[n - 1],
            stddev_s: var.sqrt(),
        }
    }

    /// Pretty time with unit scaling.
    pub fn human(seconds: f64) -> String {
        if seconds >= 1.0 {
            format!("{seconds:.3} s")
        } else if seconds >= 1e-3 {
            format!("{:.3} ms", seconds * 1e3)
        } else if seconds >= 1e-6 {
            format!("{:.3} µs", seconds * 1e6)
        } else {
            format!("{:.1} ns", seconds * 1e9)
        }
    }
}

/// Time `f` with `warmup` unmeasured runs then `samples` measured runs.
pub fn bench_fn<F: FnMut()>(warmup: usize, samples: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut xs = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        f();
        xs.push(t.elapsed().as_secs_f64());
    }
    BenchStats::from_samples(xs)
}

/// Print a single result row in the shared bench format.
pub fn report(name: &str, stats: &BenchStats) {
    println!(
        "bench {name:<44} median {:>12}  mean {:>12}  p95 {:>12}  (n={})",
        BenchStats::human(stats.median_s),
        BenchStats::human(stats.mean_s),
        BenchStats::human(stats.p95_s),
        stats.samples,
    );
}

/// Print a section banner (keeps `cargo bench` output scannable).
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_on_known_data() {
        let s = BenchStats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.samples, 5);
        assert!((s.mean_s - 3.0).abs() < 1e-12);
        assert_eq!(s.median_s, 3.0);
        assert_eq!(s.min_s, 1.0);
        assert_eq!(s.max_s, 5.0);
    }

    #[test]
    fn bench_fn_runs_expected_counts() {
        let mut calls = 0;
        let s = bench_fn(3, 10, || calls += 1);
        assert_eq!(calls, 13);
        assert_eq!(s.samples, 10);
        assert!(s.min_s >= 0.0);
    }

    #[test]
    fn human_units() {
        assert!(BenchStats::human(2.0).ends_with(" s"));
        assert!(BenchStats::human(2e-3).ends_with(" ms"));
        assert!(BenchStats::human(2e-6).ends_with(" µs"));
        assert!(BenchStats::human(2e-9).ends_with(" ns"));
    }
}
