//! Machine-readable benchmark reports.
//!
//! A dependency-free JSON value tree (writer **and** parser — the offline
//! image has no serde) plus the [`BenchReport`] builder every binary in
//! `rust/benches/` uses to emit `BENCH_<name>.json` alongside its text
//! output. The CI bench-smoke job uploads those files as artifacts and
//! diffs them against the committed baselines in `rust/bench_baselines/`
//! via the `bench_diff` binary, so perf changes are visible per PR instead
//! of anecdotal.
//!
//! Report schema (stable; bump `schema` when it changes):
//!
//! ```json
//! {
//!   "schema": 1,
//!   "name": "virtual_scale",
//!   "quick": true,
//!   "config": { "n_workers": 1000, ... },
//!   "stats": { "<label>": {"samples": 5, "median_s": ..., ...}, ... },
//!   "metrics": { "sim_iters_per_sec": ..., "pooled_speedup": ..., ... },
//!   "series": [ {"label": "...", ...}, ... ]
//! }
//! ```
//!
//! Comparison conventions (used by `bench_diff`): metric keys ending in
//! `_s` are durations (lower is better); keys containing `per_sec` or
//! `speedup` are rates (higher is better); everything else is contextual
//! and not diffed.

use std::fmt;
use std::io::Write;
use std::path::PathBuf;

use super::{quick_mode, results_dir, BenchStats};

/// A parsed/printable JSON value. Objects keep insertion order so reports
/// serialize deterministically.
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Object entries (empty for non-objects).
    pub fn entries(&self) -> &[(String, JsonValue)] {
        match self {
            JsonValue::Obj(fields) => fields,
            _ => &[],
        }
    }

    /// Array items (empty for non-arrays).
    pub fn items(&self) -> &[JsonValue] {
        match self {
            JsonValue::Arr(items) => items,
            _ => &[],
        }
    }

    fn write_indented(&self, f: &mut fmt::Formatter<'_>, depth: usize) -> fmt::Result {
        let pad = "  ".repeat(depth + 1);
        let close = "  ".repeat(depth);
        match self {
            JsonValue::Null => write!(f, "null"),
            JsonValue::Bool(b) => write!(f, "{b}"),
            // JSON has no NaN/Infinity; non-finite collapses to null (the
            // figure benches use NaN to mark skipped diagnostics).
            JsonValue::Num(v) if !v.is_finite() => write!(f, "null"),
            JsonValue::Num(v) => {
                if *v == v.trunc() && v.abs() < 1e15 {
                    write!(f, "{}", *v as i64)
                } else {
                    write!(f, "{v:e}")
                }
            }
            JsonValue::Str(s) => write!(f, "\"{}\"", escape(s)),
            JsonValue::Arr(items) => {
                if items.is_empty() {
                    return write!(f, "[]");
                }
                writeln!(f, "[")?;
                for (i, item) in items.iter().enumerate() {
                    write!(f, "{pad}")?;
                    item.write_indented(f, depth + 1)?;
                    writeln!(f, "{}", if i + 1 < items.len() { "," } else { "" })?;
                }
                write!(f, "{close}]")
            }
            JsonValue::Obj(fields) => {
                if fields.is_empty() {
                    return write!(f, "{{}}");
                }
                writeln!(f, "{{")?;
                for (i, (k, v)) in fields.iter().enumerate() {
                    write!(f, "{pad}\"{}\": ", escape(k))?;
                    v.write_indented(f, depth + 1)?;
                    writeln!(f, "{}", if i + 1 < fields.len() { "," } else { "" })?;
                }
                write!(f, "{close}}}")
            }
        }
    }
}

impl fmt::Display for JsonValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write_indented(f, 0)
    }
}

impl From<f64> for JsonValue {
    fn from(v: f64) -> Self {
        JsonValue::Num(v)
    }
}

impl From<usize> for JsonValue {
    fn from(v: usize) -> Self {
        JsonValue::Num(v as f64)
    }
}

impl From<bool> for JsonValue {
    fn from(v: bool) -> Self {
        JsonValue::Bool(v)
    }
}

impl From<&str> for JsonValue {
    fn from(v: &str) -> Self {
        JsonValue::Str(v.to_string())
    }
}

impl From<String> for JsonValue {
    fn from(v: String) -> Self {
        JsonValue::Str(v)
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Parse a JSON document (strict enough for round-tripping our reports and
/// any hand-edited baseline; rejects trailing garbage).
pub fn parse(text: &str) -> Result<JsonValue, String> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing input at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, lit: &str, v: JsonValue) -> Result<JsonValue, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<JsonValue, String> {
        match self.peek() {
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected input at byte {}", self.pos)),
        }
    }

    fn number(&mut self) -> Result<JsonValue, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| format!("invalid utf-8 in number at byte {start}"))?;
        text.parse::<f64>()
            .map(JsonValue::Num)
            .map_err(|_| format!("bad number {text:?} at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            // surrogate pairs are not emitted by our writer;
                            // map them to the replacement character
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 character
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest
                        .chars()
                        .next()
                        .ok_or_else(|| "unterminated string".to_string())?;
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue, String> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<JsonValue, String> {
        self.expect_byte(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_ws();
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Obj(fields));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Bit-exact numeric codecs (used by the session-checkpoint format)
// ---------------------------------------------------------------------------

/// Encode an `f64` as its exact 16-hex-digit bit pattern. The JSON number
/// path round-trips finite values but collapses NaN/Inf to `null`; the bit
/// pattern is lossless for *every* value, which bit-identical
/// checkpoint/resume needs (a divergence checkpoint legitimately holds
/// non-finite state).
pub fn hex_f64(v: f64) -> JsonValue {
    JsonValue::Str(format!("{:016x}", v.to_bits()))
}

pub fn f64_from_hex(v: &JsonValue) -> Result<f64, String> {
    let s = v.as_str().ok_or_else(|| format!("expected hex f64 string, got {v}"))?;
    let bits = u64::from_str_radix(s, 16).map_err(|_| format!("bad f64 hex {s:?}"))?;
    Ok(f64::from_bits(bits))
}

/// Encode a `u128` (PCG-64 RNG state words) as a 32-hex-digit string —
/// JSON numbers are f64 and cannot carry 128 bits.
pub fn hex_u128(v: u128) -> JsonValue {
    JsonValue::Str(format!("{v:032x}"))
}

pub fn u128_from_hex(v: &JsonValue) -> Result<u128, String> {
    let s = v.as_str().ok_or_else(|| format!("expected hex u128 string, got {v}"))?;
    u128::from_str_radix(s, 16).map_err(|_| format!("bad u128 hex {s:?}"))
}

/// A vector of bit-exact [`hex_f64`] strings.
pub fn hex_vec(xs: &[f64]) -> JsonValue {
    JsonValue::Arr(xs.iter().map(|&v| hex_f64(v)).collect())
}

pub fn vec_from_hex(v: &JsonValue) -> Result<Vec<f64>, String> {
    v.items().iter().map(f64_from_hex).collect()
}

/// A matrix (vec of rows) of bit-exact [`hex_f64`] strings.
pub fn hex_mat(m: &[Vec<f64>]) -> JsonValue {
    JsonValue::Arr(m.iter().map(|row| hex_vec(row)).collect())
}

pub fn mat_from_hex(v: &JsonValue) -> Result<Vec<Vec<f64>>, String> {
    v.items().iter().map(vec_from_hex).collect()
}

/// Read a non-negative integer that fits `usize` exactly (rejects
/// fractional values and anything at/above 2^53 where f64 loses integer
/// precision).
pub fn json_usize(v: &JsonValue) -> Result<usize, String> {
    match v.as_f64() {
        Some(x) if x >= 0.0 && x.fract() == 0.0 && x < 9.0e15 => Ok(x as usize),
        _ => Err(format!("expected a non-negative integer, got {v}")),
    }
}

/// The bench-report schema version this build reads and writes.
pub const REPORT_SCHEMA_VERSION: u32 = 1;

/// Validate a bench-report document's schema version. Returns the version
/// when it is one this build understands; a clear error for a missing,
/// non-numeric or unknown `schema` field (used by `bench_diff` to reject
/// malformed baselines instead of silently mis-comparing them).
pub fn report_schema(doc: &JsonValue) -> Result<u32, String> {
    match doc.get("schema") {
        None => Err("missing \"schema\" field (not a bench report?)".to_string()),
        Some(v) => match v.as_f64() {
            Some(s) if s == REPORT_SCHEMA_VERSION as f64 => Ok(REPORT_SCHEMA_VERSION),
            Some(s) => Err(format!(
                "unsupported bench-report schema version {s} \
                 (this build reads version {REPORT_SCHEMA_VERSION})"
            )),
            None => Err(format!("\"schema\" field is not a number: {v}")),
        },
    }
}

/// Builder for one bench binary's `BENCH_<name>.json` report.
pub struct BenchReport {
    name: String,
    config: Vec<(String, JsonValue)>,
    stats: Vec<(String, JsonValue)>,
    metrics: Vec<(String, JsonValue)>,
    series: Vec<JsonValue>,
}

impl BenchReport {
    pub fn new(name: &str) -> Self {
        BenchReport {
            name: name.to_string(),
            config: Vec::new(),
            stats: Vec::new(),
            metrics: Vec::new(),
            series: Vec::new(),
        }
    }

    /// Record a configuration knob (worker counts, sizes, sweeps…).
    pub fn config(&mut self, key: &str, value: impl Into<JsonValue>) -> &mut Self {
        self.config.push((key.to_string(), value.into()));
        self
    }

    /// Record a robust-stats block from [`super::bench_fn`].
    pub fn stats(&mut self, label: &str, s: &BenchStats) -> &mut Self {
        self.stats.push((label.to_string(), stats_obj(s)));
        self
    }

    /// Record a scalar headline metric (iters/sec, time-to-tolerance,
    /// speedup…). Follow the key conventions in the module docs so
    /// `bench_diff` knows which direction is a regression.
    pub fn metric(&mut self, key: &str, value: f64) -> &mut Self {
        self.metrics.push((key.to_string(), JsonValue::Num(value)));
        self
    }

    /// Append one row of a per-setting series (a sweep point, a curve).
    pub fn series(&mut self, fields: Vec<(&str, JsonValue)>) -> &mut Self {
        self.series.push(JsonValue::Obj(
            fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        ));
        self
    }

    /// The assembled report document.
    pub fn to_json(&self) -> JsonValue {
        JsonValue::Obj(vec![
            ("schema".into(), JsonValue::Num(1.0)),
            ("name".into(), JsonValue::Str(self.name.clone())),
            ("quick".into(), JsonValue::Bool(quick_mode())),
            ("config".into(), JsonValue::Obj(self.config.clone())),
            ("stats".into(), JsonValue::Obj(self.stats.clone())),
            ("metrics".into(), JsonValue::Obj(self.metrics.clone())),
            ("series".into(), JsonValue::Arr(self.series.clone())),
        ])
    }

    /// Write `BENCH_<name>.json` into [`results_dir`] and return the path.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let dir = results_dir();
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("BENCH_{}.json", self.name));
        let mut file = std::io::BufWriter::new(std::fs::File::create(&path)?);
        writeln!(file, "{}", self.to_json())?;
        file.flush()?;
        Ok(path)
    }
}

fn stats_obj(s: &BenchStats) -> JsonValue {
    JsonValue::Obj(vec![
        ("samples".into(), JsonValue::Num(s.samples as f64)),
        ("mean_s".into(), JsonValue::Num(s.mean_s)),
        ("median_s".into(), JsonValue::Num(s.median_s)),
        ("p95_s".into(), JsonValue::Num(s.p95_s)),
        ("min_s".into(), JsonValue::Num(s.min_s)),
        ("max_s".into(), JsonValue::Num(s.max_s)),
        ("stddev_s".into(), JsonValue::Num(s.stddev_s)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_report() {
        let mut r = BenchReport::new("unit");
        r.config("n_workers", 4usize)
            .config("label", "smoke")
            .metric("sim_iters_per_sec", 1234.5)
            .metric("total_real_s", 0.25)
            .series(vec![("tau", JsonValue::Num(50.0)), ("ok", JsonValue::Bool(true))]);
        let text = r.to_json().to_string();
        let back = parse(&text).expect("parse own output");
        assert_eq!(back.get("name").and_then(JsonValue::as_str), Some("unit"));
        assert_eq!(
            back.get("config").and_then(|c| c.get("n_workers")).and_then(JsonValue::as_f64),
            Some(4.0)
        );
        assert_eq!(
            back.get("metrics")
                .and_then(|m| m.get("sim_iters_per_sec"))
                .and_then(JsonValue::as_f64),
            Some(1234.5)
        );
        let series = back.get("series").unwrap().items();
        assert_eq!(series.len(), 1);
        assert_eq!(series[0].get("ok").and_then(JsonValue::as_bool), Some(true));
    }

    #[test]
    fn non_finite_serializes_as_null() {
        let v = JsonValue::Obj(vec![("x".into(), JsonValue::Num(f64::NAN))]);
        let text = v.to_string();
        assert!(text.contains("null"), "{text}");
        let back = parse(&text).unwrap();
        assert_eq!(back.get("x"), Some(&JsonValue::Null));
    }

    #[test]
    fn parses_standard_documents() {
        let v = parse(r#"{"a": [1, -2.5e3, true, null, "s\"t\n"], "b": {}}"#).unwrap();
        let a = v.get("a").unwrap().items();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[1].as_f64(), Some(-2500.0));
        assert_eq!(a[2].as_bool(), Some(true));
        assert_eq!(a[3], JsonValue::Null);
        assert_eq!(a[4].as_str(), Some("s\"t\n"));
        assert_eq!(v.get("b").unwrap().entries().len(), 0);
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{} garbage").is_err());
    }

    #[test]
    fn hex_f64_roundtrips_every_class_of_value() {
        for v in [
            0.0,
            -0.0,
            1.5,
            -1.0 / 3.0,
            f64::MIN_POSITIVE,
            f64::MAX,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
        ] {
            let back = f64_from_hex(&hex_f64(v)).unwrap();
            assert_eq!(v.to_bits(), back.to_bits(), "value {v}");
        }
        assert!(f64_from_hex(&JsonValue::Num(1.0)).is_err());
        assert!(f64_from_hex(&JsonValue::Str("zz".into())).is_err());
    }

    #[test]
    fn hex_u128_roundtrips() {
        for v in [0u128, 1, u128::MAX, 0x0123_4567_89ab_cdef_u128] {
            assert_eq!(u128_from_hex(&hex_u128(v)).unwrap(), v);
        }
    }

    #[test]
    fn hex_vectors_and_matrices_roundtrip_through_json_text() {
        let m = vec![vec![1.0, f64::NAN], vec![-0.0, 1e-308]];
        let text = hex_mat(&m).to_string();
        let back = mat_from_hex(&parse(&text).unwrap()).unwrap();
        for (a, b) in m.iter().zip(&back) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn json_usize_bounds() {
        assert_eq!(json_usize(&JsonValue::Num(0.0)), Ok(0));
        assert_eq!(json_usize(&JsonValue::Num(42.0)), Ok(42));
        assert!(json_usize(&JsonValue::Num(-1.0)).is_err());
        assert!(json_usize(&JsonValue::Num(1.5)).is_err());
        assert!(json_usize(&JsonValue::Num(1e16)).is_err());
        assert!(json_usize(&JsonValue::Str("3".into())).is_err());
    }

    #[test]
    fn parse_edge_cases_fail_cleanly() {
        // empty file
        assert!(parse("").is_err());
        assert!(parse("   \n\t").is_err());
        // truncated object/array/string/literal
        assert!(parse("{\"a\"").is_err());
        assert!(parse("{\"a\": 1").is_err());
        assert!(parse("{\"a\": ").is_err());
        assert!(parse("[1, 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("tru").is_err());
        // bad number
        assert!(parse("1e").is_err());
    }

    #[test]
    fn report_schema_validation() {
        let good = parse(r#"{"schema": 1, "name": "x"}"#).unwrap();
        assert_eq!(report_schema(&good), Ok(REPORT_SCHEMA_VERSION));
        // unknown schema version
        let future = parse(r#"{"schema": 99}"#).unwrap();
        assert!(report_schema(&future).unwrap_err().contains("unsupported"));
        // missing / non-numeric schema field
        let missing = parse(r#"{"name": "x"}"#).unwrap();
        assert!(report_schema(&missing).is_err());
        let stringy = parse(r#"{"schema": "1"}"#).unwrap();
        assert!(report_schema(&stringy).is_err());
        // a real BenchReport always validates
        assert_eq!(report_schema(&BenchReport::new("unit").to_json()), Ok(1));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = JsonValue::Str("tab\t\"quote\"\\back\nnl \u{1} end".into());
        let back = parse(&original.to_string()).unwrap();
        assert_eq!(back, original);
    }

    #[test]
    fn stats_block_has_expected_keys() {
        let s = BenchStats::from_samples(vec![1.0, 2.0, 3.0]);
        let o = stats_obj(&s);
        for key in ["samples", "mean_s", "median_s", "p95_s", "min_s", "max_s", "stddev_s"] {
            assert!(o.get(key).is_some(), "missing {key}");
        }
        assert_eq!(o.get("median_s").and_then(JsonValue::as_f64), Some(2.0));
    }
}
