//! Workload generators matching the paper's Section V setups, plus a
//! LIBSVM-format loader for real datasets.

pub mod libsvm;

use std::sync::Arc;

use crate::linalg::dense::DenseMatrix;
use crate::linalg::sparse::CsrMatrix;
use crate::problems::{
    BlockError, BlockPattern, ConsensusProblem, LassoLocal, LocalCost, LogisticLocal, SpcaLocal,
};
use crate::prox::Regularizer;
use crate::rng::Pcg64;

/// The global column indices of worker `i`'s owned slice, in owned order.
fn owned_columns(pattern: &BlockPattern, worker: usize) -> Vec<usize> {
    let mut cols = Vec::with_capacity(pattern.owned_len(worker));
    pattern.for_each_range(worker, |_lo, g, len| {
        for k in 0..len {
            cols.push(g + k);
        }
    });
    cols
}

/// Column-select a dense design matrix (construction-time only).
fn select_columns(a: &DenseMatrix, cols: &[usize]) -> DenseMatrix {
    let mut out = DenseMatrix::zeros(a.rows(), cols.len());
    for r in 0..a.rows() {
        for (c_out, &c_in) in cols.iter().enumerate() {
            out.set(r, c_out, a.get(r, c_in));
        }
    }
    out
}

/// The Fig. 4 LASSO workload (eq. (52)): `A_i ~ N(0,1)^{m×n}`,
/// `b_i = A_i w⁰ + ν_i`, `w⁰` sparse with ≈`sparsity·n` non-zeros,
/// `ν ~ N(0, 0.01)`.
pub struct LassoInstance {
    pub blocks: Vec<DenseMatrix>,
    pub rhs: Vec<Vec<f64>>,
    /// The planted sparse signal.
    pub w_true: Vec<f64>,
    pub theta: f64,
}

impl LassoInstance {
    /// Generate with the paper's defaults (`noise_var = 0.01` → sd 0.1).
    pub fn synthetic(
        rng: &mut Pcg64,
        n_workers: usize,
        m_per_worker: usize,
        n: usize,
        sparsity: f64,
        theta: f64,
    ) -> Self {
        // planted signal: ≈ sparsity·n non-zeros
        let nnz = ((n as f64 * sparsity).round() as usize).clamp(1, n);
        let mut w_true = vec![0.0; n];
        for idx in rng.sample_indices(n, nnz) {
            w_true[idx] = rng.normal();
        }
        let mut blocks = Vec::with_capacity(n_workers);
        let mut rhs = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            let a = DenseMatrix::randn(rng, m_per_worker, n);
            let mut b = a.matvec(&w_true);
            for v in b.iter_mut() {
                *v += rng.normal_ms(0.0, 0.1);
            }
            blocks.push(a);
            rhs.push(b);
        }
        LassoInstance { blocks, rhs, w_true, theta }
    }

    /// Assemble the consensus problem (4).
    pub fn problem(&self) -> ConsensusProblem {
        let locals: Vec<Arc<dyn LocalCost>> = self
            .blocks
            .iter()
            .zip(&self.rhs)
            .map(|(a, b)| Arc::new(LassoLocal::new(a.clone(), b.clone())) as Arc<dyn LocalCost>)
            .collect();
        ConsensusProblem::new(locals, Regularizer::L1 { theta: self.theta })
    }

    /// Block-sharded general-form consensus over this instance: worker i
    /// fits only its owned feature blocks,
    /// `f_i(w) = ‖A_i[:, S_i] w − b_i‖²` with `w ∈ ℝ^{|S_i|}`, so every
    /// message (and the master's per-coordinate reduction) shrinks to the
    /// owned slice. Overlapping patterns (several workers sharing feature
    /// blocks) are the general-form scenario of arXiv:1802.08882.
    pub fn sharded_problem(
        &self,
        pattern: &BlockPattern,
    ) -> Result<ConsensusProblem, BlockError> {
        // Checked up front: the column-selection loop below indexes the
        // pattern per worker and the instance's matrices per global
        // column, so a mismatch must be the typed error, not an index
        // panic (or a silently truncated problem).
        if pattern.num_workers() != self.blocks.len() {
            return Err(BlockError::WorkerCountMismatch {
                pattern: pattern.num_workers(),
                problem: self.blocks.len(),
            });
        }
        if pattern.dim() != self.dim() {
            return Err(BlockError::DimMismatch {
                pattern: pattern.dim(),
                problem: self.dim(),
            });
        }
        let locals: Vec<Arc<dyn LocalCost>> = self
            .blocks
            .iter()
            .zip(&self.rhs)
            .enumerate()
            .map(|(i, (a, b))| {
                let cols = owned_columns(pattern, i);
                Arc::new(LassoLocal::new(select_columns(a, &cols), b.clone()))
                    as Arc<dyn LocalCost>
            })
            .collect();
        ConsensusProblem::sharded(
            locals,
            Regularizer::L1 { theta: self.theta },
            pattern.clone(),
        )
    }

    /// The dense embedding of [`LassoInstance::sharded_problem`]: every
    /// worker keeps a full-width matrix but with the columns *outside* its
    /// owned slice zeroed, so the full-vector protocol minimizes the
    /// identical objective `Σ ‖A_i[:, S_i] x_{S_i} − b_i‖² + θ‖x‖₁`. The
    /// sharded and dense-embedded runs therefore converge to the same
    /// optimum — the apples-to-apples baseline for the sharded-vs-dense
    /// KKT and comm-volume comparisons. Same typed validation as
    /// [`LassoInstance::sharded_problem`].
    pub fn masked_dense_problem(
        &self,
        pattern: &BlockPattern,
    ) -> Result<ConsensusProblem, BlockError> {
        if pattern.num_workers() != self.blocks.len() {
            return Err(BlockError::WorkerCountMismatch {
                pattern: pattern.num_workers(),
                problem: self.blocks.len(),
            });
        }
        if pattern.dim() != self.dim() {
            return Err(BlockError::DimMismatch {
                pattern: pattern.dim(),
                problem: self.dim(),
            });
        }
        let n = self.dim();
        let locals: Vec<Arc<dyn LocalCost>> = self
            .blocks
            .iter()
            .zip(&self.rhs)
            .enumerate()
            .map(|(i, (a, b))| {
                let owned = owned_columns(pattern, i);
                let mut mask = vec![false; n];
                for &c in &owned {
                    mask[c] = true;
                }
                let mut masked = DenseMatrix::zeros(a.rows(), n);
                for r in 0..a.rows() {
                    for c in 0..n {
                        if mask[c] {
                            masked.set(r, c, a.get(r, c));
                        }
                    }
                }
                Arc::new(LassoLocal::new(masked, b.clone())) as Arc<dyn LocalCost>
            })
            .collect();
        Ok(ConsensusProblem::new(locals, Regularizer::L1 { theta: self.theta }))
    }

    pub fn dim(&self) -> usize {
        self.w_true.len()
    }

    pub fn num_workers(&self) -> usize {
        self.blocks.len()
    }
}

/// The Fig. 3 sparse-PCA workload (eq. (50)): each `B_j` is an `m×n` sparse
/// matrix with `nnz` non-zeros ~ N(0,1).
pub struct SparsePcaInstance {
    pub blocks: Vec<CsrMatrix>,
    pub theta: f64,
}

impl SparsePcaInstance {
    pub fn synthetic(
        rng: &mut Pcg64,
        n_workers: usize,
        m: usize,
        n: usize,
        nnz: usize,
        theta: f64,
    ) -> Self {
        let blocks = (0..n_workers).map(|_| CsrMatrix::random(rng, m, n, nnz)).collect();
        SparsePcaInstance { blocks, theta }
    }

    pub fn problem(&self) -> ConsensusProblem {
        let locals: Vec<Arc<dyn LocalCost>> = self
            .blocks
            .iter()
            .map(|b| Arc::new(SpcaLocal::new(b.clone())) as Arc<dyn LocalCost>)
            .collect();
        // h = θ‖·‖₁ restricted to the unit box: Assumption 2 requires
        // dom(h) compact, and without it (50) is unbounded below (−‖Bw‖²
        // beats θ‖w‖₁ at scale). The box also makes this *the* sparse-PCA
        // problem: maximize ‖Bw‖² over the box with an L1 sparsity push.
        ConsensusProblem::new(locals, Regularizer::L1Box { theta: self.theta, bound: 1.0 })
    }

    /// `max_j λmax(B_jᵀB_j)` — input to the paper's `ρ = β·λmax` rule.
    /// (Recomputes the locals; callers that already built the problem can
    /// read it off the `SpcaLocal`s instead.)
    pub fn max_lambda_max(&self) -> f64 {
        self.blocks
            .iter()
            .map(|b| SpcaLocal::new(b.clone()).lambda_max())
            .fold(0.0, f64::max)
    }

    pub fn dim(&self) -> usize {
        self.blocks[0].cols()
    }
}

/// Distributed logistic regression (the Part-II companion workload):
/// separable two-class Gaussian clouds, labels ±1.
pub struct LogisticInstance {
    pub blocks: Vec<DenseMatrix>,
    pub labels: Vec<Vec<f64>>,
    pub w_true: Vec<f64>,
    pub theta: f64,
}

impl LogisticInstance {
    pub fn synthetic(
        rng: &mut Pcg64,
        n_workers: usize,
        m_per_worker: usize,
        n: usize,
        theta: f64,
    ) -> Self {
        let mut w_true = vec![0.0; n];
        rng.fill_normal(&mut w_true);
        let mut blocks = Vec::new();
        let mut labels = Vec::new();
        for _ in 0..n_workers {
            let a = DenseMatrix::randn(rng, m_per_worker, n);
            let margins = a.matvec(&w_true);
            let y: Vec<f64> = margins
                .iter()
                .map(|&mj| {
                    // logistic noise: flip with prob σ(−|m|)
                    let p = 1.0 / (1.0 + (-mj).exp());
                    if rng.uniform() < p {
                        1.0
                    } else {
                        -1.0
                    }
                })
                .collect();
            blocks.push(a);
            labels.push(y);
        }
        LogisticInstance { blocks, labels, w_true, theta }
    }

    pub fn problem(&self) -> ConsensusProblem {
        let locals: Vec<Arc<dyn LocalCost>> = self
            .blocks
            .iter()
            .zip(&self.labels)
            .map(|(a, y)| {
                Arc::new(LogisticLocal::new(a.clone(), y.clone())) as Arc<dyn LocalCost>
            })
            .collect();
        ConsensusProblem::new(locals, Regularizer::L1 { theta: self.theta })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lasso_shapes_and_sparsity() {
        let mut rng = Pcg64::seed_from_u64(61);
        let inst = LassoInstance::synthetic(&mut rng, 4, 30, 50, 0.05, 0.1);
        assert_eq!(inst.blocks.len(), 4);
        assert_eq!(inst.rhs.len(), 4);
        assert_eq!(inst.blocks[0].rows(), 30);
        assert_eq!(inst.blocks[0].cols(), 50);
        let nnz = inst.w_true.iter().filter(|v| **v != 0.0).count();
        assert!(nnz >= 1 && nnz <= 5, "nnz={nnz}"); // ≈ 0.05·50 = 2.5
        let p = inst.problem();
        assert_eq!(p.num_workers(), 4);
        assert_eq!(p.dim(), 50);
    }

    #[test]
    fn lasso_signal_explains_rhs() {
        // With low noise, residual at w_true should be far below ||b||.
        let mut rng = Pcg64::seed_from_u64(62);
        let inst = LassoInstance::synthetic(&mut rng, 2, 40, 20, 0.2, 0.1);
        for (a, b) in inst.blocks.iter().zip(&inst.rhs) {
            let pred = a.matvec(&inst.w_true);
            let res: f64 = pred.iter().zip(b).map(|(p, bi)| (p - bi).powi(2)).sum();
            let total: f64 = b.iter().map(|v| v * v).sum();
            assert!(res < 0.3 * total.max(1.0), "res={res} total={total}");
        }
    }

    #[test]
    fn sharded_lasso_matches_its_dense_embedding() {
        let mut rng = Pcg64::seed_from_u64(65);
        let inst = LassoInstance::synthetic(&mut rng, 4, 20, 12, 0.2, 0.1);
        let pattern = BlockPattern::round_robin(12, 4, 4, 2).unwrap();
        let sharded = inst.sharded_problem(&pattern).unwrap();
        assert_eq!(sharded.dim(), 12);
        for i in 0..4 {
            assert_eq!(sharded.local(i).dim(), pattern.owned_len(i));
        }
        let dense = inst.masked_dense_problem(&pattern).unwrap();
        assert_eq!(dense.dim(), 12);
        assert!(dense.pattern().is_none());
        // The dense embedding minimizes the identical objective: the two
        // must agree at any shared consensus point.
        let x: Vec<f64> = (0..12).map(|j| (j as f64 * 0.3).sin()).collect();
        assert!(
            (sharded.objective(&x) - dense.objective(&x)).abs() < 1e-9,
            "sharded {} vs dense-embedded {}",
            sharded.objective(&x),
            dense.objective(&x)
        );
    }

    #[test]
    fn spca_instance_matches_paper_shape() {
        let mut rng = Pcg64::seed_from_u64(63);
        let inst = SparsePcaInstance::synthetic(&mut rng, 3, 100, 50, 500, 0.1);
        assert_eq!(inst.blocks.len(), 3);
        assert_eq!(inst.blocks[0].nnz(), 500);
        assert!(inst.max_lambda_max() > 0.0);
        let p = inst.problem();
        assert_eq!(p.dim(), 50);
    }

    #[test]
    fn logistic_labels_pm1() {
        let mut rng = Pcg64::seed_from_u64(64);
        let inst = LogisticInstance::synthetic(&mut rng, 2, 25, 8, 0.05);
        for y in &inst.labels {
            // ad-lint: allow(float-eq): labels are exact ±1.0 sentinels assigned by the generator
            assert!(y.iter().all(|&v| v == 1.0 || v == -1.0));
        }
        let p = inst.problem();
        assert_eq!(p.num_workers(), 2);
    }
}
