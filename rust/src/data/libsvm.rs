//! LIBSVM-format dataset loader — the standard interchange for the
//! classification/regression workloads the paper's applications section
//! targets (LASSO, logistic regression, SVM).
//!
//! Format: one sample per line, `label idx:val idx:val ...`, 1-based
//! indices. The loader densifies (problem dims here are small) and can
//! shard samples across `N` workers, matching the paper's "training
//! samples uniformly distributed over the workers".

use std::path::Path;

use crate::linalg::dense::DenseMatrix;

/// A dense-ified LIBSVM dataset.
#[derive(Clone, Debug)]
pub struct LibsvmDataset {
    /// `m × n` feature matrix.
    pub features: DenseMatrix,
    /// `m` labels (as given; ±1 for classification).
    pub labels: Vec<f64>,
}

impl LibsvmDataset {
    /// Parse from text. `n_features = None` infers the max index.
    pub fn parse(text: &str, n_features: Option<usize>) -> Result<Self, String> {
        let mut rows: Vec<(f64, Vec<(usize, f64)>)> = Vec::new();
        let mut max_idx = 0usize;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let mut toks = line.split_whitespace();
            // A whitespace-only line has no tokens even though it is
            // non-empty; treat it like a bad label, not a panic.
            let label: f64 = toks
                .next()
                .ok_or_else(|| format!("line {}: missing label", lineno + 1))?
                .parse()
                .map_err(|_| format!("line {}: bad label", lineno + 1))?;
            let mut feats = Vec::new();
            for tok in toks {
                let (i, v) = tok
                    .split_once(':')
                    .ok_or_else(|| format!("line {}: expected idx:val, got {tok:?}", lineno + 1))?;
                let idx: usize = i
                    .parse()
                    .map_err(|_| format!("line {}: bad index {i:?}", lineno + 1))?;
                if idx == 0 {
                    return Err(format!("line {}: LIBSVM indices are 1-based", lineno + 1));
                }
                let val: f64 = v
                    .parse()
                    .map_err(|_| format!("line {}: bad value {v:?}", lineno + 1))?;
                max_idx = max_idx.max(idx);
                feats.push((idx - 1, val));
            }
            rows.push((label, feats));
        }
        let n = n_features.unwrap_or(max_idx);
        if max_idx > n {
            return Err(format!("feature index {max_idx} exceeds declared n_features {n}"));
        }
        let m = rows.len();
        let mut features = DenseMatrix::zeros(m, n);
        let mut labels = Vec::with_capacity(m);
        for (r, (label, feats)) in rows.into_iter().enumerate() {
            labels.push(label);
            for (c, v) in feats {
                features.set(r, c, v);
            }
        }
        Ok(LibsvmDataset { features, labels })
    }

    /// Load from a file.
    pub fn load(path: &Path, n_features: Option<usize>) -> Result<Self, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::parse(&text, n_features)
    }

    pub fn num_samples(&self) -> usize {
        self.labels.len()
    }

    pub fn num_features(&self) -> usize {
        self.features.cols()
    }

    /// Shard samples round-robin across `n_workers` blocks (the paper's
    /// uniform distribution of training data).
    pub fn shard(&self, n_workers: usize) -> Vec<(DenseMatrix, Vec<f64>)> {
        assert!(n_workers >= 1);
        let n = self.num_features();
        let mut shards: Vec<(Vec<f64>, Vec<f64>)> = vec![(Vec::new(), Vec::new()); n_workers];
        for r in 0..self.num_samples() {
            let w = r % n_workers;
            shards[w].0.extend_from_slice(self.features.row(r));
            shards[w].1.push(self.labels[r]);
        }
        shards
            .into_iter()
            .map(|(data, labels)| {
                let rows = labels.len();
                (DenseMatrix::from_vec(rows, n, data), labels)
            })
            .collect()
    }

    /// Serialize back to LIBSVM text (round-trip/testing, sparse output).
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for r in 0..self.num_samples() {
            out.push_str(&format!("{}", self.labels[r]));
            for (c, &v) in self.features.row(r).iter().enumerate() {
                if v != 0.0 {
                    out.push_str(&format!(" {}:{}", c + 1, v));
                }
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
+1 1:0.5 3:2.0   # comment
-1 2:1.5
+1 1:1.0 2:-0.5 3:0.25
";

    #[test]
    fn parses_dense_shape_and_values() {
        let d = LibsvmDataset::parse(SAMPLE, None).unwrap();
        assert_eq!(d.num_samples(), 3);
        assert_eq!(d.num_features(), 3);
        assert_eq!(d.labels, vec![1.0, -1.0, 1.0]);
        assert_eq!(d.features.get(0, 0), 0.5);
        assert_eq!(d.features.get(0, 2), 2.0);
        assert_eq!(d.features.get(1, 1), 1.5);
        assert_eq!(d.features.get(1, 0), 0.0);
    }

    #[test]
    fn explicit_feature_count() {
        let d = LibsvmDataset::parse(SAMPLE, Some(5)).unwrap();
        assert_eq!(d.num_features(), 5);
        assert!(LibsvmDataset::parse(SAMPLE, Some(2)).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(LibsvmDataset::parse("+1 0:1.0\n", None).is_err()); // 0-based
        assert!(LibsvmDataset::parse("+1 a:1.0\n", None).is_err());
        assert!(LibsvmDataset::parse("+1 1-1.0\n", None).is_err());
    }

    #[test]
    fn round_trip() {
        let d = LibsvmDataset::parse(SAMPLE, None).unwrap();
        let d2 = LibsvmDataset::parse(&d.to_text(), Some(3)).unwrap();
        assert_eq!(d.labels, d2.labels);
        assert!(d.features.max_abs_diff(&d2.features) < 1e-12);
    }

    #[test]
    fn sharding_partitions_samples() {
        let d = LibsvmDataset::parse(SAMPLE, None).unwrap();
        let shards = d.shard(2);
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].1.len() + shards[1].1.len(), 3);
        assert_eq!(shards[0].0.cols(), 3);
        // worker 0 gets samples 0 and 2
        assert_eq!(shards[0].1, vec![1.0, 1.0]);
    }

    #[test]
    fn shards_feed_the_solver() {
        use crate::problems::{ConsensusProblem, LassoLocal, LocalCost};
        use crate::prox::Regularizer;
        use std::sync::Arc;
        let d = LibsvmDataset::parse(SAMPLE, None).unwrap();
        let locals: Vec<Arc<dyn LocalCost>> = d
            .shard(2)
            .into_iter()
            .map(|(a, b)| Arc::new(LassoLocal::new(a, b)) as Arc<dyn LocalCost>)
            .collect();
        let p = ConsensusProblem::new(locals, Regularizer::L1 { theta: 0.01 });
        let cfg = crate::admm::AdmmConfig { rho: 5.0, max_iters: 200, ..Default::default() };
        let out = crate::testkit::drivers::run_full_barrier(&p, &cfg);
        let r = crate::admm::kkt::kkt_residual(&p, &out.state);
        assert!(r.max() < 1e-5, "{r:?}");
    }
}
