//! # ad-admm — Asynchronous Distributed ADMM (Part I)
//!
//! A full reproduction of *"Asynchronous Distributed ADMM for Large-Scale
//! Optimization — Part I: Algorithm and Convergence Analysis"* (Chang, Hong,
//! Liao, Wang; 2015/2016) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the paper's contribution: the asynchronous star
//!   master/worker coordinator (Algorithm 2), the serial master-point-of-view
//!   simulator used for the paper's figures (Algorithm 3), the synchronous
//!   baseline (Algorithm 1) and the cautionary alternative scheme
//!   (Algorithm 4), plus every substrate they stand on (linear algebra, RNG,
//!   config/CLI, metrics, a threaded star cluster).
//! - **L2/L1 (build time, `python/`)** — JAX compute graphs for the worker
//!   subproblem solves and the master prox step, with the hot-spot Gram
//!   mat-vec and soft-threshold written as Pallas kernels; AOT-lowered to
//!   HLO text under `artifacts/` and executed from Rust through PJRT
//!   ([`runtime`]).
//!
//! ## Quickstart
//!
//! The public face is the [`admm::session::Session`] builder: build-time
//! validation (typed [`admm::session::EngineError`], no panics on user
//! input), incremental `step()` execution, streaming
//! [`admm::session::Observer`]s instead of mandatory history buffering,
//! and bit-identical [`admm::session::Checkpoint`]/resume.
//!
//! ```no_run
//! use ad_admm::prelude::*;
//!
//! let mut rng = Pcg64::seed_from_u64(7);
//! let inst = LassoInstance::synthetic(&mut rng, 4, 50, 20, 0.05, 0.1);
//! let problem = inst.problem();
//! let mut history = BufferingObserver::new();
//! let mut session = Session::builder()
//!     .problem(&problem)
//!     .config(AdmmConfig { rho: 50.0, tau: 5, max_iters: 400, ..Default::default() })
//!     .policy(PartialBarrier { tau: 5 })
//!     .arrivals(&ArrivalModel::probabilistic(vec![0.5; 4], 1))
//!     .observer(&mut history)
//!     .build()
//!     .expect("valid config");
//! session.run_to_completion().expect("run");
//! drop(session);
//! println!("final objective {}", history.records().last().unwrap().objective);
//! ```
//!
//! Long-horizon runs can `step()` one master iteration at a time,
//! checkpoint mid-run and resume bit-identically — see
//! [`admm::session`] and the `quickstart` example.

// Numeric-kernel style: indexed loops over several slices at once are the
// clearest way to write the BLAS-1-ish hot paths, and the coordinator entry
// points legitimately take many scalar knobs.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::manual_range_contains)]

pub mod admm;
pub mod analysis;
pub mod bench;
pub mod cluster;
pub mod data;
pub mod linalg;
pub mod metrics;
pub mod problems;
pub mod prox;
pub mod rng;
pub mod runtime;
pub mod solvers;
pub mod testkit;
pub mod util;

/// One-stop import for examples and downstream users.
pub mod prelude {
    // The deprecated free-function drivers stay publicly re-exported for
    // downstream compatibility (re-exporting a deprecated item needs the
    // allow); in-tree callers use `Session::builder()` or
    // `testkit::drivers` instead.
    #[allow(deprecated)]
    pub use crate::admm::alt_scheme::run_alt_scheme;
    pub use crate::admm::alt_scheme::AltSchemeOutput;
    pub use crate::admm::arrivals::{ArrivalModel, ArrivalTrace};
    #[allow(deprecated)]
    pub use crate::admm::engine::run_trace_driven;
    #[allow(deprecated)]
    pub use crate::admm::engine::{LegacySourceAdapter, LegacyWorkerSource};
    pub use crate::admm::engine::{
        run_engine, ActiveSet, AltScheme, DelaySpike, EngineOptions, EngineRun, FaultPlan,
        FullBarrier, Outage, PartialBarrier, StepOrder, TraceSource, UpdatePolicy, WorkerSource,
    };
    #[allow(deprecated)]
    pub use crate::admm::master_pov::run_master_pov;
    pub use crate::admm::master_pov::MasterPovOutput;
    pub use crate::admm::params::{
        gamma_lower_bound, rho_lower_bound_convex, rho_lower_bound_nonconvex,
    };
    pub use crate::admm::session::{
        BufferingObserver, Checkpoint, EngineError, Observer, Session, SessionBuilder,
        SessionOutcome, StepStatus,
    };
    #[allow(deprecated)]
    pub use crate::admm::sync::run_sync_admm;
    pub use crate::admm::{AdmmConfig, AdmmState, IterRecord, SparseView, StopReason};
    pub use crate::cluster::{
        ClusterConfig, ClusterConfigBuilder, ClusterReport, DelayModel, ExecutionMode, Protocol,
        StarCluster, VirtualSource,
    };
    pub use crate::data::{LassoInstance, LogisticInstance, SparsePcaInstance};
    pub use crate::linalg::dense::DenseMatrix;
    pub use crate::linalg::sparse::CsrMatrix;
    pub use crate::metrics::RunLog;
    pub use crate::problems::{BlockError, BlockPattern, ConsensusProblem, LocalCost};
    pub use crate::prox::Regularizer;
    pub use crate::rng::Pcg64;
    pub use crate::runtime::{ArtifactRegistry, PjrtEngine};
    pub use crate::solvers::fista::fista_lasso;
    pub use crate::solvers::inexact::{InexactPolicy, WarmState};
}
