//! Deterministic pseudo-random number generation.
//!
//! The offline image carries no `rand` crate, so this module provides the
//! PCG-64 (XSL-RR 128/64) generator plus the distributions the repo needs:
//! uniform, Bernoulli, standard normal (Box–Muller), permutations and sparse
//! supports. Everything is seedable and reproducible across runs, which the
//! figure benches rely on.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
///
/// Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
/// Statistically Good Algorithms for Random Number Generation".
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed with a stream selector derived from the seed itself.
    pub fn seed_from_u64(seed: u64) -> Self {
        // SplitMix64 to spread the seed over the 128-bit state/stream.
        let mut sm = SplitMix64 { state: seed };
        let s0 = sm.next();
        let s1 = sm.next();
        let i0 = sm.next();
        let i1 = sm.next();
        let mut rng = Pcg64 {
            state: ((s0 as u128) << 64) | s1 as u128,
            inc: (((i0 as u128) << 64) | i1 as u128) | 1,
        };
        // Burn a few outputs so nearby seeds decorrelate.
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    /// The raw `(state, increment)` pair — everything the generator is.
    /// Used by session checkpoints to serialize RNG streams exactly, so a
    /// resumed run draws the same sequence bit-for-bit.
    pub fn to_raw(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Pcg64::to_raw`] output. No seeding or
    /// warm-up: the next draw continues the saved stream.
    pub fn from_raw(state: u128, inc: u128) -> Self {
        Pcg64 { state, inc }
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        let s = self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Pcg64::seed_from_u64(s)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53-bit mantissa trick.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (pairs are not cached; simpler and
    /// still fast enough for data generation).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with the given mean / standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Log-normal: exp(N(mu, sigma)). Used by the cluster delay models.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with the given rate.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let u = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Fill a slice with standard normal entries.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        if n < 2 {
            return;
        }
        for i in (1..n).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices from [0, n) (order randomized).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

/// SplitMix64 — seed expander for PCG.
struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    fn next(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seed_from_u64(42);
        let mut b = Pcg64::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed_from_u64(1);
        let mut b = Pcg64::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval_with_sane_mean() {
        let mut rng = Pcg64::seed_from_u64(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed_from_u64(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_unbiased_over_small_range() {
        let mut rng = Pcg64::seed_from_u64(11);
        let mut counts = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[rng.below(5) as usize] += 1;
        }
        for c in counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.2).abs() < 0.02, "frac={frac}");
        }
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Pcg64::seed_from_u64(13);
        let hits = (0..50_000).filter(|_| rng.bernoulli(0.8)).count();
        let rate = hits as f64 / 50_000.0;
        assert!((rate - 0.8).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::seed_from_u64(17);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Pcg64::seed_from_u64(19);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::seed_from_u64(23);
        let idx = rng.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }

    #[test]
    fn raw_state_roundtrip_continues_the_stream() {
        let mut a = Pcg64::seed_from_u64(99);
        for _ in 0..10 {
            a.next_u64();
        }
        let (state, inc) = a.to_raw();
        let mut b = Pcg64::from_raw(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_decorrelate() {
        let mut root = Pcg64::seed_from_u64(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn lognormal_positive() {
        let mut rng = Pcg64::seed_from_u64(29);
        for _ in 0..1000 {
            assert!(rng.lognormal(0.0, 1.0) > 0.0);
        }
    }
}
