//! Logistic-regression local cost:
//! `f_i(w) = Σ_j log(1 + exp(−y_j a_jᵀ w))`, labels `y ∈ {−1, +1}`.
//!
//! This is the Part-II companion workload (large-scale LR on a cluster).
//! The subproblem has no closed form; it is solved by damped Newton with a
//! Cholesky on `∇²f + ρI` — a handful of O(n³) steps, fine at these dims
//! (and the L2/L1 PJRT path exists for the quadratic workloads instead).

use super::{LocalCost, WorkerScratch};
use crate::linalg::cholesky::Cholesky;
use crate::linalg::dense::DenseMatrix;
use crate::linalg::power::power_iteration;
use crate::linalg::vecops;

pub struct LogisticLocal {
    a: DenseMatrix,
    y: Vec<f64>,
    /// λmax(AᵀA) — Hessian bound `∇²f ⪯ ¼ AᵀA`.
    lam_max: f64,
    /// Newton iteration cap for the subproblem solve.
    newton_iters: usize,
    newton_tol: f64,
}

impl LogisticLocal {
    pub fn new(a: DenseMatrix, y: Vec<f64>) -> Self {
        assert_eq!(a.rows(), y.len());
        // ad-lint: allow(float-eq): labels are exact ±1.0 sentinels assigned by the generator, never computed
        assert!(y.iter().all(|&v| v == 1.0 || v == -1.0), "labels must be ±1");
        let gram = a.gram();
        let n = a.cols();
        let (lam_max, _) =
            power_iteration(|v, out| gram.matvec_into(v, out), n, 300, 1e-9, 0x106);
        LogisticLocal { a, y, lam_max: lam_max.max(0.0), newton_iters: 30, newton_tol: 1e-10 }
    }

    fn margins(&self, x: &[f64]) -> Vec<f64> {
        // m_j = y_j a_jᵀ x
        let mut m = self.a.matvec(x);
        for (mj, yj) in m.iter_mut().zip(&self.y) {
            *mj *= yj;
        }
        m
    }

    /// `margins` into a caller buffer (resized to `rows`) — the hot path.
    fn margins_into(&self, x: &[f64], m: &mut Vec<f64>) {
        m.resize(self.a.rows(), 0.0);
        self.a.matvec_into(x, m);
        for (mj, yj) in m.iter_mut().zip(&self.y) {
            *mj *= yj;
        }
    }

    /// `f(x)` through a caller-owned margin buffer (bit-identical to
    /// [`LocalCost::eval`]; separate from `eval_with` so the line search in
    /// `solve_subproblem` can evaluate while other scratch fields are
    /// borrowed).
    fn loss_with(&self, x: &[f64], m: &mut Vec<f64>) -> f64 {
        self.margins_into(x, m);
        m.iter().map(|&mj| log1p_exp_neg(mj)).sum()
    }

    /// `iters` damped-Newton steps on
    /// `g(x) = f(x) + xᵀλ + ρ/2‖x − x0‖²` from the *current* `out`
    /// (callers choose the start: `x0` for the exact solve, the previous
    /// iterate for the capped warm-started path). Vector temporaries live
    /// in `scratch` (`rows` = margins, `rows2` = Newton weights / Hessian
    /// diagonal, `grad`/`step`/`trial` as named); only the n×n Hessian and
    /// its factorization still allocate per Newton step — they are
    /// factor-sized, not iteration-hot-loop-sized.
    fn newton(
        &self,
        iters: usize,
        lam: &[f64],
        x0: &[f64],
        rho: f64,
        out: &mut [f64],
        scratch: &mut WorkerScratch,
    ) {
        let n = self.dim();
        let mrows = self.a.rows();
        let WorkerScratch { rows, rows2, grad, step, trial, .. } = scratch;
        grad.resize(n, 0.0);
        step.resize(n, 0.0);
        trial.resize(n, 0.0);
        rows2.resize(mrows, 0.0);

        for _ in 0..iters {
            // gradient of g: ∇f = Aᵀw with w_j = −σ(−m_j) y_j
            self.margins_into(out, rows);
            for j in 0..mrows {
                rows2[j] = -sigma_neg(rows[j]) * self.y[j];
            }
            self.a.matvec_t_into(rows2, grad);
            for i in 0..n {
                grad[i] += lam[i] + rho * (out[i] - x0[i]);
            }
            if vecops::nrm2(grad) < self.newton_tol * (1.0 + vecops::nrm2(out)) {
                break;
            }
            // Hessian: Aᵀ D A + ρI, D_jj = σ(−m)σ(m); margins still in `rows`
            for j in 0..mrows {
                let s = sigma_neg(rows[j]);
                rows2[j] = s * (1.0 - s);
            }
            let mut h = DenseMatrix::zeros(n, n);
            for r in 0..mrows {
                let d = rows2[r];
                if d <= 1e-14 {
                    continue;
                }
                let row = self.a.row(r);
                for i in 0..n {
                    let di = d * row[i];
                    if di == 0.0 {
                        continue;
                    }
                    for j in 0..n {
                        let cur = h.get(i, j);
                        h.set(i, j, cur + di * row[j]);
                    }
                }
            }
            h.add_diag(rho);
            let chol = match Cholesky::factor(&h) {
                Ok(c) => c,
                Err(_) => break, // ρ > 0 should prevent this; bail defensively
            };
            step.copy_from_slice(grad);
            chol.solve_in_place(step);
            // backtracking line search on g
            let g0 = self.loss_with(out, rows)
                + vecops::dot(out, lam)
                + 0.5 * rho * vecops::dist2_sq(out, x0);
            let mut t = 1.0;
            let slope = vecops::dot(grad, step);
            for _ in 0..30 {
                for i in 0..n {
                    trial[i] = out[i] - t * step[i];
                }
                let g1 = self.loss_with(trial, rows)
                    + vecops::dot(trial, lam)
                    + 0.5 * rho * vecops::dist2_sq(trial, x0);
                if g1 <= g0 - 1e-4 * t * slope {
                    break;
                }
                t *= 0.5;
            }
            for i in 0..n {
                out[i] -= t * step[i];
            }
        }
    }
}

/// Numerically-stable `log(1 + e^{-m})`.
#[inline]
fn log1p_exp_neg(m: f64) -> f64 {
    if m > 0.0 {
        (-m).exp().ln_1p()
    } else {
        -m + m.exp().ln_1p()
    }
}

/// Stable logistic sigmoid σ(−m) = 1/(1+e^{m}).
#[inline]
fn sigma_neg(m: f64) -> f64 {
    if m >= 0.0 {
        let e = (-m).exp();
        e / (1.0 + e)
    } else {
        1.0 / (1.0 + m.exp())
    }
}

impl LocalCost for LogisticLocal {
    fn dim(&self) -> usize {
        self.a.cols()
    }

    fn eval(&self, x: &[f64]) -> f64 {
        self.margins(x).iter().map(|&m| log1p_exp_neg(m)).sum()
    }

    fn eval_with(&self, x: &[f64], scratch: &mut WorkerScratch) -> f64 {
        self.loss_with(x, &mut scratch.rows)
    }

    fn grad_into(&self, x: &[f64], out: &mut [f64]) {
        // ∇f = −Σ_j σ(−m_j) y_j a_j
        let m = self.margins(x);
        let mut w = vec![0.0; m.len()];
        for j in 0..m.len() {
            w[j] = -sigma_neg(m[j]) * self.y[j];
        }
        self.a.matvec_t_into(&w, out);
    }

    fn lipschitz(&self) -> f64 {
        0.25 * self.lam_max
    }

    fn solve_subproblem(
        &self,
        lam: &[f64],
        x0: &[f64],
        rho: f64,
        out: &mut [f64],
        scratch: &mut WorkerScratch,
    ) {
        out.copy_from_slice(x0); // warm start at the consensus point
        self.newton(self.newton_iters, lam, x0, rho, out, scratch);
    }

    fn solve_subproblem_capped(
        &self,
        steps: usize,
        lam: &[f64],
        x0: &[f64],
        rho: f64,
        out: &mut [f64],
        scratch: &mut WorkerScratch,
    ) -> bool {
        // `out` arrives pre-initialized (the inexact-policy warm start).
        self.newton(steps, lam, x0, rho, out, scratch);
        true
    }

    fn kind(&self) -> &'static str {
        "logistic"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::tests::{check_grad, check_subproblem};
    use crate::rng::Pcg64;

    fn inst(seed: u64, m: usize, n: usize) -> LogisticLocal {
        let mut rng = Pcg64::seed_from_u64(seed);
        let a = DenseMatrix::randn(&mut rng, m, n);
        let y: Vec<f64> = (0..m).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        LogisticLocal::new(a, y)
    }

    #[test]
    fn eval_at_zero_is_m_log2() {
        let l = inst(51, 20, 5);
        let f0 = l.eval(&[0.0; 5]);
        assert!((f0 - 20.0 * std::f64::consts::LN_2).abs() < 1e-10);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let l = inst(52, 15, 6);
        let x: Vec<f64> = (0..6).map(|i| 0.2 * (i as f64).sin()).collect();
        check_grad(&l, &x, 1e-4);
    }

    #[test]
    fn subproblem_stationarity_via_newton() {
        let l = inst(53, 25, 6);
        check_subproblem(&l, 2.0, 1e-6);
    }

    #[test]
    fn stable_for_large_margins() {
        let l = inst(54, 10, 3);
        let big = vec![50.0, -50.0, 30.0];
        assert!(l.eval(&big).is_finite());
        let mut g = vec![0.0; 3];
        l.grad_into(&big, &mut g);
        assert!(g.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn log1p_exp_neg_stable() {
        assert!((log1p_exp_neg(0.0) - std::f64::consts::LN_2).abs() < 1e-12);
        assert!(log1p_exp_neg(1000.0) < 1e-300);
        assert!((log1p_exp_neg(-1000.0) - 1000.0).abs() < 1e-9);
    }
}
