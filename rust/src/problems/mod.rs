//! Problem definitions: the consensus form (4) and its local costs `f_i`.
//!
//! A [`ConsensusProblem`] is `min Σ f_i(x_i) + h(x₀)  s.t. x_i = x₀`; each
//! `f_i` is a [`LocalCost`] living on one worker. Every local cost knows how
//! to solve its own ADMM subproblem (13)/(19)
//! `argmin f_i(x) + xᵀλ + ρ/2‖x − x₀‖²` — in closed form through a cached
//! factorization where possible — because that solve *is* the worker's whole
//! job in Algorithm 2.

pub mod blocks;
pub mod cache;
pub mod lasso;
pub mod logistic;
pub mod quadratic;
pub mod ridge;
pub mod spca;
pub mod svm;

pub use blocks::{BlockError, BlockPattern};
pub use lasso::LassoLocal;
pub use logistic::LogisticLocal;
pub use quadratic::QuadraticLocal;
pub use ridge::RidgeLocal;
pub use spca::SpcaLocal;
pub use svm::SvmLocal;

use crate::prox::Regularizer;
use std::sync::Arc;

/// Reusable per-worker buffers for the per-iteration hot path.
///
/// One instance is owned by each worker-side execution context — a thread
/// of the real-thread cluster, a `VirtualWorker` of the discrete-event
/// simulator, a `NativeSolver` in the serial coordinators — and threaded
/// into [`LocalCost::solve_subproblem`] / [`LocalCost::eval_with`], so the
/// steady-state iteration performs no heap allocation: buffers grow to the
/// local block's dimensions on first use and are reused thereafter.
///
/// The fields are generic storage named by the dimension they carry; each
/// implementation documents what it keeps in them. Contents are undefined
/// between calls: callers must not read them, and implementations must
/// fully overwrite whatever they use.
#[derive(Debug, Default)]
pub struct WorkerScratch {
    /// Row-dimension (`m`) buffer: residuals `A x − b`, margins, CSR rows.
    pub rows: Vec<f64>,
    /// Second row-dimension buffer: Newton weights / Hessian diagonals.
    pub rows2: Vec<f64>,
    /// Shared-dimension (`n`) buffer: subproblem gradients.
    pub grad: Vec<f64>,
    /// Shared-dimension buffer: Newton steps.
    pub step: Vec<f64>,
    /// Shared-dimension buffer: line-search trial points.
    pub trial: Vec<f64>,
    /// Global-dimension buffer: owned-slice gathers of `x₀` under a
    /// block-sharded pattern ([`BlockPattern`]). Kept separate from the
    /// solver buffers above so a gather is never clobbered by the
    /// `eval_with`/`solve_subproblem` call it feeds.
    pub gather: Vec<f64>,
}

impl WorkerScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// One worker's smooth cost `f_i` (Assumption 2: twice differentiable with
/// `L`-Lipschitz gradient; convexity **not** required).
pub trait LocalCost: Send + Sync {
    /// Dimension `n` of the shared variable.
    fn dim(&self) -> usize;

    /// `f_i(x)`.
    fn eval(&self, x: &[f64]) -> f64;

    /// `f_i(x)` through caller-owned scratch — the hot-loop variant used by
    /// every coordinator for the `f_i(x_i)` cache refresh and the objective
    /// diagnostics. Must be **bit-identical** to [`LocalCost::eval`] (the
    /// cross-mode reproducibility tests rely on it); the default delegates,
    /// and implementations with internal temporaries override it to reuse
    /// `scratch` instead of allocating.
    fn eval_with(&self, x: &[f64], scratch: &mut WorkerScratch) -> f64 {
        let _ = scratch;
        self.eval(x)
    }

    /// `∇f_i(x)` into `out`.
    fn grad_into(&self, x: &[f64], out: &mut [f64]);

    /// A Lipschitz constant of `∇f_i` (used by the Theorem-1 rules).
    fn lipschitz(&self) -> f64;

    /// Solve the worker subproblem
    /// `out = argmin_x f_i(x) + xᵀλ + ρ/2‖x − x₀‖²` (eq. (13)).
    ///
    /// Implementations cache any `ρ`-dependent factorization internally, so
    /// repeated calls at the same `ρ` are cheap (the per-iteration path),
    /// and keep their vector temporaries in `scratch` so the steady state
    /// allocates nothing (closed-form solves need no temporaries at all;
    /// the Newton-based costs document what they stash where).
    fn solve_subproblem(
        &self,
        lam: &[f64],
        x0: &[f64],
        rho: f64,
        out: &mut [f64],
        scratch: &mut WorkerScratch,
    );

    /// Run at most `steps` iterations of the implementation's *own*
    /// iterative subproblem solver, starting from the caller-initialized
    /// `out` (the warm start of
    /// [`crate::solvers::inexact::InexactPolicy::NewtonSteps`]) instead of
    /// iterating to the internal tolerance. Returns `true` when handled;
    /// the default `false` marks costs with no iterative solver — their
    /// closed-form solve is exact at any budget, and
    /// [`crate::solvers::inexact::solve_inexact`] falls back to
    /// [`LocalCost::solve_subproblem`].
    #[allow(unused_variables)]
    fn solve_subproblem_capped(
        &self,
        steps: usize,
        lam: &[f64],
        x0: &[f64],
        rho: f64,
        out: &mut [f64],
        scratch: &mut WorkerScratch,
    ) -> bool {
        false
    }

    /// Human-readable kind tag (artifact lookup + logs).
    fn kind(&self) -> &'static str;
}

/// The consensus problem (4): `N` local costs plus the shared regularizer.
///
/// Two forms:
///
/// - **Dense** ([`ConsensusProblem::new`], the historical form): every
///   local cost lives on the full shared dimension and the consensus
///   constraint is `x_i = x₀`.
/// - **Block-sharded** ([`ConsensusProblem::sharded`]): a [`BlockPattern`]
///   assigns each worker a subset of coordinate blocks; worker i's cost
///   has dimension `|S_i|` and the constraint is the general-form
///   `x_i = (x₀)_{S_i}`. [`ConsensusProblem::dim`] stays the *global*
///   dimension.
#[derive(Clone)]
pub struct ConsensusProblem {
    locals: Vec<Arc<dyn LocalCost>>,
    reg: Regularizer,
    /// Block-ownership map; `None` = the historical dense form.
    pattern: Option<Arc<BlockPattern>>,
}

impl ConsensusProblem {
    pub fn new(locals: Vec<Arc<dyn LocalCost>>, reg: Regularizer) -> Self {
        assert!(!locals.is_empty(), "need at least one worker");
        let n = locals[0].dim();
        assert!(locals.iter().all(|l| l.dim() == n), "all locals must share dim");
        ConsensusProblem { locals, reg, pattern: None }
    }

    /// Block-sharded general-form consensus: worker i's local cost must
    /// have dimension `pattern.owned_len(i)` (it sees only its owned
    /// slice of `x₀`). Validation is typed — the session builder surfaces
    /// these as [`BlockError`]-carrying engine errors.
    pub fn sharded(
        locals: Vec<Arc<dyn LocalCost>>,
        reg: Regularizer,
        pattern: BlockPattern,
    ) -> Result<Self, BlockError> {
        if pattern.num_workers() != locals.len() {
            return Err(BlockError::WorkerCountMismatch {
                pattern: pattern.num_workers(),
                problem: locals.len(),
            });
        }
        for (i, l) in locals.iter().enumerate() {
            if l.dim() != pattern.owned_len(i) {
                return Err(BlockError::LocalDimMismatch {
                    worker: i,
                    local_dim: l.dim(),
                    owned_len: pattern.owned_len(i),
                });
            }
        }
        Ok(ConsensusProblem { locals, reg, pattern: Some(Arc::new(pattern)) })
    }

    /// Number of workers `N`.
    pub fn num_workers(&self) -> usize {
        self.locals.len()
    }

    /// Shared (global) dimension `n`.
    pub fn dim(&self) -> usize {
        match &self.pattern {
            Some(p) => p.dim(),
            None => self.locals[0].dim(),
        }
    }

    /// The block-ownership map (None for the dense form).
    pub fn pattern(&self) -> Option<&Arc<BlockPattern>> {
        self.pattern.as_ref()
    }

    pub fn local(&self, i: usize) -> &Arc<dyn LocalCost> {
        &self.locals[i]
    }

    pub fn locals(&self) -> &[Arc<dyn LocalCost>] {
        &self.locals
    }

    pub fn regularizer(&self) -> &Regularizer {
        &self.reg
    }

    /// The original objective (1) at a consensus point: `Σ f_i(x) + h(x)`
    /// (sharded: `Σ f_i(x_{S_i}) + h(x)` — each local sees its owned
    /// slice of the global point).
    pub fn objective(&self, x: &[f64]) -> f64 {
        match &self.pattern {
            None => self.locals.iter().map(|l| l.eval(x)).sum::<f64>() + self.reg.eval(x),
            Some(p) => {
                let mut slice = Vec::new();
                let mut total = 0.0;
                for (i, l) in self.locals.iter().enumerate() {
                    p.gather_into(i, x, &mut slice);
                    total += l.eval(&slice);
                }
                total + self.reg.eval(x)
            }
        }
    }

    /// [`ConsensusProblem::objective`] through caller-owned scratch — the
    /// per-iteration diagnostics path. Bit-identical to `objective` (every
    /// `eval_with` is bit-identical to `eval`, the summation order is the
    /// same, and the sharded gather reproduces the same slices).
    pub fn objective_with(&self, x: &[f64], scratch: &mut WorkerScratch) -> f64 {
        match self.pattern.clone() {
            None => {
                let mut total = 0.0;
                for l in &self.locals {
                    total += l.eval_with(x, scratch);
                }
                total + self.reg.eval(x)
            }
            Some(p) => {
                let mut total = 0.0;
                for (i, l) in self.locals.iter().enumerate() {
                    // Move the gather out of the scratch so `eval_with`
                    // can use every scratch buffer freely.
                    let mut slice = std::mem::take(&mut scratch.gather);
                    p.gather_into(i, x, &mut slice);
                    total += l.eval_with(&slice, scratch);
                    scratch.gather = slice;
                }
                total + self.reg.eval(x)
            }
        }
    }

    /// Max Lipschitz constant over workers (the `L` of Assumption 2).
    pub fn lipschitz(&self) -> f64 {
        self.locals.iter().map(|l| l.lipschitz()).fold(0.0, f64::max)
    }

    /// Full gradient `Σ ∇f_i(x)` (for centralized baselines). Sharded:
    /// each worker's local gradient is scattered back into its owned
    /// coordinates of `out`.
    pub fn full_grad_into(&self, x: &[f64], out: &mut [f64]) {
        out.fill(0.0);
        match &self.pattern {
            None => {
                let mut tmp = vec![0.0; x.len()];
                for l in &self.locals {
                    l.grad_into(x, &mut tmp);
                    for (o, t) in out.iter_mut().zip(&tmp) {
                        *o += t;
                    }
                }
            }
            Some(p) => {
                let mut slice = Vec::new();
                let mut tmp = Vec::new();
                for (i, l) in self.locals.iter().enumerate() {
                    p.gather_into(i, x, &mut slice);
                    tmp.resize(slice.len(), 0.0);
                    l.grad_into(&slice, &mut tmp);
                    p.for_each_range(i, |lo, g, len| {
                        for k in 0..len {
                            out[g + k] += tmp[lo + k];
                        }
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops;

    /// Finite-difference check utility shared by the per-problem test files.
    pub(crate) fn check_grad(cost: &dyn LocalCost, x: &[f64], tol: f64) {
        let n = x.len();
        let mut g = vec![0.0; n];
        cost.grad_into(x, &mut g);
        let h = 1e-6;
        for j in 0..n {
            let mut xp = x.to_vec();
            let mut xm = x.to_vec();
            xp[j] += h;
            xm[j] -= h;
            let fd = (cost.eval(&xp) - cost.eval(&xm)) / (2.0 * h);
            assert!(
                (fd - g[j]).abs() <= tol * (1.0 + fd.abs()),
                "grad[{j}]={} fd={fd}",
                g[j]
            );
        }
    }

    /// Subproblem optimality check: ∇f(x*) + λ + ρ(x* − x0) ≈ 0  (eq. (28)).
    pub(crate) fn check_subproblem(cost: &dyn LocalCost, rho: f64, tol: f64) {
        let n = cost.dim();
        let lam: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let x0: Vec<f64> = (0..n).map(|i| (i as f64 * 0.11).cos()).collect();
        let mut x = vec![0.0; n];
        let mut scratch = WorkerScratch::new();
        cost.solve_subproblem(&lam, &x0, rho, &mut x, &mut scratch);
        // the scratch-based eval must agree bitwise with the plain one
        assert_eq!(cost.eval_with(&x, &mut scratch).to_bits(), cost.eval(&x).to_bits());
        let mut g = vec![0.0; n];
        cost.grad_into(&x, &mut g);
        for i in 0..n {
            g[i] += lam[i] + rho * (x[i] - x0[i]);
        }
        let r = vecops::nrm2(&g);
        assert!(r < tol, "stationarity residual {r}");
    }

    #[test]
    fn consensus_objective_sums() {
        use crate::linalg::DenseMatrix;
        let a = DenseMatrix::from_rows(&[&[1.0, 0.0], &[0.0, 1.0]]);
        let l1 = Arc::new(LassoLocal::new(a.clone(), vec![1.0, 2.0]));
        let l2 = Arc::new(LassoLocal::new(a, vec![0.0, 0.0]));
        let p = ConsensusProblem::new(
            vec![l1, l2],
            Regularizer::L1 { theta: 1.0 },
        );
        // f1([0,0]) = 1+4 = 5, f2 = 0, h = 0 → 5
        assert!((p.objective(&[0.0, 0.0]) - 5.0).abs() < 1e-12);
        assert_eq!(p.num_workers(), 2);
        assert_eq!(p.dim(), 2);
    }
}
