//! LASSO local cost: `f_i(w) = ‖A_i w − b_i‖²` (paper eq. (52), no ½).
//!
//! Subproblem (13): `argmin ‖Aw−b‖² + wᵀλ + ρ/2‖w−x₀‖²`
//! ⇔ `(2AᵀA + ρI) w = 2Aᵀb − λ + ρ x₀` — SPD for any ρ > 0, solved by a
//! cached Cholesky backsolve.

use super::cache::{Factor, RhoCache};
use super::{LocalCost, WorkerScratch};
use crate::linalg::dense::DenseMatrix;
use crate::linalg::power::power_iteration;
use crate::linalg::vecops;

pub struct LassoLocal {
    a: DenseMatrix,
    b: Vec<f64>,
    /// Gram `AᵀA`, formed once.
    gram: DenseMatrix,
    /// `2 Aᵀ b`, formed once.
    two_atb: Vec<f64>,
    /// `2 λmax(AᵀA)` (Lipschitz constant of ∇f).
    lip: f64,
    cache: RhoCache,
}

impl LassoLocal {
    pub fn new(a: DenseMatrix, b: Vec<f64>) -> Self {
        assert_eq!(a.rows(), b.len(), "rows(A) != len(b)");
        let gram = a.gram();
        let mut two_atb = a.matvec_t(&b);
        vecops::scale(2.0, &mut two_atb);
        let n = a.cols();
        let (lam_max, _) =
            power_iteration(|v, out| gram.matvec_into(v, out), n, 300, 1e-9, 0x1a550);
        LassoLocal { a, b, gram, two_atb, lip: 2.0 * lam_max.max(0.0), cache: RhoCache::new() }
    }

    pub fn matrix(&self) -> &DenseMatrix {
        &self.a
    }

    pub fn rhs(&self) -> &[f64] {
        &self.b
    }

    /// Samples held by this worker.
    pub fn num_samples(&self) -> usize {
        self.a.rows()
    }
}

impl LocalCost for LassoLocal {
    fn dim(&self) -> usize {
        self.a.cols()
    }

    fn eval(&self, x: &[f64]) -> f64 {
        let mut r = self.a.matvec(x);
        for (ri, bi) in r.iter_mut().zip(&self.b) {
            *ri -= bi;
        }
        vecops::nrm2_sq(&r)
    }

    fn eval_with(&self, x: &[f64], scratch: &mut WorkerScratch) -> f64 {
        // residual ‖Ax − b‖² through the reusable row buffer (same
        // arithmetic order as `eval`, hence bit-identical)
        scratch.rows.resize(self.a.rows(), 0.0);
        self.a.matvec_into(x, &mut scratch.rows);
        for (ri, bi) in scratch.rows.iter_mut().zip(&self.b) {
            *ri -= bi;
        }
        vecops::nrm2_sq(&scratch.rows)
    }

    fn grad_into(&self, x: &[f64], out: &mut [f64]) {
        // ∇f = 2AᵀA x − 2Aᵀb
        self.gram.matvec_into(x, out);
        for (o, t) in out.iter_mut().zip(&self.two_atb) {
            *o = 2.0 * *o - t;
        }
    }

    fn lipschitz(&self) -> f64 {
        self.lip
    }

    fn solve_subproblem(
        &self,
        lam: &[f64],
        x0: &[f64],
        rho: f64,
        out: &mut [f64],
        _scratch: &mut WorkerScratch,
    ) {
        // Closed form: rhs assembled directly in `out`, no temporaries.
        let n = self.dim();
        debug_assert_eq!(lam.len(), n);
        debug_assert_eq!(x0.len(), n);
        debug_assert_eq!(out.len(), n);
        let factor = self.cache.get_or_build(rho, || {
            let mut m = self.gram.clone();
            m.scale(2.0);
            m.add_diag(rho);
            Factor::of(&m)
        });
        // rhs = 2Aᵀb − λ + ρ x₀
        for i in 0..n {
            out[i] = self.two_atb[i] - lam[i] + rho * x0[i];
        }
        factor.solve_in_place(out);
    }

    fn kind(&self) -> &'static str {
        "lasso"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::tests::{check_grad, check_subproblem};
    use crate::rng::Pcg64;

    fn inst(seed: u64, m: usize, n: usize) -> LassoLocal {
        let mut rng = Pcg64::seed_from_u64(seed);
        let a = DenseMatrix::randn(&mut rng, m, n);
        let b: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        LassoLocal::new(a, b)
    }

    #[test]
    fn eval_known() {
        let a = DenseMatrix::from_rows(&[&[1.0, 0.0], &[0.0, 2.0]]);
        let l = LassoLocal::new(a, vec![1.0, 0.0]);
        // f([1, 1]) = 0 + 4 = 4
        assert!((l.eval(&[1.0, 1.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let l = inst(21, 15, 8);
        let x: Vec<f64> = (0..8).map(|i| 0.3 * (i as f64).sin()).collect();
        check_grad(&l, &x, 1e-5);
    }

    #[test]
    fn subproblem_stationarity() {
        let l = inst(22, 20, 10);
        check_subproblem(&l, 5.0, 1e-8);
        check_subproblem(&l, 500.0, 1e-8);
    }

    #[test]
    fn lipschitz_bounds_gradient_difference() {
        let l = inst(23, 12, 6);
        let mut rng = Pcg64::seed_from_u64(99);
        for _ in 0..20 {
            let x: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
            let mut gx = vec![0.0; 6];
            let mut gy = vec![0.0; 6];
            l.grad_into(&x, &mut gx);
            l.grad_into(&y, &mut gy);
            let lhs = vecops::dist2(&gx, &gy);
            let rhs = l.lipschitz() * vecops::dist2(&x, &y);
            assert!(lhs <= rhs * (1.0 + 1e-6), "lhs={lhs} rhs={rhs}");
        }
    }

    #[test]
    fn underdetermined_block_works() {
        // The Fig. 4(c,d) regime: n >> m, f_i not strongly convex.
        let l = inst(24, 20, 100);
        check_subproblem(&l, 500.0, 1e-7);
    }

    #[test]
    fn fixed_point_when_lam_matches_gradient() {
        // If λ = −∇f(x0), the subproblem solution is x0 itself.
        let l = inst(25, 10, 5);
        let x0: Vec<f64> = (0..5).map(|i| 0.1 * i as f64).collect();
        let mut lam = vec![0.0; 5];
        l.grad_into(&x0, &mut lam);
        for v in lam.iter_mut() {
            *v = -*v;
        }
        let mut out = vec![0.0; 5];
        l.solve_subproblem(&lam, &x0, 10.0, &mut out, &mut WorkerScratch::new());
        assert!(vecops::dist2(&out, &x0) < 1e-9);
    }
}
