//! Generic quadratic local cost `f(x) = ½ xᵀQx + qᵀx` (Q symmetric, not
//! necessarily PSD). The workhorse of unit/property tests: every identity in
//! the convergence analysis can be checked exactly against it.

use super::cache::{Factor, RhoCache};
use super::{LocalCost, WorkerScratch};
use crate::linalg::dense::DenseMatrix;
use crate::linalg::power::power_iteration;
use crate::linalg::vecops;

pub struct QuadraticLocal {
    q_mat: DenseMatrix,
    q_vec: Vec<f64>,
    lip: f64,
    cache: RhoCache,
}

impl QuadraticLocal {
    pub fn new(q_mat: DenseMatrix, q_vec: Vec<f64>) -> Self {
        assert_eq!(q_mat.rows(), q_mat.cols());
        assert_eq!(q_mat.rows(), q_vec.len());
        // symmetry check (cheap, catches test bugs early)
        for i in 0..q_mat.rows() {
            for j in i + 1..q_mat.cols() {
                assert!(
                    (q_mat.get(i, j) - q_mat.get(j, i)).abs() < 1e-9,
                    "Q must be symmetric"
                );
            }
        }
        let n = q_mat.rows();
        // L = spectral norm of Q; power iteration on Q² keeps it sign-safe.
        let mut scratch = vec![0.0; n];
        let (lam2, _) = power_iteration(
            |v, out| {
                q_mat.matvec_into(v, &mut scratch);
                q_mat.matvec_into(&scratch, out);
            },
            n,
            400,
            1e-10,
            0x9d,
        );
        QuadraticLocal { q_mat, q_vec, lip: lam2.max(0.0).sqrt(), cache: RhoCache::new() }
    }

    /// Convenience: diagonal quadratic.
    pub fn diagonal(diag: &[f64], q_vec: Vec<f64>) -> Self {
        let n = diag.len();
        let mut m = DenseMatrix::zeros(n, n);
        for i in 0..n {
            m.set(i, i, diag[i]);
        }
        QuadraticLocal::new(m, q_vec)
    }

    /// Like [`QuadraticLocal::new`] with a caller-supplied Lipschitz
    /// constant, skipping the power iteration. For fleets of workers that
    /// share one `Q` (the `virtual_scale` pooled benchmark builds 1000 of
    /// these), the spectral norm is computed once and reused.
    pub fn with_lipschitz(q_mat: DenseMatrix, q_vec: Vec<f64>, lip: f64) -> Self {
        assert_eq!(q_mat.rows(), q_mat.cols());
        assert_eq!(q_mat.rows(), q_vec.len());
        assert!(lip >= 0.0);
        QuadraticLocal { q_mat, q_vec, lip, cache: RhoCache::new() }
    }
}

impl LocalCost for QuadraticLocal {
    fn dim(&self) -> usize {
        self.q_vec.len()
    }

    fn eval(&self, x: &[f64]) -> f64 {
        let qx = self.q_mat.matvec(x);
        0.5 * vecops::dot(x, &qx) + vecops::dot(&self.q_vec, x)
    }

    fn eval_with(&self, x: &[f64], scratch: &mut WorkerScratch) -> f64 {
        // Qx through the reusable n-buffer (bit-identical to `eval`).
        scratch.grad.resize(self.dim(), 0.0);
        self.q_mat.matvec_into(x, &mut scratch.grad);
        0.5 * vecops::dot(x, &scratch.grad) + vecops::dot(&self.q_vec, x)
    }

    fn grad_into(&self, x: &[f64], out: &mut [f64]) {
        self.q_mat.matvec_into(x, out);
        for (o, q) in out.iter_mut().zip(&self.q_vec) {
            *o += q;
        }
    }

    fn lipschitz(&self) -> f64 {
        self.lip
    }

    fn solve_subproblem(
        &self,
        lam: &[f64],
        x0: &[f64],
        rho: f64,
        out: &mut [f64],
        _scratch: &mut WorkerScratch,
    ) {
        // (Q + ρI) x = −q − λ + ρ x₀ — closed form, no temporaries.
        let n = self.dim();
        let factor = self.cache.get_or_build(rho, || {
            let mut m = self.q_mat.clone();
            m.add_diag(rho);
            Factor::of(&m)
        });
        for i in 0..n {
            out[i] = -self.q_vec[i] - lam[i] + rho * x0[i];
        }
        factor.solve_in_place(out);
    }

    fn kind(&self) -> &'static str {
        "quadratic"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::tests::{check_grad, check_subproblem};

    #[test]
    fn eval_and_grad_known() {
        let q = QuadraticLocal::diagonal(&[2.0, 4.0], vec![1.0, -1.0]);
        // f([1,1]) = ½(2+4) + (1−1) = 3
        assert!((q.eval(&[1.0, 1.0]) - 3.0).abs() < 1e-12);
        let mut g = vec![0.0; 2];
        q.grad_into(&[1.0, 1.0], &mut g);
        assert_eq!(g, vec![3.0, 3.0]);
    }

    #[test]
    fn gradient_fd() {
        let m = DenseMatrix::from_rows(&[&[3.0, 1.0], &[1.0, 2.0]]);
        let q = QuadraticLocal::new(m, vec![0.5, -0.5]);
        check_grad(&q, &[0.3, -0.7], 1e-6);
    }

    #[test]
    fn subproblem_convex_and_nonconvex() {
        let convex = QuadraticLocal::diagonal(&[1.0, 2.0, 3.0], vec![0.1, 0.2, 0.3]);
        check_subproblem(&convex, 1.0, 1e-9);
        // non-convex but ρ > |λmin| keeps the shifted system SPD
        let noncvx = QuadraticLocal::diagonal(&[-1.0, 2.0], vec![0.0, 0.0]);
        check_subproblem(&noncvx, 3.0, 1e-9);
    }

    #[test]
    fn lipschitz_is_spectral_norm() {
        let q = QuadraticLocal::diagonal(&[-5.0, 3.0], vec![0.0, 0.0]);
        assert!((q.lipschitz() - 5.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "symmetric")]
    fn asymmetric_rejected() {
        let m = DenseMatrix::from_rows(&[&[1.0, 2.0], &[0.0, 1.0]]);
        QuadraticLocal::new(m, vec![0.0, 0.0]);
    }
}
