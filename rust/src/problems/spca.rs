//! Sparse-PCA local cost: `f_j(w) = −wᵀB_jᵀB_j w` (paper eq. (50)) — the
//! paper's **non-convex** showcase for Theorem 1.
//!
//! Subproblem (13): `argmin −‖Bw‖² + wᵀλ + ρ/2‖w−x₀‖²`
//! ⇔ `(ρI − 2BᵀB) w = ρ x₀ − λ`. SPD iff `ρ > 2λmax(BᵀB)`; the Fig. 3
//! parameterization `ρ = β·λmax` gives SPD for β = 3 and an **indefinite**
//! system for β = 1.5 (the divergence regime), handled by the LU fallback.

use super::cache::{Factor, RhoCache};
use super::{LocalCost, WorkerScratch};
use crate::linalg::power::power_iteration;
use crate::linalg::sparse::CsrMatrix;
use crate::linalg::vecops;
use crate::linalg::DenseMatrix;

pub struct SpcaLocal {
    b: CsrMatrix,
    /// Dense `BᵀB` (n×n), formed once.
    gram: DenseMatrix,
    /// `λmax(BᵀB)`.
    lam_max: f64,
    cache: RhoCache,
}

impl SpcaLocal {
    pub fn new(b: CsrMatrix) -> Self {
        let n = b.cols();
        let gram = b.gram_dense();
        let (lam_max, _) =
            power_iteration(|v, out| gram.matvec_into(v, out), n, 500, 1e-10, 0x59ca);
        SpcaLocal { b, gram, lam_max: lam_max.max(0.0), cache: RhoCache::new() }
    }

    /// `λmax(BᵀB)` — the paper's ρ-rule input (`ρ = β·max_j λmax`).
    pub fn lambda_max(&self) -> f64 {
        self.lam_max
    }

    pub fn data(&self) -> &CsrMatrix {
        &self.b
    }
}

impl LocalCost for SpcaLocal {
    fn dim(&self) -> usize {
        self.b.cols()
    }

    fn eval(&self, x: &[f64]) -> f64 {
        let mut scratch = vec![0.0; self.b.rows()];
        -self.b.quad_form(x, &mut scratch)
    }

    fn eval_with(&self, x: &[f64], scratch: &mut WorkerScratch) -> f64 {
        scratch.rows.resize(self.b.rows(), 0.0);
        -self.b.quad_form(x, &mut scratch.rows)
    }

    fn grad_into(&self, x: &[f64], out: &mut [f64]) {
        // ∇f = −2 BᵀB x
        self.gram.matvec_into(x, out);
        vecops::scale(-2.0, out);
    }

    fn lipschitz(&self) -> f64 {
        2.0 * self.lam_max
    }

    fn solve_subproblem(
        &self,
        lam: &[f64],
        x0: &[f64],
        rho: f64,
        out: &mut [f64],
        _scratch: &mut WorkerScratch,
    ) {
        // (ρI − 2BᵀB) w = ρ x₀ − λ — closed form, no temporaries.
        let n = self.dim();
        let factor = self.cache.get_or_build(rho, || {
            let mut m = self.gram.clone();
            m.scale(-2.0);
            m.add_diag(rho);
            Factor::of(&m)
        });
        for i in 0..n {
            out[i] = rho * x0[i] - lam[i];
        }
        factor.solve_in_place(out);
    }

    fn kind(&self) -> &'static str {
        "spca"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::tests::{check_grad, check_subproblem};
    use crate::rng::Pcg64;

    fn inst(seed: u64, m: usize, n: usize, nnz: usize) -> SpcaLocal {
        let mut rng = Pcg64::seed_from_u64(seed);
        SpcaLocal::new(CsrMatrix::random(&mut rng, m, n, nnz))
    }

    #[test]
    fn objective_is_negative_quadratic() {
        let b = CsrMatrix::from_triplets(2, 2, &[(0, 0, 1.0), (1, 1, 2.0)]);
        let s = SpcaLocal::new(b);
        // f([1,1]) = −(1 + 4) = −5
        assert!((s.eval(&[1.0, 1.0]) + 5.0).abs() < 1e-12);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let s = inst(31, 20, 8, 40);
        let x: Vec<f64> = (0..8).map(|i| 0.2 * (i as f64).cos()).collect();
        check_grad(&s, &x, 1e-5);
    }

    #[test]
    fn subproblem_spd_regime() {
        let s = inst(32, 25, 10, 60);
        let rho = 3.0 * s.lambda_max(); // β = 3 → SPD
        check_subproblem(&s, rho, 1e-8);
    }

    #[test]
    fn subproblem_indefinite_regime_still_stationary() {
        // β = 1.5 → ρ < 2λmax → indefinite, LU path. The solve still
        // satisfies the stationarity system (it's just not a minimizer).
        let s = inst(33, 25, 10, 60);
        let rho = 1.5 * s.lambda_max();
        check_subproblem(&s, rho, 1e-6);
    }

    #[test]
    fn lipschitz_is_twice_lambda_max() {
        let s = inst(34, 30, 12, 80);
        assert!((s.lipschitz() - 2.0 * s.lambda_max()).abs() < 1e-12);
    }

    #[test]
    fn lambda_max_positive_for_nonempty() {
        let s = inst(35, 15, 6, 20);
        assert!(s.lambda_max() > 0.0);
    }
}
