//! ρ-keyed factorization cache shared by the local costs.
//!
//! A worker's subproblem matrix depends only on its (fixed) data block and
//! `ρ`, so each local cost factors once per `ρ` and backsolves thereafter.
//! The cache is a single slot (runs use one `ρ`); changing `ρ` mid-run
//! simply refactors.

use std::sync::{Arc, RwLock};

use crate::linalg::cholesky::Cholesky;
use crate::linalg::dense::DenseMatrix;
use crate::linalg::lu::Lu;

/// A direct factorization of the subproblem system matrix.
pub enum Factor {
    /// SPD path (LASSO/ridge always; sparse-PCA when `ρ > 2λmax`).
    Chol(Cholesky),
    /// Indefinite fallback (sparse-PCA divergence regime still has to run).
    Lu(Lu),
}

impl Factor {
    /// Factor `m`, preferring Cholesky, falling back to LU.
    pub fn of(m: &DenseMatrix) -> Factor {
        match Cholesky::factor(m) {
            Ok(c) => Factor::Chol(c),
            // ad-lint: allow(panic-free-lib): AᵀA + ρI is positive definite for ρ > 0; singularity here is unrecoverable numeric corruption
            Err(_) => Factor::Lu(Lu::factor(m).expect("subproblem matrix singular")),
        }
    }

    pub fn solve_in_place(&self, x: &mut [f64]) {
        match self {
            Factor::Chol(c) => c.solve_in_place(x),
            Factor::Lu(lu) => {
                let sol = lu.solve(x);
                x.copy_from_slice(&sol);
            }
        }
    }
}

/// Single-slot `ρ → Factor` cache, thread-safe (workers run on threads).
pub struct RhoCache {
    slot: RwLock<Option<(u64, Arc<Factor>)>>,
}

impl RhoCache {
    pub fn new() -> Self {
        RhoCache { slot: RwLock::new(None) }
    }

    /// Get the factor for `rho`, building it with `build` on miss.
    pub fn get_or_build<F: FnOnce() -> Factor>(&self, rho: f64, build: F) -> Arc<Factor> {
        let key = rho.to_bits();
        // ad-lint: allow(panic-free-lib): RwLock poisoning only follows a panic elsewhere; propagating it is the lock idiom
        if let Some((k, f)) = self.slot.read().unwrap().as_ref() {
            if *k == key {
                return f.clone();
            }
        }
        let f = Arc::new(build());
        // ad-lint: allow(panic-free-lib): RwLock poisoning only follows a panic elsewhere; propagating it is the lock idiom
        *self.slot.write().unwrap() = Some((key, f.clone()));
        f
    }
}

impl Default for RhoCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn caches_per_rho_and_invalidates() {
        let cache = RhoCache::new();
        let mut builds = 0;
        let m = {
            let mut m = DenseMatrix::eye(3);
            m.add_diag(1.0);
            m
        };
        for _ in 0..3 {
            let _ = cache.get_or_build(2.0, || {
                builds += 1;
                Factor::of(&m)
            });
        }
        assert_eq!(builds, 1);
        let _ = cache.get_or_build(3.0, || {
            builds += 1;
            Factor::of(&m)
        });
        assert_eq!(builds, 2);
    }

    #[test]
    fn factor_prefers_cholesky_falls_back_to_lu() {
        let spd = DenseMatrix::from_rows(&[&[2.0, 0.0], &[0.0, 2.0]]);
        assert!(matches!(Factor::of(&spd), Factor::Chol(_)));
        let indef = DenseMatrix::from_rows(&[&[1.0, 0.0], &[0.0, -1.0]]);
        let f = Factor::of(&indef);
        assert!(matches!(f, Factor::Lu(_)));
        let mut x = vec![1.0, 2.0];
        f.solve_in_place(&mut x);
        assert_eq!(x, vec![1.0, -2.0]);
    }
}
