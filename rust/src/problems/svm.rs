//! Squared-hinge SVM local cost:
//! `f_i(w) = Σ_j max(0, 1 − y_j a_jᵀw)²` — the smooth L2-SVM variant of the
//! paper's §II-A application list (the plain hinge is nonsmooth and
//! violates Assumption 2; the squared hinge is C¹ with Lipschitz gradient).
//!
//! The subproblem (13) is solved by semismooth Newton: on the active set
//! `{j : y_j a_jᵀw < 1}` the objective is quadratic, so each step solves
//! `(2 A_𝒜ᵀ A_𝒜 + ρI) Δ = −∇g` and converges in a handful of iterations.

use super::{LocalCost, WorkerScratch};
use crate::linalg::cholesky::Cholesky;
use crate::linalg::dense::DenseMatrix;
use crate::linalg::power::power_iteration;
use crate::linalg::vecops;

pub struct SvmLocal {
    a: DenseMatrix,
    y: Vec<f64>,
    /// λmax(AᵀA) — gradient Lipschitz bound is `2λmax`.
    lam_max: f64,
    newton_iters: usize,
    newton_tol: f64,
}

impl SvmLocal {
    pub fn new(a: DenseMatrix, y: Vec<f64>) -> Self {
        assert_eq!(a.rows(), y.len());
        // ad-lint: allow(float-eq): labels are exact ±1.0 sentinels assigned by the generator, never computed
        assert!(y.iter().all(|&v| v == 1.0 || v == -1.0), "labels must be ±1");
        let gram = a.gram();
        let n = a.cols();
        let (lam_max, _) =
            power_iteration(|v, out| gram.matvec_into(v, out), n, 300, 1e-9, 0x51f);
        SvmLocal { a, y, lam_max: lam_max.max(0.0), newton_iters: 50, newton_tol: 1e-10 }
    }

    /// Margins `m_j = y_j a_jᵀ x`.
    fn margins(&self, x: &[f64]) -> Vec<f64> {
        let mut m = self.a.matvec(x);
        for (mj, yj) in m.iter_mut().zip(&self.y) {
            *mj *= yj;
        }
        m
    }

    /// `margins` into a caller buffer (resized to `rows`) — the hot path.
    fn margins_into(&self, x: &[f64], m: &mut Vec<f64>) {
        m.resize(self.a.rows(), 0.0);
        self.a.matvec_into(x, m);
        for (mj, yj) in m.iter_mut().zip(&self.y) {
            *mj *= yj;
        }
    }

    /// `f(x)` through a caller-owned margin buffer (bit-identical to
    /// [`LocalCost::eval`]; usable while other scratch fields are borrowed).
    fn loss_with(&self, x: &[f64], m: &mut Vec<f64>) -> f64 {
        self.margins_into(x, m);
        m.iter()
            .map(|&mj| {
                let v = (1.0 - mj).max(0.0);
                v * v
            })
            .sum()
    }

    /// `iters` semismooth-Newton steps on
    /// `g(x) = f(x) + xᵀλ + ρ/2‖x − x0‖²` from the *current* `out`
    /// (callers choose the start: `x0` for the exact solve, the previous
    /// iterate for the capped warm-started path). Vector temporaries live
    /// in `scratch` (`rows` = margins, `rows2` = active weights,
    /// `grad`/`step`/`trial` as named); only the n×n generalized Hessian
    /// and its factorization still allocate per Newton step.
    fn newton(
        &self,
        iters: usize,
        lam: &[f64],
        x0: &[f64],
        rho: f64,
        out: &mut [f64],
        scratch: &mut WorkerScratch,
    ) {
        let n = self.dim();
        let mrows = self.a.rows();
        let WorkerScratch { rows, rows2, grad, step, trial, .. } = scratch;
        grad.resize(n, 0.0);
        step.resize(n, 0.0);
        trial.resize(n, 0.0);
        rows2.resize(mrows, 0.0);
        for _ in 0..iters {
            // gradient of g: ∇f = Aᵀw with w_j = −2(1 − m_j)y_j on the
            // active set, 0 elsewhere
            self.margins_into(out, rows);
            for j in 0..mrows {
                let slack = 1.0 - rows[j];
                rows2[j] = if slack > 0.0 { -2.0 * slack * self.y[j] } else { 0.0 };
            }
            self.a.matvec_t_into(rows2, grad);
            for i in 0..n {
                grad[i] += lam[i] + rho * (out[i] - x0[i]);
            }
            if vecops::nrm2(grad) < self.newton_tol * (1.0 + vecops::nrm2(out)) {
                break;
            }
            // Generalized Hessian: 2 A_activeᵀ A_active + ρI (margins still
            // in `rows`).
            let mut h = DenseMatrix::zeros(n, n);
            for r in 0..mrows {
                if rows[r] < 1.0 {
                    let row = self.a.row(r);
                    for i in 0..n {
                        let ri = 2.0 * row[i];
                        if ri == 0.0 {
                            continue;
                        }
                        for j in 0..n {
                            let cur = h.get(i, j);
                            h.set(i, j, cur + ri * row[j]);
                        }
                    }
                }
            }
            h.add_diag(rho);
            let chol = match Cholesky::factor(&h) {
                Ok(c) => c,
                Err(_) => break,
            };
            step.copy_from_slice(grad);
            chol.solve_in_place(step);
            // backtracking on g (the active set may change across the step)
            let g0 = self.loss_with(out, rows)
                + vecops::dot(out, lam)
                + 0.5 * rho * vecops::dist2_sq(out, x0);
            let slope = vecops::dot(grad, step);
            let mut t = 1.0;
            for _ in 0..30 {
                for i in 0..n {
                    trial[i] = out[i] - t * step[i];
                }
                let g1 = self.loss_with(trial, rows)
                    + vecops::dot(trial, lam)
                    + 0.5 * rho * vecops::dist2_sq(trial, x0);
                if g1 <= g0 - 1e-4 * t * slope {
                    break;
                }
                t *= 0.5;
            }
            for i in 0..n {
                out[i] -= t * step[i];
            }
        }
    }
}

impl LocalCost for SvmLocal {
    fn dim(&self) -> usize {
        self.a.cols()
    }

    fn eval(&self, x: &[f64]) -> f64 {
        self.margins(x)
            .iter()
            .map(|&m| {
                let v = (1.0 - m).max(0.0);
                v * v
            })
            .sum()
    }

    fn eval_with(&self, x: &[f64], scratch: &mut WorkerScratch) -> f64 {
        self.loss_with(x, &mut scratch.rows)
    }

    fn grad_into(&self, x: &[f64], out: &mut [f64]) {
        // ∇f = −2 Σ_{j active} (1 − m_j) y_j a_j
        let m = self.margins(x);
        let mut w = vec![0.0; m.len()];
        for j in 0..m.len() {
            let slack = 1.0 - m[j];
            if slack > 0.0 {
                w[j] = -2.0 * slack * self.y[j];
            }
        }
        self.a.matvec_t_into(&w, out);
    }

    fn lipschitz(&self) -> f64 {
        2.0 * self.lam_max
    }

    fn solve_subproblem(
        &self,
        lam: &[f64],
        x0: &[f64],
        rho: f64,
        out: &mut [f64],
        scratch: &mut WorkerScratch,
    ) {
        out.copy_from_slice(x0);
        self.newton(self.newton_iters, lam, x0, rho, out, scratch);
    }

    fn solve_subproblem_capped(
        &self,
        steps: usize,
        lam: &[f64],
        x0: &[f64],
        rho: f64,
        out: &mut [f64],
        scratch: &mut WorkerScratch,
    ) -> bool {
        // `out` arrives pre-initialized (the inexact-policy warm start).
        self.newton(steps, lam, x0, rho, out, scratch);
        true
    }

    fn kind(&self) -> &'static str {
        "svm"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::tests::{check_grad, check_subproblem};
    use crate::rng::Pcg64;

    fn inst(seed: u64, m: usize, n: usize) -> SvmLocal {
        let mut rng = Pcg64::seed_from_u64(seed);
        let a = DenseMatrix::randn(&mut rng, m, n);
        let y: Vec<f64> = (0..m).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        SvmLocal::new(a, y)
    }

    #[test]
    fn eval_at_zero_is_m() {
        // margins 0 → slack 1 per sample
        let l = inst(61, 15, 5);
        assert!((l.eval(&[0.0; 5]) - 15.0).abs() < 1e-12);
    }

    #[test]
    fn perfectly_separated_point_has_zero_loss() {
        let a = DenseMatrix::from_rows(&[&[1.0, 0.0], &[-1.0, 0.0]]);
        let l = SvmLocal::new(a, vec![1.0, -1.0]);
        // w = (2, 0): margins are 2 and 2 → no slack
        assert_eq!(l.eval(&[2.0, 0.0]), 0.0);
        let mut g = vec![0.0; 2];
        l.grad_into(&[2.0, 0.0], &mut g);
        assert!(vecops::nrm2(&g) < 1e-12);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let l = inst(62, 12, 6);
        // keep away from the (measure-zero) kink m = 1
        let x: Vec<f64> = (0..6).map(|i| 0.17 * (i as f64 + 1.0).sin()).collect();
        check_grad(&l, &x, 1e-4);
    }

    #[test]
    fn subproblem_stationarity_semismooth_newton() {
        let l = inst(63, 20, 6);
        check_subproblem(&l, 3.0, 1e-6);
        check_subproblem(&l, 50.0, 1e-6);
    }

    #[test]
    fn distributed_svm_converges_through_coordinator() {
        use crate::admm::arrivals::ArrivalModel;
        use crate::admm::kkt::kkt_residual;
        use crate::testkit::drivers::run_partial_barrier;
        use crate::admm::AdmmConfig;
        use crate::problems::ConsensusProblem;
        use crate::prox::Regularizer;
        use std::sync::Arc;

        let mut rng = Pcg64::seed_from_u64(64);
        let w_true: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let mut locals: Vec<Arc<dyn crate::problems::LocalCost>> = Vec::new();
        for _ in 0..4 {
            let a = DenseMatrix::randn(&mut rng, 25, 6);
            let y: Vec<f64> = a
                .matvec(&w_true)
                .iter()
                .map(|&m| if m >= 0.0 { 1.0 } else { -1.0 })
                .collect();
            locals.push(Arc::new(SvmLocal::new(a, y)));
        }
        let p = ConsensusProblem::new(locals, Regularizer::L2Sq { theta: 1.0 });
        let rho = p.lipschitz().max(1.0);
        let cfg = AdmmConfig { rho, tau: 3, max_iters: 3000, ..Default::default() };
        let out = run_partial_barrier(&p, &cfg, &ArrivalModel::fig3_profile(4, 5));
        let r = kkt_residual(&p, &out.state);
        // squared-hinge + weak coupling converges slowly near the active-set
        // boundary; 3000 iterations reach ~1e-3 stationarity
        assert!(r.max() < 5e-3, "{r:?}");
    }
}
