//! Block-sharded general-form consensus: coordinate-block ownership.
//!
//! The paper's star protocol makes every worker ship the *entire* global
//! variable `x ∈ ℝⁿ` to the master each arrival, so master bandwidth and
//! the `O(N·n)` reduction are the scale ceiling. Block-wise asynchronous
//! ADMM (Zhu et al., arXiv:1802.08882; Hong, arXiv:1412.6058) removes it
//! with the general-form consensus fix: the global dimension is split into
//! contiguous coordinate **blocks**, each worker *owns* only the blocks its
//! local cost actually touches, and the consensus constraint becomes
//! `x_i = (x₀)_{S_i}` over the owned slice `S_i`. Workers then solve and
//! communicate `|S_i|`-length vectors, the master's per-coordinate
//! reduction shrinks from `N` terms to the owner count `N_j`, and the
//! τ-bounded-delay analysis (Assumption 1) applies per worker-block.
//!
//! A [`BlockPattern`] is the static ownership map: a partition of `[0, n)`
//! into blocks plus a sorted per-worker list of owned block ids. The
//! [`BlockPattern::dense`] pattern (one block, everyone owns it)
//! reproduces the historical behaviour exactly — the engine run with a
//! dense pattern is **bit-identical** to the unsharded engine (pinned by
//! the `sharded_consensus` integration suite).

use crate::bench::json::{json_usize, JsonValue};
use std::fmt;

/// Everything [`BlockPattern::new`] (and the session builder) can reject.
/// Wrapped into [`crate::admm::session::EngineError::Block`] so sharding
/// misconfigurations surface as typed build-time errors, never panics.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BlockError {
    /// The global dimension must be ≥ 1.
    EmptyDimension,
    /// A pattern needs at least one block and one worker.
    EmptyPattern,
    /// Block `block` has zero length.
    EmptyBlock { block: usize },
    /// Block `block` ends at `end`, beyond the global dimension `n`.
    OutOfRange { block: usize, end: usize, n: usize },
    /// Block `block` starts before the previous block ended (blocks must
    /// be disjoint and listed in ascending order).
    Overlap { block: usize },
    /// The partition leaves coordinate `at` uncovered.
    Gap { at: usize },
    /// Worker `worker` owns no blocks (its local variable would be empty).
    WorkerOwnsNothing { worker: usize },
    /// Worker `worker` lists block id `block`, but the pattern only has
    /// `num_blocks` blocks.
    OwnedOutOfRange { worker: usize, block: usize, num_blocks: usize },
    /// Worker `worker`'s owned list is not strictly ascending at `block`
    /// (duplicates and out-of-order ids are both rejected).
    OwnedNotSorted { worker: usize, block: usize },
    /// Block `block` is owned by no worker, so its coordinates of `x₀`
    /// would never receive a contribution.
    NoOwner { block: usize },
    /// Worker `worker`'s local cost has dimension `local_dim`, but the
    /// pattern assigns it an owned slice of length `owned_len`.
    LocalDimMismatch { worker: usize, local_dim: usize, owned_len: usize },
    /// The pattern drives a different worker count than the problem.
    WorkerCountMismatch { pattern: usize, problem: usize },
    /// The pattern's global dimension differs from the problem's.
    DimMismatch { pattern: usize, problem: usize },
    /// A pattern supplied to the builder disagrees with the one the
    /// problem was constructed with.
    PatternMismatch,
}

impl fmt::Display for BlockError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BlockError::EmptyDimension => write!(f, "global dimension must be >= 1"),
            BlockError::EmptyPattern => write!(f, "pattern needs >= 1 block and >= 1 worker"),
            BlockError::EmptyBlock { block } => write!(f, "block {block} has zero length"),
            BlockError::OutOfRange { block, end, n } => {
                write!(f, "block {block} ends at {end}, beyond the global dimension {n}")
            }
            BlockError::Overlap { block } => {
                write!(f, "block {block} overlaps the previous block (or is out of order)")
            }
            BlockError::Gap { at } => {
                write!(f, "the block partition leaves coordinate {at} uncovered")
            }
            BlockError::WorkerOwnsNothing { worker } => {
                write!(f, "worker {worker} owns no blocks")
            }
            BlockError::OwnedOutOfRange { worker, block, num_blocks } => {
                write!(
                    f,
                    "worker {worker} owns block {block}, but the pattern has only \
                     {num_blocks} blocks"
                )
            }
            BlockError::OwnedNotSorted { worker, block } => {
                write!(
                    f,
                    "worker {worker}'s owned blocks are not strictly ascending at id {block}"
                )
            }
            BlockError::NoOwner { block } => write!(f, "block {block} has no owner"),
            BlockError::LocalDimMismatch { worker, local_dim, owned_len } => {
                write!(
                    f,
                    "worker {worker}'s local cost has dimension {local_dim}, but its owned \
                     slice has length {owned_len}"
                )
            }
            BlockError::WorkerCountMismatch { pattern, problem } => {
                write!(f, "pattern drives {pattern} workers, the problem has {problem}")
            }
            BlockError::DimMismatch { pattern, problem } => {
                write!(f, "pattern dimension {pattern} != problem dimension {problem}")
            }
            BlockError::PatternMismatch => {
                write!(f, "builder pattern differs from the problem's own pattern")
            }
        }
    }
}

impl std::error::Error for BlockError {}

/// A validated block-ownership map: a partition of the global dimension
/// `[0, n)` into contiguous blocks plus, per worker, the sorted list of
/// block ids it owns. Immutable after construction; every derived quantity
/// the hot loops need (per-coordinate owner counts, per-worker owned
/// lengths) is precomputed here.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockPattern {
    /// Global dimension `n`.
    n: usize,
    /// Block `b` covers `[starts[b], starts[b] + lens[b])`.
    starts: Vec<usize>,
    lens: Vec<usize>,
    /// Per-worker strictly ascending owned block ids.
    owned: Vec<Vec<usize>>,
    /// Per-coordinate owner count `N_j` (derived; ≥ 1 after validation).
    counts: Vec<usize>,
    /// Per-worker owned-slice length `|S_i|` (derived).
    owned_lens: Vec<usize>,
    /// CSR transpose of `owned`: block `b`'s owner entries are
    /// `owner_entries[owner_idx[b]..owner_idx[b + 1]]`, ascending by
    /// worker (derived). Stored compactly — one flat allocation, 8 bytes
    /// per (block, owner) incidence — so million-worker patterns carry no
    /// per-block heap overhead.
    owner_idx: Vec<usize>,
    /// `(worker, local_offset)` of each (block, owner) incidence.
    owner_entries: Vec<(u32, u32)>,
}

impl BlockPattern {
    /// Build and validate a pattern. `blocks` is the global partition as
    /// `(start, len)` pairs in ascending order; `owned[i]` lists worker
    /// i's block ids, strictly ascending. Every coordinate must be covered
    /// by exactly one block and every block owned by at least one worker.
    pub fn new(
        n: usize,
        blocks: &[(usize, usize)],
        owned: Vec<Vec<usize>>,
    ) -> Result<Self, BlockError> {
        if n == 0 {
            return Err(BlockError::EmptyDimension);
        }
        if blocks.is_empty() || owned.is_empty() {
            return Err(BlockError::EmptyPattern);
        }
        let mut cursor = 0usize;
        for (b, &(start, len)) in blocks.iter().enumerate() {
            if len == 0 {
                return Err(BlockError::EmptyBlock { block: b });
            }
            if start < cursor {
                return Err(BlockError::Overlap { block: b });
            }
            if start > cursor {
                return Err(BlockError::Gap { at: cursor });
            }
            let end = start + len;
            if end > n {
                return Err(BlockError::OutOfRange { block: b, end, n });
            }
            cursor = end;
        }
        if cursor < n {
            return Err(BlockError::Gap { at: cursor });
        }
        let num_blocks = blocks.len();
        let mut block_owner_count = vec![0usize; num_blocks];
        for (i, ids) in owned.iter().enumerate() {
            if ids.is_empty() {
                return Err(BlockError::WorkerOwnsNothing { worker: i });
            }
            let mut prev: Option<usize> = None;
            for &b in ids {
                if b >= num_blocks {
                    return Err(BlockError::OwnedOutOfRange { worker: i, block: b, num_blocks });
                }
                if prev.is_some_and(|p| b <= p) {
                    return Err(BlockError::OwnedNotSorted { worker: i, block: b });
                }
                prev = Some(b);
                block_owner_count[b] += 1;
            }
        }
        if let Some(b) = block_owner_count.iter().position(|&c| c == 0) {
            return Err(BlockError::NoOwner { block: b });
        }

        let starts: Vec<usize> = blocks.iter().map(|&(s, _)| s).collect();
        let lens: Vec<usize> = blocks.iter().map(|&(_, l)| l).collect();
        let mut counts = vec![0usize; n];
        for (b, &c) in block_owner_count.iter().enumerate() {
            for j in starts[b]..starts[b] + lens[b] {
                counts[j] = c;
            }
        }
        let owned_lens: Vec<usize> =
            owned.iter().map(|ids| ids.iter().map(|&b| lens[b]).sum()).collect();
        // The compact owner transpose stores worker ids and local offsets
        // as u32 — ample for the 10⁶-worker sweeps this layout exists for.
        assert!(
            owned.len() <= u32::MAX as usize && n <= u32::MAX as usize,
            "pattern exceeds the u32 owner-transpose capacity"
        );
        let mut owner_idx = vec![0usize; num_blocks + 1];
        for (b, &c) in block_owner_count.iter().enumerate() {
            owner_idx[b + 1] = owner_idx[b] + c;
        }
        let mut fill = owner_idx.clone();
        let mut owner_entries = vec![(0u32, 0u32); owner_idx[num_blocks]];
        // Outer loop ascends over workers, so each block's entries land in
        // ascending worker order — the reduction order the sparse master's
        // bit-identity argument relies on.
        for (i, ids) in owned.iter().enumerate() {
            let mut local = 0usize;
            for &b in ids {
                owner_entries[fill[b]] = (i as u32, local as u32);
                fill[b] += 1;
                local += lens[b];
            }
        }
        Ok(BlockPattern { n, starts, lens, owned, counts, owned_lens, owner_idx, owner_entries })
    }

    /// The historical behaviour as a pattern: one block covering `[0, n)`,
    /// owned by every worker. An engine run with this pattern is
    /// bit-identical to the unsharded engine.
    pub fn dense(n: usize, n_workers: usize) -> Self {
        BlockPattern::new(n, &[(0, n)], vec![vec![0]; n_workers])
            // ad-lint: allow(panic-free-lib): one full-range block owned by every worker always passes validation
            .expect("the dense pattern is always valid for n, n_workers >= 1")
    }

    /// An even partition of `[0, n)` into `n_blocks` contiguous blocks
    /// (the first `n % n_blocks` blocks are one coordinate longer), as the
    /// `(start, len)` input of [`BlockPattern::new`]. `n_blocks` must be
    /// ≥ 1; with `n_blocks > n` the trailing blocks come out empty, which
    /// [`BlockPattern::new`] rejects as the typed
    /// [`BlockError::EmptyBlock`].
    pub fn even_blocks(n: usize, n_blocks: usize) -> Vec<(usize, usize)> {
        assert!(n_blocks >= 1, "need at least one block");
        let base = n / n_blocks;
        let extra = n % n_blocks;
        let mut out = Vec::with_capacity(n_blocks);
        let mut start = 0;
        for b in 0..n_blocks {
            let len = base + usize::from(b < extra);
            out.push((start, len));
            start += len;
        }
        out
    }

    /// A round-robin overlapping ownership over an even partition: block
    /// `b` is owned by workers `(b + j) mod N` for `j = 0..copies`. With
    /// `copies = 1` the blocks are disjoint across workers; `copies > 1`
    /// gives the overlapping-feature-blocks scenario (several workers
    /// share a block, general-form consensus resolves them on the master);
    /// `copies = N` is the dense pattern over `n_blocks` blocks.
    ///
    /// Every worker is covered iff `n_blocks + copies - 1 >= n_workers`
    /// (the owner slots span `(b + j) mod N`); otherwise this returns the
    /// typed [`BlockError::WorkerOwnsNothing`]. Every misconfiguration is
    /// a typed error, never a panic: `n_blocks = 0`, `n_workers = 0` or
    /// `copies = 0` → [`BlockError::EmptyPattern`] /
    /// [`BlockError::WorkerOwnsNothing`], `n_blocks > n` →
    /// [`BlockError::EmptyBlock`], `copies > n_workers` (a worker would
    /// own the same block twice) → [`BlockError::OwnedNotSorted`].
    pub fn round_robin(
        n: usize,
        n_blocks: usize,
        n_workers: usize,
        copies: usize,
    ) -> Result<Self, BlockError> {
        if n_blocks == 0 || n_workers == 0 {
            return Err(BlockError::EmptyPattern);
        }
        let blocks = Self::even_blocks(n, n_blocks);
        let mut owned = vec![Vec::new(); n_workers];
        for b in 0..n_blocks {
            for j in 0..copies {
                owned[(b + j) % n_workers].push(b);
            }
        }
        // Block ids were pushed in ascending order per worker; the
        // validation below turns any remaining misuse (empty ownership,
        // duplicate ids from copies > n_workers, empty trailing blocks
        // from n_blocks > n) into its typed error.
        BlockPattern::new(n, &blocks, owned)
    }

    /// Global dimension `n`.
    pub fn dim(&self) -> usize {
        self.n
    }

    pub fn num_blocks(&self) -> usize {
        self.starts.len()
    }

    pub fn num_workers(&self) -> usize {
        self.owned.len()
    }

    /// Block `b`'s global coordinate range.
    pub fn block_range(&self, b: usize) -> (usize, usize) {
        (self.starts[b], self.lens[b])
    }

    /// Worker i's owned block ids (strictly ascending).
    pub fn owned(&self, worker: usize) -> &[usize] {
        &self.owned[worker]
    }

    /// Length of worker i's owned slice `|S_i|` — the dimension of its
    /// local variable, dual and every message it exchanges.
    pub fn owned_len(&self, worker: usize) -> usize {
        self.owned_lens[worker]
    }

    /// Per-coordinate owner count `N_j` (the master's per-coordinate
    /// reduction width and prox denominator weight).
    pub fn count(&self, j: usize) -> usize {
        self.counts[j]
    }

    /// True when every worker owns the full dimension — the pattern where
    /// sharding changes nothing (all messages are full-length and every
    /// `N_j = N`). [`BlockPattern::dense`] is the canonical instance.
    pub fn is_effectively_dense(&self) -> bool {
        self.owned_lens.iter().all(|&l| l == self.n)
    }

    /// Walk worker i's owned slice as contiguous `(local_offset,
    /// global_start, len)` runs, in ascending global order. This is the
    /// one primitive every gather/scatter/reduction loop is written with,
    /// so the local↔global coordinate convention lives in exactly one
    /// place.
    pub fn for_each_range<F: FnMut(usize, usize, usize)>(&self, worker: usize, mut f: F) {
        let mut local = 0usize;
        for &b in &self.owned[worker] {
            f(local, self.starts[b], self.lens[b]);
            local += self.lens[b];
        }
    }

    /// Walk block `b`'s owners as `(worker, local_offset)` pairs in
    /// ascending worker order, where `local_offset` is where block `b`
    /// starts inside that worker's owned slice — the transpose of
    /// [`BlockPattern::for_each_range`], and the primitive the O(active)
    /// sparse master reduction ([`crate::admm::SparseMaster`]) is written
    /// with. Cost is `O(N_b)` with no allocation.
    pub fn for_each_owner<F: FnMut(usize, usize)>(&self, b: usize, mut f: F) {
        for &(w, lo) in &self.owner_entries[self.owner_idx[b]..self.owner_idx[b + 1]] {
            f(w as usize, lo as usize);
        }
    }

    /// Gather the global vector's owned slice for worker i into `out`
    /// (resized to `owned_len`).
    pub fn gather_into(&self, worker: usize, global: &[f64], out: &mut Vec<f64>) {
        debug_assert_eq!(global.len(), self.n);
        out.resize(self.owned_lens[worker], 0.0);
        let mut local = 0usize;
        for &b in &self.owned[worker] {
            let (s, l) = (self.starts[b], self.lens[b]);
            out[local..local + l].copy_from_slice(&global[s..s + l]);
            local += l;
        }
    }

    /// Allocating variant of [`BlockPattern::gather_into`].
    pub fn gather_vec(&self, worker: usize, global: &[f64]) -> Vec<f64> {
        let mut out = Vec::new();
        self.gather_into(worker, global, &mut out);
        out
    }

    /// Total communicated coordinates over one full round of all workers,
    /// as a fraction of the dense protocol's `N·n`. Strictly `< 1` for any
    /// genuinely sharded pattern — the comm-volume reduction the
    /// `virtual_scale` bench reports as `sharded_comm_volume_ratio`.
    pub fn comm_volume_ratio(&self) -> f64 {
        let total: usize = self.owned_lens.iter().sum();
        total as f64 / (self.owned.len() * self.n) as f64
    }

    /// Serialize for the v2 checkpoint format.
    pub fn to_json(&self) -> JsonValue {
        let blocks = JsonValue::Arr(
            self.starts
                .iter()
                .zip(&self.lens)
                .map(|(&s, &l)| {
                    JsonValue::Arr(vec![JsonValue::Num(s as f64), JsonValue::Num(l as f64)])
                })
                .collect(),
        );
        let owned = JsonValue::Arr(
            self.owned
                .iter()
                .map(|ids| {
                    JsonValue::Arr(ids.iter().map(|&b| JsonValue::Num(b as f64)).collect())
                })
                .collect(),
        );
        JsonValue::Obj(vec![
            ("n".to_string(), JsonValue::Num(self.n as f64)),
            ("blocks".to_string(), blocks),
            ("owned".to_string(), owned),
        ])
    }

    /// Restore a pattern serialized by [`BlockPattern::to_json`]
    /// (re-validated on load).
    pub fn from_json(doc: &JsonValue) -> Result<Self, String> {
        let n = json_usize(doc.get("n").ok_or("pattern missing field \"n\"")?)?;
        let mut blocks = Vec::new();
        for pair in doc.get("blocks").ok_or("pattern missing field \"blocks\"")?.items() {
            let items = pair.items();
            if items.len() != 2 {
                return Err("pattern block entry is not a [start, len] pair".to_string());
            }
            blocks.push((json_usize(&items[0])?, json_usize(&items[1])?));
        }
        let mut owned = Vec::new();
        for ids in doc.get("owned").ok_or("pattern missing field \"owned\"")?.items() {
            owned.push(ids.items().iter().map(json_usize).collect::<Result<Vec<_>, _>>()?);
        }
        BlockPattern::new(n, &blocks, owned).map_err(|e| format!("invalid pattern: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_pattern_is_effectively_dense() {
        let p = BlockPattern::dense(10, 4);
        assert_eq!(p.dim(), 10);
        assert_eq!(p.num_blocks(), 1);
        assert_eq!(p.num_workers(), 4);
        assert!(p.is_effectively_dense());
        for i in 0..4 {
            assert_eq!(p.owned_len(i), 10);
        }
        for j in 0..10 {
            assert_eq!(p.count(j), 4);
        }
        assert_eq!(p.comm_volume_ratio(), 1.0);
    }

    #[test]
    fn even_blocks_partition_exactly() {
        assert_eq!(BlockPattern::even_blocks(10, 3), vec![(0, 4), (4, 3), (7, 3)]);
        let singletons: Vec<(usize, usize)> = (0..6).map(|i| (i, 1)).collect();
        assert_eq!(BlockPattern::even_blocks(6, 6), singletons);
    }

    #[test]
    fn round_robin_disjoint_and_overlapping() {
        let p = BlockPattern::round_robin(12, 4, 4, 1).unwrap();
        assert_eq!(p.owned(0), &[0]);
        assert_eq!(p.owned(3), &[3]);
        assert!((p.comm_volume_ratio() - 0.25).abs() < 1e-12);
        for j in 0..12 {
            assert_eq!(p.count(j), 1);
        }

        let q = BlockPattern::round_robin(12, 4, 4, 2).unwrap();
        assert_eq!(q.owned(0), &[0, 3]);
        assert_eq!(q.owned(1), &[0, 1]);
        for j in 0..12 {
            assert_eq!(q.count(j), 2);
        }
        assert!((q.comm_volume_ratio() - 0.5).abs() < 1e-12);

        let dense = BlockPattern::round_robin(12, 4, 4, 4).unwrap();
        assert!(dense.is_effectively_dense());
    }

    #[test]
    fn validation_rejects_gaps_overlaps_out_of_range() {
        // gap between blocks
        let err = BlockPattern::new(10, &[(0, 4), (6, 4)], vec![vec![0, 1]]).unwrap_err();
        assert_eq!(err, BlockError::Gap { at: 4 });
        // tail gap
        let err = BlockPattern::new(10, &[(0, 4)], vec![vec![0]]).unwrap_err();
        assert_eq!(err, BlockError::Gap { at: 4 });
        // overlap
        let err = BlockPattern::new(10, &[(0, 6), (4, 6)], vec![vec![0, 1]]).unwrap_err();
        assert_eq!(err, BlockError::Overlap { block: 1 });
        // out of range
        let err = BlockPattern::new(10, &[(0, 11)], vec![vec![0]]).unwrap_err();
        assert_eq!(err, BlockError::OutOfRange { block: 0, end: 11, n: 10 });
        // empty block
        let err = BlockPattern::new(10, &[(0, 0), (0, 10)], vec![vec![1]]).unwrap_err();
        assert_eq!(err, BlockError::EmptyBlock { block: 0 });
    }

    #[test]
    fn validation_rejects_bad_ownership() {
        let blocks = [(0usize, 5usize), (5, 5)];
        let err = BlockPattern::new(10, &blocks, vec![vec![0, 2], vec![1]]).unwrap_err();
        assert_eq!(err, BlockError::OwnedOutOfRange { worker: 0, block: 2, num_blocks: 2 });
        let err = BlockPattern::new(10, &blocks, vec![vec![1, 0], vec![1]]).unwrap_err();
        assert_eq!(err, BlockError::OwnedNotSorted { worker: 0, block: 0 });
        let err = BlockPattern::new(10, &blocks, vec![vec![0, 0], vec![1]]).unwrap_err();
        assert_eq!(err, BlockError::OwnedNotSorted { worker: 0, block: 0 });
        let err = BlockPattern::new(10, &blocks, vec![vec![0], Vec::new()]).unwrap_err();
        assert_eq!(err, BlockError::WorkerOwnsNothing { worker: 1 });
        let err = BlockPattern::new(10, &blocks, vec![vec![0], vec![0]]).unwrap_err();
        assert_eq!(err, BlockError::NoOwner { block: 1 });
    }

    #[test]
    fn round_robin_misuse_is_typed_never_a_panic() {
        assert_eq!(BlockPattern::round_robin(10, 0, 4, 1), Err(BlockError::EmptyPattern));
        assert_eq!(BlockPattern::round_robin(10, 2, 0, 1), Err(BlockError::EmptyPattern));
        // n_blocks > n: the trailing blocks are empty.
        assert!(matches!(
            BlockPattern::round_robin(3, 5, 2, 2),
            Err(BlockError::EmptyBlock { .. })
        ));
        // copies = 0: nobody owns anything.
        assert!(matches!(
            BlockPattern::round_robin(10, 2, 2, 0),
            Err(BlockError::WorkerOwnsNothing { worker: 0 })
        ));
        // copies > n_workers: a worker would own the same block twice.
        assert!(matches!(
            BlockPattern::round_robin(10, 2, 2, 3),
            Err(BlockError::OwnedNotSorted { .. })
        ));
        // too few owner slots to cover every worker
        assert!(matches!(
            BlockPattern::round_robin(10, 2, 5, 1),
            Err(BlockError::WorkerOwnsNothing { worker: 2 })
        ));
    }

    #[test]
    fn gather_and_ranges_agree() {
        let p = BlockPattern::new(8, &[(0, 3), (3, 2), (5, 3)], vec![vec![0, 2], vec![1]])
            .unwrap();
        assert_eq!(p.owned_len(0), 6);
        assert_eq!(p.owned_len(1), 2);
        let global: Vec<f64> = (0..8).map(|v| v as f64).collect();
        assert_eq!(p.gather_vec(0, &global), vec![0.0, 1.0, 2.0, 5.0, 6.0, 7.0]);
        assert_eq!(p.gather_vec(1, &global), vec![3.0, 4.0]);
        let mut runs = Vec::new();
        p.for_each_range(0, |lo, g, len| runs.push((lo, g, len)));
        assert_eq!(runs, vec![(0, 0, 3), (3, 5, 3)]);
        // counts: block 0 and 2 owned once, block 1 owned once
        assert!((0..8).all(|j| p.count(j) == 1));
    }

    #[test]
    fn owner_transpose_is_consistent_with_ranges() {
        let p = BlockPattern::new(8, &[(0, 3), (3, 2), (5, 3)], vec![vec![0, 2], vec![1, 2]])
            .unwrap();
        // Reconstruct (worker → block, local) incidences from for_each_range
        // and check for_each_owner yields the transpose, ascending by worker.
        let mut expected: Vec<Vec<(usize, usize)>> = vec![Vec::new(); p.num_blocks()];
        for i in 0..p.num_workers() {
            let mut local = 0usize;
            for &b in p.owned(i) {
                expected[b].push((i, local));
                local += p.block_range(b).1;
            }
        }
        for b in 0..p.num_blocks() {
            let mut got = Vec::new();
            p.for_each_owner(b, |w, lo| got.push((w, lo)));
            assert_eq!(got, expected[b], "block {b}");
            assert!(got.windows(2).all(|w| w[0].0 < w[1].0), "ascending workers");
        }
        assert_eq!(p.count(5), 2); // block 2 owned by both workers
    }

    #[test]
    fn json_roundtrip_revalidates() {
        let p = BlockPattern::round_robin(11, 3, 4, 2).unwrap();
        let back = BlockPattern::from_json(&p.to_json()).unwrap();
        assert_eq!(p, back);
        // corrupt document fails cleanly
        assert!(BlockPattern::from_json(&JsonValue::Obj(Vec::new())).is_err());
    }
}
