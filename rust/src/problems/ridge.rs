//! Ridge local cost: `f_i(w) = ‖A_i w − b_i‖² + μ/2 ‖w‖²` — a strongly
//! convex variant used by the Algorithm-4 experiments (Theorem 2 *requires*
//! strong convexity) and by tests that need a known modulus σ² = μ.

use super::cache::{Factor, RhoCache};
use super::{LocalCost, WorkerScratch};
use crate::linalg::dense::DenseMatrix;
use crate::linalg::power::power_iteration;
use crate::linalg::vecops;

pub struct RidgeLocal {
    a: DenseMatrix,
    b: Vec<f64>,
    mu: f64,
    gram: DenseMatrix,
    two_atb: Vec<f64>,
    lip: f64,
    cache: RhoCache,
}

impl RidgeLocal {
    pub fn new(a: DenseMatrix, b: Vec<f64>, mu: f64) -> Self {
        assert_eq!(a.rows(), b.len());
        assert!(mu >= 0.0);
        let gram = a.gram();
        let mut two_atb = a.matvec_t(&b);
        vecops::scale(2.0, &mut two_atb);
        let n = a.cols();
        let (lam_max, _) =
            power_iteration(|v, out| gram.matvec_into(v, out), n, 300, 1e-9, 0x41d6e);
        RidgeLocal {
            a,
            b,
            mu,
            gram,
            two_atb,
            lip: 2.0 * lam_max.max(0.0) + mu,
            cache: RhoCache::new(),
        }
    }

    /// Strong-convexity modulus σ² (= μ here; larger if AᵀA ≻ 0).
    pub fn strong_convexity(&self) -> f64 {
        self.mu
    }
}

impl LocalCost for RidgeLocal {
    fn dim(&self) -> usize {
        self.a.cols()
    }

    fn eval(&self, x: &[f64]) -> f64 {
        let mut r = self.a.matvec(x);
        for (ri, bi) in r.iter_mut().zip(&self.b) {
            *ri -= bi;
        }
        vecops::nrm2_sq(&r) + 0.5 * self.mu * vecops::nrm2_sq(x)
    }

    fn eval_with(&self, x: &[f64], scratch: &mut WorkerScratch) -> f64 {
        scratch.rows.resize(self.a.rows(), 0.0);
        self.a.matvec_into(x, &mut scratch.rows);
        for (ri, bi) in scratch.rows.iter_mut().zip(&self.b) {
            *ri -= bi;
        }
        vecops::nrm2_sq(&scratch.rows) + 0.5 * self.mu * vecops::nrm2_sq(x)
    }

    fn grad_into(&self, x: &[f64], out: &mut [f64]) {
        self.gram.matvec_into(x, out);
        for i in 0..out.len() {
            out[i] = 2.0 * out[i] - self.two_atb[i] + self.mu * x[i];
        }
    }

    fn lipschitz(&self) -> f64 {
        self.lip
    }

    fn solve_subproblem(
        &self,
        lam: &[f64],
        x0: &[f64],
        rho: f64,
        out: &mut [f64],
        _scratch: &mut WorkerScratch,
    ) {
        // (2AᵀA + (μ+ρ) I) w = 2Aᵀb − λ + ρ x₀ — closed form, no temporaries.
        let n = self.dim();
        let factor = self.cache.get_or_build(rho, || {
            let mut m = self.gram.clone();
            m.scale(2.0);
            m.add_diag(self.mu + rho);
            Factor::of(&m)
        });
        for i in 0..n {
            out[i] = self.two_atb[i] - lam[i] + rho * x0[i];
        }
        factor.solve_in_place(out);
    }

    fn kind(&self) -> &'static str {
        "ridge"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::tests::{check_grad, check_subproblem};
    use crate::rng::Pcg64;

    fn inst(seed: u64, m: usize, n: usize, mu: f64) -> RidgeLocal {
        let mut rng = Pcg64::seed_from_u64(seed);
        let a = DenseMatrix::randn(&mut rng, m, n);
        let b: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        RidgeLocal::new(a, b, mu)
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let l = inst(41, 12, 7, 0.5);
        let x: Vec<f64> = (0..7).map(|i| 0.1 * i as f64 - 0.3).collect();
        check_grad(&l, &x, 1e-5);
    }

    #[test]
    fn subproblem_stationarity() {
        let l = inst(42, 15, 6, 1.0);
        check_subproblem(&l, 2.0, 1e-8);
    }

    #[test]
    fn mu_zero_reduces_to_lasso_cost() {
        use crate::problems::LassoLocal;
        let mut rng = Pcg64::seed_from_u64(43);
        let a = DenseMatrix::randn(&mut rng, 10, 5);
        let b: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        let ridge = RidgeLocal::new(a.clone(), b.clone(), 0.0);
        let lasso = LassoLocal::new(a, b);
        let x: Vec<f64> = (0..5).map(|i| (i as f64).sin()).collect();
        assert!((ridge.eval(&x) - lasso.eval(&x)).abs() < 1e-10);
    }
}
