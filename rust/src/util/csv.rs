//! CSV emission for figure/bench series (read back by any plotting tool).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Buffered CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    columns: usize,
}

impl CsvWriter {
    /// Create (truncating) a CSV with the given header row.
    pub fn create(path: &Path, header: &[&str]) -> std::io::Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, columns: header.len() })
    }

    /// Write one row of f64 cells (NaN/inf serialized literally; figure
    /// series use them to mark divergence).
    pub fn row(&mut self, cells: &[f64]) -> std::io::Result<()> {
        assert_eq!(cells.len(), self.columns, "row width != header width");
        let mut line = String::with_capacity(cells.len() * 12);
        for (i, c) in cells.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            line.push_str(&format_cell(*c));
        }
        writeln!(self.out, "{line}")
    }

    /// Write a row of preformatted string cells.
    pub fn row_str(&mut self, cells: &[String]) -> std::io::Result<()> {
        assert_eq!(cells.len(), self.columns);
        writeln!(self.out, "{}", cells.join(","))
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

fn format_cell(v: f64) -> String {
    if v.is_nan() {
        "nan".into()
    } else if v.is_infinite() {
        if v > 0.0 {
            "inf".into()
        } else {
            "-inf".into()
        }
    } else if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.10e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("ad_admm_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["k", "acc"]).unwrap();
            w.row(&[0.0, 1.5]).unwrap();
            w.row(&[1.0, f64::INFINITY]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "k,acc");
        assert!(lines[1].starts_with("0,1.5"));
        assert_eq!(lines[2], "1,inf");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let dir = std::env::temp_dir().join("ad_admm_csv_test2");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        let _ = w.row(&[1.0]);
    }
}
