//! Terminal plotting: log-scale convergence curves as ASCII, so the figure
//! benches show the paper's plots directly in `cargo bench` output.

/// One named series of (x, y) points.
pub struct Series<'a> {
    pub label: &'a str,
    pub ys: &'a [f64],
}

/// Render several series (shared x = index) on a log10-y ASCII canvas.
///
/// Non-finite / non-positive values are clipped to the canvas edge (they
/// mark divergence). Each series uses its own glyph; a legend is appended.
pub fn render_log_curves(series: &[Series<'_>], width: usize, height: usize) -> String {
    const GLYPHS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];
    assert!(width >= 16 && height >= 4);
    let max_len = series.iter().map(|s| s.ys.len()).max().unwrap_or(0);
    if max_len == 0 {
        return String::from("(no data)\n");
    }
    // y range over finite positive values (log10)
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for s in series {
        for &y in s.ys {
            if y.is_finite() && y > 0.0 {
                let l = y.log10();
                lo = lo.min(l);
                hi = hi.max(l);
            }
        }
    }
    if !lo.is_finite() || !hi.is_finite() {
        return String::from("(no positive finite data — all series diverged)\n");
    }
    if (hi - lo).abs() < 1e-12 {
        hi = lo + 1.0;
    }

    let mut canvas = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let glyph = GLYPHS[si % GLYPHS.len()];
        for (i, &y) in s.ys.iter().enumerate() {
            let col = if max_len <= 1 { 0 } else { i * (width - 1) / (max_len - 1) };
            let l = if y.is_finite() && y > 0.0 {
                y.log10()
            } else {
                hi
            };
            let frac = ((l - lo) / (hi - lo)).clamp(0.0, 1.0);
            let row = ((1.0 - frac) * (height - 1) as f64).round() as usize;
            canvas[row][col] = glyph;
        }
    }

    let mut out = String::new();
    for (r, row) in canvas.iter().enumerate() {
        let l = hi - (hi - lo) * r as f64 / (height - 1) as f64;
        out.push_str(&format!("1e{l:>6.1} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>9} +{}\n", "", "-".repeat(width)));
    out.push_str(&format!("{:>10}0{:>width$}\n", "", max_len - 1, width = width - 1));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", GLYPHS[si % GLYPHS.len()], s.label));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_monotone_decay() {
        let ys: Vec<f64> = (0..50).map(|k| 10f64.powi(-(k as i32) / 10)).collect();
        let text = render_log_curves(&[Series { label: "decay", ys: &ys }], 40, 10);
        assert!(text.contains("decay"));
        // top-left should hold the first (largest) point, bottom-right the tail
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[0].contains('*'));
        assert!(lines.len() > 10);
    }

    #[test]
    fn diverged_series_clip_to_top() {
        let ys = vec![1.0, f64::INFINITY, f64::NAN];
        let text = render_log_curves(&[Series { label: "div", ys: &ys }], 30, 6);
        assert!(text.contains("div"));
    }

    #[test]
    fn all_nonpositive_is_graceful() {
        let ys = vec![0.0, -1.0];
        let text = render_log_curves(&[Series { label: "z", ys: &ys }], 30, 6);
        assert!(text.contains("diverged"));
    }

    #[test]
    fn multiple_series_get_distinct_glyphs() {
        let a = vec![1.0, 0.1, 0.01];
        let b = vec![1.0, 0.5, 0.25];
        let text = render_log_curves(
            &[Series { label: "a", ys: &a }, Series { label: "b", ys: &b }],
            30,
            8,
        );
        assert!(text.contains("* a"));
        assert!(text.contains("o b"));
    }
}
