//! INI/TOML-lite config files: `[section]` headers, `key = value` lines,
//! `#` comments. Enough to configure experiments reproducibly without
//! `serde` on the image.

use std::collections::HashMap;
use std::path::Path;

/// Parsed config: `section.key -> value` (top-level keys have no prefix).
#[derive(Clone, Debug, Default)]
pub struct ConfigFile {
    values: HashMap<String, String>,
}

impl ConfigFile {
    /// Parse from a string.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut values = HashMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[') {
                let name = inner
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section header", lineno + 1))?;
                section = name.trim().to_string();
            } else if let Some((k, v)) = line.split_once('=') {
                let key = if section.is_empty() {
                    k.trim().to_string()
                } else {
                    format!("{section}.{}", k.trim())
                };
                values.insert(key, v.trim().trim_matches('"').to_string());
            } else {
                return Err(format!("line {}: expected key = value, got {raw:?}", lineno + 1));
            }
        }
        Ok(ConfigFile { values })
    }

    /// Load and parse a file.
    pub fn load(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            None => default,
            Some(s) => s.parse().unwrap_or(default),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_keys_comments() {
        let cfg = ConfigFile::parse(
            "# experiment\nrho = 500\n[network]\ntau = 10   # delay\nworkers = 16\n",
        )
        .unwrap();
        assert_eq!(cfg.get("rho"), Some("500"));
        assert_eq!(cfg.get("network.tau"), Some("10"));
        assert_eq!(cfg.get_parse_or::<usize>("network.workers", 0), 16);
        assert_eq!(cfg.len(), 3);
    }

    #[test]
    fn quoted_values_unquoted() {
        let cfg = ConfigFile::parse("name = \"fig3\"\n").unwrap();
        assert_eq!(cfg.get("name"), Some("fig3"));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(ConfigFile::parse("this is not a kv line\n").is_err());
        assert!(ConfigFile::parse("[unterminated\n").is_err());
    }

    #[test]
    fn missing_key_falls_back() {
        let cfg = ConfigFile::parse("").unwrap();
        assert!(cfg.is_empty());
        assert_eq!(cfg.get_parse_or::<f64>("rho", 1.25), 1.25);
    }
}
