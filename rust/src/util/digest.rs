//! Bit-exact fingerprints for the reproducibility claims.
//!
//! The CLI, the solver service and the transport e2e suite all compare
//! runs across process boundaries by printing/grepping one 64-bit FNV-1a
//! digest over the exact bit patterns of x₀ — moving the digest here (from
//! a private helper in `main.rs`) makes "same digest" mean the same thing
//! everywhere.

/// FNV-1a over the little-endian `to_bits()` bytes of each coordinate — a
/// stable fingerprint for bit-identity claims (checkpoint/resume, lockstep
/// transport replay). Two digests are equal iff every f64 is bit-equal.
pub fn x0_digest(x0: &[f64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for v in x0 {
        for byte in v.to_bits().to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digest_distinguishes_bit_patterns() {
        // 0.0 and -0.0 are == but not bit-equal: the digest must differ.
        assert_ne!(x0_digest(&[0.0]), x0_digest(&[-0.0]));
        assert_eq!(x0_digest(&[1.5, 2.5]), x0_digest(&[1.5, 2.5]));
        assert_ne!(x0_digest(&[1.5, 2.5]), x0_digest(&[2.5, 1.5]));
        // NaN payloads are preserved verbatim.
        let q = f64::from_bits(0x7ff8_0000_0000_0001);
        let r = f64::from_bits(0x7ff8_0000_0000_0002);
        assert_ne!(x0_digest(&[q]), x0_digest(&[r]));
    }

    #[test]
    fn digest_matches_known_fnv_vector() {
        // Empty input = FNV-1a offset basis.
        assert_eq!(x0_digest(&[]), 0xcbf2_9ce4_8422_2325);
    }
}
