//! Timing helpers: the [`Clock`] abstraction shared by the real-thread and
//! virtual-time cluster modes, plus a wall-clock stopwatch.

use std::time::{Duration, Instant};

/// A monotone clock readable in seconds since its epoch.
///
/// Two implementations exist: [`Stopwatch`] (wall clock, used by the
/// real-thread star cluster) and `cluster::clock::VirtualClock` (a
/// discrete-event simulated clock advanced by the scheduler). Code that
/// only *reads* time — utilization stats, timelines, reports — is written
/// against this trait so it works identically in both modes.
pub trait Clock {
    /// Seconds elapsed since the clock's epoch (start of the run).
    fn now_s(&self) -> f64;
}

/// A simple stopwatch with lap support.
#[derive(Clone, Debug)]
pub struct Stopwatch {
    start: Instant, // ad-lint: allow(wallclock): Stopwatch IS the real-time measurement utility; consumed by bench/bins
    last_lap: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        let now = Instant::now(); // ad-lint: allow(wallclock): Stopwatch measures real elapsed time by definition
        Stopwatch { start: now, last_lap: now }
    }

    /// Seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Seconds since the previous `lap()` (or start), and reset the lap.
    pub fn lap_s(&mut self) -> f64 {
        let now = Instant::now(); // ad-lint: allow(wallclock): Stopwatch measures real elapsed time by definition
        let dt = now.duration_since(self.last_lap).as_secs_f64();
        self.last_lap = now;
        dt
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

impl Clock for Stopwatch {
    fn now_s(&self) -> f64 {
        self.elapsed_s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_monotone() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_s();
        let b = sw.elapsed_s();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn stopwatch_is_a_clock() {
        let sw = Stopwatch::start();
        let c: &dyn Clock = &sw;
        assert!(c.now_s() >= 0.0);
        assert!(c.now_s() <= sw.elapsed_s());
    }

    #[test]
    fn laps_accumulate_to_elapsed() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        let l1 = sw.lap_s();
        std::thread::sleep(Duration::from_millis(2));
        let l2 = sw.lap_s();
        assert!(l1 > 0.0 && l2 > 0.0);
        assert!(sw.elapsed_s() >= l1 + l2 - 1e-3);
    }
}
