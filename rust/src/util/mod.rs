//! Small infrastructure substrates: CLI parsing, config files, CSV output,
//! timing. The offline image carries no `clap`/`serde`/`csv`, so these are
//! in-repo.

pub mod cli;
pub mod configfile;
pub mod csv;
pub mod digest;
pub mod plot;
pub mod timer;

pub use cli::ArgParser;
pub use digest::x0_digest;
pub use configfile::ConfigFile;
pub use csv::CsvWriter;
pub use timer::{Clock, Stopwatch};
