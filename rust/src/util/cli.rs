//! Minimal GNU-style argument parser (`--key value`, `--key=value`,
//! `--flag`, positionals). Replaces `clap` on this offline image.

use std::collections::HashMap;

/// Parsed command line: options, flags and positionals.
#[derive(Clone, Debug, Default)]
pub struct ArgParser {
    opts: HashMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl ArgParser {
    /// Parse from an explicit token list (testable); `known_flags` names the
    /// options that take **no** value.
    pub fn parse_from<I: IntoIterator<Item = String>>(args: I, known_flags: &[&str]) -> Self {
        let mut out = ArgParser::default();
        let mut it = args.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        out.flags.push(body.to_string());
                    } else {
                        // ad-lint: allow(panic-free-lib): guarded by the it.peek() arm above
                        out.opts.insert(body.to_string(), it.next().unwrap());
                    }
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn from_env(known_flags: &[&str]) -> Self {
        Self::parse_from(std::env::args().skip(1), known_flags)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed access with a default; panics with a clear message on a
    /// malformed value (CLI misuse should fail loudly).
    pub fn get_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            None => default,
            Some(s) => s
                .parse()
                // ad-lint: allow(panic-free-lib): CLI parse failure aborts by design; the binaries own their argv
                .unwrap_or_else(|_| panic!("--{key}: cannot parse {s:?}")),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn key_value_pairs() {
        let a = ArgParser::parse_from(toks("--rho 500 --tau=10 run"), &[]);
        assert_eq!(a.get("rho"), Some("500"));
        assert_eq!(a.get("tau"), Some("10"));
        assert_eq!(a.positional(), &["run".to_string()]);
    }

    #[test]
    fn flags_and_typed() {
        let a = ArgParser::parse_from(toks("--verbose --n 32"), &["verbose"]);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get_parse_or::<usize>("n", 0), 32);
        assert_eq!(a.get_parse_or::<f64>("rho", 1.5), 1.5);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = ArgParser::parse_from(toks("--n 4 --dry-run"), &[]);
        assert!(a.has_flag("dry-run"));
    }

    #[test]
    fn flag_followed_by_option() {
        let a = ArgParser::parse_from(toks("--fast --rho 2.0"), &[]);
        assert!(a.has_flag("fast"));
        assert_eq!(a.get("rho"), Some("2.0"));
    }

    #[test]
    #[should_panic(expected = "cannot parse")]
    fn malformed_typed_value_panics() {
        let a = ArgParser::parse_from(toks("--n abc"), &[]);
        a.get_parse_or::<usize>("n", 0);
    }
}
