//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts (HLO text under
//! `artifacts/`) and execute them from the Rust hot path.
//!
//! Python runs only at build time (`make artifacts`); at run time the
//! [`PjrtEngine`] compiles each `*.hlo.txt` once on the PJRT CPU client and
//! the per-worker [`solvers`] keep their data blocks resident as device
//! buffers, so a subproblem solve is: upload `(λ, x₀, ρ)` (three small
//! buffers) → `execute_b` → download `x`.
//!
//! Interchange is HLO **text**, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod engine;
pub mod manifest;
pub mod solvers;

pub use engine::PjrtEngine;
pub use manifest::{ArtifactEntry, ArtifactRegistry};
pub use solvers::{PjrtLassoSolver, PjrtMasterProx, PjrtSpcaSolver};

/// Default artifacts directory (relative to the repo root).
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts directory: `$AD_ADMM_ARTIFACTS` override, else
/// `artifacts/` relative to the current directory, else relative to the
/// crate root (so `cargo test` from anywhere finds it).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("AD_ADMM_ARTIFACTS") {
        return p.into();
    }
    let cwd = std::path::PathBuf::from(DEFAULT_ARTIFACTS_DIR);
    if cwd.join("manifest.txt").exists() {
        return cwd;
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(DEFAULT_ARTIFACTS_DIR)
}

/// True when AOT artifacts have been built (`make artifacts`).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.txt").exists()
}
