//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts (HLO text under
//! `artifacts/`) and execute them from the Rust hot path.
//!
//! Python runs only at build time (`make artifacts`); at run time the
//! [`PjrtEngine`] compiles each `*.hlo.txt` once on the PJRT CPU client and
//! the per-worker [`PjrtLassoSolver`]/[`PjrtSpcaSolver`] keep their data
//! blocks resident as device buffers, so a subproblem solve is: upload
//! `(λ, x₀, ρ)` (three small buffers) → `execute_b` → download `x`.
//!
//! Interchange is HLO **text**, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! ## Feature gating
//!
//! Real execution needs the `xla` PJRT binding crate, which the offline CI
//! image does not carry. The `pjrt` cargo feature selects the real
//! implementation; without it (the default) this module exposes
//! API-compatible stubs whose constructors return [`RuntimeError`], so
//! every caller — the cluster example, the hot-path bench, the parity
//! tests — compiles unchanged and falls back to the native closed-form
//! solvers. Check [`pjrt_enabled`] before attempting to load an engine.

#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod solvers;

#[cfg(not(feature = "pjrt"))]
mod stub;

pub mod manifest;

#[cfg(feature = "pjrt")]
pub use engine::PjrtEngine;
#[cfg(feature = "pjrt")]
pub use solvers::{PjrtLassoSolver, PjrtMasterProx, PjrtSpcaSolver};

#[cfg(not(feature = "pjrt"))]
pub use stub::{PjrtBuffer, PjrtEngine, PjrtLassoSolver, PjrtMasterProx, PjrtSpcaSolver};

pub use manifest::{ArtifactEntry, ArtifactRegistry};

/// Error type of the runtime layer (std-only replacement for `anyhow`).
#[derive(Debug, Clone)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

impl From<String> for RuntimeError {
    fn from(s: String) -> Self {
        RuntimeError(s)
    }
}

impl From<&str> for RuntimeError {
    fn from(s: &str) -> Self {
        RuntimeError(s.to_string())
    }
}

/// Result alias used across the runtime layer.
pub type RuntimeResult<T> = Result<T, RuntimeError>;

/// True when this build carries the real PJRT backend (`pjrt` feature).
/// Callers use this to skip (rather than fail) artifact-backed paths.
pub fn pjrt_enabled() -> bool {
    cfg!(feature = "pjrt")
}

/// Default artifacts directory (relative to the repo root).
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts directory: `$AD_ADMM_ARTIFACTS` override, else
/// `artifacts/` relative to the current directory, else relative to the
/// crate root (so `cargo test` from anywhere finds it).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("AD_ADMM_ARTIFACTS") {
        return p.into();
    }
    let cwd = std::path::PathBuf::from(DEFAULT_ARTIFACTS_DIR);
    if cwd.join("manifest.txt").exists() {
        return cwd;
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(DEFAULT_ARTIFACTS_DIR)
}

/// True when AOT artifacts have been built (`make artifacts`).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_error_displays_message() {
        let e = RuntimeError::from("artifact missing");
        assert_eq!(e.to_string(), "artifact missing");
        let e2: RuntimeError = format!("bad {}", 7).into();
        assert_eq!(e2.to_string(), "bad 7");
    }

    #[test]
    fn pjrt_enabled_matches_feature() {
        assert_eq!(pjrt_enabled(), cfg!(feature = "pjrt"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_engine_reports_unavailable() {
        let err = PjrtEngine::load(std::path::Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
