//! The artifacts manifest: `artifacts/manifest.txt`, one line per artifact,
//! space-separated `key=value` pairs. Written by `python/compile/aot.py`,
//! parsed here. Example line:
//!
//! ```text
//! name=lasso_worker_m200_n100 file=lasso_worker_m200_n100.hlo.txt kind=lasso_worker m=200 n=100 dtype=f64 cg_iters=80
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One artifact's metadata.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub attrs: HashMap<String, String>,
}

impl ArtifactEntry {
    pub fn attr_usize(&self, key: &str) -> Option<usize> {
        self.attrs.get(key).and_then(|v| v.parse().ok())
    }

    fn parse_line(line: &str) -> Result<Self, String> {
        let mut attrs = HashMap::new();
        for tok in line.split_whitespace() {
            let (k, v) = tok
                .split_once('=')
                .ok_or_else(|| format!("bad manifest token {tok:?}"))?;
            attrs.insert(k.to_string(), v.to_string());
        }
        let name = attrs.remove("name").ok_or("manifest line missing name=")?;
        let file = attrs.remove("file").ok_or("manifest line missing file=")?;
        let kind = attrs.remove("kind").unwrap_or_default();
        Ok(ArtifactEntry { name, file, kind, attrs })
    }
}

/// All artifacts in a directory.
#[derive(Clone, Debug, Default)]
pub struct ArtifactRegistry {
    pub dir: PathBuf,
    entries: HashMap<String, ArtifactEntry>,
}

impl ArtifactRegistry {
    /// Parse `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self, String> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e} (run `make artifacts`)", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Self, String> {
        let mut entries = HashMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let e = ArtifactEntry::parse_line(line)
                .map_err(|msg| format!("manifest line {}: {msg}", lineno + 1))?;
            entries.insert(e.name.clone(), e);
        }
        Ok(ArtifactRegistry { dir: dir.to_path_buf(), entries })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, name: &str) -> Option<PathBuf> {
        self.get(name).map(|e| self.dir.join(&e.file))
    }

    /// Look up the worker-update artifact for a problem kind and shape.
    pub fn worker_artifact(&self, kind: &str, m: usize, n: usize) -> Option<&ArtifactEntry> {
        self.get(&format!("{kind}_worker_m{m}_n{n}"))
    }

    /// Look up the master prox artifact for dimension n.
    pub fn master_prox(&self, n: usize) -> Option<&ArtifactEntry> {
        self.get(&format!("master_prox_n{n}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment line
name=lasso_worker_m20_n10 file=lw.hlo.txt kind=lasso_worker m=20 n=10 dtype=f64 cg_iters=40

name=master_prox_n10 file=mp.hlo.txt kind=master_prox n=10 dtype=f64
";

    #[test]
    fn parses_entries_and_attrs() {
        let reg = ArtifactRegistry::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        assert_eq!(reg.len(), 2);
        let e = reg.get("lasso_worker_m20_n10").unwrap();
        assert_eq!(e.kind, "lasso_worker");
        assert_eq!(e.attr_usize("cg_iters"), Some(40));
        assert_eq!(reg.path_of("master_prox_n10").unwrap(), Path::new("/tmp/x/mp.hlo.txt"));
    }

    #[test]
    fn shape_lookups() {
        let reg = ArtifactRegistry::parse(Path::new("."), SAMPLE).unwrap();
        assert!(reg.worker_artifact("lasso", 20, 10).is_some());
        assert!(reg.worker_artifact("lasso", 21, 10).is_none());
        assert!(reg.master_prox(10).is_some());
        assert!(reg.master_prox(11).is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(ArtifactRegistry::parse(Path::new("."), "name_only_no_eq\n").is_err());
        assert!(ArtifactRegistry::parse(Path::new("."), "file=x.hlo\n").is_err());
    }
}
