//! PJRT-backed implementations of the ADMM update contracts.
//! Compiled only with the `pjrt` feature (needs the vendored `xla` crate).
//!
//! Each solver keeps its worker's data block (`A_i` / dense `B_j`) resident
//! on the device and uploads only the small per-iteration vectors.

use std::sync::Arc;

use crate::admm::master_pov::SubproblemSolver;
use crate::data::{LassoInstance, SparsePcaInstance};

use super::engine::PjrtEngine;
use super::{RuntimeError, RuntimeResult};

/// Worker subproblem solver for LASSO blocks, executing the
/// `lasso_worker_m{M}_n{N}` artifact (L2 CG + L1 Pallas Gram kernel).
pub struct PjrtLassoSolver {
    engine: Arc<PjrtEngine>,
    exe_name: String,
    /// Per-worker `(A, b)` device buffers, uploaded once.
    blocks: Vec<(xla::PjRtBuffer, xla::PjRtBuffer)>,
    n: usize,
}

impl PjrtLassoSolver {
    pub fn new(engine: Arc<PjrtEngine>, inst: &LassoInstance) -> RuntimeResult<Self> {
        let m = inst.blocks[0].rows();
        let n = inst.dim();
        let exe_name = format!("lasso_worker_m{m}_n{n}");
        if !engine.has(&exe_name) {
            return Err(RuntimeError(format!(
                "artifact {exe_name} not built; re-run `make artifacts` with matching shapes"
            )));
        }
        let mut blocks = Vec::with_capacity(inst.blocks.len());
        for (a, b) in inst.blocks.iter().zip(&inst.rhs) {
            assert_eq!(a.rows(), m, "all blocks must share m (one artifact per shape)");
            let a_buf = engine.upload(a.data(), &[m, n])?;
            let b_buf = engine.upload(b, &[m])?;
            blocks.push((a_buf, b_buf));
        }
        Ok(PjrtLassoSolver { engine, exe_name, blocks, n })
    }

    /// A solver holding only one worker's block (index 0) — what each
    /// thread of the star cluster owns, avoiding N× data duplication.
    pub fn for_worker(
        engine: Arc<PjrtEngine>,
        a: &crate::linalg::DenseMatrix,
        b: &[f64],
    ) -> RuntimeResult<Self> {
        let (m, n) = (a.rows(), a.cols());
        let exe_name = format!("lasso_worker_m{m}_n{n}");
        if !engine.has(&exe_name) {
            return Err(RuntimeError(format!("artifact {exe_name} not built")));
        }
        let a_buf = engine.upload(a.data(), &[m, n])?;
        let b_buf = engine.upload(b, &[m])?;
        Ok(PjrtLassoSolver { engine, exe_name, blocks: vec![(a_buf, b_buf)], n })
    }

    /// Single solve against worker `i`'s resident block.
    pub fn solve_for(
        &self,
        i: usize,
        lam: &[f64],
        x0: &[f64],
        rho: f64,
    ) -> RuntimeResult<Vec<f64>> {
        let (a_buf, b_buf) = &self.blocks[i];
        let lam_buf = self.engine.upload(lam, &[self.n])?;
        let x0_buf = self.engine.upload(x0, &[self.n])?;
        let rho_buf = self.engine.upload_scalar(rho)?;
        self.engine
            .execute_f64(&self.exe_name, &[a_buf, b_buf, &lam_buf, &x0_buf, &rho_buf])
    }
}

// SAFETY: same argument as `PjrtEngine` — the PJRT CPU C API is
// thread-safe and device buffers are immutable after creation; the raw
// pointers inside `PjRtBuffer`/`PjRtClient` are what blocks the derive.
unsafe impl Send for PjrtLassoSolver {}

impl SubproblemSolver for PjrtLassoSolver {
    fn solve(&mut self, worker: usize, lam: &[f64], x0: &[f64], rho: f64, out: &mut [f64]) {
        let x = self
            .solve_for(worker, lam, x0, rho)
            // ad-lint: allow(panic-free-lib): SubproblemSolver::solve is infallible by signature; a PJRT failure is unrecoverable mid-run
            .expect("PJRT lasso worker solve failed");
        out.copy_from_slice(&x);
    }
}

/// Worker subproblem solver for sparse-PCA blocks (densified for the
/// artifact path), executing `spca_worker_m{M}_n{N}`.
pub struct PjrtSpcaSolver {
    engine: Arc<PjrtEngine>,
    exe_name: String,
    blocks: Vec<xla::PjRtBuffer>,
    n: usize,
}

impl PjrtSpcaSolver {
    pub fn new(engine: Arc<PjrtEngine>, inst: &SparsePcaInstance) -> RuntimeResult<Self> {
        let m = inst.blocks[0].rows();
        let n = inst.dim();
        let exe_name = format!("spca_worker_m{m}_n{n}");
        if !engine.has(&exe_name) {
            return Err(RuntimeError(format!("artifact {exe_name} not built")));
        }
        let mut blocks = Vec::with_capacity(inst.blocks.len());
        for b in &inst.blocks {
            let dense = b.to_dense();
            blocks.push(engine.upload(dense.data(), &[m, n])?);
        }
        Ok(PjrtSpcaSolver { engine, exe_name, blocks, n })
    }

    pub fn solve_for(
        &self,
        i: usize,
        lam: &[f64],
        x0: &[f64],
        rho: f64,
    ) -> RuntimeResult<Vec<f64>> {
        let b_buf = &self.blocks[i];
        let lam_buf = self.engine.upload(lam, &[self.n])?;
        let x0_buf = self.engine.upload(x0, &[self.n])?;
        let rho_buf = self.engine.upload_scalar(rho)?;
        self.engine
            .execute_f64(&self.exe_name, &[b_buf, &lam_buf, &x0_buf, &rho_buf])
    }
}

// SAFETY: see `PjrtLassoSolver`.
unsafe impl Send for PjrtSpcaSolver {}

impl SubproblemSolver for PjrtSpcaSolver {
    fn solve(&mut self, worker: usize, lam: &[f64], x0: &[f64], rho: f64, out: &mut [f64]) {
        let x = self
            .solve_for(worker, lam, x0, rho)
            // ad-lint: allow(panic-free-lib): SubproblemSolver::solve is infallible by signature; a PJRT failure is unrecoverable mid-run
            .expect("PJRT spca worker solve failed");
        out.copy_from_slice(&x);
    }
}

/// The master prox step as an artifact (`master_prox_n{N}`):
/// `x₀⁺ = S_{θ/(Nρ+γ)}((ρ·Σx + Σλ + γ·x₀ᵏ)/(Nρ+γ))` — used by the
/// hot-path bench and the kernel parity tests.
pub struct PjrtMasterProx {
    engine: Arc<PjrtEngine>,
    exe_name: String,
    n: usize,
}

impl PjrtMasterProx {
    pub fn new(engine: Arc<PjrtEngine>, n: usize) -> RuntimeResult<Self> {
        let exe_name = format!("master_prox_n{n}");
        if !engine.has(&exe_name) {
            return Err(RuntimeError(format!("artifact {exe_name} not built")));
        }
        Ok(PjrtMasterProx { engine, exe_name, n })
    }

    pub fn run(
        &self,
        sum_x: &[f64],
        sum_lam: &[f64],
        x0_prev: &[f64],
        rho: f64,
        gamma: f64,
        theta: f64,
        n_workers: usize,
    ) -> RuntimeResult<Vec<f64>> {
        let sx = self.engine.upload(sum_x, &[self.n])?;
        let sl = self.engine.upload(sum_lam, &[self.n])?;
        let xp = self.engine.upload(x0_prev, &[self.n])?;
        let r = self.engine.upload_scalar(rho)?;
        let g = self.engine.upload_scalar(gamma)?;
        let t = self.engine.upload_scalar(theta)?;
        let nw = self.engine.upload_scalar(n_workers as f64)?;
        self.engine
            .execute_f64(&self.exe_name, &[&sx, &sl, &xp, &r, &g, &t, &nw])
    }
}
