//! The PJRT engine: one CPU client, one compiled executable per artifact.
//! Compiled only with the `pjrt` feature (needs the vendored `xla` crate).

use std::collections::HashMap;
use std::path::Path;

use super::manifest::ArtifactRegistry;
use super::{RuntimeError, RuntimeResult};

/// Compiled artifacts ready to execute.
///
/// # Thread safety
///
/// `xla::PjRtClient` / `PjRtLoadedExecutable` / `PjRtBuffer` hold raw
/// pointers and therefore don't derive `Send`/`Sync`, but the PJRT CPU C
/// API is thread-safe (clients, executables and immutable buffers may be
/// used concurrently from multiple threads — this is how every PJRT-based
/// serving stack drives it). We assert that here so the threaded star
/// cluster can run PJRT-backed workers.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    registry: ArtifactRegistry,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

unsafe impl Send for PjrtEngine {}
unsafe impl Sync for PjrtEngine {}

fn ctx<E: std::fmt::Display>(what: &str, e: E) -> RuntimeError {
    RuntimeError(format!("{what}: {e}"))
}

impl PjrtEngine {
    /// Load + compile every artifact listed in `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> RuntimeResult<Self> {
        let registry = ArtifactRegistry::load(dir).map_err(RuntimeError)?;
        let client = xla::PjRtClient::cpu().map_err(|e| ctx("create PJRT CPU client", e))?;
        let mut exes = HashMap::new();
        for name in registry.names() {
            // ad-lint: allow(panic-free-lib): name is drawn from registry.names(); path_of is total over that set
            let path = registry.path_of(name).unwrap();
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| ctx(&format!("parse HLO text {}", path.display()), e))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| ctx(&format!("compile artifact {name}"), e))?;
            exes.insert(name.to_string(), exe);
        }
        Ok(PjrtEngine { client, registry, exes })
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    pub fn has(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    /// Upload an f64 buffer to the device (kept resident; reusable across
    /// executions — this is how worker data blocks avoid re-upload).
    pub fn upload(&self, data: &[f64], dims: &[usize]) -> RuntimeResult<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| ctx("upload host buffer", e))
    }

    /// Upload an f64 scalar.
    pub fn upload_scalar(&self, v: f64) -> RuntimeResult<xla::PjRtBuffer> {
        self.upload(&[v], &[])
    }

    /// Execute artifact `name` on device buffers; returns the first output
    /// (jax lowers with `return_tuple=True`, so outputs arrive as a 1-tuple
    /// which we unwrap) as a host `Vec<f64>`.
    pub fn execute_f64(&self, name: &str, args: &[&xla::PjRtBuffer]) -> RuntimeResult<Vec<f64>> {
        let exe = self.exes.get(name).ok_or_else(|| {
            RuntimeError(format!(
                "unknown artifact {name:?} (have: {:?})",
                self.registry.names()
            ))
        })?;
        let outs = exe
            .execute_b(args)
            .map_err(|e| ctx(&format!("execute {name}"), e))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| ctx("fetch output", e))?;
        let out = lit.to_tuple1().map_err(|e| ctx("unwrap 1-tuple output", e))?;
        out.to_vec::<f64>().map_err(|e| ctx("output to f64 vec", e))
    }
}
