//! The PJRT engine: one CPU client, one compiled executable per artifact.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::manifest::ArtifactRegistry;

/// Compiled artifacts ready to execute.
///
/// # Thread safety
///
/// `xla::PjRtClient` / `PjRtLoadedExecutable` / `PjRtBuffer` hold raw
/// pointers and therefore don't derive `Send`/`Sync`, but the PJRT CPU C
/// API is thread-safe (clients, executables and immutable buffers may be
/// used concurrently from multiple threads — this is how every PJRT-based
/// serving stack drives it). We assert that here so the threaded star
/// cluster can run PJRT-backed workers.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    registry: ArtifactRegistry,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

unsafe impl Send for PjrtEngine {}
unsafe impl Sync for PjrtEngine {}

impl PjrtEngine {
    /// Load + compile every artifact listed in `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let registry = ArtifactRegistry::load(dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut exes = HashMap::new();
        for name in registry.names() {
            let path = registry.path_of(name).unwrap();
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compile artifact {name}"))?;
            exes.insert(name.to_string(), exe);
        }
        Ok(PjrtEngine { client, registry, exes })
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    pub fn has(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    /// Upload an f64 buffer to the device (kept resident; reusable across
    /// executions — this is how worker data blocks avoid re-upload).
    pub fn upload(&self, data: &[f64], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("upload host buffer")
    }

    /// Upload an f64 scalar.
    pub fn upload_scalar(&self, v: f64) -> Result<xla::PjRtBuffer> {
        self.upload(&[v], &[])
    }

    /// Execute artifact `name` on device buffers; returns the first output
    /// (jax lowers with `return_tuple=True`, so outputs arrive as a 1-tuple
    /// which we unwrap) as a host `Vec<f64>`.
    pub fn execute_f64(&self, name: &str, args: &[&xla::PjRtBuffer]) -> Result<Vec<f64>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?} (have: {:?})", self.registry.names()))?;
        let outs = exe.execute_b(args).with_context(|| format!("execute {name}"))?;
        let lit = outs[0][0].to_literal_sync().context("fetch output")?;
        let out = lit.to_tuple1().context("unwrap 1-tuple output")?;
        out.to_vec::<f64>().context("output to f64 vec")
    }
}
