//! API-compatible stand-ins for the PJRT runtime, compiled when the `pjrt`
//! feature is off (the offline/CI default).
//!
//! Every constructor returns [`RuntimeError`], so callers that probe for
//! the backend (`PjrtEngine::load`, `PjrtLassoSolver::new`, …) fall back
//! to the native closed-form solvers without any `cfg` in their own code.
//! The execution methods exist only to satisfy the type checker; they are
//! unreachable because no value of these types can be observed outside
//! this module.

use std::path::Path;
use std::sync::Arc;

use crate::admm::master_pov::SubproblemSolver;
use crate::data::{LassoInstance, SparsePcaInstance};
use crate::linalg::DenseMatrix;

use super::{ArtifactRegistry, RuntimeError, RuntimeResult};

fn unavailable() -> RuntimeError {
    RuntimeError::from(
        "PJRT backend unavailable: built without the `pjrt` cargo feature \
         (requires the vendored `xla` binding crate)",
    )
}

/// Placeholder for a resident device buffer.
#[derive(Debug)]
pub struct PjrtBuffer;

/// Stub engine: `load` always fails, so no instance ever escapes.
pub struct PjrtEngine {
    registry: ArtifactRegistry,
}

impl PjrtEngine {
    pub fn load(_dir: &Path) -> RuntimeResult<Self> {
        Err(unavailable())
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    pub fn has(&self, _name: &str) -> bool {
        false
    }

    pub fn upload(&self, _data: &[f64], _dims: &[usize]) -> RuntimeResult<PjrtBuffer> {
        Err(unavailable())
    }

    pub fn upload_scalar(&self, _v: f64) -> RuntimeResult<PjrtBuffer> {
        Err(unavailable())
    }

    pub fn execute_f64(&self, _name: &str, _args: &[&PjrtBuffer]) -> RuntimeResult<Vec<f64>> {
        Err(unavailable())
    }
}

/// Stub LASSO worker solver.
pub struct PjrtLassoSolver;

impl PjrtLassoSolver {
    pub fn new(_engine: Arc<PjrtEngine>, _inst: &LassoInstance) -> RuntimeResult<Self> {
        Err(unavailable())
    }

    pub fn for_worker(
        _engine: Arc<PjrtEngine>,
        _a: &DenseMatrix,
        _b: &[f64],
    ) -> RuntimeResult<Self> {
        Err(unavailable())
    }

    pub fn solve_for(
        &self,
        _i: usize,
        _lam: &[f64],
        _x0: &[f64],
        _rho: f64,
    ) -> RuntimeResult<Vec<f64>> {
        Err(unavailable())
    }
}

impl SubproblemSolver for PjrtLassoSolver {
    fn solve(&mut self, _worker: usize, _lam: &[f64], _x0: &[f64], _rho: f64, _out: &mut [f64]) {
        unreachable!("stub PjrtLassoSolver cannot be constructed");
    }
}

/// Stub sparse-PCA worker solver.
pub struct PjrtSpcaSolver;

impl PjrtSpcaSolver {
    pub fn new(_engine: Arc<PjrtEngine>, _inst: &SparsePcaInstance) -> RuntimeResult<Self> {
        Err(unavailable())
    }

    pub fn solve_for(
        &self,
        _i: usize,
        _lam: &[f64],
        _x0: &[f64],
        _rho: f64,
    ) -> RuntimeResult<Vec<f64>> {
        Err(unavailable())
    }
}

impl SubproblemSolver for PjrtSpcaSolver {
    fn solve(&mut self, _worker: usize, _lam: &[f64], _x0: &[f64], _rho: f64, _out: &mut [f64]) {
        unreachable!("stub PjrtSpcaSolver cannot be constructed");
    }
}

/// Stub master prox executor.
pub struct PjrtMasterProx;

impl PjrtMasterProx {
    pub fn new(_engine: Arc<PjrtEngine>, _n: usize) -> RuntimeResult<Self> {
        Err(unavailable())
    }

    pub fn run(
        &self,
        _sum_x: &[f64],
        _sum_lam: &[f64],
        _x0_prev: &[f64],
        _rho: f64,
        _gamma: f64,
        _theta: f64,
        _n_workers: usize,
    ) -> RuntimeResult<Vec<f64>> {
        Err(unavailable())
    }
}
