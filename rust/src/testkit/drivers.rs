//! Non-deprecated one-shot runners for tests, benches and examples.
//!
//! The historical free-function drivers (`run_sync_admm`,
//! `run_master_pov`, `run_alt_scheme`, `run_trace_driven`) are deprecated
//! in favour of [`Session::builder`]; everything in-tree that is *not*
//! pinning those wrappers' exact behaviour migrates here. Each runner is a
//! thin Session assembly — one policy, the in-process trace-driven source,
//! a [`BufferingObserver`] — so results are bit-identical to the wrappers
//! they replace (both paths are the same `Session::step` loop).

use crate::admm::arrivals::{ArrivalModel, ArrivalTrace};
use crate::admm::engine::{AltScheme, FaultPlan, FullBarrier, PartialBarrier, UpdatePolicy};
use crate::admm::session::{BufferingObserver, Session};
use crate::admm::{AdmmConfig, AdmmState, IterRecord, StopReason};
use crate::problems::ConsensusProblem;

/// What one driver run returns — the union of the historical output
/// structs (`SyncOutput`, `MasterPovOutput`, `AltSchemeOutput`), so
/// migrated call sites keep reading the same fields.
pub struct DriverRun {
    pub state: AdmmState,
    pub history: Vec<IterRecord>,
    /// Realized arrival sets — replayable through any source.
    pub trace: ArrivalTrace,
    pub stop: StopReason,
    /// Final per-worker delay counters.
    pub final_delays: Vec<usize>,
}

impl DriverRun {
    pub fn diverged(&self) -> bool {
        self.stop == StopReason::Diverged
    }
}

/// Run any policy over the in-process trace-driven source to completion,
/// optionally under a deterministic [`FaultPlan`]. Panics on an invalid
/// configuration, like the legacy entry points tests relied on.
pub fn run_policy_with_faults<P: UpdatePolicy + 'static>(
    problem: &ConsensusProblem,
    cfg: &AdmmConfig,
    arrivals: &ArrivalModel,
    policy: P,
    residual_stopping: bool,
    faults: Option<FaultPlan>,
) -> DriverRun {
    let mut history = BufferingObserver::new();
    let mut builder = Session::builder()
        .problem(problem)
        .config(cfg.clone())
        .policy(policy)
        .arrivals(arrivals)
        .residual_stopping(residual_stopping)
        .observer(&mut history);
    if let Some(plan) = faults {
        builder = builder.faults(plan);
    }
    let mut session = builder.build().expect("valid driver configuration");
    let stop = session.run_to_completion().expect("driver run");
    // `_` drops the boxed source, releasing the `&mut history` borrow.
    let (outcome, _) = session.finish();
    DriverRun {
        state: outcome.state,
        history: history.into_records(),
        trace: outcome.trace,
        stop,
        final_delays: outcome.final_delays,
    }
}

/// [`run_policy_with_faults`] without a fault plan.
pub fn run_policy<P: UpdatePolicy + 'static>(
    problem: &ConsensusProblem,
    cfg: &AdmmConfig,
    arrivals: &ArrivalModel,
    policy: P,
    residual_stopping: bool,
) -> DriverRun {
    run_policy_with_faults(problem, cfg, arrivals, policy, residual_stopping, None)
}

/// Algorithm 1 (synchronous full barrier, master-first) — the
/// Session-based replacement for `run_sync_admm`.
pub fn run_full_barrier(problem: &ConsensusProblem, cfg: &AdmmConfig) -> DriverRun {
    run_policy(problem, cfg, &ArrivalModel::Full, FullBarrier, true)
}

/// Algorithms 2/3 (partially asynchronous, τ from the config) — the
/// Session-based replacement for `run_master_pov`.
pub fn run_partial_barrier(
    problem: &ConsensusProblem,
    cfg: &AdmmConfig,
    arrivals: &ArrivalModel,
) -> DriverRun {
    run_policy(problem, cfg, arrivals, PartialBarrier { tau: cfg.tau }, true)
}

/// Algorithm 4 (master-owned duals; residual stopping historically off) —
/// the Session-based replacement for `run_alt_scheme`.
pub fn run_alt(
    problem: &ConsensusProblem,
    cfg: &AdmmConfig,
    arrivals: &ArrivalModel,
) -> DriverRun {
    run_policy(problem, cfg, arrivals, AltScheme { tau: cfg.tau }, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::LassoInstance;
    use crate::rng::Pcg64;

    #[test]
    #[allow(deprecated)] // pins the drivers against the legacy wrappers
    fn drivers_bit_match_the_legacy_wrappers() {
        let mut rng = Pcg64::seed_from_u64(44);
        let p = LassoInstance::synthetic(&mut rng, 3, 15, 6, 0.2, 0.1).problem();
        let cfg = AdmmConfig { rho: 30.0, tau: 3, max_iters: 40, ..Default::default() };
        let arr = ArrivalModel::probabilistic(vec![0.4, 0.9, 0.6], 5);

        let new = run_partial_barrier(&p, &cfg, &arr);
        let old = crate::admm::master_pov::run_master_pov(&p, &cfg, &arr);
        assert_eq!(new.state.x0, old.state.x0);
        assert_eq!(new.trace, old.trace);
        assert_eq!(new.final_delays, old.final_delays);
        for (a, b) in new.history.iter().zip(&old.history) {
            assert_eq!(a.aug_lagrangian.to_bits(), b.aug_lagrangian.to_bits());
        }

        let sync_cfg = AdmmConfig { tau: 1, ..cfg.clone() };
        let new = run_full_barrier(&p, &sync_cfg);
        let old = crate::admm::sync::run_sync_admm(&p, &sync_cfg);
        assert_eq!(new.state.x0, old.state.x0);
        assert_eq!(new.stop, old.stop);

        let alt_cfg = AdmmConfig { rho: 5.0, ..cfg };
        let new = run_alt(&p, &alt_cfg, &arr);
        let old = crate::admm::alt_scheme::run_alt_scheme(&p, &alt_cfg, &arr);
        assert_eq!(new.state.x0, old.state.x0);
        assert!(!new.diverged());
    }
}
