//! Property-based testing mini-framework ("proptest-lite").
//!
//! The image has no `proptest`/`quickcheck`, so this module supplies the
//! pieces the repo's invariant tests need: seeded case generation, a runner
//! that reports the failing seed + case index, and a small combinator set.
//! No shrinking — failures print the full generated case instead, which for
//! our numeric cases is actionable enough.
//!
//! ```no_run
//! // (no_run: rustdoc test binaries miss the image's rpath to libstdc++)
//! use ad_admm::testkit::{Runner, Gen};
//! let mut r = Runner::new(0xad_a11, 64);
//! r.run("abs is nonnegative", |g| {
//!     let x = g.f64_range(-1e6, 1e6);
//!     assert!(x.abs() >= 0.0);
//! });
//! ```

use crate::rng::Pcg64;

pub mod drivers;

/// Per-case generator handle: draws primitives from the case's RNG stream.
pub struct Gen {
    rng: Pcg64,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    pub fn usize_range(&mut self, lo: usize, hi_incl: usize) -> usize {
        assert!(hi_incl >= lo);
        lo + self.rng.below((hi_incl - lo + 1) as u64) as usize
    }

    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_range(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    pub fn prob(&mut self) -> f64 {
        self.rng.uniform()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        let mut v = vec![0.0; n];
        self.rng.fill_normal(&mut v);
        v
    }

    /// Vector uniform in [lo, hi).
    pub fn vec_in(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.f64_range(lo, hi)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

/// Drives `cases` generated executions of each property.
pub struct Runner {
    seed: u64,
    cases: usize,
}

impl Runner {
    pub fn new(seed: u64, cases: usize) -> Self {
        Runner { seed, cases }
    }

    /// Run `prop` over `self.cases` generated cases. Panics (bubbling the
    /// property's own assert) with seed/case context on failure.
    pub fn run<F: FnMut(&mut Gen)>(&mut self, name: &str, mut prop: F) {
        for case in 0..self.cases {
            let case_seed = self
                .seed
                .wrapping_mul(0x9e37_79b9_7f4a_7c15)
                .wrapping_add(case as u64);
            let mut g = Gen { rng: Pcg64::seed_from_u64(case_seed) };
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
            if let Err(payload) = result {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".into());
                panic!(
                    "property {name:?} failed at case {case}/{} (case_seed={case_seed:#x}): {msg}",
                    self.cases
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut r = Runner::new(1, 32);
        let mut count = 0;
        r.run("counts", |_g| {
            count += 1;
        });
        assert_eq!(count, 32);
    }

    #[test]
    fn failing_property_reports_context() {
        let mut r = Runner::new(2, 16);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            r.run("always fails", |g| {
                let x = g.f64_range(0.0, 1.0);
                assert!(x < 0.0, "x={x} is not negative");
            });
        }));
        let err = res.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("always fails"));
        assert!(msg.contains("case_seed"));
    }

    #[test]
    fn generators_in_bounds() {
        let mut r = Runner::new(3, 64);
        r.run("bounds", |g| {
            let u = g.usize_range(3, 9);
            assert!((3..=9).contains(&u));
            let f = g.f64_range(-2.0, 2.0);
            assert!((-2.0..2.0).contains(&f));
            let p = g.prob();
            assert!((0.0..1.0).contains(&p));
            let v = g.vec_in(5, 1.0, 2.0);
            assert_eq!(v.len(), 5);
            assert!(v.iter().all(|x| (1.0..2.0).contains(x)));
            let c = *g.choose(&[1, 2, 3]);
            assert!([1, 2, 3].contains(&c));
        });
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let collect = |seed| {
            let mut r = Runner::new(seed, 8);
            let mut vals = Vec::new();
            r.run("collect", |g| vals.push(g.f64_range(0.0, 1.0)));
            vals
        };
        assert_eq!(collect(7), collect(7));
        assert_ne!(collect(7), collect(8));
    }
}
