//! Convergence-rate estimation — the Part-II preview.
//!
//! The companion paper (Part II) analyzes *linear* convergence of the
//! AD-ADMM under error-bound conditions. This module fits the observed
//! accuracy sequence to `acc(k) ≈ C·rᵏ` (log-linear least squares) and
//! classifies the empirical regime, so the benches can report "linear with
//! rate r" next to each curve.

/// Result of fitting `log acc(k) = log C + k·log r` on the tail.
#[derive(Clone, Debug)]
pub struct RateFit {
    /// Per-iteration contraction factor `r` (1.0 ⇒ no progress).
    pub rate: f64,
    /// `C` in `acc(k) ≈ C·rᵏ`.
    pub constant: f64,
    /// R² of the log-linear fit (≥ ~0.95 ⇒ convincingly linear).
    pub r_squared: f64,
    /// Points used.
    pub points: usize,
}

impl RateFit {
    /// Convincing linear convergence?
    pub fn is_linear(&self) -> bool {
        self.points >= 8 && self.rate < 0.9999 && self.r_squared > 0.9
    }

    /// Iterations needed to gain one decimal digit at this rate.
    pub fn iters_per_digit(&self) -> f64 {
        if self.rate <= 0.0 || self.rate >= 1.0 {
            return f64::INFINITY;
        }
        -1.0 / self.rate.log10()
    }
}

/// Fit the last `tail_frac` of the positive, finite accuracy values.
/// Returns `None` when fewer than 4 usable points exist.
pub fn fit_linear_rate(acc: &[f64], tail_frac: f64) -> Option<RateFit> {
    assert!((0.0..=1.0).contains(&tail_frac));
    let start = ((acc.len() as f64) * (1.0 - tail_frac)) as usize;
    // Stop at machine-precision floor: below ~1e-15 the series is noise.
    let pts: Vec<(f64, f64)> = acc
        .iter()
        .enumerate()
        .skip(start)
        .filter(|(_, &a)| a.is_finite() && a > 1e-15)
        .map(|(k, &a)| (k as f64, a.ln()))
        .collect();
    if pts.len() < 4 {
        return None;
    }
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return None;
    }
    let slope = (n * sxy - sx * sy) / denom;
    let intercept = (sy - slope * sx) / n;
    // R²
    let mean_y = sy / n;
    let ss_tot: f64 = pts.iter().map(|p| (p.1 - mean_y).powi(2)).sum();
    let ss_res: f64 = pts
        .iter()
        .map(|p| (p.1 - (intercept + slope * p.0)).powi(2))
        .sum();
    let r_squared = if ss_tot > 1e-12 { 1.0 - ss_res / ss_tot } else { 1.0 };
    Some(RateFit {
        rate: slope.exp(),
        constant: intercept.exp(),
        r_squared,
        points: pts.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_exact_geometric_decay() {
        let r: f64 = 0.93;
        let acc: Vec<f64> = (0..200).map(|k| 5.0 * r.powi(k)).collect();
        let fit = fit_linear_rate(&acc, 0.8).unwrap();
        assert!((fit.rate - r).abs() < 1e-6, "rate={}", fit.rate);
        assert!((fit.constant - 5.0).abs() < 1e-3);
        assert!(fit.r_squared > 0.999999);
        assert!(fit.is_linear());
    }

    #[test]
    fn sublinear_decay_is_not_classified_linear() {
        // 1/k decay: log acc vs k is strongly curved → low R² on a long tail
        let acc: Vec<f64> = (1..400).map(|k| 1.0 / k as f64).collect();
        let fit = fit_linear_rate(&acc, 1.0).unwrap();
        assert!(fit.r_squared < 0.95, "r2={}", fit.r_squared);
    }

    #[test]
    fn too_few_points_is_none() {
        assert!(fit_linear_rate(&[1.0, 0.5], 1.0).is_none());
        let diverged = vec![f64::INFINITY; 50];
        assert!(fit_linear_rate(&diverged, 1.0).is_none());
    }

    #[test]
    fn iters_per_digit() {
        let fit = RateFit { rate: 0.1, constant: 1.0, r_squared: 1.0, points: 10 };
        assert!((fit.iters_per_digit() - 1.0).abs() < 1e-12);
        let stalled = RateFit { rate: 1.0, constant: 1.0, r_squared: 1.0, points: 10 };
        assert!(stalled.iters_per_digit().is_infinite());
    }

    #[test]
    fn admm_on_lasso_shows_linear_rate() {
        // End-to-end: the paper's observation that AD-ADMM "may exhibit
        // linear convergence for some structured instances".
        use crate::testkit::drivers::run_full_barrier;
        use crate::admm::AdmmConfig;
        use crate::data::LassoInstance;
        use crate::metrics::accuracy_series;
        use crate::rng::Pcg64;
        use crate::solvers::fista::fista_lasso;

        let mut rng = Pcg64::seed_from_u64(500);
        let inst = LassoInstance::synthetic(&mut rng, 4, 30, 10, 0.2, 0.1);
        let (_, f_star) = fista_lasso(&inst, 40_000);
        let p = inst.problem();
        let cfg = AdmmConfig { rho: 50.0, max_iters: 80, ..Default::default() };
        let out = run_full_barrier(&p, &cfg);
        let acc = accuracy_series(&out.history, f_star);
        // fit the whole run; the floor filter drops machine-precision tail
        let fit = fit_linear_rate(&acc, 1.0).expect("fit");
        assert!(fit.is_linear(), "{fit:?}");
        assert!(fit.rate < 0.99);
    }
}
