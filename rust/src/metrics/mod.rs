//! Run-level metrics: the paper's accuracy definitions, convergence-rate
//! estimation and CSV logging.

pub mod rate;

use std::path::Path;

use crate::admm::IterRecord;
use crate::util::csv::CsvWriter;

/// The paper's accuracy metric ((51)/(53)):
/// `accuracy(k) = |L_ρ(xᵏ, x₀ᵏ, λᵏ) − F_ref| / |F_ref|`,
/// where `F_ref` is `F̂` (long synchronous run, Fig. 3) or `F*` (optimal
/// objective, Fig. 4).
pub fn accuracy_series(history: &[IterRecord], f_ref: f64) -> Vec<f64> {
    let denom = f_ref.abs().max(f64::MIN_POSITIVE);
    history
        .iter()
        .map(|r| {
            if r.aug_lagrangian.is_finite() {
                (r.aug_lagrangian - f_ref).abs() / denom
            } else {
                f64::INFINITY
            }
        })
        .collect()
}

/// A named convergence curve (one line of a paper figure).
pub struct RunLog {
    pub label: String,
    pub history: Vec<IterRecord>,
}

impl RunLog {
    pub fn new(label: impl Into<String>, history: Vec<IterRecord>) -> Self {
        RunLog { label: label.into(), history }
    }

    /// First iteration index reaching the target accuracy (None = never) —
    /// the "iterations to ε" summary used in bench output tables.
    pub fn iters_to_accuracy(&self, f_ref: f64, eps: f64) -> Option<usize> {
        accuracy_series(&self.history, f_ref)
            .iter()
            .position(|&a| a <= eps)
    }

    /// Final accuracy value.
    pub fn final_accuracy(&self, f_ref: f64) -> f64 {
        accuracy_series(&self.history, f_ref).last().copied().unwrap_or(f64::INFINITY)
    }
}

/// Write several curves as one long-format CSV:
/// `label,k,accuracy,objective,aug_lagrangian,consensus`.
pub fn write_curves(path: &Path, curves: &[RunLog], f_ref: f64) -> std::io::Result<()> {
    let mut w = CsvWriter::create(
        path,
        &["label", "k", "accuracy", "objective", "aug_lagrangian", "consensus"],
    )?;
    for c in curves {
        let acc = accuracy_series(&c.history, f_ref);
        for (r, a) in c.history.iter().zip(acc) {
            w.row_str(&[
                c.label.clone(),
                r.k.to_string(),
                fmt(a),
                fmt(r.objective),
                fmt(r.aug_lagrangian),
                fmt(r.consensus),
            ])?;
        }
    }
    w.flush()
}

fn fmt(v: f64) -> String {
    if v.is_nan() {
        "nan".into()
    } else if v.is_infinite() {
        "inf".into()
    } else {
        format!("{v:.8e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(k: usize, aug: f64) -> IterRecord {
        IterRecord {
            k,
            objective: aug,
            aug_lagrangian: aug,
            consensus: 0.0,
            x0_change: 0.0,
            arrivals: 1,
        }
    }

    #[test]
    fn accuracy_matches_definition() {
        let h = vec![rec(0, 20.0), rec(1, 11.0), rec(2, 10.0)];
        let acc = accuracy_series(&h, 10.0);
        assert!((acc[0] - 1.0).abs() < 1e-12);
        assert!((acc[1] - 0.1).abs() < 1e-12);
        assert!(acc[2] < 1e-12);
    }

    #[test]
    fn infinite_aug_maps_to_infinite_accuracy() {
        let h = vec![rec(0, f64::INFINITY)];
        assert!(accuracy_series(&h, 5.0)[0].is_infinite());
    }

    #[test]
    fn iters_to_accuracy() {
        let log = RunLog::new("x", vec![rec(0, 20.0), rec(1, 10.5), rec(2, 10.01)]);
        assert_eq!(log.iters_to_accuracy(10.0, 0.1), Some(1));
        assert_eq!(log.iters_to_accuracy(10.0, 1e-4), None);
        assert!((log.final_accuracy(10.0) - 0.001).abs() < 1e-9);
    }

    #[test]
    fn csv_round_trip() {
        let dir = std::env::temp_dir().join("ad_admm_metrics_test");
        let path = dir.join("curves.csv");
        let logs = vec![RunLog::new("tau=1", vec![rec(0, 12.0), rec(1, 10.0)])];
        write_curves(&path, &logs, 10.0).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().count() == 3);
        assert!(text.contains("tau=1,0,"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
