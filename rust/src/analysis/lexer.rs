//! A token-level Rust lexer for the `ad-lint` static-analysis pass.
//!
//! This is deliberately **not** a parser: the rules in [`crate::analysis::rules`]
//! operate on a flat token stream plus a little bracket/attribute context, which
//! is enough to enforce the repo's determinism and panic-freedom conventions
//! without pulling in `syn` (the crate is dependency-free by policy).
//!
//! The lexer understands the lexical structure that matters for *not lying*
//! about code: line comments, nested block comments, string / raw-string /
//! byte-string / char literals, lifetimes, numeric literals (with float
//! classification), and multi-character operators (`==`, `!=`, `::`, …).
//! Comment and string contents are preserved verbatim in the token text so the
//! suppression scanner can read `// ad-lint: allow(...)` comments, but rules
//! that look for identifiers never match inside them.

/// Lexical class of a [`Token`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenKind {
    /// Identifier or keyword (including raw identifiers like `r#match`).
    Ident,
    /// A lifetime such as `'a` (including `'static`).
    Lifetime,
    /// Integer literal (`42`, `0xFF_u8`, `1_000`).
    Int,
    /// Float literal (`1.0`, `1.`, `1e-3`, `2f64`).
    Float,
    /// String-like literal: `"…"`, `r"…"`, `r#"…"#`, `b"…"`, `br#"…"#`.
    Str,
    /// Character or byte literal: `'x'`, `'\n'`, `b'x'`.
    Char,
    /// Punctuation / operator, possibly multi-character (`==`, `->`, `::`).
    Punct,
    /// `// …` comment (text includes the slashes, excludes the newline).
    LineComment,
    /// `/* … */` comment, nesting handled (text includes the delimiters).
    BlockComment,
}

/// One lexed token with its 1-based source position.
#[derive(Debug, Clone, Copy)]
pub struct Token<'a> {
    pub kind: TokenKind,
    /// Verbatim source slice, including delimiters for strings and comments.
    pub text: &'a str,
    /// 1-based line of the token's first character.
    pub line: u32,
    /// 1-based column (in characters) of the token's first character.
    pub col: u32,
}

impl<'a> Token<'a> {
    /// True for comment tokens, which most rules skip.
    pub fn is_comment(&self) -> bool {
        matches!(self.kind, TokenKind::LineComment | TokenKind::BlockComment)
    }
}

/// A lexing failure (unterminated literal or comment). The analyzer surfaces
/// this as a `parse` diagnostic rather than aborting the whole run.
#[derive(Debug, Clone)]
pub struct LexError {
    pub line: u32,
    pub col: u32,
    pub message: String,
}

struct Cursor<'a> {
    src: &'a str,
    /// Byte offset into `src`.
    pos: usize,
    line: u32,
    col: u32,
}

impl<'a> Cursor<'a> {
    fn new(src: &'a str) -> Self {
        Cursor { src, pos: 0, line: 1, col: 1 }
    }

    fn peek(&self) -> Option<char> {
        self.src[self.pos..].chars().next()
    }

    fn peek2(&self) -> Option<char> {
        let mut it = self.src[self.pos..].chars();
        it.next();
        it.next()
    }

    fn peek_at(&self, n: usize) -> Option<char> {
        self.src[self.pos..].chars().nth(n)
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek()?;
        self.pos += c.len_utf8();
        if c == '\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }

    fn starts_with(&self, s: &str) -> bool {
        self.src[self.pos..].starts_with(s)
    }

    fn err(&self, message: &str) -> LexError {
        LexError { line: self.line, col: self.col, message: message.to_string() }
    }
}

fn is_ident_start(c: char) -> bool {
    c == '_' || c.is_alphabetic()
}

fn is_ident_continue(c: char) -> bool {
    c == '_' || c.is_alphanumeric()
}

/// Lex `src` into a flat token stream. Whitespace is dropped; comments are
/// kept as tokens so the suppression scanner can see them.
pub fn lex(src: &str) -> Result<Vec<Token<'_>>, LexError> {
    let mut cur = Cursor::new(src);
    let mut out = Vec::new();
    while let Some(c) = cur.peek() {
        if c.is_whitespace() {
            cur.bump();
            continue;
        }
        let (start, line, col) = (cur.pos, cur.line, cur.col);
        let kind = if cur.starts_with("//") {
            lex_line_comment(&mut cur)
        } else if cur.starts_with("/*") {
            lex_block_comment(&mut cur)?
        } else if c == '"' {
            lex_string(&mut cur)?
        } else if c == '\'' {
            lex_quote(&mut cur)?
        } else if (c == 'r' || c == 'b') && starts_raw_or_byte_literal(&cur) {
            lex_prefixed_literal(&mut cur)?
        } else if is_ident_start(c) {
            lex_ident(&mut cur)
        } else if c.is_ascii_digit() {
            lex_number(&mut cur)
        } else {
            lex_punct(&mut cur)
        };
        out.push(Token { kind, text: &src[start..cur.pos], line, col });
    }
    Ok(out)
}

fn lex_line_comment(cur: &mut Cursor) -> TokenKind {
    while let Some(c) = cur.peek() {
        if c == '\n' {
            break;
        }
        cur.bump();
    }
    TokenKind::LineComment
}

fn lex_block_comment(cur: &mut Cursor) -> Result<TokenKind, LexError> {
    let open = cur.err("unterminated block comment");
    cur.bump(); // '/'
    cur.bump(); // '*'
    let mut depth = 1usize;
    while depth > 0 {
        if cur.starts_with("/*") {
            cur.bump();
            cur.bump();
            depth += 1;
        } else if cur.starts_with("*/") {
            cur.bump();
            cur.bump();
            depth -= 1;
        } else if cur.bump().is_none() {
            return Err(open);
        }
    }
    Ok(TokenKind::BlockComment)
}

/// Consume a `"…"` string body (cursor on the opening quote).
fn lex_string(cur: &mut Cursor) -> Result<TokenKind, LexError> {
    let open = cur.err("unterminated string literal");
    cur.bump(); // opening '"'
    loop {
        match cur.bump() {
            None => return Err(open),
            Some('\\') => {
                // Escape: consume the next char blindly (covers \" and \\).
                cur.bump();
            }
            Some('"') => return Ok(TokenKind::Str),
            Some(_) => {}
        }
    }
}

/// Does the cursor sit on `r"`, `r#"`, `r#ident`, `b"`, `b'`, `br"`, `br#"`?
/// (Plain idents like `radius` or `bytes` must fall through to `lex_ident`.)
fn starts_raw_or_byte_literal(cur: &Cursor) -> bool {
    let rest = &cur.src[cur.pos..];
    for prefix in ["r\"", "r#", "b\"", "b'", "br\"", "br#"] {
        if rest.starts_with(prefix) {
            return true;
        }
    }
    false
}

/// Lex a literal starting with `r`/`b`/`br`: raw strings, byte strings, byte
/// chars, and raw identifiers (`r#match`).
fn lex_prefixed_literal(cur: &mut Cursor) -> Result<TokenKind, LexError> {
    if cur.peek() == Some('b') {
        cur.bump();
        match cur.peek() {
            Some('\'') => return lex_quote_char_only(cur),
            Some('"') => return lex_string(cur),
            Some('r') => {
                cur.bump();
                return lex_raw_string(cur);
            }
            _ => return Ok(lex_ident_rest(cur)),
        }
    }
    // 'r' prefix: raw string or raw identifier.
    cur.bump(); // 'r'
    match cur.peek() {
        Some('"') | Some('#') => {
            // `r#ident` (raw identifier) vs `r#"…"#` (raw string): look past
            // the hashes for a quote.
            let mut n = 0usize;
            while cur.peek_at(n) == Some('#') {
                n += 1;
            }
            if cur.peek_at(n) == Some('"') {
                lex_raw_string(cur)
            } else {
                // Raw identifier: consume '#' then the ident body.
                cur.bump();
                Ok(lex_ident_rest(cur))
            }
        }
        _ => Ok(lex_ident_rest(cur)),
    }
}

/// Consume `#…#"…"#…#` with the cursor on the first `#` or the quote.
fn lex_raw_string(cur: &mut Cursor) -> Result<TokenKind, LexError> {
    let open = cur.err("unterminated raw string literal");
    let mut hashes = 0usize;
    while cur.peek() == Some('#') {
        cur.bump();
        hashes += 1;
    }
    if cur.peek() != Some('"') {
        return Err(open);
    }
    cur.bump(); // opening quote
    let closer: String = std::iter::once('"').chain(std::iter::repeat('#').take(hashes)).collect();
    loop {
        if cur.starts_with(&closer) {
            for _ in 0..closer.len() {
                cur.bump();
            }
            return Ok(TokenKind::Str);
        }
        if cur.bump().is_none() {
            return Err(open);
        }
    }
}

/// Disambiguate `'a` (lifetime) from `'x'` (char literal); cursor on `'`.
fn lex_quote(cur: &mut Cursor) -> Result<TokenKind, LexError> {
    // Escaped char (`'\n'`) is always a char literal.
    if cur.peek2() == Some('\\') {
        return lex_quote_char_only(cur);
    }
    // `'ident` followed by another `'` is a char ('a'); otherwise a lifetime.
    if cur.peek2().map(is_ident_start).unwrap_or(false) {
        let mut n = 2usize;
        while cur.peek_at(n).map(is_ident_continue).unwrap_or(false) {
            n += 1;
        }
        if cur.peek_at(n) == Some('\'') {
            return lex_quote_char_only(cur);
        }
        // Lifetime: consume the quote + ident run.
        for _ in 0..n {
            cur.bump();
        }
        return Ok(TokenKind::Lifetime);
    }
    // Anything else (`'('`, `'"'`, `' '`) is a char literal.
    lex_quote_char_only(cur)
}

/// Consume a char/byte literal unconditionally; cursor on the opening `'`.
fn lex_quote_char_only(cur: &mut Cursor) -> Result<TokenKind, LexError> {
    let open = cur.err("unterminated char literal");
    cur.bump(); // opening '\''
    loop {
        match cur.bump() {
            None => return Err(open),
            Some('\\') => {
                cur.bump();
            }
            Some('\'') => return Ok(TokenKind::Char),
            Some(_) => {}
        }
    }
}

fn lex_ident(cur: &mut Cursor) -> TokenKind {
    lex_ident_rest(cur)
}

fn lex_ident_rest(cur: &mut Cursor) -> TokenKind {
    while cur.peek().map(is_ident_continue).unwrap_or(false) {
        cur.bump();
    }
    TokenKind::Ident
}

fn lex_number(cur: &mut Cursor) -> TokenKind {
    // Hex / octal / binary: integers only.
    if cur.peek() == Some('0') && matches!(cur.peek2(), Some('x') | Some('o') | Some('b')) {
        cur.bump();
        cur.bump();
        while cur.peek().map(|c| c.is_ascii_hexdigit() || c == '_').unwrap_or(false) {
            cur.bump();
        }
        consume_suffix(cur);
        return TokenKind::Int;
    }
    let mut is_float = false;
    digits(cur);
    // Fractional part: `1.5`, `1.` — but not `1..2` (range) or `1.max()`.
    if cur.peek() == Some('.') {
        match cur.peek2() {
            Some(c) if c.is_ascii_digit() => {
                cur.bump();
                digits(cur);
                is_float = true;
            }
            Some('.') => {}                              // range `1..`
            Some(c) if is_ident_start(c) => {}           // method call `1.max(2)`
            _ => {
                // Trailing-dot float: `1.` then `)`/`,`/whitespace/EOF.
                cur.bump();
                is_float = true;
            }
        }
    }
    // Exponent: `1e9`, `1.5e-3`.
    if matches!(cur.peek(), Some('e') | Some('E')) {
        let sign = matches!(cur.peek2(), Some('+') | Some('-'));
        let digit_at = if sign { 2 } else { 1 };
        if cur.peek_at(digit_at).map(|c| c.is_ascii_digit()).unwrap_or(false) {
            cur.bump(); // e
            if sign {
                cur.bump();
            }
            digits(cur);
            is_float = true;
        }
    }
    // Type suffix (`f64`, `u32`, `usize`): a float suffix makes it a float.
    let suffix = consume_suffix(cur);
    if suffix == "f32" || suffix == "f64" {
        is_float = true;
    }
    if is_float {
        TokenKind::Float
    } else {
        TokenKind::Int
    }
}

fn digits(cur: &mut Cursor) {
    while cur.peek().map(|c| c.is_ascii_digit() || c == '_').unwrap_or(false) {
        cur.bump();
    }
}

fn consume_suffix<'a>(cur: &mut Cursor<'a>) -> &'a str {
    let start = cur.pos;
    while cur.peek().map(is_ident_continue).unwrap_or(false) {
        cur.bump();
    }
    &cur.src[start..cur.pos]
}

/// Multi-character operators, longest first. Only the ones that change how a
/// rule reads the stream matter (`==` vs `=` `=`); the rest ride along so the
/// token text stays faithful to the source.
const PUNCT3: [&str; 4] = ["..=", "<<=", ">>=", "..."];
const PUNCT2: [&str; 19] = [
    "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "<<", ">>", "+=", "-=", "*=", "/=",
    "%=", "^=", "&=", "|=",
];

fn lex_punct(cur: &mut Cursor) -> TokenKind {
    for p in PUNCT3 {
        if cur.starts_with(p) {
            for _ in 0..p.len() {
                cur.bump();
            }
            return TokenKind::Punct;
        }
    }
    for p in PUNCT2 {
        if cur.starts_with(p) {
            cur.bump();
            cur.bump();
            return TokenKind::Punct;
        }
    }
    cur.bump();
    TokenKind::Punct
}
