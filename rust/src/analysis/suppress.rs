//! Inline suppression comments: `// ad-lint: allow(rule-id): <reason>`.
//!
//! An allow-comment covers diagnostics of the named rule on **its own line and
//! the next line**, so both trailing (`stmt // ad-lint: allow(...)`) and
//! preceding-line placements work. A missing or empty reason is itself a
//! diagnostic (`suppression` rule), as is naming a rule id the registry does
//! not know — suppressions must stay auditable.

use super::diag::Diagnostic;
use super::lexer::{Token, TokenKind};

/// One parsed allow-comment.
#[derive(Debug, Clone)]
pub struct Allow {
    pub line: u32,
    pub col: u32,
    /// The rule id named inside `allow(...)` (may be unknown; checked later).
    pub rule: String,
    /// Justification text after the trailing `: `; empty string if missing.
    pub reason: String,
}

/// Scan comment tokens for `ad-lint:` directives. Malformed directives
/// (anything after `ad-lint:` that is not `allow(id): reason`) are reported
/// immediately as `suppression` diagnostics.
pub fn scan_allows(file: &str, tokens: &[Token<'_>], diags: &mut Vec<Diagnostic>) -> Vec<Allow> {
    let mut allows = Vec::new();
    for tok in tokens {
        if !tok.is_comment() {
            continue;
        }
        let body = comment_body(tok);
        let Some(rest) = strip_directive_prefix(body) else { continue };
        match parse_allow(rest) {
            Ok((rule, reason)) => {
                if reason.is_empty() {
                    diags.push(Diagnostic::error(
                        file,
                        tok.line,
                        tok.col,
                        "suppression",
                        format!(
                            "ad-lint: allow({rule}) has no reason; write \
                             `// ad-lint: allow({rule}): <why this is safe>`"
                        ),
                    ));
                }
                allows.push(Allow {
                    line: tok.line,
                    col: tok.col,
                    rule: rule.to_string(),
                    reason: reason.to_string(),
                });
            }
            Err(msg) => diags.push(Diagnostic::error(
                file,
                tok.line,
                tok.col,
                "suppression",
                msg,
            )),
        }
    }
    allows
}

/// Apply `allows` to `diags` in place: a diagnostic whose rule matches an
/// allow on the same or preceding line is marked suppressed. Returns, for each
/// allow, whether it matched anything (unused allows are stale and reported by
/// the caller).
pub fn apply_allows(allows: &[Allow], diags: &mut [Diagnostic]) -> Vec<bool> {
    let mut used = vec![false; allows.len()];
    for d in diags.iter_mut() {
        if d.rule == "suppression" {
            continue; // allow-comments cannot excuse their own malformation
        }
        for (i, a) in allows.iter().enumerate() {
            if a.rule == d.rule && !a.reason.is_empty() && covers(a.line, d.line) {
                d.suppressed = true;
                d.reason = Some(a.reason.clone());
                used[i] = true;
                break;
            }
        }
    }
    used
}

/// An allow on line L covers findings on L (trailing comment) and L+1
/// (comment on the line above the flagged statement).
fn covers(allow_line: u32, diag_line: u32) -> bool {
    diag_line == allow_line || diag_line == allow_line + 1
}

/// Strip comment delimiters: `// x`, `/// x`, `//! x`, `/* x */`.
fn comment_body<'a>(tok: &Token<'a>) -> &'a str {
    let t = tok.text;
    if tok.kind == TokenKind::LineComment {
        t.trim_start_matches('/').trim_start_matches('!').trim()
    } else {
        t.trim_start_matches("/*")
            .trim_end_matches("*/")
            .trim_start_matches(['*', '!'])
            .trim()
    }
}

/// Return the text after a leading `ad-lint:` marker, or None if this comment
/// is not a directive at all.
fn strip_directive_prefix(body: &str) -> Option<&str> {
    body.strip_prefix("ad-lint:").map(str::trim)
}

/// Parse `allow(rule-id): reason` → `(rule-id, reason)`.
fn parse_allow(rest: &str) -> Result<(&str, &str), String> {
    let Some(inner) = rest.strip_prefix("allow(") else {
        return Err(format!(
            "unrecognized ad-lint directive `{rest}`; only \
             `allow(rule-id): <reason>` is supported"
        ));
    };
    let Some(close) = inner.find(')') else {
        return Err("ad-lint: allow(... is missing its closing `)`".to_string());
    };
    let rule = inner[..close].trim();
    if rule.is_empty() || !rule.chars().all(|c| c.is_ascii_alphanumeric() || c == '-') {
        return Err(format!("ad-lint: allow(...) names an invalid rule id `{rule}`"));
    }
    let after = inner[close + 1..].trim();
    let reason = after.strip_prefix(':').map(str::trim).unwrap_or("");
    Ok((rule, reason))
}
