//! Typed diagnostics for the `ad-lint` pass.

use std::fmt;

/// How bad a finding is. Every shipped rule currently emits [`Severity::Error`];
/// `Warning` exists so future advisory rules don't need a schema change.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warning,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
        }
    }
}

/// One finding, anchored to a `file:line:col` position.
///
/// A diagnostic starts unsuppressed; the suppression scanner flips
/// [`Diagnostic::suppressed`] (and records the justification) when a
/// `// ad-lint: allow(rule-id): <reason>` comment covers the position.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Repo-relative path with forward slashes (e.g. `rust/src/admm/engine.rs`).
    pub file: String,
    /// 1-based line.
    pub line: u32,
    /// 1-based column (characters).
    pub col: u32,
    /// Stable rule id (e.g. `wallclock`); `parse` and `suppression` are
    /// reserved ids for lexer failures and malformed allow-comments.
    pub rule: &'static str,
    pub severity: Severity,
    pub message: String,
    /// True once an allow-comment with a reason covers this finding.
    pub suppressed: bool,
    /// The reason text from the covering allow-comment, if suppressed.
    pub reason: Option<String>,
}

impl Diagnostic {
    pub fn error(file: &str, line: u32, col: u32, rule: &'static str, message: String) -> Self {
        Diagnostic {
            file: file.to_string(),
            line,
            col,
            rule,
            severity: Severity::Error,
            message,
            suppressed: false,
            reason: None,
        }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}:{}: {} [{}] {}",
            self.file,
            self.line,
            self.col,
            self.severity.as_str(),
            self.rule,
            self.message
        )
    }
}
