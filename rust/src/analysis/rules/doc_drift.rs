//! `doc-drift`: structural cross-file checks keeping the docs honest.
//!
//! Two claims in the docs are load-bearing enough to verify mechanically:
//!
//! 1. **Wire table** — the message-type tables in `README.md` and in the
//!    `transport/wire.rs` module doc must list exactly the tags the decoder's
//!    match arms accept (the decode `match` is ground truth; every `"tag" =>`
//!    arm must appear in both tables and vice versa).
//! 2. **Checkpoint version** — every backticked `` `version: N` `` claim in
//!    the README must equal `Checkpoint::VERSION` in `admm/session.rs`.
//!    Prose about *older* formats writes "format v2" / "v1–v3" instead, so a
//!    backticked `version: N` always describes what the current writer emits.
//!
//! Unlike the token rules this one is cross-file, so it implements
//! [`Rule::check_tree`] and anchors diagnostics in whichever file is stale.

use super::Rule;
use crate::analysis::diag::Diagnostic;
use crate::analysis::lexer::{lex, TokenKind};
use crate::analysis::SourceFile;

pub struct DocDrift;

const README: &str = "README.md";
const WIRE: &str = "rust/src/cluster/transport/wire.rs";
const SESSION: &str = "rust/src/admm/session.rs";

impl Rule for DocDrift {
    fn id(&self) -> &'static str {
        "doc-drift"
    }

    fn summary(&self) -> &'static str {
        "README/wire-doc tables match the decoder's tags; README checkpoint \
         version claims match Checkpoint::VERSION"
    }

    fn check_tree(&self, files: &[SourceFile], out: &mut Vec<Diagnostic>) {
        let Some(readme) = find(files, README) else {
            // Scanning a partial set (unit tests feed synthetic trees); the
            // rule only judges what it can see.
            return;
        };
        if let Some(wire) = find(files, WIRE) {
            self.check_wire_tables(readme, wire, out);
        }
        if let Some(session) = find(files, SESSION) {
            self.check_checkpoint_version(readme, session, out);
        }
    }
}

impl DocDrift {
    fn check_wire_tables(&self, readme: &SourceFile, wire: &SourceFile, out: &mut Vec<Diagnostic>) {
        let tags = match decode_tags(&wire.text) {
            Ok(tags) => tags,
            Err(msg) => {
                out.push(Diagnostic::error(&wire.path, 1, 1, self.id(), msg));
                return;
            }
        };
        for (doc, table) in [
            (readme, wire_table(&readme.text, "")),
            (wire, wire_table(&wire.text, "//!")),
        ] {
            let Some((header_line, rows)) = table else {
                out.push(Diagnostic::error(
                    &doc.path,
                    1,
                    1,
                    self.id(),
                    "no wire-message table (header `| type | direction | ... |`) found"
                        .to_string(),
                ));
                continue;
            };
            for tag in &tags {
                if !rows.iter().any(|(_, t)| t == tag) {
                    out.push(Diagnostic::error(
                        &doc.path,
                        header_line,
                        1,
                        self.id(),
                        format!(
                            "wire table is missing the `{tag}` message that \
                             transport/wire.rs decodes"
                        ),
                    ));
                }
            }
            for (line, t) in &rows {
                if !tags.iter().any(|tag| tag == t) {
                    out.push(Diagnostic::error(
                        &doc.path,
                        *line,
                        1,
                        self.id(),
                        format!(
                            "wire table lists `{t}`, which transport/wire.rs does \
                             not decode"
                        ),
                    ));
                }
            }
        }
    }

    fn check_checkpoint_version(
        &self,
        readme: &SourceFile,
        session: &SourceFile,
        out: &mut Vec<Diagnostic>,
    ) {
        let version = match checkpoint_version(&session.text) {
            Ok(v) => v,
            Err(msg) => {
                out.push(Diagnostic::error(&session.path, 1, 1, self.id(), msg));
                return;
            }
        };
        for (line, claimed) in version_claims(&readme.text) {
            if claimed != version {
                out.push(Diagnostic::error(
                    &readme.path,
                    line,
                    1,
                    self.id(),
                    format!(
                        "README claims `version: {claimed}` but Checkpoint::VERSION \
                         is {version} (describe old formats as \"format v{claimed}\" \
                         prose instead)"
                    ),
                ));
            }
        }
    }
}

fn find<'a>(files: &'a [SourceFile], path: &str) -> Option<&'a SourceFile> {
    files.iter().find(|f| f.path == path)
}

/// The tags the decoder accepts: every string literal immediately followed by
/// `=>` in `wire.rs` (i.e. the decode match arms).
fn decode_tags(wire_src: &str) -> Result<Vec<String>, String> {
    let tokens =
        lex(wire_src).map_err(|e| format!("could not lex wire.rs: {}", e.message))?;
    let code: Vec<_> = tokens.iter().filter(|t| !t.is_comment()).collect();
    let mut tags = Vec::new();
    for pair in code.windows(2) {
        if pair[0].kind == TokenKind::Str
            && pair[1].kind == TokenKind::Punct
            && pair[1].text == "=>"
        {
            let tag = pair[0].text.trim_matches('"').to_string();
            if !tags.contains(&tag) {
                tags.push(tag);
            }
        }
    }
    if tags.is_empty() {
        return Err("found no `\"tag\" =>` decode arms in wire.rs".to_string());
    }
    Ok(tags)
}

/// Parse the wire-message markdown table out of `text`. `strip` is a line
/// prefix to remove first (`"//!"` for the module doc, `""` for the README).
/// Returns the 1-based header line and `(line, tag)` for each body row.
#[allow(clippy::type_complexity)]
fn wire_table(text: &str, strip: &str) -> Option<(u32, Vec<(u32, String)>)> {
    let unprefix = |raw: &str| -> String {
        let t = raw.trim_start();
        let t = if strip.is_empty() { t } else { t.strip_prefix(strip).unwrap_or(t) };
        t.trim().to_string()
    };
    let mut lines = text.lines().enumerate();
    let header_line = loop {
        let (i, raw) = lines.next()?;
        let line = unprefix(raw);
        if line.starts_with('|') && line.contains("type") && line.contains("direction") {
            break i as u32 + 1;
        }
    };
    let mut rows = Vec::new();
    for (i, raw) in lines {
        let line = unprefix(raw);
        if !line.starts_with('|') {
            break;
        }
        let cell = line.trim_start_matches('|').split('|').next().unwrap_or("").trim();
        if cell.chars().all(|c| c == '-' || c == ' ') {
            continue; // separator row
        }
        let tag = cell.trim_matches('`').to_string();
        rows.push((i as u32 + 1, tag));
    }
    Some((header_line, rows))
}

/// Extract `pub const VERSION: usize = N` from `session.rs` tokens.
fn checkpoint_version(session_src: &str) -> Result<u64, String> {
    let tokens =
        lex(session_src).map_err(|e| format!("could not lex session.rs: {}", e.message))?;
    let code: Vec<_> = tokens.iter().filter(|t| !t.is_comment()).collect();
    for w in code.windows(5) {
        if w[0].text == "VERSION"
            && w[1].text == ":"
            && w[2].text == "usize"
            && w[3].text == "="
            && w[4].kind == TokenKind::Int
        {
            return w[4]
                .text
                .parse::<u64>()
                .map_err(|_| format!("unparseable Checkpoint::VERSION `{}`", w[4].text));
        }
    }
    Err("no `VERSION: usize = N` constant found in session.rs".to_string())
}

/// Every `` `version: N` `` claim in the README, with its 1-based line.
fn version_claims(readme: &str) -> Vec<(u32, u64)> {
    let mut out = Vec::new();
    for (i, line) in readme.lines().enumerate() {
        let mut rest = line;
        while let Some(pos) = rest.find("`version: ") {
            rest = &rest[pos + "`version: ".len()..];
            let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            if !digits.is_empty() && rest[digits.len()..].starts_with('`') {
                if let Ok(n) = digits.parse::<u64>() {
                    out.push((i as u32 + 1, n));
                }
            }
        }
    }
    out
}
