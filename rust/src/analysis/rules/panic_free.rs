//! `panic-free-lib`: no `unwrap`/`expect`/`panic!` in non-test library code.
//!
//! PR 4 made every input-validation failure a typed [`crate::EngineError`]; a
//! panic in library code aborts the long-lived `admm_serve` loop (or a whole
//! multi-job service) where a typed error would fail one job. Binaries and
//! `main.rs` may panic at the top level (they own the process), `testkit/` and
//! `#[cfg(test)]`/`#[test]` regions assert freely, and genuinely unreachable
//! invariant `expect`s carry an inline `ad-lint: allow(panic-free-lib)` with
//! the invariant spelled out — the allow reason is the documentation.

use super::{under, FileCtx, Rule};
use crate::analysis::diag::Diagnostic;
use crate::analysis::lexer::TokenKind;

pub struct PanicFreeLib;

const EXEMPT: [&str; 3] = ["rust/src/main.rs", "rust/src/bin", "rust/src/testkit"];

impl Rule for PanicFreeLib {
    fn id(&self) -> &'static str {
        "panic-free-lib"
    }

    fn summary(&self) -> &'static str {
        "no unwrap/expect/panic! in non-test library code (typed EngineError \
         policy)"
    }

    fn applies_to(&self, path: &str) -> bool {
        under(path, "rust/src") && !EXEMPT.iter().any(|e| under(path, e))
    }

    fn check_file(&self, ctx: &FileCtx<'_>, out: &mut Vec<Diagnostic>) {
        let toks: Vec<_> = ctx.tokens.iter().filter(|t| !t.is_comment()).collect();
        for (i, t) in toks.iter().enumerate() {
            if t.kind != TokenKind::Ident || ctx.in_test(t.line) {
                continue;
            }
            let next_is = |s: &str| {
                toks.get(i + 1).is_some_and(|n| n.kind == TokenKind::Punct && n.text == s)
            };
            let prev_is_dot =
                i > 0 && toks[i - 1].kind == TokenKind::Punct && toks[i - 1].text == ".";
            let hit = match t.text {
                "panic" => next_is("!"),
                "unwrap" | "expect" => prev_is_dot && next_is("("),
                _ => false,
            };
            if hit {
                out.push(Diagnostic::error(
                    ctx.path,
                    t.line,
                    t.col,
                    self.id(),
                    format!(
                        "`{}` can abort a long-lived service; return a typed \
                         EngineError (or justify the invariant with an inline allow)",
                        t.text
                    ),
                ));
            }
        }
    }
}
