//! The `ad-lint` rule registry.
//!
//! Each rule encodes one of the repo's standing invariants (see the README
//! "Static analysis" section for the one-line rationale of each). Rules are
//! token-level: they receive the lexed stream of one file via [`FileCtx`]
//! (comments and string literals already separated out by the lexer, so a
//! mention of `HashMap` in a doc comment never fires) plus `#[cfg(test)]` /
//! `#[test]` region information. The cross-file `doc-drift` rule instead
//! implements [`Rule::check_tree`] over the whole scanned file set.

use super::diag::Diagnostic;
use super::lexer::Token;
use super::SourceFile;

mod deprecated_surface;
mod doc_drift;
mod float_eq;
mod panic_free;
mod unordered_iter;
mod wallclock;

pub use deprecated_surface::DeprecatedSurface;
pub use doc_drift::DocDrift;
pub use float_eq::FloatEq;
pub use panic_free::PanicFreeLib;
pub use unordered_iter::UnorderedIter;
pub use wallclock::Wallclock;

/// Per-file context handed to [`Rule::check_file`].
pub struct FileCtx<'a> {
    /// Repo-relative path with forward slashes.
    pub path: &'a str,
    /// Lexed token stream (comments included; rules usually skip them).
    pub tokens: &'a [Token<'a>],
    /// 1-based inclusive line ranges covered by `#[cfg(test)]` items or
    /// `#[test]` functions.
    pub test_regions: &'a [(u32, u32)],
}

impl FileCtx<'_> {
    /// Is `line` inside a test-only region?
    pub fn in_test(&self, line: u32) -> bool {
        self.test_regions.iter().any(|&(lo, hi)| line >= lo && line <= hi)
    }
}

/// One static-analysis rule. Implementations are stateless; scoping decisions
/// (`applies_to`) live with the rule so the registry stays declarative.
pub trait Rule {
    /// Stable kebab-case id, used in diagnostics and `ad-lint: allow(...)`.
    fn id(&self) -> &'static str;
    /// One-line description for `--json` output and the README rule table.
    fn summary(&self) -> &'static str;
    /// Should `check_file` run on this repo-relative path at all?
    fn applies_to(&self, _path: &str) -> bool {
        false
    }
    /// Token-level per-file check. Only called when `applies_to` is true.
    fn check_file(&self, _ctx: &FileCtx<'_>, _out: &mut Vec<Diagnostic>) {}
    /// Cross-file structural check over the whole scanned set.
    fn check_tree(&self, _files: &[SourceFile], _out: &mut Vec<Diagnostic>) {}
}

/// All shipped rules, in diagnostic-output order.
pub fn registry() -> Vec<Box<dyn Rule>> {
    vec![
        Box::new(Wallclock),
        Box::new(UnorderedIter),
        Box::new(FloatEq),
        Box::new(PanicFreeLib),
        Box::new(DeprecatedSurface),
        Box::new(DocDrift),
    ]
}

/// Path prefix test on repo-relative forward-slash paths: `path` is `prefix`
/// itself or a file beneath it.
pub(crate) fn under(path: &str, prefix: &str) -> bool {
    match path.strip_prefix(prefix) {
        Some(rest) => rest.is_empty() || rest.starts_with('/'),
        None => false,
    }
}
