//! `deprecated-surface`: the legacy free-function drivers stay quarantined.
//!
//! PR 5 migrated every in-tree caller off the deprecated pre-`Session` entry
//! points by hand; this rule mechanizes that sweep so the surface cannot grow
//! back. The deprecated names may appear only in their defining modules, the
//! prelude re-export, and the allowlisted pin-test modules that exist
//! precisely to keep the legacy paths bit-identical
//! (`testkit/drivers.rs`, `tests/engine_equivalence.rs`,
//! `tests/session_api.rs`). Everything else goes through
//! `Session::builder()`.

use super::{under, FileCtx, Rule};
use crate::analysis::diag::Diagnostic;
use crate::analysis::lexer::TokenKind;

pub struct DeprecatedSurface;

/// The `#[deprecated]` items as of PR 10 (`LegacySourceAdapter` is *not*
/// deprecated — it is the sanctioned migration shim).
const DEPRECATED: [&str; 8] = [
    "run_sync_admm",
    "run_sync_admm_with_solver",
    "run_master_pov",
    "run_master_pov_with_solver",
    "run_alt_scheme",
    "run_alt_scheme_with_solver",
    "run_trace_driven",
    "LegacyWorkerSource",
];

const ALLOWED: [&str; 8] = [
    "rust/src/admm/sync.rs",
    "rust/src/admm/master_pov.rs",
    "rust/src/admm/alt_scheme.rs",
    "rust/src/admm/engine.rs",
    "rust/src/lib.rs",
    "rust/src/testkit/drivers.rs",
    "rust/tests/engine_equivalence.rs",
    "rust/tests/session_api.rs",
];

impl Rule for DeprecatedSurface {
    fn id(&self) -> &'static str {
        "deprecated-surface"
    }

    fn summary(&self) -> &'static str {
        "deprecated free-function drivers only in defining modules and \
         allowlisted pin tests (use Session::builder())"
    }

    fn applies_to(&self, path: &str) -> bool {
        path.ends_with(".rs") && !ALLOWED.iter().any(|a| under(path, a))
    }

    fn check_file(&self, ctx: &FileCtx<'_>, out: &mut Vec<Diagnostic>) {
        for t in ctx.tokens {
            if t.kind == TokenKind::Ident && DEPRECATED.contains(&t.text) {
                out.push(Diagnostic::error(
                    ctx.path,
                    t.line,
                    t.col,
                    self.id(),
                    format!(
                        "`{}` is a deprecated pre-Session driver; use \
                         Session::builder() (pin tests live in the allowlisted \
                         modules only)",
                        t.text
                    ),
                ));
            }
        }
    }
}
