//! `wallclock`: no wall-clock time in virtual-time code paths.
//!
//! The deterministic cluster replays arrivals on a virtual clock; any
//! `Instant::now()`, `SystemTime`, or `thread::sleep` in those paths makes
//! runs irreproducible. Real-time modules are allowlisted:
//! `cluster/threaded.rs` (the OS-thread source is real time by definition),
//! `cluster/transport/**` (sockets block on real deadlines), `bench/` (timing
//! harness), and binaries. `use` declarations are skipped — the call or
//! construction site is the violation, not the import.

use super::{under, FileCtx, Rule};
use crate::analysis::diag::Diagnostic;
use crate::analysis::lexer::TokenKind;

pub struct Wallclock;

const ALLOWED: [&str; 6] = [
    "rust/src/main.rs",
    "rust/src/bin",
    "rust/src/bench",
    "rust/src/cluster/threaded.rs",
    "rust/src/cluster/transport",
    "rust/src/testkit",
];

impl Rule for Wallclock {
    fn id(&self) -> &'static str {
        "wallclock"
    }

    fn summary(&self) -> &'static str {
        "no Instant::now/SystemTime/thread::sleep outside real-time modules \
         (virtual-time determinism)"
    }

    fn applies_to(&self, path: &str) -> bool {
        under(path, "rust/src") && !ALLOWED.iter().any(|a| under(path, a))
    }

    fn check_file(&self, ctx: &FileCtx<'_>, out: &mut Vec<Diagnostic>) {
        let toks: Vec<_> = ctx.tokens.iter().filter(|t| !t.is_comment()).collect();
        let mut in_use = false;
        for (i, t) in toks.iter().enumerate() {
            if t.kind == TokenKind::Ident && t.text == "use" {
                in_use = true;
            } else if in_use {
                if t.kind == TokenKind::Punct && t.text == ";" {
                    in_use = false;
                }
                continue;
            }
            if t.kind != TokenKind::Ident || ctx.in_test(t.line) {
                continue;
            }
            let flagged = match t.text {
                "Instant" | "SystemTime" => true,
                // `thread::sleep` / `std::thread::sleep`, not a local `sleep`.
                "sleep" => {
                    i >= 2
                        && toks[i - 1].text == "::"
                        && toks[i - 2].kind == TokenKind::Ident
                        && toks[i - 2].text == "thread"
                }
                _ => false,
            };
            if flagged {
                out.push(Diagnostic::error(
                    ctx.path,
                    t.line,
                    t.col,
                    self.id(),
                    format!(
                        "`{}` is wall-clock; virtual-time paths must go through the \
                         scheduler (real time is allowlisted only in cluster/threaded.rs, \
                         cluster/transport/, bench/, and binaries)",
                        t.text
                    ),
                ));
            }
        }
    }
}
