//! `unordered-iter`: no `HashMap`/`HashSet` in bit-identical layers.
//!
//! `std::collections::HashMap` iteration order is unspecified (and seeded per
//! process), so any use inside the ADMM engine, the virtual-time simulator,
//! the multi-master group, or the checkpoint/wire codecs risks iteration-order
//! nondeterminism leaking into iterate histories or serialized bytes. Those
//! layers must use `Vec`, `BTreeMap`, or index-keyed arrays. This rule is a
//! conservative over-approximation: it flags the *type names* appearing at all
//! in the scoped files, because even an "unordered but never iterated" map is
//! one refactor away from a byte-instability bug.

use super::{under, FileCtx, Rule};
use crate::analysis::diag::Diagnostic;
use crate::analysis::lexer::TokenKind;

pub struct UnorderedIter;

const SCOPED: [&str; 6] = [
    "rust/src/admm",
    "rust/src/cluster/sim.rs",
    "rust/src/cluster/multimaster",
    "rust/src/cluster/transport/wire.rs",
    "rust/src/cluster/transport/frame.rs",
    "rust/src/bench/json.rs",
];

impl Rule for UnorderedIter {
    fn id(&self) -> &'static str {
        "unordered-iter"
    }

    fn summary(&self) -> &'static str {
        "no HashMap/HashSet in the engine, simulator, multi-master, or codec \
         layers (iteration order breaks bit-identity)"
    }

    fn applies_to(&self, path: &str) -> bool {
        SCOPED.iter().any(|s| under(path, s))
    }

    fn check_file(&self, ctx: &FileCtx<'_>, out: &mut Vec<Diagnostic>) {
        for t in ctx.tokens {
            if t.kind == TokenKind::Ident && (t.text == "HashMap" || t.text == "HashSet") {
                out.push(Diagnostic::error(
                    ctx.path,
                    t.line,
                    t.col,
                    self.id(),
                    format!(
                        "`{}` has unspecified iteration order; bit-identical layers \
                         must use Vec/BTreeMap/index-keyed state",
                        t.text
                    ),
                ));
            }
        }
    }
}
